//! Integration tests for the continuous-batching decode engine. These run
//! entirely on the pure-Rust `nn` path (no AOT artifacts needed): the engine
//! is driven deterministically through `submit`/`step`, and its output is
//! cross-checked against full-prefix re-forwarding — including through
//! fake-quant (SF4) weights, proving the quantized weight path works
//! unchanged under incremental decode.

use std::sync::mpsc;

use llm_datatypes::coordinator::pipeline::{fake_quant_checkpoint, PipelineConfig};
use llm_datatypes::coordinator::{corpus_for, trainer};
use llm_datatypes::model_io::{zoo, Checkpoint, ModelConfig};
use llm_datatypes::nn;
use llm_datatypes::serving::{
    DecodeRequest, Engine, EngineConfig, FinishReason, SchedulerConfig, TokenEvent,
};
use llm_datatypes::tensor::argmax;

fn engine_for(cfg: ModelConfig, ckpt: Checkpoint, slots: usize) -> Engine {
    Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots,
            scheduler: SchedulerConfig { max_batch: slots, ..SchedulerConfig::default() },
            ..EngineConfig::default()
        },
    )
}

fn collect(rx: &mpsc::Receiver<TokenEvent>) -> (Vec<i32>, Option<FinishReason>) {
    let mut tokens = Vec::new();
    let mut finished = None;
    while let Ok(ev) = rx.try_recv() {
        match ev {
            TokenEvent::Token { token, index, .. } => {
                assert_eq!(index, tokens.len(), "stream indices are contiguous");
                tokens.push(token);
            }
            TokenEvent::Finished { reason, generated, .. } => {
                finished = Some((reason, generated));
            }
            TokenEvent::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
        }
    }
    if let Some((_, generated)) = finished {
        assert_eq!(generated, tokens.len(), "Finished reports the streamed count");
    }
    (tokens, finished.map(|(r, _)| r))
}

/// Greedy reference: re-forward the full growing prefix every step.
fn reference_greedy(
    cfg: &ModelConfig,
    ckpt: &Checkpoint,
    prompt: &[i32],
    max_new: usize,
) -> Vec<i32> {
    let mut ctxt = prompt.to_vec();
    let mut out = Vec::new();
    for _ in 0..max_new {
        let logits = nn::forward_lm(cfg, ckpt, &ctxt, None).unwrap();
        let next = argmax(logits.row(ctxt.len() - 1)) as i32;
        out.push(next);
        if ctxt.len() >= cfg.seq {
            break;
        }
        ctxt.push(next);
    }
    out
}

#[test]
fn engine_decode_matches_full_reforward_fp32_and_sf4() {
    // the greedy-equivalence acceptance test, end to end through the engine
    let cfg = zoo("nano").unwrap();
    let fp32 = trainer::init_lm_params(&cfg, 0xdec0de);
    let corpus = corpus_for(&cfg);
    let sf4 =
        fake_quant_checkpoint(&cfg, &fp32, &PipelineConfig::weight_only("sf4"), &corpus).unwrap();
    let prompt: Vec<i32> = (0..6).map(|i| (i * 3 + 2) % cfg.vocab as i32).collect();
    let max_new = 10usize;
    for ckpt in [fp32, sf4] {
        let expect = reference_greedy(&cfg, &ckpt, &prompt, max_new);
        let mut eng = engine_for(cfg, ckpt, 2);
        let (req, rx) = DecodeRequest::new(prompt.clone(), max_new);
        eng.submit(req);
        while eng.has_work() {
            eng.step().unwrap();
        }
        let (tokens, fin) = collect(&rx);
        assert_eq!(tokens, expect, "incremental path must equal re-forwarding");
        assert_eq!(fin, Some(FinishReason::MaxTokens));
    }
}

#[test]
fn late_request_joins_mid_flight_and_both_finish() {
    // continuous-batching acceptance: B admitted after A started decoding
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0xba7c);
    let expect_a = reference_greedy(&cfg, &ckpt, &[1, 2, 3], 12);
    let expect_b = reference_greedy(&cfg, &ckpt, &[7, 8], 4);
    let mut eng = engine_for(cfg, ckpt, 4);

    let (req_a, rx_a) = DecodeRequest::new(vec![1, 2, 3], 12);
    eng.submit(req_a);
    for _ in 0..4 {
        eng.step().unwrap(); // A: prefill+token, then 3 decode steps
    }
    let (a_head, a_fin) = collect(&rx_a);
    assert!(a_head.len() >= 3, "A must already be decoding");
    assert!(a_fin.is_none());

    let (req_b, rx_b) = DecodeRequest::new(vec![7, 8], 4);
    eng.submit(req_b);
    eng.step().unwrap();
    assert_eq!(eng.cache().slots_in_use(), 2, "B joined while A is in flight");

    while eng.has_work() {
        eng.step().unwrap();
    }
    let (a_tail, a_fin) = collect(&rx_a);
    let (b_tokens, b_fin) = collect(&rx_b);
    let a_tokens: Vec<i32> = a_head.into_iter().chain(a_tail).collect();
    assert_eq!(a_tokens, expect_a, "A's stream is unperturbed by B joining");
    assert_eq!(b_tokens, expect_b);
    assert_eq!(a_fin, Some(FinishReason::MaxTokens));
    assert_eq!(b_fin, Some(FinishReason::MaxTokens));
}

#[test]
fn slot_churn_under_many_short_requests() {
    // more requests than slots: retirement must keep refilling the batch
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x51075);
    let mut eng = engine_for(cfg, ckpt, 2);
    let mut rxs = Vec::new();
    for i in 0..7i32 {
        let (req, rx) = DecodeRequest::new(vec![i + 1, i + 2], 3);
        eng.submit(req);
        rxs.push(rx);
    }
    let mut max_in_use = 0;
    while eng.has_work() {
        eng.step().unwrap();
        max_in_use = max_in_use.max(eng.cache().slots_in_use());
    }
    assert_eq!(max_in_use, 2, "pool saturates but never exceeds its size");
    assert_eq!(eng.cache().slots_in_use(), 0);
    for rx in &rxs {
        let (tokens, fin) = collect(rx);
        assert_eq!(tokens.len(), 3);
        assert_eq!(fin, Some(FinishReason::MaxTokens));
    }
    let report = eng.report();
    assert_eq!(report.completed, 7);
    assert!(report.mean_occupancy > 1.0, "batch stayed multi-tenant: {}", report.mean_occupancy);
}
