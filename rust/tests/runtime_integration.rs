//! Integration: load the AOT artifacts through PJRT, execute them, and
//! cross-validate the Rust reference model against the XLA graphs.
//!
//! Requires `make artifacts` (skips with a clear message otherwise).

use std::collections::HashMap;

use llm_datatypes::formats;
use llm_datatypes::model_io::{zoo, Checkpoint};
use llm_datatypes::nn;
use llm_datatypes::quant::{quantize_weight, BlockSize, Calib, QuantConfig};
use llm_datatypes::rng::Pcg64;
use llm_datatypes::runtime::{Engine, Value};
use llm_datatypes::tensor::Tensor;

/// One shared PJRT client: concurrent TfrtCpuClient construction from
/// multiple test threads segfaults inside xla_extension, so every test goes
/// through this OnceLock (and the quantized sweep serializes executions).
static ENGINE: std::sync::OnceLock<Option<Engine>> = std::sync::OnceLock::new();

fn engine() -> Option<&'static Engine> {
    ENGINE
        .get_or_init(|| {
            if std::path::Path::new("artifacts/MANIFEST.txt").exists() {
                Some(Engine::cpu("artifacts").expect("PJRT CPU client"))
            } else {
                eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
                None
            }
        })
        .as_ref()
}

fn random_ckpt(cfg: &llm_datatypes::model_io::ModelConfig, seed: u64) -> Checkpoint {
    let mut rng = Pcg64::new(seed);
    let mut c = Checkpoint::new();
    for (name, shape) in cfg.param_specs() {
        let n: usize = shape.iter().product();
        let leaf = name.rsplit('.').next().unwrap();
        let t = if leaf.ends_with("_g") {
            Tensor::full(&shape, 1.0)
        } else if leaf.ends_with("_b") {
            Tensor::zeros(&shape)
        } else {
            let std = if leaf == "embed" || leaf == "pos" {
                0.02
            } else {
                (2.0 / shape[0] as f64).sqrt()
            };
            Tensor::new(&shape, rng.normal_vec(n, std))
        };
        c.insert(&name, t);
    }
    c
}

fn fp32_inputs(
    cfg: &llm_datatypes::model_io::ModelConfig,
    ckpt: &Checkpoint,
    tokens: Vec<i32>,
    s: usize,
) -> Vec<Value> {
    let mut inputs = vec![Value::I32(tokens, vec![cfg.batch_eval, s])];
    for (name, _) in cfg.param_specs() {
        inputs.push(Value::F32(ckpt.get(&name).unwrap().clone()));
    }
    inputs
}

#[test]
fn lut_matmul_bench_artifact_matches_host_math() {
    let Some(engine) = engine() else { return };
    let exe = engine.load("lut_matmul_bench").unwrap();
    let (m, k, n, blk) = (256usize, 512usize, 512usize, 128usize);
    let mut rng = Pcg64::new(1);
    let x = Tensor::new(&[m, k], rng.normal_vec(m * k, 1.0));
    let codes: Vec<i8> = (0..k * n).map(|_| rng.below(16) as i8).collect();
    let scales =
        Tensor::new(&[k / blk, n], (0..k / blk * n).map(|_| rng.range(0.5, 2.0) as f32).collect());
    let spec = formats::must("sf4");
    let cb = Tensor::new(&[16], spec.padded16());

    let outs = exe
        .run(&[
            Value::F32(x.clone()),
            Value::I8(codes.clone(), vec![k, n]),
            Value::F32(scales.clone()),
            Value::F32(cb.clone()),
        ])
        .unwrap();
    let y = outs[0].as_f32().unwrap();
    assert_eq!(y.shape(), &[m, n]);

    // host-side dequant + matmul oracle
    let cbv = cb.data();
    let mut w = Tensor::zeros(&[k, n]);
    for kk in 0..k {
        for j in 0..n {
            let s = scales.at2(kk / blk, j);
            w.set2(kk, j, cbv[codes[kk * n + j] as usize] * s);
        }
    }
    let want = x.matmul(&w);
    let mut max_rel = 0.0f32;
    for (a, b) in y.data().iter().zip(want.data()) {
        max_rel = max_rel.max((a - b).abs() / (b.abs() + 1.0));
    }
    assert!(max_rel < 1e-4, "max rel err {max_rel}");
}

#[test]
fn fp32_fwd_artifact_matches_rust_reference() {
    let Some(engine) = engine() else { return };
    let cfg = zoo("nano").unwrap();
    let exe = engine.load("lm_fwd_fp32_nano").unwrap();
    let ckpt = random_ckpt(&cfg, 42);
    let mut rng = Pcg64::new(7);
    let tokens: Vec<i32> =
        (0..cfg.batch_eval * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect();

    let outs = exe.run(&fp32_inputs(&cfg, &ckpt, tokens.clone(), cfg.seq)).unwrap();
    let logits = outs[0].as_f32().unwrap();
    assert_eq!(logits.shape(), &[cfg.batch_eval, cfg.seq, cfg.vocab]);

    // per-sequence cross-check against the pure-Rust forward
    for b in 0..cfg.batch_eval {
        let seq = &tokens[b * cfg.seq..(b + 1) * cfg.seq];
        let want = nn::forward_lm(&cfg, &ckpt, seq, None).unwrap();
        for i in 0..cfg.seq {
            for v in 0..cfg.vocab {
                let got = logits.data()[(b * cfg.seq + i) * cfg.vocab + v];
                let w = want.at2(i, v);
                assert!(
                    (got - w).abs() < 2e-3 + 2e-3 * w.abs(),
                    "b={b} i={i} v={v}: xla={got} rust={w}"
                );
            }
        }
    }
}

#[test]
fn quantized_fwd_artifact_runs_all_formats() {
    let Some(engine) = engine() else { return };
    let cfg = zoo("nano").unwrap();
    let exe = engine.load("lm_fwd_nano").unwrap();
    let ckpt = random_ckpt(&cfg, 43);
    let mut rng = Pcg64::new(8);
    let tokens: Vec<i32> =
        (0..cfg.batch_eval * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect();

    for fmt in ["sf4", "nf4", "int4", "e2m1", "e2m1_sp", "apot4"] {
        let spec = formats::must(fmt);
        let qcfg = QuantConfig {
            format: spec.clone(),
            block: BlockSize::Sub(32),
            calib: Calib::None,
        };
        let mut named: HashMap<String, Value> = HashMap::new();
        named.insert(
            "tokens".into(),
            Value::I32(tokens.clone(), vec![cfg.batch_eval, cfg.seq]),
        );
        let qnames = cfg.quant_linear_names();
        for (name, _) in cfg.param_specs() {
            let t = ckpt.get(&name).unwrap();
            if qnames.contains(&name) {
                let q = quantize_weight(t, &qcfg);
                named.insert(format!("{name}.codes"), Value::I8(q.codes.clone(), vec![q.k, q.n]));
                named.insert(format!("{name}.scales"), Value::F32(q.expanded_scales()));
            } else {
                named.insert(name.clone(), Value::F32(t.clone()));
            }
        }
        named.insert("codebook".into(), Value::F32(Tensor::new(&[16], spec.padded16())));
        let outs = exe.run_named(&named).unwrap();
        let logits = outs[0].as_f32().unwrap();
        assert!(logits.data().iter().all(|v| v.is_finite()), "{fmt}: non-finite logits");

        // cross-check: XLA quantized fwd == Rust fwd over dequantized ckpt
        let mut deq_ckpt = ckpt.clone();
        for name in &qnames {
            let q = quantize_weight(ckpt.get(name).unwrap(), &qcfg);
            deq_ckpt.insert(name, q.dequant(&spec));
        }
        let seq0 = &tokens[..cfg.seq];
        let want = nn::forward_lm(&cfg, &deq_ckpt, seq0, None).unwrap();
        for i in 0..cfg.seq {
            for v in 0..cfg.vocab {
                let got = logits.data()[i * cfg.vocab + v];
                let w = want.at2(i, v);
                assert!(
                    (got - w).abs() < 3e-3 + 3e-3 * w.abs(),
                    "{fmt} i={i} v={v}: xla={got} rust={w}"
                );
            }
        }
    }
}

#[test]
fn bound_inputs_reuse_device_weights() {
    let Some(engine) = engine() else { return };
    let cfg = zoo("nano").unwrap();
    let exe = engine.load("lm_fwd_fp32_nano").unwrap();
    let ckpt = random_ckpt(&cfg, 44);
    let mut fixed: HashMap<String, Value> = HashMap::new();
    for (name, _) in cfg.param_specs() {
        fixed.insert(name.clone(), Value::F32(ckpt.get(&name).unwrap().clone()));
    }
    let bound = exe.bind(&fixed).unwrap();
    assert_eq!(bound.missing, vec!["tokens".to_string()]);

    let mut rng = Pcg64::new(9);
    let tokens: Vec<i32> =
        (0..cfg.batch_eval * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect();
    let mut rest = HashMap::new();
    rest.insert(
        "tokens".to_string(),
        Value::I32(tokens.clone(), vec![cfg.batch_eval, cfg.seq]),
    );
    let out_bound = exe.run_bound(&bound, &rest).unwrap();
    let out_plain = exe.run(&fp32_inputs(&cfg, &ckpt, tokens, cfg.seq)).unwrap();
    let a = out_bound[0].as_f32().unwrap();
    let b = out_plain[0].as_f32().unwrap();
    for (x, y) in a.data().iter().zip(b.data()) {
        assert!((x - y).abs() < 1e-5);
    }
}

#[test]
fn train_step_artifact_decreases_loss() {
    let Some(engine) = engine() else { return };
    let cfg = zoo("nano").unwrap();
    let exe = engine.load("lm_train_nano").unwrap();
    let ckpt = random_ckpt(&cfg, 45);
    let specs = cfg.param_specs();

    let mut params: Vec<Value> =
        specs.iter().map(|(n, _)| Value::F32(ckpt.get(n).unwrap().clone())).collect();
    let mut m: Vec<Value> =
        specs.iter().map(|(_, s)| Value::F32(Tensor::zeros(s))).collect();
    let mut v: Vec<Value> =
        specs.iter().map(|(_, s)| Value::F32(Tensor::zeros(s))).collect();

    let mut rng = Pcg64::new(10);
    let tokens: Vec<i32> = (0..cfg.batch_train * (cfg.seq + 1))
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();

    let mut losses = Vec::new();
    for step in 0..12 {
        let mut inputs = vec![
            Value::F32(Tensor::scalar(step as f32)),
            Value::I32(tokens.clone(), vec![cfg.batch_train, cfg.seq + 1]),
        ];
        inputs.extend(params.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        let outs = exe.run(&inputs).unwrap();
        losses.push(outs[0].scalar_f32().unwrap());
        let np = specs.len();
        params = outs[1..1 + np].to_vec();
        m = outs[1 + np..1 + 2 * np].to_vec();
        v = outs[1 + 2 * np..1 + 3 * np].to_vec();
    }
    assert!(
        losses[11] < losses[0] - 0.3,
        "loss should drop on a repeated batch: {losses:?}"
    );
}
