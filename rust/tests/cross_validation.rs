//! Cross-layer validation: the Rust-derived codebooks must match the
//! Python-derived ones bit-for-bit-ish (both implement paper Algorithm 1 /
//! Table 15 independently), and quantizer/distribution invariants hold
//! under randomized stress (hand-rolled property tests; no proptest in the
//! offline vendor set).

use llm_datatypes::distfit;
use llm_datatypes::formats;
use llm_datatypes::quant::{quantize_weight, BlockSize, Calib, QuantConfig};
use llm_datatypes::rng::Pcg64;
use llm_datatypes::tensor::Tensor;

#[test]
fn rust_codebooks_match_python_emission() {
    let path = "artifacts/codebooks.tsv";
    let Ok(text) = std::fs::read_to_string(path) else {
        eprintln!("skipping: {path} missing (run `make artifacts`)");
        return;
    };
    let mut checked = 0;
    for line in text.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split('\t').collect();
        let name = parts[0];
        let Some(spec) = formats::get(name) else {
            // python-only entries (e.g. int8 reference) are fine
            continue;
        };
        let py_values: Vec<f64> = parts[3..].iter().map(|v| v.parse().unwrap()).collect();
        assert_eq!(
            py_values.len(),
            spec.codebook.len(),
            "{name}: value count differs (py {} vs rust {})",
            py_values.len(),
            spec.codebook.len()
        );
        for (p, r) in py_values.iter().zip(&spec.codebook) {
            assert!(
                (p - r).abs() < 5e-7,
                "{name}: python {p} vs rust {r} — Algorithm 1 drift"
            );
        }
        checked += 1;
    }
    assert!(checked >= 20, "only {checked} formats cross-checked");
}

/// Property: dequantized output is always a codebook value times the block
/// scale, for every format, across random shapes/seeds.
#[test]
fn prop_dequant_lands_on_grid() {
    let mut rng = Pcg64::new(0x9409);
    for trial in 0..60 {
        let fmt = {
            let names = formats::all_names();
            names[rng.below(names.len())]
        };
        let spec = formats::must(fmt);
        let kb = 1 + rng.below(4);
        let block = [16, 32, 64][rng.below(3)];
        let k = kb * block;
        let n = 1 + rng.below(24);
        let scale_mag = 10f64.powf(rng.range(-3.0, 2.0));
        let w = Tensor::new(&[k, n], rng.student_t_vec(k * n, 4.0, scale_mag));
        let cfg = QuantConfig {
            format: spec.clone(),
            block: BlockSize::Sub(block),
            calib: if rng.below(2) == 0 { Calib::None } else { Calib::Mse },
        };
        let q = quantize_weight(&w, &cfg);
        let deq = q.dequant(&spec);
        for kk in 0..k {
            for j in 0..n {
                let s = q.scales.at2(kk / block, j);
                let v = deq.at2(kk, j);
                let vn = v / s;
                let nearest = spec
                    .codebook
                    .iter()
                    .map(|&c| (c - vn as f64).abs())
                    .fold(f64::INFINITY, f64::min);
                assert!(
                    nearest < 1e-5,
                    "trial {trial} {fmt}: value {vn} off-grid (scale {s})"
                );
            }
        }
    }
}

/// Property: quantization error is bounded by scale x worst-case cell.
#[test]
fn prop_error_bound() {
    let mut rng = Pcg64::new(0x0b0b);
    for _ in 0..40 {
        let names = formats::all_names();
        let fmt = names[rng.below(names.len())];
        let spec = formats::must(fmt);
        // worst-case normalized error: max(mid-gap, edge clip)
        let mids = spec.midpoints();
        let mut worst = 0.0f64;
        for (i, w) in spec.codebook.windows(2).enumerate() {
            worst = worst.max((w[1] - w[0]) / 2.0 + 1e-12);
            let _ = i;
        }
        worst = worst.max(1.0 - spec.codebook.last().unwrap());
        worst = worst.max(1.0 + spec.codebook.first().unwrap());
        let _ = mids;
        let k = 64;
        let w = Tensor::new(&[k, 4], rng.normal_vec(k * 4, 0.5));
        let cfg = QuantConfig {
            format: spec.clone(),
            block: BlockSize::Sub(64),
            calib: Calib::None,
        };
        let q = quantize_weight(&w, &cfg);
        let deq = q.dequant(&spec);
        for kk in 0..k {
            for j in 0..4 {
                let s = q.scales.at2(0, j) as f64;
                let e = (w.at2(kk, j) - deq.at2(kk, j)).abs() as f64;
                assert!(
                    e <= s * worst * (1.0 + 1e-5) + 1e-12,
                    "{fmt}: err {e} > bound {} (scale {s})",
                    s * worst
                );
            }
        }
    }
}

/// Property: the t-fit degrees of freedom tracks the planted parameter
/// monotonically across the paper's range.
#[test]
fn prop_distfit_monotone_in_nu() {
    let mut rng = Pcg64::new(77);
    let mut fitted = Vec::new();
    for nu in [2.0, 4.0, 8.0, 16.0] {
        let xs: Vec<f32> = rng.student_t_vec(12_000, nu, 1.0);
        fitted.push(distfit::fit_student_t(&distfit::subsample(&xs, 12_000)).nu);
    }
    for w in fitted.windows(2) {
        assert!(w[0] < w[1], "fit not monotone: {fitted:?}");
    }
}

/// Property: scales never zero/negative/NaN even on adversarial blocks.
#[test]
fn prop_scales_always_valid() {
    let spec = formats::must("sf4");
    for data in [
        vec![0.0f32; 128],                       // all-zero block
        vec![f32::MIN_POSITIVE; 128],            // denormal-tiny
        (0..128).map(|i| if i == 0 { 1e30 } else { 0.0 }).collect::<Vec<_>>(), // outlier
    ] {
        let w = Tensor::new(&[128, 1], data);
        for calib in [Calib::None, Calib::Mse] {
            let cfg = QuantConfig {
                format: spec.clone(),
                block: BlockSize::Sub(128),
                calib,
            };
            let q = quantize_weight(&w, &cfg);
            let s = q.scales.at2(0, 0);
            assert!(s.is_finite() && s > 0.0, "bad scale {s}");
        }
    }
}
