//! Differential harness for the `tensor::simd` microkernels (PR 10).
//!
//! The contract under test: every SIMD path — the gemm micro-tile, the
//! nibble -> LUT row expansion inside `lut_gemm`, and the paged packed-KV
//! attention — is **bit-identical** to the scalar oracle it replaced, for
//! every <= 4-bit codebook, every batch size 1..=8, and ragged shapes that
//! exercise the vector tails (odd N, non-multiple-of-tile K, partially
//! filled last KV page). On a host with no vector ISA both sides run the
//! scalar loops and the comparisons pass trivially — the harness is then a
//! dispatch sanity check, and CI's `-Ctarget-cpu=native` leg provides the
//! vector coverage.
//!
//! W4A4 is the exception by design: quantizing the activations changes the
//! numbers, so its gate is an NLL delta on the `micro` zoo model (the
//! Table 8 contract), not bit-identity.
//!
//! The force-scalar flag is process-global, so every test that toggles it
//! serializes through one poison-tolerant mutex and restores the
//! environment's setting before returning.

use std::sync::{Mutex, MutexGuard};

use llm_datatypes::coordinator::pipeline::{w4a4_checkpoint, PipelineConfig};
use llm_datatypes::coordinator::{corpus_for, trainer};
use llm_datatypes::formats;
use llm_datatypes::model_io::{zoo, Checkpoint};
use llm_datatypes::nn::{self, SeqKvCache};
use llm_datatypes::quant::{
    lut_gemm, quantize_weight, BlockSize, Calib, KvFormat, PackedWeight, QuantConfig,
};
use llm_datatypes::rng::Pcg64;
use llm_datatypes::serving::{
    DecodeRequest, Engine, EngineConfig, FinishReason, SchedulerConfig, TokenEvent,
};
use llm_datatypes::tensor::{
    argmax, gemm, lut_attend_head_paged, lut_attend_head_paged_scalar, simd, PagedPackedLane,
    Tensor,
};

static LOCK: Mutex<()> = Mutex::new(());

/// Serialize force-flag toggling; a panicked holder must not wedge the rest
/// of the suite, so poison is tolerated.
fn guard() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Hand the dispatch back to whatever LLMDT_FORCE_SCALAR says.
fn restore_env_force() {
    simd::force_scalar(
        std::env::var("LLMDT_FORCE_SCALAR")
            .map(|v| !(v.is_empty() || v == "0"))
            .unwrap_or(false),
    );
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: element {i} diverged ({x:?} vs {y:?})"
        );
    }
}

/// Distinct deterministic seed per format name (no hash dep needed).
fn seed_for(name: &str) -> u64 {
    name.bytes().fold(0x51d0_u64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64))
}

/// Page `p` of a row-major buffer split into `per_page`-element pages; the
/// last page may be short (ragged block-table tail).
fn page_slice<T>(buf: &[T], p: usize, per_page: usize) -> &[T] {
    &buf[p * per_page..buf.len().min((p + 1) * per_page)]
}

/// gemm: the vectorized MR x NR micro-tile (and its scalar column
/// remainder) must be bit-identical to the scalar oracle chain for ragged
/// (M, K, N) — N crossing the NR=16 lanes, K crossing the KC=256 panel,
/// M covering partial MR=4 tiles and batch sizes 1..=8.
#[test]
fn gemm_simd_bit_identical_to_scalar_oracle() {
    let _g = guard();
    let mut rng = Pcg64::new(0x9a3d);
    for &(k, n) in &[(7usize, 5usize), (64, 16), (100, 33), (256, 1), (300, 130)] {
        for m in (1..=8usize).chain([13]) {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let mut out_s = vec![0.0f32; m * n];
            let mut out_v = vec![0.0f32; m * n];
            simd::force_scalar(true);
            gemm(m, k, n, &a, &b, &mut out_s);
            simd::force_scalar(false);
            gemm(m, k, n, &a, &b, &mut out_v);
            assert_bits_eq(&out_s, &out_v, &format!("gemm m={m} k={k} n={n}"));
        }
    }
    restore_env_force();
}

/// lut_gemm: the shuffle-based nibble -> LUT expansion must reproduce the
/// scalar expansion bit for bit on every packable (<= 16-value) codebook,
/// batch 1..=8, including the odd-N padding nibble.
#[test]
fn lut_gemm_simd_bit_identical_across_packable_formats() {
    let _g = guard();
    for name in formats::packable_names() {
        let spec = formats::must(name);
        let mut rng = Pcg64::new(seed_for(name));
        let (k, n, block) = (96usize, 33usize, 32usize);
        let w = Tensor::new(&[k, n], rng.student_t_vec(k * n, 5.0, 0.05));
        let q = quantize_weight(
            &w,
            &QuantConfig { format: spec.clone(), block: BlockSize::Sub(block), calib: Calib::None },
        );
        let packed = PackedWeight::from_quantized(&q, &spec);
        for m in 1..=8usize {
            let x = Tensor::new(&[m, k], rng.normal_vec(m * k, 1.0));
            simd::force_scalar(true);
            let ys = lut_gemm(&x, &packed);
            simd::force_scalar(false);
            let yv = lut_gemm(&x, &packed);
            assert_bits_eq(ys.data(), yv.data(), &format!("{name} lut_gemm m={m}"));
        }
    }
    restore_env_force();
}

/// lut_gemm ragged-shape sweep on one format: K panels that are not
/// multiples of the 16-wide expansion chunk, single-column N, N around the
/// tile edge, and a 256-wide scale block (one block per KC panel).
#[test]
fn lut_gemm_simd_bit_identical_on_ragged_shapes() {
    let _g = guard();
    let spec = formats::must("sf4");
    let mut rng = Pcg64::new(0x4a66);
    for &(m, k, n, block) in &[
        (1usize, 64usize, 1usize, 32usize),
        (2, 128, 17, 64),
        (5, 96, 40, 48),
        (7, 320, 129, 64),
        (3, 512, 15, 256),
    ] {
        let w = Tensor::new(&[k, n], rng.student_t_vec(k * n, 5.0, 0.05));
        let q = quantize_weight(
            &w,
            &QuantConfig { format: spec.clone(), block: BlockSize::Sub(block), calib: Calib::None },
        );
        let packed = PackedWeight::from_quantized(&q, &spec);
        let x = Tensor::new(&[m, k], rng.normal_vec(m * k, 1.0));
        simd::force_scalar(true);
        let ys = lut_gemm(&x, &packed);
        simd::force_scalar(false);
        let yv = lut_gemm(&x, &packed);
        assert_bits_eq(ys.data(), yv.data(), &format!("lut_gemm m={m} k={k} n={n} blk={block}"));
    }
    restore_env_force();
}

/// Paged packed-KV attention: walk a block table whose last page is
/// partially filled, on every packable codebook, and require the SIMD
/// dequant-tile path — and the forced-scalar dispatch — to match the
/// scalar oracle body (`lut_attend_head_paged_scalar`) bit for bit.
#[test]
fn lut_attend_paged_walk_simd_bit_identical_to_scalar() {
    let _g = guard();
    let (d, heads) = (64usize, 2usize);
    let dh = d / heads;
    let page_rows = 5usize;
    for name in formats::packable_names() {
        let spec = formats::must(name);
        let kvf = KvFormat::new(&spec, dh);
        let mut rng = Pcg64::new(seed_for(name) ^ 0xa77);
        for &rows in &[1usize, 3, 5, 13] {
            let row_bytes = kvf.codes_per_row(d);
            let s_per = kvf.scales_per_row(d);
            let mut mk = |seed: u64| {
                let mut r = Pcg64::new(seed);
                let mut codes = vec![0u8; rows * row_bytes];
                let mut scales = vec![0.0f32; rows * s_per];
                for i in 0..rows {
                    let row = r.normal_vec(d, 1.0);
                    kvf.encode_row(
                        &row,
                        &mut codes[i * row_bytes..(i + 1) * row_bytes],
                        &mut scales[i * s_per..(i + 1) * s_per],
                    );
                }
                (codes, scales)
            };
            let (kc, ks) = mk(seed_for(name).wrapping_add(rows as u64));
            let (vc, vs) = mk(seed_for(name).wrapping_add(100 + rows as u64));
            let q = rng.normal_vec(d, 1.0);
            let scale = 1.0 / (dh as f32).sqrt();
            // contiguous lanes give us the lut/block the codec resolved to
            let klane = kvf.lane(&kc, &ks, d);
            let vlane = kvf.lane(&vc, &vs, d);
            // block-table views: fixed-size pages, ragged last page
            let n_pages = rows.div_ceil(page_rows);
            let kp_codes: Vec<&[u8]> =
                (0..n_pages).map(|p| page_slice(&kc, p, page_rows * row_bytes)).collect();
            let kp_scales: Vec<&[f32]> =
                (0..n_pages).map(|p| page_slice(&ks, p, page_rows * s_per)).collect();
            let vp_codes: Vec<&[u8]> =
                (0..n_pages).map(|p| page_slice(&vc, p, page_rows * row_bytes)).collect();
            let vp_scales: Vec<&[f32]> =
                (0..n_pages).map(|p| page_slice(&vs, p, page_rows * s_per)).collect();
            let kp = PagedPackedLane {
                pages_codes: &kp_codes,
                pages_scales: &kp_scales,
                lut: klane.lut,
                d,
                block: klane.block,
                page_rows,
            };
            let vp = PagedPackedLane {
                pages_codes: &vp_codes,
                pages_scales: &vp_scales,
                lut: vlane.lut,
                d,
                block: vlane.block,
                page_rows,
            };
            for h in 0..heads {
                let off = h * dh;
                let q_head = &q[off..off + dh];
                let mut att_o = vec![0.0f32; rows];
                let mut ctx_o = vec![0.0f32; dh];
                lut_attend_head_paged_scalar(q_head, kp, vp, off, rows, scale, &mut att_o, &mut ctx_o);
                let mut att_f = vec![0.0f32; rows];
                let mut ctx_f = vec![0.0f32; dh];
                simd::force_scalar(true);
                lut_attend_head_paged(q_head, kp, vp, off, rows, scale, &mut att_f, &mut ctx_f);
                let mut att_v = vec![0.0f32; rows];
                let mut ctx_v = vec![0.0f32; dh];
                simd::force_scalar(false);
                lut_attend_head_paged(q_head, kp, vp, off, rows, scale, &mut att_v, &mut ctx_v);
                let what = format!("{name} rows={rows} head={h}");
                assert_bits_eq(&ctx_f, &ctx_o, &format!("{what} (forced-scalar dispatch)"));
                assert_bits_eq(&att_f, &att_o, &format!("{what} att (forced-scalar dispatch)"));
                assert_bits_eq(&ctx_v, &ctx_o, &format!("{what} (simd)"));
                assert_bits_eq(&att_v, &att_o, &format!("{what} att (simd)"));
            }
        }
    }
    restore_env_force();
}

// ---------------------------------------------------------------------------
// W4A4: the deliberate exception to bit-identity
// ---------------------------------------------------------------------------

/// Teacher-forced NLL over a heldout window on the `micro` zoo model, fp32
/// weights vs the W4A4 checkpoint (packed 4-bit weights + on-the-fly 4-bit
/// activations through the 16x16 product LUT). The Table-8 claim scaled to
/// this zoo: quantizing *both* sides costs only a bounded NLL delta.
#[test]
fn w4a4_nll_within_table8_tolerance_on_micro() {
    let cfg = zoo("micro").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x9e11);
    let corpus = corpus_for(&cfg);
    let s = 32usize;
    let tokens: Vec<i32> = (0..=s as i32).map(|i| (i * 7 + 3) % cfg.vocab as i32).collect();
    let nll_over = |ck: &Checkpoint| -> f64 {
        let mut kv = SeqKvCache::new(&cfg);
        let mut total = 0.0f64;
        for i in 0..s {
            let logits = nn::forward_lm_step(&cfg, ck, tokens[i], &mut kv).unwrap();
            let logp = logits.log_softmax_last();
            total -= logp.at2(0, tokens[i + 1] as usize) as f64;
        }
        total / s as f64
    };
    let nll_fp32 = nll_over(&ckpt);
    assert!(nll_fp32.is_finite());
    for fmt in ["sf4", "e2m1"] {
        let w4a4 =
            w4a4_checkpoint(&cfg, &ckpt, &PipelineConfig::w4a4(fmt, false), &corpus).unwrap();
        let nll_w4a4 = nll_over(&w4a4);
        assert!(nll_w4a4.is_finite(), "{fmt}: W4A4 NLL must stay finite");
        let delta = (nll_w4a4 - nll_fp32).abs();
        assert!(
            delta <= 0.15 * nll_fp32,
            "{fmt}: W4A4 NLL {nll_w4a4:.4} drifted from fp32 {nll_fp32:.4} (delta {delta:.4})"
        );
    }
}

/// The full `serve-decode --w4a4` path in-process: the batched engine over
/// a W4A4 checkpoint streams the same tokens as feeding the same prompt
/// through the single-step forward — the code x code GEMM is row-wise
/// deterministic, so batching must not change any stream.
#[test]
fn w4a4_checkpoint_serves_through_batched_engine() {
    let cfg = zoo("nano").unwrap();
    let fp32 = trainer::init_lm_params(&cfg, 0x44a4);
    let corpus = corpus_for(&cfg);
    let ckpt = w4a4_checkpoint(&cfg, &fp32, &PipelineConfig::w4a4("sf4", false), &corpus).unwrap();
    let prompt = vec![4i32, 9, 1, 7];
    let max_new = 8usize;

    // sequential reference over the same checkpoint
    let mut kv = SeqKvCache::new(&cfg);
    let mut logits = None;
    for &t in &prompt {
        logits = Some(nn::forward_lm_step(&cfg, &ckpt, t, &mut kv).unwrap());
    }
    let mut expect = Vec::new();
    while expect.len() < max_new {
        let next = argmax(logits.as_ref().unwrap().row(0)) as i32;
        expect.push(next);
        if expect.len() >= max_new {
            break;
        }
        logits = Some(nn::forward_lm_step(&cfg, &ckpt, next, &mut kv).unwrap());
    }

    let mut eng = Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots: 2,
            scheduler: SchedulerConfig { max_batch: 2, ..SchedulerConfig::default() },
            ..EngineConfig::default()
        },
    );
    let (req_a, rx_a) = DecodeRequest::new(prompt.clone(), max_new);
    let (req_b, rx_b) = DecodeRequest::new(prompt, max_new);
    eng.submit(req_a);
    eng.submit(req_b);
    while eng.has_work() {
        eng.step().unwrap();
    }
    for rx in [&rx_a, &rx_b] {
        let mut tokens = Vec::new();
        let mut finished = None;
        while let Ok(ev) = rx.try_recv() {
            match ev {
                TokenEvent::Token { token, .. } => tokens.push(token),
                TokenEvent::Finished { reason, .. } => finished = Some(reason),
                TokenEvent::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
            }
        }
        assert_eq!(tokens, expect, "W4A4 batched stream diverged from the sequential forward");
        assert_eq!(finished, Some(FinishReason::MaxTokens));
    }
}
