//! Property + admission harness for the paged KV cache (block-table
//! layout).
//!
//! The contract under test: paging changes **where** cached K/V rows live
//! (fixed-size pages claimed on demand, named by a per-sequence block
//! table) but never **what** attention computes. Paged decode must be
//! bit-identical — tokens *and* logprobs — to the contiguous-slot layout
//! per step, for every batch size 1–8, on fp32 and every packed KV format,
//! through a mid-decode preempt → requeue → replay cycle. On top of the
//! bit-level contract, admission tests pin the capacity win the layout
//! exists for: a sequence mix whose summed worst-case context exceeds the
//! pool's positions runs concurrently, where worst-case contiguous
//! reservation (one window-sized page per slot) cannot, and page pressure
//! evicts the longest-context victim.
//!
//! The contiguous reference is the same engine with `page_size =
//! capacity` and one page per slot — byte-for-byte the pre-paging layout
//! (one contiguous lane per sequence) — so both sides of every comparison
//! run through the production code path.

use std::sync::mpsc;

use llm_datatypes::coordinator::trainer;
use llm_datatypes::model_io::{zoo, Checkpoint, ModelConfig};
use llm_datatypes::nn::{self, SeqKvCache};
use llm_datatypes::serving::{
    DecodeRequest, Engine, EngineConfig, FinishReason, SchedulerConfig, TokenEvent,
};

/// KV formats the paged layout is certified on, `None` = fp32 lanes.
const KV_FORMATS: [Option<&str>; 4] = [None, Some("sf4"), Some("nf4"), Some("e2m1_sp")];

fn engine(
    cfg: ModelConfig,
    ckpt: Checkpoint,
    slots: usize,
    kv_format: Option<&'static str>,
    page_size: usize,
    kv_pages: usize,
) -> Engine {
    Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots,
            kv_format,
            page_size,
            kv_pages,
            scheduler: SchedulerConfig { max_batch: slots, ..SchedulerConfig::default() },
            ..EngineConfig::default()
        },
    )
}

/// Drain one request's stream: its `(token, logprob-bits)` trace and the
/// terminal reason. Logprobs compare as raw bits — "bit-identical" means
/// the whole emitted stream, not just the argmax winners.
fn collect(rx: &mpsc::Receiver<TokenEvent>) -> (Vec<(i32, u32)>, Option<FinishReason>) {
    let mut trace = Vec::new();
    let mut finished = None;
    while let Ok(ev) = rx.try_recv() {
        match ev {
            TokenEvent::Token { token, logprob, .. } => trace.push((token, logprob.to_bits())),
            TokenEvent::Finished { reason, .. } => finished = Some(reason),
            TokenEvent::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
        }
    }
    (trace, finished)
}

/// Deterministic varied-length prompt for lane `i`.
fn prompt(cfg: &ModelConfig, i: usize) -> Vec<i32> {
    (0..2 + (i * 3) % 5).map(|t| ((t * 7 + i * 11 + 1) % cfg.vocab) as i32).collect()
}

/// Run `b` requests to completion on `eng`, returning each lane's trace.
fn run_batch(eng: &mut Engine, cfg: &ModelConfig, b: usize, max_new: usize) -> Vec<Vec<(i32, u32)>> {
    let mut rxs = Vec::new();
    for i in 0..b {
        let (req, rx) = DecodeRequest::new(prompt(cfg, i), max_new);
        eng.submit(req);
        rxs.push(rx);
    }
    while eng.has_work() {
        eng.step().unwrap();
    }
    rxs.iter()
        .map(|rx| {
            let (trace, fin) = collect(rx);
            assert_eq!(fin, Some(FinishReason::MaxTokens));
            trace
        })
        .collect()
}

/// The headline property: for batches 1–8 on every KV format, the paged
/// engine (8-position pages, block tables) streams bit-identically to the
/// contiguous-slot engine (one window-sized page per sequence).
#[test]
fn paged_engine_bit_identical_to_contiguous_slots_b1_to_8() {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x9a9e);
    for kv in KV_FORMATS {
        for b in 1..=8usize {
            let mut contiguous = engine(cfg, ckpt.clone(), b, kv, cfg.seq, b);
            let mut paged = engine(cfg, ckpt.clone(), b, kv, 8, 0);
            assert_eq!(contiguous.cache().pages_total(), b, "one lane-sized page per slot");
            assert_eq!(paged.cache().page_size(), 8);
            let expect = run_batch(&mut contiguous, &cfg, b, 4);
            let got = run_batch(&mut paged, &cfg, b, 4);
            for (lane, (e, g)) in expect.iter().zip(&got).enumerate() {
                assert_eq!(e.len(), 4, "kv={kv:?} b={b} lane {lane}: budget");
                assert_eq!(
                    e, g,
                    "kv={kv:?} b={b} lane {lane}: paged stream diverged from contiguous"
                );
            }
        }
    }
}

/// Tracing is observation only: a paged engine running with span
/// recording enabled streams bit-identically to the contiguous engine
/// running untraced. Exercises the instrumented step/micro-step, kernel,
/// and pool paths under the strictest output contract the repo has.
#[test]
fn paged_engine_bit_identical_with_tracing_enabled() {
    use llm_datatypes::obs::trace;
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x9a9e);
    for kv in [None, Some("sf4")] {
        let b = 4usize;
        let mut contiguous = engine(cfg, ckpt.clone(), b, kv, cfg.seq, b);
        let expect = run_batch(&mut contiguous, &cfg, b, 4);

        trace::set_enabled(true);
        let mut paged = engine(cfg, ckpt.clone(), b, kv, 8, 0);
        let got = run_batch(&mut paged, &cfg, b, 4);
        trace::set_enabled(false);
        let snap = trace::snapshot_and_drain();

        for (lane, (e, g)) in expect.iter().zip(&got).enumerate() {
            assert_eq!(e, g, "kv={kv:?} lane {lane}: traced paged run diverged");
        }
        assert!(
            snap.records.iter().any(|r| r.name == "engine.step"),
            "kv={kv:?}: enabled tracing recorded engine steps"
        );
    }
}

/// Page boundaries inside one sequence: the paged owned store (SeqKvCache)
/// is step-for-step bit-identical to the contiguous one across a whole
/// window of positions, fp32 and packed.
#[test]
fn paged_seq_store_crosses_boundaries_bit_identically() {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x9a9f);
    let tokens: Vec<i32> = (0..cfg.seq).map(|i| ((i * 5 + 3) % cfg.vocab) as i32).collect();
    // fp32: page sizes that divide, straddle and exceed the sequence
    for page_rows in [1usize, 4, 7, 16, 64] {
        let mut flat = SeqKvCache::new(&cfg);
        let mut paged = SeqKvCache::paged(&cfg, page_rows);
        for (i, &t) in tokens.iter().enumerate() {
            let a = nn::forward_lm_step(&cfg, &ckpt, t, &mut flat).unwrap();
            let b = nn::forward_lm_step(&cfg, &ckpt, t, &mut paged).unwrap();
            assert_eq!(a.data(), b.data(), "page_rows={page_rows} step {i}");
        }
    }
    for name in ["sf4", "nf4", "e2m1_sp"] {
        let spec = llm_datatypes::formats::must(name);
        let mut flat = SeqKvCache::packed(&cfg, &spec);
        let mut paged = SeqKvCache::paged_packed(&cfg, &spec, 8);
        for (i, &t) in tokens.iter().take(20).enumerate() {
            let a = nn::forward_lm_step(&cfg, &ckpt, t, &mut flat).unwrap();
            let b = nn::forward_lm_step(&cfg, &ckpt, t, &mut paged).unwrap();
            assert_eq!(a.data(), b.data(), "{name} step {i}");
        }
    }
}

/// Mid-decode preempt → requeue → replay on the paged engine must land on
/// the same stream the contiguous engine produces uninterrupted: eviction
/// frees pages (not lanes), replay re-claims fresh pages, and the greedy
/// stream is oblivious to all of it.
#[test]
fn paged_preempt_requeue_replay_matches_uninterrupted_contiguous() {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x9aa0);
    for kv in [None, Some("sf4")] {
        let p = vec![2i32, 5, 7];
        // contiguous, uninterrupted reference
        let mut reference = engine(cfg, ckpt.clone(), 1, kv, cfg.seq, 1);
        let (req, rx) = DecodeRequest::new(p.clone(), 10);
        reference.submit(req);
        while reference.has_work() {
            reference.step().unwrap();
        }
        let (expect, _) = collect(&rx);
        assert_eq!(expect.len(), 10);

        // paged, preempted mid-decode
        let mut eng = engine(cfg, ckpt.clone(), 1, kv, 4, 0);
        let (req, rx) = DecodeRequest::new(p, 10);
        let id = req.id;
        eng.submit(req);
        for _ in 0..4 {
            eng.step().unwrap();
        }
        let (head, fin) = collect(&rx);
        assert!(head.len() >= 2 && fin.is_none(), "kv={kv:?}: mid-generation before eviction");
        assert!(eng.cache().pages_in_use() > 0);
        assert!(eng.preempt(id));
        assert_eq!(eng.cache().pages_in_use(), 0, "kv={kv:?}: eviction frees the pages");
        assert!(eng.cache().free_pages_are_zeroed(), "kv={kv:?}: freed pages scrubbed");
        while eng.has_work() {
            eng.step().unwrap();
        }
        let (tail, fin) = collect(&rx);
        let resumed: Vec<(i32, u32)> = head.into_iter().chain(tail).collect();
        assert_eq!(resumed, expect, "kv={kv:?}: replay diverged from the uninterrupted stream");
        assert_eq!(fin, Some(FinishReason::MaxTokens));
    }
}

/// The admission win: 4 sequences whose summed worst-case context (4
/// windows = 128 positions) exceeds the pool (8 pages x 8 = 64 positions)
/// all run concurrently under paging, while worst-case contiguous
/// reservation on the same budget caps at 2 resident.
#[test]
fn paged_admission_exceeds_contiguous_worst_case_capacity() {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x9aa1);
    let mk_reqs = |eng: &mut Engine| {
        (0..4)
            .map(|i| {
                let (req, rx) =
                    DecodeRequest::new((0..6).map(|t| ((t + i) % 7 + 1) as i32).collect(), 3);
                eng.submit(req);
                rx
            })
            .collect::<Vec<_>>()
    };

    // paged: 64-position pool, block tables — everything admits at once
    let mut paged = engine(cfg, ckpt.clone(), 4, None, 8, 8);
    assert!(
        4 * paged.window() > paged.cache().config().pool_positions(),
        "the mix's summed max-context must exceed the physical pool"
    );
    let rxs = mk_reqs(&mut paged);
    paged.step().unwrap();
    assert_eq!(
        paged.cache().slots_in_use(),
        4,
        "paged admission keeps the whole mix resident"
    );
    while paged.has_work() {
        paged.step().unwrap();
    }
    for rx in &rxs {
        let (trace, fin) = collect(rx);
        assert_eq!(trace.len(), 3);
        assert_eq!(fin, Some(FinishReason::MaxTokens));
    }
    let report = paged.report();
    assert_eq!(report.peak_occupancy, 4);
    assert_eq!(report.completed, 4);
    assert_eq!(report.page_preemptions, 0, "short contexts: no pressure on this mix");

    // contiguous worst-case reservation on the same 64 positions: the
    // pool is two window-sized lanes, so only two sequences ever coexist
    let mut contiguous = engine(cfg, ckpt, 4, None, cfg.seq, 2);
    let rxs = mk_reqs(&mut contiguous);
    let mut peak = 0usize;
    while contiguous.has_work() {
        contiguous.step().unwrap();
        peak = peak.max(contiguous.cache().slots_in_use());
    }
    for rx in &rxs {
        let (trace, _) = collect(rx);
        assert_eq!(trace.len(), 3);
    }
    assert_eq!(peak, 2, "worst-case reservation caps residency at the lane count");
}

/// Satellite: the page-pressure eviction policy picks the longest-context
/// (most pages held) runnable victim, not an arbitrary one.
#[test]
fn preemption_victim_is_the_longest_context_session() {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x9aa2);
    // ample pool: no actual pressure, we only interrogate the policy
    let mut eng = engine(cfg, ckpt, 3, None, 4, 0);
    assert!(eng.preemption_victim().is_none(), "no active sessions yet");
    let lens = [4usize, 12, 8];
    let mut ids = Vec::new();
    let mut rxs = Vec::new();
    for (i, &n) in lens.iter().enumerate() {
        let (req, rx) =
            DecodeRequest::new((0..n).map(|t| ((t * 3 + i) % 9 + 1) as i32).collect(), 8);
        ids.push(req.id);
        eng.submit(req);
        rxs.push(rx);
    }
    eng.step().unwrap(); // prefills everything (chunk 32 >= 12) into pages
    let held: Vec<usize> =
        (0..3).map(|s| eng.cache().pages_held(s)).collect();
    assert!(held.iter().sum::<usize>() >= 3 + 1 + 2, "4-position pages over 4/12/8 contexts");
    assert_eq!(
        eng.preemption_victim(),
        Some(ids[1]),
        "the 12-token context holds the most pages and must be the victim"
    );
    // preempting it frees the most pages in one eviction
    let before = eng.cache().pages_free();
    assert!(eng.preempt(ids[1]));
    let freed = eng.cache().pages_free() - before;
    assert!(freed >= 3, "longest context returned {freed} pages");
}

/// Pressure end-to-end: admission plans only for the replayed context, so
/// decode *growth* can outrun a small pool mid-flight. Two short-prompt,
/// long-budget sessions on a 16-position pool must trip the page-pressure
/// guard (both fit at admission, their summed growth does not), evict the
/// longest, and still complete both exact budgets via requeue + replay
/// (the window clamp guarantees a lone sequence always fits).
#[test]
fn page_pressure_evicts_and_every_stream_still_completes() {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x9aa3);
    // 4 pages x 4 positions = 16-position pool, window clamps to 16
    let mut eng = engine(cfg, ckpt, 2, None, 4, 4);
    assert_eq!(eng.window(), 16, "window is pool-clamped");
    // contexts grow to 11 and 12 positions (3 pages each) — 6 pages of
    // demand against 4 physical
    let rxs: Vec<_> = [2usize, 3]
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            let (req, rx) =
                DecodeRequest::new((0..n).map(|t| ((t * 5 + i) % 11 + 1) as i32).collect(), 10);
            eng.submit(req);
            rx
        })
        .collect();
    while eng.has_work() {
        eng.step().unwrap();
    }
    for (i, rx) in rxs.iter().enumerate() {
        let (trace, fin) = collect(rx);
        assert_eq!(trace.len(), 10, "lane {i} finished its budget despite pressure");
        assert_eq!(fin, Some(FinishReason::MaxTokens), "lane {i}");
    }
    let report = eng.report();
    assert_eq!(report.completed, 2);
    assert!(report.page_preemptions >= 1, "the guard must have fired");
    assert!(report.evicted >= 1);
    assert_eq!(eng.cache().pages_in_use(), 0, "pool fully drained");
    assert!(eng.cache().free_pages_are_zeroed());
}
