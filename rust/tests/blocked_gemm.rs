//! Property harness for the blocked/register-tiled `tensor::gemm` kernel.
//!
//! Two contracts:
//!
//! 1. **Correctness**: over a sweep of (m, k, n) shapes that straddles
//!    every tile boundary (MR=4 row tiles, NR=16 column tiles, KC=256
//!    K-blocks, and the threading threshold), the blocked kernel agrees
//!    with the kept naive reference (`gemm_naive`) and with an f64
//!    accumulation oracle, within the f32 reassociation tolerance.
//! 2. **Batch-row bit-identity** (the PR-2 fused-decode invariant): every
//!    output row is bit-identical to running that row alone through a
//!    `[1, K]` call — for every m, including the threaded row-parallel
//!    path. `rust/tests/batched_decode.rs` relies on this at the model
//!    level; this file pins it at the kernel level.

use llm_datatypes::rng::Pcg64;
use llm_datatypes::tensor::{gemm, gemm_naive, gemm_threaded, Tensor};

fn rand_mat(rng: &mut Pcg64, rows: usize, cols: usize) -> Vec<f32> {
    (0..rows * cols).map(|_| rng.range(-1.0, 1.0) as f32).collect()
}

/// f64 accumulation oracle (sequential, most accurate of the three).
fn gemm_f64(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f64> {
    let mut out = vec![0.0f64; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk] as f64;
            for j in 0..n {
                out[i * n + j] += av * b[kk * n + j] as f64;
            }
        }
    }
    out
}

#[test]
fn blocked_kernel_matches_naive_and_f64_across_shape_sweep() {
    // remainder coverage: m hits 1..5 and non-multiples of MR=4; n hits
    // 1..17 and non-multiples of NR=16; k crosses the KC=256 boundary
    let ms = [1usize, 2, 3, 4, 5, 8, 13];
    let ks = [1usize, 7, 16, 128, 257, 300];
    let ns = [1usize, 3, 15, 16, 17, 33, 64];
    let mut rng = Pcg64::new(0x6e33);
    for &m in &ms {
        for &k in &ks {
            for &n in &ns {
                let a = rand_mat(&mut rng, m, k);
                let b = rand_mat(&mut rng, k, n);
                let mut fast = vec![0.0f32; m * n];
                let mut naive = vec![0.0f32; m * n];
                gemm(m, k, n, &a, &b, &mut fast);
                gemm_naive(m, k, n, &a, &b, &mut naive);
                let oracle = gemm_f64(m, k, n, &a, &b);
                // |values| <= 1, so absolute error scales with sqrt(k) for
                // random signs; k * ~10eps is a safely loose deterministic
                // bound that still catches any indexing/tiling bug outright
                let tol = 1e-6 * (k as f32) + 1e-6;
                for i in 0..m * n {
                    let o = oracle[i] as f32;
                    assert!(
                        (fast[i] - o).abs() <= tol,
                        "[{m},{k},{n}] elem {i}: blocked {} vs f64 {o}",
                        fast[i]
                    );
                    assert!(
                        (naive[i] - o).abs() <= tol,
                        "[{m},{k},{n}] elem {i}: naive {} vs f64 {o}",
                        naive[i]
                    );
                }
            }
        }
    }
}

#[test]
fn every_row_is_bit_identical_to_its_single_row_call() {
    // the fused-decode contract at kernel level: row r of a [m, k] GEMM is
    // bitwise the result of the same row alone — across full tiles (m=4),
    // remainder tiles (m=5, 7), and mixes of zero / denormal-ish values
    let mut rng = Pcg64::new(0xb17);
    let (k, n) = (193, 37);
    let b = rand_mat(&mut rng, k, n);
    for m in 1..=9usize {
        let mut a = rand_mat(&mut rng, m, k);
        // sprinkle exact zeros: the old kernel's sparsity skip would have
        // made per-row work depend on content; the blocked kernel must not
        for (i, v) in a.iter_mut().enumerate() {
            if i % 11 == 0 {
                *v = 0.0;
            }
        }
        let mut fused = vec![0.0f32; m * n];
        gemm(m, k, n, &a, &b, &mut fused);
        for r in 0..m {
            let mut single = vec![0.0f32; n];
            gemm(1, k, n, &a[r * k..(r + 1) * k], &b, &mut single);
            assert_eq!(
                &fused[r * n..(r + 1) * n],
                &single[..],
                "m={m} row {r}: fused row differs bitwise from its [1, K] call"
            );
        }
    }
}

#[test]
fn threaded_row_parallel_path_is_bit_identical_to_serial_rows() {
    // m * k * n above the parallel threshold (2^21): the scoped-thread
    // row-block path must still produce rows bitwise equal to per-row calls
    let (m, k, n) = (192, 160, 96); // 2.9M mul-adds -> threaded
    let mut rng = Pcg64::new(0x7ead);
    let a = rand_mat(&mut rng, m, k);
    let b = rand_mat(&mut rng, k, n);
    let mut fused = vec![0.0f32; m * n];
    gemm(m, k, n, &a, &b, &mut fused);
    for r in [0usize, 1, 63, 64, 100, 191] {
        let mut single = vec![0.0f32; n];
        gemm(1, k, n, &a[r * k..(r + 1) * k], &b, &mut single);
        assert_eq!(
            &fused[r * n..(r + 1) * n],
            &single[..],
            "row {r}: threaded path changed the arithmetic"
        );
    }
    // and the whole result agrees with the naive reference numerically
    let mut naive = vec![0.0f32; m * n];
    gemm_naive(m, k, n, &a, &b, &mut naive);
    for i in 0..m * n {
        assert!((fused[i] - naive[i]).abs() <= 1e-4, "elem {i}");
    }
}

#[test]
fn every_thread_count_produces_bitwise_identical_output() {
    // the explicit-thread-count entry (`quant::lut_gemm` pins one decision
    // for all its K-blocks): 1, 2, 3, 5, 8 and an absurd count must all
    // equal the serial result bitwise
    let (m, k, n) = (37, 64, 29);
    let mut rng = Pcg64::new(0x7c0de);
    let a = rand_mat(&mut rng, m, k);
    let b = rand_mat(&mut rng, k, n);
    let mut serial = vec![0.0f32; m * n];
    gemm_threaded(m, k, n, &a, &b, &mut serial, 1);
    for threads in [2usize, 3, 5, 8, 1000] {
        let mut out = vec![0.0f32; m * n];
        gemm_threaded(m, k, n, &a, &b, &mut out, threads);
        assert_eq!(out, serial, "threads={threads} changed the arithmetic");
    }
}

#[test]
fn gemm_accumulates_and_handles_degenerate_shapes() {
    // accumulate-into-out is part of the contract (lut_gemm leans on it)
    let a = [1.0f32, 2.0, 3.0];
    let b = [2.0f32, 0.5, 1.0];
    let mut out = vec![100.0f32];
    gemm(1, 3, 1, &a, &b, &mut out);
    assert_eq!(out, vec![100.0 + 2.0 + 1.0 + 3.0]);
    // zero-sized dimensions are no-ops, not panics
    let mut empty: Vec<f32> = Vec::new();
    gemm(0, 3, 1, &[], &b, &mut empty);
    let mut z = vec![5.0f32; 2];
    gemm(2, 0, 1, &[], &[], &mut z);
    assert_eq!(z, vec![5.0, 5.0], "k=0 leaves the accumulator untouched");
}

#[test]
fn matmul_and_matmul_t_share_the_kernel() {
    let mut rng = Pcg64::new(0x3a3a);
    let a = Tensor::new(&[6, 50], rand_mat(&mut rng, 6, 50));
    let b = Tensor::new(&[50, 21], rand_mat(&mut rng, 50, 21));
    let c1 = a.matmul(&b);
    let c2 = a.matmul_t(&b.transpose2());
    // matmul_t transposes back internally: identical blocked arithmetic
    assert_eq!(c1.data(), c2.data(), "matmul_t must route through the same kernel");
}
