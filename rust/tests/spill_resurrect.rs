//! Token-identity properties for graceful degradation (ISSUE 9).
//!
//! The contract under test: neither tier movement nor an engine crash may
//! change **what** a stream says — only **when** it says it. Two schedules
//! are certified against an unperturbed baseline, for every batch size
//! 1–8 on fp32 and every packed KV format:
//!
//! - **spill → restore**: a page-starved engine with a host tier evicts
//!   victims by copying their packed KV pages out and splices them back
//!   at re-admission. The restored stream must be bit-identical (tokens
//!   *and* logprob bits) to the unpressured run — the spilled bytes are
//!   the on-device layout verbatim, so the splice is exact by the paged
//!   equivalence property.
//! - **panic → resurrect**: `recover_after_panic` with `resurrect: true`
//!   requeues every in-flight session instead of failing it; the chunked
//!   prefill replay of `prompt ++ generated` must continue each stream
//!   bit-identically (greedy decode is deterministic in the committed
//!   context).
//!
//! Both properties also pin the zero-leak invariant: after the drain,
//! every device page is back in the pool and the host tier holds nothing.

use std::sync::mpsc;

use llm_datatypes::coordinator::trainer;
use llm_datatypes::model_io::{zoo, Checkpoint, ModelConfig};
use llm_datatypes::serving::{
    DecodeRequest, Engine, EngineConfig, FinishReason, SchedulerConfig, TokenEvent,
};

/// KV formats certified, `None` = fp32 lanes (spilled as raw f32 LE bytes).
const KV_FORMATS: [Option<&str>; 4] = [None, Some("sf4"), Some("nf4"), Some("e2m1_sp")];

const MAX_NEW: usize = 12;

#[allow(clippy::too_many_arguments)]
fn engine(
    cfg: ModelConfig,
    ckpt: Checkpoint,
    slots: usize,
    kv_format: Option<&'static str>,
    page_size: usize,
    kv_pages: usize,
    host_tier_bytes: usize,
    resurrect: bool,
) -> Engine {
    Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots,
            kv_format,
            page_size,
            kv_pages,
            host_tier_bytes,
            scheduler: SchedulerConfig { max_batch: slots, resurrect, ..SchedulerConfig::default() },
            ..EngineConfig::default()
        },
    )
}

/// Deterministic varied-length prompt for lane `i` (2–6 tokens).
fn prompt(cfg: &ModelConfig, i: usize) -> Vec<i32> {
    (0..2 + (i * 3) % 5).map(|t| ((t * 7 + i * 11 + 1) % cfg.vocab) as i32).collect()
}

fn submit_batch(eng: &mut Engine, cfg: &ModelConfig, b: usize) -> Vec<mpsc::Receiver<TokenEvent>> {
    (0..b)
        .map(|i| {
            let (req, rx) = DecodeRequest::new(prompt(cfg, i), MAX_NEW);
            assert!(eng.submit(req), "submit must admit or queue, not reject");
            rx
        })
        .collect()
}

/// Drain one stream: its `(token, logprob-bits)` trace + terminal reason.
fn collect(rx: &mpsc::Receiver<TokenEvent>) -> (Vec<(i32, u32)>, Option<FinishReason>) {
    let mut trace = Vec::new();
    let mut finished = None;
    while let Ok(ev) = rx.try_recv() {
        match ev {
            TokenEvent::Token { token, logprob, .. } => trace.push((token, logprob.to_bits())),
            TokenEvent::Finished { reason, .. } => finished = Some(reason),
            TokenEvent::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
        }
    }
    (trace, finished)
}

fn drain(eng: &mut Engine) {
    while eng.has_work() {
        eng.step().unwrap();
    }
}

fn collect_all(rxs: &[mpsc::Receiver<TokenEvent>], what: &str) -> Vec<Vec<(i32, u32)>> {
    rxs.iter()
        .enumerate()
        .map(|(lane, rx)| {
            let (trace, fin) = collect(rx);
            assert_eq!(fin, Some(FinishReason::MaxTokens), "{what}: lane {lane} terminal");
            assert_eq!(trace.len(), MAX_NEW, "{what}: lane {lane} budget");
            trace
        })
        .collect()
}

fn assert_no_leaks(eng: &Engine, what: &str) {
    assert_eq!(
        eng.cache().pages_free(),
        eng.cache().pages_total(),
        "{what}: device pages leaked after drain"
    );
    assert!(eng.cache().free_pages_are_zeroed(), "{what}: freed pages must be zeroed");
    assert_eq!(eng.host_tier().sessions(), 0, "{what}: host entries leaked after drain");
    assert_eq!(eng.host_tier().bytes_in_use(), 0, "{what}: host bytes leaked after drain");
}

/// The unperturbed reference: same slots/format, worst-case page pool
/// (never any pressure), no host tier, no resurrection.
fn baseline(cfg: &ModelConfig, ckpt: &Checkpoint, b: usize, kv: Option<&'static str>) -> Vec<Vec<(i32, u32)>> {
    let mut eng = engine(*cfg, ckpt.clone(), b, kv, 8, 0, 0, false);
    let rxs = submit_batch(&mut eng, cfg, b);
    drain(&mut eng);
    collect_all(&rxs, "baseline")
}

/// Headline property 1: a page-starved engine that spills victims to the
/// host tier and splices them back streams bit-identically to the
/// unpressured baseline, and actually exercises the tier (pages spilled,
/// restores served) whenever pressure exists (b >= 2 here: the pool holds
/// at most 3 pages per session against a ~5-page final context).
#[test]
fn spill_restore_streams_bit_identical_to_unpressured_run() {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x51f7);
    for kv in KV_FORMATS {
        for b in 1..=8usize {
            let expect = baseline(&cfg, &ckpt, b, kv);
            // a pool big enough to admit and finish any single session
            // (final context <= 6 + 12 + 1 = 19 positions = 5 pages of 4)
            // but far short of the batch's summed demand once b >= 2
            let kv_pages = (3 * b).max(6);
            let mut eng = engine(cfg, ckpt.clone(), b, kv, 4, kv_pages, 1 << 20, false);
            let rxs = submit_batch(&mut eng, &cfg, b);
            drain(&mut eng);
            let got = collect_all(&rxs, "spill");
            for (lane, (e, g)) in expect.iter().zip(&got).enumerate() {
                assert_eq!(e, g, "kv={kv:?} b={b} lane {lane}: spill/restore diverged");
            }
            let report = eng.report();
            if b >= 2 {
                assert!(
                    report.page_preemptions > 0,
                    "kv={kv:?} b={b}: starved pool never hit pressure — test is vacuous"
                );
                assert!(report.pages_spilled > 0, "kv={kv:?} b={b}: no pages spilled");
                assert!(report.restores > 0, "kv={kv:?} b={b}: no restores served");
            }
            assert_eq!(report.failed, 0, "kv={kv:?} b={b}: spill must not fail sessions");
            assert_no_leaks(&eng, "spill");
        }
    }
}

/// Headline property 2: crashing the engine mid-decode and resurrecting
/// every in-flight session continues each stream bit-identically. The
/// supervisor contract is mirrored exactly: a panic escapes `step`, the
/// owner calls `recover_after_panic`, then re-enters the serve loop —
/// here compressed to calling the recovery at a step boundary, which is
/// the state every escaped panic leaves behind (KV commit is atomic per
/// step under `supervised_forward`).
#[test]
fn resurrection_streams_bit_identical_and_fail_nothing() {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x51f7);
    for kv in KV_FORMATS {
        for b in 1..=8usize {
            let expect = baseline(&cfg, &ckpt, b, kv);
            let mut eng = engine(cfg, ckpt.clone(), b, kv, 8, 0, 0, true);
            let rxs = submit_batch(&mut eng, &cfg, b);
            // step 1 admits + prefills (prompts fit one chunk) + first
            // token; two more decode steps leave every lane mid-stream
            for _ in 0..3 {
                eng.step().unwrap();
            }
            eng.recover_after_panic();
            drain(&mut eng);
            let got = collect_all(&rxs, "resurrect");
            for (lane, (e, g)) in expect.iter().zip(&got).enumerate() {
                assert_eq!(e, g, "kv={kv:?} b={b} lane {lane}: resurrected stream diverged");
            }
            let report = eng.report();
            assert_eq!(report.failed, 0, "kv={kv:?} b={b}: resurrection must fail nothing");
            assert_eq!(
                report.resurrections, b,
                "kv={kv:?} b={b}: every in-flight session resurrects exactly once"
            );
            assert!(report.replay_tokens > 0, "kv={kv:?} b={b}: replay work not accounted");
            assert_no_leaks(&eng, "resurrect");
        }
    }
}

/// Degradation layers compose: spill pressure *and* a mid-run crash with
/// resurrection, together, still reproduce the baseline streams. This is
/// the full ISSUE 9 stack in one schedule — spilled images survive the
/// restart in the host tier only if their session terminally exits, so
/// the recovery path must also keep host accounting leak-free.
#[test]
fn spill_plus_resurrection_compose_bit_identically() {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x51f7);
    for kv in [None, Some("sf4")] {
        let b = 4usize;
        let expect = baseline(&cfg, &ckpt, b, kv);
        let mut eng = engine(cfg, ckpt.clone(), b, kv, 4, 3 * b, 1 << 20, true);
        let rxs = submit_batch(&mut eng, &cfg, b);
        for _ in 0..4 {
            eng.step().unwrap();
        }
        eng.recover_after_panic();
        drain(&mut eng);
        let got = collect_all(&rxs, "spill+resurrect");
        for (lane, (e, g)) in expect.iter().zip(&got).enumerate() {
            assert_eq!(e, g, "kv={kv:?} lane {lane}: composed degradation diverged");
        }
        let report = eng.report();
        assert_eq!(report.failed, 0, "kv={kv:?}: composed degradation must fail nothing");
        assert_no_leaks(&eng, "spill+resurrect");
    }
}
