//! Equivalence + property harness for the fused `[B, d]` batched decode
//! step. The contract under test: batching is a pure *scheduling* change —
//! every logit, token, and KV lane must be bit-identical to running each
//! sequence alone through `forward_lm_step`, across fp32 and fake-quant
//! (SF4, E2M1 supernormal) checkpoints, for ragged batches whose rows sit at
//! different positions and drop out mid-flight. On top of that, the engine
//! integration tests pin down slot accounting when sessions finish
//! mid-batch and when the preemption/eviction path reclaims and reuses
//! slots.

use std::sync::mpsc;

use llm_datatypes::coordinator::pipeline::{fake_quant_checkpoint, PipelineConfig};
use llm_datatypes::coordinator::{corpus_for, trainer};
use llm_datatypes::model_io::{zoo, Checkpoint, ModelConfig};
use llm_datatypes::nn::{self, KvStore, SeqKvCache};
use llm_datatypes::rng::Pcg64;
use llm_datatypes::serving::{
    DecodeRequest, Engine, EngineConfig, FinishReason, SchedulerConfig, TokenEvent,
};
use llm_datatypes::tensor::{argmax, Tensor};

fn checkpoints() -> (ModelConfig, Vec<(&'static str, Checkpoint)>) {
    let cfg = zoo("nano").unwrap();
    let fp32 = trainer::init_lm_params(&cfg, 0xba7c4);
    let corpus = corpus_for(&cfg);
    let sf4 =
        fake_quant_checkpoint(&cfg, &fp32, &PipelineConfig::weight_only("sf4"), &corpus).unwrap();
    let e2m1_sp =
        fake_quant_checkpoint(&cfg, &fp32, &PipelineConfig::weight_only("e2m1_sp"), &corpus)
            .unwrap();
    (cfg, vec![("fp32", fp32), ("sf4", sf4), ("e2m1_sp", e2m1_sp)])
}

fn engine_for(cfg: ModelConfig, ckpt: Checkpoint, slots: usize) -> Engine {
    Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots,
            scheduler: SchedulerConfig { max_batch: slots, ..SchedulerConfig::default() },
            ..EngineConfig::default()
        },
    )
}

fn collect(rx: &mpsc::Receiver<TokenEvent>) -> (Vec<i32>, Option<FinishReason>) {
    let mut tokens = Vec::new();
    let mut finished = None;
    while let Ok(ev) = rx.try_recv() {
        match ev {
            TokenEvent::Token { token, index, .. } => {
                assert_eq!(index, tokens.len(), "stream indices are contiguous");
                tokens.push(token);
            }
            TokenEvent::Finished { reason, .. } => finished = Some(reason),
            TokenEvent::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
        }
    }
    (tokens, finished)
}

/// Greedy reference: re-forward the full growing prefix every step.
fn reference_greedy(
    cfg: &ModelConfig,
    ckpt: &Checkpoint,
    prompt: &[i32],
    max_new: usize,
) -> Vec<i32> {
    let mut ctxt = prompt.to_vec();
    let mut out = Vec::new();
    for _ in 0..max_new {
        let logits = nn::forward_lm(cfg, ckpt, &ctxt, None).unwrap();
        let next = argmax(logits.row(ctxt.len() - 1)) as i32;
        out.push(next);
        if ctxt.len() >= cfg.seq {
            break;
        }
        ctxt.push(next);
    }
    out
}

/// The property: for random ragged prompts and every batch size 1..=8, each
/// row of `forward_lm_step_batch` is bit-identical to the same sequence fed
/// alone through `forward_lm_step` — on fp32 and both quantized checkpoints.
/// Lanes run dry at different steps, so the fused batch shrinks as it goes,
/// exercising every intermediate batch size below `b` as well.
#[test]
fn batched_rows_bit_identical_to_sequential_all_formats() {
    let (cfg, ckpts) = checkpoints();
    for (label, ckpt) in &ckpts {
        let mut rng = Pcg64::new(0x51de);
        for b in 1..=8usize {
            let lens: Vec<usize> = (0..b).map(|_| 1 + rng.below(10)).collect();
            let prompts: Vec<Vec<i32>> = lens
                .iter()
                .map(|&n| (0..n).map(|_| rng.below(cfg.vocab) as i32).collect())
                .collect();

            // sequential reference: per-lane logits for every position
            let mut expect: Vec<Vec<Tensor>> = Vec::new();
            for prompt in &prompts {
                let mut kv = SeqKvCache::new(&cfg);
                expect.push(
                    prompt
                        .iter()
                        .map(|&t| nn::forward_lm_step(&cfg, ckpt, t, &mut kv).unwrap())
                        .collect(),
                );
            }

            // fused path: lockstep over lanes, dropping finished lanes
            let mut kvs: Vec<SeqKvCache> = (0..b).map(|_| SeqKvCache::new(&cfg)).collect();
            for step in 0..*lens.iter().max().unwrap() {
                let live: Vec<usize> = (0..b).filter(|&i| step < lens[i]).collect();
                let tokens: Vec<i32> = live.iter().map(|&i| prompts[i][step]).collect();
                let mut stores: Vec<&mut dyn KvStore> = kvs
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| step < lens[*i])
                    .map(|(_, kv)| kv as &mut dyn KvStore)
                    .collect();
                let logits =
                    nn::forward_lm_step_batch(&cfg, ckpt, &tokens, &mut stores).unwrap();
                assert_eq!(logits.shape(), &[live.len(), cfg.vocab]);
                for (r, &lane) in live.iter().enumerate() {
                    assert_eq!(
                        logits.row(r),
                        expect[lane][step].row(0),
                        "{label} b={b} lane={lane} step={step}: batched row diverged"
                    );
                }
            }
            for (lane, &n) in lens.iter().enumerate() {
                assert_eq!(kvs[lane].len(), n, "{label} b={b}: lane {lane} commit count");
            }
        }
    }
}

/// Engine-level equivalence on quantized weights: generation through the
/// fused batched engine equals full-prefix re-forwarding, token for token.
#[test]
fn engine_generation_matches_reforward_on_quantized_weights() {
    let (cfg, ckpts) = checkpoints();
    let prompt: Vec<i32> = (0..5).map(|i| (i * 3 + 2) % cfg.vocab as i32).collect();
    for (label, ckpt) in ckpts {
        let expect = reference_greedy(&cfg, &ckpt, &prompt, 9);
        let mut eng = engine_for(cfg, ckpt, 3);
        let (req, rx) = DecodeRequest::new(prompt.clone(), 9);
        eng.submit(req);
        while eng.has_work() {
            eng.step().unwrap();
        }
        let (tokens, fin) = collect(&rx);
        assert_eq!(tokens, expect, "{label}: fused engine diverged from re-forwarding");
        assert_eq!(fin, Some(FinishReason::MaxTokens));
    }
}

/// Tracing is observation-only: with span recording enabled the fused
/// engine must produce bit-identical streams to the untraced greedy
/// re-forwarding reference, on fp32 and both quantized checkpoints.
#[test]
fn engine_generation_bit_identical_with_tracing_enabled() {
    use llm_datatypes::obs::trace;
    let (cfg, ckpts) = checkpoints();
    let prompt: Vec<i32> = (0..6).map(|i| (i * 5 + 1) % cfg.vocab as i32).collect();
    for (label, ckpt) in ckpts {
        let expect = reference_greedy(&cfg, &ckpt, &prompt, 8);
        trace::set_enabled(true);
        let mut eng = engine_for(cfg, ckpt, 2);
        let (req, rx) = DecodeRequest::new(prompt.clone(), 8);
        eng.submit(req);
        while eng.has_work() {
            eng.step().unwrap();
        }
        trace::set_enabled(false);
        let snap = trace::snapshot_and_drain();
        let (tokens, fin) = collect(&rx);
        assert_eq!(tokens, expect, "{label}: traced engine diverged from re-forwarding");
        assert_eq!(fin, Some(FinishReason::MaxTokens));
        assert!(
            snap.records.iter().any(|r| r.name == "engine.step"),
            "{label}: enabled tracing recorded engine steps"
        );
    }
}

/// A session hitting its budget mid-batch must free its KV slot and shrink
/// the next fused batch without perturbing the surviving sessions' tokens.
#[test]
fn mid_batch_finish_frees_slot_without_perturbing_survivors() {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0xf1a7);
    let expect_long = reference_greedy(&cfg, &ckpt, &[2, 3, 4], 12);
    let expect_short = reference_greedy(&cfg, &ckpt, &[9, 1], 2);
    let mut eng = engine_for(cfg, ckpt, 3);

    let (long, rx_long) = DecodeRequest::new(vec![2, 3, 4], 12);
    let (short, rx_short) = DecodeRequest::new(vec![9, 1], 2);
    eng.submit(long);
    eng.submit(short);

    let mut in_use_trace = Vec::new();
    while eng.has_work() {
        eng.step().unwrap();
        in_use_trace.push(eng.cache().slots_in_use());
    }
    assert_eq!(in_use_trace[0], 2, "both sessions co-resident at the start");
    assert!(
        in_use_trace.windows(2).all(|w| w[1] <= w[0]),
        "no arrivals: occupancy only shrinks as sessions retire: {in_use_trace:?}"
    );
    assert_eq!(*in_use_trace.last().unwrap(), 0, "all slots returned");

    let (long_tokens, long_fin) = collect(&rx_long);
    let (short_tokens, short_fin) = collect(&rx_short);
    assert_eq!(short_tokens, expect_short);
    assert_eq!(short_fin, Some(FinishReason::MaxTokens));
    assert_eq!(
        long_tokens, expect_long,
        "survivor's stream must be unperturbed by the mid-batch retirement"
    );
    assert_eq!(long_fin, Some(FinishReason::MaxTokens));

    let report = eng.report();
    assert!(report.mean_fused_batch > 1.0, "the two sessions shared fused batches");
    assert!(report.fused_gemms > 0);
}

/// End-to-end eviction: preempting a decoding session frees its slot for
/// the queue, and on re-admission it replays prompt + generated into a
/// fresh slot and finishes with exactly the stream it would have produced
/// uninterrupted (the KV slot reuse / `reset` contract under eviction).
#[test]
fn eviction_reclaims_slot_and_resumes_stream_identically() {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0xe71c);
    let expect_a = reference_greedy(&cfg, &ckpt, &[1, 2, 3], 10);
    let expect_b = reference_greedy(&cfg, &ckpt, &[5, 6], 6);
    let mut eng = engine_for(cfg, ckpt, 1);

    let (a, rx_a) = DecodeRequest::new(vec![1, 2, 3], 10);
    let id_a = a.id;
    let (b, rx_b) = DecodeRequest::new(vec![5, 6], 6);
    eng.submit(a);
    eng.submit(b); // one slot: B waits in the queue behind A
    for _ in 0..4 {
        eng.step().unwrap();
    }
    let (a_head, a_fin) = collect(&rx_a);
    assert!(a_head.len() >= 2, "A must be mid-generation before the eviction");
    assert!(a_fin.is_none());
    assert_eq!(eng.cache().slots_in_use(), 1);

    assert!(eng.preempt(id_a));
    assert_eq!(eng.cache().slots_in_use(), 0, "evicted session returned its slot");
    assert_eq!(eng.report().evicted, 1);

    // the freed slot is immediately reusable — A re-enters at the queue head
    eng.step().unwrap();
    assert_eq!(eng.cache().slots_in_use(), 1);
    while eng.has_work() {
        eng.step().unwrap();
    }
    let (a_tail, a_fin) = collect(&rx_a);
    let a_tokens: Vec<i32> = a_head.into_iter().chain(a_tail).collect();
    assert_eq!(
        a_tokens, expect_a,
        "resumed stream must equal the uninterrupted greedy stream"
    );
    assert_eq!(a_fin, Some(FinishReason::MaxTokens));
    let (b_tokens, b_fin) = collect(&rx_b);
    assert_eq!(b_tokens, expect_b, "the queued session is unaffected by the eviction");
    assert_eq!(b_fin, Some(FinishReason::MaxTokens));
    assert_eq!(eng.cache().slots_in_use(), 0);
    assert_eq!(eng.report().completed, 2);
}
