//! End-to-end integration: train nano through the AOT step artifact, PTQ
//! it across formats, evaluate through the XLA graphs, and check the
//! coordinator's serve loop — the full request path in one test.

use std::sync::OnceLock;

use llm_datatypes::coordinator::model::{GraphKind, LmHandle};
use llm_datatypes::coordinator::pipeline::{
    fake_quant_checkpoint, fp32_values, quantize_lm, PipelineConfig,
};
use llm_datatypes::coordinator::serve::{run_loadgen, ServeConfig, Server};
use llm_datatypes::coordinator::{corpus_for, trainer, Session};
use llm_datatypes::model_io::zoo;
use llm_datatypes::rng::Pcg64;
use llm_datatypes::tasks::{completion_accuracy, perplexity};

static SESSION: OnceLock<Option<Session>> = OnceLock::new();

fn session() -> Option<&'static Session> {
    SESSION
        .get_or_init(|| {
            if std::path::Path::new("artifacts/MANIFEST.txt").exists() {
                Some(Session::open("artifacts", "/tmp/llmdt_e2e_ckpt", "/tmp/llmdt_e2e_results").unwrap())
            } else {
                eprintln!("skipping: artifacts missing");
                None
            }
        })
        .as_ref()
}

#[test]
fn train_quantize_eval_serve() {
    let Some(session) = session() else { return };
    let cfg = zoo("nano").unwrap();
    let corpus = corpus_for(&cfg);

    // 1. train through the fused AOT step
    let (ckpt, trace) =
        trainer::train_lm(&session.engine, &cfg, &corpus, 50, 0x7e57, 10).unwrap();
    let first = trace.first().unwrap().1;
    let last = trace.last().unwrap().1;
    assert!(last < first - 0.2, "training must reduce loss: {first} -> {last}");

    // 2. fp32 eval through XLA
    let windows = corpus.heldout_windows(32, cfg.seq);
    let values = fp32_values(&cfg, &ckpt).unwrap();
    let mut fp = LmHandle::bind(&session.engine, &cfg, GraphKind::Fp32, &values).unwrap();
    let acc0 = completion_accuracy(&mut fp, &windows).unwrap();
    let ppl0 = perplexity(&mut fp, &windows[..16]).unwrap();
    assert!(ppl0 < cfg.vocab as f64, "trained ppl must beat uniform: {ppl0}");

    // 3. PTQ + eval: 4-bit formats must stay within a sane band of fp32
    for fmt in ["sf4", "int4"] {
        let pc = PipelineConfig::weight_only(fmt);
        let qm = quantize_lm(&cfg, &ckpt, &pc, &corpus).unwrap();
        let mut h =
            LmHandle::bind(&session.engine, &cfg, GraphKind::WeightOnly, &qm.values).unwrap();
        let ppl = perplexity(&mut h, &windows[..16]).unwrap();
        assert!(
            ppl < ppl0 * 1.8 && ppl > ppl0 * 0.8,
            "{fmt}: quantized ppl {ppl} vs fp32 {ppl0}"
        );
        let acc = completion_accuracy(&mut h, &windows).unwrap();
        assert!((acc - acc0).abs() < 0.4);
    }

    // 4. W4A4 path end to end
    let pc = PipelineConfig::w4a4("e2m1", true);
    let qm = quantize_lm(&cfg, &ckpt, &pc, &corpus).unwrap();
    let mut h = LmHandle::bind(&session.engine, &cfg, GraphKind::W4A4, &qm.values).unwrap();
    let ppl_w4a4 = perplexity(&mut h, &windows[..16]).unwrap();
    assert!(ppl_w4a4.is_finite() && ppl_w4a4 < cfg.vocab as f64 * 2.0);

    // 5. serve loop: batched requests through the decode-engine shim over
    // the same sf4 weights (fake-quant checkpoint), every client answered
    let sf4 =
        fake_quant_checkpoint(&cfg, &ckpt, &PipelineConfig::weight_only("sf4"), &corpus)
            .unwrap();
    let server = Server::new(cfg, sf4, ServeConfig::default());
    let mut rng = Pcg64::new(5);
    let prompts: Vec<Vec<i32>> = (0..16)
        .map(|_| {
            let start = rng.below(corpus.heldout.len() - cfg.seq);
            corpus.heldout[start..start + cfg.seq / 2].to_vec()
        })
        .collect();
    let stats = run_loadgen(server, prompts, 4, 8).unwrap();
    assert_eq!(stats.served, 32);
    assert!(stats.batches <= 32);
    assert!(stats.mean_batch_fill >= 1.0);
}
