//! Property harness for the packed 4-bit weight backend: `PackedWeight`
//! packing, the fused `lut_gemm`, `packed_checkpoint`, and the serving
//! engine decoding straight from packed weights.
//!
//! The central property: for **every** registered <= 4-bit codebook,
//! pack -> `lut_gemm` matches dequant -> `matmul` within 1e-6 (in fact the
//! two paths share the expansion expression, the K-block boundaries and
//! the blocked kernel, so they are bit-identical — asserted exactly where
//! the contract says so). On top of that, the batch-row bit-identity
//! invariant of `tests/batched_decode.rs` must extend to the packed
//! backend: a `[B, d]` packed forward row equals the same sequence stepped
//! alone.

use std::sync::mpsc;

use llm_datatypes::coordinator::pipeline::{
    fake_quant_checkpoint, packed_checkpoint, PipelineConfig,
};
use llm_datatypes::coordinator::{corpus_for, trainer};
use llm_datatypes::formats;
use llm_datatypes::model_io::zoo;
use llm_datatypes::nn::{self, KvStore, SeqKvCache};
use llm_datatypes::quant::{
    lut_gemm, quantize_weight, BlockSize, Calib, PackedWeight, QuantConfig,
};
use llm_datatypes::rng::Pcg64;
use llm_datatypes::serving::{DecodeRequest, Engine, EngineConfig, SchedulerConfig, TokenEvent};
use llm_datatypes::tensor::Tensor;

/// Every registered codebook that fits 4-bit packing (nibble codes).
fn packable_formats() -> Vec<&'static str> {
    formats::all_names()
        .into_iter()
        .filter(|name| formats::must(name).n_values() <= 16)
        .collect()
}

#[test]
fn pack_lut_gemm_matches_dequant_matmul_on_every_packable_codebook() {
    let names = packable_formats();
    assert!(names.len() >= 20, "the zoo should be mostly 4-bit: {names:?}");
    let mut rng = Pcg64::new(0x9acc);
    // K crosses the KC=256 block boundary; N is odd (half-filled last byte)
    let (k, n, block) = (320usize, 19usize, 64usize);
    for name in names {
        let spec = formats::must(name);
        let w = Tensor::new(&[k, n], rng.student_t_vec(k * n, 5.0, 0.02));
        let q = quantize_weight(
            &w,
            &QuantConfig { format: spec.clone(), block: BlockSize::Sub(block), calib: Calib::None },
        );
        let p = PackedWeight::from_quantized(&q, &spec);
        // codes survive nibble packing exactly
        for kk in (0..k).step_by(37) {
            for j in 0..n {
                assert_eq!(p.code(kk, j) as i8, q.codes[kk * n + j], "{name} ({kk},{j})");
            }
        }
        let x = Tensor::new(&[3, k], rng.normal_vec(3 * k, 1.0));
        let fused = lut_gemm(&x, &p);
        let dense = x.matmul(&q.dequant(&spec));
        for (i, (a, b)) in fused.data().iter().zip(dense.data()).enumerate() {
            assert!(
                (a - b).abs() <= 1e-6,
                "{name} elem {i}: fused {a} vs dequant-matmul {b}"
            );
        }
        // and in fact exactly: same expansion expression, same kernel
        assert_eq!(fused.data(), dense.data(), "{name}: paths diverged bitwise");
    }
}

#[test]
fn five_bit_codebooks_are_rejected_by_packing() {
    let spec = formats::must("int5");
    let w = Tensor::from_fn(&[32, 4], |i| (i as f32 * 0.37).sin());
    let q = quantize_weight(
        &w,
        &QuantConfig { format: spec.clone(), block: BlockSize::Sub(32), calib: Calib::None },
    );
    let result = std::panic::catch_unwind(|| PackedWeight::from_quantized(&q, &spec));
    assert!(result.is_err(), "int5 (32 values) must not pack into nibbles");
}

#[test]
fn packed_forward_is_bit_identical_to_fake_quant_forward() {
    // the packed checkpoint serves the same model as the dense fake-quant
    // checkpoint: logits equal bitwise, step by step, on both 4-bit formats
    let cfg = zoo("nano").unwrap();
    let fp32 = trainer::init_lm_params(&cfg, 0x9ac0);
    let corpus = corpus_for(&cfg);
    for format in ["sf4", "e2m1_sp"] {
        let pc = PipelineConfig::weight_only(format);
        let dense = fake_quant_checkpoint(&cfg, &fp32, &pc, &corpus).unwrap();
        let packed = packed_checkpoint(&cfg, &fp32, &pc, &corpus).unwrap();
        let tokens: Vec<i32> = (0..12).map(|i| (i * 7 + 3) % cfg.vocab as i32).collect();
        let mut kv_d = SeqKvCache::new(&cfg);
        let mut kv_p = SeqKvCache::new(&cfg);
        for (i, &t) in tokens.iter().enumerate() {
            let ld = nn::forward_lm_step(&cfg, &dense, t, &mut kv_d).unwrap();
            let lp = nn::forward_lm_step(&cfg, &packed, t, &mut kv_p).unwrap();
            assert_eq!(
                ld.data(),
                lp.data(),
                "{format} step {i}: packed logits diverged from fake-quant"
            );
        }
        // full (non-incremental) forward agrees too
        let fd = nn::forward_lm(&cfg, &dense, &tokens, None).unwrap();
        let fp = nn::forward_lm(&cfg, &packed, &tokens, None).unwrap();
        assert_eq!(fd.data(), fp.data(), "{format}: full forward diverged");
    }
}

#[test]
fn batch_bit_identity_holds_on_the_packed_backend() {
    // the PR-2 contract extended: fused [B, d] rows through packed weights
    // are bit-identical to each sequence stepped alone
    let cfg = zoo("nano").unwrap();
    let fp32 = trainer::init_lm_params(&cfg, 0x9acded);
    let corpus = corpus_for(&cfg);
    let packed = packed_checkpoint(
        &cfg,
        &fp32,
        &PipelineConfig::weight_only("sf4"),
        &corpus,
    )
    .unwrap();
    let mut rng = Pcg64::new(0x77);
    for b in [1usize, 3, 5, 8] {
        let lens: Vec<usize> = (0..b).map(|_| 1 + rng.below(8)).collect();
        let prompts: Vec<Vec<i32>> = lens
            .iter()
            .map(|&n| (0..n).map(|_| rng.below(cfg.vocab) as i32).collect())
            .collect();
        let mut expect: Vec<Vec<Tensor>> = Vec::new();
        for prompt in &prompts {
            let mut kv = SeqKvCache::new(&cfg);
            expect.push(
                prompt
                    .iter()
                    .map(|&t| nn::forward_lm_step(&cfg, &packed, t, &mut kv).unwrap())
                    .collect(),
            );
        }
        let mut kvs: Vec<SeqKvCache> = (0..b).map(|_| SeqKvCache::new(&cfg)).collect();
        for step in 0..*lens.iter().max().unwrap() {
            let live: Vec<usize> = (0..b).filter(|&i| step < lens[i]).collect();
            let tokens: Vec<i32> = live.iter().map(|&i| prompts[i][step]).collect();
            let mut stores: Vec<&mut dyn KvStore> = kvs
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| step < lens[*i])
                .map(|(_, kv)| kv as &mut dyn KvStore)
                .collect();
            let logits = nn::forward_lm_step_batch(&cfg, &packed, &tokens, &mut stores).unwrap();
            for (r, &lane) in live.iter().enumerate() {
                assert_eq!(
                    logits.row(r),
                    expect[lane][step].row(0),
                    "packed b={b} lane={lane} step={step}: batched row diverged"
                );
            }
        }
    }
}

#[test]
fn engine_serves_packed_weights_with_identical_streams() {
    // end to end: the continuous-batching engine decoding from packed
    // weights streams exactly the tokens the dense fake-quant engine does
    let cfg = zoo("nano").unwrap();
    let fp32 = trainer::init_lm_params(&cfg, 0xe2e);
    let corpus = corpus_for(&cfg);
    let pc = PipelineConfig::weight_only("sf4");
    let dense = fake_quant_checkpoint(&cfg, &fp32, &pc, &corpus).unwrap();
    let packed = packed_checkpoint(&cfg, &fp32, &pc, &corpus).unwrap();
    assert!(packed.has_packed());
    let run = |ckpt| {
        let mut eng = Engine::new(
            cfg,
            ckpt,
            EngineConfig {
                slots: 2,
                scheduler: SchedulerConfig { max_batch: 2, ..SchedulerConfig::default() },
                ..EngineConfig::default()
            },
        );
        let mut rxs: Vec<mpsc::Receiver<TokenEvent>> = Vec::new();
        for prompt in [vec![1, 2, 3], vec![7, 8]] {
            let (req, rx) = DecodeRequest::new(prompt, 6);
            eng.submit(req);
            rxs.push(rx);
        }
        while eng.has_work() {
            eng.step().unwrap();
        }
        rxs.iter()
            .map(|rx| {
                let mut tokens = Vec::new();
                while let Ok(ev) = rx.try_recv() {
                    if let TokenEvent::Token { token, .. } = ev {
                        tokens.push(token);
                    }
                }
                tokens
            })
            .collect::<Vec<Vec<i32>>>()
    };
    let dense_streams = run(dense);
    let packed_streams = run(packed);
    assert_eq!(dense_streams, packed_streams, "packed engine streams diverged");
    assert_eq!(dense_streams.len(), 2);
    assert_eq!(dense_streams[0].len(), 6);
}
