//! Property + quality harness for the packed 4-bit KV cache.
//!
//! The contract under test: storing K/V in a <= 4-bit codebook changes
//! *what* the cache holds (quantized rows), but the fused dequant-attention
//! kernels must be **bit-identical** to a dequantize-then-attend oracle
//! over the same codes — per step, per row, for every ragged batch size,
//! on every packed format — and fp32 lanes must behave exactly as before.
//! On top of the bit-level contract, an NLL-delta test bounds the quality
//! cost of 4-bit KV on a zoo model, and engine-level tests pin the
//! end-to-end `--kv-format` path including preemption-resume and the
//! packed-weights + packed-KV combination.

use std::sync::mpsc;

use llm_datatypes::coordinator::pipeline::{packed_checkpoint, PipelineConfig};
use llm_datatypes::coordinator::{corpus_for, trainer};
use llm_datatypes::formats::{self, FormatSpec};
use llm_datatypes::model_io::{zoo, Checkpoint, ModelConfig};
use llm_datatypes::nn::{self, KvLanes, KvStore, SeqKvCache};
use llm_datatypes::quant::KvFormat;
use llm_datatypes::rng::Pcg64;
use llm_datatypes::serving::{
    DecodeRequest, Engine, EngineConfig, FinishReason, SchedulerConfig, TokenEvent,
};
use llm_datatypes::tensor::argmax;

/// Formats the packed KV backend is certified on (<= 16-value codebooks
/// spanning lookup, lookup-normal and supernormal-minifloat families).
const KV_FORMATS: [&str; 3] = ["sf4", "nf4", "e2m1_sp"];

/// The dequantize-then-attend oracle: every appended row goes through the
/// same `KvFormat` codec (encode → `lut[code] * scale`), but the result is
/// stored **dense** and attention runs the plain fp32 kernels over it. The
/// fused packed path reads codes and expands the identical product inside
/// the kernel, so it must match this store bit for bit.
struct OracleKv {
    inner: SeqKvCache,
    fmt: KvFormat,
}

impl OracleKv {
    fn new(cfg: &ModelConfig, spec: &FormatSpec) -> OracleKv {
        OracleKv { inner: SeqKvCache::new(cfg), fmt: KvFormat::for_model(spec, cfg) }
    }
}

impl KvStore for OracleKv {
    fn len(&self) -> usize {
        self.inner.len()
    }

    fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    fn append_kv(&mut self, layer: usize, k_row: &[f32], v_row: &[f32]) {
        let mut kq = vec![0.0f32; k_row.len()];
        let mut vq = vec![0.0f32; v_row.len()];
        self.fmt.fake_quant_row(k_row, &mut kq);
        self.fmt.fake_quant_row(v_row, &mut vq);
        self.inner.append_kv(layer, &kq, &vq);
    }

    fn lanes(&self, layer: usize) -> KvLanes<'_> {
        self.inner.lanes(layer)
    }

    fn advance(&mut self) {
        self.inner.advance()
    }
}

fn engine_for(cfg: ModelConfig, ckpt: Checkpoint, slots: usize, kv: Option<&'static str>) -> Engine {
    Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots,
            kv_format: kv,
            scheduler: SchedulerConfig { max_batch: slots, ..SchedulerConfig::default() },
            ..EngineConfig::default()
        },
    )
}

fn collect(rx: &mpsc::Receiver<TokenEvent>) -> (Vec<i32>, Option<FinishReason>) {
    let mut tokens = Vec::new();
    let mut finished = None;
    while let Ok(ev) = rx.try_recv() {
        match ev {
            TokenEvent::Token { token, .. } => tokens.push(token),
            TokenEvent::Finished { reason, .. } => finished = Some(reason),
            TokenEvent::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
        }
    }
    (tokens, finished)
}

/// Greedy decode through `forward_lm_step` over an arbitrary KvStore.
fn greedy_over(
    cfg: &ModelConfig,
    ckpt: &Checkpoint,
    kv: &mut dyn KvStore,
    prompt: &[i32],
    max_new: usize,
) -> Vec<i32> {
    let mut logits = None;
    for &t in prompt {
        logits = Some(nn::forward_lm_step(cfg, ckpt, t, kv).unwrap());
    }
    let mut out = Vec::new();
    while out.len() < max_new {
        let next = argmax(logits.as_ref().unwrap().row(0)) as i32;
        out.push(next);
        if out.len() >= max_new || kv.len() >= cfg.seq {
            break;
        }
        logits = Some(nn::forward_lm_step(cfg, ckpt, next, kv).unwrap());
    }
    out
}

/// The property: for random ragged prompts and every batch size 1..=8, each
/// row of the fused batched step over **packed** KV stores is bit-identical
/// to the same sequence fed alone through `forward_lm_step` over the
/// dequantize-then-attend oracle — on every packed format.
#[test]
fn packed_kv_rows_bit_identical_to_dequant_oracle() {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x4b1d);
    for fmt_name in KV_FORMATS {
        let spec = formats::must(fmt_name);
        let mut rng = Pcg64::new(kv_seed(fmt_name));
        for b in 1..=8usize {
            let lens: Vec<usize> = (0..b).map(|_| 1 + rng.below(10)).collect();
            let prompts: Vec<Vec<i32>> = lens
                .iter()
                .map(|&n| (0..n).map(|_| rng.below(cfg.vocab) as i32).collect())
                .collect();

            // sequential oracle: dequantized lanes + fp32 attention
            let mut expect: Vec<Vec<llm_datatypes::tensor::Tensor>> = Vec::new();
            for prompt in &prompts {
                let mut kv = OracleKv::new(&cfg, &spec);
                expect.push(
                    prompt
                        .iter()
                        .map(|&t| nn::forward_lm_step(&cfg, &ckpt, t, &mut kv).unwrap())
                        .collect(),
                );
            }

            // fused packed path: lockstep over lanes, dropping finished ones
            let mut kvs: Vec<SeqKvCache> =
                (0..b).map(|_| SeqKvCache::packed(&cfg, &spec)).collect();
            for step in 0..*lens.iter().max().unwrap() {
                let live: Vec<usize> = (0..b).filter(|&i| step < lens[i]).collect();
                let tokens: Vec<i32> = live.iter().map(|&i| prompts[i][step]).collect();
                let mut stores: Vec<&mut dyn KvStore> = kvs
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| step < lens[*i])
                    .map(|(_, kv)| kv as &mut dyn KvStore)
                    .collect();
                let logits =
                    nn::forward_lm_step_batch(&cfg, &ckpt, &tokens, &mut stores).unwrap();
                for (r, &lane) in live.iter().enumerate() {
                    assert_eq!(
                        logits.row(r),
                        expect[lane][step].row(0),
                        "{fmt_name} b={b} lane={lane} step={step}: fused packed-KV row \
                         diverged from the dequant-then-attend oracle"
                    );
                }
            }
        }
    }
}

/// Distinct deterministic seed per format name (no hash dep needed).
fn kv_seed(name: &str) -> u64 {
    name.bytes().fold(0x51de_u64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64))
}

/// Engine-level equivalence: greedy generation through the engine with
/// `kv_format` set equals greedy decode over the oracle store, token for
/// token — and the fp32-KV engine still equals the plain fp32 cache.
#[test]
fn engine_kv_format_matches_oracle_greedy() {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x0dec);
    let prompt: Vec<i32> = (0..6).map(|i| (i * 5 + 2) % cfg.vocab as i32).collect();
    let max_new = 10usize;

    // fp32 KV: unchanged vs the plain incremental path
    let mut fp32_kv = SeqKvCache::new(&cfg);
    let expect_fp32 = greedy_over(&cfg, &ckpt, &mut fp32_kv, &prompt, max_new);
    let mut eng = engine_for(cfg, ckpt.clone(), 2, None);
    let (req, rx) = DecodeRequest::new(prompt.clone(), max_new);
    eng.submit(req);
    while eng.has_work() {
        eng.step().unwrap();
    }
    let (tokens, _) = collect(&rx);
    assert_eq!(tokens, expect_fp32, "fp32 KV lanes must be bit-identical to before");

    for fmt_name in KV_FORMATS {
        let spec = formats::must(fmt_name);
        let mut oracle = OracleKv::new(&cfg, &spec);
        let expect = greedy_over(&cfg, &ckpt, &mut oracle, &prompt, max_new);
        // leak is fine: three short 'static names, test process only
        let leaked: &'static str = Box::leak(fmt_name.to_string().into_boxed_str());
        let mut eng = engine_for(cfg, ckpt.clone(), 2, Some(leaked));
        let (req, rx) = DecodeRequest::new(prompt.clone(), max_new);
        eng.submit(req);
        while eng.has_work() {
            eng.step().unwrap();
        }
        let (tokens, fin) = collect(&rx);
        assert_eq!(
            tokens, expect,
            "{fmt_name}: engine packed-KV stream diverged from the oracle"
        );
        assert_eq!(fin, Some(FinishReason::MaxTokens));
    }
}

/// Preemption under packed KV: the resumed stream must equal the
/// uninterrupted packed-KV stream (context replay re-quantizes the same
/// rows to the same codes).
#[test]
fn packed_kv_eviction_resumes_stream_identically() {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0xe71c);
    let prompt = vec![1i32, 2, 3];

    // uninterrupted packed-KV reference
    let mut eng = engine_for(cfg, ckpt.clone(), 1, Some("sf4"));
    let (req, rx) = DecodeRequest::new(prompt.clone(), 10);
    eng.submit(req);
    while eng.has_work() {
        eng.step().unwrap();
    }
    let (expect, _) = collect(&rx);
    assert_eq!(expect.len(), 10);

    // same request, preempted mid-flight
    let mut eng = engine_for(cfg, ckpt, 1, Some("sf4"));
    let (req, rx) = DecodeRequest::new(prompt, 10);
    let id = req.id;
    eng.submit(req);
    for _ in 0..4 {
        eng.step().unwrap();
    }
    let (head, fin) = collect(&rx);
    assert!(head.len() >= 2 && fin.is_none(), "mid-generation before the eviction");
    assert!(eng.preempt(id));
    assert_eq!(eng.cache().pages_in_use(), 0, "evicted session must release its pages");
    assert!(
        eng.cache().free_pages_are_zeroed(),
        "evicted session's packed pages must be scrubbed"
    );
    while eng.has_work() {
        eng.step().unwrap();
    }
    let (tail, fin) = collect(&rx);
    let resumed: Vec<i32> = head.into_iter().chain(tail).collect();
    assert_eq!(resumed, expect, "packed-KV resume must replay bit-identically");
    assert_eq!(fin, Some(FinishReason::MaxTokens));
}

/// The full `serve-decode --packed --kv-format sf4` path in-process: true
/// 4-bit weights through the fused LUT GEMM *and* a packed KV cache through
/// the fused dequant-attention, still bit-identical to the oracle.
#[test]
fn packed_weights_and_packed_kv_compose() {
    let cfg = zoo("nano").unwrap();
    let fp32 = trainer::init_lm_params(&cfg, 0x44b1);
    let corpus = corpus_for(&cfg);
    let ckpt = packed_checkpoint(&cfg, &fp32, &PipelineConfig::weight_only("sf4"), &corpus)
        .unwrap();
    assert!(ckpt.has_packed());
    let spec = formats::must("sf4");
    let prompt = vec![4i32, 9, 1, 7];
    let mut oracle = OracleKv::new(&cfg, &spec);
    let expect = greedy_over(&cfg, &ckpt, &mut oracle, &prompt, 8);
    let mut eng = engine_for(cfg, ckpt, 2, Some("sf4"));
    let (req, rx) = DecodeRequest::new(prompt, 8);
    eng.submit(req);
    while eng.has_work() {
        eng.step().unwrap();
    }
    let (tokens, _) = collect(&rx);
    assert_eq!(tokens, expect, "packed weights + packed KV diverged from the oracle");
}

/// Quality: teacher-forced NLL over a heldout window on the `micro` zoo
/// model, fp32 KV vs packed KV. Quantizing the cache to the paper's 4-bit
/// codebooks must cost only a small NLL delta (the activations-are-
/// t-distributed claim applied to cached K/V).
#[test]
fn packed_kv_nll_within_tolerance_of_fp32_kv() {
    let cfg = zoo("micro").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0x9e11);
    let s = 32usize;
    let tokens: Vec<i32> = (0..=s as i32).map(|i| (i * 7 + 3) % cfg.vocab as i32).collect();

    let nll_over = |kv: &mut dyn KvStore| -> f64 {
        let mut total = 0.0f64;
        for i in 0..s {
            let logits = nn::forward_lm_step(&cfg, &ckpt, tokens[i], kv).unwrap();
            let logp = logits.log_softmax_last();
            total -= logp.at2(0, tokens[i + 1] as usize) as f64;
        }
        total / s as f64
    };

    let mut fp32_kv = SeqKvCache::new(&cfg);
    let nll_fp32 = nll_over(&mut fp32_kv);
    assert!(nll_fp32.is_finite());
    for fmt_name in KV_FORMATS {
        let spec = formats::must(fmt_name);
        let mut packed = SeqKvCache::packed(&cfg, &spec);
        let nll_packed = nll_over(&mut packed);
        assert!(nll_packed.is_finite(), "{fmt_name}: NLL must stay finite");
        let delta = (nll_packed - nll_fp32).abs();
        assert!(
            delta <= 0.10 * nll_fp32,
            "{fmt_name}: packed-KV NLL {nll_packed:.4} drifted from fp32 KV {nll_fp32:.4} \
             (delta {delta:.4})"
        );
    }
}
