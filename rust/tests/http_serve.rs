//! End-to-end checks for the HTTP serving front end: a real listener on an
//! ephemeral port, real connections, and the full engine behind it. The
//! robustness surface is the point — backpressure answers 429 with
//! Retry-After (and the engine's own `rejected` metric counts it),
//! mid-stream client disconnects retire the session as `Disconnected` and
//! free its KV pages, and a graceful drain finishes every in-flight
//! stream while refusing new work.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use llm_datatypes::coordinator::trainer;
use llm_datatypes::model_io::{zoo, Checkpoint, ModelConfig};
use llm_datatypes::serving::http::{fetch, serve, ChunkStream, HttpConfig};
use llm_datatypes::serving::{Engine, EngineConfig, FinishReason, SchedulerConfig};

fn model(name: &str) -> (ModelConfig, Checkpoint) {
    let cfg = zoo(name).unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0xb0b5);
    (cfg, ckpt)
}

fn engine(name: &str, slots: usize, sched: SchedulerConfig) -> Engine {
    let (cfg, ckpt) = model(name);
    Engine::new(cfg, ckpt, EngineConfig { slots, scheduler: sched, ..EngineConfig::default() })
}

fn start(eng: Engine) -> llm_datatypes::serving::HttpServer {
    serve(eng, HttpConfig::default()).expect("bind 127.0.0.1:0")
}

fn gen_body(prompt: &[i32], max_new: usize) -> String {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!("{{\"prompt\":[{}],\"max_new_tokens\":{max_new}}}", toks.join(","))
}

#[test]
fn generate_streams_ndjson_chunks_end_to_end() {
    let eng = engine("nano", 2, SchedulerConfig { max_batch: 2, ..SchedulerConfig::default() });
    let server = start(eng);
    let addr = server.addr();

    let mut stream =
        ChunkStream::open(addr, "POST", "/generate", Some(&gen_body(&[1, 2, 3], 5))).unwrap();
    assert_eq!(stream.status, 200);
    let te = stream
        .headers
        .iter()
        .find(|(n, _)| n.eq_ignore_ascii_case("transfer-encoding"))
        .map(|(_, v)| v.clone());
    assert_eq!(te.as_deref(), Some("chunked"));

    let mut lines = Vec::new();
    while let Some(chunk) = stream.next_chunk().unwrap() {
        lines.push(chunk);
    }
    assert_eq!(lines.len(), 6, "5 token chunks + 1 terminal chunk: {lines:?}");
    for (i, line) in lines[..5].iter().enumerate() {
        assert_eq!(
            llm_datatypes::serving::http::json_int_field(line, "index"),
            Some(i as i64),
            "token chunks arrive in order: {line}"
        );
        assert!(line.contains("\"logprob\":"), "{line}");
        assert!(line.ends_with('\n'), "NDJSON lines are newline-terminated: {line:?}");
    }
    let done = &lines[5];
    assert!(done.contains("\"done\":true"), "{done}");
    assert!(done.contains("\"reason\":\"max_tokens\""), "{done}");
    assert_eq!(llm_datatypes::serving::http::json_int_field(done, "generated"), Some(5));

    let exit = server.shutdown();
    let report = exit.report.unwrap();
    assert_eq!(report.completed, 1);
    assert_eq!(exit.http.streams_completed, 1);
    assert_eq!(exit.http.tokens_streamed, 5);
    assert_eq!(exit.http.disconnects, 0);
}

#[test]
fn routes_answer_health_metrics_and_errors() {
    let eng = engine("nano", 2, SchedulerConfig { max_batch: 2, ..SchedulerConfig::default() });
    let server = start(eng);
    let addr = server.addr();

    let health = fetch(addr, "GET", "/healthz", None).unwrap();
    assert_eq!((health.status, health.body.as_str()), (200, "ok\n"));

    // one completed stream so the engine snapshot has non-zero series
    let ok = fetch(addr, "POST", "/generate", Some(&gen_body(&[4, 5], 3))).unwrap();
    assert_eq!(ok.status, 200);

    // the engine thread re-renders its snapshot when idle; poll briefly
    let deadline = Instant::now() + Duration::from_secs(5);
    let metrics = loop {
        let m = fetch(addr, "GET", "/metrics", None).unwrap();
        assert_eq!(m.status, 200);
        if m.body.contains("llmdt_completed_total 1") || Instant::now() > deadline {
            break m;
        }
        std::thread::sleep(Duration::from_millis(5));
    };
    assert!(metrics.body.contains("llmdt_completed_total 1"), "{}", metrics.body);
    for series in [
        "llmdt_http_connections_total",
        "llmdt_http_requests_total",
        "llmdt_http_streams_completed_total",
        "llmdt_http_rejected_total",
        "llmdt_http_tokens_streamed_total",
        "llmdt_http_active_connections",
        "llmdt_http_draining 0",
    ] {
        assert!(metrics.body.contains(series), "missing {series} in:\n{}", metrics.body);
    }

    for (method, path, body, want) in [
        ("GET", "/nope", None, 404),
        ("GET", "/generate", None, 405),
        ("POST", "/healthz", None, 405),
        ("POST", "/generate", Some("not json"), 400),
        ("POST", "/generate", Some("{\"prompt\":[1]}"), 400),
        ("POST", "/generate", Some("{\"prompt\":[],\"max_new_tokens\":4}"), 400),
        (
            "POST",
            "/generate",
            Some("{\"prompt\":[1],\"max_new_tokens\":4,\"oops\":1}"),
            400,
        ),
    ] {
        let r = fetch(addr, method, path, body).unwrap();
        assert_eq!(r.status, want, "{method} {path} {body:?} -> {}", r.body);
    }

    let exit = server.shutdown();
    let report = exit.report.unwrap();
    assert_eq!(report.completed, 1, "error-path requests never reach the engine");
    assert_eq!(exit.http.bad_requests, 7, "the 404, both 405s, and all four 400s count");
}

#[test]
fn overload_answers_429_with_retry_after_and_counts_rejections() {
    // one slot, a 2-deep admission queue, and prefill chunked one token at
    // a time on the med zoo model: each request occupies the engine for
    // dozens of steps, so 8 simultaneous clients cannot all fit — the
    // overflow must see 429, and every 429 must come from the engine's own
    // admission (its `rejected` metric), not a front-end side channel.
    let eng = engine(
        "med",
        1,
        SchedulerConfig {
            max_batch: 1,
            max_queue: 2,
            prefill_chunk: 1,
            reject_saturated: true,
            ..SchedulerConfig::default()
        },
    );
    let server = start(eng);
    let addr = server.addr();

    let prompt: Vec<i32> = (0..24).map(|t| (t % 64) as i32).collect();
    let body = gen_body(&prompt, 8);
    let clients: Vec<_> = (0..8)
        .map(|_| {
            let body = body.clone();
            std::thread::spawn(move || fetch(addr, "POST", "/generate", Some(&body)).unwrap())
        })
        .collect();
    let responses: Vec<_> = clients.into_iter().map(|h| h.join().unwrap()).collect();

    let ok = responses.iter().filter(|r| r.status == 200).count();
    let rejected = responses.iter().filter(|r| r.status == 429).count();
    assert_eq!(ok + rejected, 8, "only 200 or 429 leave this route");
    assert!(rejected >= 1, "8 clients into 1 slot + 2 queue spots must overflow");
    // the queue holds 2 before the first admission, so at least 2 requests
    // are always served no matter how the burst interleaves with steps
    assert!(ok >= 2, "slot + queue capacity still serves admitted requests");
    for r in responses.iter().filter(|r| r.status == 429) {
        assert_eq!(r.header("Retry-After"), Some("1"), "429 advertises Retry-After");
        assert!(
            r.body.contains("queue full") || r.body.contains("saturated"),
            "429 body names the pressure source: {}",
            r.body
        );
    }

    let exit = server.shutdown();
    let report = exit.report.unwrap();
    assert_eq!(exit.http.rejected_429 as usize, rejected);
    assert_eq!(report.rejected, rejected, "every 429 increments the engine's rejected metric");
    assert_eq!(report.completed, ok, "admitted requests all finish");
    assert_eq!(exit.engine.cache().pages_in_use(), 0, "overload leaks no pages");
}

#[test]
fn mid_stream_disconnect_retires_the_session_and_frees_pages() {
    let eng = engine("med", 1, SchedulerConfig { max_batch: 1, ..SchedulerConfig::default() });
    let server = start(eng);
    let addr = server.addr();

    // read two token chunks, then vanish mid-stream
    let mut stream =
        ChunkStream::open(addr, "POST", "/generate", Some(&gen_body(&[7, 8, 9], 64))).unwrap();
    assert_eq!(stream.status, 200);
    assert!(stream.next_chunk().unwrap().is_some());
    assert!(stream.next_chunk().unwrap().is_some());
    drop(stream);

    // the engine notices the dead event channel at an upcoming token and
    // frees the slot; a follow-up request proves the capacity came back
    let follow_up = fetch(addr, "POST", "/generate", Some(&gen_body(&[1, 2], 4))).unwrap();
    assert_eq!(follow_up.status, 200);
    assert!(follow_up.body.contains("\"reason\":\"max_tokens\""), "{}", follow_up.body);

    let exit = server.shutdown();
    let report = exit.report.unwrap();
    assert_eq!(report.completed, 2, "both sessions retire (one disconnected, one served)");
    assert_eq!(report.disconnected, 1, "the abandoned stream counts as Disconnected");
    assert!(exit.http.disconnects >= 1, "the front end saw the failed write");
    assert_eq!(exit.engine.cache().pages_in_use(), 0, "disconnect freed the KV pages");
    assert_eq!(exit.engine.cache().slots_in_use(), 0);
}

#[test]
fn graceful_drain_finishes_in_flight_streams_and_refuses_new_work() {
    let eng = engine("med", 1, SchedulerConfig { max_batch: 1, ..SchedulerConfig::default() });
    let server = start(eng);
    let addr = server.addr();

    // open a stream and initiate the drain while it is mid-flight
    let mut stream =
        ChunkStream::open(addr, "POST", "/generate", Some(&gen_body(&[3, 4], 16))).unwrap();
    assert_eq!(stream.status, 200);
    assert!(stream.next_chunk().unwrap().is_some(), "stream is live before the drain");
    server.initiate_drain();

    // the in-flight stream keeps producing tokens through the drain and
    // ends with its normal terminal chunk — never cut off
    let mut lines = Vec::new();
    while let Some(chunk) = stream.next_chunk().unwrap() {
        lines.push(chunk);
    }
    let done = lines.last().expect("stream ended with a terminal chunk");
    assert!(done.contains("\"done\":true"), "{done}");
    assert!(done.contains("\"reason\":\"max_tokens\""), "{done}");
    // one token chunk was read before the drain; 15 more + the done line
    assert_eq!(lines.len(), 16, "all 16 tokens + the done line survive the drain");

    let exit = server.wait();
    let report = exit.report.unwrap();
    assert_eq!(report.completed, 1);
    assert_eq!(report.disconnected, 0, "drain dropped no in-flight stream");
    assert_eq!(exit.http.streams_completed, 1);

    // after the drain the listener is gone: new work is refused at the
    // connection level (connect fails) or dies before a response
    let refused = match TcpStream::connect(addr) {
        Err(_) => true,
        Ok(_) => fetch(addr, "POST", "/generate", Some(&gen_body(&[1], 2))).is_err(),
    };
    assert!(refused, "a drained server accepts no new generate work");
}

#[test]
fn shutdown_route_drains_over_the_wire() {
    let eng = engine("nano", 1, SchedulerConfig { max_batch: 1, ..SchedulerConfig::default() });
    let server = start(eng);
    let addr = server.addr();

    let ok = fetch(addr, "POST", "/generate", Some(&gen_body(&[2, 3], 2))).unwrap();
    assert_eq!(ok.status, 200);
    let bye = fetch(addr, "POST", "/shutdown", None).unwrap();
    assert_eq!((bye.status, bye.body.as_str()), (200, "draining\n"));

    // wait() returns because the wire-side shutdown stopped the accept
    // loop — nothing else pokes the server
    let exit = server.wait();
    assert_eq!(exit.report.unwrap().completed, 1);
    assert_eq!(
        FinishReason::MaxTokens.as_str(),
        "max_tokens",
        "the wire reason strings stay pinned to FinishReason::as_str"
    );
}
