//! End-to-end checks for the observability subsystem: a traced decode run
//! exports structurally valid Chrome trace-event JSON (named per-session
//! and per-worker tracks, timestamp-sorted complete events) and Prometheus
//! text with every serving series CI scrapes; tracing never perturbs
//! engine output; and on a fake clock the latency metrics and span
//! timeline are exact, not approximate.
//!
//! Tracing state (enable flag, rings, track table) is process-global, so
//! every test here serializes on one lock and drains the rings before and
//! after its capture window.

use std::sync::Mutex;
use std::time::Duration;

use llm_datatypes::coordinator::trainer;
use llm_datatypes::model_io::{zoo, Checkpoint, ModelConfig};
use llm_datatypes::obs::export::{chrome_trace_json, prometheus_text, validate_json};
use llm_datatypes::obs::{clock, trace};
use llm_datatypes::runtime::pool;
use llm_datatypes::serving::{
    DecodeRequest, Engine, EngineConfig, FinishReason, SchedulerConfig, TokenEvent,
};
use llm_datatypes::tensor::gemm_threaded;

/// Tests flip the global tracing flag and drain the shared rings; they
/// must not interleave (integration tests in one binary run in parallel).
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn nano() -> (ModelConfig, Checkpoint) {
    let cfg = zoo("nano").unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0xb0b5);
    (cfg, ckpt)
}

fn engine(cfg: ModelConfig, ckpt: Checkpoint, slots: usize) -> Engine {
    Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots,
            scheduler: SchedulerConfig { max_batch: slots, ..SchedulerConfig::default() },
            ..EngineConfig::default()
        },
    )
}

/// Run `n` requests to completion, returning each stream's
/// `(token, logprob-bits)` trace.
fn run_requests(eng: &mut Engine, cfg: &ModelConfig, n: usize, max_new: usize) -> Vec<Vec<(i32, u32)>> {
    let mut rxs = Vec::new();
    for i in 0..n {
        let prompt: Vec<i32> =
            (0..3 + i % 3).map(|t| ((t * 7 + i * 11 + 1) % cfg.vocab) as i32).collect();
        let (req, rx) = DecodeRequest::new(prompt, max_new);
        eng.submit(req);
        rxs.push(rx);
    }
    while eng.has_work() {
        eng.step().unwrap();
    }
    rxs.iter()
        .map(|rx| {
            let mut out = Vec::new();
            let mut finished = None;
            while let Ok(ev) = rx.try_recv() {
                match ev {
                    TokenEvent::Token { token, logprob, .. } => out.push((token, logprob.to_bits())),
                    TokenEvent::Finished { reason, .. } => finished = Some(reason),
                    TokenEvent::Rejected { reason, .. } => panic!("rejected: {reason}"),
                }
            }
            assert_eq!(finished, Some(FinishReason::MaxTokens));
            out
        })
        .collect()
}

/// The Chrome exporter's golden shape on a real traced run: valid JSON,
/// engine/kernel spans present, per-session (and, when the pool has
/// workers, per-worker) named tracks, and `"ts"` values emitted in
/// non-decreasing order (metadata records carry no `ts` key, so every
/// occurrence belongs to an event).
#[test]
fn chrome_trace_export_is_structurally_valid() {
    let _g = lock();
    trace::set_enabled(true);
    trace::reset();

    let (cfg, ckpt) = nano();
    let mut eng = engine(cfg, ckpt, 2);
    run_requests(&mut eng, &cfg, 3, 4);

    // a multi-task pool dispatch records kernel + dispatch spans
    let (m, k, n) = (256usize, 64usize, 64usize);
    let a: Vec<f32> = (0..m * k).map(|i| (i % 13) as f32 * 0.25 - 1.0).collect();
    let b: Vec<f32> = (0..k * n).map(|i| (i % 7) as f32 * 0.5 - 1.5).collect();
    let mut out = vec![0.0f32; m * n];
    gemm_threaded(m, k, n, &a, &b, &mut out, 4);
    if pool::global().workers() > 0 {
        // pin at least one task to a worker thread: two tasks meeting at a
        // barrier cannot both run on the dispatching thread, so a worker
        // track is guaranteed (the gemm above could be fully self-drained
        // by this thread before any worker wakes)
        let barrier = std::sync::Barrier::new(2);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
            .map(|_| {
                let barrier = &barrier;
                Box::new(move || {
                    barrier.wait();
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::global().scoped(tasks);
    }

    trace::set_enabled(false);
    let snap = trace::snapshot_and_drain();

    for name in ["engine.step", "engine.micro_step", "tensor.gemm", "queued", "finished"] {
        assert!(snap.records.iter().any(|r| r.name == name), "missing span {name:?}");
    }

    let json = chrome_trace_json(&snap);
    validate_json(&json).unwrap();
    assert!(json.contains("\"displayTimeUnit\":\"ms\""));
    assert!(json.contains("\"process_name\""));
    assert!(json.contains("\"name\":\"session-"), "per-session tracks are named");
    if pool::global().workers() > 0 {
        assert!(json.contains("llmdt-pool-"), "worker threads get named tracks");
        assert!(snap.records.iter().any(|r| r.name == "pool.task"), "worker task spans recorded");
    }

    // every "ts" in emission order is non-decreasing
    let ts: Vec<u64> = json
        .match_indices("\"ts\":")
        .map(|(i, pat)| {
            let rest = &json[i + pat.len()..];
            let end = rest.find(',').unwrap();
            rest[..end].parse().unwrap()
        })
        .collect();
    assert!(!ts.is_empty());
    assert!(ts.windows(2).all(|w| w[0] <= w[1]), "exported events are timestamp-sorted");
}

/// The Prometheus exporter carries every series the CI smoke scrape
/// requires, with cumulative (monotone) histogram buckets.
#[test]
fn prometheus_export_has_required_series() {
    let _g = lock();
    let (cfg, ckpt) = nano();
    let mut eng = engine(cfg, ckpt, 2);
    run_requests(&mut eng, &cfg, 3, 4);

    let text = prometheus_text(&eng.metrics_registry());
    for series in [
        "llmdt_ttft_seconds_bucket{le=\"",
        "llmdt_itl_seconds_bucket{le=\"",
        "llmdt_ttft_seconds_bucket{le=\"+Inf\"}",
        "llmdt_pages_in_use",
        "llmdt_pool_utilization",
        "llmdt_decode_tokens_total",
        "llmdt_completed_total 3",
        "llmdt_samples_dropped_total 0",
        "llmdt_step_occupancy_bucket",
    ] {
        assert!(text.contains(series), "missing Prometheus series {series:?} in:\n{text}");
    }
    // cumulative bucket counts are non-decreasing and end at _count
    let counts: Vec<u64> = text
        .lines()
        .filter(|l| l.starts_with("llmdt_itl_seconds_bucket"))
        .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
        .collect();
    assert!(counts.len() >= 2, "ITL histogram has buckets");
    assert!(counts.windows(2).all(|w| w[0] <= w[1]), "{counts:?}");
    let count_line = text
        .lines()
        .find(|l| l.starts_with("llmdt_itl_seconds_count"))
        .expect("ITL _count present");
    let total: u64 = count_line.rsplit(' ').next().unwrap().parse().unwrap();
    assert_eq!(*counts.last().unwrap(), total);
    // 3 requests x 4 tokens = 3 TTFT samples + 9 inter-token gaps
    assert_eq!(total, 9);
}

/// Tracing is pure observation: the full `(token, logprob-bits)` streams
/// of an identical workload match between a traced and an untraced run.
#[test]
fn engine_output_bit_identical_tracing_on_vs_off() {
    let _g = lock();
    let (cfg, ckpt) = nano();

    trace::set_enabled(false);
    let mut plain = engine(cfg, ckpt.clone(), 2);
    let expect = run_requests(&mut plain, &cfg, 3, 6);

    trace::set_enabled(true);
    trace::reset();
    let mut traced = engine(cfg, ckpt, 2);
    let got = run_requests(&mut traced, &cfg, 3, 6);
    trace::set_enabled(false);
    let snap = trace::snapshot_and_drain();

    assert_eq!(expect, got, "tracing changed engine output");
    assert!(snap.records.iter().any(|r| r.name == "engine.step"));
}

/// On the fake clock the whole pipeline is exact: a request submitted at
/// t=0, admitted+prefilled 5ms later, then decoded one token per 3ms step
/// reports TTFT of exactly 5ms and ITL of exactly 3ms at every quantile,
/// and its `queued` span covers exactly [0, 5ms].
#[test]
fn fake_clock_yields_exact_latencies_and_timeline() {
    let _g = lock();
    let _fake = clock::fake();
    trace::set_enabled(true);
    trace::reset();

    let (cfg, ckpt) = nano();
    let mut eng = engine(cfg, ckpt, 1);
    let (req, rx) = DecodeRequest::new(vec![1, 2], 4);
    eng.submit(req);

    clock::advance(Duration::from_millis(5));
    eng.step().unwrap(); // admit + full prefill + first token at t=5ms
    while eng.has_work() {
        clock::advance(Duration::from_millis(3));
        eng.step().unwrap();
    }

    trace::set_enabled(false);
    let snap = trace::snapshot_and_drain();
    let report = eng.report();

    assert_eq!(report.completed, 1);
    assert_eq!(report.ttft_p50, Duration::from_millis(5));
    assert_eq!(report.ttft_p99, Duration::from_millis(5));
    assert_eq!(report.itl_p50, Duration::from_millis(3));
    assert_eq!(report.itl_p99, Duration::from_millis(3));
    assert_eq!(report.samples_dropped, 0);

    let mut tokens = 0;
    let mut finished = None;
    while let Ok(ev) = rx.try_recv() {
        match ev {
            TokenEvent::Token { .. } => tokens += 1,
            TokenEvent::Finished { reason, .. } => finished = Some(reason),
            TokenEvent::Rejected { reason, .. } => panic!("rejected: {reason}"),
        }
    }
    assert_eq!(tokens, 4);
    assert_eq!(finished, Some(FinishReason::MaxTokens));

    let queued = snap
        .records
        .iter()
        .find(|r| r.name == "queued")
        .expect("queued lifecycle span recorded");
    assert_eq!((queued.ts_us, queued.dur_us), (0, 5_000), "queued span covers [0, 5ms] exactly");
    let decode = snap
        .records
        .iter()
        .find(|r| r.name == "decode")
        .expect("decode lifecycle span recorded");
    // decode phase: first token at 5ms, retired with token 4 at 5 + 3*3 ms
    assert_eq!(decode.dur_us, 9_000, "decode span is exactly three 3ms steps");
}

/// Regression for ITL accounting across preemption: a 50ms
/// eviction-to-resume bubble must land in the `resume_gap` series, leaving
/// every ITL quantile at the exact 3ms decode cadence. (Before the fix,
/// `requeue` kept the stale `last_token_at`, so the first post-replay token
/// recorded a 50ms inter-token sample and ITL p99 reported scheduler
/// artifacts instead of decode latency.)
#[test]
fn itl_excludes_preemption_bubble_under_fake_clock() {
    let _g = lock();
    let _fake = clock::fake();

    let (cfg, ckpt) = nano();
    let mut eng = engine(cfg, ckpt, 1);
    let (req, rx) = DecodeRequest::new(vec![1, 2], 6);
    let id = req.id;
    eng.submit(req);

    clock::advance(Duration::from_millis(5));
    eng.step().unwrap(); // admit + prefill + token 1 (TTFT 5ms)
    for _ in 0..2 {
        clock::advance(Duration::from_millis(3));
        eng.step().unwrap(); // tokens 2 and 3, 3ms apart
    }

    assert!(eng.preempt(id), "mid-stream session is preemptible");
    clock::advance(Duration::from_millis(50)); // the scheduler bubble
    eng.step().unwrap(); // re-admit, replay context, token 4
    while eng.has_work() {
        clock::advance(Duration::from_millis(3));
        eng.step().unwrap(); // tokens 5 and 6 resume the 3ms cadence
    }

    let report = eng.report();
    assert_eq!(report.completed, 1);
    assert_eq!(report.evicted, 1);
    assert_eq!(report.ttft_p50, Duration::from_millis(5));
    assert_eq!(report.itl_p50, Duration::from_millis(3), "ITL is pure decode cadence");
    assert_eq!(
        report.itl_p99,
        Duration::from_millis(3),
        "the 50ms preemption bubble must not pollute ITL p99"
    );
    assert_eq!(report.resume_gaps, 1, "the bubble lands in its own series");
    assert_eq!(report.resume_gap_p50, Duration::from_millis(50));
    assert_eq!(report.resume_gap_p99, Duration::from_millis(50));
    assert_eq!(report.samples_dropped, 0);

    let mut tokens = 0;
    let mut finished = None;
    while let Ok(ev) = rx.try_recv() {
        match ev {
            TokenEvent::Token { .. } => tokens += 1,
            TokenEvent::Finished { reason, .. } => finished = Some(reason),
            TokenEvent::Rejected { reason, .. } => panic!("rejected: {reason}"),
        }
    }
    assert_eq!(tokens, 6, "the stream is complete despite the round trip");
    assert_eq!(finished, Some(FinishReason::MaxTokens));
}
