//! Chaos harness: arm seeded fault schedules against the full stack and
//! prove the blast radius stays contained — a poisoned pool task fails one
//! dispatch and the workers live on, an injected forward panic fails only
//! the flagged sessions, a wedged micro-step costs exactly one watchdog
//! victim, an engine-thread panic costs one 503 and a supervised restart,
//! and a client disconnect storm leaks nothing. Fault state is
//! process-global, so every test here serializes through [`CHAOS_LOCK`]
//! (this binary is the only test binary that ever arms).

use std::panic::AssertUnwindSafe;
use std::sync::{mpsc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use llm_datatypes::coordinator::trainer;
use llm_datatypes::faults::{self, FaultPlan, Site};
use llm_datatypes::model_io::{zoo, Checkpoint, ModelConfig};
use llm_datatypes::obs::clock;
use llm_datatypes::runtime::pool;
use llm_datatypes::serving::http::{fetch, serve, ChunkStream, HttpConfig};
use llm_datatypes::serving::{
    DecodeRequest, Engine, EngineConfig, FinishReason, SchedulerConfig, TokenEvent,
};
use llm_datatypes::tensor::{gemm_naive, gemm_threaded};

static CHAOS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    // a previous test panicking while armed must not wedge the rest
    CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn model(name: &str) -> (ModelConfig, Checkpoint) {
    let cfg = zoo(name).unwrap();
    let ckpt = trainer::init_lm_params(&cfg, 0xb0b5);
    (cfg, ckpt)
}

fn gen_body(prompt: &[i32], max_new: usize) -> String {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!("{{\"prompt\":[{}],\"max_new_tokens\":{max_new}}}", toks.join(","))
}

/// Drive the engine to drain; `step()` degrading to `Err` or wedging
/// forever are both failures — chaos must never abort the loop.
fn drive(eng: &mut Engine) {
    for _ in 0..10_000 {
        if !eng.has_work() {
            return;
        }
        eng.step().expect("engine step must degrade, never abort");
    }
    panic!("engine failed to drain within 10k steps");
}

/// Drain a receiver: streamed token count + every terminal event seen.
fn terminal(rx: &mpsc::Receiver<TokenEvent>) -> (usize, Vec<FinishReason>) {
    let mut tokens = 0;
    let mut fins = Vec::new();
    while let Ok(ev) = rx.try_recv() {
        match ev {
            TokenEvent::Token { .. } => tokens += 1,
            TokenEvent::Finished { reason, .. } => fins.push(reason),
            TokenEvent::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
        }
    }
    (tokens, fins)
}

#[test]
fn pool_survives_repeated_worker_panics_and_recovers() {
    let _g = lock();
    faults::silence_injected_panics();
    let workers = pool::global().workers();
    if workers == 0 {
        // single-core host: every dispatch runs inline on the caller and
        // the pool_worker_panic site is unreachable — nothing to test
        return;
    }
    let (m, k, n) = (128usize, 64usize, 96usize);
    let a: Vec<f32> = (0..m * k).map(|i| ((i * 37 + 11) % 97) as f32 * 0.01 - 0.5).collect();
    let b: Vec<f32> = (0..k * n).map(|i| ((i * 53 + 7) % 89) as f32 * 0.01 - 0.4).collect();
    let mut oracle = vec![0.0f32; m * n];
    gemm_naive(m, k, n, &a, &b, &mut oracle);

    let before = pool::stats();
    // worker 0 poisons every task it pulls, three times over
    faults::arm(
        FaultPlan::new(0xc4a05)
            .rate(Site::PoolWorkerPanic, 1.0)
            .limit(Site::PoolWorkerPanic, 3)
            .pool_worker(0),
    );
    let mut failed_dispatches = 0usize;
    for _ in 0..50 {
        let mut out = vec![0.0f32; m * n];
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            gemm_threaded(m, k, n, &a, &b, &mut out, workers + 1);
        }));
        if let Err(p) = r {
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_default();
            assert!(
                msg.contains("worker pool task panicked"),
                "dispatch surfaces the worker panic, not something else: {msg:?}"
            );
            failed_dispatches += 1;
        }
        if faults::injected(Site::PoolWorkerPanic) >= 3 {
            break;
        }
    }
    faults::disarm();
    assert!(
        faults::injected(Site::PoolWorkerPanic) >= 1,
        "worker 0 pulled at least one poisoned task in 50 dispatches"
    );
    assert!(failed_dispatches >= 1, "a poisoned task fails its whole dispatch");

    // recovery on the same pool: workers survived the panics, dispatches
    // still engage them, and the result is bit-identical to the oracle
    let mut out = vec![0.0f32; m * n];
    gemm_threaded(m, k, n, &a, &b, &mut out, workers + 1);
    assert!(
        out.iter().zip(&oracle).all(|(x, y)| x.to_bits() == y.to_bits()),
        "post-chaos gemm_threaded diverges from the scalar oracle"
    );
    let delta = pool::stats().since(&before);
    assert_eq!(delta.workers, workers, "no worker thread died: panics are caught per-task");
    assert!(delta.dispatches >= 1, "the gemms above dispatched to the pool");
    assert!(
        delta.pool_tasks >= 1 && delta.utilization() > 0.0,
        "workers still pull tasks after repeated panics: {delta:?}"
    );
}

#[test]
fn engine_survives_seeded_fault_schedule_without_leaks() {
    let _g = lock();
    faults::silence_injected_panics();
    let (cfg, ckpt) = model("nano");
    let mut eng = Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots: 4,
            page_size: 4,
            kv_pages: 12,
            scheduler: SchedulerConfig { max_batch: 4, ..SchedulerConfig::default() },
            ..EngineConfig::default()
        },
    );
    let mut rxs = Vec::new();
    for i in 0..12 {
        let (req, rx) = DecodeRequest::new(vec![1 + i % 7, 2, 3, 4], 5);
        eng.submit(req);
        rxs.push(rx);
    }
    // one schedule, three failure modes: the first two forward rows panic
    // (fused batch unwinds, survivors re-attempt), one KV reservation is
    // refused (the whole batch falls back to per-row isolation), and a
    // page spike seizes a third of the pool for two steps
    faults::arm(
        FaultPlan::new(0x5eed)
            .rate(Site::ForwardPanic, 1.0)
            .limit(Site::ForwardPanic, 2)
            .one_shot(Site::KvReserveFail)
            .one_shot(Site::KvPageSpike)
            .spike(4, 2),
    );
    drive(&mut eng);
    faults::disarm();

    let mut failed = 0;
    for (i, rx) in rxs.iter().enumerate() {
        let (tokens, fins) = terminal(rx);
        assert_eq!(fins.len(), 1, "request {i}: exactly one terminal event, got {fins:?}");
        match fins[0] {
            FinishReason::Failed => {
                failed += 1;
                assert_eq!(tokens, 0, "request {i} died mid-prefill, before any token");
            }
            FinishReason::MaxTokens => {
                assert_eq!(tokens, 5, "request {i} streamed its full budget");
            }
            other => panic!("request {i}: unexpected terminal {other:?}"),
        }
    }
    assert_eq!(failed, 2, "the forward_panic limit caps the blast radius at two sessions");

    let report = eng.report();
    assert_eq!(report.failed, 2);
    assert_eq!(report.completed, 12, "every request retired through exactly one path");
    assert_eq!(faults::injected(Site::ForwardPanic), 2);
    assert!(faults::injected(Site::KvReserveFail) >= 1, "the reserve refusal was exercised");
    assert!(faults::injected(Site::KvPageSpike) >= 1, "the page spike was exercised");
    assert_eq!(eng.cache().pages_in_use(), 0, "no leaked pages after the chaos drain");
    assert_eq!(eng.cache().slots_in_use(), 0);
    assert!(eng.cache().free_pages_are_zeroed(), "failed sessions scrubbed their KV");
}

#[test]
fn stall_watchdog_kills_the_deepest_context_and_spares_the_rest() {
    let _g = lock();
    faults::silence_injected_panics();
    // the fake clock makes the "stall" deterministic: a clock_skew fault
    // jumps time past the deadline with no real sleeping
    let _clock = clock::fake();
    let (cfg, ckpt) = model("nano");
    let mut eng = Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots: 4,
            page_size: 4,
            scheduler: SchedulerConfig {
                max_batch: 4,
                step_deadline: Duration::from_millis(10),
                ..SchedulerConfig::default()
            },
            ..EngineConfig::default()
        },
    );
    let mut rxs = Vec::new();
    for len in [4i32, 8, 16] {
        let (req, rx) = DecodeRequest::new((0..len).map(|t| t % 7 + 1).collect(), 8);
        eng.submit(req);
        rxs.push(rx);
    }
    // healthy steps first so contexts (and page holdings) diverge:
    // lengths 7 / 11 / 19 -> 2 / 3 / 5 pages held
    for _ in 0..3 {
        eng.step().unwrap();
    }
    faults::arm(FaultPlan::new(3).one_shot(Site::ClockSkew).skew(Duration::from_millis(50)));
    eng.step().unwrap();
    faults::disarm();
    drive(&mut eng);

    let (t0, f0) = terminal(&rxs[0]);
    let (t1, f1) = terminal(&rxs[1]);
    let (t2, f2) = terminal(&rxs[2]);
    assert_eq!((t0, f0), (8, vec![FinishReason::MaxTokens]), "small context untouched");
    assert_eq!((t1, f1), (8, vec![FinishReason::MaxTokens]), "medium context untouched");
    assert_eq!(f2, vec![FinishReason::Failed], "the deepest context is the watchdog's victim");
    assert!(t2 < 8, "the victim never finished its budget (streamed {t2})");

    let report = eng.report();
    assert_eq!(report.watchdog_kills, 1, "exactly one kill for one blown deadline");
    assert_eq!(report.failed, 1);
    assert_eq!(eng.cache().pages_in_use(), 0, "the victim's pages came back");
}

#[test]
fn http_supervisor_restarts_the_engine_and_keeps_serving() {
    let _g = lock();
    faults::silence_injected_panics();
    faults::arm(FaultPlan::new(0xd00d).one_shot(Site::EngineStepPanic));
    let (cfg, ckpt) = model("nano");
    let eng = Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots: 1,
            scheduler: SchedulerConfig {
                max_batch: 1,
                prefill_chunk: 1,
                ..SchedulerConfig::default()
            },
            ..EngineConfig::default()
        },
    );
    let server = serve(eng, HttpConfig::default()).expect("bind 127.0.0.1:0");
    let addr = server.addr();

    // A is mid-prefill (24 tokens, one per step) when the injected step
    // panic unwinds the engine thread; its stream never started, so the
    // supervisor's recovery answers it 503 + Retry-After
    let prompt: Vec<i32> = (0..24).map(|t| t % 7 + 1).collect();
    let a = fetch(addr, "POST", "/generate", Some(&gen_body(&prompt, 2))).unwrap();
    assert_eq!(a.status, 503, "in-flight work fails visibly: {}", a.body);
    assert!(a.body.contains("engine restarted"), "{}", a.body);
    assert!(a.header("Retry-After").is_some(), "503 invites the client back");

    // the restarted loop serves fresh work on the same queue and channel
    let b = fetch(addr, "POST", "/generate", Some(&gen_body(&[5, 6], 3))).unwrap();
    assert_eq!(b.status, 200, "{}", b.body);
    assert!(b.body.contains("\"done\":true"), "{}", b.body);
    assert!(b.body.contains("\"reason\":\"max_tokens\""), "{}", b.body);

    // the restarted thread re-renders /metrics; poll for the new series
    let deadline = Instant::now() + Duration::from_secs(5);
    let metrics = loop {
        let m = fetch(addr, "GET", "/metrics", None).unwrap();
        if m.body.contains("llmdt_http_engine_restarts_total 1") || Instant::now() > deadline {
            break m;
        }
        std::thread::sleep(Duration::from_millis(5));
    };
    for series in [
        "llmdt_http_engine_restarts_total 1",
        "llmdt_sessions_failed_total 1",
        "llmdt_faults_injected_total 1",
        "llmdt_faults_engine_step_panic_total 1",
    ] {
        assert!(metrics.body.contains(series), "missing {series} in:\n{}", metrics.body);
    }
    faults::disarm();

    let exit = server.shutdown();
    let report = exit.report.expect("the supervised engine still returns its report");
    assert_eq!(exit.http.engine_restarts, 1);
    assert_eq!(report.failed, 1, "A retired Failed through the recovery path");
    assert_eq!(report.completed, 2, "A (failed) and B (served) both retired exactly once");
    assert_eq!(exit.engine.cache().pages_in_use(), 0, "recovery freed A's pages");
    assert_eq!(exit.engine.cache().slots_in_use(), 0);
}

#[test]
fn resurrection_continues_admitted_streams_across_an_engine_panic() {
    let _g = lock();
    faults::silence_injected_panics();
    faults::arm(FaultPlan::new(0xd00d).one_shot(Site::EngineStepPanic));
    let (cfg, ckpt) = model("nano");
    let eng = Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots: 1,
            scheduler: SchedulerConfig {
                max_batch: 1,
                prefill_chunk: 1,
                resurrect: true,
                ..SchedulerConfig::default()
            },
            ..EngineConfig::default()
        },
    );
    let server = serve(eng, HttpConfig::default()).expect("bind 127.0.0.1:0");
    let addr = server.addr();

    // Same schedule as the legacy supervisor test: A is mid-prefill when
    // the injected step panic unwinds the engine thread. With `resurrect`
    // on, the recovery requeues A instead of failing it, the replay
    // continues the *same* chunked stream, and the client never sees a
    // 503 for work that was already admitted.
    let prompt: Vec<i32> = (0..24).map(|t| t % 7 + 1).collect();
    let mut stream =
        ChunkStream::open(addr, "POST", "/generate", Some(&gen_body(&prompt, 4))).unwrap();
    assert_eq!(stream.status, 200, "an admitted request is never answered 503");
    let mut indices = Vec::new();
    let mut done_line = String::new();
    while let Ok(Some(line)) = stream.next_chunk() {
        if line.contains("\"done\":true") {
            done_line = line;
            break;
        }
        let idx = llm_datatypes::serving::http::json_int_field(&line, "index")
            .unwrap_or_else(|| panic!("token line without index: {line}"));
        indices.push(idx);
    }
    assert_eq!(
        indices,
        vec![0, 1, 2, 3],
        "the resurrected stream is gapless and duplicate-free across the restart"
    );
    assert!(done_line.contains("\"reason\":\"max_tokens\""), "terminal: {done_line}");

    let deadline = Instant::now() + Duration::from_secs(5);
    let metrics = loop {
        let m = fetch(addr, "GET", "/metrics", None).unwrap();
        if m.body.contains("llmdt_http_engine_restarts_total 1") || Instant::now() > deadline {
            break m;
        }
        std::thread::sleep(Duration::from_millis(5));
    };
    for series in [
        "llmdt_http_engine_restarts_total 1",
        "llmdt_sessions_failed_total 0",
        "llmdt_resurrections_total 1",
        "llmdt_faults_engine_step_panic_total 1",
    ] {
        assert!(metrics.body.contains(series), "missing {series} in:\n{}", metrics.body);
    }
    faults::disarm();

    let exit = server.shutdown();
    let report = exit.report.expect("the supervised engine still returns its report");
    assert_eq!(exit.http.engine_restarts, 1);
    assert_eq!(report.failed, 0, "resurrection reserves Failed for poisoned rows");
    assert_eq!(report.resurrections, 1, "A was requeued, not retired");
    assert!(report.replay_tokens >= prompt.len(), "the replay re-prefills A's context");
    assert_eq!(exit.engine.cache().pages_in_use(), 0, "recovery leaked no pages");
    assert_eq!(exit.engine.cache().slots_in_use(), 0);
}

#[test]
fn host_tier_failure_degrades_spill_to_recompute_without_losing_sessions() {
    let _g = lock();
    faults::silence_injected_panics();
    let (cfg, ckpt) = model("nano");
    // page-starved enough that pressure must evict (12 pages of 4 against
    // four ~3-page contexts growing to ~4 pages), with a host tier that
    // would normally absorb every victim
    let mut eng = Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots: 4,
            page_size: 4,
            kv_pages: 12,
            host_tier_bytes: 1 << 20,
            scheduler: SchedulerConfig { max_batch: 4, ..SchedulerConfig::default() },
            ..EngineConfig::default()
        },
    );
    let mut rxs = Vec::new();
    for i in 0..4 {
        let (req, rx) = DecodeRequest::new(vec![1 + i % 7, 2, 3, 4, 5, 6], 10);
        eng.submit(req);
        rxs.push(rx);
    }
    // every spill attempt fails at the (simulated) host copy
    faults::arm(FaultPlan::new(0xf411).rate(Site::HostTierFail, 1.0));
    drive(&mut eng);
    faults::disarm();

    for (i, rx) in rxs.iter().enumerate() {
        let (tokens, fins) = terminal(rx);
        assert_eq!(fins, vec![FinishReason::MaxTokens], "request {i} survived the fallback");
        assert_eq!(tokens, 10, "request {i} streamed its full budget");
    }
    let report = eng.report();
    assert!(report.page_preemptions > 0, "the pool actually hit pressure");
    assert_eq!(report.pages_spilled, 0, "no spill completes while the host link is down");
    assert_eq!(report.restores, 0);
    assert_eq!(report.failed, 0, "recompute fallback loses nothing");
    assert!(faults::injected(Site::HostTierFail) >= 1, "the fallback was exercised");
    assert_eq!(eng.host_tier().sessions(), 0, "failed spills leave no host entries");
    assert_eq!(eng.cache().pages_in_use(), 0, "no leaked pages after the drain");
}

#[test]
fn resume_cooldown_stops_preemption_ping_pong() {
    let _g = lock();
    faults::silence_injected_panics();
    // fake clock: time only moves when the test says so, making "inside
    // the cooldown" a deterministic statement
    let _clock = clock::fake();
    let (cfg, ckpt) = model("nano");
    let cooldown = Duration::from_millis(250);
    let mut eng = Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots: 2,
            page_size: 4,
            scheduler: SchedulerConfig {
                max_batch: 2,
                resume_cooldown: cooldown,
                ..SchedulerConfig::default()
            },
            ..EngineConfig::default()
        },
    );
    // A's context dwarfs B's, so most-pages always names A when eligible
    let (req_a, rx_a) = DecodeRequest::new((0..16).map(|t| t % 7 + 1).collect(), 24);
    let a_id = req_a.id;
    let (req_b, rx_b) = DecodeRequest::new(vec![1, 2, 3], 24);
    let b_id = req_b.id;
    eng.submit(req_a);
    eng.submit(req_b);
    for _ in 0..2 {
        eng.step().unwrap();
    }
    assert_eq!(eng.preemption_victim(), Some(a_id), "most pages held: A is the victim");

    // evict A and let the next step re-admit it (replay completes within
    // one prefill chunk). Pre-cooldown engines would name A again here —
    // it still holds the most pages — and sustained pressure ping-pongs
    // A forever while B never yields a page.
    assert!(eng.preempt(a_id));
    eng.step().unwrap();
    assert_eq!(
        eng.preemption_victim(),
        Some(b_id),
        "A is shielded by the resume cooldown; pressure must rotate to B"
    );

    // once the cooldown lapses, A's page holdings make it the victim again
    clock::advance(cooldown + Duration::from_millis(1));
    assert_eq!(eng.preemption_victim(), Some(a_id), "the shield expires with the cooldown");

    // waiver: when every candidate is freshly resumed, selection must
    // still name someone — pressure can never be left without a victim
    assert!(eng.preempt(a_id));
    assert!(eng.preempt(b_id));
    eng.step().unwrap();
    assert!(
        eng.preemption_victim().is_some(),
        "all-cooling-down candidates waive the filter instead of wedging pressure"
    );

    drive(&mut eng);
    let (ta, fa) = terminal(&rx_a);
    let (tb, fb) = terminal(&rx_b);
    assert_eq!((ta, fa), (24, vec![FinishReason::MaxTokens]), "A finished despite evictions");
    assert_eq!((tb, fb), (24, vec![FinishReason::MaxTokens]), "B finished despite evictions");
}

#[test]
fn client_disconnect_storm_drains_clean_and_leaks_nothing() {
    let _g = lock();
    faults::silence_injected_panics();
    // the first three chunk reads across the storm die at the socket
    faults::arm(
        FaultPlan::new(0xd15c)
            .rate(Site::HttpClientDisconnect, 1.0)
            .limit(Site::HttpClientDisconnect, 3),
    );
    let (cfg, ckpt) = model("med");
    let eng = Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots: 4,
            scheduler: SchedulerConfig { max_batch: 4, ..SchedulerConfig::default() },
            ..EngineConfig::default()
        },
    );
    let server = serve(eng, HttpConfig::default()).expect("bind 127.0.0.1:0");
    let addr = server.addr();

    let clients: Vec<_> = (0..6)
        .map(|i| {
            std::thread::spawn(move || {
                let body = gen_body(&[i + 1, 2, 3], 24);
                let mut stream =
                    ChunkStream::open(addr, "POST", "/generate", Some(&body)).unwrap();
                assert_eq!(stream.status, 200, "the storm starts with admitted streams");
                let mut done = false;
                loop {
                    match stream.next_chunk() {
                        Ok(Some(line)) => done = line.contains("\"done\":true"),
                        Ok(None) => return (done, false),
                        Err(_) => return (done, true), // injected disconnect
                    }
                }
            })
        })
        .collect();
    let outcomes: Vec<(bool, bool)> = clients.into_iter().map(|h| h.join().unwrap()).collect();
    let dropped = outcomes.iter().filter(|(_, dropped)| *dropped).count();
    let finished = outcomes.iter().filter(|(done, dropped)| *done && !*dropped).count();
    assert_eq!(
        finished + dropped,
        6,
        "every client either saw its terminal line or was injected away: {outcomes:?}"
    );
    assert!(dropped >= 1, "the armed schedule hit at least one live stream");
    assert_eq!(
        dropped as u64,
        faults::injected(Site::HttpClientDisconnect),
        "each injection kills exactly one stream"
    );
    faults::disarm();

    let exit = server.shutdown();
    let report = exit.report.unwrap();
    assert_eq!(report.completed, 6, "all six requests retired server-side exactly once");
    assert_eq!(exit.engine.cache().pages_in_use(), 0, "the storm leaked no pages");
    assert_eq!(exit.engine.cache().slots_in_use(), 0);
    assert!(exit.engine.cache().free_pages_are_zeroed(), "retired KV was scrubbed");
}

#[test]
fn disarmed_faults_change_nothing_and_runs_are_bit_identical() {
    let _g = lock();
    faults::disarm();
    assert!(!faults::enabled(), "disarmed is the default state");

    let run = || {
        let (cfg, ckpt) = model("nano");
        let mut eng = Engine::new(
            cfg,
            ckpt,
            EngineConfig {
                slots: 2,
                page_size: 4,
                scheduler: SchedulerConfig { max_batch: 2, ..SchedulerConfig::default() },
                ..EngineConfig::default()
            },
        );
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (req, rx) = DecodeRequest::new(vec![1, 2, 3 + i], 6);
            eng.submit(req);
            rxs.push(rx);
        }
        drive(&mut eng);
        rxs.iter()
            .map(|rx| {
                let mut tokens: Vec<(i32, u32)> = Vec::new();
                let mut end = None;
                while let Ok(ev) = rx.try_recv() {
                    match ev {
                        TokenEvent::Token { token, logprob, .. } => {
                            tokens.push((token, logprob.to_bits()));
                        }
                        TokenEvent::Finished { reason, generated, .. } => {
                            end = Some((reason, generated));
                        }
                        TokenEvent::Rejected { reason, .. } => {
                            panic!("unexpected rejection: {reason}");
                        }
                    }
                }
                (tokens, end)
            })
            .collect::<Vec<_>>()
    };
    let first = run();
    let second = run();
    assert!(
        first.iter().all(|(tokens, end)| !tokens.is_empty() && end.is_some()),
        "both runs actually generated: {first:?}"
    );
    assert_eq!(first, second, "with faults disarmed, token and logprob streams are bit-identical");
}
