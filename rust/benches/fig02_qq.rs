//! Bench target: regenerate paper Figure 2 (histogram + Q-Q fit data).
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp;

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    exp::ensure_model(&session, "nano")?;
    println!("{}", exp::profile::run_fig2(&session, "nano")?);
    bench("fig02_qq", 3, || exp::profile::run_fig2(&session, "nano").unwrap());
    Ok(())
}
