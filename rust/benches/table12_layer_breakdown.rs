//! Bench target: regenerate paper Table 12 (per-layer profiling) at quick scale and time it.
//! Full-scale regeneration: `repro table 12`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    exp::ensure_model(&session, "nano")?;
    let table = exp::profile::run_breakdown(&session, Scale::Quick, "nano")?;
    println!("{}", table.render());
    bench("table12_layer_breakdown", 2, || exp::profile::run_breakdown(&session, Scale::Quick, "nano").unwrap());
    Ok(())
}
