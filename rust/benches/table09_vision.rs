//! Bench target: regenerate paper Table 9 (vision models) at quick scale and time it.
//! Full-scale regeneration: `repro table 9`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    exp::ensure_cls(&session, "mlp")?;
    exp::ensure_cls(&session, "cnn")?;
    let table = exp::vision::run(&session, Scale::Quick)?;
    println!("{}", table.render());
    bench("table09_vision", 2, || exp::vision::run(&session, Scale::Quick).unwrap());
    Ok(())
}
