//! Bench target: regenerate paper Figure 3/8 (quality-vs-area Pareto).
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    exp::ensure_model(&session, "nano")?;
    let (rendered, points) = exp::pareto::run(&session, Scale::Quick)?;
    println!("{rendered}");
    println!("Pareto front: {}", exp::pareto::pareto_front(&points).join(" -> "));
    bench("fig03_pareto", 1, || exp::pareto::run(&session, Scale::Quick).unwrap());
    Ok(())
}
