//! Bench target: regenerate paper Table 2 (SF4 nu sweep) at quick scale and time it.
//! Full-scale regeneration: `repro table 2`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    exp::ensure_model(&session, "nano")?;
    let table = exp::dof_sweep::run(&session, Scale::Quick)?;
    println!("{}", table.render());
    bench("table02_dof_sweep", 2, || exp::dof_sweep::run(&session, Scale::Quick).unwrap());
    Ok(())
}
