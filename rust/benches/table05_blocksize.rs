//! Bench target: regenerate paper Table 5 (block-size sweep) at quick scale and time it.
//! Full-scale regeneration: `repro table 5`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    exp::ensure_model(&session, "nano")?;
    let table = exp::blocksize::run(&session, Scale::Quick, "nano")?;
    println!("{}", table.render());
    bench("table05_blocksize", 2, || exp::blocksize::run(&session, Scale::Quick, "nano").unwrap());
    Ok(())
}
