//! Bench target: regenerate paper Table 15 (datatype values) at quick scale and time it.
//! Full-scale regeneration: `repro table 15`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;

    let table = exp::convergence::run_table15()?;
    println!("{}", table.render());
    bench("table15_codebooks", 2, || exp::convergence::run_table15().unwrap());
    Ok(())
}
