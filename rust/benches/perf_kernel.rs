//! Perf: L1 kernel path — the lut_matmul artifact end-to-end through PJRT
//! (upload codes/scales once, stream activations), vs the pure-Rust
//! dequant+matmul on the same problem.
use std::collections::HashMap;

use llm_datatypes::bench_util::{bench, report_throughput};
use llm_datatypes::coordinator::Session;
use llm_datatypes::formats;
use llm_datatypes::rng::Pcg64;
use llm_datatypes::runtime::Value;
use llm_datatypes::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    let exe = session.engine.load("lut_matmul_bench")?;
    let (m, k, n, blk) = (256usize, 512usize, 512usize, 128usize);
    let mut rng = Pcg64::new(2);
    let x = Tensor::new(&[m, k], rng.normal_vec(m * k, 1.0));
    let codes: Vec<i8> = (0..k * n).map(|_| rng.below(16) as i8).collect();
    let scales = Tensor::new(&[k / blk, n], (0..(k / blk) * n).map(|_| 1.0f32).collect());
    let cb = Tensor::new(&[16], formats::must("sf4").padded16());
    let flops = 2 * m * k * n;

    let mut fixed = HashMap::new();
    fixed.insert("codes".to_string(), Value::I8(codes.clone(), vec![k, n]));
    fixed.insert("scales".to_string(), Value::F32(scales.clone()));
    fixed.insert("codebook".to_string(), Value::F32(cb.clone()));
    let bound = exe.bind(&fixed)?;
    let mut rest = HashMap::new();
    rest.insert("x".to_string(), Value::F32(x.clone()));
    let s = bench("xla_lut_matmul_256x512x512", 32, || exe.run_bound(&bound, &rest).unwrap());
    println!("bench {:40} gflops={:.2}", "xla_lut_matmul_256x512x512", flops as f64 / s.mean_secs() / 1e9);
    report_throughput(&s, k * n); // 4-bit codes held as i8: weight traffic

    // pure-Rust oracle on the same problem
    let spec = formats::must("sf4");
    let s2 = bench("rust_dequant_matmul_256x512x512", 8, || {
        let cbv: Vec<f32> = spec.padded16();
        let mut w = vec![0.0f32; k * n];
        for kk in 0..k {
            for j in 0..n {
                w[kk * n + j] = cbv[codes[kk * n + j] as usize];
            }
        }
        let wt = Tensor::new(&[k, n], w);
        x.matmul(&wt)
    });
    println!("bench {:40} gflops={:.2}", "rust_dequant_matmul_256x512x512", flops as f64 / s2.mean_secs() / 1e9);
    Ok(())
}
