//! Perf: matmul kernels. Three comparisons, all pure Rust (no artifacts
//! needed):
//!
//! 1. the blocked/register-tiled `tensor::gemm` vs the naive ikj reference
//!    (`gemm_naive`) vs the pre-PR-3 ikj kernel with its `a == 0.0`
//!    sparsity-skip branch, on the 256x512x512 problem and on a batch-4
//!    decode-shaped row block — the before/after for dropping the skip;
//! 2. the fused packed-4-bit `quant::lut_gemm` (nibble codes expanded
//!    through the 16-entry codebook LUT inside the matmul) vs the
//!    dequant-then-matmul oracle it replaces — the acceptance comparison on
//!    256x512x512;
//! 3. optionally, the XLA `lut_matmul_bench` artifact end-to-end through
//!    PJRT on the same problem (skipped with a note when the artifact set
//!    is absent).
//!
//! Every cell lands in `BENCH_kernel.json` (gflops + mean ms) so future
//! PRs have a perf trajectory to regress against.
use std::collections::HashMap;

use llm_datatypes::bench_util::{bench, BenchJson, BenchStats};
use llm_datatypes::coordinator::Session;
use llm_datatypes::formats;
use llm_datatypes::quant::{
    lut_gemm, quantize_weight, BlockSize, Calib, PackedWeight, QuantConfig,
};
use llm_datatypes::rng::Pcg64;
use llm_datatypes::runtime::Value;
use llm_datatypes::tensor::{gemm, gemm_naive, Tensor};

/// The pre-PR-3 kernel, verbatim: ikj with the per-element `av == 0.0`
/// sparsity skip. Kept here (not in the library) purely as the before-side
/// of the skip-branch measurement.
fn gemm_ikj_skipzero(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let o_row = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in a_row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let b_row = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in o_row.iter_mut().zip(b_row) {
                *o += av * bv;
            }
        }
    }
}

fn gflops(flops: usize, s: &BenchStats) -> f64 {
    flops as f64 / s.mean_secs() / 1e9
}

fn record(json: &mut BenchJson, name: &str, flops: usize, s: &BenchStats) {
    let gf = gflops(flops, s);
    println!("bench {name:40} gflops={gf:.2}");
    json.record(name, "gflops", gf);
    json.record(name, "mean_ms", s.mean_secs() * 1e3);
}

fn main() -> anyhow::Result<()> {
    let mut json = BenchJson::new();
    let (m, k, n, blk) = (256usize, 512usize, 512usize, 128usize);
    let flops = 2 * m * k * n;
    let mut rng = Pcg64::new(2);
    let x = Tensor::new(&[m, k], rng.normal_vec(m * k, 1.0));

    // -- 1: GEMM kernel shootout (dense f32) -------------------------------
    let b = Tensor::new(&[k, n], rng.normal_vec(k * n, 1.0));
    let mut out = vec![0.0f32; m * n];
    let s = bench("gemm_blocked_256x512x512", 48, || {
        out.iter_mut().for_each(|v| *v = 0.0);
        gemm(m, k, n, x.data(), b.data(), &mut out);
    });
    record(&mut json, "gemm_blocked_256x512x512", flops, &s);
    let s = bench("gemm_naive_256x512x512", 12, || {
        out.iter_mut().for_each(|v| *v = 0.0);
        gemm_naive(m, k, n, x.data(), b.data(), &mut out);
    });
    record(&mut json, "gemm_naive_256x512x512", flops, &s);
    let s = bench("gemm_ikj_skipzero_256x512x512", 12, || {
        out.iter_mut().for_each(|v| *v = 0.0);
        gemm_ikj_skipzero(m, k, n, x.data(), b.data(), &mut out);
    });
    record(&mut json, "gemm_ikj_skipzero_256x512x512", flops, &s);

    // batch-4 decode-shaped rows: dense activations, the shape the serving
    // engine issues per linear per step (the skip branch's worst case)
    let bm = 4usize;
    let dflops = 2 * bm * k * n;
    let xd = Tensor::new(&[bm, k], rng.normal_vec(bm * k, 1.0));
    let mut dout = vec![0.0f32; bm * n];
    let s = bench("gemm_blocked_decode_4x512x512", 256, || {
        dout.iter_mut().for_each(|v| *v = 0.0);
        gemm(bm, k, n, xd.data(), b.data(), &mut dout);
    });
    record(&mut json, "gemm_blocked_decode_4x512x512", dflops, &s);
    let s = bench("gemm_skipzero_decode_4x512x512", 128, || {
        dout.iter_mut().for_each(|v| *v = 0.0);
        gemm_ikj_skipzero(bm, k, n, xd.data(), b.data(), &mut dout);
    });
    record(&mut json, "gemm_skipzero_decode_4x512x512", dflops, &s);

    // -- 2: fused packed-LUT GEMM vs dequant-then-matmul -------------------
    let spec = formats::must("sf4");
    let w = Tensor::new(&[k, n], rng.student_t_vec(k * n, 5.0, 0.02));
    let q = quantize_weight(
        &w,
        &QuantConfig { format: spec.clone(), block: BlockSize::Sub(blk), calib: Calib::None },
    );
    let packed = PackedWeight::from_quantized(&q, &spec);
    let s_oracle = bench("rust_dequant_matmul_256x512x512", 12, || {
        let wt = q.dequant(&spec);
        x.matmul(&wt)
    });
    record(&mut json, "rust_dequant_matmul_256x512x512", flops, &s_oracle);
    let s_fused = bench("rust_lut_gemm_256x512x512", 24, || lut_gemm(&x, &packed));
    record(&mut json, "rust_lut_gemm_256x512x512", flops, &s_fused);
    let speedup = s_oracle.mean_secs() / s_fused.mean_secs();
    println!("bench lut_gemm_vs_dequant_matmul               x{speedup:.2}");
    json.record("lut_gemm_vs_dequant_matmul", "speedup", speedup);

    // decode shape for the fused path too (weight traffic per token)
    let s = bench("rust_lut_gemm_decode_4x512x512", 64, || lut_gemm(&xd, &packed));
    record(&mut json, "rust_lut_gemm_decode_4x512x512", dflops, &s);

    // -- 3: XLA lut_matmul artifact (optional) -----------------------------
    // Any failure here — missing artifacts, a stale manifest, a bind or
    // run error — must not cost us the pure-Rust cells already measured:
    // skip with a note and still write the trajectory file.
    let xla_cell = || -> anyhow::Result<BenchStats> {
        let session = Session::open("artifacts", "checkpoints", "results")?;
        let exe = session.engine.load("lut_matmul_bench")?;
        let cb = Tensor::new(&[16], spec.padded16());
        let mut fixed = HashMap::new();
        fixed.insert("codes".to_string(), Value::I8(q.codes.clone(), vec![k, n]));
        fixed.insert("scales".to_string(), Value::F32(q.scales.clone()));
        fixed.insert("codebook".to_string(), Value::F32(cb));
        let bound = exe.bind(&fixed)?;
        let mut rest = HashMap::new();
        rest.insert("x".to_string(), Value::F32(x.clone()));
        let mut err = None;
        let s = bench("xla_lut_matmul_256x512x512", 32, || {
            if let Err(e) = exe.run_bound(&bound, &rest) {
                err.get_or_insert(e);
            }
        });
        match err {
            Some(e) => Err(e),
            None => Ok(s),
        }
    };
    match xla_cell() {
        Ok(s) => record(&mut json, "xla_lut_matmul_256x512x512", flops, &s),
        Err(e) => println!("note: XLA lut_matmul cell skipped ({e:#})"),
    }

    json.write("BENCH_kernel.json")?;
    Ok(())
}
