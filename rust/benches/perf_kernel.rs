//! Perf: matmul + attention kernels. Five comparisons, all pure Rust (no
//! artifacts needed):
//!
//! 1. the blocked/register-tiled `tensor::gemm` vs the naive ikj reference
//!    (`gemm_naive`) vs the pre-PR-3 ikj kernel with its `a == 0.0`
//!    sparsity-skip branch, on the 256x512x512 problem and on a batch-4
//!    decode-shaped row block — the before/after for dropping the skip;
//! 2. the fused packed-4-bit `quant::lut_gemm` (nibble codes expanded
//!    through the 16-entry codebook LUT inside the matmul) vs the
//!    dequant-then-matmul oracle it replaces — the acceptance comparison on
//!    256x512x512;
//! 3. the persistent-pool row threading (`tensor::gemm_threaded`, PR 4) vs
//!    the pre-PR-4 per-call `thread::scope` spawns it replaced, at prefill
//!    shapes where the threading engages (`gemm_pool_*` vs `gemm_scope_*`);
//! 4. the fused packed-KV attention (`tensor::lut_attend_head`, PR 4) vs
//!    its dequantize-then-attend oracle at decode shapes, plus a
//!    long-context cell that crosses the pool threshold;
//! 5. optionally, the XLA `lut_matmul_bench` artifact end-to-end through
//!    PJRT on the same problem (skipped with a note when the artifact set
//!    is absent).
//!
//! Since PR 10 the run opens with the scalar-vs-SIMD A/B: each of the
//! three vectorized hot loops (gemm micro-tile, nibble -> LUT expansion,
//! paged dequant-attention) is timed twice through the same body — once
//! with `tensor::simd::force_scalar(true)` pinning the scalar oracle, once
//! with SIMD dispatch live — and the per-cell speedup is printed and
//! recorded. The W4A4 code x code cells ride along. `--smoke` runs only
//! that A/B as a CI gate: on any vector-capable host the SIMD `lut_gemm`
//! must not lose to the scalar oracle (skipped with a note when no vector
//! ISA is detected).
//!
//! Every cell lands in `BENCH_kernel.json` (gflops + mean ms) so future
//! PRs have a perf trajectory to regress against.
use std::collections::HashMap;

use llm_datatypes::bench_util::{bench, black_box, BenchJson, BenchStats};
use llm_datatypes::coordinator::Session;
use llm_datatypes::formats;
use llm_datatypes::quant::{
    lut_gemm, quantize_weight, w4a4_gemm, ActQuantizer, BlockSize, Calib, KvFormat, PackedWeight,
    QuantConfig,
};
use llm_datatypes::rng::Pcg64;
use llm_datatypes::runtime::Value;
use llm_datatypes::tensor::{
    attend_head, gemm, gemm_auto_threads, gemm_naive, gemm_threaded, lut_attend,
    lut_attend_head, simd, Tensor,
};

/// The pre-PR-3 kernel, verbatim: ikj with the per-element `av == 0.0`
/// sparsity skip. Kept here (not in the library) purely as the before-side
/// of the skip-branch measurement.
fn gemm_ikj_skipzero(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let o_row = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in a_row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let b_row = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in o_row.iter_mut().zip(b_row) {
                *o += av * bv;
            }
        }
    }
}

/// The pre-PR-4 row threading, verbatim in spirit: spawn one scoped thread
/// per row chunk per call, each running the serial blocked kernel
/// (`gemm_threaded` with `threads = 1`). Kept here (not in the library)
/// purely as the before-side of the persistent-pool measurement.
fn gemm_scope_threaded(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    threads: usize,
) {
    const MR: usize = 4; // tensor::GEMM_MR
    let threads = threads.max(1).min(m.div_ceil(MR));
    if threads <= 1 {
        gemm_threaded(m, k, n, a, b, out, 1);
        return;
    }
    let tiles = m.div_ceil(MR);
    let rows_per = tiles.div_ceil(threads) * MR;
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut i0 = 0usize;
        while i0 < m {
            let mb = rows_per.min(m - i0);
            let (chunk, tail) = rest.split_at_mut(mb * n);
            rest = tail;
            let a_chunk = &a[i0 * k..(i0 + mb) * k];
            scope.spawn(move || gemm_threaded(mb, k, n, a_chunk, b, chunk, 1));
            i0 += mb;
        }
    });
}

fn gflops(flops: usize, s: &BenchStats) -> f64 {
    flops as f64 / s.mean_secs() / 1e9
}

fn record(json: &mut BenchJson, name: &str, flops: usize, s: &BenchStats) {
    let gf = gflops(flops, s);
    println!("bench {name:40} gflops={gf:.2}");
    json.record(name, "gflops", gf);
    json.record(name, "mean_ms", s.mean_secs() * 1e3);
}

/// One scalar-vs-SIMD comparison: the identical body timed once with the
/// kernels pinned to the scalar oracle (`simd::force_scalar(true)`) and
/// once with SIMD dispatch live. Returns scalar mean / simd mean, so on a
/// scalar-only host every cell reports ~x1.00.
fn ab_cell(
    json: &mut BenchJson,
    name: &str,
    flops: usize,
    iters: usize,
    body: &mut dyn FnMut(),
) -> f64 {
    simd::force_scalar(true);
    let s_scalar = bench(&format!("{name}_scalar"), iters, || body());
    record(json, &format!("{name}_scalar"), flops, &s_scalar);
    simd::force_scalar(false);
    let s_simd = bench(&format!("{name}_simd"), iters, || body());
    record(json, &format!("{name}_simd"), flops, &s_simd);
    let speedup = s_scalar.mean_secs() / s_simd.mean_secs();
    println!("bench {name:40} x{speedup:.2} (simd vs scalar)");
    json.record(name, "speedup", speedup);
    speedup
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let mut json = BenchJson::new();
    let (m, k, n, blk) = (256usize, 512usize, 512usize, 128usize);
    let flops = 2 * m * k * n;
    let mut rng = Pcg64::new(2);
    let x = Tensor::new(&[m, k], rng.normal_vec(m * k, 1.0));
    let b = Tensor::new(&[k, n], rng.normal_vec(k * n, 1.0));
    let (bm, dflops) = (4usize, 2 * 4 * k * n);
    let xd = Tensor::new(&[bm, k], rng.normal_vec(bm * k, 1.0));
    let spec = formats::must("sf4");
    let w = Tensor::new(&[k, n], rng.student_t_vec(k * n, 5.0, 0.02));
    let q = quantize_weight(
        &w,
        &QuantConfig { format: spec.clone(), block: BlockSize::Sub(blk), calib: Calib::None },
    );
    let packed = PackedWeight::from_quantized(&q, &spec);

    // -- 0: scalar oracle vs SIMD microkernels (A/B via the force lever) ---
    let isa = simd::detected();
    println!("bench kernel_dispatch                          isa={} (code {})", isa.name(), isa.code());
    json.record("kernel_dispatch", "isa_code", isa.code() as f64);
    let ab_iters = if smoke { 8 } else { 24 };
    let speedup_gemm = {
        let mut dout = vec![0.0f32; bm * n];
        ab_cell(&mut json, "simd_gemm_decode_4x512x512", dflops, 8 * ab_iters, &mut || {
            dout.iter_mut().for_each(|v| *v = 0.0);
            gemm(bm, k, n, xd.data(), b.data(), &mut dout);
        })
    };
    let speedup_lut = ab_cell(&mut json, "simd_lut_gemm_256x512x512", flops, ab_iters, &mut || {
        black_box(lut_gemm(&x, &packed));
    });
    let speedup_attend = {
        let (rows, ad, heads) = (96usize, 256usize, 8usize);
        let dh = ad / heads;
        let kvf = KvFormat::new(&spec, dh);
        let mut mk = |seed: u64| {
            let mut r = Pcg64::new(seed);
            let mut codes = vec![0u8; rows * kvf.codes_per_row(ad)];
            let mut scales = vec![0.0f32; rows * kvf.scales_per_row(ad)];
            for i in 0..rows {
                let row = r.normal_vec(ad, 1.0);
                kvf.encode_row(
                    &row,
                    &mut codes[i * ad / 2..(i + 1) * ad / 2],
                    &mut scales[i * (ad / dh)..(i + 1) * (ad / dh)],
                );
            }
            (codes, scales)
        };
        let (k_codes, k_scales) = mk(31);
        let (v_codes, v_scales) = mk(32);
        let klane = kvf.lane(&k_codes, &k_scales, ad);
        let vlane = kvf.lane(&v_codes, &v_scales, ad);
        let qrow = rng.normal_vec(ad, 1.0);
        let ascale = 1.0 / (dh as f32).sqrt();
        let aflops = 4 * rows * ad;
        let mut att = vec![0.0f32; rows];
        let mut ctx = vec![0.0f32; ad];
        ab_cell(&mut json, "simd_lut_attend_96x256", aflops, 16 * ab_iters, &mut || {
            ctx.iter_mut().for_each(|v| *v = 0.0);
            for h in 0..heads {
                let off = h * dh;
                lut_attend_head(
                    &qrow[off..off + dh],
                    klane,
                    vlane,
                    off,
                    rows,
                    ascale,
                    &mut att,
                    &mut ctx[off..off + dh],
                );
            }
        })
    };
    // hand dispatch back to the environment for the remaining cells
    simd::force_scalar(
        std::env::var("LLMDT_FORCE_SCALAR")
            .map(|v| !(v.is_empty() || v == "0"))
            .unwrap_or(false),
    );

    // W4A4: activations encoded to 4-bit codes per call — exactly what the
    // serving path pays per linear per step — then code x code through the
    // 16x16 product LUT. Compared against the fused W4-only lut_gemm above;
    // the win is activation-side traffic, not FLOPs, so a modest ratio here
    // is expected on cache-resident shapes.
    let aq4 = ActQuantizer::new(&spec);
    let s = bench("rust_w4a4_gemm_256x512x512", ab_iters, || {
        let xq = aq4.encode(&x, packed.block);
        black_box(w4a4_gemm(&xq, &packed));
    });
    record(&mut json, "rust_w4a4_gemm_256x512x512", flops, &s);
    let s = bench("rust_w4a4_gemm_decode_4x512x512", 8 * ab_iters, || {
        let xq = aq4.encode(&xd, packed.block);
        black_box(w4a4_gemm(&xq, &packed));
    });
    record(&mut json, "rust_w4a4_gemm_decode_4x512x512", dflops, &s);

    if smoke {
        let _ = (speedup_gemm, speedup_attend);
        if isa == simd::Isa::Scalar {
            println!("note: SIMD smoke gate skipped — no vector ISA detected on this host");
        } else {
            // the SIMD acceptance gate (CI): the shuffle-based nibble -> LUT
            // expansion must not lose to the scalar oracle it replaces
            assert!(
                speedup_lut >= 1.0,
                "SIMD lut_gemm lost to the scalar oracle: x{speedup_lut:.2}"
            );
        }
        json.write("BENCH_kernel.json")?;
        return Ok(());
    }

    // -- 1: GEMM kernel shootout (dense f32) -------------------------------
    let mut out = vec![0.0f32; m * n];
    let s = bench("gemm_blocked_256x512x512", 48, || {
        out.iter_mut().for_each(|v| *v = 0.0);
        gemm(m, k, n, x.data(), b.data(), &mut out);
    });
    record(&mut json, "gemm_blocked_256x512x512", flops, &s);
    let s = bench("gemm_naive_256x512x512", 12, || {
        out.iter_mut().for_each(|v| *v = 0.0);
        gemm_naive(m, k, n, x.data(), b.data(), &mut out);
    });
    record(&mut json, "gemm_naive_256x512x512", flops, &s);
    let s = bench("gemm_ikj_skipzero_256x512x512", 12, || {
        out.iter_mut().for_each(|v| *v = 0.0);
        gemm_ikj_skipzero(m, k, n, x.data(), b.data(), &mut out);
    });
    record(&mut json, "gemm_ikj_skipzero_256x512x512", flops, &s);

    // batch-4 decode-shaped rows: dense activations, the shape the serving
    // engine issues per linear per step (the skip branch's worst case)
    let mut dout = vec![0.0f32; bm * n];
    let s = bench("gemm_blocked_decode_4x512x512", 256, || {
        dout.iter_mut().for_each(|v| *v = 0.0);
        gemm(bm, k, n, xd.data(), b.data(), &mut dout);
    });
    record(&mut json, "gemm_blocked_decode_4x512x512", dflops, &s);
    let s = bench("gemm_skipzero_decode_4x512x512", 128, || {
        dout.iter_mut().for_each(|v| *v = 0.0);
        gemm_ikj_skipzero(bm, k, n, xd.data(), b.data(), &mut dout);
    });
    record(&mut json, "gemm_skipzero_decode_4x512x512", dflops, &s);

    // -- 2: fused packed-LUT GEMM vs dequant-then-matmul -------------------
    let s_oracle = bench("rust_dequant_matmul_256x512x512", 12, || {
        let wt = q.dequant(&spec);
        x.matmul(&wt)
    });
    record(&mut json, "rust_dequant_matmul_256x512x512", flops, &s_oracle);
    let s_fused = bench("rust_lut_gemm_256x512x512", 24, || lut_gemm(&x, &packed));
    record(&mut json, "rust_lut_gemm_256x512x512", flops, &s_fused);
    let speedup = s_oracle.mean_secs() / s_fused.mean_secs();
    println!("bench lut_gemm_vs_dequant_matmul               x{speedup:.2}");
    json.record("lut_gemm_vs_dequant_matmul", "speedup", speedup);

    // decode shape for the fused path too (weight traffic per token)
    let s = bench("rust_lut_gemm_decode_4x512x512", 64, || lut_gemm(&xd, &packed));
    record(&mut json, "rust_lut_gemm_decode_4x512x512", dflops, &s);

    // -- 3: persistent-pool threading vs per-call thread::scope ------------
    // prefill shapes where gemm_auto_threads engages; both sides run the
    // identical serial kernel per chunk, so the delta is pure dispatch cost
    for (pm, iters) in [(256usize, 48usize), (64, 128)] {
        let t = gemm_auto_threads(pm, k, n);
        let xa = Tensor::new(&[pm, k], rng.normal_vec(pm * k, 1.0));
        let mut pout = vec![0.0f32; pm * n];
        let pflops = 2 * pm * k * n;
        let name = format!("gemm_pool_{pm}x{k}x{n}");
        let s_pool = bench(&name, iters, || {
            pout.iter_mut().for_each(|v| *v = 0.0);
            gemm_threaded(pm, k, n, xa.data(), b.data(), &mut pout, t);
        });
        record(&mut json, &name, pflops, &s_pool);
        let name = format!("gemm_scope_{pm}x{k}x{n}");
        let s_scope = bench(&name, iters, || {
            pout.iter_mut().for_each(|v| *v = 0.0);
            gemm_scope_threaded(pm, k, n, xa.data(), b.data(), &mut pout, t);
        });
        record(&mut json, &name, pflops, &s_scope);
        let win = s_scope.mean_secs() / s_pool.mean_secs();
        println!("bench gemm_pool_vs_scope_{pm}x{k}x{n}             x{win:.2} (threads={t})");
        json.record(&format!("gemm_pool_vs_scope_{pm}x{k}x{n}"), "speedup", win);
    }

    // -- 4: fused packed-KV attention vs dequantize-then-attend ------------
    // decode shape: one query row attending over a cached history (the
    // shape the serving engine issues per head per layer per step)
    let (rows, ad, heads) = (96usize, 256usize, 8usize);
    let dh = ad / heads;
    let kvf = KvFormat::new(&spec, dh);
    let mk_lane = |seed: u64| {
        let mut r = Pcg64::new(seed);
        let mut codes = vec![0u8; rows * kvf.codes_per_row(ad)];
        let mut scales = vec![0.0f32; rows * kvf.scales_per_row(ad)];
        for i in 0..rows {
            let row = r.normal_vec(ad, 1.0);
            kvf.encode_row(
                &row,
                &mut codes[i * ad / 2..(i + 1) * ad / 2],
                &mut scales[i * (ad / dh)..(i + 1) * (ad / dh)],
            );
        }
        (codes, scales)
    };
    let (k_codes, k_scales) = mk_lane(21);
    let (v_codes, v_scales) = mk_lane(22);
    let aq = rng.normal_vec(ad, 1.0);
    let ascale = 1.0 / (dh as f32).sqrt();
    let aflops = 4 * rows * ad; // scores + V accumulation MACs
    let mut att = vec![0.0f32; rows];
    let mut ctx = vec![0.0f32; ad];
    let mut kd = vec![0.0f32; rows * ad];
    let mut vd = vec![0.0f32; rows * ad];
    let s_oracle = bench("dequant_then_attend_96x256", 512, || {
        // the oracle pays the full lane expansion into f32 buffers first
        for i in 0..rows {
            kvf.dequant_row(
                &k_codes[i * ad / 2..(i + 1) * ad / 2],
                &k_scales[i * (ad / dh)..(i + 1) * (ad / dh)],
                &mut kd[i * ad..(i + 1) * ad],
            );
            kvf.dequant_row(
                &v_codes[i * ad / 2..(i + 1) * ad / 2],
                &v_scales[i * (ad / dh)..(i + 1) * (ad / dh)],
                &mut vd[i * ad..(i + 1) * ad],
            );
        }
        ctx.iter_mut().for_each(|v| *v = 0.0);
        for h in 0..heads {
            let off = h * dh;
            attend_head(
                &aq[off..off + dh],
                &kd,
                &vd,
                ad,
                off,
                rows,
                ascale,
                &mut att,
                &mut ctx[off..off + dh],
            );
        }
    });
    record(&mut json, "dequant_then_attend_96x256", aflops, &s_oracle);
    let klane = kvf.lane(&k_codes, &k_scales, ad);
    let vlane = kvf.lane(&v_codes, &v_scales, ad);
    let s_fused = bench("lut_attend_96x256", 1024, || {
        ctx.iter_mut().for_each(|v| *v = 0.0);
        for h in 0..heads {
            let off = h * dh;
            lut_attend_head(
                &aq[off..off + dh],
                klane,
                vlane,
                off,
                rows,
                ascale,
                &mut att,
                &mut ctx[off..off + dh],
            );
        }
    });
    record(&mut json, "lut_attend_96x256", aflops, &s_fused);
    let win = s_oracle.mean_secs() / s_fused.mean_secs();
    println!("bench lut_attend_vs_dequant_attend             x{win:.2}");
    json.record("lut_attend_vs_dequant_attend", "speedup", win);

    // long-context cell: crosses the pool threshold (2 * rows * d MACs),
    // heads fan out across the persistent workers
    {
        let rows = 4608usize;
        let kvf = KvFormat::new(&spec, dh);
        // distinct K and V lanes: the V pass must stream its own buffer,
        // as it does in the engine, not re-read a cache-warm K lane
        let mk_long = |seed: u64| {
            let mut r = Pcg64::new(seed);
            let mut codes = vec![0u8; rows * kvf.codes_per_row(ad)];
            let mut scales = vec![0.0f32; rows * kvf.scales_per_row(ad)];
            for i in 0..rows {
                let row = r.normal_vec(ad, 1.0);
                kvf.encode_row(
                    &row,
                    &mut codes[i * ad / 2..(i + 1) * ad / 2],
                    &mut scales[i * (ad / dh)..(i + 1) * (ad / dh)],
                );
            }
            (codes, scales)
        };
        let (lk_codes, lk_scales) = mk_long(23);
        let (lv_codes, lv_scales) = mk_long(24);
        let klane = kvf.lane(&lk_codes, &lk_scales, ad);
        let vlane = kvf.lane(&lv_codes, &lv_scales, ad);
        let lflops = 4 * rows * ad;
        let mut att = vec![0.0f32; rows];
        let mut ctx = vec![0.0f32; ad];
        let s = bench("lut_attend_longctx_4608x256", 128, || {
            ctx.iter_mut().for_each(|v| *v = 0.0);
            lut_attend(&aq, klane, vlane, heads, rows, ascale, &mut att, &mut ctx);
        });
        record(&mut json, "lut_attend_longctx_4608x256", lflops, &s);
    }

    // -- 5: XLA lut_matmul artifact (optional) -----------------------------
    // Any failure here — missing artifacts, a stale manifest, a bind or
    // run error — must not cost us the pure-Rust cells already measured:
    // skip with a note and still write the trajectory file.
    let xla_cell = || -> anyhow::Result<BenchStats> {
        let session = Session::open("artifacts", "checkpoints", "results")?;
        let exe = session.engine.load("lut_matmul_bench")?;
        let cb = Tensor::new(&[16], spec.padded16());
        let mut fixed = HashMap::new();
        fixed.insert("codes".to_string(), Value::I8(q.codes.clone(), vec![k, n]));
        fixed.insert("scales".to_string(), Value::F32(q.scales.clone()));
        fixed.insert("codebook".to_string(), Value::F32(cb));
        let bound = exe.bind(&fixed)?;
        let mut rest = HashMap::new();
        rest.insert("x".to_string(), Value::F32(x.clone()));
        let mut err = None;
        let s = bench("xla_lut_matmul_256x512x512", 32, || {
            if let Err(e) = exe.run_bound(&bound, &rest) {
                err.get_or_insert(e);
            }
        });
        match err {
            Some(e) => Err(e),
            None => Ok(s),
        }
    };
    match xla_cell() {
        Ok(s) => record(&mut json, "xla_lut_matmul_256x512x512", flops, &s),
        Err(e) => println!("note: XLA lut_matmul cell skipped ({e:#})"),
    }

    // -- 6: traced kernel cell (observability artifact) --------------------
    // a short tracing-enabled pass over the three instrumented kernels so
    // every bench run also leaves a Perfetto-loadable kernel timeline
    // (per-kernel spans + pool dispatch/task spans) next to
    // BENCH_kernel.json
    {
        use llm_datatypes::obs::{export, trace};
        trace::reset();
        trace::set_enabled(true);
        let t = gemm_auto_threads(m, k, n);
        for _ in 0..4 {
            out.iter_mut().for_each(|v| *v = 0.0);
            gemm_threaded(m, k, n, x.data(), b.data(), &mut out, t);
            lut_gemm(&x, &packed);
            ctx.iter_mut().for_each(|v| *v = 0.0);
            lut_attend(&aq, klane, vlane, heads, rows, ascale, &mut att, &mut ctx);
        }
        trace::set_enabled(false);
        let snap = trace::snapshot_and_drain();
        std::fs::write("BENCH_kernel.trace.json", export::chrome_trace_json(&snap))?;
        println!(
            "bench kernel_traced                      events={} dropped={}",
            snap.records.len(),
            snap.dropped,
        );
        json.record("kernel_traced", "trace_events", snap.records.len() as f64);
    }

    json.write("BENCH_kernel.json")?;
    Ok(())
}
