//! Bench target: regenerate paper Table 8 (W4A4 +- SmoothQuant) at quick scale and time it.
//! Full-scale regeneration: `repro table 8`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    exp::ensure_model(&session, "nano")?;
    let table = exp::w4a4::run(&session, Scale::Quick)?;
    println!("{}", table.render());
    bench("table08_w4a4", 2, || exp::w4a4::run(&session, Scale::Quick).unwrap());
    Ok(())
}
