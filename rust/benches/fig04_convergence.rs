//! Bench target: regenerate paper Figure 4/5 (SF4 -> NF4) at quick scale and time it.
//! Full-scale regeneration: `repro figure 4`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;

    let table = exp::convergence::run_fig4(&session)?;
    println!("{}", table.render());
    bench("fig04_convergence", 2, || exp::convergence::run_fig4(&session).unwrap());
    Ok(())
}
