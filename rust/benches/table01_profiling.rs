//! Bench target: regenerate paper Table 1/11 (profiling) at quick scale and time it.
//! Full-scale regeneration: `repro table 1`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    exp::ensure_model(&session, "nano")?;
    let table = exp::profile::run(&session, Scale::Quick)?;
    println!("{}", table.render());
    bench("table01_profiling", 2, || exp::profile::run(&session, Scale::Quick).unwrap());
    Ok(())
}
