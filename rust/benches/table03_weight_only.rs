//! Bench target: regenerate paper Table 3/13 (weight-only eval) at quick scale and time it.
//! Full-scale regeneration: `repro table 3`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    exp::ensure_model(&session, "nano")?;
    let table = exp::weight_only::run(&session, Scale::Quick)?;
    println!("{}", table.render());
    bench("table03_weight_only", 2, || exp::weight_only::run(&session, Scale::Quick).unwrap());
    Ok(())
}
