//! Bench target: regenerate paper Table 14 (multi-lingual) at quick scale and time it.
//! Full-scale regeneration: `repro table 14`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    exp::ensure_model(&session, "nano")?;
    let table = exp::multilingual::run(&session, Scale::Quick, "nano")?;
    println!("{}", table.render());
    bench("table14_multilingual", 2, || exp::multilingual::run(&session, Scale::Quick, "nano").unwrap());
    Ok(())
}
