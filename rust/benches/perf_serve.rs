//! Perf: serving. Two workloads:
//!
//! 1. the historical one-shot scoring loop (dynamic batching win vs batch=1,
//!    §Perf target >= 2x throughput at 16+ concurrent clients), now running
//!    through the decode-engine shim; and
//! 2. sustained multi-token decode through the continuous-batching engine
//!    with the fused `[B, d]` batched step, swept over batch sizes 1/4/16
//!    per weight format (fp32 baseline vs sf4 vs e2m1_sp supernormal) — the
//!    memory-bound loop the paper's formats are priced for. The fused path
//!    amortizes the per-forward fixed costs (checkpoint lookups, tensor
//!    allocations, one attention/layernorm pass setup) across all rows of
//!    the batch — the naive ikj kernel still reads the weights per row, so
//!    per-call overhead, not weight streaming, is what batching currently
//!    buys; decode tok/s must climb with batch size regardless.
//!
//! `--smoke` runs a cut-down sweep (batch 1/4, fewer tokens, scoring loop
//! skipped) as a CI gate: it still fails fast if fused batching regresses
//! (batch-4 must beat batch-1 on sf4), just cheaply. Each cell is timed
//! best-of-2 so a single scheduler hiccup cannot flip the gate.

use std::time::{Duration, Instant};

use llm_datatypes::coordinator::pipeline::{fake_quant_checkpoint, PipelineConfig};
use llm_datatypes::coordinator::serve::{run_loadgen, ServeConfig, Server};
use llm_datatypes::coordinator::{corpus_for, trainer, Session};
use llm_datatypes::model_io::zoo;
use llm_datatypes::rng::Pcg64;
use llm_datatypes::serving::{run_decode_loadgen, Engine, EngineConfig, SchedulerConfig};

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let session = Session::open("artifacts", "checkpoints", "results")?;
    let cfg = zoo("nano")?;
    let ckpt = match session.load_checkpoint("nano") {
        Ok(c) => c,
        Err(_) => trainer::init_lm_params(&cfg, 0x5eed),
    };
    let corpus = corpus_for(&cfg);
    let mut rng = Pcg64::new(7);
    let prompts: Vec<Vec<i32>> = (0..64)
        .map(|_| {
            let start = rng.below(corpus.heldout.len() - cfg.seq);
            corpus.heldout[start..start + cfg.seq / 2].to_vec()
        })
        .collect();

    // -- workload 1: one-shot scoring, batching win ------------------------
    if !smoke {
        let sf4 =
            fake_quant_checkpoint(&cfg, &ckpt, &PipelineConfig::weight_only("sf4"), &corpus)?;
        let mut results = Vec::new();
        for (label, clients, wait) in [
            ("serve_batch1", 1usize, Duration::from_micros(1)),
            ("serve_batched_16c", 16usize, Duration::from_millis(2)),
        ] {
            let server =
                Server::new(cfg, sf4.clone(), ServeConfig { max_wait: wait, max_requests: 0 });
            let total = 192;
            let t0 = Instant::now();
            let stats = run_loadgen(server, prompts.clone(), clients, total / clients)?;
            let rps = stats.served as f64 / t0.elapsed().as_secs_f64();
            println!(
                "bench {label:40} req/s={rps:8.1} fill={:.2} p50={:?} p99={:?}",
                stats.mean_batch_fill, stats.p50_latency, stats.p99_latency
            );
            results.push((label, rps));
        }
        let speedup = results[1].1 / results[0].1;
        println!("bench serve_batching_speedup                  x{speedup:.2}");
    }

    // -- workload 2: sustained decode tok/s per format x batch size --------
    let batch_sizes: &[usize] = if smoke { &[1, 4] } else { &[1, 4, 16] };
    let (per_client, max_new) = if smoke { (1usize, 16usize) } else { (2usize, 24usize) };
    let mut sweep: Vec<(&str, usize, f64)> = Vec::new();
    for format in ["fp32", "sf4", "e2m1_sp"] {
        let weights = match format {
            "fp32" => ckpt.clone(),
            f => fake_quant_checkpoint(&cfg, &ckpt, &PipelineConfig::weight_only(f), &corpus)?,
        };
        for &b in batch_sizes {
            // best-of-2: the gate below compares timings, so shield it from
            // one-off scheduler jitter
            let mut best_tps = 0.0f64;
            let mut last = None;
            for _ in 0..2 {
                let mut engine = Engine::new(
                    cfg,
                    weights.clone(),
                    EngineConfig {
                        slots: b,
                        kv_capacity: 0,
                        scheduler: SchedulerConfig { max_batch: b, ..SchedulerConfig::default() },
                    },
                );
                let report = run_decode_loadgen(&mut engine, &prompts, b, per_client, max_new)?;
                best_tps = best_tps.max(report.decode_tps);
                last = Some(report);
            }
            let report = last.expect("two timed runs");
            println!(
                "bench serve_decode_{format:<8}_b{b:<2} tok/s={best_tps:8.1} itl_p50={:?} \
                 occupancy={:.2} fused_batch={:.2} fused_gemms={}",
                report.itl_p50,
                report.mean_occupancy,
                report.mean_fused_batch,
                report.fused_gemms,
            );
            sweep.push((format, b, best_tps));
        }
    }
    // scaling lines: fused batching must amortize the weight stream
    let top = *batch_sizes.last().unwrap();
    for format in ["fp32", "sf4", "e2m1_sp"] {
        let tps_at = |b: usize| {
            sweep
                .iter()
                .find(|&&(f, bb, _)| f == format && bb == b)
                .map(|&(_, _, tps)| tps)
                .expect("sweep covers every (format, batch) cell")
        };
        let scaling = tps_at(top) / tps_at(1);
        println!("bench serve_decode_{format}_b{top}_vs_b1          x{scaling:.2}");
        if format == "sf4" {
            // the batching acceptance gate: fused batch-N decode must beat
            // sequential batch-1 decode outright
            assert!(
                scaling > 1.0,
                "fused batched decode regressed: sf4 batch-{top} {}x batch-1",
                scaling
            );
        }
    }
    Ok(())
}
