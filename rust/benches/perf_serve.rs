//! Perf: serving. Two workloads:
//!
//! 1. the historical one-shot scoring loop (dynamic batching win vs batch=1,
//!    §Perf target >= 2x throughput at 16+ concurrent clients), now running
//!    through the decode-engine shim; and
//! 2. sustained multi-token decode through the continuous-batching engine,
//!    comparing weight formats (fp32 baseline vs sf4 vs e2m1_sp supernormal)
//!    on generated tokens/sec — the memory-bound loop the paper's formats
//!    are priced for.

use std::time::{Duration, Instant};

use llm_datatypes::coordinator::pipeline::{fake_quant_checkpoint, PipelineConfig};
use llm_datatypes::coordinator::serve::{run_loadgen, ServeConfig, Server};
use llm_datatypes::coordinator::{corpus_for, trainer, Session};
use llm_datatypes::model_io::zoo;
use llm_datatypes::rng::Pcg64;
use llm_datatypes::serving::{run_decode_loadgen, Engine, EngineConfig, SchedulerConfig};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    let cfg = zoo("nano")?;
    let ckpt = match session.load_checkpoint("nano") {
        Ok(c) => c,
        Err(_) => trainer::init_lm_params(&cfg, 0x5eed),
    };
    let corpus = corpus_for(&cfg);
    let mut rng = Pcg64::new(7);
    let prompts: Vec<Vec<i32>> = (0..64)
        .map(|_| {
            let start = rng.below(corpus.heldout.len() - cfg.seq);
            corpus.heldout[start..start + cfg.seq / 2].to_vec()
        })
        .collect();

    // -- workload 1: one-shot scoring, batching win ------------------------
    let sf4 = fake_quant_checkpoint(&cfg, &ckpt, &PipelineConfig::weight_only("sf4"), &corpus)?;
    let mut results = Vec::new();
    for (label, clients, wait) in [
        ("serve_batch1", 1usize, Duration::from_micros(1)),
        ("serve_batched_16c", 16usize, Duration::from_millis(2)),
    ] {
        let server =
            Server::new(cfg, sf4.clone(), ServeConfig { max_wait: wait, max_requests: 0 });
        let total = 192;
        let t0 = Instant::now();
        let stats = run_loadgen(server, prompts.clone(), clients, total / clients)?;
        let rps = stats.served as f64 / t0.elapsed().as_secs_f64();
        println!(
            "bench {label:40} req/s={rps:8.1} fill={:.2} p50={:?} p99={:?}",
            stats.mean_batch_fill, stats.p50_latency, stats.p99_latency
        );
        results.push((label, rps));
    }
    let speedup = results[1].1 / results[0].1;
    println!("bench serve_batching_speedup                  x{speedup:.2}");

    // -- workload 2: sustained decode tokens/sec per weight format ---------
    let slots = 8usize;
    let (clients, per_client, max_new) = (8usize, 3usize, 24usize);
    let mut decode_results = Vec::new();
    for format in ["fp32", "sf4", "e2m1_sp"] {
        let weights = match format {
            "fp32" => ckpt.clone(),
            f => fake_quant_checkpoint(&cfg, &ckpt, &PipelineConfig::weight_only(f), &corpus)?,
        };
        let mut engine = Engine::new(
            cfg,
            weights,
            EngineConfig {
                slots,
                kv_capacity: 0,
                scheduler: SchedulerConfig { max_batch: slots, ..SchedulerConfig::default() },
            },
        );
        let report = run_decode_loadgen(&mut engine, &prompts, clients, per_client, max_new)?;
        println!(
            "bench serve_decode_{format:<25} tok/s={:8.1} ttft_p50={:?} itl_p50={:?} \
             itl_p99={:?} occupancy={:.2}",
            report.decode_tps,
            report.ttft_p50,
            report.itl_p50,
            report.itl_p99,
            report.mean_occupancy,
        );
        decode_results.push((format, report.decode_tps));
    }
    // sanity line: quantized decode should not collapse vs fp32 (same
    // dense matmul substrate; fake-quant only changes the values)
    let fp32 = decode_results[0].1;
    for (format, tps) in &decode_results[1..] {
        println!("bench serve_decode_{format}_vs_fp32            x{:.2}", tps / fp32);
    }
    Ok(())
}
