//! Perf: serving. Five workloads:
//!
//! 1. the historical one-shot scoring loop (dynamic batching win vs batch=1,
//!    §Perf target >= 2x throughput at 16+ concurrent clients), now running
//!    through the decode-engine shim;
//! 2. sustained multi-token decode through the continuous-batching engine
//!    with the fused `[B, d]` batched step, swept over batch sizes 1/4/16
//!    per weight format (fp32 baseline vs sf4 vs e2m1_sp supernormal) on
//!    the nano model — the batching-amortization line PR 2 established; and
//! 3. **packed vs dense weight backends** on the `large` model, whose f32
//!    weights (~43 MB) overflow the last-level cache, so sustained decode
//!    is genuinely weight-stream-bound: dense fp32 and fake-quant sf4
//!    stream the full f32 matrix per step, while the packed backend
//!    (`packed_checkpoint` + fused `lut_gemm`) streams 4-bit codes and
//!    expands them through the codebook LUT inside the kernel; and
//! 4. **packed vs fp32 KV cache** on the `med` model with packed sf4
//!    weights (so the weight stream is already small and sustained decode
//!    is KV-traffic-bound): fp32 lanes stream the full f32 K/V history per
//!    step, packed lanes (`--kv-format`) stream nibble codes + per-head
//!    scales through the fused `lut_attend` kernels. Cells record decode
//!    tok/s, KV KiB read per forwarded token, and worker-pool utilization;
//!    and
//! 5. **paged vs contiguous KV admission** under a fixed memory budget
//!    (pages for two full nano windows): the contiguous-equivalent layout
//!    (one window-sized page per sequence, i.e. worst-case reservation)
//!    can keep at most 2 sequences resident, while 16-position pages admit
//!    the whole 4-client mix concurrently. Cells record decode tok/s, peak
//!    concurrent sessions, and page fragmentation.
//!
//! Since PR 10 workload 2b rides along: the packed-sf4, packed-KV batch-4
//! decode cell timed with the kernels pinned to the scalar oracle vs with
//! SIMD dispatch live (`tensor::simd::force_scalar`) — the serving-level
//! A/B for the `--force-scalar` lever.
//!
//! `--page-size N` (default 16) sets the KV page size every decode cell
//! runs with, so the whole bench — including the CI gates — exercises the
//! paged path.
//!
//! `--smoke` runs a cut-down sweep (batch 1/4, fewer tokens, scoring loop
//! skipped) as a CI gate with four assertions: fused batch-4 sf4 decode
//! must beat batch-1 (the PR-2 gate), packed sf4 weights must be at least
//! as fast as dense fp32 at batch 4 (the PR-3 gate), sf4 packed-KV decode
//! must be at least as fast as fp32-KV at batch 4 (the PR-4 gate), and the
//! paged layout must admit more concurrent sessions than the
//! contiguous-equivalent one on the same budget (the PR-5 gate). Each cell
//! is timed best-of-2 so a single scheduler hiccup cannot flip a gate.
//! Every cell lands in `BENCH_serve.json` for the perf trajectory.

use std::time::{Duration, Instant};

use llm_datatypes::bench_util::BenchJson;
use llm_datatypes::coordinator::pipeline::{
    fake_quant_checkpoint, packed_checkpoint, PipelineConfig,
};
use llm_datatypes::coordinator::serve::{run_loadgen, ServeConfig, Server};
use llm_datatypes::coordinator::{corpus_for, trainer, Session};
use llm_datatypes::model_io::{zoo, Checkpoint, ModelConfig};
use llm_datatypes::rng::Pcg64;
use llm_datatypes::serving::{run_decode_loadgen, Engine, EngineConfig, SchedulerConfig};

fn prompts_for(cfg: &ModelConfig, n: usize, len: usize, seed: u64) -> Vec<Vec<i32>> {
    let corpus = corpus_for(cfg);
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            let start = rng.below(corpus.heldout.len() - cfg.seq);
            corpus.heldout[start..start + len].to_vec()
        })
        .collect()
}

/// Best-of-2 sustained-decode tok/s for one (checkpoint, batch, kv-format,
/// page-size) cell.
#[allow(clippy::too_many_arguments)]
fn decode_cell(
    cfg: ModelConfig,
    weights: &Checkpoint,
    prompts: &[Vec<i32>],
    b: usize,
    per_client: usize,
    max_new: usize,
    kv_format: Option<&'static str>,
    page_size: usize,
) -> anyhow::Result<(f64, llm_datatypes::serving::MetricsReport)> {
    let mut best_tps = 0.0f64;
    let mut last = None;
    for _ in 0..2 {
        let mut engine = Engine::new(
            cfg,
            weights.clone(),
            EngineConfig {
                slots: b,
                kv_format,
                page_size,
                scheduler: SchedulerConfig { max_batch: b, ..SchedulerConfig::default() },
                ..EngineConfig::default()
            },
        );
        let report = run_decode_loadgen(&mut engine, prompts, b, per_client, max_new)?;
        best_tps = best_tps.max(report.decode_tps);
        last = Some(report);
    }
    Ok((best_tps, last.expect("two timed runs")))
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let page_size: usize = argv
        .windows(2)
        .find(|w| w[0] == "--page-size")
        .map(|w| w[1].parse())
        .transpose()?
        .unwrap_or(16);
    let mut json = BenchJson::new();
    let session = Session::open("artifacts", "checkpoints", "results")?;
    let cfg = zoo("nano")?;
    let ckpt = match session.load_checkpoint("nano") {
        Ok(c) => c,
        Err(_) => trainer::init_lm_params(&cfg, 0x5eed),
    };
    let corpus = corpus_for(&cfg);
    let prompts = prompts_for(&cfg, 64, cfg.seq / 2, 7);

    // -- workload 1: one-shot scoring, batching win ------------------------
    if !smoke {
        let sf4 =
            fake_quant_checkpoint(&cfg, &ckpt, &PipelineConfig::weight_only("sf4"), &corpus)?;
        let mut results = Vec::new();
        for (label, clients, wait) in [
            ("serve_batch1", 1usize, Duration::from_micros(1)),
            ("serve_batched_16c", 16usize, Duration::from_millis(2)),
        ] {
            let server =
                Server::new(cfg, sf4.clone(), ServeConfig { max_wait: wait, max_requests: 0 });
            let total = 192;
            let t0 = Instant::now();
            let stats = run_loadgen(server, prompts.clone(), clients, total / clients)?;
            let rps = stats.served as f64 / t0.elapsed().as_secs_f64();
            println!(
                "bench {label:40} req/s={rps:8.1} fill={:.2} p50={:?} p99={:?}",
                stats.mean_batch_fill, stats.p50_latency, stats.p99_latency
            );
            json.record(label, "req_s", rps);
            results.push((label, rps));
        }
        let speedup = results[1].1 / results[0].1;
        println!("bench serve_batching_speedup                  x{speedup:.2}");
        json.record("serve_batching_speedup", "x", speedup);
    }

    // -- workload 2: sustained decode tok/s per format x batch size --------
    let batch_sizes: &[usize] = if smoke { &[1, 4] } else { &[1, 4, 16] };
    let (per_client, max_new) = if smoke { (1usize, 16usize) } else { (2usize, 24usize) };
    let mut sweep: Vec<(&str, usize, f64)> = Vec::new();
    for format in ["fp32", "sf4", "e2m1_sp"] {
        let weights = match format {
            "fp32" => ckpt.clone(),
            f => fake_quant_checkpoint(&cfg, &ckpt, &PipelineConfig::weight_only(f), &corpus)?,
        };
        for &b in batch_sizes {
            let (best_tps, report) =
                decode_cell(cfg, &weights, &prompts, b, per_client, max_new, None, page_size)?;
            println!(
                "bench serve_decode_{format:<8}_b{b:<2} tok/s={best_tps:8.1} itl_p50={:?} \
                 occupancy={:.2} fused_batch={:.2} fused_gemms={}",
                report.itl_p50,
                report.mean_occupancy,
                report.mean_fused_batch,
                report.fused_gemms,
            );
            json.record(&format!("serve_decode_{format}_b{b}"), "tok_s", best_tps);
            sweep.push((format, b, best_tps));
        }
    }
    // scaling lines: fused batching must amortize the per-step fixed costs
    let top = *batch_sizes.last().unwrap();
    for format in ["fp32", "sf4", "e2m1_sp"] {
        let tps_at = |b: usize| {
            sweep
                .iter()
                .find(|&&(f, bb, _)| f == format && bb == b)
                .map(|&(_, _, tps)| tps)
                .expect("sweep covers every (format, batch) cell")
        };
        let scaling = tps_at(top) / tps_at(1);
        println!("bench serve_decode_{format}_b{top}_vs_b1          x{scaling:.2}");
        json.record(&format!("serve_decode_{format}_b{top}_vs_b1"), "x", scaling);
        if format == "sf4" && smoke {
            // the batching acceptance gate (CI): fused batch-N decode must
            // beat sequential batch-1 decode outright. Smoke-only so a full
            // bench run on a loaded box still reaches workload 3 and the
            // BENCH_serve.json write.
            assert!(
                scaling > 1.0,
                "fused batched decode regressed: sf4 batch-{top} {}x batch-1",
                scaling
            );
        }
    }

    // -- workload 2b: SIMD vs forced-scalar kernels, end to end ------------
    // the same packed-sf4, packed-KV batch-4 decode cell timed twice: once
    // with every kernel pinned to the scalar oracle (the --force-scalar /
    // LLMDT_FORCE_SCALAR lever) and once with SIMD dispatch live — the
    // serving-level view of the perf_kernel scalar-vs-SIMD A/B. No gate:
    // end-to-end decode is scheduler-noisy, so the acceptance assertion
    // lives in perf_kernel --smoke where the kernels are timed in isolation.
    {
        use llm_datatypes::tensor::simd;
        let weights =
            packed_checkpoint(&cfg, &ckpt, &PipelineConfig::weight_only("sf4"), &corpus)?;
        simd::force_scalar(true);
        let (scalar_tps, _) =
            decode_cell(cfg, &weights, &prompts, 4, per_client, max_new, Some("sf4"), page_size)?;
        simd::force_scalar(false);
        let (simd_tps, _) =
            decode_cell(cfg, &weights, &prompts, 4, per_client, max_new, Some("sf4"), page_size)?;
        // hand dispatch back to the environment for the remaining workloads
        simd::force_scalar(
            std::env::var("LLMDT_FORCE_SCALAR")
                .map(|v| !(v.is_empty() || v == "0"))
                .unwrap_or(false),
        );
        println!("bench serve_decode_sf4_packedkv_b4_scalar      tok/s={scalar_tps:8.1}");
        println!("bench serve_decode_sf4_packedkv_b4_simd        tok/s={simd_tps:8.1}");
        json.record("serve_decode_sf4_packedkv_b4_scalar", "tok_s", scalar_tps);
        json.record("serve_decode_sf4_packedkv_b4_simd", "tok_s", simd_tps);
        let win = simd_tps / scalar_tps;
        println!(
            "bench serve_decode_simd_vs_scalar_b4           x{win:.2} (isa {})",
            simd::detected().name(),
        );
        json.record("serve_decode_simd_vs_scalar_b4", "x", win);
    }

    // -- workload 3: packed vs dense weight backends (weight-stream-bound) -
    let wcfg = zoo("large")?;
    let wckpt = match session.load_checkpoint("large") {
        Ok(c) => c,
        Err(_) => trainer::init_lm_params(&wcfg, 0x5eed),
    };
    let wcorpus = corpus_for(&wcfg);
    let wprompts = prompts_for(&wcfg, 16, wcfg.seq / 8, 11);
    let (wb, wmax_new) = (4usize, if smoke { 12usize } else { 24 });
    let mut cells: Vec<(&str, f64)> = Vec::new();
    let backends: &[&str] = if smoke {
        &["fp32_dense", "sf4_packed"]
    } else {
        &["fp32_dense", "sf4_dense", "sf4_packed", "e2m1_sp_packed"]
    };
    for &label in backends {
        let weights = match label {
            "fp32_dense" => wckpt.clone(),
            "sf4_dense" => fake_quant_checkpoint(
                &wcfg,
                &wckpt,
                &PipelineConfig::weight_only("sf4"),
                &wcorpus,
            )?,
            "sf4_packed" => packed_checkpoint(
                &wcfg,
                &wckpt,
                &PipelineConfig::weight_only("sf4"),
                &wcorpus,
            )?,
            "e2m1_sp_packed" => packed_checkpoint(
                &wcfg,
                &wckpt,
                &PipelineConfig::weight_only("e2m1_sp"),
                &wcorpus,
            )?,
            other => unreachable!("unknown backend cell {other}"),
        };
        let (best_tps, report) =
            decode_cell(wcfg, &weights, &wprompts, wb, 1, wmax_new, None, page_size)?;
        println!(
            "bench serve_decode_large_{label:<14}_b{wb} tok/s={best_tps:8.1} itl_p50={:?} \
             fused_batch={:.2}",
            report.itl_p50, report.mean_fused_batch,
        );
        json.record(&format!("serve_decode_large_{label}_b{wb}"), "tok_s", best_tps);
        cells.push((label, best_tps));
    }
    let tps_of = |label: &str| {
        cells
            .iter()
            .find(|(l, _)| *l == label)
            .map(|&(_, tps)| tps)
            .expect("backend cell present")
    };
    let packed_win = tps_of("sf4_packed") / tps_of("fp32_dense");
    println!("bench serve_decode_large_packed_vs_fp32_b{wb}     x{packed_win:.2}");
    json.record("serve_decode_large_packed_vs_fp32_b4", "x", packed_win);
    if smoke {
        // the packed-backend acceptance gate: streaming 4-bit codes through
        // the fused LUT GEMM must not lose to streaming dense f32 weights
        // on a model whose weights overflow the cache
        assert!(
            packed_win >= 1.0,
            "packed sf4 decode lost to dense fp32 at batch {wb}: {packed_win:.2}x"
        );
    }

    // -- workload 4: packed vs fp32 KV cache (KV-traffic-bound) ------------
    // med model + packed sf4 weights: the weight stream is already 4-bit,
    // so sustained decode at batch >= 4 is dominated by the KV history each
    // step re-reads — exactly the traffic --kv-format shrinks.
    let kcfg = zoo("med")?;
    let kckpt = match session.load_checkpoint("med") {
        Ok(c) => c,
        Err(_) => trainer::init_lm_params(&kcfg, 0x5eed),
    };
    let kcorpus = corpus_for(&kcfg);
    let kweights =
        packed_checkpoint(&kcfg, &kckpt, &PipelineConfig::weight_only("sf4"), &kcorpus)?;
    let kprompts = prompts_for(&kcfg, 16, kcfg.seq / 2, 13);
    let kv_batches: &[usize] = if smoke { &[1, 4] } else { &[1, 4, 16] };
    let kv_formats: &[Option<&'static str>] = if smoke {
        &[None, Some("sf4")]
    } else {
        &[None, Some("sf4"), Some("nf4"), Some("e2m1_sp")]
    };
    let kv_max_new = if smoke { 12usize } else { 24 };
    let mut kv_cells: Vec<(&str, usize, f64)> = Vec::new();
    for &kvf in kv_formats {
        let label = kvf.unwrap_or("fp32");
        for &b in kv_batches {
            let pool_before = llm_datatypes::runtime::pool::stats();
            let (best_tps, report) =
                decode_cell(kcfg, &kweights, &kprompts, b, 1, kv_max_new, kvf, page_size)?;
            let pool = llm_datatypes::runtime::pool::stats().since(&pool_before);
            let kv_kib_tok = report.kv_bytes_per_token / 1024.0;
            println!(
                "bench serve_decode_kv_{label:<8}_b{b:<2} tok/s={best_tps:8.1} \
                 kv={kv_kib_tok:.1} KiB/tok pool_util={:.2} itl_p50={:?}",
                pool.utilization(),
                report.itl_p50,
            );
            let cell = format!("serve_decode_kv_{label}_b{b}");
            json.record(&cell, "tok_s", best_tps);
            json.record(&cell, "kv_kib_per_tok", kv_kib_tok);
            json.record(&cell, "pool_util", pool.utilization());
            kv_cells.push((label, b, best_tps));
        }
    }
    let kv_tps = |label: &str, b: usize| {
        kv_cells
            .iter()
            .find(|&&(l, bb, _)| l == label && bb == b)
            .map(|&(_, _, tps)| tps)
            .expect("kv sweep covers every (format, batch) cell")
    };
    let kv_win = kv_tps("sf4", 4) / kv_tps("fp32", 4);
    println!("bench serve_decode_kv_sf4_vs_fp32_b4           x{kv_win:.2}");
    json.record("serve_decode_kv_sf4_vs_fp32_b4", "x", kv_win);
    if smoke {
        // the packed-KV acceptance gate: streaming 4-bit KV lanes through
        // the fused dequant-attention must not lose to streaming fp32 lanes
        // on a KV-traffic-bound model
        assert!(
            kv_win >= 1.0,
            "packed sf4 KV decode lost to fp32 KV at batch 4: {kv_win:.2}x"
        );
    }

    // -- workload 5: paged vs contiguous KV admission (fixed budget) -------
    // KV memory for exactly two full nano windows, 4 clients with
    // quarter-window prompts. Contiguous-equivalent = one window-sized
    // page per sequence (worst-case reservation): at most 2 resident.
    // Paged = `--page-size` pages over the same positions: the whole mix
    // admits concurrently, because each sequence only holds the pages its
    // context covers.
    let psize = page_size.clamp(1, cfg.seq);
    let budget_positions = 2 * cfg.seq;
    let paged_prompts = prompts_for(&cfg, 8, cfg.seq / 4, 17);
    let paged_max_new = if smoke { 6 } else { 12 };
    let mut admission_cells: Vec<(String, f64, usize)> = Vec::new();
    for (label, cell_page, cell_pages) in [
        ("contiguous".to_string(), cfg.seq, 2),
        (format!("paged{psize}"), psize, budget_positions / psize),
    ] {
        // best-of-2 on tok/s and peak admission (scheduler noise can
        // depress a single run's peak); fragmentation is recorded from the
        // best-peak run so the cell's gauges describe one run
        let mut best_tps = 0.0f64;
        let mut peak = 0usize;
        let mut frag = 0.0f64;
        for _ in 0..2 {
            let mut engine = Engine::new(
                cfg,
                ckpt.clone(),
                EngineConfig {
                    slots: 4,
                    page_size: cell_page,
                    kv_pages: cell_pages,
                    scheduler: SchedulerConfig { max_batch: 4, ..SchedulerConfig::default() },
                    ..EngineConfig::default()
                },
            );
            let report = run_decode_loadgen(&mut engine, &paged_prompts, 4, 1, paged_max_new)?;
            best_tps = best_tps.max(report.decode_tps);
            if report.peak_occupancy >= peak {
                peak = report.peak_occupancy;
                frag = report.page_fragmentation;
            }
        }
        println!(
            "bench serve_decode_admission_{label:<12} tok/s={best_tps:8.1} \
             peak_sessions={peak} frag={frag:.2}"
        );
        let cell = format!("serve_decode_admission_{label}");
        json.record(&cell, "tok_s", best_tps);
        json.record(&cell, "peak_sessions", peak as f64);
        json.record(&cell, "page_frag", frag);
        admission_cells.push((label, best_tps, peak));
    }
    let contig_peak = admission_cells[0].2;
    let paged_peak = admission_cells[1].2;
    println!(
        "bench serve_decode_admission_paged_vs_contig   {paged_peak} vs {contig_peak} sessions"
    );
    json.record(
        "serve_decode_admission_paged_vs_contig",
        "x",
        paged_peak as f64 / contig_peak.max(1) as f64,
    );
    if smoke {
        // the paged-admission acceptance gate: on the same KV budget, the
        // block-table layout must keep more of the mix resident than
        // worst-case contiguous reservation (which is structurally capped
        // at 2 here)
        assert!(
            paged_peak > contig_peak,
            "paged layout admitted {paged_peak} sessions vs contiguous {contig_peak} \
             on the same page budget"
        );
    }

    // -- workload 6: traced cell (observability artifacts) -----------------
    // one tracing-enabled decode run so every bench run also leaves a
    // Perfetto-loadable span timeline and a Prometheus metrics snapshot
    // next to BENCH_serve.json. The cell's tok/s is recorded (not gated):
    // tracing costs one relaxed atomic load when off, and when on the
    // bounded per-thread rings drop-oldest rather than grow.
    {
        use llm_datatypes::obs::{export, trace};
        let mut engine = Engine::new(
            cfg,
            ckpt.clone(),
            EngineConfig {
                slots: 4,
                page_size,
                scheduler: SchedulerConfig { max_batch: 4, ..SchedulerConfig::default() },
                ..EngineConfig::default()
            },
        );
        trace::reset();
        trace::set_enabled(true);
        let report =
            run_decode_loadgen(&mut engine, &prompts, 4, 1, if smoke { 8 } else { 16 })?;
        trace::set_enabled(false);
        let snap = trace::snapshot_and_drain();
        std::fs::write("BENCH_serve.trace.json", export::chrome_trace_json(&snap))?;
        std::fs::write(
            "BENCH_serve.metrics.prom",
            export::prometheus_text(&engine.metrics_registry()),
        )?;
        println!(
            "bench serve_decode_traced_b4             tok/s={:8.1} events={} dropped={}",
            report.decode_tps,
            snap.records.len(),
            snap.dropped,
        );
        json.record("serve_decode_traced_b4", "tok_s", report.decode_tps);
        json.record("serve_decode_traced_b4", "trace_events", snap.records.len() as f64);
    }

    json.write("BENCH_serve.json")?;
    Ok(())
}
