//! Perf: serve loop — dynamic batching win vs batch=1 (§Perf target >= 2x
//! throughput at 16+ concurrent clients).
use std::time::{Duration, Instant};

use llm_datatypes::coordinator::model::{GraphKind, LmHandle};
use llm_datatypes::coordinator::pipeline::{quantize_lm, PipelineConfig};
use llm_datatypes::coordinator::serve::{run_loadgen, ServeConfig, Server};
use llm_datatypes::coordinator::{corpus_for, Session};
use llm_datatypes::exp::ensure_model;
use llm_datatypes::model_io::zoo;
use llm_datatypes::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    ensure_model(&session, "nano")?;
    let cfg = zoo("nano")?;
    let ckpt = session.load_checkpoint("nano")?;
    let corpus = corpus_for(&cfg);
    let qm = quantize_lm(&cfg, &ckpt, &PipelineConfig::weight_only("sf4"), &corpus)?;
    let mut rng = Pcg64::new(7);
    let prompts: Vec<Vec<i32>> = (0..64)
        .map(|_| {
            let start = rng.below(corpus.heldout.len() - cfg.seq);
            corpus.heldout[start..start + cfg.seq / 2].to_vec()
        })
        .collect();

    let mut results = Vec::new();
    for (label, clients, wait) in [
        ("serve_batch1", 1usize, Duration::from_micros(1)),
        ("serve_batched_16c", 16usize, Duration::from_millis(2)),
    ] {
        let handle = LmHandle::bind(&session.engine, &cfg, GraphKind::WeightOnly, &qm.values)?;
        let server = Server::new(handle, ServeConfig { max_wait: wait, max_requests: 0 });
        let total = 192;
        let t0 = Instant::now();
        let stats = run_loadgen(server, prompts.clone(), clients, total / clients)?;
        let rps = stats.served as f64 / t0.elapsed().as_secs_f64();
        println!(
            "bench {label:40} req/s={rps:8.1} fill={:.2} p50={:?} p99={:?}",
            stats.mean_batch_fill, stats.p50_latency, stats.p99_latency
        );
        results.push((label, rps));
    }
    let speedup = results[1].1 / results[0].1;
    println!("bench serve_batching_speedup                  x{speedup:.2}");
    Ok(())
}
