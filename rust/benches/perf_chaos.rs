//! Perf: goodput under injected faults. Open-loop traffic over the wire
//! path (same shape as `perf_http`) while a seeded fault schedule poisons
//! forward steps, refuses KV reservations, spikes the page pool, and — in
//! the heavy cell — panics the engine thread itself once so the supervised
//! restart is on the measured path.
//!
//! Four cells: `chaos_clean` (disarmed), `chaos_light` (2% row fault
//! rate), `chaos_heavy` (10% + one engine-thread panic), and
//! `chaos_spill_heavy` — the same heavy schedule against a page-starved
//! pool with the host spill tier and session resurrection on, where the
//! engine panic costs resume gaps instead of failed answers. The
//! invariants hold in every cell — the server never aborts, drains with
//! zero leaked KV pages, and every client gets a terminal answer (a
//! completed NDJSON stream, a mid-stream `"reason":"failed"` done line, or
//! a 503 from the restart path). Goodput is expected to degrade with the
//! fault rate, not collapse: that trajectory is the artifact, recorded in
//! `BENCH_chaos.json`. `--smoke` shrinks the arrival count and asserts the
//! contract (clean cell fails nothing; heavy cell fails something and
//! restarts the engine exactly once).

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use llm_datatypes::bench_util::BenchJson;
use llm_datatypes::coordinator::{corpus_for, trainer};
use llm_datatypes::faults::{self, FaultPlan, Site};
use llm_datatypes::model_io::zoo;
use llm_datatypes::rng::Pcg64;
use llm_datatypes::serving::http::{serve, ChunkStream, HttpConfig, ServerExit};
use llm_datatypes::serving::{Engine, EngineConfig, SchedulerConfig};

/// What one client saw, terminally.
struct Outcome {
    /// The request got *some* terminal answer: a done line, or a 503 body.
    terminal: bool,
    /// Stream finished with a non-failed reason.
    completed: bool,
    /// Failed visibly: 503 from the restart path or a `"failed"` done line.
    failed: bool,
    tokens: usize,
}

fn run_client(addr: SocketAddr, body: &str) -> Outcome {
    let mut out = Outcome { terminal: false, completed: false, failed: false, tokens: 0 };
    let mut stream = match ChunkStream::open(addr, "POST", "/generate", Some(body)) {
        Ok(s) => s,
        Err(_) => return out,
    };
    if stream.status != 200 {
        // the supervised-restart path answers never-streamed sessions 503
        let _ = stream.read_body();
        out.terminal = stream.status == 503;
        out.failed = true;
        return out;
    }
    loop {
        match stream.next_chunk() {
            Ok(Some(chunk)) => {
                if chunk.contains("\"done\":true") {
                    out.terminal = true;
                    out.failed = chunk.contains("\"reason\":\"failed\"");
                    out.completed = !out.failed;
                } else {
                    out.tokens += 1;
                }
            }
            Ok(None) => break,
            Err(_) => break,
        }
    }
    out
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let mut json = BenchJson::new();
    let cfg = zoo("nano")?;
    let corpus: Vec<i32> = corpus_for(&cfg).heldout;
    let n = if smoke { 16usize } else { 40 };
    let gap = Duration::from_millis(4);

    faults::silence_injected_panics();
    let mut clean_failed = usize::MAX;
    let mut heavy_failed = 0usize;
    // the fourth cell reruns the heavy schedule with the ISSUE 9 degradation
    // stack on: a page-starved pool backed by the host spill tier, and
    // resurrection replaying in-flight sessions across the engine restart —
    // the same faults should now cost latency (resume gaps), not answers
    for (cell, rate, heavy, degrade) in [
        ("chaos_clean", 0.0f64, false, false),
        ("chaos_light", 0.02, false, false),
        ("chaos_heavy", 0.10, true, false),
        ("chaos_spill_heavy", 0.10, true, true),
    ] {
        if rate > 0.0 {
            let mut plan = FaultPlan::new(0xfa57 ^ rate.to_bits())
                .rate(Site::ForwardPanic, rate)
                .limit(Site::ForwardPanic, 6)
                .rate(Site::KvReserveFail, rate)
                .limit(Site::KvReserveFail, 6)
                .one_shot(Site::KvPageSpike)
                .spike(4, 2);
            if heavy {
                plan = plan.one_shot(Site::EngineStepPanic);
            }
            faults::arm(plan);
        } else {
            faults::disarm();
        }

        let engine = Engine::new(
            cfg,
            trainer::init_lm_params(&cfg, 0x5eed),
            EngineConfig {
                slots: 4,
                page_size: 4,
                // degraded cell: 12 pages cannot hold four full contexts, so
                // page pressure spills victims to the host tier mid-run
                kv_pages: if degrade { 12 } else { 0 },
                host_tier_bytes: if degrade { 1 << 20 } else { 0 },
                scheduler: SchedulerConfig {
                    max_batch: 4,
                    resurrect: degrade,
                    ..SchedulerConfig::default()
                },
                ..EngineConfig::default()
            },
        );
        let server = serve(engine, HttpConfig::default())?;
        let addr = server.addr();

        let mut rng = Pcg64::new(0xc4a05 ^ rate.to_bits());
        let t0 = Instant::now();
        let mut handles = Vec::with_capacity(n);
        for _ in 0..n {
            std::thread::sleep(gap);
            let prompt_len = 4 + rng.below(5);
            let start = rng.below(corpus.len() - prompt_len);
            let toks: Vec<String> =
                corpus[start..start + prompt_len].iter().map(|t| t.to_string()).collect();
            let body =
                format!("{{\"prompt\":[{}],\"max_new_tokens\":6}}", toks.join(","));
            handles.push(std::thread::spawn(move || run_client(addr, &body)));
        }
        let outcomes: Vec<Outcome> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let elapsed = t0.elapsed();

        let injected = faults::injected_total();
        let ServerExit { report, engine, http } = server.shutdown();
        faults::disarm();
        let report = report.expect("the supervised engine always returns its report");

        let completed = outcomes.iter().filter(|o| o.completed).count();
        let failed = outcomes.iter().filter(|o| o.failed).count();
        let good_tokens: usize = outcomes.iter().filter(|o| o.completed).map(|o| o.tokens).sum();
        let goodput = good_tokens as f64 / elapsed.as_secs_f64();
        println!(
            "bench {cell:<16} goodput={goodput:8.1} tok/s ok={completed} failed={failed} \
             injected={injected} restarts={} steps={}",
            http.engine_restarts, report.steps,
        );
        json.record(cell, "goodput_tok_s", goodput);
        json.record(cell, "completed", completed as f64);
        json.record(cell, "failed_visible", failed as f64);
        json.record(cell, "faults_injected", injected as f64);
        json.record(cell, "engine_restarts", http.engine_restarts as f64);
        json.record(cell, "pages_spilled", report.pages_spilled as f64);
        json.record(cell, "restores", report.restores as f64);
        json.record(cell, "resurrections", report.resurrections as f64);

        // survival invariants — these hold at every fault rate
        assert_eq!(
            completed + failed,
            n,
            "{cell}: every client saw a terminal answer: {} lost",
            n - completed - failed
        );
        assert!(outcomes.iter().all(|o| o.terminal), "{cell}: a client saw no terminal event");
        assert!(goodput > 0.0, "{cell}: goodput collapsed to zero");
        assert_eq!(engine.cache().pages_in_use(), 0, "{cell}: drained server leaked KV pages");
        assert_eq!(engine.cache().slots_in_use(), 0, "{cell}: drained server leaked slots");
        assert!(report.failed >= failed, "{cell}: every visible failure retired server-side");

        match cell {
            "chaos_clean" => {
                assert_eq!(failed, 0, "{cell}: no faults armed, no failures");
                assert_eq!(injected, 0, "{cell}: disarmed cells inject nothing");
                clean_failed = failed;
            }
            "chaos_heavy" => {
                assert!(injected >= 1, "{cell}: the heavy schedule must actually fire");
                assert!(failed >= 1, "{cell}: a 10% fault rate must fail at least one request");
                assert_eq!(
                    http.engine_restarts, 1,
                    "{cell}: exactly one engine-thread panic + restart"
                );
                heavy_failed = failed;
            }
            "chaos_spill_heavy" => {
                assert!(injected >= 1, "{cell}: the heavy schedule must actually fire");
                assert_eq!(
                    http.engine_restarts, 1,
                    "{cell}: exactly one engine-thread panic + restart"
                );
                // the engine panic no longer fails its in-flight sessions —
                // resurrection replays them — so only row-level poison
                // (forward panics) stays visible; never more than the
                // undegraded heavy cell
                assert!(
                    failed <= heavy_failed,
                    "{cell}: degradation must not increase visible failures \
                     ({failed} > {heavy_failed})"
                );
                assert_eq!(
                    engine.host_tier().sessions(),
                    0,
                    "{cell}: drained server leaked host-tier entries"
                );
            }
            _ => {}
        }
    }
    assert!(clean_failed < heavy_failed, "failures grow with the fault rate");

    json.write("BENCH_chaos.json")?;
    Ok(())
}
