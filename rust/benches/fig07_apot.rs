//! Bench target: regenerate paper Figure 7 (APoT variants) at quick scale and time it.
//! Full-scale regeneration: `repro figure 7`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;

    let table = exp::convergence::run_fig7()?;
    println!("{}", table.render());
    bench("fig07_apot", 2, || exp::convergence::run_fig7().unwrap());
    Ok(())
}
