//! Perf: the HTTP serving front end under open-loop traffic. Where
//! `perf_serve` drives the engine in-process, this bench goes through the
//! whole wire path — TCP connect, request parse, admission, chunked token
//! streaming — the way a real client fleet would, and measures what a
//! client fleet cares about:
//!
//! * **TTFT / ITL percentiles, client-side**: each token chunk is
//!   timestamped as it arrives off the socket, so the numbers include
//!   connection handling, head-of-line waits in the admission queue, and
//!   chunk framing — not just engine step time.
//! * **Goodput under overload**: an open-loop arrival process does not
//!   slow down because the server is struggling (that is what makes it
//!   open-loop), so at 3x the calibrated capacity the server must shed
//!   load via 429 + Retry-After. Goodput counts only tokens delivered on
//!   completed streams; the gate is that shedding keeps it near the
//!   low-load level instead of collapsing.
//!
//! Two arrival processes over a long-tail prompt/length mix (mostly short
//! prompts with a heavy tail, the shape continuous batching exists for):
//!
//! * `poisson` — exponential inter-arrival gaps at a target rate;
//! * `bursty` — the same mean rate delivered in 4-request bursts, the
//!   arrival shape that stresses the admission queue hardest.
//!
//! Rates are calibrated per run: a closed-loop warm-up measures this
//! machine's capacity, then the open-loop cells run at 0.5x ("low") and
//! 3.0x ("overload") of it. Every cell lands in `BENCH_http.json`.
//! `--smoke` shrinks the request counts and asserts the contract: overload
//! sheds (>= 1 429), every 429 carries Retry-After, goodput stays > 0,
//! and both servers drain cleanly with zero leaked KV pages.

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use llm_datatypes::bench_util::BenchJson;
use llm_datatypes::coordinator::{corpus_for, trainer, Session};
use llm_datatypes::model_io::zoo;
use llm_datatypes::rng::Pcg64;
use llm_datatypes::serving::http::{
    fetch_with_retry, serve, ChunkStream, HttpConfig, RetryPolicy, ServerExit,
};
use llm_datatypes::serving::{percentile_sorted, Engine, EngineConfig, SchedulerConfig};

/// One request's shape in the workload mix.
#[derive(Clone, Copy)]
struct Job {
    prompt_len: usize,
    max_new: usize,
}

/// Long-tail mix: mostly short exchanges, a heavy tail of long ones.
fn sample_job(rng: &mut Pcg64, seq: usize) -> Job {
    let (prompt_len, max_new) = match rng.below(20) {
        0 => (seq / 2, seq / 4),      // 5%: long context, long generation
        1..=3 => (seq / 4, seq / 8),  // 15%: medium
        _ => (seq / 8, 4),            // 80%: short
    };
    Job { prompt_len: prompt_len.max(1), max_new: max_new.max(1) }
}

fn body_for(job: Job, corpus: &[i32], rng: &mut Pcg64) -> String {
    let start = rng.below(corpus.len() - job.prompt_len);
    let toks: Vec<String> =
        corpus[start..start + job.prompt_len].iter().map(|t| t.to_string()).collect();
    format!("{{\"prompt\":[{}],\"max_new_tokens\":{}}}", toks.join(","), job.max_new)
}

/// What one open-loop client observed for its single request.
struct Observation {
    status: u16,
    ttft: Option<Duration>,
    itl: Vec<Duration>,
    tokens: usize,
    completed: bool,
    had_retry_after: bool,
    /// Parsed Retry-After seconds, when the header was present.
    retry_after: Option<u64>,
}

/// Fire one request and watch the chunks arrive. Client-side clocks: TTFT
/// runs from just before `connect`, so admission-queue waits count.
fn run_client(addr: SocketAddr, body: &str) -> Observation {
    let t0 = Instant::now();
    let mut obs = Observation {
        status: 0,
        ttft: None,
        itl: Vec::new(),
        tokens: 0,
        completed: false,
        had_retry_after: false,
        retry_after: None,
    };
    let mut stream = match ChunkStream::open(addr, "POST", "/generate", Some(body)) {
        Ok(s) => s,
        Err(_) => return obs,
    };
    obs.status = stream.status;
    obs.retry_after = stream
        .headers
        .iter()
        .find(|(n, _)| n.eq_ignore_ascii_case("retry-after"))
        .and_then(|(_, v)| v.trim().parse().ok());
    obs.had_retry_after = obs.retry_after.is_some();
    if stream.status != 200 {
        let _ = stream.read_body();
        return obs;
    }
    let mut last = t0;
    loop {
        match stream.next_chunk() {
            Ok(Some(chunk)) => {
                let now = Instant::now();
                if chunk.contains("\"done\":true") {
                    obs.completed = true;
                } else {
                    match obs.ttft {
                        None => obs.ttft = Some(now - t0),
                        Some(_) => obs.itl.push(now - last),
                    }
                    obs.tokens += 1;
                    last = now;
                }
            }
            Ok(None) => break,
            Err(_) => break,
        }
    }
    obs
}

fn start_server(slots: usize, max_queue: usize) -> anyhow::Result<llm_datatypes::serving::HttpServer> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    let cfg = zoo("nano")?;
    let ckpt = match session.load_checkpoint("nano") {
        Ok(c) => c,
        Err(_) => trainer::init_lm_params(&cfg, 0x5eed),
    };
    let engine = Engine::new(
        cfg,
        ckpt,
        EngineConfig {
            slots,
            scheduler: SchedulerConfig {
                max_batch: slots,
                max_queue,
                reject_saturated: true,
                ..SchedulerConfig::default()
            },
            ..EngineConfig::default()
        },
    );
    Ok(serve(engine, HttpConfig::default())?)
}

struct CellResult {
    goodput_tok_s: f64,
    completed: usize,
    rejected: usize,
    failed: usize,
    ttft_p50: Duration,
    ttft_p99: Duration,
    itl_p50: Duration,
    itl_p99: Duration,
    retry_after_ok: bool,
    /// Distinct Retry-After hints handed out across the cell's 429s. The
    /// pressure-derived, staggered hint must spread a shed wave over
    /// several comeback slots instead of landing it in one burst.
    retry_after_distinct: usize,
}

/// Drive `n` open-loop arrivals against `addr`. `gap(i)` yields the wait
/// before arrival `i` — that is the whole difference between the Poisson
/// and bursty processes.
fn run_cell(
    addr: SocketAddr,
    n: usize,
    seq: usize,
    corpus: &[i32],
    seed: u64,
    mut gap: impl FnMut(&mut Pcg64, usize) -> Duration,
) -> CellResult {
    let mut rng = Pcg64::new(seed);
    let mut handles = Vec::with_capacity(n);
    let t0 = Instant::now();
    for i in 0..n {
        std::thread::sleep(gap(&mut rng, i));
        let body = body_for(sample_job(&mut rng, seq), corpus, &mut rng);
        handles.push(std::thread::spawn(move || run_client(addr, &body)));
    }
    let obs: Vec<Observation> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let elapsed = t0.elapsed();

    let mut ttft: Vec<Duration> = obs.iter().filter_map(|o| o.ttft).collect();
    let mut itl: Vec<Duration> = obs.iter().flat_map(|o| o.itl.iter().copied()).collect();
    ttft.sort();
    itl.sort();
    let completed = obs.iter().filter(|o| o.completed).count();
    let rejected = obs.iter().filter(|o| o.status == 429).count();
    let failed = obs.iter().filter(|o| !o.completed && o.status != 429).count();
    let good_tokens: usize = obs.iter().filter(|o| o.completed).map(|o| o.tokens).sum();
    CellResult {
        goodput_tok_s: good_tokens as f64 / elapsed.as_secs_f64(),
        completed,
        rejected,
        failed,
        ttft_p50: percentile_sorted(&ttft, 0.50),
        ttft_p99: percentile_sorted(&ttft, 0.99),
        itl_p50: percentile_sorted(&itl, 0.50),
        itl_p99: percentile_sorted(&itl, 0.99),
        retry_after_ok: obs.iter().filter(|o| o.status == 429).all(|o| o.had_retry_after),
        retry_after_distinct: {
            let mut hints: Vec<u64> =
                obs.iter().filter(|o| o.status == 429).filter_map(|o| o.retry_after).collect();
            hints.sort_unstable();
            hints.dedup();
            hints.len()
        },
    }
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let mut json = BenchJson::new();
    let cfg = zoo("nano")?;
    let corpus: Vec<i32> = corpus_for(&cfg).heldout;
    let n = if smoke { 24usize } else { 96 };

    // -- calibration: closed-loop capacity on this machine -----------------
    // sequential requests back to back measure per-request service time;
    // open-loop rates are set relative to the implied capacity so the
    // "low" and "overload" cells mean the same thing on any box
    let server = start_server(4, 8)?;
    let addr = server.addr();
    let mut rng = Pcg64::new(0xca11b);
    let warm = if smoke { 8 } else { 24 };
    let t0 = Instant::now();
    let mut calib_tokens = 0usize;
    for _ in 0..warm {
        let body = body_for(sample_job(&mut rng, cfg.seq), &corpus, &mut rng);
        let o = run_client(addr, &body);
        assert!(o.completed, "calibration requests run unloaded and must complete");
        calib_tokens += o.tokens;
    }
    let capacity_rps = warm as f64 / t0.elapsed().as_secs_f64();
    let exit = server.shutdown();
    exit.report.expect("calibration server drains cleanly");
    println!(
        "bench http_calibration            capacity={capacity_rps:8.1} req/s \
         ({calib_tokens} tokens closed-loop)"
    );
    json.record("http_calibration", "capacity_rps", capacity_rps);

    // -- open-loop cells: {poisson, bursty} x {low, overload} --------------
    for (process, burst) in [("poisson", 1usize), ("bursty", 4usize)] {
        for (load, factor, slots, queue) in
            [("low", 0.5f64, 4usize, 8usize), ("overload", 3.0, 2, 2)]
        {
            let rate = (capacity_rps * factor).max(1.0);
            let mean_gap = Duration::from_secs_f64(1.0 / rate);
            let server = start_server(slots, queue)?;
            let addr = server.addr();
            let cell = format!("http_{process}_{load}");
            let r = run_cell(addr, n, cfg.seq, &corpus, 0x5eed ^ rate as u64, |rng, i| {
                if burst == 1 {
                    // Poisson process: exponential inter-arrival gaps
                    let u = rng.uniform().max(1e-12);
                    mean_gap.mul_f64(-u.ln())
                } else if i % burst == 0 {
                    // bursty: same mean rate, delivered `burst` at a time
                    mean_gap.mul_f64(burst as f64)
                } else {
                    Duration::ZERO
                }
            });
            // shed clients come back through the bundled retry policy:
            // exponential backoff + jitter, honoring the server's
            // Retry-After hint. Once the open-loop wave subsides the
            // retried requests must land instead of 429ing forever.
            let mut retry_attempted = 0usize;
            let mut retry_recovered = 0usize;
            if load == "overload" {
                let policy = RetryPolicy::default();
                let mut retry_rng = Pcg64::new(0x7e721 ^ rate as u64);
                retry_attempted = r.rejected.min(4);
                for _ in 0..retry_attempted {
                    let body =
                        body_for(sample_job(&mut retry_rng, cfg.seq), &corpus, &mut retry_rng);
                    if let Ok(resp) =
                        fetch_with_retry(addr, "POST", "/generate", Some(&body), &policy)
                    {
                        if resp.status == 200 {
                            retry_recovered += 1;
                        }
                    }
                }
            }
            let ServerExit { report, engine, http } = server.shutdown();
            let report = report.expect("cell server drains cleanly");
            println!(
                "bench {cell:<24} goodput={:8.1} tok/s ttft_p50={:?} ttft_p99={:?} \
                 itl_p50={:?} itl_p99={:?} ok={} 429={} failed={}",
                r.goodput_tok_s,
                r.ttft_p50,
                r.ttft_p99,
                r.itl_p50,
                r.itl_p99,
                r.completed,
                r.rejected,
                r.failed,
            );
            json.record(&cell, "goodput_tok_s", r.goodput_tok_s);
            json.record(&cell, "ttft_p50_ms", r.ttft_p50.as_secs_f64() * 1e3);
            json.record(&cell, "ttft_p99_ms", r.ttft_p99.as_secs_f64() * 1e3);
            json.record(&cell, "itl_p50_ms", r.itl_p50.as_secs_f64() * 1e3);
            json.record(&cell, "itl_p99_ms", r.itl_p99.as_secs_f64() * 1e3);
            json.record(&cell, "completed", r.completed as f64);
            json.record(&cell, "rejected_429", r.rejected as f64);
            if load == "overload" {
                println!(
                    "bench {cell:<24} retry_recovered={retry_recovered}/{retry_attempted} \
                     (backoff + Retry-After)"
                );
                json.record(&cell, "retry_recovered", retry_recovered as f64);
            }

            // contract checks, cheap enough to hold in full runs too
            assert_eq!(
                r.failed, 0,
                "{cell}: admitted streams are never cut and errors never leak \
                 past the 429 path"
            );
            assert!(r.retry_after_ok, "{cell}: every 429 carries Retry-After");
            if r.rejected >= 4 {
                // the hint is derived per answer (queue depth + page
                // pressure + a mod-3 stagger), so a shed wave must see
                // more than one comeback slot — a constant hint would
                // re-land the whole wave at once
                assert!(
                    r.retry_after_distinct > 1,
                    "{cell}: {} 429s all got the same Retry-After hint",
                    r.rejected
                );
            }
            json.record(&cell, "retry_after_distinct", r.retry_after_distinct as f64);
            assert_eq!(
                engine.cache().pages_in_use(),
                0,
                "{cell}: drained server leaks no KV pages"
            );
            assert_eq!(
                http.streams_completed as usize,
                r.completed + retry_recovered,
                "{cell}: server-side and client-side completion counts agree"
            );
            assert_eq!(
                report.completed,
                r.completed + retry_recovered,
                "{cell}: engine agrees too"
            );
            if smoke {
                assert!(r.goodput_tok_s > 0.0, "{cell}: goodput collapsed to zero");
                if load == "overload" {
                    // the backpressure acceptance gate: an open-loop overload
                    // must be shed with 429s, not absorbed into an unbounded
                    // queue (r.failed would grow and TTFT would run away)
                    assert!(
                        r.rejected >= 1,
                        "{cell}: 3x-capacity arrivals produced no 429s"
                    );
                    assert_eq!(
                        retry_recovered, retry_attempted,
                        "{cell}: backed-off retries must land once the wave subsides"
                    );
                }
            }
        }
    }

    json.write("BENCH_http.json")?;
    Ok(())
}
