//! Perf: quantization engine hot path — RTN / MSE / GPTQ throughput.
//! §Perf targets: RTN >= 100 MB/s of f32 weights (EXPERIMENTS.md).
use llm_datatypes::bench_util::{bench, report_throughput};
use llm_datatypes::formats;
use llm_datatypes::quant::{gptq_quantize, quantize_weight, BlockSize, Calib, GptqConfig, QuantConfig};
use llm_datatypes::rng::Pcg64;
use llm_datatypes::tensor::Tensor;

fn main() {
    let mut rng = Pcg64::new(1);
    let (k, n) = (1024usize, 1024usize);
    let w = Tensor::new(&[k, n], rng.student_t_vec(k * n, 5.0, 0.02));
    let bytes = k * n * 4;

    for fmt in ["sf4", "int4", "e2m1"] {
        let spec = formats::must(fmt);
        let cfg = QuantConfig { format: spec.clone(), block: BlockSize::Sub(128), calib: Calib::None };
        let s = bench(&format!("rtn_{fmt}_1Mx4B"), 24, || quantize_weight(&w, &cfg));
        report_throughput(&s, bytes);
    }

    // the RTN inner call in isolation: slice-level nearest-code search
    // (`Encoder::encode_block`) over 1M pre-normalized values — the loop
    // the per-column hot path of `quantize_weight` amortizes its bounds
    // checks into
    let enc = formats::must("sf4").encoder();
    let vals: Vec<f32> = w.data().iter().map(|&v| v * 40.0).collect(); // ~[-1, 1]
    let mut codes = vec![0i8; vals.len()];
    let s = bench("encode_block_sf4_1M", 48, || enc.encode_block(&vals, &mut codes));
    report_throughput(&s, bytes);
    let spec = formats::must("sf4");
    let cfg = QuantConfig { format: spec.clone(), block: BlockSize::Sub(128), calib: Calib::Mse };
    let s = bench("mse_sf4_1Mx4B", 6, || quantize_weight(&w, &cfg));
    report_throughput(&s, bytes);

    // GPTQ on a layer-sized problem
    let (k2, n2) = (256usize, 256usize);
    let w2 = Tensor::new(&[k2, n2], rng.student_t_vec(k2 * n2, 5.0, 0.02));
    let x2 = Tensor::new(&[512, k2], rng.normal_vec(512 * k2, 1.0));
    let qc = QuantConfig { format: spec, block: BlockSize::Sub(128), calib: Calib::None };
    let s = bench("gptq_256x256_cal512", 4, || gptq_quantize(&w2, &x2, &qc, &GptqConfig::default()));
    report_throughput(&s, k2 * n2 * 4);
}
