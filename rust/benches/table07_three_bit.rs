//! Bench target: regenerate paper Table 7 (3-bit formats) at quick scale and time it.
//! Full-scale regeneration: `repro table 7`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    exp::ensure_model(&session, "nano")?;
    let table = exp::three_bit::run(&session, Scale::Quick, "nano")?;
    println!("{}", table.render());
    bench("table07_three_bit", 2, || exp::three_bit::run(&session, Scale::Quick, "nano").unwrap());
    Ok(())
}
