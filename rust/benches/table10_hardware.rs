//! Bench target: regenerate paper Table 10 (MAC area/power) at quick scale and time it.
//! Full-scale regeneration: `repro table 10`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;

    let table = exp::hardware::run()?;
    println!("{}", table.render());
    bench("table10_hardware", 2, || exp::hardware::run().unwrap());
    Ok(())
}
