//! Bench target: regenerate paper Table 6 (RTN vs GPTQ) at quick scale and time it.
//! Full-scale regeneration: `repro table 6`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    exp::ensure_model(&session, "nano")?;
    let table = exp::gptq_cmp::run(&session, Scale::Quick, "nano")?;
    println!("{}", table.render());
    bench("table06_gptq", 2, || exp::gptq_cmp::run(&session, Scale::Quick, "nano").unwrap());
    Ok(())
}
