//! Bench target: regenerate paper Table 4 (zero-shot suite) at quick scale and time it.
//! Full-scale regeneration: `repro table 4`.
#![allow(unused_imports)]
use llm_datatypes::bench_util::bench;
use llm_datatypes::coordinator::Session;
use llm_datatypes::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let session = Session::open("artifacts", "checkpoints", "results")?;
    exp::ensure_model(&session, "nano")?;
    let table = exp::zeroshot::run(&session, Scale::Quick, "nano")?;
    println!("{}", table.render());
    bench("table04_zeroshot", 2, || exp::zeroshot::run(&session, Scale::Quick, "nano").unwrap());
    Ok(())
}
