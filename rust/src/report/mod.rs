//! Table and figure rendering: aligned text tables (the paper's tables),
//! TSV emission for downstream plotting, and ASCII scatter plots (Paretos).

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

/// A simple column-aligned table with a title; renders to text and TSV.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for i in 0..ncol {
                if i > 0 {
                    s.push_str("  ");
                }
                let _ = write!(s, "{:width$}", cells[i], width = widths[i]);
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Write TSV next to the rendered table under `results/`.
    pub fn save_tsv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut s = String::new();
        let _ = writeln!(s, "# {}", self.title);
        let _ = writeln!(s, "{}", self.headers.join("\t"));
        for row in &self.rows {
            let _ = writeln!(s, "{}", row.join("\t"));
        }
        fs::write(path, s)
    }
}

/// Format a float with fixed decimals, or "-" for NaN.
pub fn fnum(v: f64, decimals: usize) -> String {
    if v.is_nan() {
        "-".to_string()
    } else {
        format!("{v:.decimals$}")
    }
}

/// Signed percentage with two decimals (the paper's Delta% cells).
pub fn pct(v: f64) -> String {
    if v.is_nan() {
        "-".to_string()
    } else {
        format!("{v:+.2}")
    }
}

/// ASCII scatter plot (for the Pareto figures): points labelled by marker
/// characters, rendered into a `width x height` grid with axes.
pub struct AsciiScatter {
    pub title: String,
    pub xlabel: String,
    pub ylabel: String,
    pub points: Vec<(f64, f64, char, String)>,
}

impl AsciiScatter {
    pub fn new(title: &str, xlabel: &str, ylabel: &str) -> Self {
        AsciiScatter {
            title: title.into(),
            xlabel: xlabel.into(),
            ylabel: ylabel.into(),
            points: Vec::new(),
        }
    }

    pub fn point(&mut self, x: f64, y: f64, marker: char, label: &str) {
        self.points.push((x, y, marker, label.to_string()));
    }

    pub fn render(&self, width: usize, height: usize) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        if self.points.is_empty() {
            return out;
        }
        let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y, _, _) in &self.points {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
        let xpad = (xmax - xmin).max(1e-9) * 0.05;
        let ypad = (ymax - ymin).max(1e-9) * 0.05;
        xmin -= xpad;
        xmax += xpad;
        ymin -= ypad;
        ymax += ypad;
        let mut grid = vec![vec![' '; width]; height];
        for &(x, y, m, _) in &self.points {
            let cx = ((x - xmin) / (xmax - xmin) * (width - 1) as f64) as usize;
            let cy = ((y - ymin) / (ymax - ymin) * (height - 1) as f64) as usize;
            grid[height - 1 - cy][cx] = m;
        }
        for (r, rowv) in grid.iter().enumerate() {
            let yv = ymax - (ymax - ymin) * r as f64 / (height - 1) as f64;
            let _ = writeln!(out, "{yv:>9.2} |{}", rowv.iter().collect::<String>());
        }
        let _ = writeln!(out, "{:>9} +{}", "", "-".repeat(width));
        let _ = writeln!(
            out,
            "{:>10} {:<.2}{}{:>.2}   ({} vs {})",
            "",
            xmin,
            " ".repeat(width.saturating_sub(12)),
            xmax,
            self.ylabel,
            self.xlabel
        );
        let _ = writeln!(out, "legend:");
        for (_, _, m, label) in &self.points {
            let _ = writeln!(out, "  {m} = {label}");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1.00".into()]);
        t.row(vec!["b".into(), "-12.50".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("alpha"));
        let lines: Vec<&str> = s.lines().collect();
        // header + rule + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn tsv_roundtrip(){
        let dir = std::env::temp_dir().join("llmdt_report_test");
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let p = dir.join("t.tsv");
        t.save_tsv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("a\tb"));
        assert!(s.contains("1\t2"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn scatter_renders() {
        let mut p = AsciiScatter::new("P", "area", "acc");
        p.point(1.0, -1.0, 'I', "int4");
        p.point(2.0, -0.5, 'E', "e2m1");
        let s = p.render(40, 10);
        assert!(s.contains('I') && s.contains('E'));
        assert!(s.contains("legend"));
    }

    #[test]
    fn fnum_handles_nan() {
        assert_eq!(fnum(f64::NAN, 2), "-");
        assert_eq!(fnum(1.2345, 2), "1.23");
        assert_eq!(pct(-3.21001), "-3.21");
    }
}
