//! Additive Powers-of-Two formats (Li et al., ICLR 2020) and the variant
//! search space of paper Appendix E / Figure 7.

/// The paper's `2S (3)` sets: S1 = {0, 2^-1, 2^-2, 2^-4}, S2 = {0, 2^-3}.
pub const APOT4_S1: [f64; 4] = [0.0, 0.5, 0.25, 0.0625];
pub const APOT4_S2: [f64; 2] = [0.0, 0.125];

/// Build an APoT codebook from value sets: all sums taking one element per
/// set, mirrored to signed, normalized; positive-only supernormal extras.
pub fn apot_from_sets(sets: &[&[f64]], extra_pos: &[f64]) -> Vec<f64> {
    let mut sums = vec![0.0f64];
    for set in sets {
        let mut next = Vec::with_capacity(sums.len() * set.len());
        for &a in &sums {
            for &b in *set {
                next.push(a + b);
            }
        }
        sums = next;
    }
    sums.iter_mut().for_each(|v| *v = (*v * 1e12).round() / 1e12);
    sums.sort_by(|a, b| a.partial_cmp(b).unwrap());
    sums.dedup();
    let mx = *sums.last().unwrap();
    let mags: Vec<f64> = sums.iter().map(|v| v / mx).collect();
    let mut all: Vec<f64> = mags.iter().filter(|&&v| v != 0.0).map(|v| -v).collect();
    all.extend(mags.iter());
    all.extend(extra_pos.iter());
    all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    all.dedup();
    let mx = all.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    all.iter().map(|&v| v / mx).collect()
}

/// APoT4 of the paper (2S(3) variant); `sp` adds the 0.5 supernormal value.
pub fn apot4(sp: bool) -> Vec<f64> {
    let sets: [&[f64]; 2] = [&APOT4_S1, &APOT4_S2];
    if sp {
        apot_from_sets(&sets, &[0.5])
    } else {
        apot_from_sets(&sets, &[])
    }
}

/// One enumerated APoT variant (Fig. 7).
#[derive(Clone, Debug)]
pub struct ApotVariant {
    pub label: String,
    pub sets: Vec<Vec<f64>>,
    pub codebook: Vec<f64>,
    /// Unique magnitudes produced (8 = full 4-bit utilization).
    pub n_magnitudes: usize,
}

/// Enumerate the reasonable 4-bit APoT variants: 2-set and 3-set choices
/// drawn from {0, 2^-1, 2^-2, 2^-3, 2^-4}, filtered to those that produce
/// eight unique magnitudes (full bitspace use) — Appendix E's search space.
pub fn enumerate_apot_variants() -> Vec<ApotVariant> {
    let pool = [0.5f64, 0.25, 0.125, 0.0625];
    let mut out = Vec::new();
    let mut seen: Vec<Vec<f64>> = Vec::new();

    // 2-set variants: S1 = {0} + 3 picks, S2 = {0} + 1 pick.
    for mask in 0u32..16 {
        if mask.count_ones() != 3 {
            continue;
        }
        let s1: Vec<f64> = std::iter::once(0.0)
            .chain((0..4).filter(|i| mask >> i & 1 == 1).map(|i| pool[i]))
            .collect();
        for (j, &b) in pool.iter().enumerate() {
            if mask >> j & 1 == 1 {
                continue;
            }
            let s2 = vec![0.0, b];
            let sets: Vec<&[f64]> = vec![&s1, &s2];
            let cb = apot_from_sets(&sets, &[]);
            // 8 unique magnitudes incl. zero = full 3-bit magnitude space
            let mags = cb.iter().filter(|&&v| v > 0.0).count() + 1;
            if mags != 8 {
                continue;
            }
            let key: Vec<f64> = cb.clone();
            if seen.contains(&key) {
                continue;
            }
            seen.push(key);
            out.push(ApotVariant {
                label: format!("2S s1={s1:?} s2={s2:?}"),
                sets: vec![s1.clone(), s2.clone()],
                codebook: cb,
                n_magnitudes: mags,
            });
        }
    }

    // 3-set variants: three {0, x} pairs with distinct x.
    for a in 0..4 {
        for b in a + 1..4 {
            for c in b + 1..4 {
                let s1 = vec![0.0, pool[a]];
                let s2 = vec![0.0, pool[b]];
                let s3 = vec![0.0, pool[c]];
                let sets: Vec<&[f64]> = vec![&s1, &s2, &s3];
                let cb = apot_from_sets(&sets, &[]);
                let mags = cb.iter().filter(|&&v| v > 0.0).count() + 1;
                if mags != 8 {
                    continue;
                }
                if seen.contains(&cb) {
                    continue;
                }
                seen.push(cb.clone());
                out.push(ApotVariant {
                    label: format!("3S {:?}/{:?}/{:?}", s1, s2, s3),
                    sets: vec![s1, s2, s3],
                    codebook: cb,
                    n_magnitudes: mags,
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_variant_magnitudes() {
        let cb = apot4(false);
        let pos: Vec<f64> = cb.iter().copied().filter(|&v| v > 0.0).collect();
        let want = [0.1, 0.2, 0.3, 0.4, 0.6, 0.8, 1.0];
        assert_eq!(pos.len(), want.len());
        for (a, b) in pos.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn sp_adds_half() {
        let base = apot4(false);
        let sp = apot4(true);
        assert_eq!(sp.len(), base.len() + 1);
        assert!(sp.iter().any(|&v| (v - 0.5).abs() < 1e-12));
        assert!(!base.iter().any(|&v| (v - 0.5).abs() < 1e-12));
    }

    #[test]
    fn enumeration_contains_paper_variant() {
        let variants = enumerate_apot_variants();
        assert!(!variants.is_empty());
        let paper = apot4(false);
        assert!(
            variants.iter().any(|v| {
                v.codebook.len() == paper.len()
                    && v.codebook.iter().zip(&paper).all(|(a, b)| (a - b).abs() < 1e-9)
            }),
            "paper 2S(3) variant missing from enumeration"
        );
    }

    #[test]
    fn all_variants_fully_use_bitspace() {
        for v in enumerate_apot_variants() {
            assert_eq!(v.n_magnitudes, 8, "{}", v.label);
            // signed codebook: 8 pos + 7 neg + zero = 15 (sign-bit format)
            assert_eq!(v.codebook.len(), 15, "{}", v.label);
        }
    }
}
