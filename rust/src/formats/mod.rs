//! The datatype zoo — every numeric format in the paper's evaluation,
//! re-derived natively (Table 15 is the golden reference; the Python
//! `formats.py` emission is cross-checked in `rust/tests/`).
//!
//! Each format is a *codebook*: the sorted set of representable values
//! normalized so max |v| = 1. Nearest-value rounding against the codebook is
//! exactly how both the Rust quantizer and the in-graph Pallas kernels
//! consume a format — the datatype is runtime data end-to-end.

mod apot;

pub use apot::{apot_from_sets, enumerate_apot_variants, ApotVariant};

use crate::special::{normal, student_t};

/// Format family, used by the hardware model to pick a MAC structure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Quantile-derived lookup format (NF/SF): needs LUT decode + fp MAC.
    Lookup,
    /// Plain integers: cheapest MAC.
    Int,
    /// Minifloat with (exp, man) split.
    Float,
    /// Additive powers-of-two: shift-add MAC.
    Apot,
}

/// A named quantization datatype.
#[derive(Clone, Debug)]
pub struct FormatSpec {
    pub name: &'static str,
    /// Sorted, max-|v|-normalized representable values.
    pub codebook: Vec<f64>,
    pub bits: u32,
    pub family: Family,
    /// (exponent bits, mantissa bits) for minifloats.
    pub fp_split: Option<(u32, u32)>,
    /// Number of supernormal values (codes recovered from negative zero).
    pub supernormal: u32,
}

impl FormatSpec {
    /// Midpoints between consecutive codebook entries (for RTN rounding).
    pub fn midpoints(&self) -> Vec<f64> {
        self.codebook.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect()
    }

    /// Nearest codebook *index* for a normalized value.
    ///
    /// Convenience path; hot loops should use [`FormatSpec::encoder`], which
    /// hoists the midpoint table out of the per-element call (§Perf: this
    /// allocation dominated the RTN profile).
    pub fn encode(&self, x: f64) -> usize {
        let mids = self.midpoints();
        match mids.binary_search_by(|m| m.partial_cmp(&x).unwrap()) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// Allocation-free nearest-value encoder for hot loops.
    pub fn encoder(&self) -> Encoder {
        Encoder {
            mids: self.midpoints().iter().map(|&m| m as f32).collect(),
            values: self.codebook.iter().map(|&v| v as f32).collect(),
        }
    }

    /// Nearest codebook value for a normalized value.
    pub fn quantize(&self, x: f64) -> f64 {
        self.codebook[self.encode(x)]
    }

    /// Codebook padded to 16 entries (repeat top value) as f32 — the fixed
    /// shape the AOT artifacts take. Padding never changes nearest-value
    /// results because duplicates tie-break to the same value.
    pub fn padded16(&self) -> Vec<f32> {
        assert!(self.codebook.len() <= 16, "{}: codebook > 16", self.name);
        let mut cb: Vec<f32> = self.codebook.iter().map(|&v| v as f32).collect();
        let top = *cb.last().unwrap();
        cb.resize(16, top);
        cb
    }

    pub fn n_values(&self) -> usize {
        self.codebook.len()
    }

    /// Max magnitude before normalization — recovers the raw value grids of
    /// Table 15 for the accumulator-sizing model (`hw`).
    pub fn raw_max(&self) -> f64 {
        match self.name {
            "e2m1" | "e2m1_i" | "e2m1_sp" => 6.0,
            "e2m1_sr" => 8.0,
            "e2m1_b" => 12.0,
            "e3m0" => 16.0,
            "int4" => 8.0,
            "int5" => 16.0,
            "int3" => 4.0,
            "e2m0" => 4.0,
            // APoT sums of {0,2^-1,2^-2,2^-4} + {0,2^-3}: dyadic k/16 grid
            "apot4" | "apot4_sp" => 0.625,
            _ => 1.0,
        }
    }

    /// Smallest *normal* magnitude on the raw grid (minifloats only):
    /// products of two subnormals fall below this and are flushed by the
    /// cheap-MAC datapath the paper synthesizes.
    pub fn min_normal(&self) -> f64 {
        match self.name {
            "e2m1" | "e2m1_i" | "e2m1_sp" | "e2m1_sr" | "e2m0" => 1.0,
            "e2m1_b" => 2.0,
            "e3m0" => 0.25, // E3M0 has no nonzero subnormals
            _ => 0.0,
        }
    }
}

/// Precomputed nearest-value encoder (see [`FormatSpec::encoder`]).
#[derive(Clone, Debug)]
pub struct Encoder {
    mids: Vec<f32>,
    values: Vec<f32>,
}

impl Encoder {
    /// Nearest codebook index for a normalized value. Linear scan over the
    /// <=15 midpoints vectorizes better than binary search at these sizes.
    #[inline]
    pub fn encode(&self, x: f32) -> usize {
        let mut i = 0usize;
        for &m in &self.mids {
            i += (x > m) as usize;
        }
        i
    }

    /// Nearest codebook value (dequantized, normalized).
    #[inline]
    pub fn quantize(&self, x: f32) -> f32 {
        self.values[self.encode(x)]
    }

    /// Encode a whole slice of normalized values into codebook indices —
    /// the quantizer's per-column hot loop (`quant::quantize_weight`).
    /// Equivalent to [`Encoder::encode`] per element, but the bounds checks
    /// and the midpoint-table load are amortized across the block, so the
    /// midpoint comparison loop vectorizes over the slice (`perf_quant`
    /// tracks the win).
    pub fn encode_block(&self, xs: &[f32], out: &mut [i8]) {
        assert_eq!(xs.len(), out.len(), "encode_block: {} values for {} codes", xs.len(), out.len());
        let mids = &self.mids[..];
        for (o, &x) in out.iter_mut().zip(xs) {
            let mut i = 0usize;
            for &m in mids {
                i += (x > m) as usize;
            }
            *o = i as i8;
        }
    }

    #[inline]
    pub fn value(&self, idx: usize) -> f32 {
        self.values[idx]
    }
}

// ---------------------------------------------------------------------------
// Algorithm 1 (paper): quantile-derived lookup formats
// ---------------------------------------------------------------------------

/// Paper Algorithm 1 generalized to `n_values` levels over any quantile fn.
///
/// `ceil(n/2)` negative-side probabilities in [delta, 1/2] and the rest
/// (one more) in [1/2, 1-delta], sharing an exact zero at p = 1/2; offset
/// delta = (1/(2n) + 1/(2(n-1)))/2 as in QLoRA.
pub fn algorithm1(quantile: impl Fn(f64) -> f64, n_values: usize) -> Vec<f64> {
    assert!(n_values >= 4);
    let n = n_values as f64;
    let delta = 0.5 * (1.0 / (2.0 * n) + 1.0 / (2.0 * (n - 1.0)));
    let n_neg = n_values / 2;
    let n_pos = n_values - n_neg + 1;
    let mut q = Vec::with_capacity(n_values);
    for i in 0..n_neg {
        let p = delta + (0.5 - delta) * i as f64 / (n_neg - 1) as f64;
        q.push(quantile(p));
    }
    q[n_neg - 1] = 0.0; // p = 1/2 -> exactly zero
    for i in 1..n_pos {
        let p = 0.5 + (0.5 - delta) * i as f64 / (n_pos - 1) as f64;
        q.push(quantile(p));
    }
    let mx = q.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    q.iter().map(|&v| v / mx).collect()
}

/// NF-k: Algorithm 1 over the standard-normal quantile (QLoRA's NF4).
pub fn normal_float(bits: u32) -> Vec<f64> {
    algorithm1(normal::ppf, 1usize << bits)
}

/// SF-k(nu): Algorithm 1 over the Student-t quantile — the paper's format.
pub fn student_float(nu: f64, bits: u32) -> Vec<f64> {
    algorithm1(|p| student_t::ppf(p, nu), 1usize << bits)
}

// ---------------------------------------------------------------------------
// Hardened formats
// ---------------------------------------------------------------------------

fn int_format(bits: u32) -> Vec<f64> {
    let lo = -(1i64 << (bits - 1));
    let hi = (1i64 << (bits - 1)) - 1;
    let mx = lo.unsigned_abs() as f64;
    (lo..=hi).map(|v| v as f64 / mx).collect()
}

fn minifloat_magnitudes(exp_bits: u32, man_bits: u32, bias: i32, subnormals: bool) -> Vec<f64> {
    let mut mags = vec![0.0f64];
    let n_man = 1u32 << man_bits;
    for e in 0..(1u32 << exp_bits) {
        for m in 0..n_man {
            let val = if e == 0 {
                if !subnormals {
                    continue;
                }
                (m as f64 / n_man as f64) * 2f64.powi(1 - bias)
            } else {
                (1.0 + m as f64 / n_man as f64) * 2f64.powi(e as i32 - bias)
            };
            if val != 0.0 {
                mags.push(val);
            }
        }
    }
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    mags.dedup();
    mags
}

/// Mirror magnitudes to signed; supernormal `extra_pos` are positive-only
/// (they reassign the negative-zero code — paper Section 3.5).
fn signed(mags: &[f64], extra_pos: &[f64]) -> Vec<f64> {
    let mut pos: Vec<f64> = mags.iter().chain(extra_pos).copied().collect();
    pos.sort_by(|a, b| a.partial_cmp(b).unwrap());
    pos.dedup();
    let mut all: Vec<f64> = mags.iter().filter(|&&v| v != 0.0).map(|v| -v).collect();
    all.extend(pos);
    all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mx = all.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    all.iter().map(|&v| v / mx).collect()
}

fn e2m1(variant: &str) -> Vec<f64> {
    let base = minifloat_magnitudes(2, 1, 1, true); // 0,.5,1,1.5,2,3,4,6
    let normals: Vec<f64> = base.iter().copied().filter(|&v| v >= 1.0).collect();
    match variant {
        "base" => signed(&base, &[]),
        "sr" => signed(&base, &[8.0]),
        "sp" => signed(&base, &[5.0]),
        "ns" => signed(&minifloat_magnitudes(2, 1, 1, false), &[]),
        "i" => {
            let mut m = vec![0.0, 0.0625];
            m.extend(&normals);
            signed(&m, &[])
        }
        "b" => {
            let mut m = vec![0.0, 0.0625];
            m.extend(normals.iter().map(|v| 2.0 * v));
            signed(&m, &[])
        }
        _ => panic!("unknown e2m1 variant {variant}"),
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// The 11 datatypes of the paper's main evaluation (Tables 3-8, Fig. 3),
/// in the paper's row order.
pub const MAIN_FORMATS: [&str; 11] = [
    "nf4", "sf4", "int4", "e2m1_i", "e2m1_b", "e2m1", "e2m1_sr", "e2m1_sp",
    "e3m0", "apot4", "apot4_sp",
];

/// Every format by name. Unknown names return None.
pub fn get(name: &str) -> Option<FormatSpec> {
    let spec = |name: &'static str, cb: Vec<f64>, bits, family, fp, sn| FormatSpec {
        name,
        codebook: cb,
        bits,
        family,
        fp_split: fp,
        supernormal: sn,
    };
    Some(match name {
        "nf4" => spec("nf4", normal_float(4), 4, Family::Lookup, None, 0),
        "nf3" => spec("nf3", normal_float(3), 3, Family::Lookup, None, 0),
        "sf4" => spec("sf4", student_float(5.0, 4), 4, Family::Lookup, None, 0),
        "sf3" => spec("sf3", student_float(5.0, 3), 3, Family::Lookup, None, 0),
        "sf4_v3" => spec("sf4_v3", student_float(3.0, 4), 4, Family::Lookup, None, 0),
        "sf4_v4" => spec("sf4_v4", student_float(4.0, 4), 4, Family::Lookup, None, 0),
        "sf4_v5" => spec("sf4_v5", student_float(5.0, 4), 4, Family::Lookup, None, 0),
        "sf4_v6" => spec("sf4_v6", student_float(6.0, 4), 4, Family::Lookup, None, 0),
        "sf4_v7" => spec("sf4_v7", student_float(7.0, 4), 4, Family::Lookup, None, 0),
        "sf4_v8" => spec("sf4_v8", student_float(8.0, 4), 4, Family::Lookup, None, 0),
        "sf4_v10" => spec("sf4_v10", student_float(10.0, 4), 4, Family::Lookup, None, 0),
        "sf4_v20" => spec("sf4_v20", student_float(20.0, 4), 4, Family::Lookup, None, 0),
        "int3" => spec("int3", int_format(3), 3, Family::Int, None, 0),
        "int4" => spec("int4", int_format(4), 4, Family::Int, None, 0),
        "int5" => spec("int5", int_format(5), 5, Family::Int, None, 0),
        "e2m1" => spec("e2m1", e2m1("base"), 4, Family::Float, Some((2, 1)), 0),
        "e2m1_i" => spec("e2m1_i", e2m1("i"), 4, Family::Float, Some((2, 1)), 0),
        "e2m1_b" => spec("e2m1_b", e2m1("b"), 4, Family::Float, Some((2, 1)), 0),
        "e2m1_ns" => spec("e2m1_ns", e2m1("ns"), 4, Family::Float, Some((2, 1)), 0),
        "e2m1_sr" => spec("e2m1_sr", e2m1("sr"), 4, Family::Float, Some((2, 1)), 1),
        "e2m1_sp" => spec("e2m1_sp", e2m1("sp"), 4, Family::Float, Some((2, 1)), 1),
        "e3m0" => spec("e3m0", signed(&minifloat_magnitudes(3, 0, 2, true), &[]), 4,
                       Family::Float, Some((3, 0)), 0),
        "e2m0" => spec("e2m0", signed(&minifloat_magnitudes(2, 0, 0, true), &[]), 3,
                       Family::Float, Some((2, 0)), 0),
        "apot4" => spec("apot4", apot::apot4(false), 4, Family::Apot, None, 0),
        "apot4_sp" => spec("apot4_sp", apot::apot4(true), 4, Family::Apot, None, 1),
        _ => {
            // parametric SF4: "sf4_v<nu>" with arbitrary integer nu
            if let Some(rest) = name.strip_prefix("sf4_v") {
                if let Ok(nu) = rest.parse::<u32>() {
                    let cb = student_float(nu as f64, 4);
                    return Some(FormatSpec {
                        name: "sf4_vN",
                        codebook: cb,
                        bits: 4,
                        family: Family::Lookup,
                        fp_split: None,
                        supernormal: 0,
                    });
                }
            }
            return None;
        }
    })
}

/// `get` that panics with a clear message (most call sites).
pub fn must(name: &str) -> FormatSpec {
    get(name).unwrap_or_else(|| panic!("unknown format: {name}"))
}

/// Names of all registered formats (stable order).
pub fn all_names() -> Vec<&'static str> {
    vec![
        "nf4", "nf3", "sf4", "sf3", "sf4_v3", "sf4_v4", "sf4_v5", "sf4_v6",
        "sf4_v7", "sf4_v8", "sf4_v10", "sf4_v20", "int3", "int4", "int5",
        "e2m1", "e2m1_i", "e2m1_b", "e2m1_ns", "e2m1_sr", "e2m1_sp", "e3m0",
        "e2m0", "apot4", "apot4_sp",
    ]
}

/// Names of every format whose codebook fits 4-bit nibble packing
/// (<= 16 values) — the set the packed weight/KV/activation codecs and the
/// SIMD differential harness (`rust/tests/simd_kernels.rs`) iterate over.
/// Today this is everything in [`all_names`] except `int5` (32 values).
pub fn packable_names() -> Vec<&'static str> {
    all_names().into_iter().filter(|n| must(n).n_values() <= 16).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: &[f64], b: &[f64], tol: f64) {
        assert_eq!(a.len(), b.len(), "{a:?} vs {b:?}");
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < tol, "{x} vs {y} in {a:?}");
        }
    }

    #[test]
    fn nf4_matches_table15() {
        let want = [
            -1.000, -0.696, -0.525, -0.395, -0.284, -0.185, -0.091, 0.000,
            0.080, 0.161, 0.246, 0.338, 0.441, 0.563, 0.723, 1.000,
        ];
        close(&must("nf4").codebook, &want, 2e-3);
    }

    #[test]
    fn sf4_spot_values_match_table15() {
        for (nu, lo, hi) in [(3u32, -0.576, 0.606), (4, -0.609, 0.638),
                             (5, -0.628, 0.657), (6, -0.640, 0.669)] {
            let cb = must(&format!("sf4_v{nu}")).codebook;
            assert!((cb[1] - lo).abs() < 1.5e-3, "nu={nu} {}", cb[1]);
            assert!((cb[14] - hi).abs() < 1.5e-3, "nu={nu} {}", cb[14]);
        }
    }

    #[test]
    fn e2m1_family_matches_table15() {
        let base: Vec<f64> =
            [-6.0, -4.0, -3.0, -2.0, -1.5, -1.0, -0.5, 0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]
                .iter().map(|v| v / 6.0).collect();
        close(&must("e2m1").codebook, &base, 1e-9);
        let sp: Vec<f64> =
            [-6.0, -4.0, -3.0, -2.0, -1.5, -1.0, -0.5, 0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 5.0, 6.0]
                .iter().map(|v| v / 6.0).collect();
        close(&must("e2m1_sp").codebook, &sp, 1e-9);
        let sr: Vec<f64> =
            [-6.0, -4.0, -3.0, -2.0, -1.5, -1.0, -0.5, 0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0, 8.0]
                .iter().map(|v| v / 8.0).collect();
        close(&must("e2m1_sr").codebook, &sr, 1e-9);
        assert_eq!(must("e3m0").n_values(), 15);
        assert_eq!(must("e2m1_i").n_values(), 15);
        assert_eq!(must("e2m1_b").n_values(), 15);
    }

    #[test]
    fn apot_matches_table15() {
        let want = [
            -1.0, -0.8, -0.6, -0.4, -0.3, -0.2, -0.1, 0.0, 0.1, 0.2, 0.3, 0.4,
            0.6, 0.8, 1.0,
        ];
        close(&must("apot4").codebook, &want, 1e-9);
        let want_sp = [
            -1.0, -0.8, -0.6, -0.4, -0.3, -0.2, -0.1, 0.0, 0.1, 0.2, 0.3, 0.4,
            0.5, 0.6, 0.8, 1.0,
        ];
        close(&must("apot4_sp").codebook, &want_sp, 1e-9);
    }

    #[test]
    fn invariants_for_all_formats() {
        for name in all_names() {
            let s = must(name);
            let cb = &s.codebook;
            assert!(cb.windows(2).all(|w| w[0] < w[1]), "{name} not sorted");
            assert!(cb.contains(&0.0), "{name} lacks exact zero");
            let mx = cb.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
            assert!((mx - 1.0).abs() < 1e-12, "{name} not normalized");
            assert!(cb.len() <= 1 << s.bits, "{name} too many values");
        }
    }

    #[test]
    fn encode_is_nearest() {
        let s = must("sf4");
        for i in 0..=2000 {
            let x = -1.5 + 3.0 * i as f64 / 2000.0;
            let got = s.quantize(x);
            let want = s
                .codebook
                .iter()
                .copied()
                .min_by(|a, b| ((a - x).abs()).partial_cmp(&(b - x).abs()).unwrap())
                .unwrap();
            assert!((got - want).abs() < 1e-12, "x={x}");
        }
    }

    #[test]
    fn padded16_preserves_quantization() {
        let s = must("nf3");
        let padded = s.padded16();
        assert_eq!(padded.len(), 16);
        for i in 0..200 {
            let x = -1.2 + 2.4 * i as f64 / 200.0;
            let q1 = s.quantize(x);
            let q2 = padded
                .iter()
                .copied()
                .min_by(|a, b| {
                    ((*a as f64 - x).abs()).partial_cmp(&(*b as f64 - x).abs()).unwrap()
                })
                .unwrap() as f64;
            assert!((q1 - q2).abs() < 1e-6);
        }
    }

    #[test]
    fn sf_converges_to_nf() {
        let nf = normal_float(4);
        let sf200 = student_float(200.0, 4);
        let sf3 = student_float(3.0, 4);
        let d_big: f64 =
            nf.iter().zip(&sf200).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        let d_small: f64 =
            nf.iter().zip(&sf3).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(d_big < 0.01, "{d_big}");
        assert!(d_big < d_small / 10.0);
    }

    #[test]
    fn supernormal_counts() {
        assert_eq!(must("e2m1").n_values(), 15);
        assert_eq!(must("e2m1_sr").n_values(), 16);
        assert_eq!(must("e2m1_sp").n_values(), 16);
        assert_eq!(must("apot4").n_values(), 15);
        assert_eq!(must("apot4_sp").n_values(), 16);
        assert_eq!(must("nf4").n_values(), 16);
        assert_eq!(must("sf4").n_values(), 16);
    }

    #[test]
    fn quantize_is_idempotent_everywhere() {
        // quantize(quantize(x)) == quantize(x) exactly — codebook values are
        // fixed points of nearest-value rounding, for both the f64 path and
        // the hot-loop Encoder, on every registered codebook
        use crate::rng::Pcg64;
        let mut rng = Pcg64::new(0xf0f0);
        for name in all_names() {
            let s = must(name);
            let enc = s.encoder();
            for _ in 0..400 {
                let x = rng.range(-1.5, 1.5);
                let q = s.quantize(x);
                assert_eq!(s.quantize(q), q, "{name}: f64 quantize not idempotent at {x}");
                let xf = x as f32;
                let qf = enc.quantize(xf);
                assert_eq!(enc.quantize(qf), qf, "{name}: encoder not idempotent at {xf}");
            }
        }
    }

    #[test]
    fn quantize_is_monotone_over_a_dense_grid() {
        for name in all_names() {
            let s = must(name);
            let enc = s.encoder();
            let mut prev = f64::NEG_INFINITY;
            let mut prev_f = f32::NEG_INFINITY;
            for i in 0..=4000 {
                let x = -1.25 + 2.5 * i as f64 / 4000.0;
                let q = s.quantize(x);
                assert!(q >= prev, "{name}: quantize not monotone at {x}: {q} < {prev}");
                prev = q;
                let qf = enc.quantize(x as f32);
                assert!(qf >= prev_f, "{name}: encoder not monotone at {x}: {qf} < {prev_f}");
                prev_f = qf;
            }
            // the grid covers the whole codebook: both endpoints were hit
            assert_eq!(prev, *s.codebook.last().unwrap(), "{name}: top code never reached");
        }
    }

    #[test]
    fn codebook_points_round_trip_through_their_own_index() {
        for name in all_names() {
            let s = must(name);
            let enc = s.encoder();
            for (i, &c) in s.codebook.iter().enumerate() {
                assert_eq!(s.encode(c), i, "{name}: encode({c}) lost its index");
                assert_eq!(s.quantize(c), c, "{name}: {c} is not a fixed point");
                assert_eq!(enc.value(i), c as f32, "{name}: encoder value table mismatch");
                assert_eq!(
                    enc.quantize(c as f32),
                    c as f32,
                    "{name}: {c} is not an encoder fixed point"
                );
            }
        }
    }

    #[test]
    fn encode_block_matches_scalar_encode() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::new(0xb10c);
        for name in all_names() {
            let enc = must(name).encoder();
            let xs: Vec<f32> = (0..257).map(|_| rng.range(-1.5, 1.5) as f32).collect();
            let mut codes = vec![0i8; xs.len()];
            enc.encode_block(&xs, &mut codes);
            for (&x, &c) in xs.iter().zip(&codes) {
                assert_eq!(c as usize, enc.encode(x), "{name}: block/scalar disagree at {x}");
            }
        }
    }

    #[test]
    fn packable_names_excludes_only_wide_codebooks() {
        let packable = packable_names();
        assert!(!packable.contains(&"int5"), "int5 has 32 values");
        assert_eq!(packable.len(), all_names().len() - 1);
        for name in packable {
            assert!(must(name).n_values() <= 16, "{name}");
        }
    }

    #[test]
    fn positive_side_bias_of_lookup_formats() {
        for name in ["nf4", "sf4", "nf3", "sf3"] {
            let cb = must(name).codebook;
            let pos = cb.iter().filter(|&&v| v > 0.0).count();
            let neg = cb.iter().filter(|&&v| v < 0.0).count();
            assert_eq!(pos, neg + 1, "{name}");
        }
    }
}
