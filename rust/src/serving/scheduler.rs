//! Admission queue + iteration-level scheduling policy.
//!
//! The scheduler owns the FIFO of sessions waiting for a KV slot and decides,
//! each engine step, which of them join the running batch (vLLM-style
//! continuous batching: admissions happen between *steps*, not between
//! *requests*). Prefill/decode interleave is governed by `prefill_chunk` —
//! how many prompt tokens one prefilling session may consume per step before
//! yielding the step back to decoding sessions — which bounds how long a
//! long-prompt arrival can stall in-flight streams.

use std::collections::VecDeque;
use std::time::Duration;

use crate::serving::session::DecodeSession;
use crate::serving::victim::VictimPolicyKind;

/// Scheduling knobs, generalizing the old `ServeConfig` pair
/// (`max_wait`/`max_requests`) to the decode engine.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Cap on concurrently active (prefill + decoding) sessions; also the
    /// natural KV slot-pool size.
    pub max_batch: usize,
    /// Arrival-coalescing window: when the engine is idle and a first
    /// request arrives, wait up to this long for more before stepping.
    pub max_wait: Duration,
    /// Admission queue bound; 0 = unbounded. Requests beyond it are
    /// rejected rather than queued (backpressure surface).
    pub max_queue: usize,
    /// Max prompt tokens one session prefills per engine step.
    pub prefill_chunk: usize,
    /// Reject arrivals while the KV page pool is saturated (the admission
    /// queue is non-empty and the pool lacks the pages the arrival's first
    /// admission would claim) instead of queuing them behind an unknown
    /// wait. Off by default — batch drivers prefer to queue — and switched
    /// on by the HTTP front end, whose 429 + `Retry-After` backpressure
    /// contract promises an answer instead of an unbounded queue.
    pub reject_saturated: bool,
    /// Stall watchdog: if one fused micro-step takes longer than this, the
    /// engine kills the batch row holding the most KV pages (it retires as
    /// `FinishReason::Failed`) so the rest of the batch keeps serving.
    /// `Duration::ZERO` (the default) disables the watchdog. Measured on
    /// `obs::clock`, so deterministic tests drive it with the fake clock.
    pub step_deadline: Duration,
    /// How the engine picks which active session to evict under page
    /// pressure (and which row the stall watchdog retires). See
    /// [`VictimPolicyKind`] for the policies.
    pub victim_policy: VictimPolicyKind,
    /// A session re-admitted after an eviction is ineligible as a victim
    /// for this long (measured on `obs::clock` from its re-admission), so
    /// two equal candidates under sustained pressure cannot ping-pong
    /// preempt→requeue→preempt forever. When *every* candidate is inside
    /// the cooldown the filter is waived — page pressure must always be
    /// able to reclaim a runnable session. `Duration::ZERO` (the default —
    /// batch drivers and the existing eviction schedules are pinned
    /// without it; the serving CLIs switch it on) disables it.
    pub resume_cooldown: Duration,
    /// Resurrect in-flight sessions after an engine-thread panic: instead
    /// of retiring them as `Failed`, [`Engine::recover_after_panic`]
    /// requeues them and the deterministic replay continues each HTTP
    /// stream (clients see a `resume_gap`, not a terminal `"failed"`
    /// line). Off by default: batch drivers and the legacy restart
    /// contract expect admitted work to fail visibly on a crash.
    ///
    /// [`Engine::recover_after_panic`]: crate::serving::Engine::recover_after_panic
    pub resurrect: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            max_queue: 0,
            prefill_chunk: 32,
            reject_saturated: false,
            step_deadline: Duration::ZERO,
            victim_policy: VictimPolicyKind::MostPages,
            resume_cooldown: Duration::ZERO,
            resurrect: false,
        }
    }
}

/// FIFO admission queue + step-boundary admission policy. Rejection
/// tallies live in the engine's `MetricsCollector` (single source of
/// truth); the scheduler only hands overflowing sessions back.
pub struct Scheduler {
    cfg: SchedulerConfig,
    queue: VecDeque<DecodeSession>,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        Scheduler { cfg, queue: VecDeque::new() }
    }

    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Queue a session for admission; `Err` hands it back on overflow.
    pub fn enqueue(&mut self, s: DecodeSession) -> Result<(), DecodeSession> {
        if self.cfg.max_queue > 0 && self.queue.len() >= self.cfg.max_queue {
            return Err(s);
        }
        self.queue.push_back(s);
        Ok(())
    }

    /// Re-queue a preempted session at the *head*: it already waited its
    /// turn, so it outranks fresh arrivals when its slot frees up.
    pub fn enqueue_front(&mut self, s: DecodeSession) -> Result<(), DecodeSession> {
        if self.cfg.max_queue > 0 && self.queue.len() >= self.cfg.max_queue {
            return Err(s);
        }
        self.queue.push_front(s);
        Ok(())
    }

    /// Step-boundary admission: pop as many queued sessions as fit in both
    /// the free slot pool and the batch cap, in FIFO order.
    pub fn admit(&mut self, free_slots: usize, active: usize) -> Vec<DecodeSession> {
        self.admit_within(free_slots, active, |_| true)
    }

    /// [`Self::admit`] with a caller-supplied resource check: sessions pop
    /// in FIFO order while `fits(head)` holds, and admission stops at the
    /// first head that does not fit (no skip-ahead — a long-context
    /// arrival is never starved by shorter ones behind it). The paged
    /// engine uses this to admit against a *pages-available* budget
    /// (enough free KV pages for the session's replayed context) instead
    /// of reserving worst-case positions per slot.
    pub fn admit_within(
        &mut self,
        free_slots: usize,
        active: usize,
        mut fits: impl FnMut(&DecodeSession) -> bool,
    ) -> Vec<DecodeSession> {
        let room = self.cfg.max_batch.saturating_sub(active).min(free_slots);
        let mut out = Vec::new();
        while out.len() < room {
            let head_fits = match self.queue.front() {
                Some(head) => fits(head),
                None => false,
            };
            if !head_fits {
                break;
            }
            out.push(self.queue.pop_front().expect("checked head exists"));
        }
        out
    }

    /// Empty the queue (engine shutdown/abort path).
    pub fn drain(&mut self) -> Vec<DecodeSession> {
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::clock;
    use crate::serving::TokenEvent;
    use std::sync::mpsc;

    fn session(id: u64) -> DecodeSession {
        // the receiver is dropped; these tests never emit events
        let (tx, _rx) = mpsc::channel::<TokenEvent>();
        DecodeSession::new(id, vec![1, 2], 4, None, tx, clock::now())
    }

    fn sched(max_batch: usize, max_queue: usize) -> Scheduler {
        Scheduler::new(SchedulerConfig { max_batch, max_queue, ..SchedulerConfig::default() })
    }

    #[test]
    fn admission_respects_slots_and_batch_cap() {
        let mut s = sched(3, 0);
        for id in 0..5 {
            s.enqueue(session(id)).unwrap();
        }
        // batch cap 3, 1 already active, plenty of slots -> admit 2
        let a = s.admit(10, 1);
        assert_eq!(a.iter().map(|x| x.id).collect::<Vec<_>>(), vec![0, 1], "FIFO order");
        assert_eq!(s.queue_len(), 3);
        // only 1 free slot -> admit 1 even though batch has room
        let b = s.admit(1, 0);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].id, 2);
        // batch full -> admit none
        assert!(s.admit(10, 3).is_empty());
        assert_eq!(s.queue_len(), 2);
    }

    #[test]
    fn bounded_queue_rejects_overflow() {
        let mut s = sched(4, 2);
        assert!(s.enqueue(session(0)).is_ok());
        assert!(s.enqueue(session(1)).is_ok());
        let back = s.enqueue(session(2));
        assert!(back.is_err());
        assert_eq!(back.unwrap_err().id, 2, "rejected session is handed back");
        assert_eq!(s.queue_len(), 2);
        // draining makes room again
        s.admit(10, 0);
        assert!(s.enqueue(session(3)).is_ok());
    }

    #[test]
    fn enqueue_front_outranks_fresh_arrivals() {
        let mut s = sched(4, 2);
        s.enqueue(session(0)).unwrap();
        s.enqueue_front(session(1)).unwrap();
        let a = s.admit(10, 0);
        assert_eq!(a.iter().map(|x| x.id).collect::<Vec<_>>(), vec![1, 0]);
        // the bound applies to the front door too
        s.enqueue(session(2)).unwrap();
        s.enqueue(session(3)).unwrap();
        assert!(s.enqueue_front(session(4)).is_err());
    }

    #[test]
    fn admit_within_stops_at_first_unfitting_head() {
        let mut s = sched(4, 0);
        for id in 0..4 {
            s.enqueue(session(id)).unwrap();
        }
        // a page-budget-style predicate: admit two, then run dry — the
        // third head blocks admission even though the fourth would fit
        let mut budget = 2;
        let a = s.admit_within(10, 0, |sess| {
            if sess.id == 2 {
                return false;
            }
            if budget == 0 {
                return false;
            }
            budget -= 1;
            true
        });
        assert_eq!(a.iter().map(|x| x.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(s.queue_len(), 2, "FIFO order preserved, no skip-ahead");
        // once the head fits again, admission resumes from it
        let b = s.admit_within(10, 0, |_| true);
        assert_eq!(b.iter().map(|x| x.id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn unbounded_queue_never_rejects() {
        let mut s = sched(2, 0);
        for id in 0..100 {
            assert!(s.enqueue(session(id)).is_ok());
        }
        assert_eq!(s.queue_len(), 100);
    }
}
