//! Victim selection for page-pressure preemption and the stall watchdog.
//!
//! When the KV page pool runs dry mid-step the engine must reclaim pages
//! from one active session; when a micro-step blows the stall deadline the
//! watchdog must retire one batch row. Both used to hard-code
//! "most-pages-held". This module makes the choice a policy: the engine
//! snapshots each runnable session into a [`VictimView`] and hands the
//! slate to the configured [`VictimPolicy`], which returns the index of
//! the session to sacrifice.
//!
//! Selection also enforces the **resume cooldown** (satellite of ISSUE 9):
//! a session re-admitted after an eviction is ineligible for
//! `resume_cooldown`, so two equal candidates under sustained pressure
//! cannot ping-pong preempt→requeue→preempt forever. The filter is waived
//! when *every* candidate is inside the cooldown — page pressure must
//! always be able to reclaim a runnable session (the engine's
//! `resolve_page_pressure` loop relies on it).

use std::time::{Duration, Instant};

/// Policy selector carried in `SchedulerConfig` (which is `Copy`, so this
/// is too). CLI names: `most-pages`, `lru`, `fair-share`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VictimPolicyKind {
    /// Evict the session holding the most KV pages (the longest context):
    /// most pages freed per eviction, fewest evictions per reclaimed page.
    /// The pre-policy engine's behavior, and the default.
    MostPages,
    /// Evict the session whose decode advanced least recently — the
    /// coldest stream loses its slot, mirroring the LRU intuition of the
    /// host tier itself.
    Lru,
    /// Evict the session with the most deadline slack; best-effort
    /// sessions (no deadline) go first. Fed by the HTTP layer's
    /// `deadline_ms` request field, judged by the `perf_http` p99 curves.
    FairShare,
}

impl VictimPolicyKind {
    /// Parse a CLI/config name; `None` for unknown names.
    pub fn from_name(name: &str) -> Option<VictimPolicyKind> {
        match name {
            "most-pages" => Some(VictimPolicyKind::MostPages),
            "lru" => Some(VictimPolicyKind::Lru),
            "fair-share" => Some(VictimPolicyKind::FairShare),
            _ => None,
        }
    }

    /// Stable name, inverse of [`Self::from_name`].
    pub fn name(&self) -> &'static str {
        match self {
            VictimPolicyKind::MostPages => "most-pages",
            VictimPolicyKind::Lru => "lru",
            VictimPolicyKind::FairShare => "fair-share",
        }
    }

    /// The policy implementation behind this kind.
    pub fn policy(&self) -> &'static dyn VictimPolicy {
        match self {
            VictimPolicyKind::MostPages => &MostPagesHeld,
            VictimPolicyKind::Lru => &LruByLastStep,
            VictimPolicyKind::FairShare => &FairShareSlack,
        }
    }
}

/// One eviction candidate, snapshotted at selection time. Built by the
/// engine from each runnable session + its cache accounting; policies see
/// only this view, never the sessions themselves.
#[derive(Clone, Copy, Debug)]
pub struct VictimView {
    pub id: u64,
    /// KV pages the session's slot holds right now.
    pub pages: usize,
    /// Committed cache positions (context length so far).
    pub len: usize,
    /// When the session last emitted a token; `None` while still
    /// prefilling its first token.
    pub last_token_at: Option<Instant>,
    /// Remaining latency budget (`deadline - elapsed`, floored at zero);
    /// `None` for best-effort sessions without a deadline.
    pub deadline_slack: Option<Duration>,
    /// When the session last re-entered a slot after an eviction; `None`
    /// for first admissions (immediately evictable).
    pub resumed_at: Option<Instant>,
}

/// A victim-selection policy: given the runnable candidates (in batch
/// order), return the index of the one to evict, or `None` for an empty
/// slate. Implementations must be deterministic — tests replay schedules
/// and expect identical victims.
pub trait VictimPolicy {
    fn pick(&self, candidates: &[VictimView]) -> Option<usize>;
}

/// See [`VictimPolicyKind::MostPages`]. Ties break toward the most
/// committed positions, then the most recently admitted (matching the
/// pre-policy `max_by_key` exactly, so existing eviction tests and traces
/// replay unchanged).
pub struct MostPagesHeld;

impl VictimPolicy for MostPagesHeld {
    fn pick(&self, candidates: &[VictimView]) -> Option<usize> {
        candidates
            .iter()
            .enumerate()
            .max_by_key(|(_, c)| (c.pages, c.len))
            .map(|(i, _)| i)
    }
}

/// See [`VictimPolicyKind::Lru`]. A session that has never emitted
/// (`last_token_at == None`) is the coldest of all; ties break toward the
/// most pages freed, then the earliest candidate.
pub struct LruByLastStep;

impl VictimPolicy for LruByLastStep {
    fn pick(&self, candidates: &[VictimView]) -> Option<usize> {
        use std::cmp::Reverse;
        candidates
            .iter()
            .enumerate()
            .min_by_key(|(_, c)| (c.last_token_at, Reverse(c.pages), Reverse(c.len)))
            .map(|(i, _)| i)
    }
}

/// See [`VictimPolicyKind::FairShare`]. Best-effort sessions outrank any
/// deadline-bearing one as victims; among deadline holders the most slack
/// loses; ties break toward the most pages freed.
pub struct FairShareSlack;

impl VictimPolicy for FairShareSlack {
    fn pick(&self, candidates: &[VictimView]) -> Option<usize> {
        candidates
            .iter()
            .enumerate()
            .max_by_key(|(_, c)| (c.deadline_slack.is_none(), c.deadline_slack, c.pages, c.len))
            .map(|(i, _)| i)
    }
}

/// Apply the resume cooldown, then the policy: candidates re-admitted
/// within `cooldown` of `now` are filtered out unless that would empty the
/// slate (pressure always reclaims *someone*). Returns the victim's
/// session id.
pub fn select(
    kind: VictimPolicyKind,
    candidates: &[VictimView],
    cooldown: Duration,
    now: Instant,
) -> Option<u64> {
    if candidates.is_empty() {
        return None;
    }
    let policy = kind.policy();
    if !cooldown.is_zero() {
        let eligible: Vec<VictimView> = candidates
            .iter()
            .copied()
            .filter(|c| match c.resumed_at {
                Some(t) => now.saturating_duration_since(t) >= cooldown,
                None => true,
            })
            .collect();
        if !eligible.is_empty() {
            return policy.pick(&eligible).map(|i| eligible[i].id);
        }
        // every candidate is mid-cooldown: waive the filter rather than
        // leave the pressure loop with no victim
    }
    policy.pick(candidates).map(|i| candidates[i].id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::clock;

    fn view(id: u64, pages: usize, len: usize) -> VictimView {
        VictimView { id, pages, len, last_token_at: None, deadline_slack: None, resumed_at: None }
    }

    #[test]
    fn policy_names_round_trip() {
        for kind in [VictimPolicyKind::MostPages, VictimPolicyKind::Lru, VictimPolicyKind::FairShare]
        {
            assert_eq!(VictimPolicyKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(VictimPolicyKind::from_name("round-robin"), None);
    }

    #[test]
    fn most_pages_prefers_pages_then_len_then_latest() {
        let c = [view(1, 2, 8), view(2, 3, 4), view(3, 3, 6), view(4, 3, 6)];
        let picked = MostPagesHeld.pick(&c).unwrap();
        // pages tie at 3 → len tie at 6 → max_by_key keeps the last (the
        // most recently admitted), exactly like the pre-policy engine
        assert_eq!(c[picked].id, 4);
    }

    #[test]
    fn lru_evicts_the_coldest_stream() {
        let _clock = clock::fake();
        let t0 = clock::now();
        clock::advance(Duration::from_millis(10));
        let t1 = clock::now();
        let c = [
            VictimView { last_token_at: Some(t1), ..view(1, 4, 9) },
            VictimView { last_token_at: Some(t0), ..view(2, 1, 3) },
            VictimView { last_token_at: Some(t1), ..view(3, 2, 5) },
        ];
        assert_eq!(c[LruByLastStep.pick(&c).unwrap()].id, 2, "oldest token wins eviction");
        // a never-emitted session is colder than any emitted one
        let c2 = [VictimView { last_token_at: Some(t0), ..view(1, 4, 9) }, view(2, 1, 3)];
        assert_eq!(c2[LruByLastStep.pick(&c2).unwrap()].id, 2);
    }

    #[test]
    fn fair_share_sacrifices_best_effort_then_most_slack() {
        let slack = |ms| Some(Duration::from_millis(ms));
        let c = [
            VictimView { deadline_slack: slack(5), ..view(1, 4, 9) },
            VictimView { deadline_slack: None, ..view(2, 1, 3) },
            VictimView { deadline_slack: slack(500), ..view(3, 2, 5) },
        ];
        assert_eq!(c[FairShareSlack.pick(&c).unwrap()].id, 2, "best-effort goes first");
        let c2 = [
            VictimView { deadline_slack: slack(5), ..view(1, 4, 9) },
            VictimView { deadline_slack: slack(500), ..view(3, 2, 5) },
        ];
        assert_eq!(c2[FairShareSlack.pick(&c2).unwrap()].id, 3, "most slack loses");
    }

    #[test]
    fn cooldown_shields_the_just_resumed_until_it_expires() {
        let _clock = clock::fake();
        let resumed = clock::now();
        let cooldown = Duration::from_millis(250);
        // the bigger session just resumed; the smaller one is fair game
        let c = [VictimView { resumed_at: Some(resumed), ..view(1, 4, 9) }, view(2, 1, 3)];
        assert_eq!(select(VictimPolicyKind::MostPages, &c, cooldown, clock::now()), Some(2));
        // once the cooldown lapses the policy's own preference returns
        clock::advance(cooldown);
        assert_eq!(select(VictimPolicyKind::MostPages, &c, cooldown, clock::now()), Some(1));
    }

    #[test]
    fn cooldown_is_waived_when_every_candidate_is_inside_it() {
        let _clock = clock::fake();
        let resumed = clock::now();
        let c = [
            VictimView { resumed_at: Some(resumed), ..view(1, 4, 9) },
            VictimView { resumed_at: Some(resumed), ..view(2, 1, 3) },
        ];
        let picked = select(VictimPolicyKind::MostPages, &c, Duration::from_millis(250), clock::now());
        assert_eq!(picked, Some(1), "pressure still reclaims a session");
    }

    #[test]
    fn zero_cooldown_disables_the_filter() {
        let _clock = clock::fake();
        let c = [VictimView { resumed_at: Some(clock::now()), ..view(1, 4, 9) }, view(2, 1, 3)];
        assert_eq!(select(VictimPolicyKind::MostPages, &c, Duration::ZERO, clock::now()), Some(1));
        assert_eq!(select(VictimPolicyKind::MostPages, &[], Duration::ZERO, clock::now()), None);
    }
}
