//! Paged slot-pool KV cache: a global pool of fixed-size *pages* plus a
//! per-sequence *block table*, in either of two lane formats.
//!
//! The pre-PR-5 cache reserved one worst-case contiguous lane
//! (`capacity × d_model` per layer) per concurrent sequence, so admission
//! capacity was `slots × capacity` positions even when every live sequence
//! held a fraction of that. Now storage is `pages × page_size` positions of
//! K and V per layer — fp32 values, **or** packed 4-bit codes + per-block
//! scales (`quant::KvFormat`, page-granular) — and each sequence owns only
//! the pages its committed positions actually cover, listed in its block
//! table. Position `j` of a sequence lives at row `j % page_size` of page
//! `table[j / page_size]`. Pages are claimed on demand as sequences grow
//! (one `try_reserve` ahead of each append) and returned — zeroed — when
//! the sequence retires or is preempted, so many long-context sequences
//! admit against the same physical pool.
//!
//! Views ([`SlotView`], via [`KvCache::slots_mut`]) implement
//! [`crate::nn::KvStore`] and hand the forwards a *block table* of page
//! slices ([`crate::nn::KvLanes::PagedF32`] / `PagedPacked4`); the
//! page-walking attention kernels visit positions in exactly the
//! contiguous order, so paging changes where rows live, never any bit of
//! the result (`rust/tests/paged_kv.rs`).
//!
//! Allocation is LIFO at both granularities (slots = block tables, pages).
//! Freed pages are zeroed before returning to the pool (a reused page must
//! never leak a prior session's K/V). All storage is allocated once at
//! engine start; per-step work allocates only transient views.

use std::collections::HashMap;

use crate::model_io::ModelConfig;
use crate::nn::{KvLanes, KvStore};
use crate::quant::KvFormat;

use anyhow::Result;

/// Index of one sequence's block table.
pub type SlotId = usize;

/// Index of one page in the pool.
pub type PageId = usize;

/// Default positions per page: small enough that a short sequence wastes
/// at most 15 positions of tail fragmentation, large enough that block
/// tables and page-walk overhead stay negligible (vLLM's default block).
pub const DEFAULT_PAGE_SIZE: usize = 16;

/// Cache geometry. `capacity` is the most positions one sequence may hold
/// (≤ the model's positional window for the pure-Rust path); `pages ×
/// page_size` is the pool — the *physical* admission capacity, which the
/// paged layout lets sit well below the worst case `slots × capacity`.
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    pub slots: usize,
    pub capacity: usize,
    pub n_layers: usize,
    pub d_model: usize,
    pub page_size: usize,
    pub pages: usize,
}

impl KvCacheConfig {
    /// Overflow-checked constructor: absurd geometries (the old
    /// `max_seq × slots` unchecked multiplication could wrap) error
    /// instead of wrapping into a tiny allocation.
    pub fn try_new(
        slots: usize,
        capacity: usize,
        n_layers: usize,
        d_model: usize,
        page_size: usize,
        pages: usize,
    ) -> Result<KvCacheConfig> {
        anyhow::ensure!(
            slots > 0 && capacity > 0 && n_layers > 0 && d_model > 0,
            "degenerate cache geometry: slots {slots}, capacity {capacity}, \
             layers {n_layers}, d_model {d_model}"
        );
        anyhow::ensure!(page_size > 0 && pages > 0, "degenerate page pool: {pages} x {page_size}");
        let cfg = KvCacheConfig { slots, capacity, n_layers, d_model, page_size, pages };
        anyhow::ensure!(
            slots.checked_mul(capacity).is_some() && cfg.checked_bytes().is_some(),
            "KV cache geometry overflows usize: {cfg:?}"
        );
        Ok(cfg)
    }

    /// Geometry for a zoo model: worst-case pool (every slot can hold a
    /// full positional window), default page size — the same admission
    /// capacity as the old contiguous layout, in pages.
    pub fn for_model(cfg: &ModelConfig, slots: usize) -> KvCacheConfig {
        let slots = slots.max(1);
        let page_size = DEFAULT_PAGE_SIZE.min(cfg.seq.max(1));
        KvCacheConfig {
            slots,
            capacity: cfg.seq,
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            page_size,
            pages: slots * cfg.seq.div_ceil(page_size),
        }
    }

    /// Positions the page pool physically holds.
    pub fn pool_positions(&self) -> usize {
        self.pages * self.page_size
    }

    /// Pages a sequence of `positions` committed positions occupies.
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.page_size)
    }

    /// Pages one full-capacity sequence occupies (the worst case a single
    /// admission can grow to).
    pub fn seq_pages(&self) -> usize {
        self.capacity.div_ceil(self.page_size)
    }

    /// Bytes one cached position occupies across K+V for one layer in
    /// **fp32** lanes — the single source the byte accounting derives from
    /// (packed caches scale this down; see [`KvCache::position_bytes`]).
    pub fn position_bytes_f32(&self) -> usize {
        2 * self.d_model * std::mem::size_of::<f32>()
    }

    fn checked_bytes(&self) -> Option<usize> {
        self.pages
            .checked_mul(self.page_size)?
            .checked_mul(self.n_layers)?
            .checked_mul(2usize.checked_mul(self.d_model)?.checked_mul(4)?)
    }

    /// Bytes of K+V storage the **fp32** lane format preallocates for this
    /// geometry — derived from [`Self::position_bytes_f32`], not a second
    /// copy of the formula.
    pub fn bytes(&self) -> usize {
        self.n_layers * self.pool_positions() * self.position_bytes_f32()
    }
}

/// Per-layer lane storage: one flat buffer per layer, sliced into
/// page-sized chunks on access (page `p` holds rows
/// `p * page_size .. (p + 1) * page_size`).
enum PoolStore {
    F32 {
        k: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
    },
    Packed4 {
        fmt: KvFormat,
        k_codes: Vec<Vec<u8>>,
        k_scales: Vec<Vec<f32>>,
        v_codes: Vec<Vec<u8>>,
        v_scales: Vec<Vec<f32>>,
    },
}

/// The paged pool. See the module docs for the layout.
pub struct KvCache {
    cfg: KvCacheConfig,
    store: PoolStore,
    /// Per-slot block table: the pages holding this sequence, in position
    /// order. Empty for free slots.
    tables: Vec<Vec<PageId>>,
    /// Committed positions per slot.
    lens: Vec<usize>,
    in_use: Vec<bool>,
    free_slots: Vec<SlotId>,
    free_pages: Vec<PageId>,
}

impl KvCache {
    /// Dense fp32 lanes (the default; bit-identical results to the
    /// contiguous engine).
    pub fn new(cfg: KvCacheConfig) -> KvCache {
        Self::assert_geometry(&cfg);
        let lane = cfg.pool_positions() * cfg.d_model;
        KvCache {
            store: PoolStore::F32 {
                k: (0..cfg.n_layers).map(|_| vec![0.0; lane]).collect(),
                v: (0..cfg.n_layers).map(|_| vec![0.0; lane]).collect(),
            },
            tables: vec![Vec::new(); cfg.slots],
            lens: vec![0; cfg.slots],
            in_use: vec![false; cfg.slots],
            free_slots: (0..cfg.slots).rev().collect(),
            free_pages: (0..cfg.pages).rev().collect(),
            cfg,
        }
    }

    /// Packed 4-bit lanes: K/V rows are quantized on append
    /// (`KvFormat::encode_row`) into page-granular code/scale storage and
    /// dequantized inside the fused attention kernels — ~8x less cache
    /// storage and ~5x less read traffic per decode step than fp32 lanes.
    pub fn new_packed(cfg: KvCacheConfig, fmt: KvFormat) -> KvCache {
        Self::assert_geometry(&cfg);
        assert_eq!(
            cfg.d_model % fmt.block,
            0,
            "KV block {} does not divide d_model {}",
            fmt.block,
            cfg.d_model
        );
        let positions = cfg.pool_positions();
        let cb = positions * fmt.codes_per_row(cfg.d_model);
        let sb = positions * fmt.scales_per_row(cfg.d_model);
        KvCache {
            store: PoolStore::Packed4 {
                k_codes: (0..cfg.n_layers).map(|_| vec![0u8; cb]).collect(),
                k_scales: (0..cfg.n_layers).map(|_| vec![0.0f32; sb]).collect(),
                v_codes: (0..cfg.n_layers).map(|_| vec![0u8; cb]).collect(),
                v_scales: (0..cfg.n_layers).map(|_| vec![0.0f32; sb]).collect(),
                fmt,
            },
            tables: vec![Vec::new(); cfg.slots],
            lens: vec![0; cfg.slots],
            in_use: vec![false; cfg.slots],
            free_slots: (0..cfg.slots).rev().collect(),
            free_pages: (0..cfg.pages).rev().collect(),
            cfg,
        }
    }

    fn assert_geometry(cfg: &KvCacheConfig) {
        assert!(
            cfg.slots > 0 && cfg.capacity > 0 && cfg.page_size > 0 && cfg.pages > 0,
            "degenerate cache geometry {cfg:?}"
        );
        assert!(
            KvCacheConfig::try_new(
                cfg.slots,
                cfg.capacity,
                cfg.n_layers,
                cfg.d_model,
                cfg.page_size,
                cfg.pages
            )
            .is_ok(),
            "cache geometry overflows {cfg:?}"
        );
    }

    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }

    /// The packed lane format, if this pool quantizes its cache.
    pub fn kv_format(&self) -> Option<&KvFormat> {
        match &self.store {
            PoolStore::F32 { .. } => None,
            PoolStore::Packed4 { fmt, .. } => Some(fmt),
        }
    }

    /// Bytes one cached position occupies across K+V for **one** layer —
    /// the unit of KV read traffic per attended position per layer.
    pub fn position_bytes(&self) -> usize {
        match &self.store {
            PoolStore::F32 { .. } => self.cfg.position_bytes_f32(),
            PoolStore::Packed4 { fmt, .. } => 2 * fmt.row_bytes(self.cfg.d_model),
        }
    }

    /// Actual bytes of K+V lane storage this pool holds — derived from
    /// [`Self::position_bytes`] over the pool's positions, one formula for
    /// both lane formats.
    pub fn bytes(&self) -> usize {
        self.cfg.n_layers * self.cfg.pool_positions() * self.position_bytes()
    }

    /// Most positions one sequence may hold.
    pub fn capacity(&self) -> usize {
        self.cfg.capacity
    }

    pub fn page_size(&self) -> usize {
        self.cfg.page_size
    }

    pub fn pages_total(&self) -> usize {
        self.cfg.pages
    }

    pub fn pages_free(&self) -> usize {
        self.free_pages.len()
    }

    pub fn pages_in_use(&self) -> usize {
        self.cfg.pages - self.free_pages.len()
    }

    /// Pages a sequence of `positions` occupies (delegates to the config).
    pub fn pages_for(&self, positions: usize) -> usize {
        self.cfg.pages_for(positions)
    }

    /// Pages one slot's block table currently holds.
    pub fn pages_held(&self, slot: SlotId) -> usize {
        self.tables[slot].len()
    }

    pub fn slots_total(&self) -> usize {
        self.cfg.slots
    }

    pub fn slots_free(&self) -> usize {
        self.free_slots.len()
    }

    pub fn slots_in_use(&self) -> usize {
        self.cfg.slots - self.free_slots.len()
    }

    /// Fraction of block-table slots occupied, in [0, 1].
    pub fn occupancy(&self) -> f64 {
        self.slots_in_use() as f64 / self.cfg.slots as f64
    }

    /// Tail waste of the current allocation, in [0, 1]: the fraction of
    /// held page positions no committed row occupies. 0 when nothing is
    /// held. High fragmentation means the page size is too coarse for the
    /// live sequence lengths.
    pub fn page_fragmentation(&self) -> f64 {
        let held = self.pages_in_use() * self.cfg.page_size;
        if held == 0 {
            return 0.0;
        }
        let live: usize = (0..self.cfg.slots).filter(|&s| self.in_use[s]).map(|s| self.lens[s]).sum();
        1.0 - live as f64 / held as f64
    }

    /// Claim a free slot (an empty block table); `None` when every table
    /// is taken. Claims **no pages** — they arrive on demand as the
    /// sequence appends.
    pub fn allocate(&mut self) -> Option<SlotId> {
        let slot = self.free_slots.pop()?;
        debug_assert!(!self.in_use[slot]);
        debug_assert!(self.tables[slot].is_empty(), "free slot kept pages");
        self.in_use[slot] = true;
        self.lens[slot] = 0;
        Some(slot)
    }

    /// Return a slot to the pool: every page in its block table is zeroed
    /// (a reused page must never observe a prior session's K/V — including
    /// an appended-but-uncommitted row from a failed batch step) and
    /// returned to the free list. Panics on double-free (an engine bug).
    pub fn free(&mut self, slot: SlotId) {
        assert!(self.in_use[slot], "freeing slot {slot} that is not in use");
        let pages = std::mem::take(&mut self.tables[slot]);
        for &page in &pages {
            self.clear_page(page);
        }
        self.free_pages.extend(pages);
        self.lens[slot] = 0;
        self.in_use[slot] = false;
        self.free_slots.push(slot);
    }

    /// Zero one page in every layer's K and V lanes.
    fn clear_page(&mut self, page: PageId) {
        let d = self.cfg.d_model;
        match &mut self.store {
            PoolStore::F32 { k, v } => {
                let lane = self.cfg.page_size * d;
                for layer in k.iter_mut().chain(v.iter_mut()) {
                    layer[page * lane..(page + 1) * lane].fill(0.0);
                }
            }
            PoolStore::Packed4 { fmt, k_codes, k_scales, v_codes, v_scales } => {
                let clane = fmt.codes_per_page(d, self.cfg.page_size);
                let slane = fmt.scales_per_page(d, self.cfg.page_size);
                for layer in k_codes.iter_mut().chain(v_codes.iter_mut()) {
                    layer[page * clane..(page + 1) * clane].fill(0);
                }
                for layer in k_scales.iter_mut().chain(v_scales.iter_mut()) {
                    layer[page * slane..(page + 1) * slane].fill(0.0);
                }
            }
        }
    }

    /// True when every byte of one page's K/V storage is zero.
    pub fn page_is_zeroed(&self, page: PageId) -> bool {
        let d = self.cfg.d_model;
        match &self.store {
            PoolStore::F32 { k, v } => {
                let lane = self.cfg.page_size * d;
                k.iter().chain(v.iter()).all(|layer| {
                    layer[page * lane..(page + 1) * lane].iter().all(|&x| x == 0.0)
                })
            }
            PoolStore::Packed4 { fmt, k_codes, k_scales, v_codes, v_scales } => {
                let clane = fmt.codes_per_page(d, self.cfg.page_size);
                let slane = fmt.scales_per_page(d, self.cfg.page_size);
                k_codes.iter().chain(v_codes.iter()).all(|layer| {
                    layer[page * clane..(page + 1) * clane].iter().all(|&x| x == 0)
                }) && k_scales.iter().chain(v_scales.iter()).all(|layer| {
                    layer[page * slane..(page + 1) * slane].iter().all(|&x| x == 0.0)
                })
            }
        }
    }

    /// True when every free-list page is fully zeroed — the invariant
    /// [`KvCache::free`] establishes (regression surface for the
    /// reused-page isolation tests).
    pub fn free_pages_are_zeroed(&self) -> bool {
        self.free_pages.iter().all(|&p| self.page_is_zeroed(p))
    }

    /// Committed positions in one slot.
    pub fn len(&self, slot: SlotId) -> usize {
        self.lens[slot]
    }

    /// True when this slot's next append needs a page its block table does
    /// not yet hold — the engine's per-step page-pressure accounting.
    pub fn next_append_needs_page(&self, slot: SlotId) -> bool {
        self.lens[slot] < self.cfg.capacity
            && self.lens[slot] >= self.tables[slot].len() * self.cfg.page_size
    }

    /// Grow one slot's block table (from the free list) until it covers
    /// `positions` committed positions (clamped to `capacity`). A
    /// reservation is all-or-nothing: when the pool runs dry partway
    /// through a multi-page grow, every page claimed **by this call** goes
    /// back to the free list before `false` returns — a half-satisfied
    /// reservation must not hold pages it cannot use while the engine
    /// resolves the pressure by preempting or spilling a victim. (Claimed-
    /// and-rolled-back pages were never written, so the zeroed-free-page
    /// invariant survives.)
    pub fn try_reserve(&mut self, slot: SlotId, positions: usize) -> bool {
        assert!(self.in_use[slot], "reserving for slot {slot} that is not in use");
        if crate::faults::fire(crate::faults::Site::KvReserveFail) {
            return false;
        }
        let target = self.cfg.pages_for(positions.min(self.cfg.capacity));
        let before = self.tables[slot].len();
        while self.tables[slot].len() < target {
            match self.free_pages.pop() {
                Some(page) => self.tables[slot].push(page),
                None => {
                    while self.tables[slot].len() > before {
                        let page = self.tables[slot].pop().expect("rollback page");
                        self.free_pages.push(page);
                    }
                    return false;
                }
            }
        }
        true
    }

    /// Take up to `n` pages out of the free list without attaching them to
    /// any slot — the `kv_page_spike` fault's exhaustion pressure. The
    /// seized pages count as in-use (admission and the page-pressure guard
    /// both see a smaller pool) until [`Self::return_pages`] hands them
    /// back; they are never written, so the zeroed-free-page invariant
    /// survives the round trip.
    pub fn seize_free_pages(&mut self, n: usize) -> Vec<PageId> {
        let keep = self.free_pages.len().saturating_sub(n);
        self.free_pages.split_off(keep)
    }

    /// Return pages taken by [`Self::seize_free_pages`] to the free list.
    pub fn return_pages(&mut self, pages: Vec<PageId>) {
        debug_assert!(pages.iter().all(|&p| self.page_is_zeroed(p)), "seized pages were written");
        self.free_pages.extend(pages);
    }

    /// Borrow one slot's lanes as a [`KvStore`] for the incremental
    /// forward (reserves room for one append, like [`Self::slots_mut`]).
    pub fn slot(&mut self, slot: SlotId) -> SlotView<'_> {
        assert!(self.in_use[slot], "viewing slot {slot} that is not in use");
        self.slots_mut(&[slot]).pop().expect("one view for one id")
    }

    /// Borrow several *distinct* slots' lanes at once — the fused batched
    /// decode step (`nn::forward_lm_step_batch`) needs every row's
    /// [`KvStore`] live simultaneously. Views come back in `ids` order,
    /// each with one appendable position reserved (the engine's
    /// page-pressure guard ran first, so reservation cannot fail short of
    /// an accounting bug). The disjointness that makes the simultaneous
    /// `&mut` borrows sound is proven to the borrow checker by carving
    /// each layer buffer into page chunks and handing each page out at
    /// most once — block tables never share pages, so neither do views;
    /// duplicate or not-in-use ids panic (engine bugs).
    pub fn slots_mut(&mut self, ids: &[SlotId]) -> Vec<KvView<'_>> {
        for &id in ids {
            assert!(self.in_use[id], "viewing slot {id} that is not in use");
            assert!(
                self.try_reserve(id, self.lens[id] + 1),
                "page pool exhausted reserving for slot {id} \
                 (engine accounting bug, or an injected kv_reserve_fail fault)"
            );
        }
        let cfg = self.cfg;
        let d = cfg.d_model;
        let tables: Vec<Vec<PageId>> = ids.iter().map(|&id| self.tables[id].clone()).collect();
        let limits: Vec<usize> =
            tables.iter().map(|t| (t.len() * cfg.page_size).min(cfg.capacity)).collect();
        let views: Vec<ViewLanes<'_>> = match &mut self.store {
            PoolStore::F32 { k, v } => {
                let lane = cfg.page_size * d;
                let ks = carve_pages(k, lane, &tables);
                let vs = carve_pages(v, lane, &tables);
                ks.into_iter().zip(vs).map(|(k, v)| ViewLanes::F32 { k, v }).collect()
            }
            PoolStore::Packed4 { fmt, k_codes, k_scales, v_codes, v_scales } => {
                let clane = fmt.codes_per_page(d, cfg.page_size);
                let slane = fmt.scales_per_page(d, cfg.page_size);
                let kc = carve_pages(k_codes, clane, &tables);
                let ks = carve_pages(k_scales, slane, &tables);
                let vc = carve_pages(v_codes, clane, &tables);
                let vs = carve_pages(v_scales, slane, &tables);
                let fmt: &KvFormat = fmt;
                kc.into_iter()
                    .zip(ks)
                    .zip(vc.into_iter().zip(vs))
                    .map(|((k_codes, k_scales), (v_codes, v_scales))| ViewLanes::Packed4 {
                        fmt,
                        k_codes,
                        k_scales,
                        v_codes,
                        v_scales,
                    })
                    .collect()
            }
        };
        let mut lens: Vec<Option<&mut usize>> = self.lens.iter_mut().map(Some).collect();
        ids.iter()
            .zip(views)
            .zip(limits)
            .map(|((&id, lanes), limit)| SlotView {
                lanes,
                len: lens[id].take().expect("duplicate slot id in batch"),
                limit,
                page_rows: cfg.page_size,
                d,
            })
            .collect()
    }

    // -- host-tier spill / restore ------------------------------------------

    /// Bytes one page occupies in the host-tier byte image: every layer's K
    /// then V lane bytes, in the exact on-device layout (raw f32 words for
    /// fp32 lanes, already-encoded codes + scale words for packed lanes).
    pub fn page_spill_bytes(&self) -> usize {
        self.cfg.n_layers * self.cfg.page_size * self.position_bytes()
    }

    /// Copy one slot's pages into a [`HostEntry`] — the device-layout byte
    /// image a later [`Self::restore_slot`] splices back. fp32 lanes are
    /// captured as raw f32 words (quantizing them on the way out would
    /// break the byte-identical restore the resume path promises); packed
    /// lanes are captured as their codes + scales, which *are* the
    /// configured `KvFormat` encoder's output — spilling a packed page
    /// moves ~8x fewer bytes than fp32. The slot itself is untouched; the
    /// engine frees it (zeroing the device pages) after the copy.
    pub fn export_slot(&self, slot: SlotId) -> HostEntry {
        assert!(self.in_use[slot], "exporting slot {slot} that is not in use");
        let pages = self.tables[slot]
            .iter()
            .map(|&p| {
                let mut buf = Vec::with_capacity(self.page_spill_bytes());
                self.export_page(p, &mut buf);
                buf
            })
            .collect();
        HostEntry { len: self.lens[slot], pages }
    }

    /// Splice a spilled byte image back into a freshly allocated slot:
    /// claim pages for `entry.len` positions, copy each host page into its
    /// device page (same byte layout both ways, so the round trip is
    /// bit-identical), and set the committed length. Returns `false` —
    /// with nothing claimed, by the all-or-nothing [`Self::try_reserve`] —
    /// when the pool cannot supply the pages; the caller falls back to
    /// replaying the context through prefill instead.
    pub fn restore_slot(&mut self, slot: SlotId, entry: &HostEntry) -> bool {
        assert!(self.in_use[slot], "restoring into slot {slot} that is not in use");
        assert!(self.tables[slot].is_empty() && self.lens[slot] == 0, "restore needs a fresh slot");
        assert_eq!(
            entry.pages.len(),
            self.cfg.pages_for(entry.len),
            "host entry page count disagrees with its length"
        );
        if !self.try_reserve(slot, entry.len) {
            return false;
        }
        for (i, bytes) in entry.pages.iter().enumerate() {
            let page = self.tables[slot][i];
            self.import_page(page, bytes);
        }
        self.lens[slot] = entry.len;
        true
    }

    /// Serialize one device page into `out` (layer-major, K then V).
    fn export_page(&self, page: PageId, out: &mut Vec<u8>) {
        let d = self.cfg.d_model;
        match &self.store {
            PoolStore::F32 { k, v } => {
                let lane = self.cfg.page_size * d;
                for layer in 0..self.cfg.n_layers {
                    push_f32s(out, &k[layer][page * lane..(page + 1) * lane]);
                    push_f32s(out, &v[layer][page * lane..(page + 1) * lane]);
                }
            }
            PoolStore::Packed4 { fmt, k_codes, k_scales, v_codes, v_scales } => {
                let clane = fmt.codes_per_page(d, self.cfg.page_size);
                let slane = fmt.scales_per_page(d, self.cfg.page_size);
                for layer in 0..self.cfg.n_layers {
                    out.extend_from_slice(&k_codes[layer][page * clane..(page + 1) * clane]);
                    push_f32s(out, &k_scales[layer][page * slane..(page + 1) * slane]);
                    out.extend_from_slice(&v_codes[layer][page * clane..(page + 1) * clane]);
                    push_f32s(out, &v_scales[layer][page * slane..(page + 1) * slane]);
                }
            }
        }
    }

    /// Inverse of [`Self::export_page`]: write one host page image into a
    /// device page. Bit-exact — f32 words round-trip through `to_le_bytes`
    /// / `from_le_bytes`, which preserve every bit pattern including NaNs.
    fn import_page(&mut self, page: PageId, bytes: &[u8]) {
        let d = self.cfg.d_model;
        assert_eq!(bytes.len(), self.page_spill_bytes(), "host page image size");
        let mut at = 0usize;
        match &mut self.store {
            PoolStore::F32 { k, v } => {
                let lane = self.cfg.page_size * d;
                for layer in 0..self.cfg.n_layers {
                    at = take_f32s(bytes, at, &mut k[layer][page * lane..(page + 1) * lane]);
                    at = take_f32s(bytes, at, &mut v[layer][page * lane..(page + 1) * lane]);
                }
            }
            PoolStore::Packed4 { fmt, k_codes, k_scales, v_codes, v_scales } => {
                let clane = fmt.codes_per_page(d, self.cfg.page_size);
                let slane = fmt.scales_per_page(d, self.cfg.page_size);
                for layer in 0..self.cfg.n_layers {
                    k_codes[layer][page * clane..(page + 1) * clane]
                        .copy_from_slice(&bytes[at..at + clane]);
                    at += clane;
                    at = take_f32s(bytes, at, &mut k_scales[layer][page * slane..(page + 1) * slane]);
                    v_codes[layer][page * clane..(page + 1) * clane]
                        .copy_from_slice(&bytes[at..at + clane]);
                    at += clane;
                    at = take_f32s(bytes, at, &mut v_scales[layer][page * slane..(page + 1) * slane]);
                }
            }
        }
        debug_assert_eq!(at, bytes.len(), "host page image fully consumed");
    }
}

fn push_f32s(out: &mut Vec<u8>, vals: &[f32]) {
    for &x in vals {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn take_f32s(bytes: &[u8], mut at: usize, dst: &mut [f32]) -> usize {
    for x in dst {
        *x = f32::from_le_bytes(bytes[at..at + 4].try_into().expect("4-byte f32 word"));
        at += 4;
    }
    at
}

/// One spilled sequence: its committed length and its pages as device-
/// layout byte images, in block-table order.
pub struct HostEntry {
    /// Committed positions the spilled block table covered.
    pub len: usize,
    pages: Vec<Vec<u8>>,
}

impl HostEntry {
    pub fn pages(&self) -> usize {
        self.pages.len()
    }

    pub fn bytes(&self) -> usize {
        self.pages.iter().map(|p| p.len()).sum()
    }
}

/// Host-side tier for spilled KV pages, keyed by session id. Bounded by a
/// byte budget: an insert past the budget is refused and the engine falls
/// back to preempt-and-recompute — degrading to the old behavior, never
/// growing host memory without bound. A budget of zero disables the tier.
pub struct HostTier {
    cap_bytes: usize,
    used_bytes: usize,
    entries: HashMap<u64, HostEntry>,
}

impl HostTier {
    pub fn new(cap_bytes: usize) -> HostTier {
        HostTier { cap_bytes, used_bytes: 0, entries: HashMap::new() }
    }

    /// True when the tier can hold anything at all.
    pub fn enabled(&self) -> bool {
        self.cap_bytes > 0
    }

    pub fn cap_bytes(&self) -> usize {
        self.cap_bytes
    }

    pub fn bytes_in_use(&self) -> usize {
        self.used_bytes
    }

    /// Spilled pages currently held (the zero-leak accounting surface: a
    /// drained engine must report 0 here, like `pages_in_use` on-device).
    pub fn pages_in_use(&self) -> usize {
        self.entries.values().map(|e| e.pages()).sum()
    }

    pub fn sessions(&self) -> usize {
        self.entries.len()
    }

    pub fn contains(&self, session: u64) -> bool {
        self.entries.contains_key(&session)
    }

    /// Admit one spilled sequence; refuses (returning the entry back) when
    /// it would exceed the byte budget or the tier is disabled.
    pub fn insert(&mut self, session: u64, entry: HostEntry) -> Result<(), HostEntry> {
        let bytes = entry.bytes();
        if self.used_bytes.saturating_add(bytes) > self.cap_bytes {
            return Err(entry);
        }
        self.used_bytes += bytes;
        if let Some(old) = self.entries.insert(session, entry) {
            // a session spilled twice keeps only its latest image
            self.used_bytes -= old.bytes();
        }
        Ok(())
    }

    /// Remove and return a session's spilled image (the restore path).
    pub fn take(&mut self, session: u64) -> Option<HostEntry> {
        let entry = self.entries.remove(&session)?;
        self.used_bytes -= entry.bytes();
        Some(entry)
    }

    /// Drop a session's spilled image, if any — every terminal path
    /// (finish, disconnect, abort, failed) must call this so host pages
    /// never outlive their session.
    pub fn remove(&mut self, session: u64) {
        if let Some(entry) = self.entries.remove(&session) {
            self.used_bytes -= entry.bytes();
        }
    }
}

/// Spill-vs-recompute decision: restoring a spilled image costs
/// `bytes / restore bandwidth`; recomputing it costs
/// `tokens / prefill rate`. Spill wins exactly when the modeled restore is
/// no slower — with packed lanes ~8x smaller than fp32, spill wins at far
/// shorter contexts, which is what makes the host tier a robustness
/// feature of the paper's formats rather than a generic cache. The rates
/// are configuration, not measurements: they keep the decision
/// deterministic and testable.
#[derive(Clone, Copy, Debug)]
pub struct SpillPolicy {
    /// Modeled host-link restore bandwidth, bytes per microsecond.
    pub restore_bytes_per_us: f64,
    /// Modeled chunked-prefill recompute rate, tokens per microsecond.
    pub prefill_tokens_per_us: f64,
}

impl Default for SpillPolicy {
    fn default() -> SpillPolicy {
        // ~16 GiB/s host link vs ~50k tok/s prefill: spill wins whenever a
        // token's KV image is under ~340 KiB, i.e. essentially always for
        // the zoo geometries — recompute remains the escape hatch for
        // hosts with a slow link (set a small restore bandwidth).
        SpillPolicy { restore_bytes_per_us: 16384.0, prefill_tokens_per_us: 0.05 }
    }
}

impl SpillPolicy {
    /// Should a victim holding `bytes` of KV across `tokens` committed
    /// positions spill (true) or be preempted for recompute (false)?
    pub fn spill_wins(&self, bytes: usize, tokens: usize) -> bool {
        if self.restore_bytes_per_us <= 0.0 {
            return false;
        }
        if self.prefill_tokens_per_us <= 0.0 {
            return true;
        }
        let restore_us = bytes as f64 / self.restore_bytes_per_us;
        let recompute_us = tokens as f64 / self.prefill_tokens_per_us;
        restore_us <= recompute_us
    }
}

/// Split each layer's flat pool buffer into page chunks and hand out every
/// page a requested block table names exactly once (`out[i][layer][p]` is
/// the `p`-th page of table `i`) — the borrow-checker-visible disjointness
/// proof behind [`KvCache::slots_mut`], shared by both lane formats. A
/// page named twice (duplicate slot id in the batch, or a corrupt block
/// table) panics.
#[allow(clippy::type_complexity)]
fn carve_pages<'a, T>(
    layers: &'a mut [Vec<T>],
    page_elems: usize,
    tables: &[Vec<PageId>],
) -> Vec<Vec<Vec<&'a mut [T]>>> {
    let mut out: Vec<Vec<Vec<&'a mut [T]>>> =
        (0..tables.len()).map(|_| Vec::with_capacity(layers.len())).collect();
    for layer in layers.iter_mut() {
        let mut pages: Vec<Option<&mut [T]>> = layer.chunks_mut(page_elems).map(Some).collect();
        for (i, table) in tables.iter().enumerate() {
            out[i].push(
                table
                    .iter()
                    .map(|&p| {
                        pages[p].take().expect("duplicate slot id in batch (page handed out twice)")
                    })
                    .collect(),
            );
        }
    }
    out
}

/// The engine-facing name for one borrowed KV lane: `slots_mut` hands the
/// fused batched step one `KvView` per row.
pub type KvView<'a> = SlotView<'a>;

/// Borrowed page slices, `[layer][page-in-table-order]`.
enum ViewLanes<'a> {
    F32 {
        k: Vec<Vec<&'a mut [f32]>>,
        v: Vec<Vec<&'a mut [f32]>>,
    },
    Packed4 {
        fmt: &'a KvFormat,
        k_codes: Vec<Vec<&'a mut [u8]>>,
        k_scales: Vec<Vec<&'a mut [f32]>>,
        v_codes: Vec<Vec<&'a mut [u8]>>,
        v_scales: Vec<Vec<&'a mut [f32]>>,
    },
}

/// Mutable page-walking view of one slot's per-layer K/V lanes (either
/// format). `capacity()` reflects the positions the reserved block table
/// covers, so the forwards' overflow checks see the true headroom.
///
/// `lanes()` builds a fresh page-pointer list per call (the mutable page
/// slices appends need cannot alias a cached immutable copy): a handful
/// of pointer-sized elements, bounded by pages-per-sequence and dwarfed
/// by the tensors each forward step allocates per linear.
pub struct SlotView<'a> {
    lanes: ViewLanes<'a>,
    len: &'a mut usize,
    limit: usize,
    page_rows: usize,
    d: usize,
}

impl KvStore for SlotView<'_> {
    fn len(&self) -> usize {
        *self.len
    }

    fn capacity(&self) -> usize {
        self.limit
    }

    fn append_kv(&mut self, layer: usize, k_row: &[f32], v_row: &[f32]) {
        let (pos, d) = (*self.len, self.d);
        debug_assert!(pos < self.limit, "append past reserved pages");
        assert_eq!(k_row.len(), d);
        assert_eq!(v_row.len(), d);
        let (page, r) = (pos / self.page_rows, pos % self.page_rows);
        match &mut self.lanes {
            ViewLanes::F32 { k, v } => {
                k[layer][page][r * d..(r + 1) * d].copy_from_slice(k_row);
                v[layer][page][r * d..(r + 1) * d].copy_from_slice(v_row);
            }
            ViewLanes::Packed4 { fmt, k_codes, k_scales, v_codes, v_scales } => {
                let (cb, sb) = (fmt.codes_per_row(d), fmt.scales_per_row(d));
                fmt.encode_row(
                    k_row,
                    &mut k_codes[layer][page][r * cb..(r + 1) * cb],
                    &mut k_scales[layer][page][r * sb..(r + 1) * sb],
                );
                fmt.encode_row(
                    v_row,
                    &mut v_codes[layer][page][r * cb..(r + 1) * cb],
                    &mut v_scales[layer][page][r * sb..(r + 1) * sb],
                );
            }
        }
    }

    fn lanes(&self, layer: usize) -> KvLanes<'_> {
        match &self.lanes {
            ViewLanes::F32 { k, v } => KvLanes::PagedF32 {
                k: k[layer].iter().map(|p| &**p).collect(),
                v: v[layer].iter().map(|p| &**p).collect(),
                page_rows: self.page_rows,
            },
            ViewLanes::Packed4 { fmt, k_codes, k_scales, v_codes, v_scales } => {
                KvLanes::PagedPacked4 {
                    k_codes: k_codes[layer].iter().map(|p| &**p).collect(),
                    k_scales: k_scales[layer].iter().map(|p| &**p).collect(),
                    v_codes: v_codes[layer].iter().map(|p| &**p).collect(),
                    v_scales: v_scales[layer].iter().map(|p| &**p).collect(),
                    lut: &fmt.lut,
                    d: self.d,
                    block: fmt.block,
                    page_rows: self.page_rows,
                }
            }
        }
    }

    fn advance(&mut self) {
        *self.len += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats;

    /// 3 block tables over a 4-page pool of 2 positions each: worst case
    /// would need 6 pages (3 slots x capacity 4), so the pool is
    /// deliberately oversubscribed — the layout the paged cache exists for.
    fn geometry() -> KvCacheConfig {
        KvCacheConfig { slots: 3, capacity: 4, n_layers: 2, d_model: 8, page_size: 2, pages: 4 }
    }

    fn small() -> KvCache {
        KvCache::new(geometry())
    }

    fn small_packed() -> KvCache {
        KvCache::new_packed(geometry(), KvFormat::new(&formats::must("sf4"), 4))
    }

    /// Dequantized (or raw) first `rows * d` values of one view's K lane.
    fn k_lane(view: &SlotView<'_>, layer: usize, rows: usize) -> Vec<f32> {
        let mut out = Vec::new();
        match view.lanes(layer) {
            KvLanes::PagedF32 { k, page_rows, .. } => {
                let mut j = 0;
                'walk: for page in k {
                    for r in 0..page_rows {
                        if j == rows {
                            break 'walk;
                        }
                        out.extend_from_slice(&page[r * view.d..(r + 1) * view.d]);
                        j += 1;
                    }
                }
            }
            KvLanes::PagedPacked4 { k_codes, k_scales, lut, d, block, page_rows, .. } => {
                let mut j = 0;
                'walk: for (codes, scales) in k_codes.iter().zip(&k_scales) {
                    for r in 0..page_rows {
                        if j == rows {
                            break 'walk;
                        }
                        for col in 0..d {
                            let byte = codes[r * d / 2 + col / 2];
                            let c = (byte >> (4 * (col % 2))) & 0x0f;
                            out.push(lut[c as usize] * scales[r * (d / block) + col / block]);
                        }
                        j += 1;
                    }
                }
            }
            _ => unreachable!("slot views return paged lanes"),
        }
        assert_eq!(out.len(), rows * view.d, "short block table");
        out
    }

    #[test]
    fn seized_pages_leave_and_rejoin_the_free_list_intact() {
        let mut c = small();
        assert_eq!(c.pages_free(), 4);
        let seized = c.seize_free_pages(3);
        assert_eq!(seized.len(), 3);
        assert_eq!(c.pages_free(), 1);
        assert_eq!(c.pages_in_use(), 3, "seized pages read as pool pressure");
        // seizing more than the pool holds clamps instead of panicking
        let rest = c.seize_free_pages(10);
        assert_eq!(rest.len(), 1);
        assert_eq!(c.pages_free(), 0);
        // a slot under the spike can still allocate (pages arrive on demand)
        // but its first reserve fails until pages return
        let slot = c.allocate().unwrap();
        assert!(!c.try_reserve(slot, 1), "spike exhausts reservations");
        c.return_pages(rest);
        c.return_pages(seized);
        assert_eq!(c.pages_free(), 4);
        assert!(c.free_pages_are_zeroed(), "untouched pages come back zeroed");
        assert!(c.try_reserve(slot, 1), "pool recovers after the spike");
        c.free(slot);
        assert_eq!(c.pages_in_use(), 0);
    }

    #[test]
    fn allocate_free_accounting_slots_and_pages() {
        let mut c = small();
        assert_eq!(c.slots_free(), 3);
        assert_eq!(c.pages_free(), 4);
        assert_eq!(c.pages_in_use(), 0);
        let a = c.allocate().unwrap();
        let b = c.allocate().unwrap();
        assert_ne!(a, b);
        assert_eq!(c.slots_free(), 1);
        // allocation claims no pages until rows are appended
        assert_eq!(c.pages_free(), 4);
        assert!((c.occupancy() - 2.0 / 3.0).abs() < 1e-12);
        {
            let mut view = c.slot(a); // reserves page 1 of slot a
            view.append_kv(0, &[1.0; 8], &[2.0; 8]);
            view.advance();
        }
        assert_eq!(c.pages_in_use(), 1);
        assert_eq!(c.pages_held(a), 1);
        assert_eq!(c.pages_held(b), 0);
        c.free(a);
        assert_eq!(c.slots_free(), 2);
        assert_eq!(c.pages_free(), 4, "freed slot returns its pages");
        let a2 = c.allocate().unwrap();
        assert_eq!(a2, a, "freed slot is immediately reusable");
        assert_eq!(c.len(a2), 0);
    }

    #[test]
    fn pages_grow_on_demand_across_boundaries() {
        let mut c = small();
        let a = c.allocate().unwrap();
        assert!(c.next_append_needs_page(a), "first append needs the first page");
        for pos in 0..4 {
            let mut view = c.slot(a);
            view.append_kv(0, &[pos as f32 + 1.0; 8], &[0.5; 8]);
            view.append_kv(1, &[pos as f32 + 1.0; 8], &[0.5; 8]);
            view.advance();
            // 2-position pages: positions 0-1 on page one, 2-3 on page two
            assert_eq!(c.pages_held(a), pos / 2 + 1, "pos {pos}");
        }
        assert_eq!(c.len(a), 4);
        assert!(!c.next_append_needs_page(a), "at capacity: no further page wanted");
        // all four committed rows survive the page walk, in order
        let view = c.slot(a);
        let lane = k_lane(&view, 0, 4);
        for pos in 0..4 {
            assert!(
                lane[pos * 8..(pos + 1) * 8].iter().all(|&x| x == pos as f32 + 1.0),
                "pos {pos} landed on the wrong page row"
            );
        }
    }

    #[test]
    fn partial_reservation_rolls_back_fully_under_pool_pressure() {
        // regression (mid-reservation kv_page_spike shape): a multi-page
        // reservation that only partially satisfies must return every page
        // it claimed — no leaked claimed pages, pool count restored
        let mut c = small();
        let a = c.allocate().unwrap();
        // the spike's mechanism: seize pages out from under the reservation
        let seized = c.seize_free_pages(3);
        assert_eq!(c.pages_free(), 1);
        // needs 2 pages, pool holds 1: claims one, then must roll it back
        assert!(!c.try_reserve(a, 4), "pool cannot cover the reservation");
        assert_eq!(c.pages_held(a), 0, "half-satisfied reservation leaked a page");
        assert_eq!(c.pages_free(), 1, "claimed page went back to the pool");
        assert!(c.free_pages_are_zeroed(), "rolled-back pages stay zeroed");
        c.return_pages(seized);
        assert!(c.try_reserve(a, 4), "reservation succeeds once the spike lifts");
        assert_eq!(c.pages_held(a), 2);
        c.free(a);
        assert_eq!(c.pages_in_use(), 0);
    }

    #[test]
    fn host_tier_round_trips_every_format_bit_exactly() {
        // spill -> free -> restore must reproduce the device lanes byte for
        // byte, in both lane formats and every packed codebook
        let caches: Vec<(&str, KvCache)> = vec![
            ("fp32", small()),
            ("sf4", KvCache::new_packed(geometry(), KvFormat::new(&formats::must("sf4"), 4))),
            ("nf4", KvCache::new_packed(geometry(), KvFormat::new(&formats::must("nf4"), 4))),
            (
                "e2m1_sp",
                KvCache::new_packed(geometry(), KvFormat::new(&formats::must("e2m1_sp"), 4)),
            ),
        ];
        for (label, mut c) in caches {
            let a = c.allocate().unwrap();
            for pos in 0..3 {
                let mut view = c.slot(a);
                let row: Vec<f32> = (0..8).map(|i| (i as f32 - 3.0) * 0.3 + pos as f32).collect();
                view.append_kv(0, &row, &row);
                view.append_kv(1, &row, &row);
                view.advance();
            }
            let before = k_lane(&c.slot(a), 0, 3);
            let entry = c.export_slot(a);
            assert_eq!(entry.len, 3, "{label}");
            assert_eq!(entry.pages(), 2, "{label}: 3 positions over 2-row pages");
            assert_eq!(entry.bytes(), 2 * c.page_spill_bytes(), "{label}");
            let mut tier = HostTier::new(1 << 20);
            assert!(tier.insert(7, entry).is_ok(), "{label}: fits the budget");
            assert_eq!(tier.sessions(), 1, "{label}");
            assert_eq!(tier.pages_in_use(), 2, "{label}");
            c.free(a);
            assert_eq!(c.pages_in_use(), 0, "{label}");

            let b = c.allocate().unwrap();
            let entry = tier.take(7).expect("entry present");
            assert!(c.restore_slot(b, &entry), "{label}: pool has room");
            assert_eq!(tier.pages_in_use(), 0, "{label}: take() releases host pages");
            assert_eq!(tier.bytes_in_use(), 0, "{label}");
            assert_eq!(c.len(b), 3, "{label}: restored length");
            assert_eq!(c.pages_held(b), 2, "{label}");
            let after = k_lane(&c.slot(b), 0, 3);
            let (ab, bb): (Vec<u32>, Vec<u32>) = (
                before.iter().map(|x| x.to_bits()).collect(),
                after.iter().map(|x| x.to_bits()).collect(),
            );
            assert_eq!(ab, bb, "{label}: restore is not bit-identical");
            c.free(b);
            assert!(c.free_pages_are_zeroed(), "{label}");
        }
    }

    #[test]
    fn host_tier_budget_refuses_and_restore_fails_clean_when_pool_dry() {
        let mut c = small();
        let a = c.allocate().unwrap();
        for _ in 0..3 {
            let mut view = c.slot(a);
            view.append_kv(0, &[1.0; 8], &[1.0; 8]);
            view.advance();
        }
        let entry = c.export_slot(a);
        // budget smaller than the image: refused, entry handed back
        let mut tiny = HostTier::new(entry.bytes() - 1);
        assert!(tiny.enabled());
        let entry = tiny.insert(1, entry).expect_err("over budget");
        assert_eq!(tiny.bytes_in_use(), 0);
        assert!(!HostTier::new(0).enabled(), "zero budget disables the tier");
        c.free(a);
        // restore into a pool too dry to cover the image: false, nothing claimed
        let seized = c.seize_free_pages(3);
        let b = c.allocate().unwrap();
        assert!(!c.restore_slot(b, &entry), "dry pool cannot restore");
        assert_eq!(c.pages_held(b), 0, "failed restore claimed nothing");
        c.return_pages(seized);
        assert!(c.restore_slot(b, &entry), "restore succeeds with the pool back");
        assert_eq!(c.len(b), 3);
    }

    #[test]
    fn pool_exhaustion_fails_reserve_not_panics() {
        let mut c = small();
        let a = c.allocate().unwrap();
        let b = c.allocate().unwrap();
        assert!(c.try_reserve(a, 4), "two pages for a");
        assert!(c.try_reserve(b, 4), "the other two for b");
        assert_eq!(c.pages_free(), 0);
        let x = c.allocate().unwrap();
        assert!(!c.try_reserve(x, 1), "pool dry: reservation reports failure");
        assert_eq!(c.pages_held(x), 0);
        c.free(a);
        assert!(c.try_reserve(x, 1), "freed pages are claimable again");
    }

    #[test]
    #[should_panic(expected = "not in use")]
    fn double_free_panics() {
        let mut c = small();
        let a = c.allocate().unwrap();
        c.free(a);
        c.free(a);
    }

    #[test]
    fn slot_views_are_disjoint_in_both_formats() {
        for mut c in [small(), small_packed()] {
            let a = c.allocate().unwrap();
            let b = c.allocate().unwrap();
            {
                let mut view = c.slot(a);
                view.append_kv(1, &[1.0; 8], &[2.0; 8]);
                view.advance();
            }
            {
                let mut view = c.slot(b);
                view.append_kv(1, &[9.0; 8], &[9.0; 8]);
                view.advance();
            }
            // distinct pages: b's write never lands in a's lane
            let view = c.slot(a);
            assert!(k_lane(&view, 1, 1).iter().all(|&x| x == 1.0), "pages are disjoint");
        }
    }

    #[test]
    fn freed_pages_are_zeroed_in_both_formats() {
        // the reused-page isolation invariant: retiring a session scrubs
        // every page it held, fp32 and packed alike
        for (label, mut c) in [("fp32", small()), ("packed", small_packed())] {
            let a = c.allocate().unwrap();
            for step in 0..3 {
                let mut view = c.slot(a);
                let row = [0.5 + step as f32; 8];
                view.append_kv(0, &row, &row);
                view.append_kv(1, &row, &row);
                view.advance();
            }
            assert_eq!(c.pages_held(a), 2, "{label}");
            c.free(a);
            assert_eq!(c.pages_in_use(), 0, "{label}: free() returns the pages");
            assert!(c.free_pages_are_zeroed(), "{label}: free() must scrub the pages");
            // the next tenant starts from all-zero pages
            let a2 = c.allocate().unwrap();
            {
                // commit one (zero) position so the walk below has a row
                let mut view = c.slot(a2);
                view.advance();
            }
            let view = c.slot(a2);
            assert!(
                k_lane(&view, 0, 1).iter().all(|&x| x == 0.0),
                "{label}: reused page observed a prior session's K/V"
            );
        }
    }

    #[test]
    fn packed_append_round_trips_through_paged_lanes() {
        let mut c = small_packed();
        let a = c.allocate().unwrap();
        let row: Vec<f32> = (0..8).map(|i| (i as f32 - 3.5) * 0.25).collect();
        {
            let mut view = c.slot(a);
            view.append_kv(0, &row, &row);
            view.advance();
        }
        let fmt = c.kv_format().unwrap().clone();
        let mut expect = vec![0.0f32; 8];
        fmt.fake_quant_row(&row, &mut expect);
        let view = c.slot(a);
        assert_eq!(k_lane(&view, 0, 1), expect, "page dequant == codec round trip");
    }

    #[test]
    fn slots_mut_borrows_many_disjoint_views_at_once() {
        let mut c = small();
        let a = c.allocate().unwrap();
        let b = c.allocate().unwrap();
        {
            // both views live at the same time, in request order
            let mut views = c.slots_mut(&[b, a]);
            assert_eq!(views.len(), 2);
            views[0].append_kv(0, &[5.0; 8], &[0.0; 8]);
            views[0].advance();
            match views[1].lanes(0) {
                KvLanes::PagedF32 { k, .. } => {
                    assert!(k.iter().all(|p| p.iter().all(|&x| x == 0.0)), "disjoint")
                }
                _ => unreachable!("fp32 pool"),
            }
            views[1].advance();
        }
        assert_eq!(c.len(b), 1);
        assert_eq!(c.len(a), 1);
        // single-slot view sees what the batched view wrote
        let view = c.slot(b);
        assert!(k_lane(&view, 0, 1).iter().all(|&x| x == 5.0));
    }

    #[test]
    #[should_panic(expected = "duplicate slot id")]
    fn slots_mut_rejects_duplicates() {
        let mut c = small();
        let a = c.allocate().unwrap();
        // give the table a page so the duplicate is visible to the carver
        assert!(c.try_reserve(a, 1));
        c.slots_mut(&[a, a]);
    }

    #[test]
    #[should_panic(expected = "not in use")]
    fn slots_mut_rejects_free_slots() {
        let mut c = small();
        let a = c.allocate().unwrap();
        c.free(a);
        c.slots_mut(&[a]);
    }

    #[test]
    fn view_capacity_tracks_reserved_pages() {
        let mut c = small();
        let a = c.allocate().unwrap();
        {
            let view = c.slot(a); // one page reserved for the first append
            assert_eq!(view.capacity(), 2);
            assert_eq!(view.len(), 0);
        }
        assert!(c.try_reserve(a, 4));
        let view = c.slot(a);
        assert_eq!(view.capacity(), 4, "capped at the sequence capacity");
    }

    #[test]
    fn fragmentation_counts_tail_waste() {
        let mut c = small();
        assert_eq!(c.page_fragmentation(), 0.0, "empty pool: no waste");
        let a = c.allocate().unwrap();
        {
            let mut view = c.slot(a);
            view.append_kv(0, &[1.0; 8], &[1.0; 8]);
            view.advance();
        }
        // 1 live position on one 2-position page
        assert!((c.page_fragmentation() - 0.5).abs() < 1e-12);
        {
            let mut view = c.slot(a);
            view.append_kv(0, &[1.0; 8], &[1.0; 8]);
            view.advance();
        }
        assert_eq!(c.page_fragmentation(), 0.0, "full page: no waste");
    }

    #[test]
    fn bytes_accounting_per_format() {
        let cfg = geometry();
        // 2 (K+V) * 2 layers * (4 pages * 2 pos) * 8 dim * 4 bytes
        assert_eq!(cfg.bytes(), 2 * 2 * (4 * 2) * 8 * 4);
        assert_eq!(cfg.position_bytes_f32(), 2 * 8 * 4);
        let dense = small();
        assert_eq!(dense.bytes(), cfg.bytes());
        assert_eq!(dense.position_bytes(), cfg.position_bytes_f32());
        assert!(dense.kv_format().is_none());
        let packed = small_packed();
        // per position per layer: 2 * (8/2 codes + 2 scales * 4 bytes)
        assert_eq!(packed.position_bytes(), 2 * (4 + 8));
        assert_eq!(packed.bytes(), 2 * (4 * 2) * packed.position_bytes());
        assert!(packed.bytes() < dense.bytes());
        assert_eq!(packed.kv_format().unwrap().name, "sf4");
        // the paged pool is genuinely smaller than the worst case
        assert!(cfg.pool_positions() < cfg.slots * cfg.capacity);
    }

    #[test]
    fn checked_constructor_rejects_absurd_geometries() {
        // the old unchecked `2 * layers * slots * seq * d * 4` wrapped here
        let huge = usize::MAX / 2;
        assert!(KvCacheConfig::try_new(huge, huge, 2, 8, 16, huge).is_err());
        assert!(KvCacheConfig::try_new(4, 1 << 40, 8, 1 << 20, 16, 1 << 40).is_err());
        assert!(KvCacheConfig::try_new(0, 4, 2, 8, 2, 4).is_err(), "degenerate slots");
        assert!(KvCacheConfig::try_new(3, 4, 2, 8, 0, 4).is_err(), "degenerate page");
        let ok = KvCacheConfig::try_new(3, 4, 2, 8, 2, 4).unwrap();
        assert_eq!(ok.bytes(), geometry().bytes());
        assert_eq!(ok.pages_for(0), 0);
        assert_eq!(ok.pages_for(1), 1);
        assert_eq!(ok.pages_for(3), 2);
        assert_eq!(ok.seq_pages(), 2);
    }

    #[test]
    fn for_model_defaults_to_worst_case_pool() {
        let m = crate::model_io::zoo("nano").unwrap();
        let cfg = KvCacheConfig::for_model(&m, 3);
        assert_eq!(cfg.page_size, DEFAULT_PAGE_SIZE.min(m.seq));
        assert_eq!(cfg.pool_positions(), 3 * m.seq.div_ceil(cfg.page_size) * cfg.page_size);
        assert!(cfg.pool_positions() >= 3 * m.seq, "worst case admits every slot full");
    }
}
