//! Slot-pool KV cache: preallocated per-layer key/value storage for a fixed
//! number of concurrent sequences, in either of two lane formats.
//!
//! Each *slot* holds one sequence's cache — per layer, `[capacity, d_model]`
//! fp32 lanes for K and V, **or** packed 4-bit lanes (nibble codes +
//! per-block scales, `quant::KvFormat`) at ~8x less storage — and is handed
//! to the incremental forward through [`SlotView`], which implements
//! [`crate::nn::KvStore`]. The format is chosen once per cache
//! ([`KvCache::new`] vs [`KvCache::new_packed`]); the forwards dispatch on
//! [`crate::nn::KvLanes`], so fp32 pools behave bit-identically to the
//! pre-packed engine.
//!
//! Allocation is a LIFO free list; freeing a retired sequence's slot zeroes
//! its written lanes (a reused slot must never observe a prior session's
//! K/V — defense in depth on top of the `len = 0` reset) and makes it
//! immediately available to the next admitted request (continuous
//! batching). All K/V storage is allocated once at engine start; per-step
//! work allocates only transient [`SlotView`]s.

use crate::model_io::ModelConfig;
use crate::nn::{KvLanes, KvStore};
use crate::quant::KvFormat;

/// Index of one sequence's cache lane.
pub type SlotId = usize;

/// Cache geometry. `capacity` is positions per slot (≤ the model's
/// positional window for the pure-Rust path).
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    pub slots: usize,
    pub capacity: usize,
    pub n_layers: usize,
    pub d_model: usize,
}

impl KvCacheConfig {
    /// Geometry for a zoo model: one slot per concurrent sequence, capacity
    /// equal to the positional window.
    pub fn for_model(cfg: &ModelConfig, slots: usize) -> KvCacheConfig {
        KvCacheConfig { slots, capacity: cfg.seq, n_layers: cfg.n_layers, d_model: cfg.d_model }
    }

    /// Bytes of K+V storage the **fp32** lane format preallocates for this
    /// geometry (packed caches store less — see [`KvCache::bytes`]).
    pub fn bytes(&self) -> usize {
        2 * self.n_layers * self.slots * self.capacity * self.d_model * std::mem::size_of::<f32>()
    }
}

/// Per-layer lane storage, one flat buffer per layer sliced per slot.
enum PoolStore {
    F32 {
        k: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
    },
    Packed4 {
        fmt: KvFormat,
        k_codes: Vec<Vec<u8>>,
        k_scales: Vec<Vec<f32>>,
        v_codes: Vec<Vec<u8>>,
        v_scales: Vec<Vec<f32>>,
    },
}

/// The pool. K and V are stored per layer as one flat buffer each (fp32
/// values, or packed codes + scales), sliced per slot on access.
pub struct KvCache {
    cfg: KvCacheConfig,
    store: PoolStore,
    /// Committed positions per slot.
    lens: Vec<usize>,
    in_use: Vec<bool>,
    free: Vec<SlotId>,
}

impl KvCache {
    /// Dense fp32 lanes (the default; bit-identical to the pre-packed-KV
    /// engine).
    pub fn new(cfg: KvCacheConfig) -> KvCache {
        assert!(cfg.slots > 0 && cfg.capacity > 0, "degenerate cache geometry {cfg:?}");
        let lane = cfg.slots * cfg.capacity * cfg.d_model;
        KvCache {
            store: PoolStore::F32 {
                k: (0..cfg.n_layers).map(|_| vec![0.0; lane]).collect(),
                v: (0..cfg.n_layers).map(|_| vec![0.0; lane]).collect(),
            },
            lens: vec![0; cfg.slots],
            in_use: vec![false; cfg.slots],
            free: (0..cfg.slots).rev().collect(),
            cfg,
        }
    }

    /// Packed 4-bit lanes: K/V rows are quantized on append
    /// (`KvFormat::encode_row`) and dequantized inside the fused attention
    /// kernels — ~8x less cache storage and ~5x less read traffic per
    /// decode step than fp32 lanes.
    pub fn new_packed(cfg: KvCacheConfig, fmt: KvFormat) -> KvCache {
        assert!(cfg.slots > 0 && cfg.capacity > 0, "degenerate cache geometry {cfg:?}");
        assert_eq!(
            cfg.d_model % fmt.block,
            0,
            "KV block {} does not divide d_model {}",
            fmt.block,
            cfg.d_model
        );
        let positions = cfg.slots * cfg.capacity;
        let cb = positions * fmt.codes_per_row(cfg.d_model);
        let sb = positions * fmt.scales_per_row(cfg.d_model);
        KvCache {
            store: PoolStore::Packed4 {
                k_codes: (0..cfg.n_layers).map(|_| vec![0u8; cb]).collect(),
                k_scales: (0..cfg.n_layers).map(|_| vec![0.0f32; sb]).collect(),
                v_codes: (0..cfg.n_layers).map(|_| vec![0u8; cb]).collect(),
                v_scales: (0..cfg.n_layers).map(|_| vec![0.0f32; sb]).collect(),
                fmt,
            },
            lens: vec![0; cfg.slots],
            in_use: vec![false; cfg.slots],
            free: (0..cfg.slots).rev().collect(),
            cfg,
        }
    }

    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }

    /// The packed lane format, if this pool quantizes its cache.
    pub fn kv_format(&self) -> Option<&KvFormat> {
        match &self.store {
            PoolStore::F32 { .. } => None,
            PoolStore::Packed4 { fmt, .. } => Some(fmt),
        }
    }

    /// Bytes one cached position occupies across K+V for **one** layer —
    /// the unit of KV read traffic per attended position per layer.
    pub fn position_bytes(&self) -> usize {
        let d = self.cfg.d_model;
        match &self.store {
            PoolStore::F32 { .. } => 2 * d * 4,
            PoolStore::Packed4 { fmt, .. } => 2 * fmt.row_bytes(d),
        }
    }

    /// Actual bytes of K+V lane storage this pool holds.
    pub fn bytes(&self) -> usize {
        self.cfg.n_layers * self.cfg.slots * self.cfg.capacity * self.position_bytes()
    }

    pub fn capacity(&self) -> usize {
        self.cfg.capacity
    }

    pub fn slots_total(&self) -> usize {
        self.cfg.slots
    }

    pub fn slots_free(&self) -> usize {
        self.free.len()
    }

    pub fn slots_in_use(&self) -> usize {
        self.cfg.slots - self.free.len()
    }

    /// Fraction of slots occupied, in [0, 1].
    pub fn occupancy(&self) -> f64 {
        self.slots_in_use() as f64 / self.cfg.slots as f64
    }

    /// Claim a free slot with an empty cache; `None` when the pool is full.
    pub fn allocate(&mut self) -> Option<SlotId> {
        let slot = self.free.pop()?;
        debug_assert!(!self.in_use[slot]);
        self.in_use[slot] = true;
        self.lens[slot] = 0;
        Some(slot)
    }

    /// Return a slot to the pool, zeroing every lane row the retiring
    /// session wrote (committed positions plus one — a failed batch step
    /// can leave an appended-but-uncommitted row). Reused slots therefore
    /// never observe a prior session's K/V even through a raw-lane bug.
    /// Panics on double-free (an engine bug).
    pub fn free(&mut self, slot: SlotId) {
        assert!(self.in_use[slot], "freeing slot {slot} that is not in use");
        self.clear_slot(slot);
        self.in_use[slot] = false;
        self.free.push(slot);
    }

    /// Zero one slot's written rows in every layer's K and V lanes.
    fn clear_slot(&mut self, slot: SlotId) {
        let rows = (self.lens[slot] + 1).min(self.cfg.capacity);
        let d = self.cfg.d_model;
        match &mut self.store {
            PoolStore::F32 { k, v } => {
                let lane = self.cfg.capacity * d;
                for layer in k.iter_mut().chain(v.iter_mut()) {
                    layer[slot * lane..slot * lane + rows * d].fill(0.0);
                }
            }
            PoolStore::Packed4 { fmt, k_codes, k_scales, v_codes, v_scales } => {
                let (cr, sr) = (fmt.codes_per_row(d), fmt.scales_per_row(d));
                let (clane, slane) = (self.cfg.capacity * cr, self.cfg.capacity * sr);
                for layer in k_codes.iter_mut().chain(v_codes.iter_mut()) {
                    layer[slot * clane..slot * clane + rows * cr].fill(0);
                }
                for layer in k_scales.iter_mut().chain(v_scales.iter_mut()) {
                    layer[slot * slane..slot * slane + rows * sr].fill(0.0);
                }
            }
        }
    }

    /// True when every byte of this slot's K/V lanes is zero — the
    /// invariant [`KvCache::free`] establishes (regression surface for the
    /// reused-slot isolation tests).
    pub fn slot_is_zeroed(&self, slot: SlotId) -> bool {
        let d = self.cfg.d_model;
        match &self.store {
            PoolStore::F32 { k, v } => {
                let lane = self.cfg.capacity * d;
                k.iter().chain(v.iter()).all(|layer| {
                    layer[slot * lane..(slot + 1) * lane].iter().all(|&x| x == 0.0)
                })
            }
            PoolStore::Packed4 { fmt, k_codes, k_scales, v_codes, v_scales } => {
                let clane = self.cfg.capacity * fmt.codes_per_row(d);
                let slane = self.cfg.capacity * fmt.scales_per_row(d);
                k_codes.iter().chain(v_codes.iter()).all(|layer| {
                    layer[slot * clane..(slot + 1) * clane].iter().all(|&x| x == 0)
                }) && k_scales.iter().chain(v_scales.iter()).all(|layer| {
                    layer[slot * slane..(slot + 1) * slane].iter().all(|&x| x == 0.0)
                })
            }
        }
    }

    /// Committed positions in one slot.
    pub fn len(&self, slot: SlotId) -> usize {
        self.lens[slot]
    }

    /// Borrow one slot's lanes as a [`KvStore`] for the incremental forward.
    pub fn slot(&mut self, slot: SlotId) -> SlotView<'_> {
        assert!(self.in_use[slot], "viewing slot {slot} that is not in use");
        self.slots_mut(&[slot]).pop().expect("one view for one id")
    }

    /// Borrow several *distinct* slots' lanes at once — the fused batched
    /// decode step (`nn::forward_lm_step_batch`) needs every row's [`KvStore`]
    /// live simultaneously. Views come back in `ids` order. The disjointness
    /// that makes this sound is proven to the borrow checker by carving each
    /// layer buffer into per-slot chunks and handing each chunk out at most
    /// once; duplicate or not-in-use ids panic (engine bugs).
    pub fn slots_mut(&mut self, ids: &[SlotId]) -> Vec<KvView<'_>> {
        for &id in ids {
            assert!(self.in_use[id], "viewing slot {id} that is not in use");
        }
        let (cfg, d) = (self.cfg, self.cfg.d_model);
        let views: Vec<ViewLanes<'_>> = match &mut self.store {
            PoolStore::F32 { k, v } => {
                let lane = cfg.capacity * d;
                let ks = carve(k, lane, ids);
                let vs = carve(v, lane, ids);
                ks.into_iter()
                    .zip(vs)
                    .map(|(k, v)| ViewLanes::F32 { k, v })
                    .collect()
            }
            PoolStore::Packed4 { fmt, k_codes, k_scales, v_codes, v_scales } => {
                let clane = cfg.capacity * fmt.codes_per_row(d);
                let slane = cfg.capacity * fmt.scales_per_row(d);
                let kc = carve(k_codes, clane, ids);
                let ks = carve(k_scales, slane, ids);
                let vc = carve(v_codes, clane, ids);
                let vs = carve(v_scales, slane, ids);
                let fmt: &KvFormat = fmt;
                kc.into_iter()
                    .zip(ks)
                    .zip(vc.into_iter().zip(vs))
                    .map(|((k_codes, k_scales), (v_codes, v_scales))| ViewLanes::Packed4 {
                        fmt,
                        k_codes,
                        k_scales,
                        v_codes,
                        v_scales,
                    })
                    .collect()
            }
        };
        let mut lens: Vec<Option<&mut usize>> = self.lens.iter_mut().map(Some).collect();
        ids.iter()
            .zip(views)
            .map(|(&id, lanes)| SlotView {
                lanes,
                len: lens[id].take().expect("duplicate slot id in batch"),
                capacity: cfg.capacity,
                d,
            })
            .collect()
    }
}

/// Split each layer's flat buffer into per-slot chunks of `lane` elements
/// and hand out the chunk for every requested id exactly once (duplicate
/// ids panic) — the borrow-checker-visible disjointness proof behind
/// [`KvCache::slots_mut`], shared by both lane formats.
fn carve<'a, T>(layers: &'a mut [Vec<T>], lane: usize, ids: &[SlotId]) -> Vec<Vec<&'a mut [T]>> {
    let mut out: Vec<Vec<&'a mut [T]>> =
        (0..ids.len()).map(|_| Vec::with_capacity(layers.len())).collect();
    for layer in layers.iter_mut() {
        let mut lanes: Vec<Option<&mut [T]>> = layer.chunks_mut(lane).map(Some).collect();
        for (i, &id) in ids.iter().enumerate() {
            out[i].push(lanes[id].take().expect("duplicate slot id in batch"));
        }
    }
    out
}

/// The engine-facing name for one borrowed KV lane: `slots_mut` hands the
/// fused batched step one `KvView` per row.
pub type KvView<'a> = SlotView<'a>;

enum ViewLanes<'a> {
    F32 {
        k: Vec<&'a mut [f32]>,
        v: Vec<&'a mut [f32]>,
    },
    Packed4 {
        fmt: &'a KvFormat,
        k_codes: Vec<&'a mut [u8]>,
        k_scales: Vec<&'a mut [f32]>,
        v_codes: Vec<&'a mut [u8]>,
        v_scales: Vec<&'a mut [f32]>,
    },
}

/// Mutable view of one slot's per-layer K/V lanes (either format).
pub struct SlotView<'a> {
    lanes: ViewLanes<'a>,
    len: &'a mut usize,
    capacity: usize,
    d: usize,
}

impl KvStore for SlotView<'_> {
    fn len(&self) -> usize {
        *self.len
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn append_kv(&mut self, layer: usize, k_row: &[f32], v_row: &[f32]) {
        let (pos, d) = (*self.len, self.d);
        debug_assert!(pos < self.capacity, "append past capacity");
        assert_eq!(k_row.len(), d);
        assert_eq!(v_row.len(), d);
        match &mut self.lanes {
            ViewLanes::F32 { k, v } => {
                k[layer][pos * d..(pos + 1) * d].copy_from_slice(k_row);
                v[layer][pos * d..(pos + 1) * d].copy_from_slice(v_row);
            }
            ViewLanes::Packed4 { fmt, k_codes, k_scales, v_codes, v_scales } => {
                let (cb, sb) = (fmt.codes_per_row(d), fmt.scales_per_row(d));
                fmt.encode_row(
                    k_row,
                    &mut k_codes[layer][pos * cb..(pos + 1) * cb],
                    &mut k_scales[layer][pos * sb..(pos + 1) * sb],
                );
                fmt.encode_row(
                    v_row,
                    &mut v_codes[layer][pos * cb..(pos + 1) * cb],
                    &mut v_scales[layer][pos * sb..(pos + 1) * sb],
                );
            }
        }
    }

    fn lanes(&self, layer: usize) -> KvLanes<'_> {
        match &self.lanes {
            ViewLanes::F32 { k, v } => KvLanes::F32 { k: &*k[layer], v: &*v[layer] },
            ViewLanes::Packed4 { fmt, k_codes, k_scales, v_codes, v_scales } => {
                KvLanes::Packed4 {
                    k: fmt.lane(&*k_codes[layer], &*k_scales[layer], self.d),
                    v: fmt.lane(&*v_codes[layer], &*v_scales[layer], self.d),
                }
            }
        }
    }

    fn advance(&mut self) {
        *self.len += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats;

    fn geometry() -> KvCacheConfig {
        KvCacheConfig { slots: 3, capacity: 4, n_layers: 2, d_model: 8 }
    }

    fn small() -> KvCache {
        KvCache::new(geometry())
    }

    fn small_packed() -> KvCache {
        KvCache::new_packed(geometry(), KvFormat::new(&formats::must("sf4"), 4))
    }

    fn k_lane(view: &SlotView<'_>, layer: usize) -> Vec<f32> {
        match view.lanes(layer) {
            KvLanes::F32 { k, .. } => k.to_vec(),
            KvLanes::Packed4 { k, .. } => {
                let rows = k.codes.len() / (k.d / 2);
                let mut out = vec![0.0f32; rows * k.d];
                for (j, o) in out.iter_mut().enumerate() {
                    let c = (k.codes[j / 2] >> (4 * (j % 2))) & 0x0f;
                    *o = k.lut[c as usize] * k.scales[j / k.block];
                }
                out
            }
        }
    }

    #[test]
    fn allocate_free_accounting() {
        let mut c = small();
        assert_eq!(c.slots_free(), 3);
        assert_eq!(c.slots_in_use(), 0);
        let a = c.allocate().unwrap();
        let b = c.allocate().unwrap();
        assert_ne!(a, b);
        assert_eq!(c.slots_free(), 1);
        assert!((c.occupancy() - 2.0 / 3.0).abs() < 1e-12);
        c.free(a);
        assert_eq!(c.slots_free(), 2);
        // freed slot is immediately reusable
        let a2 = c.allocate().unwrap();
        assert_eq!(a2, a);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut c = small();
        let slots: Vec<_> = (0..3).map(|_| c.allocate().unwrap()).collect();
        assert!(c.allocate().is_none());
        c.free(slots[1]);
        assert!(c.allocate().is_some());
    }

    #[test]
    #[should_panic(expected = "not in use")]
    fn double_free_panics() {
        let mut c = small();
        let a = c.allocate().unwrap();
        c.free(a);
        c.free(a);
    }

    #[test]
    fn reallocation_resets_len() {
        let mut c = small();
        let a = c.allocate().unwrap();
        {
            let mut view = c.slot(a);
            view.append_kv(0, &[7.0; 8], &[1.0; 8]);
            view.advance();
            view.append_kv(0, &[2.0; 8], &[3.0; 8]);
            view.advance();
        }
        assert_eq!(c.len(a), 2);
        c.free(a);
        let a2 = c.allocate().unwrap();
        assert_eq!(a2, a);
        assert_eq!(c.len(a2), 0, "reallocated slot must start empty");
    }

    #[test]
    fn slot_views_are_disjoint_in_both_formats() {
        for mut c in [small(), small_packed()] {
            let a = c.allocate().unwrap();
            let b = c.allocate().unwrap();
            {
                let mut view = c.slot(a);
                view.append_kv(1, &[1.0; 8], &[2.0; 8]);
                view.advance();
            }
            let view = c.slot(b);
            assert!(k_lane(&view, 1).iter().all(|&x| x == 0.0), "lanes are disjoint");
        }
    }

    #[test]
    fn freed_slot_lanes_are_zeroed_in_both_formats() {
        // the reused-slot isolation invariant: retiring a session scrubs
        // every K/V row it wrote, fp32 and packed alike
        for (label, mut c) in [("fp32", small()), ("packed", small_packed())] {
            let a = c.allocate().unwrap();
            {
                let mut view = c.slot(a);
                for step in 0..3 {
                    let row = [0.5 + step as f32; 8];
                    view.append_kv(0, &row, &row);
                    view.append_kv(1, &row, &row);
                    view.advance();
                }
            }
            assert!(!c.slot_is_zeroed(a), "{label}: lanes hold live data before free");
            c.free(a);
            assert!(c.slot_is_zeroed(a), "{label}: free() must scrub the lanes");
            // the next tenant starts from an all-zero slot
            let a2 = c.allocate().unwrap();
            assert_eq!(a2, a);
            let view = c.slot(a2);
            assert!(
                k_lane(&view, 0).iter().all(|&x| x == 0.0),
                "{label}: reused slot observed a prior session's K/V"
            );
        }
    }

    #[test]
    fn packed_append_round_trips_through_lanes() {
        let mut c = small_packed();
        let a = c.allocate().unwrap();
        let row: Vec<f32> = (0..8).map(|i| (i as f32 - 3.5) * 0.25).collect();
        {
            let mut view = c.slot(a);
            view.append_kv(0, &row, &row);
            view.advance();
        }
        let fmt = c.kv_format().unwrap().clone();
        let mut expect = vec![0.0f32; 8];
        fmt.fake_quant_row(&row, &mut expect);
        let view = c.slot(a);
        assert_eq!(&k_lane(&view, 0)[..8], &expect[..], "lane dequant == codec round trip");
    }

    #[test]
    fn slots_mut_borrows_many_disjoint_views_at_once() {
        let mut c = small();
        let a = c.allocate().unwrap();
        let b = c.allocate().unwrap();
        {
            // both views live at the same time, in request order
            let mut views = c.slots_mut(&[b, a]);
            assert_eq!(views.len(), 2);
            views[0].append_kv(0, &[5.0; 8], &[0.0; 8]);
            views[0].advance();
            match views[1].lanes(0) {
                KvLanes::F32 { k, .. } => assert!(k.iter().all(|&x| x == 0.0), "disjoint"),
                _ => unreachable!("fp32 pool"),
            }
            views[1].advance();
            views[1].advance();
        }
        assert_eq!(c.len(b), 1);
        assert_eq!(c.len(a), 2);
        // single-slot view sees what the batched view wrote
        let view = c.slot(b);
        assert!(k_lane(&view, 0)[..8].iter().all(|&x| x == 5.0));
    }

    #[test]
    #[should_panic(expected = "duplicate slot id")]
    fn slots_mut_rejects_duplicates() {
        let mut c = small();
        let a = c.allocate().unwrap();
        c.slots_mut(&[a, a]);
    }

    #[test]
    #[should_panic(expected = "not in use")]
    fn slots_mut_rejects_free_slots() {
        let mut c = small();
        let a = c.allocate().unwrap();
        c.free(a);
        c.slots_mut(&[a]);
    }

    #[test]
    fn bytes_accounting_per_format() {
        let cfg = geometry();
        // 2 (K+V) * 2 layers * 3 slots * 4 pos * 8 dim * 4 bytes
        assert_eq!(cfg.bytes(), 2 * 2 * 3 * 4 * 8 * 4);
        let dense = small();
        assert_eq!(dense.bytes(), cfg.bytes());
        assert_eq!(dense.position_bytes(), 2 * 8 * 4);
        assert!(dense.kv_format().is_none());
        let packed = small_packed();
        // per position per layer: 2 * (8/2 codes + 2 scales * 4 bytes)
        assert_eq!(packed.position_bytes(), 2 * (4 + 8));
        assert_eq!(packed.bytes(), 2 * 3 * 4 * packed.position_bytes());
        assert!(packed.bytes() < dense.bytes());
        assert_eq!(packed.kv_format().unwrap().name, "sf4");
    }
}
