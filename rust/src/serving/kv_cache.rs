//! Slot-pool KV cache: preallocated per-layer key/value storage for a fixed
//! number of concurrent sequences.
//!
//! Each *slot* holds one sequence's cache — `[capacity, d_model]` per layer
//! for K and again for V — and is handed to the incremental forward through
//! [`SlotView`], which implements [`crate::nn::KvStore`]. Allocation is a
//! LIFO free list; freeing a retired sequence's slot makes it immediately
//! available to the next admitted request (continuous batching). All K/V
//! storage is allocated once at engine start; per-step work allocates only
//! transient [`SlotView`]s (two `n_layers`-sized slice vectors per borrow).

use crate::model_io::ModelConfig;
use crate::nn::KvStore;

/// Index of one sequence's cache lane.
pub type SlotId = usize;

/// Cache geometry. `capacity` is positions per slot (≤ the model's
/// positional window for the pure-Rust path).
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    pub slots: usize,
    pub capacity: usize,
    pub n_layers: usize,
    pub d_model: usize,
}

impl KvCacheConfig {
    /// Geometry for a zoo model: one slot per concurrent sequence, capacity
    /// equal to the positional window.
    pub fn for_model(cfg: &ModelConfig, slots: usize) -> KvCacheConfig {
        KvCacheConfig { slots, capacity: cfg.seq, n_layers: cfg.n_layers, d_model: cfg.d_model }
    }

    /// Total bytes of K+V storage this geometry preallocates.
    pub fn bytes(&self) -> usize {
        2 * self.n_layers * self.slots * self.capacity * self.d_model * std::mem::size_of::<f32>()
    }
}

/// The pool. K and V are stored per layer as one flat `[slots * capacity *
/// d_model]` buffer each, sliced per slot on access.
pub struct KvCache {
    cfg: KvCacheConfig,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Committed positions per slot.
    lens: Vec<usize>,
    in_use: Vec<bool>,
    free: Vec<SlotId>,
}

impl KvCache {
    pub fn new(cfg: KvCacheConfig) -> KvCache {
        assert!(cfg.slots > 0 && cfg.capacity > 0, "degenerate cache geometry {cfg:?}");
        let lane = cfg.slots * cfg.capacity * cfg.d_model;
        KvCache {
            k: (0..cfg.n_layers).map(|_| vec![0.0; lane]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0.0; lane]).collect(),
            lens: vec![0; cfg.slots],
            in_use: vec![false; cfg.slots],
            free: (0..cfg.slots).rev().collect(),
            cfg,
        }
    }

    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }

    pub fn capacity(&self) -> usize {
        self.cfg.capacity
    }

    pub fn slots_total(&self) -> usize {
        self.cfg.slots
    }

    pub fn slots_free(&self) -> usize {
        self.free.len()
    }

    pub fn slots_in_use(&self) -> usize {
        self.cfg.slots - self.free.len()
    }

    /// Fraction of slots occupied, in [0, 1].
    pub fn occupancy(&self) -> f64 {
        self.slots_in_use() as f64 / self.cfg.slots as f64
    }

    /// Claim a free slot with an empty cache; `None` when the pool is full.
    pub fn allocate(&mut self) -> Option<SlotId> {
        let slot = self.free.pop()?;
        debug_assert!(!self.in_use[slot]);
        self.in_use[slot] = true;
        self.lens[slot] = 0;
        Some(slot)
    }

    /// Return a slot to the pool. Panics on double-free (an engine bug).
    pub fn free(&mut self, slot: SlotId) {
        assert!(self.in_use[slot], "freeing slot {slot} that is not in use");
        self.in_use[slot] = false;
        self.free.push(slot);
    }

    /// Committed positions in one slot.
    pub fn len(&self, slot: SlotId) -> usize {
        self.lens[slot]
    }

    /// Borrow one slot's lanes as a [`KvStore`] for the incremental forward.
    pub fn slot(&mut self, slot: SlotId) -> SlotView<'_> {
        assert!(self.in_use[slot], "viewing slot {slot} that is not in use");
        let lane = self.cfg.capacity * self.cfg.d_model;
        let base = slot * lane;
        SlotView {
            k: self.k.iter_mut().map(|l| &mut l[base..base + lane]).collect(),
            v: self.v.iter_mut().map(|l| &mut l[base..base + lane]).collect(),
            len: &mut self.lens[slot],
            capacity: self.cfg.capacity,
        }
    }

    /// Borrow several *distinct* slots' lanes at once — the fused batched
    /// decode step (`nn::forward_lm_step_batch`) needs every row's [`KvStore`]
    /// live simultaneously. Views come back in `ids` order. The disjointness
    /// that makes this sound is proven to the borrow checker by carving each
    /// layer buffer into per-slot chunks and handing each chunk out at most
    /// once; duplicate or not-in-use ids panic (engine bugs).
    pub fn slots_mut(&mut self, ids: &[SlotId]) -> Vec<KvView<'_>> {
        for &id in ids {
            assert!(self.in_use[id], "viewing slot {id} that is not in use");
        }
        let lane = self.cfg.capacity * self.cfg.d_model;
        let mut ks: Vec<Vec<&mut [f32]>> =
            (0..ids.len()).map(|_| Vec::with_capacity(self.cfg.n_layers)).collect();
        let mut vs: Vec<Vec<&mut [f32]>> =
            (0..ids.len()).map(|_| Vec::with_capacity(self.cfg.n_layers)).collect();
        for layer in self.k.iter_mut() {
            let mut lanes: Vec<Option<&mut [f32]>> = layer.chunks_mut(lane).map(Some).collect();
            for (i, &id) in ids.iter().enumerate() {
                ks[i].push(lanes[id].take().expect("duplicate slot id in batch"));
            }
        }
        for layer in self.v.iter_mut() {
            let mut lanes: Vec<Option<&mut [f32]>> = layer.chunks_mut(lane).map(Some).collect();
            for (i, &id) in ids.iter().enumerate() {
                vs[i].push(lanes[id].take().expect("duplicate slot id in batch"));
            }
        }
        let capacity = self.cfg.capacity;
        let mut lens: Vec<Option<&mut usize>> = self.lens.iter_mut().map(Some).collect();
        ks.into_iter()
            .zip(vs)
            .zip(ids)
            .map(|((k, v), &id)| SlotView {
                k,
                v,
                len: lens[id].take().expect("duplicate slot id in batch"),
                capacity,
            })
            .collect()
    }
}

/// The engine-facing name for one borrowed KV lane: `slots_mut` hands the
/// fused batched step one `KvView` per row.
pub type KvView<'a> = SlotView<'a>;

/// Mutable view of one slot's per-layer K/V lanes.
pub struct SlotView<'a> {
    k: Vec<&'a mut [f32]>,
    v: Vec<&'a mut [f32]>,
    len: &'a mut usize,
    capacity: usize,
}

impl KvStore for SlotView<'_> {
    fn len(&self) -> usize {
        *self.len
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn kv_mut(&mut self, layer: usize) -> (&mut [f32], &mut [f32]) {
        (&mut *self.k[layer], &mut *self.v[layer])
    }

    fn advance(&mut self) {
        *self.len += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> KvCache {
        KvCache::new(KvCacheConfig { slots: 3, capacity: 4, n_layers: 2, d_model: 8 })
    }

    #[test]
    fn allocate_free_accounting() {
        let mut c = small();
        assert_eq!(c.slots_free(), 3);
        assert_eq!(c.slots_in_use(), 0);
        let a = c.allocate().unwrap();
        let b = c.allocate().unwrap();
        assert_ne!(a, b);
        assert_eq!(c.slots_free(), 1);
        assert!((c.occupancy() - 2.0 / 3.0).abs() < 1e-12);
        c.free(a);
        assert_eq!(c.slots_free(), 2);
        // freed slot is immediately reusable
        let a2 = c.allocate().unwrap();
        assert_eq!(a2, a);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut c = small();
        let slots: Vec<_> = (0..3).map(|_| c.allocate().unwrap()).collect();
        assert!(c.allocate().is_none());
        c.free(slots[1]);
        assert!(c.allocate().is_some());
    }

    #[test]
    #[should_panic(expected = "not in use")]
    fn double_free_panics() {
        let mut c = small();
        let a = c.allocate().unwrap();
        c.free(a);
        c.free(a);
    }

    #[test]
    fn reallocation_resets_len() {
        let mut c = small();
        let a = c.allocate().unwrap();
        {
            let mut view = c.slot(a);
            let (k, _) = view.kv_mut(0);
            k[0] = 7.0;
            view.advance();
            view.advance();
        }
        assert_eq!(c.len(a), 2);
        c.free(a);
        let a2 = c.allocate().unwrap();
        assert_eq!(a2, a);
        assert_eq!(c.len(a2), 0, "reallocated slot must start empty");
    }

    #[test]
    fn slot_views_are_disjoint() {
        let mut c = small();
        let a = c.allocate().unwrap();
        let b = c.allocate().unwrap();
        {
            let mut view = c.slot(a);
            let (k, v) = view.kv_mut(1);
            k.fill(1.0);
            v.fill(2.0);
        }
        let mut view = c.slot(b);
        let (k, v) = view.kv_mut(1);
        assert!(k.iter().all(|&x| x == 0.0));
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn slots_mut_borrows_many_disjoint_views_at_once() {
        let mut c = small();
        let a = c.allocate().unwrap();
        let b = c.allocate().unwrap();
        {
            // both views live at the same time, in request order
            let mut views = c.slots_mut(&[b, a]);
            assert_eq!(views.len(), 2);
            let (kb, _) = views[0].kv_mut(0);
            kb.fill(5.0);
            views[0].advance();
            let (ka, _) = views[1].kv_mut(0);
            assert!(ka.iter().all(|&x| x == 0.0), "lanes are disjoint");
            views[1].advance();
            views[1].advance();
        }
        assert_eq!(c.len(b), 1);
        assert_eq!(c.len(a), 2);
        // single-slot view sees what the batched view wrote
        let mut view = c.slot(b);
        let (kb, _) = view.kv_mut(0);
        assert!(kb.iter().all(|&x| x == 5.0));
    }

    #[test]
    #[should_panic(expected = "duplicate slot id")]
    fn slots_mut_rejects_duplicates() {
        let mut c = small();
        let a = c.allocate().unwrap();
        c.slots_mut(&[a, a]);
    }

    #[test]
    #[should_panic(expected = "not in use")]
    fn slots_mut_rejects_free_slots() {
        let mut c = small();
        let a = c.allocate().unwrap();
        c.free(a);
        c.slots_mut(&[a]);
    }

    #[test]
    fn bytes_accounting() {
        let cfg = KvCacheConfig { slots: 3, capacity: 4, n_layers: 2, d_model: 8 };
        // 2 (K+V) * 2 layers * 3 slots * 4 pos * 8 dim * 4 bytes
        assert_eq!(cfg.bytes(), 2 * 2 * 3 * 4 * 8 * 4);
    }
}
