//! HTTP/1.1 serving front end over the decode [`Engine`] — the network edge
//! that turns the in-process streaming API into a wire protocol.
//!
//! Architecture: a `std::net::TcpListener` accept loop, one thread per
//! connection (the engine itself is the concurrency limiter — connections
//! mostly block on their event channel), and the engine on its own thread
//! driven by [`Engine::run_with`]. Each `POST /generate` becomes one
//! [`DecodeRequest`]; generated tokens stream back as newline-delimited
//! JSON over chunked transfer encoding the moment they decode.
//!
//! Wire format (deliberately minimal — token ids in, token ids out; no
//! tokenizer lives in this repo):
//!
//! ```text
//! POST /generate
//! {"prompt":[1,2,3],"max_new_tokens":8,"eos":5}        (eos optional)
//!
//! 200 OK, Transfer-Encoding: chunked, one JSON line per chunk:
//! {"token":17,"index":0,"logprob":-2.1875}
//! ...
//! {"done":true,"reason":"max_tokens","generated":8}
//! ```
//!
//! Robustness surface, not just the happy path:
//!
//! * **Backpressure**: the engine runs with
//!   [`SchedulerConfig::reject_saturated`], so a full admission queue or a
//!   saturated KV page pool answers `429` with a `Retry-After` header
//!   instead of queuing unboundedly. All admission decisions stay in
//!   [`Engine::submit`] — the front end only translates the terminal
//!   `Rejected` event, so the engine's `rejected` metric counts every 429.
//! * **Timeouts**: per-connection read/write timeouts bound how long a
//!   slow or stalled peer can hold a connection thread.
//! * **Disconnects**: a failed chunk write drops the event receiver; the
//!   engine notices the dead channel at its next token and retires the
//!   session as [`crate::serving::FinishReason::Disconnected`], freeing
//!   its KV pages.
//! * **Graceful drain**: [`HttpServer::shutdown`] (or `POST /shutdown`)
//!   stops accepting, lets in-flight streams finish, joins every
//!   connection thread, then closes the request channel so the engine
//!   drains and returns its final [`MetricsReport`].
//!
//! `GET /metrics` serves the engine's Prometheus registry (snapshotted by
//! the engine thread itself — no shared mutable engine) plus the front
//! end's own `llmdt_http_*` series; `GET /healthz` answers liveness.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::faults;
use crate::obs::export::prometheus_text;
use crate::obs::metrics::Registry;
use crate::obs::trace;
use crate::serving::{DecodeRequest, Engine, FinishReason, MetricsReport, TokenEvent};

/// Front-end knobs. `addr` may use port 0 to bind an ephemeral port
/// (tests/benches); [`HttpServer::addr`] reports the bound address.
#[derive(Clone, Debug)]
pub struct HttpConfig {
    pub addr: String,
    /// Bound on reading one request head + body from a peer.
    pub read_timeout: Duration,
    /// Bound on each response write (a stalled reader cannot pin a
    /// connection thread past this).
    pub write_timeout: Duration,
    /// Minimum seconds advertised in `Retry-After` on 429/503 answers.
    /// The advertised value is **derived per answer** from queue depth and
    /// KV page/spill pressure, staggered across consecutive rejects so one
    /// overload burst does not synchronize every client's retry into a
    /// second wave, and clamped to `[retry_after_secs, retry_after_cap]`.
    pub retry_after_secs: u64,
    /// Upper clamp on the derived `Retry-After` (see `retry_after_secs`).
    pub retry_after_cap: u64,
    /// Largest accepted request body.
    pub max_body: usize,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            addr: "127.0.0.1:0".into(),
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            retry_after_secs: 1,
            retry_after_cap: 8,
            max_body: 1 << 20,
        }
    }
}

/// Counters shared between connection threads and the `/metrics` route.
struct Shared {
    connections: AtomicU64,
    requests: AtomicU64,
    streams_completed: AtomicU64,
    rejected_429: AtomicU64,
    bad_requests: AtomicU64,
    disconnects: AtomicU64,
    tokens_streamed: AtomicU64,
    active_connections: AtomicU64,
    /// Times the engine thread's supervisor caught a panic out of the
    /// serving loop and re-entered it on the same request channel.
    engine_restarts: AtomicU64,
    /// Engine pressure gauges, published by the engine thread's observer
    /// each loop iteration; connection threads read them to derive
    /// per-answer `Retry-After` values (never touching the engine).
    queue_depth: AtomicU64,
    pages_free: AtomicU64,
    pages_total: AtomicU64,
    /// Monotone sequence over derived `Retry-After` answers: consecutive
    /// rejects land on different values, de-synchronizing the retry wave.
    retry_seq: AtomicU64,
    draining: AtomicBool,
    /// Prometheus text of the engine registry, re-rendered by the engine
    /// thread's `run_with` observer (the engine is never shared mutably).
    engine_metrics: Mutex<String>,
}

impl Shared {
    fn new() -> Shared {
        Shared {
            connections: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            streams_completed: AtomicU64::new(0),
            rejected_429: AtomicU64::new(0),
            bad_requests: AtomicU64::new(0),
            disconnects: AtomicU64::new(0),
            tokens_streamed: AtomicU64::new(0),
            active_connections: AtomicU64::new(0),
            engine_restarts: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            pages_free: AtomicU64::new(0),
            pages_total: AtomicU64::new(0),
            retry_seq: AtomicU64::new(0),
            draining: AtomicBool::new(false),
            engine_metrics: Mutex::new(String::new()),
        }
    }

    /// Derive one `Retry-After` answer from the published engine pressure:
    /// the base grows by one second per four queued requests plus a 0–2 s
    /// page-pressure bucket (pool quarter-full headroom / pool dry), then a
    /// rotating 0–2 s stagger spreads consecutive rejects apart so the
    /// overload's retry wave lands spread out instead of as one burst. The
    /// result is clamped to `[retry_after_secs, retry_after_cap]`.
    fn retry_secs(&self, cfg: &HttpConfig) -> u64 {
        let base = cfg.retry_after_secs.max(1);
        let cap = cfg.retry_after_cap.max(base);
        let queue = self.queue_depth.load(Ordering::Relaxed);
        let free = self.pages_free.load(Ordering::Relaxed);
        let total = self.pages_total.load(Ordering::Relaxed);
        let pressure = if total == 0 {
            0
        } else if free == 0 {
            2
        } else if free * 4 <= total {
            1
        } else {
            0
        };
        let load = (base + queue / 4 + pressure).min(cap.saturating_sub(2)).max(base);
        let stagger = self.retry_seq.fetch_add(1, Ordering::Relaxed) % 3;
        (load + stagger).clamp(base, cap)
    }

    fn registry(&self) -> Registry {
        let mut reg = Registry::new();
        reg.counter(
            "llmdt_http_connections_total",
            "TCP connections accepted.",
            self.connections.load(Ordering::Relaxed),
        );
        reg.counter(
            "llmdt_http_requests_total",
            "HTTP requests parsed.",
            self.requests.load(Ordering::Relaxed),
        );
        reg.counter(
            "llmdt_http_streams_completed_total",
            "Generate streams that reached their terminal chunk.",
            self.streams_completed.load(Ordering::Relaxed),
        );
        reg.counter(
            "llmdt_http_rejected_total",
            "Requests answered 429 under backpressure.",
            self.rejected_429.load(Ordering::Relaxed),
        );
        reg.counter(
            "llmdt_http_bad_requests_total",
            "Requests answered 4xx for malformed input or unknown routes.",
            self.bad_requests.load(Ordering::Relaxed),
        );
        reg.counter(
            "llmdt_http_disconnects_total",
            "Streams cut short by the client going away.",
            self.disconnects.load(Ordering::Relaxed),
        );
        reg.counter(
            "llmdt_http_tokens_streamed_total",
            "Token chunks written to clients.",
            self.tokens_streamed.load(Ordering::Relaxed),
        );
        reg.counter(
            "llmdt_http_engine_restarts_total",
            "Engine-thread panics caught by the supervisor and restarted.",
            self.engine_restarts.load(Ordering::Relaxed),
        );
        reg.gauge(
            "llmdt_http_active_connections",
            "Connections currently being served.",
            self.active_connections.load(Ordering::Relaxed) as f64,
        );
        reg.gauge(
            "llmdt_http_draining",
            "1 while the server refuses new work and drains in-flight streams.",
            if self.draining.load(Ordering::SeqCst) { 1.0 } else { 0.0 },
        );
        reg
    }
}

/// Front-end counter snapshot (tests and the CLI banner).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HttpStats {
    pub connections: u64,
    pub requests: u64,
    pub streams_completed: u64,
    pub rejected_429: u64,
    pub bad_requests: u64,
    pub disconnects: u64,
    pub tokens_streamed: u64,
    pub engine_restarts: u64,
}

/// A running HTTP front end. Dropping the handle does **not** stop the
/// server; call [`HttpServer::shutdown`] (or `POST /shutdown`, then
/// [`HttpServer::wait`]).
pub struct HttpServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: JoinHandle<()>,
    engine: JoinHandle<(Result<MetricsReport>, Engine)>,
}

/// Everything a drained server hands back: the engine's final report, the
/// engine itself (tests inspect its KV cache), and the front end's final
/// counters (read after every connection thread joined — no races).
pub struct ServerExit {
    pub report: Result<MetricsReport>,
    pub engine: Engine,
    pub http: HttpStats,
}

impl HttpServer {
    /// The bound listen address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Current front-end counters.
    pub fn stats(&self) -> HttpStats {
        snapshot(&self.shared)
    }

    /// Begin a graceful drain: stop accepting new connections and refuse
    /// new `/generate` work with 503; in-flight streams keep decoding.
    /// Idempotent. Follow with [`HttpServer::wait`].
    pub fn initiate_drain(&self) {
        initiate_drain(&self.shared, self.addr);
    }

    /// Join the accept loop (which joins every connection thread, then
    /// closes the request channel) and the engine thread. Blocks until a
    /// drain was initiated — by [`HttpServer::initiate_drain`] or a
    /// client's `POST /shutdown`.
    pub fn wait(self) -> ServerExit {
        let HttpServer { shared, accept, engine, .. } = self;
        // construct-time invariant, not a serving-path risk: the accept
        // loop only joins connection threads (whose panics it swallows via
        // `let _ = h.join()`), and the engine thread's supervisor catches
        // serving-loop panics and restarts — so these expects fire only on
        // a bug in the supervisor/accept scaffolding itself
        accept.join().expect("http accept thread panicked");
        let http = snapshot(&shared);
        let (report, engine) = engine.join().expect("engine thread panicked");
        ServerExit { report, engine, http }
    }

    /// [`HttpServer::initiate_drain`] + [`HttpServer::wait`].
    pub fn shutdown(self) -> ServerExit {
        self.initiate_drain();
        self.wait()
    }
}

fn snapshot(s: &Shared) -> HttpStats {
    HttpStats {
        connections: s.connections.load(Ordering::Relaxed),
        requests: s.requests.load(Ordering::Relaxed),
        streams_completed: s.streams_completed.load(Ordering::Relaxed),
        rejected_429: s.rejected_429.load(Ordering::Relaxed),
        bad_requests: s.bad_requests.load(Ordering::Relaxed),
        disconnects: s.disconnects.load(Ordering::Relaxed),
        tokens_streamed: s.tokens_streamed.load(Ordering::Relaxed),
        engine_restarts: s.engine_restarts.load(Ordering::Relaxed),
    }
}

/// The engine-metrics snapshot lock never stays poisoned: a panic while
/// holding it (worst case: mid-String-assign, which cannot tear) must not
/// take `/metrics` down with it.
fn lock_metrics(m: &Mutex<String>) -> std::sync::MutexGuard<'_, String> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn initiate_drain(shared: &Shared, addr: SocketAddr) {
    shared.draining.store(true, Ordering::SeqCst);
    // unblock the accept loop: it re-checks the flag per connection
    let _ = TcpStream::connect(addr);
}

/// Start serving `engine` on `cfg.addr`. The engine must have been built
/// with the backpressure posture the front end promises — callers normally
/// set [`SchedulerConfig::reject_saturated`] and a bounded `max_queue`.
pub fn serve(mut engine: Engine, cfg: HttpConfig) -> Result<HttpServer> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let shared = Arc::new(Shared::new());
    let (tx, rx) = mpsc::channel::<DecodeRequest>();

    let engine_shared = shared.clone();
    let engine_thread = std::thread::spawn(move || {
        let mut ticks = 0u64;
        // the supervisor: a panic that unwinds out of the serving loop (an
        // engine bug, or an injected engine_step_panic) retires every
        // in-flight session with a terminal event, then re-enters the loop
        // on the same receiver — queued requests and connected clients
        // survive the restart; only the sessions that were mid-forward see
        // a Finished(Failed).
        loop {
            let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
                engine.run_with(&rx, |eng| {
                    // pressure gauges feed the derived Retry-After; cheap
                    // enough to publish every iteration
                    engine_shared.queue_depth.store(eng.queue_len() as u64, Ordering::Relaxed);
                    engine_shared
                        .pages_free
                        .store(eng.cache().pages_free() as u64, Ordering::Relaxed);
                    engine_shared
                        .pages_total
                        .store(eng.cache().pages_total() as u64, Ordering::Relaxed);
                    // re-render the /metrics snapshot when idle and every
                    // 16th iteration while busy (cheap but not free)
                    if ticks % 16 == 0 || !eng.has_work() {
                        let text = prometheus_text(&eng.metrics_registry());
                        *lock_metrics(&engine_shared.engine_metrics) = text;
                    }
                    ticks += 1;
                })
            }));
            match res {
                Ok(res) => {
                    if res.is_err() {
                        // terminal events for everything in flight so no
                        // connection thread hangs on its event channel
                        engine.abort();
                    }
                    return (res, engine);
                }
                Err(_) => {
                    engine.recover_after_panic();
                    engine_shared.engine_restarts.fetch_add(1, Ordering::Relaxed);
                    let text = prometheus_text(&engine.metrics_registry());
                    *lock_metrics(&engine_shared.engine_metrics) = text;
                }
            }
        }
    });

    let accept_shared = shared.clone();
    let accept_cfg = cfg.clone();
    let accept = std::thread::spawn(move || {
        let mut conns: Vec<JoinHandle<()>> = Vec::new();
        for stream in listener.incoming() {
            if accept_shared.draining.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            accept_shared.connections.fetch_add(1, Ordering::Relaxed);
            let tx = tx.clone();
            let shared = accept_shared.clone();
            let cfg = accept_cfg.clone();
            conns.retain(|h| !h.is_finished());
            conns.push(std::thread::spawn(move || handle_connection(stream, tx, shared, cfg)));
        }
        // refuse new connections immediately (drain means "stop taking
        // work", not "hang new clients until in-flight streams finish")
        drop(listener);
        // close our sender next: the engine keeps running while any
        // connection thread still holds a clone for its in-flight stream
        drop(tx);
        for h in conns {
            let _ = h.join();
        }
    });

    Ok(HttpServer { addr, shared, accept, engine: engine_thread })
}

// ---------------------------------------------------------------------------
// connection handling

fn handle_connection(
    stream: TcpStream,
    tx: mpsc::Sender<DecodeRequest>,
    shared: Arc<Shared>,
    cfg: HttpConfig,
) {
    shared.active_connections.fetch_add(1, Ordering::Relaxed);
    let t0 = trace::start();
    let _ = stream.set_read_timeout(Some(cfg.read_timeout));
    let _ = stream.set_write_timeout(Some(cfg.write_timeout));
    let _ = stream.set_nodelay(true);
    let status = handle_request(&stream, &tx, &shared, &cfg);
    shared.active_connections.fetch_sub(1, Ordering::Relaxed);
    if let Some(t0) = t0 {
        trace::complete_here("http", "http.request", t0, &[("status", status as f64)]);
    }
}

/// One request per connection (`Connection: close`); returns the response
/// status for the connection span.
fn handle_request(
    mut stream: &TcpStream,
    tx: &mpsc::Sender<DecodeRequest>,
    shared: &Shared,
    cfg: &HttpConfig,
) -> u16 {
    let head = match read_head(&mut stream) {
        Ok(h) => h,
        Err(_) => {
            shared.bad_requests.fetch_add(1, Ordering::Relaxed);
            let _ = respond(stream, 400, "Bad Request", &[], "malformed request head\n");
            return 400;
        }
    };
    shared.requests.fetch_add(1, Ordering::Relaxed);
    match (head.method.as_str(), head.path.as_str()) {
        ("GET", "/healthz") => {
            let _ = respond(stream, 200, "OK", &[], "ok\n");
            200
        }
        ("GET", "/metrics") => {
            let engine_text = lock_metrics(&shared.engine_metrics).clone();
            let body = format!("{engine_text}{}", prometheus_text(&shared.registry()));
            let _ = respond(
                stream,
                200,
                "OK",
                &[("Content-Type", "text/plain; version=0.0.4")],
                &body,
            );
            200
        }
        ("POST", "/shutdown") => {
            // answer first: the accept loop (and this listener) is about
            // to stop serving
            let _ = respond(stream, 200, "OK", &[], "draining\n");
            if let Ok(addr) = stream.local_addr() {
                initiate_drain(shared, addr);
            }
            200
        }
        ("POST", "/generate") => handle_generate(stream, head, tx, shared, cfg),
        ("GET", "/generate") | ("POST", "/healthz") | ("POST", "/metrics")
        | ("GET", "/shutdown") => {
            shared.bad_requests.fetch_add(1, Ordering::Relaxed);
            let _ = respond(stream, 405, "Method Not Allowed", &[], "method not allowed\n");
            405
        }
        _ => {
            shared.bad_requests.fetch_add(1, Ordering::Relaxed);
            let _ = respond(stream, 404, "Not Found", &[], "unknown route\n");
            404
        }
    }
}

fn handle_generate(
    mut stream: &TcpStream,
    head: RequestHead,
    tx: &mpsc::Sender<DecodeRequest>,
    shared: &Shared,
    cfg: &HttpConfig,
) -> u16 {
    // each rejecting arm derives its own Retry-After: the rotating stagger
    // must advance once per *hinted answer*, not once per request, so
    // consecutive rejects always land on different seconds
    if shared.draining.load(Ordering::SeqCst) {
        let retry = shared.retry_secs(cfg).to_string();
        let _ = respond(
            stream,
            503,
            "Service Unavailable",
            &[("Retry-After", &retry)],
            "draining\n",
        );
        return 503;
    }
    if head.content_length > cfg.max_body {
        shared.bad_requests.fetch_add(1, Ordering::Relaxed);
        let _ = respond(stream, 413, "Payload Too Large", &[], "body too large\n");
        return 413;
    }
    let mut body = head.body_prefix;
    if let Err(e) = read_exact_body(&mut stream, &mut body, head.content_length) {
        shared.bad_requests.fetch_add(1, Ordering::Relaxed);
        let _ = respond(stream, 400, "Bad Request", &[], &format!("short body: {e}\n"));
        return 400;
    }
    let gen = match parse_generate(std::str::from_utf8(&body).unwrap_or("")) {
        Ok(g) => g,
        Err(e) => {
            shared.bad_requests.fetch_add(1, Ordering::Relaxed);
            let _ = respond(stream, 400, "Bad Request", &[], &format!("{e}\n"));
            return 400;
        }
    };
    if gen.prompt.is_empty() {
        shared.bad_requests.fetch_add(1, Ordering::Relaxed);
        let _ = respond(stream, 400, "Bad Request", &[], "empty prompt\n");
        return 400;
    }

    let (mut req, events) = DecodeRequest::new(gen.prompt, gen.max_new_tokens);
    req.eos = gen.eos;
    req.deadline = gen.deadline_ms.map(Duration::from_millis);
    let id = req.id;
    if tx.send(req).is_err() {
        let retry = shared.retry_secs(cfg).to_string();
        let _ = respond(
            stream,
            503,
            "Service Unavailable",
            &[("Retry-After", &retry)],
            "engine stopped\n",
        );
        return 503;
    }

    // the first event decides the status line: admission happens inside
    // Engine::submit, so a backpressure rejection arrives before any token
    match events.recv() {
        Ok(TokenEvent::Rejected { reason, .. }) => {
            shared.rejected_429.fetch_add(1, Ordering::Relaxed);
            let retry = shared.retry_secs(cfg).to_string();
            let _ = respond(
                stream,
                429,
                "Too Many Requests",
                &[("Retry-After", &retry)],
                &format!("{reason}\n"),
            );
            429
        }
        Ok(TokenEvent::Finished { reason: FinishReason::Failed, .. }) => {
            // the session died before streaming anything (engine restart or
            // supervised forward failure): a whole-response 503 tells the
            // client it may safely retry — once a token has gone out, the
            // same Failed arrives as the stream's terminal line instead
            let retry = shared.retry_secs(cfg).to_string();
            let _ = respond(
                stream,
                503,
                "Service Unavailable",
                &[("Retry-After", &retry)],
                "engine restarted\n",
            );
            503
        }
        Ok(first) => {
            if trace::enabled() {
                trace::instant(trace::session_track(id), "http", "stream_start", &[]);
            }
            stream_events(stream, first, events, shared, cfg.write_timeout)
        }
        Err(_) => {
            let retry = shared.retry_secs(cfg).to_string();
            let _ = respond(
                stream,
                503,
                "Service Unavailable",
                &[("Retry-After", &retry)],
                "engine stopped\n",
            );
            503
        }
    }
}

/// Stream `first` and every following event as chunked NDJSON. A write
/// error means the client went away: dropping `events` makes the engine
/// retire the session as `Disconnected` at its next token.
fn stream_events(
    stream: &TcpStream,
    first: TokenEvent,
    events: mpsc::Receiver<TokenEvent>,
    shared: &Shared,
    write_timeout: Duration,
) -> u16 {
    let header = "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\n\
                  Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n";
    if write_all_deadline(stream, header.as_bytes(), Instant::now() + write_timeout).is_err() {
        shared.disconnects.fetch_add(1, Ordering::Relaxed);
        return 200;
    }
    let mut ev = Some(first);
    loop {
        let event = match ev.take() {
            Some(e) => e,
            None => match events.recv() {
                Ok(e) => e,
                // engine gone mid-stream (abort sends terminal events, so
                // this is belt-and-braces): end the chunk stream cleanly
                Err(_) => {
                    let _ = write_all_deadline(
                        stream,
                        b"0\r\n\r\n",
                        Instant::now() + write_timeout,
                    );
                    return 200;
                }
            },
        };
        match event {
            TokenEvent::Token { token, index, logprob, .. } => {
                let lp = if logprob.is_finite() { logprob } else { 0.0 };
                let line = format!("{{\"token\":{token},\"index\":{index},\"logprob\":{lp}}}\n");
                if write_chunk(stream, &line, write_timeout).is_err() {
                    shared.disconnects.fetch_add(1, Ordering::Relaxed);
                    return 200; // dropping `events` propagates the disconnect
                }
                shared.tokens_streamed.fetch_add(1, Ordering::Relaxed);
            }
            TokenEvent::Finished { reason, generated, .. } => {
                let line = format!(
                    "{{\"done\":true,\"reason\":\"{}\",\"generated\":{generated}}}\n",
                    reason.as_str()
                );
                if write_chunk(stream, &line, write_timeout).is_ok()
                    && write_all_deadline(stream, b"0\r\n\r\n", Instant::now() + write_timeout)
                        .is_ok()
                {
                    shared.streams_completed.fetch_add(1, Ordering::Relaxed);
                } else {
                    shared.disconnects.fetch_add(1, Ordering::Relaxed);
                }
                return 200;
            }
            TokenEvent::Rejected { .. } => {
                // contract: Rejected is always the *first* event; ending the
                // stream is the only safe translation this late
                let _ =
                    write_all_deadline(stream, b"0\r\n\r\n", Instant::now() + write_timeout);
                return 200;
            }
        }
    }
}

/// Write one chunked-encoding frame, bounding the whole frame by `timeout`.
fn write_chunk(stream: &TcpStream, payload: &str, timeout: Duration) -> std::io::Result<()> {
    let framed = format!("{:x}\r\n{payload}\r\n", payload.len());
    write_all_deadline(stream, framed.as_bytes(), Instant::now() + timeout)
}

/// `write_all` with a deadline that spans **partial writes**. Plain
/// `write_all` under `SO_SNDTIMEO` re-arms the timeout on every syscall, so
/// a peer draining its receive window one byte per timeout could hold a
/// connection thread on one chunk indefinitely; here the whole buffer must
/// land by `deadline` (measured on the real clock — socket behavior must
/// not change under a test's fake `obs::clock`).
fn write_all_deadline(
    mut stream: &TcpStream,
    mut buf: &[u8],
    deadline: Instant,
) -> std::io::Result<()> {
    use std::io::ErrorKind;
    while !buf.is_empty() {
        let now = Instant::now();
        if now >= deadline {
            return Err(std::io::Error::new(ErrorKind::TimedOut, "write deadline exceeded"));
        }
        stream.set_write_timeout(Some(deadline - now))?;
        match stream.write(buf) {
            Ok(0) => {
                return Err(std::io::Error::new(ErrorKind::WriteZero, "peer stopped accepting"))
            }
            Ok(n) => buf = &buf[n..],
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                return Err(std::io::Error::new(ErrorKind::TimedOut, "write deadline exceeded"))
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Write a complete non-streamed response with `Content-Length` framing.
fn respond(
    mut stream: &TcpStream,
    status: u16,
    reason: &str,
    extra_headers: &[(&str, &str)],
    body: &str,
) -> std::io::Result<()> {
    let mut msg = format!("HTTP/1.1 {status} {reason}\r\n");
    if !extra_headers.iter().any(|(n, _)| n.eq_ignore_ascii_case("content-type")) {
        msg.push_str("Content-Type: text/plain; charset=utf-8\r\n");
    }
    for (n, v) in extra_headers {
        msg.push_str(n);
        msg.push_str(": ");
        msg.push_str(v);
        msg.push_str("\r\n");
    }
    msg.push_str(&format!("Content-Length: {}\r\nConnection: close\r\n\r\n", body.len()));
    msg.push_str(body);
    stream.write_all(msg.as_bytes())
}

// ---------------------------------------------------------------------------
// request parsing (hand-rolled: no HTTP or JSON dependency in this repo)

/// Parsed request head plus any body bytes that arrived with it.
#[derive(Debug)]
struct RequestHead {
    method: String,
    path: String,
    content_length: usize,
    body_prefix: Vec<u8>,
}

const MAX_HEAD: usize = 8 << 10;

/// Read up to the `\r\n\r\n` separator and parse the request line +
/// `Content-Length`. Bytes past the separator (the body, or a prefix of
/// it) are returned for the body reader.
fn read_head(stream: &mut &TcpStream) -> Result<RequestHead, String> {
    let mut buf: Vec<u8> = Vec::with_capacity(512);
    let mut chunk = [0u8; 1024];
    let split = loop {
        if let Some(i) = find_head_end(&buf) {
            break i;
        }
        if buf.len() > MAX_HEAD {
            return Err("head too large".into());
        }
        let n = stream.read(&mut chunk).map_err(|e| e.to_string())?;
        if n == 0 {
            return Err("eof before head end".into());
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..split]).map_err(|_| "head is not utf-8".to_string())?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().ok_or("empty head")?;
    let mut parts = request_line.split(' ');
    let method = parts.next().ok_or("missing method")?.to_string();
    let path = parts.next().ok_or("missing path")?.to_string();
    if !parts.next().is_some_and(|v| v.starts_with("HTTP/1.")) {
        return Err("not an HTTP/1.x request".into());
    }
    let mut content_length = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length =
                    value.trim().parse().map_err(|_| "bad content-length".to_string())?;
            }
        }
    }
    Ok(RequestHead { method, path, content_length, body_prefix: buf[split + 4..].to_vec() })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn read_exact_body(
    stream: &mut &TcpStream,
    body: &mut Vec<u8>,
    content_length: usize,
) -> Result<(), String> {
    let mut chunk = [0u8; 1024];
    while body.len() < content_length {
        let n = stream.read(&mut chunk).map_err(|e| e.to_string())?;
        if n == 0 {
            return Err("eof mid-body".into());
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok(())
}

/// A parsed `/generate` body.
#[derive(Debug, PartialEq, Eq)]
pub struct GenerateRequest {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub eos: Option<i32>,
    /// Optional client latency budget in milliseconds, measured from
    /// submission; feeds the engine's fair-share victim policy (sessions
    /// with less slack are preempted last). Absent = best-effort.
    pub deadline_ms: Option<u64>,
}

/// Parse the strict JSON subset the wire format uses: one object with
/// `prompt` (array of ints), `max_new_tokens` (int), and optional `eos`
/// (int) and `deadline_ms` (non-negative int). Unknown fields, trailing
/// garbage, and non-integer tokens are errors — a typo'd field silently
/// ignored would be a debugging trap.
pub fn parse_generate(body: &str) -> Result<GenerateRequest, String> {
    let mut p = Parser { s: body.as_bytes(), i: 0 };
    p.skip_ws();
    p.expect(b'{')?;
    let mut prompt: Option<Vec<i32>> = None;
    let mut max_new_tokens: Option<usize> = None;
    let mut eos: Option<i32> = None;
    let mut deadline_ms: Option<u64> = None;
    loop {
        p.skip_ws();
        if p.eat(b'}') {
            break;
        }
        if prompt.is_some() || max_new_tokens.is_some() || eos.is_some() || deadline_ms.is_some()
        {
            p.expect(b',')?;
            p.skip_ws();
        }
        let key = p.string()?;
        p.skip_ws();
        p.expect(b':')?;
        p.skip_ws();
        match key.as_str() {
            "prompt" => {
                if prompt.is_some() {
                    return Err("duplicate field \"prompt\"".into());
                }
                prompt = Some(p.int_array()?);
            }
            "max_new_tokens" => {
                if max_new_tokens.is_some() {
                    return Err("duplicate field \"max_new_tokens\"".into());
                }
                let v = p.integer()?;
                if v < 0 {
                    return Err("max_new_tokens must be >= 0".into());
                }
                max_new_tokens = Some(v as usize);
            }
            "eos" => {
                if eos.is_some() {
                    return Err("duplicate field \"eos\"".into());
                }
                eos = Some(p.i32()?);
            }
            "deadline_ms" => {
                if deadline_ms.is_some() {
                    return Err("duplicate field \"deadline_ms\"".into());
                }
                let v = p.integer()?;
                if v < 0 {
                    return Err("deadline_ms must be >= 0".into());
                }
                deadline_ms = Some(v as u64);
            }
            other => return Err(format!("unknown field {other:?}")),
        }
    }
    p.skip_ws();
    if p.i != p.s.len() {
        return Err("trailing bytes after the request object".into());
    }
    Ok(GenerateRequest {
        prompt: prompt.ok_or("missing field \"prompt\"")?,
        max_new_tokens: max_new_tokens.ok_or("missing field \"max_new_tokens\"")?,
        eos,
        deadline_ms,
    })
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, b: u8) -> bool {
        if self.peek() == Some(b) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.eat(b) {
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.i))
        }
    }

    /// A JSON string with no escapes (field names only).
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let start = self.i;
        while let Some(c) = self.peek() {
            if c == b'"' {
                let out = std::str::from_utf8(&self.s[start..self.i])
                    .map_err(|_| "non-utf8 string".to_string())?
                    .to_string();
                self.i += 1;
                return Ok(out);
            }
            if c == b'\\' {
                return Err("escapes are not part of the wire format".into());
            }
            self.i += 1;
        }
        Err("unterminated string".into())
    }

    fn integer(&mut self) -> Result<i64, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.i]).unwrap();
        text.parse::<i64>().map_err(|_| format!("bad integer at byte {start}"))
    }

    #[allow(clippy::wrong_self_convention)]
    fn i32(&mut self) -> Result<i32, String> {
        let v = self.integer()?;
        i32::try_from(v).map_err(|_| "integer out of token range".to_string())
    }

    fn int_array(&mut self) -> Result<Vec<i32>, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.eat(b']') {
            return Ok(out);
        }
        loop {
            self.skip_ws();
            out.push(self.i32()?);
            self.skip_ws();
            if self.eat(b']') {
                return Ok(out);
            }
            self.expect(b',')?;
        }
    }
}

// ---------------------------------------------------------------------------
// minimal blocking client (tests, the CI smoke, and the perf_http loadgen)

/// A fully-read HTTP response (chunked bodies are de-framed).
#[derive(Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl HttpResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// One blocking request; reads the whole response.
pub fn fetch(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> std::io::Result<HttpResponse> {
    let mut stream = ChunkStream::open(addr, method, path, body)?;
    let body = stream.read_body()?;
    Ok(HttpResponse { status: stream.status, headers: stream.headers, body })
}

/// Client retry policy for [`fetch_with_retry`]: exponential backoff with
/// deterministic seeded jitter, honoring the server's `Retry-After` hint.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 = plain [`fetch`]).
    pub max_retries: u32,
    /// First backoff; doubles per retry.
    pub base: Duration,
    /// Upper bound on any single backoff (including `Retry-After` hints).
    pub cap: Duration,
    /// Jitter seed — fixed per client so schedules are reproducible while
    /// distinct clients still decorrelate.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            base: Duration::from_millis(25),
            cap: Duration::from_secs(2),
            seed: 0x9e37_79b9_7f4a_7c15,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry `attempt` (0-based). A server `Retry-After`
    /// hint overrides the exponential schedule (still capped); otherwise
    /// `base * 2^attempt`, capped, then jittered into [50%, 100%] by a
    /// splitmix hash of (seed, attempt) — a thundering herd of rejected
    /// clients must not re-arrive in lockstep, but tests need the schedule
    /// to be a pure function of the policy.
    pub fn delay(&self, attempt: u32, retry_after: Option<Duration>) -> Duration {
        if let Some(d) = retry_after {
            return d.min(self.cap);
        }
        let exp = self.base.saturating_mul(1u32 << attempt.min(16));
        let capped = exp.min(self.cap);
        let mut x = self.seed ^ (u64::from(attempt) + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        x ^= x >> 30;
        x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
        x ^= x >> 31;
        let frac = 0.5 + (x >> 11) as f64 / (1u64 << 53) as f64 / 2.0;
        capped.mul_f64(frac)
    }
}

/// [`fetch`] with retries: transport errors and 429/503 answers back off
/// and try again (up to `policy.max_retries`); every other status returns
/// immediately. 429/503 backoffs honor the `Retry-After` header the
/// server's backpressure contract promises. The overload cells in
/// `perf_http` drive their clients through this.
pub fn fetch_with_retry(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
    policy: &RetryPolicy,
) -> std::io::Result<HttpResponse> {
    let mut attempt = 0u32;
    loop {
        let hint = match fetch(addr, method, path, body) {
            Ok(r) if (r.status == 429 || r.status == 503) && attempt < policy.max_retries => r
                .header("Retry-After")
                .and_then(|v| v.trim().parse::<u64>().ok())
                .map(Duration::from_secs),
            Ok(r) => return Ok(r),
            Err(e) => {
                if attempt >= policy.max_retries {
                    return Err(e);
                }
                None
            }
        };
        std::thread::sleep(policy.delay(attempt, hint));
        attempt += 1;
    }
}

/// An in-flight response whose chunks are read incrementally — the loadgen
/// timestamps each token chunk for client-side TTFT/ITL, and the
/// disconnect tests drop it mid-stream.
pub struct ChunkStream {
    stream: TcpStream,
    buf: Vec<u8>,
    pos: usize,
    chunked: bool,
    content_length: usize,
    pub status: u16,
    pub headers: Vec<(String, String)>,
}

impl ChunkStream {
    /// Write the request and parse the response status line + headers.
    pub fn open(
        addr: SocketAddr,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> std::io::Result<ChunkStream> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let body = body.unwrap_or("");
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: llmdt\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        stream.write_all(req.as_bytes())?;
        let mut buf = Vec::with_capacity(512);
        let mut chunk = [0u8; 1024];
        let split = loop {
            if let Some(i) = find_head_end(&buf) {
                break i;
            }
            let n = stream.read(&mut chunk)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof before response head",
                ));
            }
            buf.extend_from_slice(&chunk[..n]);
        };
        let head = String::from_utf8_lossy(&buf[..split]).to_string();
        let mut lines = head.split("\r\n");
        let status_line = lines.next().unwrap_or("");
        let status: u16 = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, "bad status line")
            })?;
        let headers: Vec<(String, String)> = lines
            .filter_map(|l| {
                l.split_once(':').map(|(n, v)| (n.trim().to_string(), v.trim().to_string()))
            })
            .collect();
        let chunked = headers.iter().any(|(n, v)| {
            n.eq_ignore_ascii_case("transfer-encoding") && v.eq_ignore_ascii_case("chunked")
        });
        let content_length = headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case("content-length"))
            .and_then(|(_, v)| v.parse().ok())
            .unwrap_or(0);
        let rest = buf[split + 4..].to_vec();
        Ok(ChunkStream { stream, buf: rest, pos: 0, chunked, content_length, status, headers })
    }

    fn fill(&mut self) -> std::io::Result<usize> {
        // client-side injection sites: the chaos harness turns this bundled
        // client into a hostile peer — one that stops reading (the server's
        // write deadline is what must hold the line) or dies mid-stream
        // (the engine must retire the session as Disconnected)
        if faults::fire(faults::Site::HttpClientStall) {
            std::thread::sleep(faults::stall());
        }
        if faults::fire(faults::Site::HttpClientDisconnect) {
            let _ = self.stream.shutdown(std::net::Shutdown::Both);
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionAborted,
                "fault-injected client disconnect",
            ));
        }
        let mut chunk = [0u8; 1024];
        let n = self.stream.read(&mut chunk)?;
        self.buf.extend_from_slice(&chunk[..n]);
        Ok(n)
    }

    fn take_line(&mut self) -> std::io::Result<String> {
        loop {
            if let Some(i) =
                self.buf[self.pos..].windows(2).position(|w| w == b"\r\n").map(|i| i + self.pos)
            {
                let line = String::from_utf8_lossy(&self.buf[self.pos..i]).to_string();
                self.pos = i + 2;
                return Ok(line);
            }
            if self.fill()? == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof mid chunk frame",
                ));
            }
        }
    }

    fn take_bytes(&mut self, n: usize) -> std::io::Result<Vec<u8>> {
        while self.buf.len() - self.pos < n {
            if self.fill()? == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof mid chunk payload",
                ));
            }
        }
        let out = self.buf[self.pos..self.pos + n].to_vec();
        self.pos += n;
        Ok(out)
    }

    /// The next chunk payload of a chunked response; `None` at the
    /// terminal zero-length chunk.
    pub fn next_chunk(&mut self) -> std::io::Result<Option<String>> {
        let size_line = self.take_line()?;
        let size = usize::from_str_radix(size_line.trim(), 16).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "bad chunk size")
        })?;
        if size == 0 {
            let _ = self.take_line(); // trailing CRLF after the last chunk
            return Ok(None);
        }
        let payload = self.take_bytes(size)?;
        let _ = self.take_line(); // CRLF closing the chunk
        Ok(Some(String::from_utf8_lossy(&payload).to_string()))
    }

    /// Drain the rest of the response into one string (both framings).
    pub fn read_body(&mut self) -> std::io::Result<String> {
        if self.chunked {
            let mut out = String::new();
            while let Some(c) = self.next_chunk()? {
                out.push_str(&c);
            }
            Ok(out)
        } else {
            let bytes = self.take_bytes(self.content_length)?;
            Ok(String::from_utf8_lossy(&bytes).to_string())
        }
    }
}

/// Pull an integer field out of a flat NDJSON line (the bench and tests
/// read `"token"`, `"index"`, `"generated"` this way — no JSON dependency).
pub fn json_int_field(line: &str, field: &str) -> Option<i64> {
    let key = format!("\"{field}\":");
    let at = line.find(&key)? + key.len();
    let rest = &line[at..];
    let end = rest
        .char_indices()
        .find(|&(_, c)| c != '-' && !c.is_ascii_digit())
        .map(|(i, _)| i)
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_after_derives_from_pressure_and_staggers_consecutive_rejects() {
        let cfg = HttpConfig::default();
        let shared = Shared::new();

        // idle server: no queue, no published pool -> the hint floors at
        // the configured base, plus the rotating stagger
        let idle: Vec<u64> = (0..3).map(|_| shared.retry_secs(&cfg)).collect();
        assert!(idle.iter().all(|&s| s >= cfg.retry_after_secs.max(1)), "{idle:?}");
        assert!(idle.iter().all(|&s| s <= cfg.retry_after_cap), "{idle:?}");
        let mut distinct = idle.clone();
        distinct.sort_unstable();
        distinct.dedup();
        assert!(
            distinct.len() > 1,
            "three consecutive rejects must not share one comeback slot: {idle:?}"
        );

        // deep queue + dry pool: the hint grows with pressure but stays
        // clamped at the cap
        shared.queue_depth.store(64, Ordering::Relaxed);
        shared.pages_total.store(16, Ordering::Relaxed);
        shared.pages_free.store(0, Ordering::Relaxed);
        let loaded: Vec<u64> = (0..3).map(|_| shared.retry_secs(&cfg)).collect();
        assert!(
            loaded.iter().all(|&s| s <= cfg.retry_after_cap),
            "pressure never overshoots the cap: {loaded:?}"
        );
        assert!(
            loaded.iter().min() > idle.iter().min(),
            "a saturated server asks shed clients to wait longer than an idle \
             one: idle {idle:?} vs loaded {loaded:?}"
        );

        // a quarter-full pool sits between the two
        shared.queue_depth.store(4, Ordering::Relaxed);
        shared.pages_free.store(4, Ordering::Relaxed);
        let mid = shared.retry_secs(&cfg);
        assert!(mid >= cfg.retry_after_secs.max(1) && mid <= cfg.retry_after_cap);
    }

    #[test]
    fn parse_generate_golden() {
        let g = parse_generate("{\"prompt\":[1,2,3],\"max_new_tokens\":8,\"eos\":5}").unwrap();
        assert_eq!(
            g,
            GenerateRequest {
                prompt: vec![1, 2, 3],
                max_new_tokens: 8,
                eos: Some(5),
                deadline_ms: None,
            }
        );
        let g = parse_generate(" { \"prompt\" : [ 7 ] , \"max_new_tokens\" : 1 } ").unwrap();
        assert_eq!(
            g,
            GenerateRequest { prompt: vec![7], max_new_tokens: 1, eos: None, deadline_ms: None }
        );
        let g =
            parse_generate("{\"prompt\":[1],\"max_new_tokens\":4,\"deadline_ms\":250}").unwrap();
        assert_eq!(g.deadline_ms, Some(250), "latency budget rides the wire");
        let g = parse_generate("{\"prompt\":[],\"max_new_tokens\":4}").unwrap();
        assert!(g.prompt.is_empty(), "empty arrays parse; the route rejects them as 400");
    }

    #[test]
    fn parse_generate_rejects_malformed_input() {
        for (body, why) in [
            ("", "empty body"),
            ("{\"prompt\":[1]}", "missing max_new_tokens"),
            ("{\"max_new_tokens\":4}", "missing prompt"),
            ("{\"prompt\":[1],\"max_new_tokens\":4,\"temperature\":1.0}", "unknown field"),
            ("{\"prompt\":[1],\"max_new_tokens\":4}x", "trailing bytes"),
            ("{\"prompt\":[1,],\"max_new_tokens\":4}", "trailing comma"),
            ("{\"prompt\":[\"a\"],\"max_new_tokens\":4}", "non-integer token"),
            ("{\"prompt\":[1],\"max_new_tokens\":-2}", "negative budget"),
            ("{\"prompt\":[1],\"prompt\":[2],\"max_new_tokens\":4}", "duplicate field"),
            ("{\"prompt\":[4294967296],\"max_new_tokens\":4}", "token out of i32 range"),
            ("{\"prompt\":[1],\"max_new_tokens\":4,\"deadline_ms\":-5}", "negative deadline"),
            (
                "{\"prompt\":[1],\"max_new_tokens\":4,\"deadline_ms\":1,\"deadline_ms\":2}",
                "duplicate deadline",
            ),
        ] {
            assert!(parse_generate(body).is_err(), "{why}: {body:?}");
        }
    }

    #[test]
    fn head_parser_handles_split_reads_and_body_prefix() {
        // find_head_end + body_prefix are what read_head builds on; pin
        // the separator logic on awkward splits
        assert_eq!(find_head_end(b"POST / HTTP/1.1\r\n\r\nrest"), Some(15));
        assert_eq!(find_head_end(b"POST / HTTP/1.1\r\n"), None);
        assert_eq!(find_head_end(b""), None);
    }

    #[test]
    fn retry_policy_backs_off_deterministically() {
        let p = RetryPolicy { seed: 7, ..RetryPolicy::default() };
        // pure function of (policy, attempt): same inputs, same delay
        assert_eq!(p.delay(0, None), p.delay(0, None));
        // exponential growth up to the cap, jitter bounded to [50%, 100%]
        for attempt in 0..6 {
            let exp = p.base.saturating_mul(1 << attempt).min(p.cap);
            let d = p.delay(attempt, None);
            assert!(d <= exp, "jitter never exceeds the schedule: {d:?} > {exp:?}");
            assert!(d >= exp / 2, "jitter floor is half the schedule: {d:?} < {exp:?}/2");
        }
        // a huge attempt count saturates at the cap instead of overflowing
        assert!(p.delay(40, None) <= p.cap);
        // the server's Retry-After hint overrides the schedule but not the cap
        assert_eq!(p.delay(0, Some(Duration::from_secs(1))), Duration::from_secs(1));
        assert_eq!(p.delay(0, Some(Duration::from_secs(3600))), p.cap);
        // distinct seeds decorrelate (the whole point of the jitter)
        let q = RetryPolicy { seed: 8, ..p };
        assert_ne!(p.delay(2, None), q.delay(2, None));
    }

    #[test]
    fn write_deadline_spans_partial_writes() {
        // regression: write_all under SO_SNDTIMEO re-arms the timeout on
        // every syscall, so a peer draining one byte per interval could pin
        // a connection thread on a single chunk forever. The deadline must
        // bound the WHOLE buffer.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let reader = std::thread::spawn(move || {
            let (mut peer, _) = listener.accept().unwrap();
            let mut byte = [0u8; 1];
            loop {
                std::thread::sleep(Duration::from_millis(20));
                match peer.read(&mut byte) {
                    Ok(0) | Err(_) => return,
                    Ok(_) => {}
                }
            }
        });
        let stream = TcpStream::connect(addr).unwrap();
        // far beyond any socket buffer, so the kernel must block us
        let payload = vec![b'x'; 32 << 20];
        let t0 = Instant::now();
        let err =
            write_all_deadline(&stream, &payload, t0 + Duration::from_millis(200)).unwrap_err();
        let took = t0.elapsed();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
        assert!(
            took < Duration::from_secs(2),
            "deadline must bound the whole write, took {took:?}"
        );
        drop(stream); // reader sees EOF and exits
        reader.join().unwrap();
    }

    #[test]
    fn json_int_field_reads_flat_ndjson() {
        let line = "{\"token\":42,\"index\":0,\"logprob\":-2.5}";
        assert_eq!(json_int_field(line, "token"), Some(42));
        assert_eq!(json_int_field(line, "index"), Some(0));
        assert_eq!(json_int_field(line, "missing"), None);
        let done = "{\"done\":true,\"reason\":\"max_tokens\",\"generated\":8}";
        assert_eq!(json_int_field(done, "generated"), Some(8));
        assert_eq!(json_int_field("{\"token\":-3}", "token"), Some(-3));
    }
}
