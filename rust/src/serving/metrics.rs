//! Serving metrics: per-step counters folded into a final report with the
//! latency percentiles that matter for decode serving — time-to-first-token
//! (TTFT) and inter-token latency (ITL) — plus sustained decode throughput,
//! batch occupancy, and the fused-path counters (rows per batched forward,
//! fused GEMM launches). Supersedes the old `ServeStats` aggregate, which
//! the coordinator shim now derives from this collector.

use std::fmt;
use std::time::{Duration, Instant};

/// Nearest-rank percentile of an (unsorted) duration sample; `q` in [0, 1].
/// Empty samples report zero; a single sample is every percentile.
pub fn percentile(samples: &[Duration], q: f64) -> Duration {
    if samples.is_empty() {
        return Duration::ZERO;
    }
    let mut s = samples.to_vec();
    s.sort();
    let rank = (s.len() as f64 * q.clamp(0.0, 1.0)).ceil() as usize;
    s[rank.saturating_sub(1).min(s.len() - 1)]
}

/// Accumulates while the engine runs; snapshot with [`MetricsCollector::report`].
#[derive(Default)]
pub struct MetricsCollector {
    /// Per-completed-prefill: submission -> first streamed token.
    pub ttft: Vec<Duration>,
    /// Per-generated-token gaps after the first.
    pub itl: Vec<Duration>,
    /// Active (prefill + decoding) sessions at each step.
    pub occupancy: Vec<usize>,
    /// Rows per fused batched forward (batched-step occupancy: how many
    /// sequences each `forward_lm_step_batch` call actually carried).
    pub fused_batch: Vec<usize>,
    /// Fused batched forwards issued.
    pub fused_steps: usize,
    /// Fused `[B, d] x [d, N]` GEMM launches (one per linear per fused
    /// forward; without fusion each would have been `B` separate GEMMs).
    pub fused_gemms: u64,
    /// KV-cache bytes attention read across the run: per forwarded row,
    /// `attended positions x layers x position_bytes` (K+V) — ~8x smaller
    /// per position under packed 4-bit lanes than fp32.
    pub kv_bytes_read: u64,
    /// Sessions evicted by the page-pressure guard (pool ran dry
    /// mid-step), a subset of `evicted`.
    pub page_preemptions: usize,
    /// Latest KV page-pool gauges (sampled once per engine step).
    pages_in_use: usize,
    pages_free: usize,
    /// Running mean of tail fragmentation across sampled steps.
    frag_sum: f64,
    frag_samples: usize,
    pub steps: usize,
    pub decode_tokens: usize,
    pub prefill_tokens: usize,
    pub completed: usize,
    pub rejected: usize,
    pub evicted: usize,
    started: Option<Instant>,
    wall: Duration,
}

impl MetricsCollector {
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn finish(&mut self) {
        if let Some(t0) = self.started.take() {
            self.wall += t0.elapsed();
        }
    }

    /// One engine step: how many sessions were active, and how many decode /
    /// prefill tokens the step produced.
    pub fn record_step(&mut self, active: usize, decoded: usize, prefilled: usize) {
        self.steps += 1;
        self.occupancy.push(active);
        self.decode_tokens += decoded;
        self.prefill_tokens += prefilled;
    }

    /// One fused batched forward: `rows` sequences rode the batch, costing
    /// `gemms` fused GEMM launches (vs `rows * gemms` unfused).
    pub fn record_fused(&mut self, rows: usize, gemms: u64) {
        self.fused_steps += 1;
        self.fused_gemms += gemms;
        self.fused_batch.push(rows);
    }

    /// KV lane bytes one forwarded row's attention read.
    pub fn record_kv_read(&mut self, bytes: u64) {
        self.kv_bytes_read += bytes;
    }

    /// One per-step sample of the KV page pool: pages held / free and the
    /// tail fragmentation of the held pages.
    pub fn record_pages(&mut self, in_use: usize, free: usize, fragmentation: f64) {
        self.pages_in_use = in_use;
        self.pages_free = free;
        self.frag_sum += fragmentation;
        self.frag_samples += 1;
    }

    pub fn record_first_token(&mut self, since_submit: Duration) {
        self.ttft.push(since_submit);
    }

    pub fn record_inter_token(&mut self, gap: Duration) {
        self.itl.push(gap);
    }

    pub fn record_completion(&mut self) {
        self.completed += 1;
    }

    pub fn report(&self) -> MetricsReport {
        let wall = match self.started {
            Some(t0) => self.wall + t0.elapsed(),
            None => self.wall,
        };
        let secs = wall.as_secs_f64();
        MetricsReport {
            completed: self.completed,
            rejected: self.rejected,
            evicted: self.evicted,
            steps: self.steps,
            decode_tokens: self.decode_tokens,
            prefill_tokens: self.prefill_tokens,
            ttft_p50: percentile(&self.ttft, 0.50),
            ttft_p99: percentile(&self.ttft, 0.99),
            itl_p50: percentile(&self.itl, 0.50),
            itl_p99: percentile(&self.itl, 0.99),
            decode_tps: if secs > 0.0 { self.decode_tokens as f64 / secs } else { 0.0 },
            mean_occupancy: self.occupancy.iter().sum::<usize>() as f64
                / self.occupancy.len().max(1) as f64,
            peak_occupancy: self.occupancy.iter().copied().max().unwrap_or(0),
            pages_in_use: self.pages_in_use,
            pages_free: self.pages_free,
            page_fragmentation: self.frag_sum / self.frag_samples.max(1) as f64,
            page_preemptions: self.page_preemptions,
            fused_steps: self.fused_steps,
            fused_gemms: self.fused_gemms,
            mean_fused_batch: self.fused_batch.iter().sum::<usize>() as f64
                / self.fused_batch.len().max(1) as f64,
            kv_bytes_read: self.kv_bytes_read,
            kv_bytes_per_token: self.kv_bytes_read as f64
                / (self.decode_tokens + self.prefill_tokens).max(1) as f64,
            wall,
        }
    }
}

/// Final engine-run summary.
#[derive(Clone, Debug)]
pub struct MetricsReport {
    pub completed: usize,
    pub rejected: usize,
    pub evicted: usize,
    pub steps: usize,
    pub decode_tokens: usize,
    pub prefill_tokens: usize,
    pub ttft_p50: Duration,
    pub ttft_p99: Duration,
    pub itl_p50: Duration,
    pub itl_p99: Duration,
    /// Sustained generated tokens per wall-clock second.
    pub decode_tps: f64,
    /// Mean active sessions per step.
    pub mean_occupancy: f64,
    /// Most sessions concurrently active at any step — the paged
    /// engine's admission headline (a page pool admits sequence mixes
    /// whose summed worst case exceeds its positions).
    pub peak_occupancy: usize,
    /// KV pages held at the last sampled step.
    pub pages_in_use: usize,
    /// KV pages free at the last sampled step.
    pub pages_free: usize,
    /// Mean tail fragmentation of held pages across the run, in [0, 1]
    /// (positions allocated but not holding a committed row).
    pub page_fragmentation: f64,
    /// Sessions evicted because the page pool ran dry mid-step.
    pub page_preemptions: usize,
    /// Fused batched forwards issued.
    pub fused_steps: usize,
    /// Fused GEMM launches across the run.
    pub fused_gemms: u64,
    /// Mean rows per fused batched forward (batched-step occupancy).
    pub mean_fused_batch: f64,
    /// Total KV lane bytes attention read across the run.
    pub kv_bytes_read: u64,
    /// KV bytes read per forwarded token (decode + prefill) — the traffic
    /// figure the packed KV backend exists to shrink.
    pub kv_bytes_per_token: f64,
    pub wall: Duration,
}

impl fmt::Display for MetricsReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "completed {} (rejected {}, evicted {}) | {} steps, {} decode + {} prefill tok \
             | {:.1} tok/s decode | ttft p50 {:?} p99 {:?} | itl p50 {:?} p99 {:?} \
             | occupancy {:.2} (peak {}) | fused {} gemms over {} calls, batch {:.2} \
             | kv {:.1} KiB/tok | pages {} used / {} free, frag {:.2}, {} page-evictions \
             | wall {:?}",
            self.completed,
            self.rejected,
            self.evicted,
            self.steps,
            self.decode_tokens,
            self.prefill_tokens,
            self.decode_tps,
            self.ttft_p50,
            self.ttft_p99,
            self.itl_p50,
            self.itl_p99,
            self.mean_occupancy,
            self.peak_occupancy,
            self.fused_gemms,
            self.fused_steps,
            self.mean_fused_batch,
            self.kv_bytes_per_token / 1024.0,
            self.pages_in_use,
            self.pages_free,
            self.page_fragmentation,
            self.page_preemptions,
            self.wall,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn percentile_empty_is_zero() {
        assert_eq!(percentile(&[], 0.5), Duration::ZERO);
        assert_eq!(percentile(&[], 0.99), Duration::ZERO);
    }

    #[test]
    fn percentile_single_sample_is_every_quantile() {
        let s = [ms(7)];
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(percentile(&s, q), ms(7), "q={q}");
        }
    }

    #[test]
    fn percentile_even_length_nearest_rank() {
        // nearest-rank on [1,2,3,4]: p50 -> 2nd element, p99/p100 -> 4th
        let s = [ms(3), ms(1), ms(4), ms(2)]; // unsorted on purpose
        assert_eq!(percentile(&s, 0.50), ms(2));
        assert_eq!(percentile(&s, 0.75), ms(3));
        assert_eq!(percentile(&s, 0.99), ms(4));
        assert_eq!(percentile(&s, 1.0), ms(4));
        assert_eq!(percentile(&s, 0.0), ms(1));
    }

    #[test]
    fn percentile_odd_length_median_is_middle() {
        let s = [ms(5), ms(1), ms(3)];
        assert_eq!(percentile(&s, 0.5), ms(3));
    }

    #[test]
    fn collector_aggregates() {
        let mut m = MetricsCollector::default();
        m.start();
        std::thread::sleep(Duration::from_millis(2)); // make wall observable
        m.record_step(2, 2, 8);
        m.record_step(4, 4, 0);
        m.record_fused(2, 13);
        m.record_fused(4, 13);
        m.record_kv_read(4096);
        m.record_kv_read(2048);
        m.record_pages(3, 5, 0.5);
        m.record_pages(2, 6, 0.25);
        m.record_first_token(ms(10));
        m.record_inter_token(ms(2));
        m.record_inter_token(ms(4));
        m.record_completion();
        m.finish();
        let r = m.report();
        assert_eq!(r.steps, 2);
        assert_eq!(r.decode_tokens, 6);
        assert_eq!(r.prefill_tokens, 8);
        assert_eq!(r.completed, 1);
        assert!((r.mean_occupancy - 3.0).abs() < 1e-12);
        assert_eq!(r.fused_steps, 2);
        assert_eq!(r.fused_gemms, 26);
        assert_eq!(r.kv_bytes_read, 6144);
        // 14 forwarded tokens (6 decode + 8 prefill)
        assert!((r.kv_bytes_per_token - 6144.0 / 14.0).abs() < 1e-9);
        assert!((r.mean_fused_batch - 3.0).abs() < 1e-12);
        assert_eq!(r.peak_occupancy, 4);
        // page gauges: latest sample wins, fragmentation is the mean
        assert_eq!(r.pages_in_use, 2);
        assert_eq!(r.pages_free, 6);
        assert!((r.page_fragmentation - 0.375).abs() < 1e-12);
        assert_eq!(r.page_preemptions, 0);
        assert_eq!(r.ttft_p50, ms(10));
        assert_eq!(r.itl_p99, ms(4));
        assert!(r.wall > Duration::ZERO);
        assert!(r.decode_tps > 0.0);
        // report is renderable
        assert!(format!("{r}").contains("tok/s"));
    }
}
