//! Serving metrics: per-step counters folded into a final report with the
//! latency percentiles that matter for decode serving — time-to-first-token
//! (TTFT) and inter-token latency (ITL) — plus sustained decode throughput,
//! batch occupancy, and the fused-path counters (rows per batched forward,
//! fused GEMM launches). Supersedes the old `ServeStats` aggregate, which
//! the coordinator shim now derives from this collector.
//!
//! Storage is bounded: every latency sample lands in an O(buckets)
//! log-bucketed [`Histogram`], and at most [`RAW_SAMPLE_CAP`] raw samples
//! per series are retained for exact percentiles. Short runs (every test,
//! every smoke) stay bit-exact; past the cap the report switches to
//! histogram percentiles (within one bucket width, ≤ 25 % relative) and
//! says so via [`MetricsReport::samples_dropped`] — memory no longer grows
//! with token count, which is what lets an engine run for days.
//! [`MetricsCollector::registry`] exposes the same state as a named-metric
//! [`Registry`] for Prometheus export.

use std::fmt;
use std::time::Duration;

use crate::obs::clock;
use crate::obs::metrics::{Histogram, Registry};
use crate::runtime::pool::PoolStats;
use crate::serving::session::FinishReason;

/// Raw latency samples retained per series for exact percentiles; beyond
/// this the histogram answers and `samples_dropped` counts the excess.
pub const RAW_SAMPLE_CAP: usize = 8192;

/// Nearest-rank percentile of an (unsorted) duration sample; `q` in [0, 1].
/// Empty samples report zero; a single sample is every percentile. Sorts a
/// copy per call — callers taking several quantiles should sort once and
/// use [`percentile_sorted`].
pub fn percentile(samples: &[Duration], q: f64) -> Duration {
    let mut s = samples.to_vec();
    s.sort();
    percentile_sorted(&s, q)
}

/// Nearest-rank percentile of an already **sorted** sample (ascending).
pub fn percentile_sorted(sorted: &[Duration], q: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let rank = (sorted.len() as f64 * q.clamp(0.0, 1.0)).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
}

fn nearest_rank(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (sorted.len() as f64 * q.clamp(0.0, 1.0)).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
}

/// One latency series: a histogram of every sample (bounded memory) plus
/// up to `cap` raw samples for exact percentiles on short runs. Samples
/// are stored in nanoseconds so sub-microsecond gaps stay observable.
pub struct SampleSet {
    hist: Histogram,
    raw: Vec<u64>,
    cap: usize,
    dropped: u64,
}

impl SampleSet {
    fn new(cap: usize) -> SampleSet {
        SampleSet { hist: Histogram::new(), raw: Vec::new(), cap, dropped: 0 }
    }

    fn record(&mut self, nanos: u64) {
        self.hist.record(nanos);
        if self.raw.len() < self.cap {
            self.raw.push(nanos);
        } else {
            self.dropped += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.hist.count()
    }

    /// Samples past the raw cap (histogram still has them all).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn hist(&self) -> &Histogram {
        &self.hist
    }

    /// The requested quantiles, one sort for all of them: exact
    /// (nearest-rank over raw samples) while nothing was dropped,
    /// histogram-resolution after.
    fn percentiles(&self, qs: &[f64]) -> Vec<Duration> {
        if self.dropped == 0 {
            let mut sorted = self.raw.clone();
            sorted.sort_unstable();
            qs.iter().map(|&q| Duration::from_nanos(nearest_rank(&sorted, q))).collect()
        } else {
            qs.iter().map(|&q| Duration::from_nanos(self.hist.percentile(q))).collect()
        }
    }
}

/// Accumulates while the engine runs; snapshot with [`MetricsCollector::report`].
pub struct MetricsCollector {
    /// Per-completed-prefill: submission -> first streamed token.
    ttft: SampleSet,
    /// Per-generated-token gaps after the first. Gaps that span a
    /// preemption land in `resume_gap` instead: ITL measures decode
    /// cadence, not scheduler artifacts.
    itl: SampleSet,
    /// Per-preemption-resume: last pre-eviction token -> first replayed
    /// token (eviction + queue wait + re-prefill, the client-visible
    /// latency bubble).
    resume_gap: SampleSet,
    /// Active (prefill + decoding) sessions at each step: distribution plus
    /// running mean/peak. O(buckets), not O(steps).
    occupancy: Histogram,
    occ_sum: u64,
    occ_samples: usize,
    occ_peak: usize,
    /// Rows per fused batched forward, as a running sum (mean in the
    /// report) — batched-step occupancy of `forward_lm_step_batch`.
    fused_rows: u64,
    /// Fused batched forwards issued.
    pub fused_steps: usize,
    /// Fused `[B, d] x [d, N]` GEMM launches (one per linear per fused
    /// forward; without fusion each would have been `B` separate GEMMs).
    pub fused_gemms: u64,
    /// KV-cache bytes attention read across the run: per forwarded row,
    /// `attended positions x layers x position_bytes` (K+V) — ~8x smaller
    /// per position under packed 4-bit lanes than fp32.
    pub kv_bytes_read: u64,
    /// Sessions evicted by the page-pressure guard (pool ran dry
    /// mid-step), a subset of `evicted`.
    pub page_preemptions: usize,
    /// KV pages copied to the host tier by spill-evictions (instead of
    /// being discarded for recompute).
    pub pages_spilled: usize,
    /// Bytes those spilled pages carried (packed on-device layout).
    pub spill_bytes: u64,
    /// Re-admissions served by a host-tier block-table splice instead of a
    /// prefill replay.
    pub restores: usize,
    /// In-flight sessions requeued (not failed) by
    /// `Engine::recover_after_panic` under `SchedulerConfig::resurrect`.
    pub resurrections: usize,
    /// Context tokens scheduled for re-prefill by those resurrections
    /// (prompt + already-generated, the recompute debt of each replay).
    pub replay_tokens: usize,
    /// Latest KV page-pool gauges (sampled once per engine step).
    pages_in_use: usize,
    pages_free: usize,
    /// Latest host-tier gauges (spilled pages resident / bytes held).
    host_pages: usize,
    host_bytes: u64,
    /// Running mean of tail fragmentation across sampled steps.
    frag_sum: f64,
    frag_samples: usize,
    pub steps: usize,
    pub decode_tokens: usize,
    pub prefill_tokens: usize,
    pub completed: usize,
    /// Streams retired because the client dropped its receiver mid-flight
    /// (a subset of `completed`).
    pub disconnected: usize,
    pub rejected: usize,
    pub evicted: usize,
    /// In-flight sessions terminated by `Engine::abort` with a
    /// `Finished(Aborted)` event (never `Rejected` — that is reserved for
    /// requests that never entered the engine).
    pub aborted: usize,
    /// Sessions retired as `Finished(Failed)` by engine supervision
    /// (forward panic, watchdog kill, engine-thread restart); a subset of
    /// `completed` — the stream still ends in exactly one terminal event.
    pub failed: usize,
    /// Sessions killed by the micro-step stall watchdog
    /// (`SchedulerConfig::step_deadline`); a subset of `failed`.
    pub watchdog_kills: usize,
    started: Option<std::time::Instant>,
    wall: Duration,
}

impl Default for MetricsCollector {
    fn default() -> MetricsCollector {
        MetricsCollector::with_raw_cap(RAW_SAMPLE_CAP)
    }
}

impl MetricsCollector {
    /// A collector retaining at most `cap` raw samples per latency series
    /// (tests use tiny caps to pin the histogram-fallback path).
    pub fn with_raw_cap(cap: usize) -> MetricsCollector {
        MetricsCollector {
            ttft: SampleSet::new(cap),
            itl: SampleSet::new(cap),
            resume_gap: SampleSet::new(cap),
            occupancy: Histogram::new(),
            occ_sum: 0,
            occ_samples: 0,
            occ_peak: 0,
            fused_rows: 0,
            fused_steps: 0,
            fused_gemms: 0,
            kv_bytes_read: 0,
            page_preemptions: 0,
            pages_spilled: 0,
            spill_bytes: 0,
            restores: 0,
            resurrections: 0,
            replay_tokens: 0,
            pages_in_use: 0,
            pages_free: 0,
            host_pages: 0,
            host_bytes: 0,
            frag_sum: 0.0,
            frag_samples: 0,
            steps: 0,
            decode_tokens: 0,
            prefill_tokens: 0,
            completed: 0,
            disconnected: 0,
            rejected: 0,
            evicted: 0,
            aborted: 0,
            failed: 0,
            watchdog_kills: 0,
            started: None,
            wall: Duration::ZERO,
        }
    }

    pub fn start(&mut self) {
        self.started = Some(clock::now());
    }

    pub fn finish(&mut self) {
        if let Some(t0) = self.started.take() {
            self.wall += clock::now().saturating_duration_since(t0);
        }
    }

    /// One engine step: how many sessions were active, and how many decode /
    /// prefill tokens the step produced.
    pub fn record_step(&mut self, active: usize, decoded: usize, prefilled: usize) {
        self.steps += 1;
        self.occupancy.record(active as u64);
        self.occ_sum += active as u64;
        self.occ_samples += 1;
        self.occ_peak = self.occ_peak.max(active);
        self.decode_tokens += decoded;
        self.prefill_tokens += prefilled;
    }

    /// One fused batched forward: `rows` sequences rode the batch, costing
    /// `gemms` fused GEMM launches (vs `rows * gemms` unfused).
    pub fn record_fused(&mut self, rows: usize, gemms: u64) {
        self.fused_steps += 1;
        self.fused_gemms += gemms;
        self.fused_rows += rows as u64;
    }

    /// KV lane bytes one forwarded row's attention read.
    pub fn record_kv_read(&mut self, bytes: u64) {
        self.kv_bytes_read += bytes;
    }

    /// One per-step sample of the KV page pool: pages held / free and the
    /// tail fragmentation of the held pages.
    pub fn record_pages(&mut self, in_use: usize, free: usize, fragmentation: f64) {
        self.pages_in_use = in_use;
        self.pages_free = free;
        self.frag_sum += fragmentation;
        self.frag_samples += 1;
    }

    /// One sample of the host spill tier: resident spilled pages and the
    /// bytes they hold.
    pub fn record_host(&mut self, pages: usize, bytes: u64) {
        self.host_pages = pages;
        self.host_bytes = bytes;
    }

    pub fn record_first_token(&mut self, since_submit: Duration) {
        self.ttft.record(since_submit.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn record_inter_token(&mut self, gap: Duration) {
        self.itl.record(gap.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// First token after a preemption replay: the whole bubble (eviction +
    /// queue wait + re-prefill) in one sample, kept out of the ITL series.
    pub fn record_resume_gap(&mut self, gap: Duration) {
        self.resume_gap.record(gap.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn record_completion(&mut self, reason: FinishReason) {
        self.completed += 1;
        match reason {
            FinishReason::Disconnected => self.disconnected += 1,
            FinishReason::Failed => self.failed += 1,
            _ => {}
        }
    }

    /// The TTFT series (histogram + drop accounting), for exporters.
    pub fn ttft(&self) -> &SampleSet {
        &self.ttft
    }

    /// The ITL series (histogram + drop accounting), for exporters.
    pub fn itl(&self) -> &SampleSet {
        &self.itl
    }

    /// The preemption resume-gap series, for exporters.
    pub fn resume_gap(&self) -> &SampleSet {
        &self.resume_gap
    }

    pub fn report(&self) -> MetricsReport {
        let wall = match self.started {
            Some(t0) => self.wall + clock::now().saturating_duration_since(t0),
            None => self.wall,
        };
        let secs = wall.as_secs_f64();
        let ttft = self.ttft.percentiles(&[0.50, 0.99]);
        let itl = self.itl.percentiles(&[0.50, 0.99]);
        let resume = self.resume_gap.percentiles(&[0.50, 0.99]);
        MetricsReport {
            completed: self.completed,
            disconnected: self.disconnected,
            rejected: self.rejected,
            evicted: self.evicted,
            aborted: self.aborted,
            failed: self.failed,
            watchdog_kills: self.watchdog_kills,
            steps: self.steps,
            decode_tokens: self.decode_tokens,
            prefill_tokens: self.prefill_tokens,
            ttft_p50: ttft[0],
            ttft_p99: ttft[1],
            itl_p50: itl[0],
            itl_p99: itl[1],
            resume_gaps: self.resume_gap.count(),
            resume_gap_p50: resume[0],
            resume_gap_p99: resume[1],
            decode_tps: if secs > 0.0 { self.decode_tokens as f64 / secs } else { 0.0 },
            mean_occupancy: self.occ_sum as f64 / self.occ_samples.max(1) as f64,
            peak_occupancy: self.occ_peak,
            pages_in_use: self.pages_in_use,
            pages_free: self.pages_free,
            page_fragmentation: self.frag_sum / self.frag_samples.max(1) as f64,
            page_preemptions: self.page_preemptions,
            pages_spilled: self.pages_spilled,
            spill_bytes: self.spill_bytes,
            restores: self.restores,
            resurrections: self.resurrections,
            replay_tokens: self.replay_tokens,
            host_pages: self.host_pages,
            host_bytes: self.host_bytes,
            fused_steps: self.fused_steps,
            fused_gemms: self.fused_gemms,
            mean_fused_batch: self.fused_rows as f64 / self.fused_steps.max(1) as f64,
            kv_bytes_read: self.kv_bytes_read,
            kv_bytes_per_token: self.kv_bytes_read as f64
                / (self.decode_tokens + self.prefill_tokens).max(1) as f64,
            samples_dropped: self.ttft.dropped + self.itl.dropped + self.resume_gap.dropped,
            wall,
        }
    }

    /// The collector as a named-metric registry (counters, gauges, and the
    /// TTFT/ITL/occupancy histograms) plus worker-pool series, for
    /// Prometheus export.
    pub fn registry(&self, pool: &PoolStats) -> Registry {
        let r = self.report();
        let mut reg = Registry::new();
        reg.histogram(
            "llmdt_ttft_seconds",
            "Submission to first streamed token.",
            self.ttft.hist.clone(),
            1e-9,
        );
        reg.histogram(
            "llmdt_itl_seconds",
            "Gap between consecutive streamed tokens.",
            self.itl.hist.clone(),
            1e-9,
        );
        reg.histogram(
            "llmdt_resume_gap_seconds",
            "Last pre-preemption token to first replayed token (scheduler bubble).",
            self.resume_gap.hist.clone(),
            1e-9,
        );
        reg.histogram(
            "llmdt_step_occupancy",
            "Active sessions per engine step.",
            self.occupancy.clone(),
            1.0,
        );
        reg.counter("llmdt_completed_total", "Requests finished.", r.completed as u64);
        reg.counter(
            "llmdt_disconnected_total",
            "Streams retired because the client went away mid-flight.",
            r.disconnected as u64,
        );
        reg.counter("llmdt_rejected_total", "Requests refused at submit.", r.rejected as u64);
        reg.counter("llmdt_evicted_total", "Sessions preempted out of their slot.", r.evicted as u64);
        reg.counter(
            "llmdt_aborted_total",
            "In-flight sessions terminated by engine shutdown.",
            r.aborted as u64,
        );
        reg.counter(
            "llmdt_sessions_failed_total",
            "Sessions retired as Finished(Failed) by engine supervision.",
            r.failed as u64,
        );
        reg.counter(
            "llmdt_watchdog_kills_total",
            "Sessions killed by the micro-step stall watchdog.",
            r.watchdog_kills as u64,
        );
        // fault-injection accounting: emitted unconditionally (zero when
        // disarmed) so CI can grep for the series' presence deterministically
        reg.counter(
            "llmdt_faults_injected_total",
            "Faults fired across every injection site since the last arm.",
            crate::faults::injected_total(),
        );
        for (site, fired) in crate::faults::counters() {
            reg.counter(
                &format!("llmdt_faults_{site}_total"),
                "Faults fired at this injection site since the last arm.",
                fired,
            );
        }
        reg.counter(
            "llmdt_page_preemptions_total",
            "Evictions forced by KV page-pool pressure.",
            r.page_preemptions as u64,
        );
        reg.counter(
            "llmdt_pages_spilled_total",
            "KV pages copied to the host tier by spill-evictions.",
            r.pages_spilled as u64,
        );
        reg.counter(
            "llmdt_spill_bytes_total",
            "Bytes spilled to the host tier (packed on-device layout).",
            r.spill_bytes,
        );
        reg.counter(
            "llmdt_restores_total",
            "Re-admissions served by a host-tier splice instead of a prefill replay.",
            r.restores as u64,
        );
        reg.counter(
            "llmdt_resurrections_total",
            "In-flight sessions requeued (not failed) across an engine restart.",
            r.resurrections as u64,
        );
        reg.counter(
            "llmdt_replay_tokens_total",
            "Context tokens scheduled for re-prefill by resurrections.",
            r.replay_tokens as u64,
        );
        reg.gauge(
            "llmdt_host_pages",
            "Spilled KV pages resident on the host tier at the last sample.",
            r.host_pages as f64,
        );
        reg.gauge(
            "llmdt_host_bytes",
            "Host-tier bytes held at the last sample.",
            r.host_bytes as f64,
        );
        reg.counter("llmdt_steps_total", "Engine steps.", r.steps as u64);
        reg.counter("llmdt_decode_tokens_total", "Generated tokens.", r.decode_tokens as u64);
        reg.counter("llmdt_prefill_tokens_total", "Prefilled context tokens.", r.prefill_tokens as u64);
        reg.counter("llmdt_fused_steps_total", "Fused batched forwards.", r.fused_steps as u64);
        reg.counter("llmdt_fused_gemms_total", "Fused GEMM launches.", r.fused_gemms);
        reg.counter("llmdt_kv_bytes_read_total", "KV lane bytes attention read.", r.kv_bytes_read);
        reg.counter(
            "llmdt_samples_dropped_total",
            "Raw latency samples past the retention cap (histograms keep them all).",
            r.samples_dropped,
        );
        reg.gauge("llmdt_pages_in_use", "KV pages held at the last sampled step.", r.pages_in_use as f64);
        reg.gauge("llmdt_pages_free", "KV pages free at the last sampled step.", r.pages_free as f64);
        reg.gauge(
            "llmdt_page_fragmentation",
            "Mean tail fragmentation of held pages, in [0, 1].",
            r.page_fragmentation,
        );
        reg.gauge("llmdt_peak_occupancy", "Most sessions concurrently active.", r.peak_occupancy as f64);
        reg.gauge(
            "llmdt_decode_tokens_per_second",
            "Sustained generated tokens per wall-clock second.",
            r.decode_tps,
        );
        // info-style gauge: the value is the dispatch code of the kernel
        // ISA every gemm / LUT-expansion / paged-attention call routes
        // through right now (0 = scalar, 1 = neon, 2 = avx2). Scalar on a
        // vector-capable host means the force-scalar lever is on.
        reg.gauge(
            "llmdt_kernel_dispatch",
            "Active SIMD kernel ISA (0 = scalar, 1 = neon, 2 = avx2).",
            crate::tensor::simd::active().code() as f64,
        );
        reg.gauge("llmdt_pool_workers", "Worker-pool threads.", pool.workers as f64);
        reg.gauge(
            "llmdt_pool_utilization",
            "Fraction of pool tasks executed by pool workers (vs the caller).",
            pool.utilization(),
        );
        reg.counter("llmdt_pool_dispatches_total", "Parallel scope dispatches.", pool.dispatches);
        reg.counter(
            "llmdt_pool_tasks_total",
            "Tasks run across pool workers and callers.",
            pool.pool_tasks + pool.caller_tasks,
        );
        reg
    }
}

/// Final engine-run summary.
#[derive(Clone, Debug)]
pub struct MetricsReport {
    pub completed: usize,
    /// Streams retired with `FinishReason::Disconnected` (client went away
    /// mid-flight; a subset of `completed`).
    pub disconnected: usize,
    pub rejected: usize,
    pub evicted: usize,
    /// In-flight sessions ended by `Engine::abort` (`Finished(Aborted)`).
    pub aborted: usize,
    /// Sessions retired as `Finished(Failed)` by supervision (a subset of
    /// `completed`).
    pub failed: usize,
    /// Stall-watchdog kills (a subset of `failed`).
    pub watchdog_kills: usize,
    pub steps: usize,
    pub decode_tokens: usize,
    pub prefill_tokens: usize,
    pub ttft_p50: Duration,
    pub ttft_p99: Duration,
    pub itl_p50: Duration,
    pub itl_p99: Duration,
    /// Preemption resume bubbles observed (one per resumed stream segment);
    /// their latency lives in its own series so ITL stays a decode-cadence
    /// figure.
    pub resume_gaps: u64,
    pub resume_gap_p50: Duration,
    pub resume_gap_p99: Duration,
    /// Sustained generated tokens per wall-clock second.
    pub decode_tps: f64,
    /// Mean active sessions per step.
    pub mean_occupancy: f64,
    /// Most sessions concurrently active at any step — the paged
    /// engine's admission headline (a page pool admits sequence mixes
    /// whose summed worst case exceeds its positions).
    pub peak_occupancy: usize,
    /// KV pages held at the last sampled step.
    pub pages_in_use: usize,
    /// KV pages free at the last sampled step.
    pub pages_free: usize,
    /// Mean tail fragmentation of held pages across the run, in [0, 1]
    /// (positions allocated but not holding a committed row).
    pub page_fragmentation: f64,
    /// Sessions evicted because the page pool ran dry mid-step.
    pub page_preemptions: usize,
    /// KV pages copied to the host tier by spill-evictions.
    pub pages_spilled: usize,
    /// Bytes spilled to the host tier (packed on-device layout).
    pub spill_bytes: u64,
    /// Re-admissions served by a host-tier splice instead of a replay.
    pub restores: usize,
    /// In-flight sessions requeued (not failed) across engine restarts.
    pub resurrections: usize,
    /// Context tokens scheduled for re-prefill by those resurrections.
    pub replay_tokens: usize,
    /// Spilled pages resident on the host tier at the last sample.
    pub host_pages: usize,
    /// Host-tier bytes held at the last sample.
    pub host_bytes: u64,
    /// Fused batched forwards issued.
    pub fused_steps: usize,
    /// Fused GEMM launches across the run.
    pub fused_gemms: u64,
    /// Mean rows per fused batched forward (batched-step occupancy).
    pub mean_fused_batch: f64,
    /// Total KV lane bytes attention read across the run.
    pub kv_bytes_read: u64,
    /// KV bytes read per forwarded token (decode + prefill) — the traffic
    /// figure the packed KV backend exists to shrink.
    pub kv_bytes_per_token: f64,
    /// Raw latency samples dropped past [`RAW_SAMPLE_CAP`]; when non-zero,
    /// the latency percentiles above are histogram-resolution (within one
    /// bucket width) rather than sample-exact.
    pub samples_dropped: u64,
    pub wall: Duration,
}

impl fmt::Display for MetricsReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "completed {} (rejected {}, evicted {}) | {} steps, {} decode + {} prefill tok \
             | {:.1} tok/s decode | ttft p50 {:?} p99 {:?} | itl p50 {:?} p99 {:?} \
             | occupancy {:.2} (peak {}) | fused {} gemms over {} calls, batch {:.2} \
             | kv {:.1} KiB/tok | pages {} used / {} free, frag {:.2}, {} page-evictions \
             | wall {:?}",
            self.completed,
            self.rejected,
            self.evicted,
            self.steps,
            self.decode_tokens,
            self.prefill_tokens,
            self.decode_tps,
            self.ttft_p50,
            self.ttft_p99,
            self.itl_p50,
            self.itl_p99,
            self.mean_occupancy,
            self.peak_occupancy,
            self.fused_gemms,
            self.fused_steps,
            self.mean_fused_batch,
            self.kv_bytes_per_token / 1024.0,
            self.pages_in_use,
            self.pages_free,
            self.page_fragmentation,
            self.page_preemptions,
            self.wall,
        )?;
        if self.resume_gaps > 0 {
            write!(
                f,
                " | {} resume gaps p50 {:?} p99 {:?}",
                self.resume_gaps, self.resume_gap_p50, self.resume_gap_p99
            )?;
        }
        if self.pages_spilled > 0 || self.restores > 0 {
            write!(
                f,
                " | spilled {} pages ({:.1} KiB) / {} restores",
                self.pages_spilled,
                self.spill_bytes as f64 / 1024.0,
                self.restores
            )?;
        }
        if self.resurrections > 0 {
            write!(
                f,
                " | {} resurrections ({} replay tok)",
                self.resurrections, self.replay_tokens
            )?;
        }
        if self.disconnected > 0 {
            write!(f, " | {} disconnected", self.disconnected)?;
        }
        if self.aborted > 0 {
            write!(f, " | {} aborted", self.aborted)?;
        }
        if self.failed > 0 {
            write!(f, " | {} failed ({} watchdog kills)", self.failed, self.watchdog_kills)?;
        }
        if self.samples_dropped > 0 {
            write!(f, " | {} raw samples dropped (histogram percentiles)", self.samples_dropped)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn percentile_empty_is_zero() {
        assert_eq!(percentile(&[], 0.5), Duration::ZERO);
        assert_eq!(percentile(&[], 0.99), Duration::ZERO);
        assert_eq!(percentile_sorted(&[], 0.0), Duration::ZERO);
        assert_eq!(percentile_sorted(&[], 1.0), Duration::ZERO);
    }

    #[test]
    fn percentile_single_sample_is_every_quantile() {
        let s = [ms(7)];
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(percentile(&s, q), ms(7), "q={q}");
            assert_eq!(percentile_sorted(&s, q), ms(7), "q={q}");
        }
    }

    #[test]
    fn percentile_even_length_nearest_rank() {
        // nearest-rank on [1,2,3,4]: p50 -> 2nd element, p99/p100 -> 4th
        let s = [ms(3), ms(1), ms(4), ms(2)]; // unsorted on purpose
        assert_eq!(percentile(&s, 0.50), ms(2));
        assert_eq!(percentile(&s, 0.75), ms(3));
        assert_eq!(percentile(&s, 0.99), ms(4));
        assert_eq!(percentile(&s, 1.0), ms(4));
        assert_eq!(percentile(&s, 0.0), ms(1));
    }

    #[test]
    fn percentile_odd_length_median_is_middle() {
        let s = [ms(5), ms(1), ms(3)];
        assert_eq!(percentile(&s, 0.5), ms(3));
    }

    #[test]
    fn percentile_sorted_matches_unsorted_and_clamps_q() {
        let sorted = [ms(1), ms(2), ms(3), ms(4), ms(5)];
        for q in [0.0, 0.2, 0.5, 0.8, 0.99, 1.0] {
            assert_eq!(percentile_sorted(&sorted, q), percentile(&sorted, q), "q={q}");
        }
        // out-of-range quantiles clamp instead of panicking
        assert_eq!(percentile_sorted(&sorted, -1.0), ms(1));
        assert_eq!(percentile_sorted(&sorted, 2.0), ms(5));
    }

    #[test]
    fn collector_aggregates() {
        let mut m = MetricsCollector::default();
        m.start();
        std::thread::sleep(Duration::from_millis(2)); // make wall observable
        m.record_step(2, 2, 8);
        m.record_step(4, 4, 0);
        m.record_fused(2, 13);
        m.record_fused(4, 13);
        m.record_kv_read(4096);
        m.record_kv_read(2048);
        m.record_pages(3, 5, 0.5);
        m.record_pages(2, 6, 0.25);
        m.record_first_token(ms(10));
        m.record_inter_token(ms(2));
        m.record_inter_token(ms(4));
        m.record_resume_gap(ms(40));
        m.record_completion(FinishReason::MaxTokens);
        m.record_completion(FinishReason::Disconnected);
        m.record_completion(FinishReason::Failed);
        m.finish();
        let r = m.report();
        assert_eq!(r.steps, 2);
        assert_eq!(r.decode_tokens, 6);
        assert_eq!(r.prefill_tokens, 8);
        assert_eq!(r.completed, 3);
        assert_eq!(r.disconnected, 1, "disconnect sub-count rides completion");
        assert_eq!(r.failed, 1, "failure sub-count rides completion");
        assert!((r.mean_occupancy - 3.0).abs() < 1e-12);
        assert_eq!(r.fused_steps, 2);
        assert_eq!(r.fused_gemms, 26);
        assert_eq!(r.kv_bytes_read, 6144);
        // 14 forwarded tokens (6 decode + 8 prefill)
        assert!((r.kv_bytes_per_token - 6144.0 / 14.0).abs() < 1e-9);
        assert!((r.mean_fused_batch - 3.0).abs() < 1e-12);
        assert_eq!(r.peak_occupancy, 4);
        // page gauges: latest sample wins, fragmentation is the mean
        assert_eq!(r.pages_in_use, 2);
        assert_eq!(r.pages_free, 6);
        assert!((r.page_fragmentation - 0.375).abs() < 1e-12);
        assert_eq!(r.page_preemptions, 0);
        assert_eq!(r.ttft_p50, ms(10));
        assert_eq!(r.itl_p99, ms(4), "the resume bubble stays out of ITL");
        assert_eq!(r.resume_gaps, 1);
        assert_eq!(r.resume_gap_p50, ms(40));
        assert_eq!(r.resume_gap_p99, ms(40));
        assert_eq!(r.samples_dropped, 0, "under the cap: percentiles are exact");
        assert!(r.wall > Duration::ZERO);
        assert!(r.decode_tps > 0.0);
        // report is renderable
        assert!(format!("{r}").contains("tok/s"));
    }

    #[test]
    fn raw_cap_switches_to_histogram_percentiles_and_counts_drops() {
        let mut m = MetricsCollector::with_raw_cap(4);
        for i in 1..=100u64 {
            m.record_inter_token(ms(i));
        }
        let r = m.report();
        assert_eq!(r.samples_dropped, 96);
        assert_eq!(m.itl().count(), 100, "histogram saw every sample");
        assert_eq!(m.itl().dropped(), 96);
        // histogram percentile: within one log-bucket (<= 25 % relative)
        let p50 = r.itl_p50.as_micros() as f64;
        let exact = ms(50).as_micros() as f64;
        assert!(
            p50 <= exact && p50 >= exact * 0.75,
            "p50 {p50} vs exact {exact}"
        );
        // extremes stay exact thanks to the [min, max] clamp
        let r99 = r.itl_p99.as_micros() as f64;
        assert!(r99 <= ms(100).as_micros() as f64 && r99 >= ms(99).as_micros() as f64 * 0.75);
        assert!(format!("{r}").contains("raw samples dropped"));
    }

    #[test]
    fn occupancy_memory_is_bounded_but_stats_are_exact() {
        let mut m = MetricsCollector::default();
        for i in 0..10_000usize {
            m.record_step(i % 7, 1, 0);
        }
        let r = m.report();
        assert_eq!(r.steps, 10_000);
        assert_eq!(r.peak_occupancy, 6);
        let mean: f64 = (0..10_000).map(|i| (i % 7) as f64).sum::<f64>() / 10_000.0;
        assert!((r.mean_occupancy - mean).abs() < 1e-9);
    }

    #[test]
    fn registry_exposes_required_series() {
        let mut m = MetricsCollector::default();
        m.record_step(2, 1, 3);
        m.record_first_token(ms(10));
        m.record_inter_token(ms(2));
        m.record_resume_gap(ms(40));
        m.record_pages(3, 5, 0.1);
        let reg = m.registry(&PoolStats::default());
        for name in [
            "llmdt_ttft_seconds",
            "llmdt_itl_seconds",
            "llmdt_resume_gap_seconds",
            "llmdt_disconnected_total",
            "llmdt_aborted_total",
            "llmdt_step_occupancy",
            "llmdt_pages_in_use",
            "llmdt_pool_utilization",
            "llmdt_decode_tokens_total",
            "llmdt_samples_dropped_total",
            "llmdt_sessions_failed_total",
            "llmdt_watchdog_kills_total",
            "llmdt_kernel_dispatch",
            // spill / resurrection series are present (zero) even when the
            // host tier is disabled, so dashboards and CI greps never 404
            "llmdt_pages_spilled_total",
            "llmdt_spill_bytes_total",
            "llmdt_restores_total",
            "llmdt_resurrections_total",
            "llmdt_replay_tokens_total",
            "llmdt_host_pages",
            "llmdt_host_bytes",
            // fault series are present (zero) even with injection disarmed
            "llmdt_faults_injected_total",
            "llmdt_faults_pool_worker_panic_total",
            "llmdt_faults_forward_panic_total",
            "llmdt_faults_kv_reserve_fail_total",
            "llmdt_faults_engine_step_panic_total",
            "llmdt_faults_host_tier_fail_total",
            "llmdt_faults_restore_stall_total",
        ] {
            assert!(reg.get(name).is_some(), "missing series {name}");
        }
    }
}
