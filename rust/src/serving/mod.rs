//! Continuous-batching autoregressive decode engine over a **paged** KV
//! cache — the serving subsystem the paper's weight-only formats are priced
//! for (memory-bound multi-token decode, not one-shot scoring).
//!
//! Architecture (vLLM-style iteration-level scheduling + block-table
//! paging, sized for the pure-Rust [`crate::nn`] reference path):
//!
//! * [`Engine`] — owns the model (a [`ModelConfig`] + [`Checkpoint`]: fp32,
//!   fake-quant dense from `coordinator::pipeline::fake_quant_checkpoint`,
//!   or true 4-bit packed weights from `packed_checkpoint`, which the
//!   forward decodes in-kernel through the fused `quant::lut_gemm` — ~8x
//!   less weight traffic on the memory-bound decode path), the paged
//!   [`KvCache`] (a global pool of fixed-size pages + per-sequence block
//!   tables; fp32 lanes, or packed 4-bit lanes via
//!   [`EngineConfig::kv_format`] — the paper's codebooks applied to the
//!   cache itself, attended through the fused `tensor::lut_attend`
//!   kernels), the [`Scheduler`] and the metrics. Requests can
//!   be `submit`ted at any time; each `step` fuses chunked prefill and one
//!   decode token for every running sequence into `[B, d]` batched forwards
//!   (`nn::forward_lm_step_batch` — one GEMM per linear instead of `B`),
//!   retires finished sequences, and immediately refills their freed
//!   pages from the queue. Admission is pages-available accounting (no
//!   worst-case per-slot reservation), growth claims pages on demand, and
//!   pool exhaustion preempts the longest-context victim
//!   ([`Engine::preemption_victim`]). `preempt` evicts a session
//!   mid-flight and resumes it later by replaying its context into fresh
//!   pages.
//! * [`DecodeRequest`] / [`TokenEvent`] — the streaming API: each request
//!   brings its own event channel and receives every generated token as it
//!   is produced, then a terminal `Finished` (or `Rejected`).
//! * [`kv_cache`] / [`scheduler`] / [`session`] / [`metrics`] — the parts.
//! * [`http`] — the HTTP/1.1 front end: chunked token streaming, 429
//!   backpressure, graceful drain ([`http::serve`]).
//!
//! The blocking [`Engine::run`] drives `submit`/`step` off an mpsc channel
//! (the coordinator serve shim and the CLI use it); tests drive the same
//! methods directly for deterministic interleavings.

pub mod http;
pub mod kv_cache;
pub mod metrics;
pub mod scheduler;
pub mod session;
pub mod victim;

pub use http::{HttpConfig, HttpServer, HttpStats, ServerExit};
pub use kv_cache::{
    HostEntry, HostTier, KvCache, KvCacheConfig, KvView, PageId, SlotId, SlotView, SpillPolicy,
    DEFAULT_PAGE_SIZE,
};
pub use metrics::{percentile, percentile_sorted, MetricsCollector, MetricsReport};
pub use scheduler::{Scheduler, SchedulerConfig};
pub use session::{DecodeSession, FinishReason, SessionState};
pub use victim::{VictimPolicy, VictimPolicyKind, VictimView};

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::faults;
use crate::model_io::{Checkpoint, ModelConfig};
use crate::nn;
use crate::obs::{clock, trace};
use crate::tensor::Tensor;

/// One fused-forward batch row: (active index, slot, input token, is_prefill).
type Row = (usize, SlotId, i32, bool);

/// Process-unique request ids. Every front end (direct [`DecodeRequest::new`]
/// callers, the loadgen, the HTTP server, the coordinator shim) allocates
/// here: ids key trace tracks (`trace::session_track`) and event streams, so
/// two allocators handing out overlapping ranges would interleave unrelated
/// sessions in every exported timeline.
pub fn next_request_id() -> u64 {
    static NEXT_ID: AtomicU64 = AtomicU64::new(0);
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// One generation request. `id` should come from [`next_request_id`] (it is
/// echoed on every event); hand-rolled ids that collide with another live
/// request will interleave streams confusingly.
pub struct DecodeRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// Generation budget (>= 1; 0 is promoted to 1).
    pub max_new_tokens: usize,
    /// Optional stop token.
    pub eos: Option<i32>,
    /// Per-request event stream (tokens arrive as they are decoded).
    pub events: mpsc::Sender<TokenEvent>,
    pub submitted: Instant,
    /// Client-declared latency budget from `submitted` (`deadline_ms` on
    /// the HTTP wire); the fair-share victim policy preempts the sessions
    /// with the most remaining slack first. `None` = best-effort.
    pub deadline: Option<Duration>,
}

impl DecodeRequest {
    /// Request + its event receiver, with a process-unique id.
    pub fn new(prompt: Vec<i32>, max_new_tokens: usize) -> (DecodeRequest, mpsc::Receiver<TokenEvent>) {
        let (tx, rx) = mpsc::channel();
        (
            DecodeRequest {
                id: next_request_id(),
                prompt,
                max_new_tokens,
                eos: None,
                events: tx,
                submitted: clock::now(),
                deadline: None,
            },
            rx,
        )
    }
}

/// Streamed per-request events.
#[derive(Clone, Debug)]
pub enum TokenEvent {
    /// One generated token (greedy), with its log-probability.
    Token { request: u64, index: usize, token: i32, logprob: f32 },
    /// Terminal: the request completed with `generated` tokens total.
    Finished { request: u64, reason: FinishReason, generated: usize },
    /// Terminal: the request never entered the engine.
    Rejected { request: u64, reason: String },
}

/// Engine sizing knobs.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineConfig {
    /// Concurrent-sequence cap (block tables); 0 = `scheduler.max_batch`.
    pub slots: usize,
    /// Max cache positions per sequence; 0 = the model's positional window.
    pub kv_capacity: usize,
    /// KV lane format: `None` (or `"fp32"`) keeps dense f32 lanes —
    /// bit-identical to the pre-packed engine — while a <= 4-bit codebook
    /// name (`"sf4"`, `"nf4"`, `"e2m1_sp"`, ...) stores the cache packed
    /// (nibble codes + per-head scales) and attends through the fused
    /// dequant kernels: ~8x less KV storage and ~5x less read traffic per
    /// decoded token.
    pub kv_format: Option<&'static str>,
    /// Positions per KV page; 0 = [`kv_cache::DEFAULT_PAGE_SIZE`].
    /// Sequences claim pages on demand as they grow, so admission is
    /// bounded by *pages available*, not by worst-case per-slot lanes.
    pub page_size: usize,
    /// KV page-pool size; 0 = the worst case (`slots` full positional
    /// windows — the old contiguous layout's footprint). Set lower to
    /// oversubscribe: more long-context sequences admit against the same
    /// memory, with page-pressure preemption as the safety valve.
    pub kv_pages: usize,
    /// Host-tier KV budget in bytes; 0 (the default) disables the tier.
    /// When enabled, page-pressure evictions *spill* the victim's packed
    /// page bytes to host memory instead of discarding them, and
    /// re-admission splices the pages back into a fresh block table —
    /// bit-identical to a replayed prefill, minus the recompute.
    pub host_tier_bytes: usize,
    /// Spill-vs-recompute break-even model consulted per eviction.
    pub spill: SpillPolicy,
    pub scheduler: SchedulerConfig,
}

/// The decode engine. See the module docs for the lifecycle.
pub struct Engine {
    model_cfg: ModelConfig,
    ckpt: Checkpoint,
    cache: KvCache,
    sched: Scheduler,
    active: Vec<DecodeSession>,
    metrics: MetricsCollector,
    prefill_chunk: usize,
    /// Host-tier store for spilled KV images, keyed by session id. Entries
    /// live only while their session waits in the admission queue: the
    /// spill path inserts, re-admission takes (restore or fallback), and
    /// every terminal exit for a queued session removes — host pages never
    /// outlive their session (the drain invariant extends to this tier).
    host: HostTier,
    /// Break-even model for spill-vs-recompute (see [`SpillPolicy`]).
    spill: SpillPolicy,
    /// Pages seized from the free list by an injected `kv_page_spike`
    /// (exhaustion pressure), with the remaining step count; always drained
    /// back into the pool before the engine goes idle so the zero-leaked-
    /// pages drain invariant holds even under injection.
    spike: Option<(Vec<PageId>, usize)>,
}

impl Engine {
    pub fn new(model_cfg: ModelConfig, ckpt: Checkpoint, cfg: EngineConfig) -> Engine {
        Engine::try_new(model_cfg, ckpt, cfg).expect("KV cache geometry overflows")
    }

    /// [`Engine::new`], but an absurd KV geometry (a `kv_pages` ×
    /// `page_size` × model product that overflows `usize`) surfaces as an
    /// error instead of a panic — the CLI reports it to the user.
    pub fn try_new(model_cfg: ModelConfig, ckpt: Checkpoint, cfg: EngineConfig) -> Result<Engine> {
        let slots = (if cfg.slots == 0 { cfg.scheduler.max_batch } else { cfg.slots }).max(1);
        let capacity = if cfg.kv_capacity == 0 {
            model_cfg.seq
        } else {
            cfg.kv_capacity.min(model_cfg.seq)
        };
        let page_size = if cfg.page_size == 0 {
            kv_cache::DEFAULT_PAGE_SIZE.min(capacity)
        } else {
            cfg.page_size.min(capacity)
        };
        let pages = if cfg.kv_pages == 0 {
            slots * capacity.div_ceil(page_size)
        } else {
            cfg.kv_pages
        };
        let kcfg = KvCacheConfig::try_new(
            slots,
            capacity,
            model_cfg.n_layers,
            model_cfg.d_model,
            page_size,
            pages,
        )?;
        let cache = match cfg.kv_format {
            None | Some("fp32") => KvCache::new(kcfg),
            Some(name) => KvCache::new_packed(
                kcfg,
                crate::quant::KvFormat::for_model(&crate::formats::must(name), &model_cfg),
            ),
        };
        // one-time kernel dispatch record: which ISA this engine's gemm /
        // LUT-expansion / paged-attention microkernels selected (scalar may
        // mean "forced" via LLMDT_FORCE_SCALAR / --force-scalar)
        let isa = crate::tensor::simd::active();
        if trace::enabled() {
            trace::instant(trace::named_track("engine"), "kernel", "isa_selected", &[(
                "isa",
                isa.code() as f64,
            )]);
        }
        Ok(Engine {
            model_cfg,
            ckpt,
            cache,
            sched: Scheduler::new(cfg.scheduler),
            active: Vec::new(),
            metrics: MetricsCollector::default(),
            prefill_chunk: cfg.scheduler.prefill_chunk.max(1),
            host: HostTier::new(cfg.host_tier_bytes),
            spill: cfg.spill,
            spike: None,
        })
    }

    pub fn model_config(&self) -> &ModelConfig {
        &self.model_cfg
    }

    pub fn cache(&self) -> &KvCache {
        &self.cache
    }

    /// The host-tier spill store (occupancy probes; the drain invariant —
    /// zero host pages once the queue empties — is asserted through this).
    pub fn host_tier(&self) -> &HostTier {
        &self.host
    }

    /// Positions one sequence may occupy (prompt + generated - 1). Clamped
    /// by the page pool as well as the positional window: a sequence can
    /// never outgrow the pool even when it holds every page, so the
    /// page-pressure guard always has either a victim to evict or a
    /// sequence that has already hit `ContextFull`.
    pub fn window(&self) -> usize {
        self.model_cfg
            .seq
            .min(self.cache.capacity())
            .min(self.cache.config().pool_positions())
    }

    /// Anything queued or running?
    pub fn has_work(&self) -> bool {
        !self.active.is_empty() || !self.sched.is_empty()
    }

    /// Admission-queue depth right now (front-end backpressure probes).
    pub fn queue_len(&self) -> usize {
        self.sched.queue_len()
    }

    /// The scheduler knobs this engine runs with.
    pub fn scheduler_config(&self) -> &SchedulerConfig {
        self.sched.config()
    }

    /// Admit a request (any time, including mid-flight). Empty prompts,
    /// queue overflow and — under [`SchedulerConfig::reject_saturated`] —
    /// KV page-pool saturation are rejected via a terminal
    /// [`TokenEvent::Rejected`]; over-long prompts are clamped to the most
    /// recent `window()` tokens. Returns `true` iff the request actually
    /// entered the admission queue (callers like [`Engine::run`] use this
    /// to decide whether a coalescing window is worth holding).
    pub fn submit(&mut self, mut req: DecodeRequest) -> bool {
        if req.prompt.is_empty() {
            self.metrics.rejected += 1;
            let _ = req
                .events
                .send(TokenEvent::Rejected { request: req.id, reason: "empty prompt".into() });
            return false;
        }
        let window = self.window();
        if req.prompt.len() > window {
            req.prompt.drain(..req.prompt.len() - window);
        }
        // Saturation backpressure: if others already wait and the pool
        // cannot hold this arrival's first admission (replayed context + one
        // decode row), answering "try later" now beats queuing it behind an
        // unbounded wait. Mirrors the admission plan in `step`.
        if self.sched.config().reject_saturated && !self.sched.is_empty() {
            let need = (req.prompt.len() + 1).min(window).div_ceil(self.cache.page_size());
            if need > self.cache.pages_free() {
                self.metrics.rejected += 1;
                let _ = req.events.send(TokenEvent::Rejected {
                    request: req.id,
                    reason: "page pool saturated".into(),
                });
                return false;
            }
        }
        let mut s = DecodeSession::new(
            req.id,
            req.prompt,
            req.max_new_tokens,
            req.eos,
            req.events,
            req.submitted,
        );
        s.deadline = req.deadline;
        match self.sched.enqueue(s) {
            Ok(()) => true,
            Err(s) => {
                self.metrics.rejected += 1;
                let _ = s
                    .events
                    .send(TokenEvent::Rejected { request: s.id, reason: "queue full".into() });
                false
            }
        }
    }

    /// One iteration-level step: admit queued sessions against the page
    /// pool, then drive every active session through **fused batched
    /// forwards** — `[B, d]` rows through `nn::forward_lm_step_batch`, one
    /// GEMM per linear per micro-step instead of `B`. The first micro-step
    /// carries one decode row per `Decoding` session plus one prefill row
    /// per `Prefill` session; the remaining `prefill_chunk - 1` micro-steps
    /// carry prefill rows only, so prompt ingestion keeps its per-step chunk
    /// budget while decode stays at one token per session per step. A
    /// session whose context completes emits its next token from its own
    /// batch row. Finished (or evicted) sessions are retired and their
    /// pages freed for the next step's admission.
    ///
    /// Admission is *pages-available* accounting: a queued session joins
    /// when a block table is free and the pool holds enough free pages for
    /// its replayed context plus one generated row — not a worst-case
    /// `capacity`-position reservation — so sequence mixes whose summed
    /// window exceeds the pool's positions run concurrently. Sessions
    /// claim further pages on demand as they decode; if the pool runs dry
    /// mid-step, the page-pressure guard preempts the longest-context
    /// victim (see [`Engine::preemption_victim`]) until the step fits.
    pub fn step(&mut self) -> Result<()> {
        let step_t0 = trace::start();
        if faults::enabled() {
            self.maybe_spike_pages();
        }
        let window = self.window();
        {
            let page_size = self.cache.page_size();
            let mut budget = self.cache.pages_free();
            let admitted =
                self.sched.admit_within(self.cache.slots_free(), self.active.len(), |s| {
                    // pages for the replayed context plus the first decode
                    // row (a plan, not a reservation: growth beyond it is
                    // handled by on-demand claims + the pressure guard)
                    let need = (s.context_len() + 1).min(window).div_ceil(page_size);
                    if need <= budget {
                        budget -= need;
                        true
                    } else {
                        false
                    }
                });
            for mut s in admitted {
                // admit_within checked slots_free(), so allocate() cannot
                // come up empty short of an accounting bug — but a bug there
                // must not abort the serving loop: hand the arrival back to
                // the queue head and let the next step retry
                let Some(slot) = self.cache.allocate() else {
                    if let Err(s) = self.sched.enqueue_front(s) {
                        self.host.remove(s.id);
                        self.metrics.rejected += 1;
                        let _ = s.events.send(TokenEvent::Rejected {
                            request: s.id,
                            reason: "engine slot accounting degraded".into(),
                        });
                    }
                    continue;
                };
                let now = clock::now();
                if trace::enabled() {
                    trace::complete(
                        trace::session_track(s.id),
                        "session",
                        "queued",
                        clock::micros_since_epoch(s.queued_at),
                        clock::micros_since_epoch(now),
                        &[
                            ("context_len", s.context_len() as f64),
                            ("pages_free", self.cache.pages_free() as f64),
                        ],
                    );
                }
                s.phase_started_at = now;
                match self.host.take(s.id) {
                    // spilled image on the host tier: splice it back into
                    // the fresh block table and skip the prefill replay.
                    // Restore can stall (injected slow host link — the
                    // bubble lands in the session's resume_gap via
                    // `resumed_from`, never its ITL) or fail (injected
                    // transfer failure, or a pool raced dry), in which case
                    // the entry is dropped and the session falls back to
                    // the ordinary recompute replay — strictly the
                    // pre-spill behavior.
                    Some(entry) => {
                        if faults::fire(faults::Site::RestoreStall) && clock::is_fake() {
                            clock::advance(faults::stall());
                        }
                        let restored = !faults::fire(faults::Site::HostTierFail)
                            && self.cache.restore_slot(slot, &entry);
                        if restored {
                            s.restore(slot, entry.len);
                            self.metrics.restores += 1;
                            if trace::enabled() {
                                trace::instant(
                                    trace::session_track(s.id),
                                    "session",
                                    "restore",
                                    &[("positions", entry.len as f64)],
                                );
                            }
                        } else {
                            s.begin_prefill(slot);
                        }
                    }
                    None => s.begin_prefill(slot),
                }
                self.active.push(s);
            }
        }

        let stepped = self.active.len();
        let gemms_per_call = nn::step_batch_gemms(&self.model_cfg);
        let deadline = self.sched.config().step_deadline;
        let mut decoded = 0usize;
        let mut prefilled = 0usize;
        for micro in 0..self.prefill_chunk {
            let micro_started = clock::now();
            if faults::fire(faults::Site::ClockSkew) && clock::is_fake() {
                // a deterministic "wedged step": jump the fake clock so the
                // stall watchdog sees a blown deadline without real sleeping
                clock::advance(faults::skew());
            }
            self.resolve_page_pressure(micro);
            // rows: (active index, slot, input token, is_prefill)
            let mut rows: Vec<Row> = Vec::new();
            for (i, s) in self.active.iter().enumerate() {
                match s.state {
                    SessionState::Prefill => rows.push((
                        i,
                        s.slot.expect("prefilling session holds a slot"),
                        s.context_token(s.prefilled),
                        true,
                    )),
                    SessionState::Decoding if micro == 0 => rows.push((
                        i,
                        s.slot.expect("decoding session holds a slot"),
                        s.last_token(),
                        false,
                    )),
                    _ => {}
                }
            }
            if rows.is_empty() {
                break;
            }
            let micro_t0 = trace::start();
            // the forward runs under catch_unwind supervision: a panicking
            // row (injected fault, poisoned session, pool-worker death)
            // retires as Finished(Failed) while the surviving rows' logits
            // come back bit-identical to an undisturbed batch
            let (rows, logits) = self.supervised_forward(rows)?;
            let n_prefill_rows =
                micro_t0.map(|_| rows.iter().filter(|&&(_, _, _, p)| p).count());
            if let Some(logits) = &logits {
                self.metrics.record_fused(rows.len(), gemms_per_call);
                // KV traffic: each row's attention streamed its whole
                // committed history (now len(slot) positions) per layer
                let pos_bytes =
                    (self.cache.position_bytes() * self.model_cfg.n_layers) as u64;
                for &(_, slot, _, _) in &rows {
                    self.metrics.record_kv_read(self.cache.len(slot) as u64 * pos_bytes);
                }
                for (r, &(i, slot, _, is_prefill)) in rows.iter().enumerate() {
                    let s = &mut self.active[i];
                    if is_prefill {
                        s.prefilled += 1;
                        prefilled += 1;
                        if s.prefilled < s.context_len() {
                            continue;
                        }
                        let now = clock::now();
                        if trace::enabled() {
                            trace::complete(
                                trace::session_track(s.id),
                                "session",
                                "prefill",
                                clock::micros_since_epoch(s.phase_started_at),
                                clock::micros_since_epoch(now),
                                &[("tokens", s.context_len() as f64)],
                            );
                        }
                        s.phase_started_at = now;
                        s.begin_decode();
                    } else {
                        decoded += 1;
                    }
                    let remaining = window - self.cache.len(slot);
                    emit_token(s, logits.row(r), remaining, &mut self.metrics);
                }
            }
            if let Some(t0) = micro_t0 {
                trace::complete_here(
                    "engine",
                    "engine.micro_step",
                    t0,
                    &[
                        ("rows", rows.len() as f64),
                        ("prefill_rows", n_prefill_rows.unwrap_or(0) as f64),
                        ("decode_rows", (rows.len() - n_prefill_rows.unwrap_or(0)) as f64),
                        ("pages_in_use", self.cache.pages_in_use() as f64),
                        ("pages_free", self.cache.pages_free() as f64),
                    ],
                );
            }
            // stall watchdog: a micro-step that blew the deadline kills the
            // batch row holding the most KV pages (the likeliest wedge) so
            // the rest of the batch keeps serving instead of timing out
            if !deadline.is_zero()
                && clock::now().saturating_duration_since(micro_started) > deadline
            {
                self.watchdog_kill(&rows);
            }
        }
        if stepped > 0 {
            self.metrics.record_step(stepped, decoded, prefilled);
        }

        // retire: free slots first so the next step's admission sees them.
        // Evicted sessions must release their slot here too — skipping them
        // (as the pre-batched engine did) leaked the slot on any eviction
        // that wasn't routed through `abort`.
        for s in &mut self.active {
            match s.state {
                SessionState::Done(reason) => {
                    if let Some(slot) = s.slot.take() {
                        self.cache.free(slot);
                    }
                    if trace::enabled() {
                        let track = trace::session_track(s.id);
                        trace::complete(
                            track,
                            "session",
                            "decode",
                            clock::micros_since_epoch(s.phase_started_at),
                            clock::now_micros(),
                            &[("generated", s.generated.len() as f64)],
                        );
                        trace::instant(track, "session", "finished", &[(
                            "generated",
                            s.generated.len() as f64,
                        )]);
                    }
                    self.metrics.record_completion(reason);
                    let _ = s.events.send(TokenEvent::Finished {
                        request: s.id,
                        reason,
                        generated: s.generated.len(),
                    });
                }
                SessionState::Evicted => {
                    if let Some(slot) = s.slot.take() {
                        self.cache.free(slot);
                    }
                }
                _ => {}
            }
        }
        self.active.retain(|s| s.is_active());
        self.metrics.record_pages(
            self.cache.pages_in_use(),
            self.cache.pages_free(),
            self.cache.page_fragmentation(),
        );
        if self.host.enabled() {
            self.metrics.record_host(self.host.pages_in_use(), self.host.bytes_in_use() as u64);
        }
        if let Some(t0) = step_t0 {
            trace::complete_here(
                "engine",
                "engine.step",
                t0,
                &[
                    ("active", stepped as f64),
                    ("decoded", decoded as f64),
                    ("prefilled", prefilled as f64),
                    ("pages_in_use", self.cache.pages_in_use() as f64),
                    ("pages_free", self.cache.pages_free() as f64),
                ],
            );
        }
        if self.spike.is_some() {
            self.tick_spike();
        }
        // end-of-step placement on purpose: sessions admitted this step are
        // in flight when the panic unwinds, exercising the supervisor's
        // recover-and-restart path rather than an empty engine
        if faults::fire(faults::Site::EngineStepPanic) {
            panic!("{} engine step panic", faults::PANIC_MARK);
        }
        Ok(())
    }

    /// Run the fused batch forward under `catch_unwind` supervision.
    ///
    /// Returns the surviving rows and their logits (`None` when every row
    /// failed). A panicking row — injected `forward_panic` fault, or a real
    /// panic out of the model/pool stack — retires its session as
    /// [`FinishReason::Failed`] (slot and pages freed immediately), and the
    /// remaining rows are re-attempted as one fused batch: batch rows are
    /// computed independently, so the survivors' logits are bit-identical
    /// to an undisturbed run.
    ///
    /// KV-commit ordering is the hazard here: `forward_lm_step_batch`
    /// advances *all* rows' KV stores after the layer loop but before the
    /// final head projection. A panic before that commit leaves every row
    /// un-appended (safe to re-attempt); a panic after it leaves KV
    /// committed with the logits lost, where a re-attempt would
    /// double-append — detected by comparing committed lengths, and the
    /// whole batch retires as `Failed` instead.
    fn supervised_forward(&mut self, mut rows: Vec<Row>) -> Result<(Vec<Row>, Option<Tensor>)> {
        // injected per-row panic flags are drawn only while armed, so the
        // disabled path allocates nothing and draws no randomness
        let mut injected: Vec<bool> = if faults::enabled() {
            rows.iter().map(|_| faults::fire(faults::Site::ForwardPanic)).collect()
        } else {
            Vec::new()
        };
        loop {
            if rows.is_empty() {
                return Ok((rows, None));
            }
            let slot_ids: Vec<SlotId> = rows.iter().map(|&(_, slot, _, _)| slot).collect();
            let tokens: Vec<i32> = rows.iter().map(|&(_, _, t, _)| t).collect();
            let pre_len = self.cache.len(slot_ids[0]);
            let inject_any = injected.iter().any(|&f| f);
            let attempt = {
                let cache = &mut self.cache;
                let model_cfg = &self.model_cfg;
                let ckpt = &self.ckpt;
                std::panic::catch_unwind(AssertUnwindSafe(|| {
                    if inject_any {
                        panic!("{} forward panic", faults::PANIC_MARK);
                    }
                    let mut views = cache.slots_mut(&slot_ids);
                    let mut stores: Vec<&mut dyn nn::KvStore> =
                        views.iter_mut().map(|v| v as &mut dyn nn::KvStore).collect();
                    nn::forward_lm_step_batch(model_cfg, ckpt, &tokens, &mut stores)
                }))
            };
            match attempt {
                Ok(res) => return Ok((rows, Some(res?))),
                Err(_) if self.cache.len(slot_ids[0]) != pre_len => {
                    // panicked after the KV commit (head projection): the
                    // logits are lost but every row's cache already
                    // advanced, so a re-attempt would double-append. Retire
                    // the whole batch.
                    for &(i, _, _, _) in &rows {
                        self.fail_session(i, "forward panicked after kv commit");
                    }
                    rows.clear();
                    return Ok((rows, None));
                }
                Err(_) if inject_any => {
                    // injected row panics: fail exactly the flagged rows and
                    // re-attempt the rest fused (KV untouched pre-commit)
                    for (k, &(i, _, _, _)) in rows.iter().enumerate() {
                        if injected[k] {
                            self.fail_session(i, "injected forward panic");
                        }
                    }
                    let keep: Vec<Row> = rows
                        .iter()
                        .zip(&injected)
                        .filter(|&(_, &inj)| !inj)
                        .map(|(&row, _)| row)
                        .collect();
                    rows = keep;
                    injected = vec![false; rows.len()];
                }
                Err(_) => {
                    // a real (non-injected) panic somewhere in the fused
                    // forward: probe row-by-row to isolate the poisoned
                    // session(s) and salvage the rest
                    return Ok(self.isolate_rows(rows));
                }
            }
        }
    }

    /// Row-by-row fallback after an unattributed fused-forward panic: each
    /// row re-runs alone under `catch_unwind`; panicking rows retire as
    /// [`FinishReason::Failed`], surviving rows' single-row logits are
    /// reassembled into a `[kept, vocab]` batch (bit-identical to the fused
    /// result by the batch-row independence invariant).
    fn isolate_rows(&mut self, rows: Vec<Row>) -> (Vec<Row>, Option<Tensor>) {
        let mut kept: Vec<Row> = Vec::new();
        let mut data: Vec<f32> = Vec::new();
        for row in rows {
            let (i, slot, token, _) = row;
            let pre_len = self.cache.len(slot);
            let attempt = {
                let cache = &mut self.cache;
                let model_cfg = &self.model_cfg;
                let ckpt = &self.ckpt;
                std::panic::catch_unwind(AssertUnwindSafe(|| {
                    let mut views = cache.slots_mut(&[slot]);
                    let mut stores: Vec<&mut dyn nn::KvStore> =
                        views.iter_mut().map(|v| v as &mut dyn nn::KvStore).collect();
                    nn::forward_lm_step_batch(model_cfg, ckpt, &[token], &mut stores)
                }))
            };
            match attempt {
                Ok(Ok(t)) => {
                    data.extend_from_slice(t.row(0));
                    kept.push(row);
                }
                Ok(Err(_)) => {
                    // a structured forward error on this row alone: retire
                    // it like a panic — the batch path would have aborted
                    // the whole engine on this, so per-row retirement is
                    // strictly gentler
                    self.fail_session(i, "forward error during isolation");
                }
                Err(_) => {
                    let why = if self.cache.len(slot) != pre_len {
                        "row panicked after kv commit"
                    } else {
                        "row panicked in isolation"
                    };
                    self.fail_session(i, why);
                }
            }
        }
        if kept.is_empty() {
            return (kept, None);
        }
        let vocab = data.len() / kept.len();
        let logits = Tensor::new(&[kept.len(), vocab], data);
        (kept, Some(logits))
    }

    /// Retire `active[i]` as [`FinishReason::Failed`]: free its slot and
    /// pages now (the end-of-step retire loop tolerates the taken slot) and
    /// mark it Done — the retire loop then sends the terminal event and
    /// records the completion.
    fn fail_session(&mut self, i: usize, why: &str) {
        if let Some(slot) = self.active[i].slot.take() {
            self.cache.free(slot);
        }
        let s = &mut self.active[i];
        if trace::enabled() {
            trace::instant(trace::session_track(s.id), "session", "failed", &[(
                "generated",
                s.generated.len() as f64,
            )]);
        }
        let _ = why; // carried for debugging/trace symmetry; events stay lean
        s.finish(FinishReason::Failed);
    }

    /// The stall watchdog's kill policy: among this micro-step's rows,
    /// retire one still-active session as [`FinishReason::Failed`] —
    /// chosen by the same configured [`VictimPolicyKind`] as page-pressure
    /// preemption (under the default most-pages policy that is the
    /// likeliest wedge, exactly the pre-policy behavior).
    fn watchdog_kill(&mut self, rows: &[Row]) {
        let cfg = self.sched.config();
        let (kind, cooldown) = (cfg.victim_policy, cfg.resume_cooldown);
        let views: Vec<VictimView> = rows
            .iter()
            .map(|&(i, _, _, _)| i)
            .filter(|&i| self.active[i].is_active())
            .map(|i| self.victim_view(&self.active[i]))
            .collect();
        if let Some(id) = victim::select(kind, &views, cooldown, clock::now()) {
            let i = self.active.iter().position(|s| s.id == id).expect("victim is active");
            self.metrics.watchdog_kills += 1;
            self.fail_session(i, "stall watchdog");
        }
    }

    /// `kv_page_spike` injection: seize free pages out of the pool for a
    /// few steps so admission and growth hit genuine exhaustion pressure.
    fn maybe_spike_pages(&mut self) {
        if self.spike.is_none() && faults::fire(faults::Site::KvPageSpike) {
            let (pages, steps) = faults::spike_shape();
            let seized = self.cache.seize_free_pages(pages);
            if !seized.is_empty() {
                self.spike = Some((seized, steps.max(1)));
            }
        }
    }

    /// Count down an active page spike; release it when it expires or the
    /// engine is about to go idle (seized pages count as in-use, and the
    /// drain invariant is zero pages in use after the queue empties).
    fn tick_spike(&mut self) {
        let expired = match &mut self.spike {
            Some((_, steps)) => {
                *steps = steps.saturating_sub(1);
                *steps == 0
            }
            None => false,
        };
        if expired || !self.has_work() {
            self.release_spike();
        }
    }

    /// Return any spike-seized pages to the free pool.
    fn release_spike(&mut self) {
        if let Some((pages, _)) = self.spike.take() {
            self.cache.return_pages(pages);
        }
    }

    /// Put the engine back into a serveable state after a panic escaped
    /// [`Engine::step`] (caught by a supervisor's `catch_unwind`, e.g. the
    /// HTTP front end's engine thread). Sessions that already finished
    /// retire with their real reason; queued sessions stay queued, so the
    /// supervisor's next `run_with` serves admitted-but-unstarted requests
    /// untouched. In-flight sessions split on
    /// [`SchedulerConfig::resurrect`]:
    ///
    /// * **off** (default): they retire as `Failed` with a terminal event —
    ///   the legacy restart contract (HTTP answers their never-streamed
    ///   requests 503).
    /// * **on**: they are requeued for deterministic resurrection — the
    ///   prompt plus every token already emitted replays through chunked
    ///   prefill into a fresh slot, greedy decode continues the same event
    ///   stream bit-identically, and the client sees a `resume_gap` sample
    ///   instead of a terminal `"failed"` line. `sessions_failed` then
    ///   counts only genuinely poisoned rows (the ones
    ///   [`Engine::supervised_forward`] retired before the panic escaped).
    ///   A full bounded queue falls back to [`FinishReason::Preempted`],
    ///   exactly like [`Engine::preempt`].
    ///
    /// In both modes every slot and its pages return to the pool. The cache
    /// itself is panic-consistent: slot bookkeeping only mutates outside
    /// the unwound forward, and [`Engine::supervised_forward`] already
    /// contains forward-path unwinds.
    pub fn recover_after_panic(&mut self) {
        self.release_spike();
        let resurrect = self.sched.config().resurrect;
        for mut s in std::mem::take(&mut self.active) {
            if let Some(slot) = s.slot.take() {
                self.cache.free(slot);
            }
            if let SessionState::Done(reason) = s.state {
                self.metrics.record_completion(reason);
                let _ = s.events.send(TokenEvent::Finished {
                    request: s.id,
                    reason,
                    generated: s.generated.len(),
                });
                continue;
            }
            if resurrect {
                if s.is_active() {
                    s.evict();
                }
                self.metrics.resurrections += 1;
                self.metrics.replay_tokens += s.context_len();
                s.requeue();
                if let Err(s) = self.sched.enqueue_front(s) {
                    self.host.remove(s.id);
                    let _ = s.events.send(TokenEvent::Finished {
                        request: s.id,
                        reason: FinishReason::Preempted,
                        generated: s.generated.len(),
                    });
                }
                continue;
            }
            self.metrics.record_completion(FinishReason::Failed);
            let _ = s.events.send(TokenEvent::Finished {
                request: s.id,
                reason: FinishReason::Failed,
                generated: s.generated.len(),
            });
        }
        self.metrics.record_pages(
            self.cache.pages_in_use(),
            self.cache.pages_free(),
            self.cache.page_fragmentation(),
        );
        self.metrics.record_host(self.host.pages_in_use(), self.host.bytes_in_use() as u64);
    }

    /// Make sure every row about to step in micro-step `micro` has a page
    /// to append into. Under shortfall it first reclaims pages held by
    /// sessions that already finished earlier in this step (they are
    /// normally retired only at step end — eviction must never cost a
    /// runnable session a replay while free-able pages exist), then
    /// preempts victims until the step fits. Each round either fits
    /// (return), reclaims a finished session's slot, or evicts one active
    /// session, so the loop terminates; evicting every stepping session
    /// leaves nothing to append and also fits.
    fn resolve_page_pressure(&mut self, micro: usize) {
        loop {
            let mut need = 0usize;
            for s in &self.active {
                let stepping = match s.state {
                    SessionState::Prefill => true,
                    SessionState::Decoding => micro == 0,
                    _ => false,
                };
                if stepping
                    && self
                        .cache
                        .next_append_needs_page(s.slot.expect("active session holds a slot"))
                {
                    need += 1;
                }
            }
            if need <= self.cache.pages_free() {
                return;
            }
            // reclaim before evicting: the end-of-step retire loop
            // tolerates already-taken slots, so freeing early is safe
            let mut reclaimed = false;
            for s in &mut self.active {
                if !s.is_active() {
                    if let Some(slot) = s.slot.take() {
                        self.cache.free(slot);
                        reclaimed = true;
                    }
                }
            }
            if reclaimed {
                continue;
            }
            let victim =
                self.preemption_victim().expect("page pressure implies a runnable session");
            if !self.spill_evict(victim) {
                self.preempt(victim);
            }
            self.metrics.page_preemptions += 1;
        }
    }

    /// Try to spill `id`'s KV image to the host tier instead of discarding
    /// it. All of the victim's pages move (attention reads the whole
    /// committed history every step, so there is no colder subset): the
    /// packed page bytes are copied out, the device pages freed, and the
    /// session requeued exactly like [`Engine::preempt`] — except its next
    /// admission splices the image back instead of replaying prefill.
    /// Returns `false` — caller falls back to preempt-and-recompute — when
    /// the tier is disabled, the break-even model favors recompute, the
    /// insert would blow the host budget, or a `host_tier_fail` injection
    /// simulates the copy failing.
    fn spill_evict(&mut self, id: u64) -> bool {
        if !self.host.enabled() {
            return false;
        }
        let Some(i) = self.active.iter().position(|s| s.id == id) else {
            return false;
        };
        let Some(slot) = self.active[i].slot else {
            return false;
        };
        let pages = self.cache.pages_held(slot);
        let bytes = pages * self.cache.page_spill_bytes();
        if pages == 0 || !self.spill.spill_wins(bytes, self.active[i].context_len()) {
            return false;
        }
        if faults::fire(faults::Site::HostTierFail) {
            return false;
        }
        let entry = self.cache.export_slot(slot);
        if self.host.insert(id, entry).is_err() {
            return false;
        }
        self.metrics.pages_spilled += pages;
        self.metrics.spill_bytes += bytes as u64;
        self.evict_to_queue(i, "spill");
        true
    }

    /// The page-pressure eviction choice, delegated to the configured
    /// [`VictimPolicyKind`] over the runnable (prefill/decoding) sessions,
    /// after the resume-cooldown filter ([`victim::select`]). The default
    /// ([`VictimPolicyKind::MostPages`], zero cooldown) reproduces the
    /// pre-policy engine exactly: the session holding the most KV pages,
    /// ties toward the most committed positions, then the most recently
    /// admitted. `None` when nothing runnable is active.
    pub fn preemption_victim(&self) -> Option<u64> {
        let cfg = self.sched.config();
        let (kind, cooldown) = (cfg.victim_policy, cfg.resume_cooldown);
        let views: Vec<VictimView> =
            self.active.iter().filter(|s| s.is_active()).map(|s| self.victim_view(s)).collect();
        victim::select(kind, &views, cooldown, clock::now())
    }

    /// Snapshot one active session for victim selection.
    fn victim_view(&self, s: &DecodeSession) -> VictimView {
        let slot = s.slot.expect("active session holds a slot");
        VictimView {
            id: s.id,
            pages: self.cache.pages_held(slot),
            len: self.cache.len(slot),
            last_token_at: s.last_token_at,
            deadline_slack: s
                .deadline
                .map(|d| d.saturating_sub(clock::now().saturating_duration_since(s.submitted))),
            resumed_at: s.resumed_at,
        }
    }

    /// Preempt an active session: reclaim its KV pages and block table
    /// *now* and send it back to the head of the admission queue. On
    /// re-admission it replays its whole context (prompt + generated so
    /// far) into freshly claimed pages, so the greedy stream resumes
    /// exactly where it stopped — the client just sees a latency bubble.
    /// Returns `false` when `id` is not currently active.
    /// If the bounded queue is full the stream ends with a terminal
    /// [`TokenEvent::Finished`] carrying [`FinishReason::Preempted`]
    /// (`Rejected` is reserved for requests that never started).
    pub fn preempt(&mut self, id: u64) -> bool {
        let i = match self.active.iter().position(|s| s.id == id) {
            Some(i) => i,
            None => return false,
        };
        self.evict_to_queue(i, "preempt");
        true
    }

    /// Shared eviction tail for [`Engine::preempt`] and
    /// [`Engine::spill_evict`]: remove `active[i]`, free its slot and
    /// pages, and send it back to the head of the admission queue. `how`
    /// labels the trace instant (`"preempt"` = recompute on re-admission,
    /// `"spill"` = host-tier restore). If the bounded queue is full the
    /// stream ends with [`FinishReason::Preempted`] and any spilled image
    /// is dropped — a terminal exit must never leave host pages behind.
    fn evict_to_queue(&mut self, i: usize, how: &'static str) {
        let mut s = self.active.remove(i);
        if trace::enabled() {
            let track = trace::session_track(s.id);
            let phase = if s.state == SessionState::Prefill { "prefill" } else { "decode" };
            trace::complete(
                track,
                "session",
                phase,
                clock::micros_since_epoch(s.phase_started_at),
                clock::now_micros(),
                &[],
            );
            let pages = s.slot.map(|slot| self.cache.pages_held(slot)).unwrap_or(0);
            trace::instant(track, "session", how, &[("pages_freed", pages as f64)]);
        }
        if let Some(slot) = s.slot.take() {
            self.cache.free(slot);
        }
        s.evict();
        self.metrics.evicted += 1;
        s.requeue();
        if let Err(s) = self.sched.enqueue_front(s) {
            self.host.remove(s.id);
            let _ = s.events.send(TokenEvent::Finished {
                request: s.id,
                reason: FinishReason::Preempted,
                generated: s.generated.len(),
            });
        }
    }

    /// Serve a request channel until it closes and all work drains; returns
    /// the run's metrics. Blocks when idle; while sequences are in flight it
    /// drains arrivals between steps, so late requests join mid-batch.
    pub fn run(&mut self, rx: mpsc::Receiver<DecodeRequest>) -> Result<MetricsReport> {
        self.run_with(&rx, |_| {})
    }

    /// [`Engine::run`] with an observer called once per loop iteration (and
    /// once before blocking on an idle channel, so idle state publishes
    /// too). The HTTP front end uses it to snapshot the metrics registry
    /// for `/metrics` without sharing the engine across threads.
    ///
    /// The receiver is borrowed, not consumed: a supervisor that catches a
    /// panic out of this loop can recover the engine
    /// ([`Engine::recover_after_panic`]) and re-enter with the same channel,
    /// so queued requests and connected clients survive the restart.
    pub fn run_with(
        &mut self,
        rx: &mpsc::Receiver<DecodeRequest>,
        mut observe: impl FnMut(&Engine),
    ) -> Result<MetricsReport> {
        self.metrics.start();
        let mut open = true;
        while open || self.has_work() {
            observe(self);
            if open {
                if !self.has_work() {
                    // idle: block for the next arrival, then hold the
                    // coalescing window to let a batch form. A rejected
                    // arrival (empty prompt / full queue / saturation)
                    // enqueues nothing, so there is no batch to coalesce:
                    // holding `max_wait` then would be pure dead latency
                    // between the reject and the next blocking recv.
                    match rx.recv() {
                        Ok(r) => {
                            if self.submit(r) {
                                let cfg = *self.sched.config();
                                let deadline = clock::now() + cfg.max_wait;
                                while self.sched.queue_len() < cfg.max_batch {
                                    let now = clock::now();
                                    if now >= deadline {
                                        break;
                                    }
                                    match rx.recv_timeout(deadline - now) {
                                        Ok(r) => {
                                            self.submit(r);
                                        }
                                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                                            open = false;
                                            break;
                                        }
                                    }
                                }
                            }
                        }
                        Err(_) => open = false,
                    }
                }
                loop {
                    match rx.try_recv() {
                        Ok(r) => {
                            self.submit(r);
                        }
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }
            if self.has_work() {
                self.step()?;
            }
        }
        self.metrics.finish();
        observe(self);
        Ok(self.metrics.report())
    }

    /// Drop all queued and in-flight work (terminal events are sent, slots
    /// freed). Used on fatal errors so clients never hang on their streams.
    /// Queued sessions never entered the engine, so they end with
    /// [`TokenEvent::Rejected`]; sessions past admission already streamed on
    /// their channel and end with a terminal [`TokenEvent::Finished`]
    /// carrying [`FinishReason::Aborted`] — a client must never see
    /// `Rejected` after its first token.
    pub fn abort(&mut self) {
        self.release_spike();
        for s in self.sched.drain() {
            self.host.remove(s.id);
            self.metrics.rejected += 1;
            let _ = s
                .events
                .send(TokenEvent::Rejected { request: s.id, reason: "engine aborted".into() });
        }
        for mut s in std::mem::take(&mut self.active) {
            if let Some(slot) = s.slot.take() {
                self.cache.free(slot);
            }
            s.finish(FinishReason::Aborted);
            self.metrics.aborted += 1;
            let _ = s.events.send(TokenEvent::Finished {
                request: s.id,
                reason: FinishReason::Aborted,
                generated: s.generated.len(),
            });
        }
    }

    /// Metrics snapshot (running or finished).
    pub fn report(&self) -> MetricsReport {
        self.metrics.report()
    }

    /// The engine's metrics (plus global worker-pool counters) as a named
    /// registry for Prometheus export ([`crate::obs::export::prometheus_text`]).
    pub fn metrics_registry(&self) -> crate::obs::metrics::Registry {
        self.metrics.registry(&crate::runtime::pool::stats())
    }
}

/// Greedy-pick from one session's logits row (its lane of the fused batch),
/// stream the token, and apply the session's stop conditions given the cache
/// positions still writable. The greedy pick argmaxes the raw logits
/// (log-softmax is monotone, and this is exactly what the re-forwarding
/// references in the equivalence tests do); the log-partition term is
/// computed only for the streamed logprob, with the same arithmetic as
/// `Tensor::log_softmax_last` and no per-token allocation.
fn emit_token(
    s: &mut DecodeSession,
    logits_row: &[f32],
    remaining_window: usize,
    metrics: &mut MetricsCollector,
) {
    let token = crate::tensor::argmax(logits_row) as i32;
    let mx = logits_row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let z: f32 = logits_row.iter().map(|&x| (x - mx).exp()).sum();
    let lz = z.ln() + mx;
    let now = clock::now();
    if let Some(prev) = s.last_token_at {
        metrics.record_inter_token(now.duration_since(prev));
    } else if let Some(prev) = s.resumed_from.take() {
        // first token after a preemption replay: eviction + queue wait +
        // re-prefill is scheduler latency, sampled apart from ITL
        metrics.record_resume_gap(now.duration_since(prev));
    } else {
        metrics.record_first_token(now.duration_since(s.submitted));
        s.first_token_at = Some(now);
    }
    s.last_token_at = Some(now);
    let index = s.generated.len();
    s.generated.push(token);
    let sent = s.events.send(TokenEvent::Token {
        request: s.id,
        index,
        token,
        logprob: logits_row[token as usize] - lz,
    });
    if sent.is_err() {
        s.finish(FinishReason::Disconnected);
        return;
    }
    if let Some(reason) = s.stop_reason(remaining_window) {
        s.finish(reason);
    }
}

/// Drive an engine with `n_clients` synthetic streaming clients issuing
/// `per_client` generation requests each (prompts round-robin); returns the
/// engine's run report. Shared by the CLI, the demo and `perf_serve`.
pub fn run_decode_loadgen(
    engine: &mut Engine,
    prompts: &[Vec<i32>],
    n_clients: usize,
    per_client: usize,
    max_new: usize,
) -> Result<MetricsReport> {
    let (tx, rx) = mpsc::channel::<DecodeRequest>();
    let report = std::thread::scope(|scope| {
        let server = scope.spawn(move || {
            let r = engine.run(rx);
            if r.is_err() {
                // terminal events for everything in flight, so the client
                // threads below always drain and the scope can join
                engine.abort();
            }
            r
        });
        for c in 0..n_clients {
            let tx = tx.clone();
            scope.spawn(move || {
                for i in 0..per_client {
                    let (etx, erx) = mpsc::channel();
                    let prompt = prompts[(c * per_client + i) % prompts.len()].clone();
                    // ids come from the process-global allocator — a local
                    // zero-based counter here once collided with ids minted
                    // by DecodeRequest::new in the same process, fusing
                    // unrelated sessions' trace tracks
                    let req = DecodeRequest {
                        id: next_request_id(),
                        prompt,
                        max_new_tokens: max_new,
                        eos: None,
                        events: etx,
                        submitted: clock::now(),
                        deadline: None,
                    };
                    if tx.send(req).is_err() {
                        return;
                    }
                    // stream this request to completion before the next one
                    for ev in erx {
                        if matches!(ev, TokenEvent::Finished { .. } | TokenEvent::Rejected { .. })
                        {
                            break;
                        }
                    }
                }
            });
        }
        drop(tx);
        server.join().expect("engine thread panicked")
    })?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::init_lm_params;
    use crate::model_io::zoo;

    fn engine(slots: usize) -> Engine {
        let cfg = zoo("nano").unwrap();
        let ckpt = init_lm_params(&cfg, 42);
        Engine::new(
            cfg,
            ckpt,
            EngineConfig {
                slots,
                scheduler: SchedulerConfig { max_batch: slots, ..SchedulerConfig::default() },
                ..EngineConfig::default()
            },
        )
    }

    fn drain_tokens(rx: &mpsc::Receiver<TokenEvent>) -> (usize, Option<FinishReason>) {
        let mut tokens = 0;
        let mut finished = None;
        while let Ok(ev) = rx.try_recv() {
            match ev {
                TokenEvent::Token { .. } => tokens += 1,
                TokenEvent::Finished { reason, .. } => finished = Some(reason),
                TokenEvent::Rejected { .. } => {}
            }
        }
        (tokens, finished)
    }

    #[test]
    fn late_request_joins_batch_mid_flight() {
        // the continuous-batching acceptance test: B is admitted after A has
        // already produced tokens, and both finish with exact budgets
        let mut eng = engine(4);
        let (req_a, rx_a) = DecodeRequest::new(vec![1, 2, 3, 4], 10);
        let id_a = req_a.id;
        eng.submit(req_a);
        // step until A has decoded a few tokens (prefill step + 2 decode)
        for _ in 0..3 {
            eng.step().unwrap();
        }
        let (a_sofar, a_fin) = drain_tokens(&rx_a);
        assert!(a_sofar >= 2, "A must be mid-generation, got {a_sofar}");
        assert!(a_fin.is_none());

        let (req_b, rx_b) = DecodeRequest::new(vec![9, 8], 3);
        let id_b = req_b.id;
        assert_ne!(id_a, id_b);
        eng.submit(req_b);
        // B joins on the next step while A keeps decoding
        eng.step().unwrap();
        assert_eq!(eng.cache().slots_in_use(), 2, "both sequences share the batch");

        while eng.has_work() {
            eng.step().unwrap();
        }
        let (a_rest, a_fin) = drain_tokens(&rx_a);
        let (b_tokens, b_fin) = drain_tokens(&rx_b);
        assert_eq!(a_sofar + a_rest, 10);
        assert_eq!(a_fin, Some(FinishReason::MaxTokens));
        assert_eq!(b_tokens, 3);
        assert_eq!(b_fin, Some(FinishReason::MaxTokens));
        assert_eq!(eng.cache().slots_in_use(), 0, "slots returned to the pool");
        let report = eng.report();
        assert_eq!(report.completed, 2);
        assert_eq!(report.decode_tokens + report.completed, 13, "one token per request is emitted from prefill logits");
    }

    #[test]
    fn freed_slots_refill_from_queue() {
        // 1 slot, 3 requests: they must run strictly one after another, each
        // picking up the slot the previous one freed
        let mut eng = engine(1);
        let mut rxs = Vec::new();
        for _ in 0..3 {
            let (req, rx) = DecodeRequest::new(vec![5, 6], 2);
            eng.submit(req);
            rxs.push(rx);
        }
        while eng.has_work() {
            eng.step().unwrap();
            assert!(eng.cache().slots_in_use() <= 1);
        }
        for rx in &rxs {
            let (tokens, fin) = drain_tokens(rx);
            assert_eq!(tokens, 2);
            assert_eq!(fin, Some(FinishReason::MaxTokens));
        }
        assert_eq!(eng.report().completed, 3);
    }

    #[test]
    fn empty_prompt_is_rejected_not_panicking() {
        let mut eng = engine(2);
        let (req, rx) = DecodeRequest::new(vec![], 4);
        eng.submit(req);
        assert!(!eng.has_work());
        match rx.try_recv().unwrap() {
            TokenEvent::Rejected { reason, .. } => assert!(reason.contains("empty")),
            other => panic!("expected rejection, got {other:?}"),
        }
        assert_eq!(eng.report().rejected, 1);
    }

    #[test]
    fn context_window_bounds_generation() {
        // budget far beyond the window: the engine must stop at ContextFull
        let cfg = zoo("nano").unwrap();
        let prompt_len = 4usize;
        let mut eng = engine(2);
        let (req, rx) = DecodeRequest::new((0..prompt_len as i32).collect(), 10_000);
        eng.submit(req);
        while eng.has_work() {
            eng.step().unwrap();
        }
        let (tokens, fin) = drain_tokens(&rx);
        assert_eq!(fin, Some(FinishReason::ContextFull));
        assert_eq!(tokens, cfg.seq - prompt_len + 1);
    }

    #[test]
    fn eos_stops_the_stream() {
        let mut eng = engine(2);
        // discover the first greedy token, then use it as EOS
        let (probe, rx) = DecodeRequest::new(vec![1, 2, 3], 1);
        eng.submit(probe);
        while eng.has_work() {
            eng.step().unwrap();
        }
        let first = match rx.try_recv().unwrap() {
            TokenEvent::Token { token, .. } => token,
            other => panic!("expected token, got {other:?}"),
        };
        let (mut req, rx) = DecodeRequest::new(vec![1, 2, 3], 64);
        req.eos = Some(first);
        eng.submit(req);
        while eng.has_work() {
            eng.step().unwrap();
        }
        let (tokens, fin) = drain_tokens(&rx);
        assert_eq!(tokens, 1);
        assert_eq!(fin, Some(FinishReason::Eos));
    }

    #[test]
    fn over_long_prompt_is_clamped_to_window() {
        let cfg = zoo("nano").unwrap();
        let mut eng = engine(2);
        let long: Vec<i32> = (0..(cfg.seq as i32 + 10)).map(|i| i % cfg.vocab as i32).collect();
        let (req, rx) = DecodeRequest::new(long, 1);
        eng.submit(req);
        while eng.has_work() {
            eng.step().unwrap();
        }
        let (tokens, fin) = drain_tokens(&rx);
        assert_eq!(tokens, 1);
        assert!(fin.is_some());
    }

    #[test]
    fn run_serves_a_channel_of_streaming_clients() {
        let cfg = zoo("nano").unwrap();
        let ckpt = init_lm_params(&cfg, 43);
        let mut eng = Engine::new(cfg, ckpt, EngineConfig::default());
        let prompts: Vec<Vec<i32>> = (0..4).map(|s| vec![s + 1, s + 2, s + 3]).collect();
        let report = run_decode_loadgen(&mut eng, &prompts, 4, 2, 5).unwrap();
        assert_eq!(report.completed, 8);
        assert_eq!(report.rejected, 0);
        // 5 tokens per request: 1 from prefill + 4 decode steps
        assert_eq!(report.decode_tokens, 8 * 4);
        assert_eq!(report.ttft_p50.is_zero(), false);
        assert!(report.mean_occupancy >= 1.0);
    }

    #[test]
    fn fused_metrics_track_batched_forwards() {
        let mut eng = engine(4);
        let (a, _rx_a) = DecodeRequest::new(vec![1, 2], 3);
        let (b, _rx_b) = DecodeRequest::new(vec![3, 4], 3);
        eng.submit(a);
        eng.submit(b);
        while eng.has_work() {
            eng.step().unwrap();
        }
        let cfg = zoo("nano").unwrap();
        let report = eng.report();
        assert!(report.fused_steps > 0);
        assert!(
            report.mean_fused_batch > 1.0,
            "two co-resident sessions must share fused batches: {}",
            report.mean_fused_batch
        );
        assert_eq!(
            report.fused_gemms,
            report.fused_steps as u64 * crate::nn::step_batch_gemms(&cfg),
            "every fused call launches one GEMM per linear"
        );
    }

    #[test]
    fn packed_kv_engine_serves_and_scrubs_slots() {
        let cfg = zoo("nano").unwrap();
        let ckpt = init_lm_params(&cfg, 45);
        let mk = |kv_format| {
            Engine::new(
                cfg,
                ckpt.clone(),
                EngineConfig {
                    slots: 2,
                    kv_format,
                    scheduler: SchedulerConfig { max_batch: 2, ..SchedulerConfig::default() },
                    ..EngineConfig::default()
                },
            )
        };
        let mut packed = mk(Some("sf4"));
        assert_eq!(packed.cache().kv_format().unwrap().name, "sf4");
        let (req, rx) = DecodeRequest::new(vec![1, 2, 3], 6);
        packed.submit(req);
        while packed.has_work() {
            packed.step().unwrap();
        }
        let (tokens, fin) = drain_tokens(&rx);
        assert_eq!(tokens, 6);
        assert_eq!(fin, Some(FinishReason::MaxTokens));
        // retiring released and scrubbed the pages: no prior session's
        // K/V lingers anywhere in the pool
        assert_eq!(packed.cache().pages_in_use(), 0, "retired session kept pages");
        assert!(packed.cache().free_pages_are_zeroed(), "freed pages kept KV after retire");
        // same workload over fp32 lanes: identical token accounting, far
        // more KV bytes streamed
        let mut dense = mk(None);
        let (req, _rx) = DecodeRequest::new(vec![1, 2, 3], 6);
        dense.submit(req);
        while dense.has_work() {
            dense.step().unwrap();
        }
        let (rp, rd) = (packed.report(), dense.report());
        assert_eq!(rp.decode_tokens, rd.decode_tokens);
        assert!(rp.kv_bytes_read > 0);
        assert!(
            rp.kv_bytes_read * 4 < rd.kv_bytes_read,
            "packed lanes must stream <1/4 the KV bytes: {} vs {}",
            rp.kv_bytes_read,
            rd.kv_bytes_read
        );
        assert!(rd.kv_bytes_per_token > rp.kv_bytes_per_token);
    }

    #[test]
    fn absurd_kv_geometry_errors_instead_of_panicking() {
        // the overflow-checked constructor surfaces through try_new (the
        // CLI's path), so --kv-pages nonsense reports instead of wrapping
        let cfg = zoo("nano").unwrap();
        let ckpt = init_lm_params(&cfg, 47);
        let res = Engine::try_new(
            cfg,
            ckpt,
            EngineConfig { kv_pages: usize::MAX / 8, ..EngineConfig::default() },
        );
        assert!(res.is_err(), "overflowing page pool must be rejected");
    }

    #[test]
    fn page_accounting_grows_and_releases_with_the_stream() {
        // nano window 32, 4-position pages: a 5-token prompt + decode
        // claims pages on demand and returns every one at retire
        let cfg = zoo("nano").unwrap();
        let ckpt = init_lm_params(&cfg, 46);
        let mut eng = Engine::new(
            cfg,
            ckpt,
            EngineConfig {
                slots: 2,
                page_size: 4,
                scheduler: SchedulerConfig { max_batch: 2, ..SchedulerConfig::default() },
                ..EngineConfig::default()
            },
        );
        assert_eq!(eng.cache().page_size(), 4);
        assert_eq!(eng.cache().pages_total(), 2 * 8, "worst-case pool by default");
        let (req, _rx) = DecodeRequest::new(vec![1, 2, 3, 4, 5], 4);
        eng.submit(req);
        eng.step().unwrap();
        // 5 prefilled + 1 reserved for the next decode row -> 2 pages
        assert_eq!(eng.cache().pages_in_use(), 2);
        let report = eng.report();
        assert_eq!(report.pages_in_use, 2);
        assert_eq!(report.pages_free, 14);
        assert!(report.page_fragmentation > 0.0, "5 live rows on 8 held positions");
        while eng.has_work() {
            eng.step().unwrap();
        }
        assert_eq!(eng.cache().pages_in_use(), 0, "retire returns the pages");
        assert_eq!(eng.report().pages_in_use, 0);
        assert_eq!(eng.report().page_preemptions, 0, "worst-case pool never pressures");
    }

    #[test]
    fn preempt_frees_slot_and_requeues_at_head() {
        let mut eng = engine(1);
        let (a, rx_a) = DecodeRequest::new(vec![1, 2], 8);
        let id_a = a.id;
        let (b, _rx_b) = DecodeRequest::new(vec![3, 4], 2);
        eng.submit(a);
        eng.submit(b);
        eng.step().unwrap(); // A active, B queued
        assert_eq!(eng.cache().slots_in_use(), 1);
        let (a_before, _) = drain_tokens(&rx_a);
        assert!(a_before >= 1);

        assert!(eng.preempt(id_a), "active session is preemptible");
        assert!(!eng.preempt(id_a), "already evicted: nothing to preempt");
        assert!(!eng.preempt(9999), "unknown id");
        assert_eq!(eng.cache().slots_in_use(), 0, "eviction returns the slot");
        assert_eq!(eng.report().evicted, 1);

        // next step: A (queue head, ahead of B) re-enters the freed slot
        eng.step().unwrap();
        assert_eq!(eng.cache().slots_in_use(), 1);
        while eng.has_work() {
            eng.step().unwrap();
        }
        let (a_after, a_fin) = drain_tokens(&rx_a);
        assert_eq!(a_before + a_after, 8, "budget unaffected by the eviction round trip");
        assert_eq!(a_fin, Some(FinishReason::MaxTokens));
        assert_eq!(eng.cache().slots_in_use(), 0);
        assert_eq!(eng.report().completed, 2);
    }

    #[test]
    fn preempt_with_full_queue_finishes_the_stream_cleanly() {
        // bounded queue, no room to requeue: the partially-served client
        // must get a terminal Finished(Preempted), never a Rejected
        let cfg = zoo("nano").unwrap();
        let ckpt = init_lm_params(&cfg, 44);
        let mut eng = Engine::new(
            cfg,
            ckpt,
            EngineConfig {
                slots: 1,
                scheduler: SchedulerConfig { max_batch: 1, max_queue: 1, ..SchedulerConfig::default() },
                ..EngineConfig::default()
            },
        );
        let (a, rx_a) = DecodeRequest::new(vec![1, 2], 8);
        let id_a = a.id;
        let (b, _rx_b) = DecodeRequest::new(vec![3, 4], 2);
        eng.submit(a);
        eng.step().unwrap(); // A active (slot held)
        eng.submit(b); // fills the queue (max_queue 1)
        assert!(eng.preempt(id_a));
        assert_eq!(eng.cache().slots_in_use(), 0);
        let (tokens, fin) = drain_tokens(&rx_a);
        assert!(tokens >= 1, "A had streamed before the preemption");
        assert_eq!(fin, Some(FinishReason::Preempted));
        assert_eq!(eng.report().evicted, 1);
        // B proceeds normally in the freed slot
        while eng.has_work() {
            eng.step().unwrap();
        }
        assert_eq!(eng.report().completed, 1);
    }

    #[test]
    fn abort_clears_all_state_and_notifies() {
        // terminal-event contract: a session past admission (A, already
        // streaming) ends with Finished(Aborted); only the still-queued B —
        // which never entered the engine — gets Rejected
        let mut eng = engine(1);
        let (a, rx_a) = DecodeRequest::new(vec![1, 2], 50);
        let (b, rx_b) = DecodeRequest::new(vec![3, 4], 50);
        eng.submit(a);
        eng.submit(b);
        eng.step().unwrap(); // a active, b queued
        eng.abort();
        assert!(!eng.has_work());
        assert_eq!(eng.cache().slots_in_use(), 0);
        let (a_tokens, fin_a) = drain_tokens(&rx_a);
        assert!(a_tokens >= 1, "A had streamed before the abort");
        assert_eq!(fin_a, Some(FinishReason::Aborted), "in-flight abort is a Finished stream");
        assert!(matches!(rx_b.try_recv(), Ok(TokenEvent::Rejected { .. })));
        let report = eng.report();
        assert_eq!(report.aborted, 1);
        assert_eq!(report.rejected, 1);
        assert_eq!(report.evicted, 0, "abort is not an eviction");
    }

    #[test]
    fn submit_reports_whether_the_request_enqueued() {
        let cfg = zoo("nano").unwrap();
        let ckpt = init_lm_params(&cfg, 48);
        let mut eng = Engine::new(
            cfg,
            ckpt,
            EngineConfig {
                slots: 1,
                scheduler: SchedulerConfig { max_batch: 1, max_queue: 1, ..SchedulerConfig::default() },
                ..EngineConfig::default()
            },
        );
        let (empty, _rx) = DecodeRequest::new(vec![], 4);
        assert!(!eng.submit(empty), "empty prompt never enqueues");
        let (ok, _rx_ok) = DecodeRequest::new(vec![1, 2], 4);
        assert!(eng.submit(ok));
        let (overflow, rx_overflow) = DecodeRequest::new(vec![3, 4], 4);
        assert!(!eng.submit(overflow), "bounded queue overflow never enqueues");
        assert!(matches!(rx_overflow.try_recv(), Ok(TokenEvent::Rejected { .. })));
    }

    #[test]
    fn saturated_page_pool_rejects_instead_of_queuing() {
        // 4-position pages, a pool of 2 pages, and reject_saturated on: with
        // one session holding the pool and another already waiting, a third
        // arrival is told to retry (Rejected) instead of queuing unboundedly
        let cfg = zoo("nano").unwrap();
        let ckpt = init_lm_params(&cfg, 49);
        let mut eng = Engine::new(
            cfg,
            ckpt,
            EngineConfig {
                slots: 2,
                page_size: 4,
                kv_pages: 2,
                scheduler: SchedulerConfig {
                    max_batch: 2,
                    reject_saturated: true,
                    ..SchedulerConfig::default()
                },
                ..EngineConfig::default()
            },
        );
        let (a, _rx_a) = DecodeRequest::new(vec![1, 2, 3, 4, 5, 6, 7], 8);
        assert!(eng.submit(a));
        eng.step().unwrap(); // A prefilling, both pages claimed
        assert_eq!(eng.cache().pages_free(), 0);
        let (b, _rx_b) = DecodeRequest::new(vec![1, 2], 4);
        assert!(eng.submit(b), "an empty queue always admits the wait");
        let (c, rx_c) = DecodeRequest::new(vec![1, 2], 4);
        assert!(!eng.submit(c), "queue occupied + pool dry -> backpressure");
        match rx_c.try_recv().unwrap() {
            TokenEvent::Rejected { reason, .. } => assert!(reason.contains("saturated")),
            other => panic!("expected saturation rejection, got {other:?}"),
        }
        assert_eq!(eng.report().rejected, 1);
        // the queued B still completes once A's pages free up
        while eng.has_work() {
            eng.step().unwrap();
        }
        assert_eq!(eng.report().completed, 2);
    }

    #[test]
    fn rejected_arrival_does_not_hold_the_coalescing_window() {
        // regression: a rejected blocking arrival used to open the max_wait
        // coalescing window with nothing queued — the engine sat in
        // recv_timeout for the whole window instead of returning to the
        // idle blocking recv. The run_with observer fires once per engine
        // loop iteration, so with the fix it is called again almost
        // immediately after the reject; with the bug it stays silent for
        // the full (here 10s) window.
        let cfg = zoo("nano").unwrap();
        let ckpt = init_lm_params(&cfg, 50);
        let mut eng = Engine::new(
            cfg,
            ckpt,
            EngineConfig {
                slots: 4,
                scheduler: SchedulerConfig {
                    max_batch: 4,
                    max_wait: std::time::Duration::from_secs(10),
                    ..SchedulerConfig::default()
                },
                ..EngineConfig::default()
            },
        );
        let loops = AtomicU64::new(0);
        let (tx, rx) = mpsc::channel::<DecodeRequest>();
        std::thread::scope(|scope| {
            let loops = &loops;
            let server =
                scope.spawn(move || eng.run_with(&rx, |_| { loops.fetch_add(1, Ordering::SeqCst); }));
            // wait for the engine to reach its first idle block
            while loops.load(Ordering::SeqCst) == 0 {
                std::thread::yield_now();
            }
            let (bad, rx_bad) = DecodeRequest::new(vec![], 4);
            tx.send(bad).unwrap();
            // the reject must come back around to the loop top (observer
            // call #2) without serving out the 10s window
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
            while loops.load(Ordering::SeqCst) < 2 {
                assert!(
                    std::time::Instant::now() < deadline,
                    "engine held the coalescing window for a rejected arrival"
                );
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            assert!(matches!(rx_bad.try_recv(), Ok(TokenEvent::Rejected { .. })));
            drop(tx);
            let report = server.join().expect("engine thread panicked").unwrap();
            assert_eq!(report.rejected, 1);
        });
    }

    #[test]
    fn request_ids_share_one_global_allocator() {
        // regression: run_decode_loadgen minted ids from its own zero-based
        // counter, colliding with DecodeRequest::new ids in the same
        // process. All allocation now flows through next_request_id.
        let (before, _rx) = DecodeRequest::new(vec![1], 1);
        let cfg = zoo("nano").unwrap();
        let ckpt = init_lm_params(&cfg, 51);
        let mut eng = Engine::new(cfg, ckpt, EngineConfig::default());
        let prompts = vec![vec![1, 2, 3]];
        run_decode_loadgen(&mut eng, &prompts, 2, 2, 2).unwrap();
        let (after, _rx) = DecodeRequest::new(vec![1], 1);
        assert!(
            after.id >= before.id + 5,
            "4 loadgen requests must advance the shared allocator: {} -> {}",
            before.id,
            after.id
        );
    }

    #[test]
    fn dropped_receiver_retires_the_session_as_disconnected() {
        // client vanishes mid-stream: the engine must notice the dead
        // channel, retire the session with Disconnected, and free its pages
        let mut eng = engine(2);
        let (req, rx) = DecodeRequest::new(vec![1, 2, 3], 50);
        eng.submit(req);
        eng.step().unwrap(); // prefill + first token
        drop(rx); // client disconnects
        while eng.has_work() {
            eng.step().unwrap();
        }
        assert_eq!(eng.cache().slots_in_use(), 0, "disconnect frees the slot");
        assert_eq!(eng.cache().pages_in_use(), 0, "disconnect frees the pages");
        let report = eng.report();
        assert_eq!(report.completed, 1);
        assert_eq!(report.disconnected, 1);
    }
}
