//! Per-request decode state machine.
//!
//! A request's life: `Queued` (admission queue) → `Prefill` (prompt tokens
//! streaming into its KV slot) → `Decoding` (one generated token per engine
//! step) → `Done(reason)`; `Evicted` is the preemption exit used when a
//! session must give its slot back before finishing (not triggered by the
//! current scheduler, but part of the state contract so later paged-KV /
//! preemption PRs don't change the machine).

use std::sync::mpsc;
use std::time::Instant;

use crate::serving::kv_cache::SlotId;
use crate::serving::TokenEvent;

/// Why a session stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit its `max_new_tokens` budget.
    MaxTokens,
    /// Generated the request's stop token.
    Eos,
    /// Ran out of positional/cache window before the budget.
    ContextFull,
    /// The client dropped its event receiver mid-stream.
    Disconnected,
}

/// Lifecycle states. Legal moves are enforced by the transition methods.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionState {
    Queued,
    Prefill,
    Decoding,
    Done(FinishReason),
    Evicted,
}

/// One in-flight generation request inside the engine.
pub struct DecodeSession {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub generated: Vec<i32>,
    pub max_new_tokens: usize,
    pub eos: Option<i32>,
    pub slot: Option<SlotId>,
    pub state: SessionState,
    pub events: mpsc::Sender<TokenEvent>,
    pub submitted: Instant,
    pub first_token_at: Option<Instant>,
    pub last_token_at: Option<Instant>,
    /// Prompt tokens already written into the KV slot.
    pub prefilled: usize,
}

impl DecodeSession {
    pub fn new(
        id: u64,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        eos: Option<i32>,
        events: mpsc::Sender<TokenEvent>,
        submitted: Instant,
    ) -> DecodeSession {
        assert!(!prompt.is_empty(), "sessions require a non-empty prompt");
        DecodeSession {
            id,
            prompt,
            generated: Vec::new(),
            max_new_tokens: max_new_tokens.max(1),
            eos,
            slot: None,
            state: SessionState::Queued,
            events,
            submitted,
            first_token_at: None,
            last_token_at: None,
            prefilled: 0,
        }
    }

    /// Still holds (or is about to hold) compute: scheduled but not finished.
    pub fn is_active(&self) -> bool {
        matches!(self.state, SessionState::Prefill | SessionState::Decoding)
    }

    /// The token the next decode step conditions on.
    pub fn last_token(&self) -> i32 {
        *self.generated.last().unwrap_or_else(|| self.prompt.last().expect("non-empty prompt"))
    }

    /// Queued → Prefill, claiming a KV slot.
    pub fn begin_prefill(&mut self, slot: SlotId) {
        assert_eq!(self.state, SessionState::Queued, "begin_prefill from {:?}", self.state);
        self.slot = Some(slot);
        self.state = SessionState::Prefill;
    }

    /// Prefill → Decoding once the whole prompt is cached.
    pub fn begin_decode(&mut self) {
        assert_eq!(self.state, SessionState::Prefill, "begin_decode from {:?}", self.state);
        assert_eq!(self.prefilled, self.prompt.len(), "decode before prefill completed");
        self.state = SessionState::Decoding;
    }

    /// Any active state → Done.
    pub fn finish(&mut self, reason: FinishReason) {
        assert!(self.is_active(), "finish({reason:?}) from {:?}", self.state);
        self.state = SessionState::Done(reason);
    }

    /// Active → Evicted (slot reclaimed before completion).
    pub fn evict(&mut self) {
        assert!(self.is_active(), "evict from {:?}", self.state);
        self.state = SessionState::Evicted;
    }

    /// Stop condition after appending a generated token, given the number of
    /// cache positions still writable. Checked in priority order: EOS, token
    /// budget, context window.
    pub fn stop_reason(&self, remaining_window: usize) -> Option<FinishReason> {
        let last = *self.generated.last()?;
        if self.eos == Some(last) {
            return Some(FinishReason::Eos);
        }
        if self.generated.len() >= self.max_new_tokens {
            return Some(FinishReason::MaxTokens);
        }
        if remaining_window == 0 {
            return Some(FinishReason::ContextFull);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn session(max_new: usize, eos: Option<i32>) -> (DecodeSession, mpsc::Receiver<TokenEvent>) {
        let (tx, rx) = mpsc::channel();
        (DecodeSession::new(1, vec![3, 4, 5], max_new, eos, tx, Instant::now()), rx)
    }

    #[test]
    fn lifecycle_happy_path() {
        let (mut s, _rx) = session(4, None);
        assert_eq!(s.state, SessionState::Queued);
        assert!(!s.is_active());
        assert_eq!(s.last_token(), 5);
        s.begin_prefill(2);
        assert!(s.is_active());
        assert_eq!(s.slot, Some(2));
        s.prefilled = s.prompt.len();
        s.begin_decode();
        s.generated.push(9);
        assert_eq!(s.last_token(), 9);
        s.finish(FinishReason::MaxTokens);
        assert_eq!(s.state, SessionState::Done(FinishReason::MaxTokens));
        assert!(!s.is_active());
    }

    #[test]
    #[should_panic(expected = "begin_decode")]
    fn decode_before_prefill_is_illegal() {
        let (mut s, _rx) = session(4, None);
        s.begin_decode();
    }

    #[test]
    #[should_panic(expected = "decode before prefill completed")]
    fn decode_with_partial_prefill_is_illegal() {
        let (mut s, _rx) = session(4, None);
        s.begin_prefill(0);
        s.prefilled = 1; // only 1 of 3 prompt tokens cached
        s.begin_decode();
    }

    #[test]
    fn stop_conditions_in_priority_order() {
        let (mut s, _rx) = session(2, Some(7));
        assert_eq!(s.stop_reason(10), None, "no tokens yet");
        s.generated.push(1);
        assert_eq!(s.stop_reason(10), None);
        s.generated.push(7); // EOS and budget hit together: EOS wins
        assert_eq!(s.stop_reason(10), Some(FinishReason::Eos));
        let (mut s, _rx) = session(2, None);
        s.generated.push(1);
        s.generated.push(2);
        assert_eq!(s.stop_reason(10), Some(FinishReason::MaxTokens));
        let (mut s, _rx) = session(8, None);
        s.generated.push(1);
        assert_eq!(s.stop_reason(0), Some(FinishReason::ContextFull));
    }

    #[test]
    fn eviction_is_a_terminal_exit() {
        let (mut s, _rx) = session(4, None);
        s.begin_prefill(0);
        s.evict();
        assert_eq!(s.state, SessionState::Evicted);
        assert!(!s.is_active());
    }
}
