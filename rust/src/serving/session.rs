//! Per-request decode state machine.
//!
//! A request's life: `Queued` (admission queue) → `Prefill` (context tokens
//! streaming into its KV slot) → `Decoding` (one generated token per engine
//! step) → `Done(reason)`. `Evicted` is the preemption exit: the session
//! gives its slot back before finishing (`Engine::preempt`), then `requeue`
//! returns it to `Queued` with its stream and budget intact — the next
//! prefill replays prompt **plus** already-generated tokens, so greedy
//! decoding resumes bit-identically in a fresh slot.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::obs::clock;
use crate::serving::kv_cache::SlotId;
use crate::serving::TokenEvent;

/// Why a session stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit its `max_new_tokens` budget.
    MaxTokens,
    /// Generated the request's stop token.
    Eos,
    /// Ran out of positional/cache window before the budget.
    ContextFull,
    /// The client dropped its event receiver mid-stream.
    Disconnected,
    /// Evicted for preemption and could not be re-queued (bounded queue
    /// full); the stream ends after the tokens already delivered.
    Preempted,
    /// The engine shut down (`Engine::abort`) while the session was past
    /// admission; the stream ends after the tokens already delivered.
    /// (`Rejected` stays reserved for requests that never entered.)
    Aborted,
    /// The session's forward work panicked (or blew the stall watchdog's
    /// `step_deadline`) and supervision retired it so the rest of the batch
    /// keeps serving; the stream ends after the tokens already delivered.
    Failed,
}

impl FinishReason {
    /// Stable lowercase name, used on the HTTP wire and in logs.
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::Eos => "eos",
            FinishReason::ContextFull => "context_full",
            FinishReason::Disconnected => "disconnected",
            FinishReason::Preempted => "preempted",
            FinishReason::Aborted => "aborted",
            FinishReason::Failed => "failed",
        }
    }
}

/// Lifecycle states. Legal moves are enforced by the transition methods.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionState {
    Queued,
    Prefill,
    Decoding,
    Done(FinishReason),
    Evicted,
}

/// One in-flight generation request inside the engine.
pub struct DecodeSession {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub generated: Vec<i32>,
    pub max_new_tokens: usize,
    pub eos: Option<i32>,
    pub slot: Option<SlotId>,
    pub state: SessionState,
    pub events: mpsc::Sender<TokenEvent>,
    pub submitted: Instant,
    pub first_token_at: Option<Instant>,
    pub last_token_at: Option<Instant>,
    /// When the stream last emitted before a preemption, if the session has
    /// been requeued since. `requeue` moves `last_token_at` here so the
    /// first token after the replay is charged to the `resume_gap` series
    /// (eviction + queue wait + re-prefill) instead of polluting ITL;
    /// consecutive preemptions keep the earliest mark so one resume sample
    /// covers the whole bubble.
    pub resumed_from: Option<Instant>,
    /// Prompt tokens already written into the KV slot.
    pub prefilled: usize,
    /// When the session last entered the admission queue: submission, then
    /// reset on every [`Self::requeue`] — the start of each traced
    /// `queued` span (unlike `submitted`, which anchors TTFT and never
    /// moves).
    pub queued_at: Instant,
    /// When the session entered its current phase (prefill/decode); the
    /// engine advances it at transitions to bound lifecycle trace spans.
    pub phase_started_at: Instant,
    /// Client-declared latency budget (`deadline_ms` on the wire), measured
    /// from `submitted`. Only the fair-share victim policy reads it: sessions
    /// with less slack are preempted last. `None` means best-effort.
    pub deadline: Option<Duration>,
    /// How many times the session has been requeued after an eviction
    /// (spill, preemption, or resurrection). Distinguishes a resumed
    /// admission from a first admission.
    pub resumes: usize,
    /// When the session last re-entered a slot after an eviction. Victim
    /// selection treats sessions inside the resume cooldown as ineligible so
    /// two equal candidates cannot ping-pong preempt→requeue→preempt.
    pub resumed_at: Option<Instant>,
}

impl DecodeSession {
    pub fn new(
        id: u64,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        eos: Option<i32>,
        events: mpsc::Sender<TokenEvent>,
        submitted: Instant,
    ) -> DecodeSession {
        assert!(!prompt.is_empty(), "sessions require a non-empty prompt");
        DecodeSession {
            id,
            prompt,
            generated: Vec::new(),
            max_new_tokens: max_new_tokens.max(1),
            eos,
            slot: None,
            state: SessionState::Queued,
            events,
            submitted,
            first_token_at: None,
            last_token_at: None,
            resumed_from: None,
            prefilled: 0,
            queued_at: submitted,
            phase_started_at: submitted,
            deadline: None,
            resumes: 0,
            resumed_at: None,
        }
    }

    /// Still holds (or is about to hold) compute: scheduled but not finished.
    pub fn is_active(&self) -> bool {
        matches!(self.state, SessionState::Prefill | SessionState::Decoding)
    }

    /// The token the next decode step conditions on.
    pub fn last_token(&self) -> i32 {
        *self.generated.last().unwrap_or_else(|| self.prompt.last().expect("non-empty prompt"))
    }

    /// Positions the KV prefill must hold before decoding: the prompt plus
    /// anything already generated (non-empty `generated` during prefill only
    /// happens on a preemption resume, which replays the full context into a
    /// fresh slot).
    pub fn context_len(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    /// Token at context position `i` (prompt first, then generated).
    pub fn context_token(&self, i: usize) -> i32 {
        if i < self.prompt.len() {
            self.prompt[i]
        } else {
            self.generated[i - self.prompt.len()]
        }
    }

    /// Queued → Prefill, claiming a KV slot.
    pub fn begin_prefill(&mut self, slot: SlotId) {
        assert_eq!(self.state, SessionState::Queued, "begin_prefill from {:?}", self.state);
        self.slot = Some(slot);
        self.state = SessionState::Prefill;
        if self.resumes > 0 {
            self.resumed_at = Some(clock::now());
        }
    }

    /// Queued → Prefill/Decoding with `cached` context positions already
    /// restored into the slot (host-tier block-table splice). If the whole
    /// context is cached the session skips prefill entirely and decodes from
    /// [`Self::last_token`] exactly as it would have without the eviction.
    pub fn restore(&mut self, slot: SlotId, cached: usize) {
        assert_eq!(self.state, SessionState::Queued, "restore from {:?}", self.state);
        assert!(self.slot.is_none(), "restore while already holding a slot");
        assert!(cached <= self.context_len(), "restored {cached} > context {}", self.context_len());
        self.slot = Some(slot);
        self.prefilled = cached;
        self.state = if cached == self.context_len() {
            SessionState::Decoding
        } else {
            SessionState::Prefill
        };
        self.resumed_at = Some(clock::now());
    }

    /// Prefill → Decoding once the whole context is cached.
    pub fn begin_decode(&mut self) {
        assert_eq!(self.state, SessionState::Prefill, "begin_decode from {:?}", self.state);
        assert_eq!(self.prefilled, self.context_len(), "decode before prefill completed");
        self.state = SessionState::Decoding;
    }

    /// Any active state → Done.
    pub fn finish(&mut self, reason: FinishReason) {
        assert!(self.is_active(), "finish({reason:?}) from {:?}", self.state);
        self.state = SessionState::Done(reason);
    }

    /// Active → Evicted (slot reclaimed before completion).
    pub fn evict(&mut self) {
        assert!(self.is_active(), "evict from {:?}", self.state);
        self.state = SessionState::Evicted;
    }

    /// Evicted → Queued for re-admission. The session keeps its stream,
    /// generated tokens and budget; the next prefill replays the whole
    /// context ([`Self::context_token`]) into a fresh slot, after which
    /// greedy decoding continues exactly where it left off.
    pub fn requeue(&mut self) {
        assert_eq!(self.state, SessionState::Evicted, "requeue from {:?}", self.state);
        assert!(self.slot.is_none(), "requeue while still holding a slot");
        self.prefilled = 0;
        self.queued_at = clock::now();
        // the gap from the last pre-preemption token to the first replayed
        // one is scheduler latency, not decode latency: park the mark for
        // the resume_gap series so ITL never sees the bubble
        if let Some(t) = self.last_token_at.take() {
            self.resumed_from.get_or_insert(t);
        }
        self.resumes += 1;
        self.state = SessionState::Queued;
    }

    /// Stop condition after appending a generated token, given the number of
    /// cache positions still writable. Checked in priority order: EOS, token
    /// budget, context window.
    pub fn stop_reason(&self, remaining_window: usize) -> Option<FinishReason> {
        let last = *self.generated.last()?;
        if self.eos == Some(last) {
            return Some(FinishReason::Eos);
        }
        if self.generated.len() >= self.max_new_tokens {
            return Some(FinishReason::MaxTokens);
        }
        if remaining_window == 0 {
            return Some(FinishReason::ContextFull);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn session(max_new: usize, eos: Option<i32>) -> (DecodeSession, mpsc::Receiver<TokenEvent>) {
        let (tx, rx) = mpsc::channel();
        (DecodeSession::new(1, vec![3, 4, 5], max_new, eos, tx, clock::now()), rx)
    }

    #[test]
    fn lifecycle_happy_path() {
        let (mut s, _rx) = session(4, None);
        assert_eq!(s.state, SessionState::Queued);
        assert!(!s.is_active());
        assert_eq!(s.last_token(), 5);
        s.begin_prefill(2);
        assert!(s.is_active());
        assert_eq!(s.slot, Some(2));
        s.prefilled = s.prompt.len();
        s.begin_decode();
        s.generated.push(9);
        assert_eq!(s.last_token(), 9);
        s.finish(FinishReason::MaxTokens);
        assert_eq!(s.state, SessionState::Done(FinishReason::MaxTokens));
        assert!(!s.is_active());
    }

    #[test]
    #[should_panic(expected = "begin_decode")]
    fn decode_before_prefill_is_illegal() {
        let (mut s, _rx) = session(4, None);
        s.begin_decode();
    }

    #[test]
    #[should_panic(expected = "decode before prefill completed")]
    fn decode_with_partial_prefill_is_illegal() {
        let (mut s, _rx) = session(4, None);
        s.begin_prefill(0);
        s.prefilled = 1; // only 1 of 3 prompt tokens cached
        s.begin_decode();
    }

    #[test]
    fn stop_conditions_in_priority_order() {
        let (mut s, _rx) = session(2, Some(7));
        assert_eq!(s.stop_reason(10), None, "no tokens yet");
        s.generated.push(1);
        assert_eq!(s.stop_reason(10), None);
        s.generated.push(7); // EOS and budget hit together: EOS wins
        assert_eq!(s.stop_reason(10), Some(FinishReason::Eos));
        let (mut s, _rx) = session(2, None);
        s.generated.push(1);
        s.generated.push(2);
        assert_eq!(s.stop_reason(10), Some(FinishReason::MaxTokens));
        let (mut s, _rx) = session(8, None);
        s.generated.push(1);
        assert_eq!(s.stop_reason(0), Some(FinishReason::ContextFull));
    }

    #[test]
    fn eviction_is_a_terminal_exit() {
        let (mut s, _rx) = session(4, None);
        s.begin_prefill(0);
        s.evict();
        assert_eq!(s.state, SessionState::Evicted);
        assert!(!s.is_active());
    }

    #[test]
    fn context_replays_prompt_then_generated() {
        let (mut s, _rx) = session(8, None);
        assert_eq!(s.context_len(), 3);
        s.generated.push(11);
        s.generated.push(12);
        assert_eq!(s.context_len(), 5);
        let ctx: Vec<i32> = (0..s.context_len()).map(|i| s.context_token(i)).collect();
        assert_eq!(ctx, vec![3, 4, 5, 11, 12]);
    }

    #[test]
    fn requeue_resumes_the_lifecycle_with_progress_intact() {
        let (mut s, _rx) = session(8, None);
        s.begin_prefill(1);
        s.prefilled = s.prompt.len();
        s.begin_decode();
        s.generated.push(9);
        let t_last = clock::now();
        s.last_token_at = Some(t_last);
        // preemption: slot reclaimed, then back to the queue
        s.slot = None;
        s.evict();
        s.requeue();
        assert_eq!(s.state, SessionState::Queued);
        assert_eq!(s.prefilled, 0);
        assert_eq!(s.generated, vec![9], "progress survives the round trip");
        assert_eq!(s.last_token_at, None, "replay must not record an ITL sample");
        assert_eq!(s.resumed_from, Some(t_last), "bubble start parked for resume_gap");
        // a second preemption before any new token keeps the earliest mark
        s.begin_prefill(1);
        s.slot = None;
        s.evict();
        s.requeue();
        assert_eq!(s.resumed_from, Some(t_last), "one resume sample spans both bubbles");
        // second admission: the replayed context includes the generated token
        s.begin_prefill(0);
        s.prefilled = s.context_len();
        s.begin_decode();
        assert_eq!(s.last_token(), 9);
    }

    #[test]
    #[should_panic(expected = "requeue from")]
    fn requeue_requires_evicted() {
        let (mut s, _rx) = session(4, None);
        s.requeue();
    }

    #[test]
    fn restore_skips_prefill_when_the_whole_context_is_cached() {
        let (mut s, _rx) = session(8, None);
        s.begin_prefill(1);
        s.prefilled = s.prompt.len();
        s.begin_decode();
        s.generated.push(9);
        s.slot = None;
        s.evict();
        s.requeue();
        assert_eq!(s.resumes, 1);
        // host-tier restore: all 4 context positions spliced back in
        s.restore(2, s.context_len());
        assert_eq!(s.state, SessionState::Decoding);
        assert_eq!(s.slot, Some(2));
        assert_eq!(s.prefilled, 4);
        assert!(s.resumed_at.is_some(), "restore marks the cooldown clock");
        assert_eq!(s.last_token(), 9, "decode continues from the last generated token");
    }

    #[test]
    fn restore_with_partial_cache_continues_chunked_prefill() {
        let (mut s, _rx) = session(8, None);
        s.begin_prefill(0);
        s.slot = None;
        s.evict();
        s.requeue();
        s.restore(1, 2); // 2 of 3 prompt tokens cached
        assert_eq!(s.state, SessionState::Prefill);
        assert_eq!(s.prefilled, 2);
        assert_eq!(s.context_token(s.prefilled), 5, "prefill resumes at the first uncached token");
    }

    #[test]
    fn first_admission_never_marks_the_resume_cooldown() {
        let (mut s, _rx) = session(4, None);
        s.begin_prefill(0);
        assert_eq!(s.resumed_at, None, "fresh admissions are immediately evictable");
        s.slot = None;
        s.evict();
        s.requeue();
        s.begin_prefill(1);
        assert!(s.resumed_at.is_some(), "re-admission after eviction arms the cooldown");
    }

    #[test]
    #[should_panic(expected = "restore from")]
    fn restore_requires_queued() {
        let (mut s, _rx) = session(4, None);
        s.begin_prefill(0);
        s.restore(1, 1);
    }
}
