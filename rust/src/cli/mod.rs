//! Hand-rolled CLI (clap is not in the offline vendor set).
//!
//! ```text
//! repro train        [--model NAME | --all] [--force]
//! repro table        <1|2|3|4|5|6|7|8|9|10|12|14|15> [--quick] [--model NAME]
//! repro figure       <2|3|4|7> [--quick] [--model NAME]
//! repro serve        [--model NAME] [--format FMT] [--clients N] [--requests N]
//! repro serve-decode [--model NAME] [--format FMT|fp32] [--packed] [--w4a4]
//!                    [--force-scalar]
//!                    [--kv-format fp32|FMT] [--clients N] [--requests N]
//!                    [--max-new T] [--slots S] [--prefill-chunk P]
//!                    [--page-size P] [--kv-pages N] [--host-tier-mb MB]
//!                    [--victim-policy most-pages|lru|fair-share]
//!                    [--resume-cooldown-ms MS]
//!                    [--trace-out FILE] [--metrics-out FILE]
//! repro serve-http   [--addr HOST:PORT] [--model NAME] [--format FMT|fp32]
//!                    [--packed] [--w4a4] [--force-scalar]
//!                    [--kv-format fp32|FMT] [--slots S]
//!                    [--max-queue N] [--prefill-chunk P] [--page-size P]
//!                    [--kv-pages N] [--host-tier-mb MB]
//!                    [--victim-policy most-pages|lru|fair-share]
//!                    [--resume-cooldown-ms MS] [--resurrect]
//!                    [--read-timeout-ms MS] [--write-timeout-ms MS]
//!                    [--retry-after SECS] [--retry-after-cap SECS]
//!                    [--fault-seed N] [--fault-rate P] [--fault-limit N]
//!                    [--fault-sites a,b,c]
//!                    [--trace-out FILE] [--metrics-out FILE]
//! repro all          [--quick]
//! ```
//! Global flags: `--artifacts DIR --checkpoints DIR --results DIR`.

use anyhow::{bail, Context, Result};

use crate::coordinator::{corpus_for, trainer, Session};
use crate::data::ImageSet;
use crate::exp::{self, Scale};
use crate::model_io::{zoo, ZOO};
use crate::nn::CLS_ZOO;

/// Parsed command line.
pub struct Args {
    pub cmd: String,
    pub positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut cmd = String::new();
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                    _ => "true".to_string(),
                };
                flags.insert(name.to_string(), value);
            } else if cmd.is_empty() {
                cmd = a.clone();
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { cmd, positional, flags })
    }

    pub fn flag(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    fn scale(&self) -> Scale {
        if self.has("quick") {
            Scale::Quick
        } else {
            Scale::Full
        }
    }
}

const USAGE: &str = "\
repro — Student-t datatypes for LLMs (ICML 2024 reproduction)

commands:
  train   [--model NAME | --all] [--force]     train the model zoo (AOT step)
  table   <id> [--quick] [--model NAME]        regenerate a paper table
          ids: 1 2 3 4 5 6 7 8 9 10 12 14 15
  figure  <id> [--quick] [--model NAME]        regenerate a paper figure
          ids: 2 3 4 7
  serve   [--model N] [--format F] [--clients C] [--requests R]
          one-shot next-token scoring through the decode engine
  serve-decode [--model N] [--format F|fp32] [--packed] [--w4a4]
               [--force-scalar] [--kv-format fp32|F]
               [--clients C] [--requests R] [--max-new T] [--slots S]
               [--prefill-chunk P] [--page-size P] [--kv-pages N]
               [--host-tier-mb MB] [--victim-policy most-pages|lru|fair-share]
               [--resume-cooldown-ms MS]
               [--trace-out FILE] [--metrics-out FILE]
          continuous-batching multi-token generation (streaming, paged KV
          cache with block tables, fused [B,d] batched decode step;
          --packed serves true 4-bit weights through the fused LUT
          dequant-GEMM; --w4a4 additionally encodes each activation tile to
          4-bit codes on the fly and multiplies code x code through a 16x16
          product LUT (implies --packed; accuracy is NLL-delta-gated, not
          bit-identical); --force-scalar pins every kernel to the scalar
          oracle path, disabling the SIMD microkernels (same as
          LLMDT_FORCE_SCALAR=1) — the A/B lever for the perf benches;
          --kv-format stores the KV cache itself in a 4-bit
          codebook, attended through the fused dequant-attention kernels;
          --page-size sets positions per KV page and --kv-pages bounds the
          page pool — 0 = worst case — so long-context mixes admit against
          pages available, not per-slot reservations; --host-tier-mb > 0
          enables the host KV spill tier: under page pressure the victim's
          packed pages move to host memory and splice back bit-identically
          at re-admission instead of being recomputed; --victim-policy picks
          the eviction victim (most-pages frees the most pages, lru the
          coldest stream, fair-share the most deadline slack) and
          --resume-cooldown-ms shields a just-resumed session from
          re-eviction (default 250, anti-thrash); --trace-out records
          the run's span timeline and writes Chrome trace-event JSON —
          load it in Perfetto/chrome://tracing — and --metrics-out writes
          the engine's metrics registry as Prometheus text)
  serve-http [--addr A] [--model N] [--format F|fp32] [--packed] [--w4a4]
             [--force-scalar] [--kv-format fp32|F] [--slots S] [--max-queue Q]
             [--prefill-chunk P] [--page-size P] [--kv-pages N]
             [--host-tier-mb MB] [--victim-policy most-pages|lru|fair-share]
             [--resume-cooldown-ms MS] [--resurrect]
             [--read-timeout-ms MS] [--write-timeout-ms MS]
             [--retry-after SECS] [--retry-after-cap SECS]
             [--fault-seed N] [--fault-rate P]
             [--fault-limit N] [--fault-sites a,b,c]
             [--trace-out FILE] [--metrics-out FILE]
          HTTP/1.1 front end over the decode engine: POST /generate streams
          tokens as chunked NDJSON; a full admission queue or saturated KV
          page pool answers 429 + Retry-After instead of queuing without
          bound (--max-queue defaults to 4x slots; the hint is derived per
          answer from queue depth + page/spill pressure, staggered, and
          clamped to [--retry-after, --retry-after-cap]); --resurrect
          replays in-flight sessions after an engine panic and continues
          the same streams (clients see resume_gap, not \"failed\");
          requests may carry deadline_ms, which the fair-share victim
          policy ranks by; GET /healthz and
          GET /metrics (Prometheus text incl. llmdt_http_* series) probe
          the server; POST /shutdown drains gracefully — stop accepting,
          finish in-flight streams, then exit with the engine report;
          --fault-seed arms deterministic fault injection (chaos drills):
          each site in --fault-sites (default forward_panic,
          kv_reserve_fail,pool_worker_panic; see rust/src/faults) fires
          with probability --fault-rate (default 0.05) at most
          --fault-limit times (0 = unlimited) — the supervised engine must
          keep serving, counting llmdt_faults_* in /metrics
  all     [--quick]                            every table + figure
global flags: --artifacts DIR --checkpoints DIR --results DIR
";

pub fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    if args.cmd.is_empty() || args.cmd == "help" {
        print!("{USAGE}");
        return Ok(());
    }
    let session = Session::open(
        &args.flag("artifacts", crate::paths::ARTIFACTS),
        &args.flag("checkpoints", crate::paths::CHECKPOINTS),
        &args.flag("results", crate::paths::RESULTS),
    )?;
    std::fs::create_dir_all(&session.results_dir).ok();

    match args.cmd.as_str() {
        "train" => cmd_train(&session, &args),
        "table" => cmd_table(&session, &args),
        "figure" => cmd_figure(&session, &args),
        "serve" => cmd_serve(&session, &args),
        "serve-decode" => cmd_serve_decode(&session, &args),
        "serve-http" => cmd_serve_http(&session, &args),
        "all" => cmd_all(&session, &args),
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
}

fn cmd_train(session: &Session, args: &Args) -> Result<()> {
    let force = args.has("force");
    let models: Vec<&str> = if args.has("models") {
        args.flag("models", "").split(',').map(|s| Box::leak(s.to_string().into_boxed_str()) as &str).collect()
    } else if args.has("all") {
        ZOO.iter().map(|c| c.name).collect()
    } else if args.has("cls") {
        vec![]
    } else {
        vec![Box::leak(args.flag("model", "small").into_boxed_str())]
    };
    for model in models {
        let cfg = zoo(model)?;
        let corpus = corpus_for(&cfg);
        trainer::train_and_save(&session.engine, &cfg, &corpus, &session.checkpoints_dir, force)?;
    }
    if args.has("all") || args.has("cls") {
        let images = ImageSet::new(16, 10, 7, 0.6);
        for cfg in CLS_ZOO {
            trainer::train_cls_and_save(
                &session.engine,
                &cfg,
                &images,
                &session.checkpoints_dir,
                force,
            )?;
        }
    }
    Ok(())
}

fn default_single_model(args: &Args, scale: Scale) -> String {
    args.flag("model", match scale {
        Scale::Quick => "nano",
        Scale::Full => "small",
    })
}

fn cmd_table(session: &Session, args: &Args) -> Result<()> {
    let id = args.positional.first().context("table needs an id")?.as_str();
    let scale = args.scale();
    let model = default_single_model(args, scale);
    let table = match id {
        "1" | "11" => exp::profile::run(session, scale)?,
        "2" => exp::dof_sweep::run(session, scale)?,
        "3" | "13" => exp::weight_only::run(session, scale)?,
        "4" => exp::zeroshot::run(session, scale, &model)?,
        "5" => exp::blocksize::run(session, scale, &model)?,
        "6" => exp::gptq_cmp::run(session, scale, &model)?,
        "7" => exp::three_bit::run(session, scale, &model)?,
        "8" => exp::w4a4::run(session, scale)?,
        "9" => exp::vision::run(session, scale)?,
        "10" => exp::hardware::run()?,
        "12" => exp::profile::run_breakdown(session, scale, &model)?,
        "14" => exp::multilingual::run(session, scale, &model)?,
        "15" => exp::convergence::run_table15()?,
        other => bail!("unknown table id {other}"),
    };
    exp::emit(session, &format!("table{id}"), &table)
}

fn cmd_figure(session: &Session, args: &Args) -> Result<()> {
    let id = args.positional.first().context("figure needs an id")?.as_str();
    let scale = args.scale();
    let model = default_single_model(args, scale);
    match id {
        "2" => {
            let txt = exp::profile::run_fig2(session, &model)?;
            println!("{txt}");
            std::fs::write(
                std::path::Path::new(&session.results_dir).join("fig2.txt"),
                txt,
            )?;
        }
        "3" | "8" => {
            let (rendered, points) = exp::pareto::run(session, scale)?;
            let front = exp::pareto::pareto_front(&points);
            let txt = format!("{rendered}\nPareto front: {}\n", front.join(" -> "));
            println!("{txt}");
            std::fs::write(
                std::path::Path::new(&session.results_dir).join("fig3.txt"),
                txt,
            )?;
        }
        "4" | "5" => {
            let table = exp::convergence::run_fig4(session)?;
            exp::emit(session, "fig4", &table)?;
        }
        "6" => {
            let table = exp::convergence::run_table15()?;
            exp::emit(session, "fig6_gallery", &table)?;
        }
        "7" => {
            let table = exp::convergence::run_fig7()?;
            exp::emit(session, "fig7_apot", &table)?;
        }
        other => bail!("unknown figure id {other}"),
    }
    Ok(())
}

/// Trained checkpoint if available, else a deterministic Student-t init so
/// the pure-Rust serving paths stay runnable without the AOT artifacts.
fn load_or_init_checkpoint(
    session: &Session,
    cfg: &crate::model_io::ModelConfig,
) -> crate::model_io::Checkpoint {
    match session.load_checkpoint(cfg.name) {
        Ok(c) => c,
        Err(_) => {
            eprintln!(
                "note: no trained checkpoint for `{}` — serving a fresh Student-t init \
                 (run `repro train --model {}` for trained weights)",
                cfg.name, cfg.name
            );
            trainer::init_lm_params(cfg, 0x5eed)
        }
    }
}

/// Weight path for the decode engine: fp32 passthrough, fake-quant
/// (dequantized f32) through the requested codebook, with `packed` true
/// 4-bit packed weights decoded in-kernel by the fused LUT GEMM, or with
/// `w4a4` the packed weights plus an activation quantizer so the linears
/// run code x code through the 16x16 product LUT.
fn serving_checkpoint(
    cfg: &crate::model_io::ModelConfig,
    ckpt: &crate::model_io::Checkpoint,
    format: &str,
    packed: bool,
    w4a4: bool,
) -> Result<crate::model_io::Checkpoint> {
    use crate::coordinator::pipeline::{
        fake_quant_checkpoint, packed_checkpoint, w4a4_checkpoint, PipelineConfig,
    };
    if format == "fp32" {
        anyhow::ensure!(
            !packed && !w4a4,
            "--packed/--w4a4 need a 4-bit --format (fp32 weights stay dense)"
        );
        return Ok(ckpt.clone());
    }
    let corpus = corpus_for(cfg);
    if w4a4 {
        // SmoothQuant stays off: the serving forward has no activation-side
        // unscale hook (see pipeline::w4a4_checkpoint)
        return w4a4_checkpoint(cfg, ckpt, &PipelineConfig::w4a4(format, false), &corpus);
    }
    let pc = PipelineConfig::weight_only(format);
    if packed {
        packed_checkpoint(cfg, ckpt, &pc, &corpus)
    } else {
        fake_quant_checkpoint(cfg, ckpt, &pc, &corpus)
    }
}

fn serve_prompts(cfg: &crate::model_io::ModelConfig, n: usize, seed: u64) -> Vec<Vec<i32>> {
    use crate::rng::Pcg64;
    let corpus = corpus_for(cfg);
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            let start = rng.below(corpus.heldout.len() - cfg.seq);
            corpus.heldout[start..start + cfg.seq / 2].to_vec()
        })
        .collect()
}

fn cmd_serve(session: &Session, args: &Args) -> Result<()> {
    use crate::coordinator::serve::{run_loadgen, ServeConfig, Server};

    let model = args.flag("model", "small");
    let format = args.flag("format", "sf4");
    let clients: usize = args.flag("clients", "8").parse()?;
    let requests: usize = args.flag("requests", "64").parse()?;
    if args.has("force-scalar") {
        crate::tensor::simd::force_scalar(true);
    }

    let cfg = zoo(&model)?;
    let ckpt = load_or_init_checkpoint(session, &cfg);
    let ckpt = serving_checkpoint(&cfg, &ckpt, &format, false, false)?;
    let server = Server::new(cfg, ckpt, ServeConfig::default());
    let prompts = serve_prompts(&cfg, 64, 1);
    let stats = run_loadgen(server, prompts, clients, requests / clients.max(1))?;
    println!(
        "served {} requests in {} steps (mean fill {:.2}/{}) p50 {:?} p99 {:?}",
        stats.served,
        stats.batches,
        stats.mean_batch_fill,
        cfg.batch_eval,
        stats.p50_latency,
        stats.p99_latency
    );
    Ok(())
}

/// `--trace-out` / `--metrics-out`: a bare flag (no value) falls back to
/// the default filename.
fn out_path(args: &Args, name: &str, default: &str) -> Option<String> {
    if !args.has(name) {
        return None;
    }
    let v = args.flag(name, default);
    Some(if v == "true" { default.to_string() } else { v })
}

/// A decode engine built from the shared `serve-decode`/`serve-http` flag
/// set (`--model --format --packed --kv-format --slots --prefill-chunk
/// --page-size --kv-pages`), plus its banner line.
struct DecodeEngineSetup {
    engine: crate::serving::Engine,
    cfg: crate::model_io::ModelConfig,
    banner: String,
}

fn build_decode_engine(
    session: &Session,
    args: &Args,
    max_queue: usize,
    reject_saturated: bool,
) -> Result<DecodeEngineSetup> {
    use crate::serving::{Engine, EngineConfig, SchedulerConfig, VictimPolicyKind};

    let model = args.flag("model", "small");
    let format = args.flag("format", "sf4");
    let w4a4 = args.has("w4a4");
    let packed = args.has("packed") || w4a4; // --w4a4 implies packed weights
    let kv_fmt = args.flag("kv-format", "fp32");
    if args.has("force-scalar") {
        // same lever as LLMDT_FORCE_SCALAR=1: pin every kernel to the
        // scalar oracle path before any dispatch decision is observed
        crate::tensor::simd::force_scalar(true);
    }
    let slots: usize = args.flag("slots", "4").parse()?;
    let prefill_chunk: usize = args.flag("prefill-chunk", "32").parse()?;
    let page_size: usize = args.flag("page-size", "16").parse()?;
    let kv_pages: usize = args.flag("kv-pages", "0").parse()?;
    // graceful degradation under page pressure: a nonzero host tier lets
    // the engine spill a victim's packed KV pages to host memory and
    // splice them back at re-admission instead of recomputing prefill
    let host_tier_mb: usize = args.flag("host-tier-mb", "0").parse()?;
    let policy_name = args.flag("victim-policy", "most-pages");
    let victim_policy = VictimPolicyKind::from_name(&policy_name).ok_or_else(|| {
        anyhow::anyhow!("unknown --victim-policy `{policy_name}` (most-pages|lru|fair-share)")
    })?;
    // the serving CLIs default the anti-thrash cooldown on; the library
    // default stays ZERO so batch drivers keep their pinned schedules
    let resume_cooldown_ms: u64 = args.flag("resume-cooldown-ms", "250").parse()?;
    let resurrect = args.has("resurrect");

    let cfg = zoo(&model)?;
    let ckpt = load_or_init_checkpoint(session, &cfg);
    let ckpt = serving_checkpoint(&cfg, &ckpt, &format, packed, w4a4)?;
    let weight_label = if w4a4 {
        format!("{format} W4A4 code x code ({} KiB codes+scales)", ckpt.packed_bytes() / 1024)
    } else if packed {
        format!("{format} packed-4bit ({} KiB codes+scales)", ckpt.packed_bytes() / 1024)
    } else if format == "fp32" {
        "fp32 dense".to_string()
    } else {
        format!("{format} fake-quant dense")
    };
    let kv_format = match kv_fmt.as_str() {
        "fp32" => None,
        name => {
            let spec = crate::formats::get(name)
                .ok_or_else(|| anyhow::anyhow!("unknown --kv-format `{name}`"))?;
            anyhow::ensure!(
                spec.n_values() <= 16,
                "--kv-format {name} has {} codebook values (> 4-bit)",
                spec.n_values()
            );
            Some(&*Box::leak(kv_fmt.clone().into_boxed_str()))
        }
    };
    let engine = Engine::try_new(
        cfg,
        ckpt,
        EngineConfig {
            slots,
            kv_format,
            page_size,
            kv_pages,
            host_tier_bytes: host_tier_mb << 20,
            scheduler: SchedulerConfig {
                max_batch: slots,
                prefill_chunk,
                max_queue,
                reject_saturated,
                victim_policy,
                resume_cooldown: std::time::Duration::from_millis(resume_cooldown_ms),
                resurrect,
                ..SchedulerConfig::default()
            },
            ..EngineConfig::default()
        },
    )?;
    let kv_label = match kv_format {
        None => "fp32".to_string(),
        Some(f) => format!("{f} packed-4bit"),
    };
    let tier_label = if host_tier_mb > 0 {
        format!(" | host spill tier {host_tier_mb} MiB")
    } else {
        String::new()
    };
    let isa = crate::tensor::simd::active();
    let isa_label = if crate::tensor::simd::scalar_forced() {
        format!("{} (forced)", isa.name())
    } else {
        isa.name().to_string()
    };
    let banner = format!(
        "decode engine: model `{}` weights {} | paged KV: {} sequences over {} pages x {} \
         positions (block tables, {} lanes, {} KiB pool) | fused [B,d] batched step, \
         prefill chunk {}, victim policy {}{} | kernels: {} ISA",
        cfg.name,
        weight_label,
        engine.cache().slots_total(),
        engine.cache().pages_total(),
        engine.cache().page_size(),
        kv_label,
        engine.cache().bytes() / 1024,
        prefill_chunk,
        victim_policy.name(),
        tier_label,
        isa_label,
    );
    Ok(DecodeEngineSetup { engine, cfg, banner })
}

fn cmd_serve_decode(session: &Session, args: &Args) -> Result<()> {
    use crate::serving::run_decode_loadgen;

    let clients: usize = args.flag("clients", "4").parse()?;
    let requests: usize = args.flag("requests", "16").parse()?;
    let max_new: usize = args.flag("max-new", "16").parse()?;
    let trace_out = out_path(args, "trace-out", "trace.json");
    let metrics_out = out_path(args, "metrics-out", "metrics.prom");

    let DecodeEngineSetup { mut engine, cfg, banner } = build_decode_engine(session, args, 0, false)?;
    println!("{banner}");
    let prompts = serve_prompts(&cfg, 64, 2);
    let per_client = (requests / clients.max(1)).max(1);
    if trace_out.is_some() {
        crate::obs::trace::reset();
        crate::obs::trace::set_enabled(true);
    }
    let report = run_decode_loadgen(&mut engine, &prompts, clients, per_client, max_new)?;
    if trace_out.is_some() {
        crate::obs::trace::set_enabled(false);
    }
    println!("{report}");
    if let Some(path) = &trace_out {
        let snap = crate::obs::trace::snapshot_and_drain();
        std::fs::write(path, crate::obs::export::chrome_trace_json(&snap))
            .with_context(|| format!("writing Chrome trace to {path}"))?;
        println!(
            "trace: {} events ({} dropped) -> {path} (open in Perfetto or chrome://tracing)",
            snap.records.len(),
            snap.dropped
        );
    }
    if let Some(path) = &metrics_out {
        let text = crate::obs::export::prometheus_text(&engine.metrics_registry());
        std::fs::write(path, text)
            .with_context(|| format!("writing Prometheus metrics to {path}"))?;
        println!("metrics: Prometheus text -> {path}");
    }
    Ok(())
}

fn cmd_serve_http(session: &Session, args: &Args) -> Result<()> {
    use crate::serving::http::{serve, HttpConfig, ServerExit};

    let addr = args.flag("addr", "127.0.0.1:8080");
    let slots: usize = args.flag("slots", "4").parse()?;
    // bounded by default: the whole point of the front end is answering
    // 429 under pressure instead of queuing without limit
    let max_queue: usize = args.flag("max-queue", &(slots * 4).to_string()).parse()?;
    let read_timeout_ms: u64 = args.flag("read-timeout-ms", "5000").parse()?;
    let write_timeout_ms: u64 = args.flag("write-timeout-ms", "5000").parse()?;
    let retry_after: u64 = args.flag("retry-after", "1").parse()?;
    let retry_after_cap: u64 = args.flag("retry-after-cap", "8").parse()?;
    let trace_out = out_path(args, "trace-out", "trace.json");
    let metrics_out = out_path(args, "metrics-out", "metrics.prom");

    // chaos drills: --fault-seed arms the deterministic fault-injection
    // layer for the whole serve run. The supervised engine is expected to
    // keep serving through every injected failure; /metrics exposes the
    // llmdt_faults_* counters for the drill to assert on.
    if args.has("fault-seed") {
        let seed: u64 = args.flag("fault-seed", "0").parse()?;
        let rate: f64 = args.flag("fault-rate", "0.05").parse()?;
        let limit: u64 = args.flag("fault-limit", "0").parse()?;
        let sites = args.flag("fault-sites", "forward_panic,kv_reserve_fail,pool_worker_panic");
        let mut plan = crate::faults::FaultPlan::new(seed);
        for name in sites.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let site = crate::faults::Site::from_name(name)
                .with_context(|| format!("unknown fault site {name:?} in --fault-sites"))?;
            plan = plan.rate(site, rate);
            if limit > 0 {
                plan = plan.limit(site, limit);
            }
        }
        crate::faults::silence_injected_panics();
        crate::faults::arm(plan);
        println!(
            "fault injection armed: seed {seed}, rate {rate}, limit {} on [{sites}]",
            if limit == 0 { "unlimited".to_string() } else { limit.to_string() },
        );
    }

    let setup = build_decode_engine(session, args, max_queue, true)?;
    println!("{}", setup.banner);
    if trace_out.is_some() {
        crate::obs::trace::reset();
        crate::obs::trace::set_enabled(true);
    }
    let server = serve(
        setup.engine,
        HttpConfig {
            addr,
            read_timeout: std::time::Duration::from_millis(read_timeout_ms),
            write_timeout: std::time::Duration::from_millis(write_timeout_ms),
            retry_after_secs: retry_after,
            retry_after_cap,
            ..HttpConfig::default()
        },
    )?;
    println!(
        "serving http on {} (admission queue {} | POST /generate, GET /healthz, \
         GET /metrics, POST /shutdown to drain)",
        server.addr(),
        max_queue,
    );
    // blocks until a client posts /shutdown; in-flight streams finish first
    let ServerExit { report, engine, http } = server.wait();
    if trace_out.is_some() {
        crate::obs::trace::set_enabled(false);
    }
    let report = report?;
    println!("{report}");
    println!(
        "http: {} connections, {} requests, {} streams completed, {} rejected (429), \
         {} bad requests, {} disconnects, {} tokens streamed, {} engine restarts",
        http.connections,
        http.requests,
        http.streams_completed,
        http.rejected_429,
        http.bad_requests,
        http.disconnects,
        http.tokens_streamed,
        http.engine_restarts,
    );
    if crate::faults::injected_total() > 0 {
        println!("faults injected: {}", crate::faults::injected_total());
        crate::faults::disarm();
    }
    if let Some(path) = &trace_out {
        let snap = crate::obs::trace::snapshot_and_drain();
        std::fs::write(path, crate::obs::export::chrome_trace_json(&snap))
            .with_context(|| format!("writing Chrome trace to {path}"))?;
        println!(
            "trace: {} events ({} dropped) -> {path} (open in Perfetto or chrome://tracing)",
            snap.records.len(),
            snap.dropped
        );
    }
    if let Some(path) = &metrics_out {
        let text = crate::obs::export::prometheus_text(&engine.metrics_registry());
        std::fs::write(path, text)
            .with_context(|| format!("writing Prometheus metrics to {path}"))?;
        println!("metrics: Prometheus text -> {path}");
    }
    Ok(())
}

fn cmd_all(session: &Session, args: &Args) -> Result<()> {
    let scale = args.scale();
    let model = default_single_model(args, scale);
    for id in ["10", "15", "1", "2", "3", "4", "5", "6", "7", "8", "9", "12", "14"] {
        let mut sub = Args::parse(&[id.to_string()])?;
        sub.flags = args.flags.clone();
        sub.positional = vec![id.to_string()];
        if let Err(e) = cmd_table(session, &sub) {
            eprintln!("table {id} failed: {e:#}");
        }
        let _ = &model;
    }
    for id in ["2", "3", "4", "7"] {
        let mut sub = Args::parse(&[id.to_string()])?;
        sub.flags = args.flags.clone();
        sub.positional = vec![id.to_string()];
        if let Err(e) = cmd_figure(session, &sub) {
            eprintln!("figure {id} failed: {e:#}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_parse_flags_and_positionals() {
        let argv: Vec<String> =
            ["table", "3", "--quick", "--model", "small"].iter().map(|s| s.to_string()).collect();
        let a = Args::parse(&argv).unwrap();
        assert_eq!(a.cmd, "table");
        assert_eq!(a.positional, vec!["3"]);
        assert!(a.has("quick"));
        assert_eq!(a.flag("model", "x"), "small");
        assert_eq!(a.flag("missing", "dflt"), "dflt");
    }
}
