//! Post-training quantization engine: sub-channel blocking, scale search
//! (absmax / MSE-clip), RTN rounding, GPTQ and SmoothQuant — plus the
//! packed 4-bit serving codecs: [`PackedWeight`]/[`lut_gemm`] for weights
//! and [`KvFormat`] (`packed_kv`) for KV-cache lanes.
//!
//! Weight layout everywhere: `[K, N]` = `[in, out]`, matching the L1 kernel.
//! Sub-channel blocks tile the K (reduction) dimension per output column —
//! exactly the paper's "sub-channel quantization with block size 128".

mod gptq;
mod packed_kv;
mod smoothquant;

pub use gptq::{gptq_quantize, GptqConfig};
pub use packed_kv::KvFormat;
pub use smoothquant::{smooth_scales, SmoothQuant};

use crate::formats::FormatSpec;
use crate::tensor::Tensor;

/// How scales are chosen per block (paper: "None" vs "MSE" calibration).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Calib {
    /// absmax scaling (round-to-nearest with full-range clipping).
    None,
    /// weight-based MSE clipping: grid-search a clip ratio per block.
    Mse,
}

impl Calib {
    pub fn label(&self) -> &'static str {
        match self {
            Calib::None => "None",
            Calib::Mse => "MSE",
        }
    }
}

/// Sub-channel block size along K; `Channelwise` = one scale per column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockSize {
    Sub(usize),
    Channelwise,
}

impl BlockSize {
    pub fn resolve(&self, k: usize) -> usize {
        match *self {
            BlockSize::Sub(b) => {
                assert!(k % b == 0, "block {b} does not divide K={k}");
                b
            }
            BlockSize::Channelwise => k,
        }
    }

    pub fn label(&self) -> String {
        match self {
            BlockSize::Sub(b) => b.to_string(),
            BlockSize::Channelwise => "CW".into(),
        }
    }
}

/// Full weight-quantization configuration.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    pub format: FormatSpec,
    pub block: BlockSize,
    pub calib: Calib,
}

impl QuantConfig {
    pub fn rtn(format: FormatSpec) -> Self {
        QuantConfig { format, block: BlockSize::Sub(128), calib: Calib::None }
    }
}

/// A quantized weight matrix: codes into the codebook + per-block scales.
#[derive(Clone, Debug)]
pub struct QuantizedWeight {
    /// [K, N] codebook indices.
    pub codes: Vec<i8>,
    /// [K/block, N] scales.
    pub scales: Tensor,
    pub k: usize,
    pub n: usize,
    pub block: usize,
}

impl QuantizedWeight {
    /// Scales expanded to one per (row, column) — the artifact input layout.
    pub fn expanded_scales(&self) -> Tensor {
        let nb = self.k / self.block;
        let mut out = vec![0.0f32; self.k * self.n];
        for bi in 0..nb {
            for r in 0..self.block {
                let k = bi * self.block + r;
                out[k * self.n..(k + 1) * self.n]
                    .copy_from_slice(self.scales.row(bi));
            }
        }
        Tensor::new(&[self.k, self.n], out)
    }

    /// Dequantized (fake-quant) weights.
    pub fn dequant(&self, spec: &FormatSpec) -> Tensor {
        let cb: Vec<f32> = spec.codebook.iter().map(|&v| v as f32).collect();
        let mut out = vec![0.0f32; self.k * self.n];
        for k in 0..self.k {
            let srow = self.scales.row(k / self.block);
            for j in 0..self.n {
                out[k * self.n + j] = cb[self.codes[k * self.n + j] as usize] * srow[j];
            }
        }
        Tensor::new(&[self.k, self.n], out)
    }
}

// ---------------------------------------------------------------------------
// Packed 4-bit weights + fused dequant-GEMM
// ---------------------------------------------------------------------------

/// A weight matrix stored at its true 4-bit footprint: two codebook indices
/// per byte, per-block scales, and the format's 16-entry dequant LUT
/// (`FormatSpec::padded16`). This is the serving engine's packed weight
/// backend (QLoRA-style codebook storage): ~8x less weight traffic than the
/// dequantized f32 tensor the fake-quant path streams on every decode step.
#[derive(Clone, Debug)]
pub struct PackedWeight {
    /// `[K, ceil(N/2)]` row-major packed nibbles: column `2j` in the low
    /// nibble and `2j+1` in the high nibble of byte `k * row_bytes + j`.
    /// Odd `N` leaves the last high nibble zero.
    pub packed: Vec<u8>,
    /// `[K/block, N]` scales (same layout as [`QuantizedWeight::scales`]).
    pub scales: Tensor,
    /// The codebook padded to 16 f32 entries — the dequant LUT.
    pub lut: [f32; 16],
    pub k: usize,
    pub n: usize,
    pub block: usize,
}

impl PackedWeight {
    /// Pack a [`QuantizedWeight`] produced under a <= 4-bit codebook.
    /// Panics if the format has more than 16 values (codes must fit a
    /// nibble — every 4-bit format in the zoo qualifies).
    pub fn from_quantized(q: &QuantizedWeight, spec: &FormatSpec) -> PackedWeight {
        assert!(
            spec.n_values() <= 16,
            "{}: {} codebook values do not fit 4-bit packing",
            spec.name,
            spec.n_values()
        );
        let padded = spec.padded16();
        let mut lut = [0.0f32; 16];
        lut.copy_from_slice(&padded);
        let row_bytes = q.n.div_ceil(2);
        let mut packed = vec![0u8; q.k * row_bytes];
        for kk in 0..q.k {
            let crow = &q.codes[kk * q.n..(kk + 1) * q.n];
            let prow = &mut packed[kk * row_bytes..(kk + 1) * row_bytes];
            for (j, &c) in crow.iter().enumerate() {
                debug_assert!((0..16).contains(&c), "code {c} out of nibble range");
                prow[j / 2] |= (c as u8 & 0x0f) << (4 * (j % 2));
            }
        }
        PackedWeight {
            packed,
            scales: q.scales.clone(),
            lut,
            k: q.k,
            n: q.n,
            block: q.block,
        }
    }

    /// Bytes per row of packed codes.
    pub fn row_bytes(&self) -> usize {
        self.n.div_ceil(2)
    }

    /// Total storage footprint (codes + scales), for traffic accounting.
    pub fn bytes(&self) -> usize {
        self.packed.len() + self.scales.len() * 4 + 16 * 4
    }

    /// Code at `(k, j)` (unpacked nibble).
    pub fn code(&self, k: usize, j: usize) -> u8 {
        let b = self.packed[k * self.row_bytes() + j / 2];
        (b >> (4 * (j % 2))) & 0x0f
    }

    /// Dequantized f32 weights — bit-identical to
    /// [`QuantizedWeight::dequant`] on the source codes (`lut[c] * scale`,
    /// same f32 expression). Reference/fallback path; the serving engine
    /// never materializes this.
    pub fn dequant(&self) -> Tensor {
        let mut out = vec![0.0f32; self.k * self.n];
        for kk in 0..self.k {
            let srow = self.scales.row(kk / self.block);
            let orow = &mut out[kk * self.n..(kk + 1) * self.n];
            for j in 0..self.n {
                orow[j] = self.lut[self.code(kk, j) as usize] * srow[j];
            }
        }
        Tensor::new(&[self.k, self.n], out)
    }
}

/// Fused dequant-GEMM: `x [M, K] @ dequant(w) [K, N]`, expanding the packed
/// nibbles through the 16-entry LUT on the fly. The weight stream from
/// memory is the 4-bit codes (+ per-block scales); the f32 expansion lives
/// only in a `[KC, N]` cache-resident tile that the blocked
/// [`crate::tensor::gemm`] kernel consumes immediately. The 64-byte LUT
/// stays register/L1-resident and the scale row streams sequentially, so
/// the per-element expansion is a nibble extract, one tiny-table load and
/// one multiply — `lut[code] * scale`, the exact f32 expression
/// [`PackedWeight::dequant`] uses.
///
/// The K-block boundaries, the expansion expression and the inner kernel
/// are exactly those of the dense path (`dequant()` then `Tensor::matmul`),
/// so the result is bit-identical to it row for row — the packed backend
/// inherits the batch-row bit-identity contract of `tensor::gemm`
/// (`rust/tests/packed_weight.rs` locks both properties down).
pub fn lut_gemm(x: &Tensor, w: &PackedWeight) -> Tensor {
    let (m, k) = (x.rows(), x.cols());
    assert_eq!(k, w.k, "lut_gemm: x [{m}, {k}] vs packed [{}, {}]", w.k, w.n);
    let n = w.n;
    let mut out = vec![0.0f32; m * n];
    lut_gemm_into(m, k, n, x.data(), w, &mut out);
    Tensor::new(&[m, n], out)
}

// Reusable expansion scratch: `lut_gemm_into` runs once per linear per
// decode micro-step, and its `[KC, N]` tile would otherwise be a fresh
// multi-hundred-KB allocation each time on the exact hot path the fused
// kernel exists to speed up. The buffers only grow; every element the GEMM
// reads is freshly written first, so stale contents are never observed.
thread_local! {
    static LUT_SCRATCH: std::cell::RefCell<(Vec<f32>, Vec<f32>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
}

/// Accumulating slice-level core of [`lut_gemm`] (caller provides a zeroed
/// or pre-accumulated `out [M, N]`).
pub fn lut_gemm_into(
    m: usize,
    k: usize,
    n: usize,
    x: &[f32],
    w: &PackedWeight,
    out: &mut [f32],
) {
    use crate::tensor::GEMM_KC;
    assert_eq!(x.len(), m * k, "lut_gemm: x is not [{m}, {k}]");
    assert_eq!(out.len(), m * n, "lut_gemm: out is not [{m}, {n}]");
    assert_eq!(k, w.k, "lut_gemm: K mismatch");
    assert_eq!(n, w.n, "lut_gemm: N mismatch");
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let _span = crate::obs::trace::span("kernel", "quant.lut_gemm")
        .arg("m", m as f64)
        .arg("k", k as f64)
        .arg("n", n as f64);
    let row_bytes = w.row_bytes();
    let lut = &w.lut;
    let kc = GEMM_KC.min(k);
    LUT_SCRATCH.with(|scratch| {
        let mut scratch = scratch.borrow_mut();
        let (wtile, xpanel) = &mut *scratch;
        if wtile.len() < kc * n {
            wtile.resize(kc * n, 0.0);
        }
        if xpanel.len() < m * kc {
            xpanel.resize(m * kc, 0.0);
        }
        lut_gemm_blocks(m, k, n, x, w, row_bytes, lut, wtile, xpanel, out);
    });
}

/// The K-block loop of [`lut_gemm_into`] over caller-provided scratch.
#[allow(clippy::too_many_arguments)]
fn lut_gemm_blocks(
    m: usize,
    k: usize,
    n: usize,
    x: &[f32],
    w: &PackedWeight,
    row_bytes: usize,
    lut: &[f32; 16],
    wtile: &mut [f32],
    xpanel: &mut [f32],
    out: &mut [f32],
) {
    use crate::tensor::{gemm_auto_threads, gemm_threaded, simd, GEMM_KC};
    // One threading decision from the full problem, not per K-block: a
    // prefill-sized call threads its MAC exactly where the dense path
    // would (the per-block m*kb*n would under-count by k/KC).
    let threads = gemm_auto_threads(m, k, n);
    // One ISA decision and one LUT byte-plane split per call: the SIMD
    // expansion shuffles nibbles through the planes in-register, computing
    // the exact per-element `lut[code] * scale` the scalar loop does
    // (bit-identical — `rust/tests/simd_kernels.rs`).
    let isa = simd::active();
    let planes = simd::NibbleLut::new(lut);
    let mut k0 = 0usize;
    while k0 < k {
        let kb = GEMM_KC.min(k - k0);
        for kk in 0..kb {
            let kabs = k0 + kk;
            let srow = w.scales.row(kabs / w.block);
            let prow = &w.packed[kabs * row_bytes..(kabs + 1) * row_bytes];
            let wrow = &mut wtile[kk * n..kk * n + n];
            match isa {
                simd::Isa::Scalar => {
                    for (jh, &byte) in prow.iter().enumerate() {
                        let j = 2 * jh;
                        wrow[j] = lut[(byte & 0x0f) as usize] * srow[j];
                        if j + 1 < n {
                            wrow[j + 1] = lut[(byte >> 4) as usize] * srow[j + 1];
                        }
                    }
                }
                isa => simd::lut_expand_row(isa, &planes, lut, prow, &srow[..n], wrow),
            }
        }
        // feed the blocked kernel this K block's x columns: when the whole
        // problem is one block (K <= KC — every d_model-sized decode
        // linear), x already is the contiguous [m, kb] panel, so skip the
        // copy; otherwise pack the strided columns once per block
        let xa: &[f32] = if kb == k {
            x
        } else {
            for i in 0..m {
                xpanel[i * kb..(i + 1) * kb]
                    .copy_from_slice(&x[i * k + k0..i * k + k0 + kb]);
            }
            &xpanel[..m * kb]
        };
        gemm_threaded(m, kb, n, xa, &wtile[..kb * n], out, threads);
        k0 += kb;
    }
}

// ---------------------------------------------------------------------------
// W4A4: packed 4-bit activations + code x code GEMM
// ---------------------------------------------------------------------------

/// Activation-side 4-bit quantizer for the W4A4 serving path (the paper's
/// Table 8 setting): encodes each activation row into nibble codes +
/// per-block absmax scales through the same [`crate::formats::Encoder`]
/// machinery as the weight and KV encoders. Stateless per call — the scale
/// block is taken from the *weight* at apply time so both sides of
/// [`w4a4_gemm`] share K-block boundaries.
#[derive(Clone, Debug)]
pub struct ActQuantizer {
    /// Format name, for banners and error messages.
    pub name: String,
    lut: [f32; 16],
    enc: crate::formats::Encoder,
}

impl ActQuantizer {
    /// Build from a <= 4-bit format (panics on wider codebooks, mirroring
    /// [`PackedWeight::from_quantized`]).
    pub fn new(spec: &FormatSpec) -> ActQuantizer {
        assert!(
            spec.n_values() <= 16,
            "{}: {} codebook values do not fit 4-bit activation packing",
            spec.name,
            spec.n_values()
        );
        let padded = spec.padded16();
        let mut lut = [0.0f32; 16];
        lut.copy_from_slice(&padded);
        ActQuantizer { name: spec.name.to_string(), lut, enc: spec.encoder() }
    }

    /// The activation codebook padded to 16 f32 entries.
    pub fn lut(&self) -> &[f32; 16] {
        &self.lut
    }

    /// Encode `x [M, K]` with absmax scale blocks of `block` along K —
    /// the per-row analogue of `KvFormat::encode_row`. `block` must be
    /// even and divide K (weight blocks satisfy both: `BlockSize::resolve`
    /// asserts divisibility and every zoo block is a power of two).
    pub fn encode(&self, x: &Tensor, block: usize) -> PackedActivations {
        let (m, k) = (x.rows(), x.cols());
        assert!(block > 0 && block % 2 == 0, "activation block {block} must be even");
        assert!(k % block == 0, "activation block {block} does not divide K={k}");
        assert!(
            block <= crate::tensor::LANE_MAX_BLOCK,
            "activation block {block} exceeds LANE_MAX_BLOCK"
        );
        let row_bytes = k / 2;
        let nb = k / block;
        let mut codes = vec![0u8; m * row_bytes];
        let mut scales = vec![0.0f32; m * nb];
        let mut scaled = [0.0f32; crate::tensor::LANE_MAX_BLOCK];
        let mut block_codes = [0i8; crate::tensor::LANE_MAX_BLOCK];
        for i in 0..m {
            let row = x.row(i);
            for b in 0..nb {
                let vals = &row[b * block..(b + 1) * block];
                let s = block_scale_enc(&self.enc, vals, Calib::None);
                let inv = 1.0 / s;
                for (sv, &v) in scaled[..block].iter_mut().zip(vals) {
                    *sv = v * inv;
                }
                self.enc.encode_block(&scaled[..block], &mut block_codes[..block]);
                let cbase = i * row_bytes + (b * block) / 2;
                for p in 0..block / 2 {
                    let lo = block_codes[2 * p] as u8 & 0x0f;
                    let hi = block_codes[2 * p + 1] as u8 & 0x0f;
                    codes[cbase + p] = lo | (hi << 4);
                }
                scales[i * nb + b] = s;
            }
        }
        PackedActivations { codes, scales, lut: self.lut, m, k, block }
    }
}

/// An activation tile at its true 4-bit footprint: the [`PackedWeight`]
/// nibble layout turned sideways — codes run along K within each *row*
/// (two per byte, low nibble first) with one absmax scale per
/// (row, K-block). Produced fresh per linear per micro-step by
/// [`ActQuantizer::encode`]; consumed by [`w4a4_gemm`].
#[derive(Clone, Debug)]
pub struct PackedActivations {
    /// `[M, K/2]` packed nibbles: column `2p` in the low nibble and
    /// `2p+1` in the high nibble of byte `i * (K/2) + p`.
    pub codes: Vec<u8>,
    /// `[M, K/block]` per-block absmax scales.
    pub scales: Vec<f32>,
    /// The activation codebook padded to 16 f32 entries.
    pub lut: [f32; 16],
    pub m: usize,
    pub k: usize,
    pub block: usize,
}

impl PackedActivations {
    /// Code at `(i, kk)` (unpacked nibble).
    pub fn code(&self, i: usize, kk: usize) -> u8 {
        let b = self.codes[i * (self.k / 2) + kk / 2];
        (b >> (4 * (kk % 2))) & 0x0f
    }

    /// Dequantized f32 activations (`lut[c] * scale`) — the oracle the
    /// W4A4 GEMM is tested against.
    pub fn dequant(&self) -> Tensor {
        let nb = self.k / self.block;
        let mut out = vec![0.0f32; self.m * self.k];
        for i in 0..self.m {
            for kk in 0..self.k {
                out[i * self.k + kk] =
                    self.lut[self.code(i, kk) as usize] * self.scales[i * nb + kk / self.block];
            }
        }
        Tensor::new(&[self.m, self.k], out)
    }
}

/// W4A4 code x code GEMM: both operands stream as 4-bit codes and the
/// inner product walks a 16 x 16 = 256-entry *product LUT*
/// (`plut[ac * 16 + wc] = a_lut[ac] * w_lut[wc]`). Because both per-block
/// scales factor out of the block's partial sum, one product LUT serves
/// every (row, K-block, column) cell:
///
/// ```text
/// out[i][j] = sum_b  a_scale[i][b] * w_scale[b][j] * sum_kk plut[ac, wc]
/// ```
///
/// Numerically this is `xq.dequant() @ w.dequant()` with the scalar
/// multiplications regrouped per block — W4A4 changes numerics *by design*
/// (the activations themselves are quantized), so the contract is the
/// Table-8-style NLL-delta gate in `rust/tests/simd_kernels.rs`, not
/// bit-identity. Requires both sides to share K and scale-block size
/// (the serving path encodes activations with the weight's own block).
pub fn w4a4_gemm(xq: &PackedActivations, w: &PackedWeight) -> Tensor {
    assert_eq!(xq.k, w.k, "w4a4_gemm: K mismatch ({} vs {})", xq.k, w.k);
    assert_eq!(
        xq.block, w.block,
        "w4a4_gemm: scale blocks must align along K ({} vs {})",
        xq.block, w.block
    );
    let (m, k, n) = (xq.m, xq.k, w.n);
    let _span = crate::obs::trace::span("kernel", "quant.w4a4_gemm")
        .arg("m", m as f64)
        .arg("k", k as f64)
        .arg("n", n as f64);
    // activation-code-major so the inner column loop reads a contiguous
    // 16-entry slice per K position
    let mut plut = [0.0f32; 256];
    for (ac, pl) in plut.chunks_mut(16).enumerate() {
        for (wc, p) in pl.iter_mut().enumerate() {
            *p = xq.lut[ac] * w.lut[wc];
        }
    }
    let block = w.block;
    let nb = k / block;
    let wrow_bytes = w.row_bytes();
    let arow_bytes = k / 2;
    let mut out = vec![0.0f32; m * n];
    let mut acc = vec![0.0f32; n];
    let mut acodes = vec![0u8; block];
    for i in 0..m {
        for b in 0..nb {
            // unpack this row-block's activation codes once
            let abase = i * arow_bytes + (b * block) / 2;
            for (p, &byte) in xq.codes[abase..abase + block / 2].iter().enumerate() {
                acodes[2 * p] = byte & 0x0f;
                acodes[2 * p + 1] = byte >> 4;
            }
            acc.fill(0.0);
            for (kk, &ac) in acodes.iter().enumerate() {
                let kabs = b * block + kk;
                let prow = &w.packed[kabs * wrow_bytes..(kabs + 1) * wrow_bytes];
                let pl = &plut[(ac as usize) * 16..(ac as usize) * 16 + 16];
                for (jh, &byte) in prow.iter().enumerate() {
                    let j = 2 * jh;
                    acc[j] += pl[(byte & 0x0f) as usize];
                    if j + 1 < n {
                        acc[j + 1] += pl[(byte >> 4) as usize];
                    }
                }
            }
            let ascale = xq.scales[i * nb + b];
            let wsrow = w.scales.row(b);
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += ascale * wsrow[j] * acc[j];
            }
        }
    }
    Tensor::new(&[m, n], out)
}

/// Scale for one block of values under the given calibration policy.
///
/// The codebook is max-|v|=1 normalized, so the absmax scale is simply the
/// block's absmax; MSE searches clip ratios in (0, 1] against reconstruction
/// error (paper's "weight-based MSE clipping").
pub fn block_scale(spec: &FormatSpec, values: &[f32], calib: Calib) -> f32 {
    block_scale_enc(&spec.encoder(), values, calib)
}

/// `block_scale` over a prebuilt encoder (hot path; no allocation).
pub fn block_scale_enc(enc: &crate::formats::Encoder, values: &[f32], calib: Calib) -> f32 {
    let absmax = values.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if absmax == 0.0 {
        return 1.0; // all-zero block: any scale reconstructs exactly
    }
    match calib {
        Calib::None => absmax,
        Calib::Mse => {
            // §Perf iteration 2: coarse-to-fine clip search (10 + 8 points)
            // instead of a flat 40-point grid — same reconstruction quality
            // on the paper's formats, ~2.2x faster (bench mse_sf4_1Mx4B).
            let eval = |ratio: f32| -> f64 {
                let s = absmax * ratio;
                let inv = 1.0 / s;
                let mut err = 0.0f64;
                for &x in values {
                    let q = enc.quantize(x * inv) * s;
                    err += ((x - q) as f64).powi(2);
                }
                err
            };
            let mut best = (f64::INFINITY, 1.0f32);
            for i in 0..10 {
                let ratio = 0.35 + 0.65 * (i as f32 + 1.0) / 10.0;
                let err = eval(ratio);
                if err < best.0 {
                    best = (err, ratio);
                }
            }
            let (lo, hi) = ((best.1 - 0.065).max(0.05), (best.1 + 0.065).min(1.0));
            for i in 0..8 {
                let ratio = lo + (hi - lo) * i as f32 / 7.0;
                let err = eval(ratio);
                if err < best.0 {
                    best = (err, ratio);
                }
            }
            absmax * best.1
        }
    }
}

/// Quantize a `[K, N]` weight matrix blockwise (RTN within each block).
pub fn quantize_weight(w: &Tensor, cfg: &QuantConfig) -> QuantizedWeight {
    let (k, n) = (w.rows(), w.cols());
    let block = cfg.block.resolve(k);
    let nb = k / block;
    let mut codes = vec![0i8; k * n];
    let mut scales = Tensor::zeros(&[nb, n]);
    // §Perf iteration 1: hoist the encoder (midpoint table) out of the
    // per-element loop — the old per-value `FormatSpec::encode` allocated
    // its midpoints on every call (28.6 -> see bench_output.txt MB/s).
    let enc = cfg.format.encoder();

    // gather per-(block, column) values column-major to compute scales
    let mut colvals = vec![0.0f32; block];
    let mut scaled = vec![0.0f32; block];
    let mut col_codes = vec![0i8; block];
    for bi in 0..nb {
        for j in 0..n {
            for r in 0..block {
                colvals[r] = w.at2(bi * block + r, j);
            }
            let s = block_scale_enc(&enc, &colvals, cfg.calib);
            scales.set2(bi, j, s);
            let inv = 1.0 / s;
            // §Perf iteration 3: normalize + encode the whole block through
            // the slice-level `Encoder::encode_block` instead of a per-value
            // `encode` call — one bounds-check amortization per block, and
            // the midpoint scan vectorizes across the slice (perf_quant
            // rtn_* benches track this loop).
            for (sv, &v) in scaled.iter_mut().zip(&colvals) {
                *sv = v * inv;
            }
            enc.encode_block(&scaled, &mut col_codes);
            for r in 0..block {
                codes[(bi * block + r) * n + j] = col_codes[r];
            }
        }
    }
    QuantizedWeight { codes, scales, k, n, block }
}

/// Fake-quantize activations per row (absmax), mirroring the L1 `act_quant`
/// kernel — used by the pure-Rust calibration forward for W4A4.
pub fn fake_quant_rows(x: &Tensor, spec: &FormatSpec) -> Tensor {
    let (m, k) = (x.rows(), x.cols());
    let cbmax = spec.codebook.iter().fold(0.0f64, |a, &v| a.max(v.abs()));
    let enc = spec.encoder();
    let mut out = vec![0.0f32; m * k];
    for i in 0..m {
        let row = x.row(i);
        let absmax = row.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let s = if absmax > 0.0 { absmax / cbmax as f32 } else { 1.0 };
        let inv = 1.0 / s;
        for (j, &v) in row.iter().enumerate() {
            out[i * k + j] = enc.quantize(v * inv) * s;
        }
    }
    Tensor::new(&[m, k], out)
}

/// Reconstruction MSE of a quantized weight vs the original.
pub fn recon_error(w: &Tensor, q: &QuantizedWeight, spec: &FormatSpec) -> f64 {
    w.sq_err(&q.dequant(spec)) / w.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats;
    use crate::rng::Pcg64;

    fn rand_w(k: usize, n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::new(seed);
        Tensor::new(&[k, n], rng.student_t_vec(k * n, 5.0, 0.02))
    }

    #[test]
    fn quantize_roundtrip_within_block_error_bound() {
        let w = rand_w(128, 16, 1);
        let spec = formats::must("int4");
        let cfg = QuantConfig {
            format: spec.clone(),
            block: BlockSize::Sub(32),
            calib: Calib::None,
        };
        let q = quantize_weight(&w, &cfg);
        let deq = q.dequant(&spec);
        // absmax scaling: error <= scale * max(gap/2, 1 - max(cb)); INT4's
        // asymmetric top (0.875) makes the positive edge the worst case.
        for bi in 0..4 {
            for j in 0..16 {
                let s = q.scales.at2(bi, j);
                for r in 0..32 {
                    let k = bi * 32 + r;
                    let e = (w.at2(k, j) - deq.at2(k, j)).abs();
                    assert!(e <= s * 0.1251, "err {e} scale {s}");
                }
            }
        }
    }

    #[test]
    fn idempotent() {
        let w = rand_w(64, 8, 2);
        let spec = formats::must("sf4");
        let cfg = QuantConfig {
            format: spec.clone(),
            block: BlockSize::Sub(64),
            calib: Calib::None,
        };
        let q1 = quantize_weight(&w, &cfg);
        let d1 = q1.dequant(&spec);
        let q2 = quantize_weight(&d1, &cfg);
        let d2 = q2.dequant(&spec);
        for (a, b) in d1.data().iter().zip(d2.data()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn scale_invariance() {
        // quantizing c*W must equal c * quantize(W) under absmax scaling
        let w = rand_w(64, 4, 3);
        let w2 = w.scale(7.5);
        let spec = formats::must("e2m1");
        let cfg = QuantConfig {
            format: spec.clone(),
            block: BlockSize::Sub(64),
            calib: Calib::None,
        };
        let d1 = quantize_weight(&w, &cfg).dequant(&spec).scale(7.5);
        let d2 = quantize_weight(&w2, &cfg).dequant(&spec);
        for (a, b) in d1.data().iter().zip(d2.data()) {
            assert!((a - b).abs() < 1e-4, "{a} {b}");
        }
    }

    #[test]
    fn zero_weights_survive() {
        let mut w = rand_w(32, 4, 4);
        for j in 0..4 {
            w.set2(5, j, 0.0);
        }
        let spec = formats::must("nf4");
        let cfg = QuantConfig {
            format: spec.clone(),
            block: BlockSize::Sub(32),
            calib: Calib::Mse,
        };
        let deq = quantize_weight(&w, &cfg).dequant(&spec);
        for j in 0..4 {
            assert_eq!(deq.at2(5, j), 0.0, "zero not preserved");
        }
    }

    #[test]
    fn mse_never_worse_than_absmax() {
        for fmt in ["int4", "e2m1", "sf4", "e3m0"] {
            let w = rand_w(128, 8, 5);
            let spec = formats::must(fmt);
            let mk = |calib| QuantConfig {
                format: spec.clone(),
                block: BlockSize::Sub(128),
                calib,
            };
            let e_none = recon_error(&w, &quantize_weight(&w, &mk(Calib::None)), &spec);
            let e_mse = recon_error(&w, &quantize_weight(&w, &mk(Calib::Mse)), &spec);
            assert!(e_mse <= e_none * 1.0001, "{fmt}: {e_mse} vs {e_none}");
        }
    }

    #[test]
    fn smaller_blocks_reduce_error() {
        let w = rand_w(256, 8, 6);
        let spec = formats::must("int4");
        let err = |bs| {
            let cfg = QuantConfig {
                format: spec.clone(),
                block: bs,
                calib: Calib::None,
            };
            recon_error(&w, &quantize_weight(&w, &cfg), &spec)
        };
        let e16 = err(BlockSize::Sub(16));
        let e128 = err(BlockSize::Sub(128));
        let ecw = err(BlockSize::Channelwise);
        assert!(e16 < e128, "{e16} {e128}");
        assert!(e128 <= ecw * 1.0001, "{e128} {ecw}");
    }

    #[test]
    fn expanded_scales_shape_and_content() {
        let w = rand_w(64, 4, 7);
        let spec = formats::must("sf4");
        let cfg = QuantConfig {
            format: spec,
            block: BlockSize::Sub(16),
            calib: Calib::None,
        };
        let q = quantize_weight(&w, &cfg);
        let exp = q.expanded_scales();
        assert_eq!(exp.shape(), &[64, 4]);
        for k in 0..64 {
            for j in 0..4 {
                assert_eq!(exp.at2(k, j), q.scales.at2(k / 16, j));
            }
        }
    }

    #[test]
    fn packed_weight_roundtrips_codes_and_dequant() {
        // odd N exercises the half-filled trailing byte per row
        let w = rand_w(64, 7, 11);
        let spec = formats::must("sf4");
        let cfg = QuantConfig {
            format: spec.clone(),
            block: BlockSize::Sub(32),
            calib: Calib::None,
        };
        let q = quantize_weight(&w, &cfg);
        let p = PackedWeight::from_quantized(&q, &spec);
        assert_eq!(p.packed.len(), 64 * 4, "ceil(7/2) bytes per row");
        for kk in 0..64 {
            for j in 0..7 {
                assert_eq!(p.code(kk, j) as i8, q.codes[kk * 7 + j], "({kk},{j})");
            }
        }
        // dequant is the same f32 expression — exactly equal, not just close
        assert_eq!(p.dequant().data(), q.dequant(&spec).data());
        // far below the dequantized f32 footprint even with scales aboard
        assert!(p.bytes() * 3 < 64 * 7 * 4, "{} bytes packed", p.bytes());
    }

    #[test]
    fn lut_gemm_matches_dequant_matmul() {
        let w = rand_w(320, 33, 12); // K crosses the GEMM_KC=256 boundary
        let spec = formats::must("e2m1_sp");
        let cfg = QuantConfig {
            format: spec.clone(),
            block: BlockSize::Sub(64),
            calib: Calib::None,
        };
        let q = quantize_weight(&w, &cfg);
        let p = PackedWeight::from_quantized(&q, &spec);
        let mut rng = Pcg64::new(13);
        let x = Tensor::new(&[5, 320], rng.normal_vec(5 * 320, 1.0));
        let fused = lut_gemm(&x, &p);
        let dense = x.matmul(&q.dequant(&spec));
        assert_eq!(fused.shape(), dense.shape());
        assert_eq!(fused.data(), dense.data(), "fused path must be bit-identical");
    }

    #[test]
    fn packed_activations_roundtrip_codes_and_scales() {
        let mut rng = Pcg64::new(21);
        let x = Tensor::new(&[3, 64], rng.normal_vec(3 * 64, 1.0));
        let spec = formats::must("sf4");
        let aq = ActQuantizer::new(&spec);
        let xq = aq.encode(&x, 32);
        assert_eq!(xq.codes.len(), 3 * 32, "K/2 bytes per row");
        assert_eq!(xq.scales.len(), 3 * 2, "K/block scales per row");
        // every scale is the block absmax (Calib::None), and dequant is the
        // exact lut[c] * scale expression per element
        let enc = spec.encoder();
        let deq = xq.dequant();
        for i in 0..3 {
            for b in 0..2 {
                let vals = &x.row(i)[b * 32..(b + 1) * 32];
                let s = block_scale_enc(&enc, vals, Calib::None);
                assert_eq!(xq.scales[i * 2 + b], s, "({i},{b}) scale");
            }
            for kk in 0..64 {
                let want = xq.lut[xq.code(i, kk) as usize] * xq.scales[i * 2 + kk / 32];
                assert_eq!(deq.at2(i, kk), want, "({i},{kk}) dequant");
            }
        }
    }

    #[test]
    fn w4a4_gemm_matches_dequant_dequant_matmul() {
        // the product-LUT regrouping only reorders scalar multiplications,
        // so against the dequantize-both-sides oracle the result is equal
        // up to f32 reassociation of the per-block scale factors
        for fmt in ["sf4", "int4", "e2m1"] {
            let spec = formats::must(fmt);
            let w = rand_w(128, 9, 31); // odd N: trailing high nibble unused
            let cfg = QuantConfig {
                format: spec.clone(),
                block: BlockSize::Sub(32),
                calib: Calib::None,
            };
            let q = quantize_weight(&w, &cfg);
            let p = PackedWeight::from_quantized(&q, &spec);
            let mut rng = Pcg64::new(37);
            let x = Tensor::new(&[4, 128], rng.normal_vec(4 * 128, 1.0));
            let aq = ActQuantizer::new(&spec);
            let xq = aq.encode(&x, p.block);
            let fused = w4a4_gemm(&xq, &p);
            let dense = xq.dequant().matmul(&p.dequant());
            assert_eq!(fused.shape(), dense.shape());
            for (i, (a, b)) in fused.data().iter().zip(dense.data()).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                    "{fmt} elem {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn w4a4_padding_nibbles_do_not_leak_into_odd_n() {
        // odd N leaves each weight row's trailing high nibble zero; the
        // product LUT has plut[ac][0] != 0 in general, so the guard in the
        // inner loop must keep the phantom column out of the result
        let spec = formats::must("sf4");
        let w = rand_w(32, 1, 41); // N=1: every byte is half padding
        let cfg =
            QuantConfig { format: spec.clone(), block: BlockSize::Sub(32), calib: Calib::None };
        let q = quantize_weight(&w, &cfg);
        let p = PackedWeight::from_quantized(&q, &spec);
        let mut rng = Pcg64::new(43);
        let x = Tensor::new(&[2, 32], rng.normal_vec(2 * 32, 1.0));
        let aq = ActQuantizer::new(&spec);
        let xq = aq.encode(&x, 32);
        let fused = w4a4_gemm(&xq, &p);
        let dense = xq.dequant().matmul(&p.dequant());
        for (a, b) in fused.data().iter().zip(dense.data()) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn sf4_beats_int4_on_t_distributed_weights() {
        // the paper's core mechanism, in miniature: heavy-tailed weights are
        // reconstructed better by SF4 than INT4 at the same bit budget.
        let w = rand_w(256, 32, 8); // t(nu=5) samples
        let mk = |name: &str| {
            let spec = formats::must(name);
            let cfg = QuantConfig {
                format: spec.clone(),
                block: BlockSize::Sub(128),
                calib: Calib::None,
            };
            recon_error(&w, &quantize_weight(&w, &cfg), &spec)
        };
        let e_sf4 = mk("sf4");
        let e_int4 = mk("int4");
        let e_e2m1 = mk("e2m1");
        assert!(e_sf4 < e_int4, "sf4 {e_sf4} vs int4 {e_int4}");
        assert!(e_e2m1 < e_int4, "e2m1 {e_e2m1} vs int4 {e_int4}");
    }

    #[test]
    fn fake_quant_rows_matches_row_absmax() {
        let x = rand_w(8, 64, 9);
        let spec = formats::must("int4");
        let y = fake_quant_rows(&x, &spec);
        for i in 0..8 {
            let am_x: f32 = x.row(i).iter().fold(0.0, |a, &v| a.max(v.abs()));
            let am_y: f32 = y.row(i).iter().fold(0.0, |a, &v| a.max(v.abs()));
            assert!(am_y <= am_x * 1.0001);
        }
    }
}
