//! Packed 4-bit KV-cache lane codec — the paper's codebooks applied to the
//! *cache*, not just the weights.
//!
//! Sustained decode streams every cached K/V position per layer per step;
//! after PR 3 removed f32 weights from the packed serving path, that fp32
//! KV traffic is the dominant stream. Cached keys/values are activations,
//! and the paper's core claim — LLM activations follow Student's
//! t-distributions, so SF4/NF4-style codebooks quantize them accurately —
//! applies to them directly. [`KvFormat`] quantizes one cached position
//! (one K or V row of `d_model` values) into nibble codes plus per-block
//! absmax scales, mirroring the weight path's sub-channel RTN
//! (`Encoder::encode_block` + `block_scale_enc`), at ~8x less storage and
//! ~5x less read traffic per position (codes + scales vs f32).
//!
//! Lane layout (one layer of one sequence, `capacity` positions):
//!
//! ```text
//! codes:  [capacity, d/2]      u8 — column 2j low nibble, 2j+1 high nibble
//! scales: [capacity, d/block]  f32 — per-block absmax dequant scales
//! lut:    [f32; 16]            the format's padded16() codebook (shared)
//! ```
//!
//! The engine picks `block = d_head`, so every attention head covers whole
//! scale blocks and the fused kernels (`tensor::lut_attend_head`) can hold
//! one `lut * scale` 16-entry tile in registers per (position, head).
//! Dequantization is `lut[code] * scale` — the exact f32 expression the
//! fused attention computes inline, so encode → [`KvFormat::dequant_row`] →
//! fp32 attend is the bit-identical oracle for the fused path.

use crate::formats::{Encoder, FormatSpec};
use crate::model_io::ModelConfig;
use crate::quant::{block_scale_enc, Calib};
use crate::tensor::{PackedLane, LANE_MAX_BLOCK};

/// One KV quantization configuration: a <= 16-value codebook (as its
/// padded16 LUT + hot-loop encoder) and the scale-block width.
#[derive(Clone, Debug)]
pub struct KvFormat {
    /// Source format name (zoo codebook).
    pub name: &'static str,
    /// The codebook padded to 16 f32 entries — the dequant LUT.
    pub lut: [f32; 16],
    /// Values per scale block along `d_model` (even; divides `d_model` and
    /// `d_head`).
    pub block: usize,
    enc: Encoder,
}

impl KvFormat {
    /// Build from a format spec. Panics if the codebook exceeds 16 values
    /// (codes must fit a nibble) or the block is odd/oversized — nibble
    /// pairs and the attention kernels' stack tiles both need even,
    /// bounded blocks.
    pub fn new(spec: &FormatSpec, block: usize) -> KvFormat {
        assert!(
            spec.n_values() <= 16,
            "{}: {} codebook values do not fit 4-bit KV packing",
            spec.name,
            spec.n_values()
        );
        assert!(block > 0 && block % 2 == 0, "KV scale block must be even, got {block}");
        assert!(block <= LANE_MAX_BLOCK, "KV scale block {block} exceeds {LANE_MAX_BLOCK}");
        let padded = spec.padded16();
        let mut lut = [0.0f32; 16];
        lut.copy_from_slice(&padded);
        KvFormat { name: spec.name, lut, block, enc: spec.encoder() }
    }

    /// The engine's geometry: one scale block per attention head
    /// (`block = d_head`), so head slices in the fused kernels are always
    /// block-aligned.
    pub fn for_model(spec: &FormatSpec, cfg: &ModelConfig) -> KvFormat {
        KvFormat::new(spec, cfg.d_head())
    }

    /// Packed code bytes per cached position of `d` values.
    pub fn codes_per_row(&self, d: usize) -> usize {
        d / 2
    }

    /// Scale entries per cached position of `d` values.
    pub fn scales_per_row(&self, d: usize) -> usize {
        d / self.block
    }

    /// Storage bytes per cached position of `d` values (codes + scales),
    /// for one of K or V.
    pub fn row_bytes(&self, d: usize) -> usize {
        self.codes_per_row(d) + self.scales_per_row(d) * 4
    }

    /// Packed code bytes one `page_rows`-position page holds — the
    /// page-granular storage unit of the paged KV cache (one K or V page
    /// of one layer).
    pub fn codes_per_page(&self, d: usize, page_rows: usize) -> usize {
        page_rows * self.codes_per_row(d)
    }

    /// Scale entries one `page_rows`-position page holds.
    pub fn scales_per_page(&self, d: usize, page_rows: usize) -> usize {
        page_rows * self.scales_per_row(d)
    }

    /// Storage bytes one page holds (codes + scales), for one of K or V.
    pub fn page_bytes(&self, d: usize, page_rows: usize) -> usize {
        page_rows * self.row_bytes(d)
    }

    /// Quantize one K/V row: per block, an absmax scale (`block_scale_enc`
    /// with [`Calib::None`], exactly the weight RTN policy) and nibble
    /// codes from `Encoder::encode_block` over the normalized values.
    pub fn encode_row(&self, row: &[f32], codes: &mut [u8], scales: &mut [f32]) {
        let d = row.len();
        assert!(d % 2 == 0 && d % self.block == 0, "row length {d} vs block {}", self.block);
        assert_eq!(codes.len(), self.codes_per_row(d), "codes buffer");
        assert_eq!(scales.len(), self.scales_per_row(d), "scales buffer");
        let mut scaled = [0.0f32; LANE_MAX_BLOCK];
        let mut block_codes = [0i8; LANE_MAX_BLOCK];
        for (bi, vals) in row.chunks(self.block).enumerate() {
            let s = block_scale_enc(&self.enc, vals, Calib::None);
            scales[bi] = s;
            let inv = 1.0 / s;
            for (sv, &v) in scaled[..self.block].iter_mut().zip(vals) {
                *sv = v * inv;
            }
            self.enc.encode_block(&scaled[..self.block], &mut block_codes[..self.block]);
            let cbase = bi * self.block / 2;
            for p in 0..self.block / 2 {
                let lo = block_codes[2 * p] as u8 & 0x0f;
                let hi = block_codes[2 * p + 1] as u8 & 0x0f;
                codes[cbase + p] = lo | (hi << 4);
            }
        }
    }

    /// Dequantize one encoded row — `lut[code] * scale` per element, the
    /// exact f32 expression the fused attention kernels compute inline.
    /// This is the oracle expansion the property tests attend over.
    pub fn dequant_row(&self, codes: &[u8], scales: &[f32], out: &mut [f32]) {
        let d = out.len();
        assert_eq!(codes.len(), self.codes_per_row(d), "codes buffer");
        assert_eq!(scales.len(), self.scales_per_row(d), "scales buffer");
        for (j, o) in out.iter_mut().enumerate() {
            let c = (codes[j / 2] >> (4 * (j % 2))) & 0x0f;
            *o = self.lut[c as usize] * scales[j / self.block];
        }
    }

    /// Round-trip one row through the codec (encode then dequantize) —
    /// convenience for oracles and quality tests.
    pub fn fake_quant_row(&self, row: &[f32], out: &mut [f32]) {
        let d = row.len();
        let mut codes = vec![0u8; self.codes_per_row(d)];
        let mut scales = vec![0.0f32; self.scales_per_row(d)];
        self.encode_row(row, &mut codes, &mut scales);
        self.dequant_row(&codes, &scales, out);
    }

    /// View a contiguous lane (`rows` encoded positions) as the kernel-side
    /// [`PackedLane`].
    pub fn lane<'a>(&'a self, codes: &'a [u8], scales: &'a [f32], d: usize) -> PackedLane<'a> {
        PackedLane { codes, scales, lut: &self.lut, d, block: self.block }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats;
    use crate::rng::Pcg64;

    fn fmt(name: &str, block: usize) -> KvFormat {
        KvFormat::new(&formats::must(name), block)
    }

    #[test]
    fn row_geometry() {
        let f = fmt("sf4", 16);
        assert_eq!(f.codes_per_row(64), 32);
        assert_eq!(f.scales_per_row(64), 4);
        assert_eq!(f.row_bytes(64), 32 + 16);
        // >= 5x less traffic than the fp32 row (64 * 4 = 256 bytes)
        assert!(f.row_bytes(64) * 5 <= 64 * 4);
    }

    #[test]
    fn page_geometry_scales_row_geometry() {
        // a page is page_rows rows, exactly — the paged cache's storage
        // accounting hangs off these
        let f = fmt("sf4", 16);
        assert_eq!(f.codes_per_page(64, 16), 16 * 32);
        assert_eq!(f.scales_per_page(64, 16), 16 * 4);
        assert_eq!(f.page_bytes(64, 16), 16 * f.row_bytes(64));
        assert_eq!(f.page_bytes(64, 1), f.row_bytes(64));
    }

    #[test]
    fn encode_dequant_error_bounded_by_block_absmax() {
        let mut rng = Pcg64::new(7);
        for name in ["sf4", "nf4", "e2m1_sp", "int4"] {
            let f = fmt(name, 16);
            let row = rng.student_t_vec(64, 5.0, 0.5);
            let mut deq = vec![0.0f32; 64];
            f.fake_quant_row(&row, &mut deq);
            for (bi, (vals, dq)) in row.chunks(16).zip(deq.chunks(16)).enumerate() {
                let absmax = vals.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                for (a, b) in vals.iter().zip(dq) {
                    assert!(
                        (a - b).abs() <= absmax * 0.26 + 1e-6,
                        "{name} block {bi}: {a} vs {b} (absmax {absmax})"
                    );
                }
            }
        }
    }

    #[test]
    fn codec_is_idempotent() {
        // re-encoding a dequantized row reproduces it exactly (codebook
        // points are fixed points of nearest-value rounding)
        let mut rng = Pcg64::new(8);
        let f = fmt("sf4", 16);
        let row = rng.normal_vec(32, 1.0);
        let mut once = vec![0.0f32; 32];
        f.fake_quant_row(&row, &mut once);
        let mut twice = vec![0.0f32; 32];
        f.fake_quant_row(&once, &mut twice);
        assert_eq!(once, twice);
    }

    #[test]
    fn zero_rows_and_blocks_survive() {
        let f = fmt("nf4", 16);
        let mut row = vec![0.0f32; 32];
        row[20] = 1.5; // second block non-zero, first all-zero
        let mut deq = vec![0.0f32; 32];
        f.fake_quant_row(&row, &mut deq);
        for &v in &deq[..16] {
            assert_eq!(v, 0.0, "all-zero block must reconstruct exactly");
        }
        assert!(deq[20] != 0.0);
    }

    #[test]
    fn lane_view_matches_dequant_row() {
        let mut rng = Pcg64::new(9);
        let f = fmt("e2m1_sp", 16);
        let (rows, d) = (5usize, 32usize);
        let mut codes = vec![0u8; rows * f.codes_per_row(d)];
        let mut scales = vec![0.0f32; rows * f.scales_per_row(d)];
        let mut dense = vec![0.0f32; rows * d];
        for r in 0..rows {
            let row = rng.normal_vec(d, 0.7);
            f.encode_row(
                &row,
                &mut codes[r * d / 2..(r + 1) * d / 2],
                &mut scales[r * 2..(r + 1) * 2],
            );
            let (crow, srow) = (&codes[r * d / 2..(r + 1) * d / 2], &scales[r * 2..(r + 1) * 2]);
            f.dequant_row(crow, srow, &mut dense[r * d..(r + 1) * d]);
        }
        let lane = f.lane(&codes, &scales, d);
        for r in 0..rows {
            for j in 0..d {
                let c = (lane.codes[r * d / 2 + j / 2] >> (4 * (j % 2))) & 0x0f;
                let got = lane.lut[c as usize] * lane.scales[r * 2 + j / lane.block];
                assert_eq!(got, dense[r * d + j], "({r},{j})");
            }
        }
    }

    #[test]
    #[should_panic(expected = "codebook values")]
    fn wide_codebooks_are_refused() {
        fmt("int5", 16); // 32 values
    }

    #[test]
    #[should_panic(expected = "must be even")]
    fn odd_blocks_are_refused() {
        fmt("sf4", 15);
    }
}
