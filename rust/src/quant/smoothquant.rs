//! SmoothQuant (Xiao et al., ICML 2023): migrate quantization difficulty
//! from activations to weights via per-input-channel smoothing.
//!
//! For a linear `y = x W` with per-channel activation absmax `a_j` and
//! weight absmax `w_j` (max over the output dim of row j), the smoothing
//! factor is `s_j = a_j^alpha / w_j^(1-alpha)`. The model then computes
//! `y = (x / s) (s W)`: the artifact takes `inv_smooth = 1/s` and the
//! quantizer sees the pre-scaled weights `s W`.

use crate::tensor::Tensor;

/// Result of the smoothing computation for one linear layer.
#[derive(Clone, Debug)]
pub struct SmoothQuant {
    /// s_j per input channel `[K]`.
    pub smooth: Vec<f32>,
    /// 1/s_j, the artifact-side activation multiplier `[K]`.
    pub inv_smooth: Vec<f32>,
}

/// Compute smoothing factors from calibration activations `x [M, K]` and
/// weights `w [K, N]`. `alpha` = 0.5 is the paper's default.
pub fn smooth_scales(x: &Tensor, w: &Tensor, alpha: f64) -> SmoothQuant {
    let k = w.rows();
    assert_eq!(x.cols(), k);
    let mut a_max = vec![0.0f32; k];
    for r in 0..x.rows() {
        for (j, &v) in x.row(r).iter().enumerate() {
            a_max[j] = a_max[j].max(v.abs());
        }
    }
    let mut w_max = vec![0.0f32; k];
    for j in 0..k {
        w_max[j] = w.row(j).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    }
    let mut smooth = vec![1.0f32; k];
    for j in 0..k {
        let a = (a_max[j] as f64).max(1e-8);
        let ww = (w_max[j] as f64).max(1e-8);
        let s = a.powf(alpha) / ww.powf(1.0 - alpha);
        smooth[j] = s.clamp(1e-4, 1e4) as f32;
    }
    let inv_smooth = smooth.iter().map(|&s| 1.0 / s).collect();
    SmoothQuant { smooth, inv_smooth }
}

impl SmoothQuant {
    /// Weights pre-scaled by s (row-wise): the tensor handed to the
    /// quantizer.
    pub fn apply_to_weight(&self, w: &Tensor) -> Tensor {
        let (k, n) = (w.rows(), w.cols());
        assert_eq!(self.smooth.len(), k);
        let mut out = w.clone();
        for j in 0..k {
            let s = self.smooth[j];
            for v in out.row_mut(j) {
                *v *= s;
            }
        }
        assert_eq!(out.shape(), &[k, n]);
        out
    }

    /// Identity smoothing (used when SmoothQuant is disabled).
    pub fn identity(k: usize) -> Self {
        SmoothQuant { smooth: vec![1.0; k], inv_smooth: vec![1.0; k] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn setup(seed: u64) -> (Tensor, Tensor) {
        let mut rng = Pcg64::new(seed);
        let mut xd = rng.normal_vec(64 * 32, 1.0);
        // plant activation outliers in a few channels (the SmoothQuant story)
        for r in 0..64 {
            xd[r * 32 + 3] *= 40.0;
            xd[r * 32 + 17] *= 25.0;
        }
        let x = Tensor::new(&[64, 32], xd);
        let w = Tensor::new(&[32, 16], rng.student_t_vec(32 * 16, 5.0, 0.02));
        (x, w)
    }

    #[test]
    fn float_product_is_invariant() {
        let (x, w) = setup(1);
        let sq = smooth_scales(&x, &w, 0.5);
        let w2 = sq.apply_to_weight(&w);
        // (x .* inv_s) @ (s .* W) == x @ W
        let mut xs = x.clone();
        for r in 0..xs.rows() {
            let row = xs.row_mut(r);
            for (j, v) in row.iter_mut().enumerate() {
                *v *= sq.inv_smooth[j];
            }
        }
        let y1 = x.matmul(&w);
        let y2 = xs.matmul(&w2);
        for (a, b) in y1.data().iter().zip(y2.data()) {
            assert!((a - b).abs() < 1e-3 * a.abs().max(1.0), "{a} {b}");
        }
    }

    #[test]
    fn outlier_channels_are_tamed() {
        let (x, w) = setup(2);
        let sq = smooth_scales(&x, &w, 0.5);
        // smoothed activation absmax of the outlier channel shrinks
        let mut before = 0.0f32;
        let mut after = 0.0f32;
        for r in 0..x.rows() {
            before = before.max(x.at2(r, 3).abs());
            after = after.max((x.at2(r, 3) * sq.inv_smooth[3]).abs());
        }
        assert!(after < before / 3.0, "{after} vs {before}");
    }

    #[test]
    fn alpha_zero_moves_nothing_to_weights() {
        // alpha=0: s_j = 1 / w_max_j — weights normalized to absmax 1/ch.
        let (x, w) = setup(3);
        let sq = smooth_scales(&x, &w, 0.0);
        let w2 = sq.apply_to_weight(&w);
        for j in 0..w2.rows() {
            let m = w2.row(j).iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            assert!((m - 1.0).abs() < 1e-3, "row {j}: {m}");
        }
    }

    #[test]
    fn identity_is_noop() {
        let (_, w) = setup(4);
        let sq = SmoothQuant::identity(w.rows());
        let w2 = sq.apply_to_weight(&w);
        assert_eq!(w, w2);
    }
}
