//! GPTQ (Frantar et al., 2023): second-order weight-only quantization.
//!
//! Column-serial quantization with error feedback through the inverse
//! Hessian of the layer's inputs, H = 2 X X^T + damping. Our weight layout
//! is `[K, N]` (in, out), so GPTQ walks the K rows: quantize row k for all N
//! output channels at once, then push the rounding error into rows > k via
//! the Cholesky factor of H^-1 — the standard "lazy batch" formulation with
//! batch = 1 row (K <= 1.5k here, so the quadratic cost is immaterial).

use crate::tensor::{cholesky, invert_spd, Tensor};

use super::{block_scale, QuantConfig, QuantizedWeight};

/// GPTQ hyperparameters.
#[derive(Clone, Debug)]
pub struct GptqConfig {
    /// Relative Hessian damping (fraction of mean diagonal). 0.01 standard.
    pub damp: f64,
    /// Process rows in descending diag(H) order ("act-order" heuristic).
    pub act_order: bool,
}

impl Default for GptqConfig {
    fn default() -> Self {
        GptqConfig { damp: 0.01, act_order: false }
    }
}

/// Quantize `w` `[K, N]` given calibration inputs `x` `[M, K]`.
///
/// Scales are still chosen per sub-channel block (from the *updated* weights
/// when each block is first reached, as in GPTQ group-size handling), so the
/// result is drop-in compatible with the RTN pipeline's artifact layout.
pub fn gptq_quantize(
    w: &Tensor,
    x: &Tensor,
    qcfg: &QuantConfig,
    gcfg: &GptqConfig,
) -> QuantizedWeight {
    let (k, n) = (w.rows(), w.cols());
    assert_eq!(x.cols(), k, "calibration inputs must be [M, K]");
    let block = qcfg.block.resolve(k);
    let nb = k / block;

    // H = 2 X^T X  (K x K), f64 for conditioning.
    let m = x.rows();
    let mut h = vec![0.0f64; k * k];
    for r in 0..m {
        let row = x.row(r);
        for i in 0..k {
            let xi = row[i] as f64;
            if xi == 0.0 {
                continue;
            }
            for j in i..k {
                h[i * k + j] += 2.0 * xi * row[j] as f64;
            }
        }
    }
    for i in 0..k {
        for j in 0..i {
            h[i * k + j] = h[j * k + i];
        }
    }

    // dead inputs (zero diag) get unit diag so the solve stays defined
    let mean_diag = (0..k).map(|i| h[i * k + i]).sum::<f64>() / k as f64;
    let mut damp = gcfg.damp * mean_diag.max(1e-12);
    for i in 0..k {
        if h[i * k + i] == 0.0 {
            h[i * k + i] = 1.0;
        }
    }

    // row order (act_order: descending diagonal = most-salient first)
    let mut order: Vec<usize> = (0..k).collect();
    if gcfg.act_order {
        order.sort_by(|&a, &b| {
            h[b * k + b].partial_cmp(&h[a * k + a]).unwrap()
        });
    }

    // Hinv via Cholesky of the damped H; retry with larger damping if the
    // calibration sample leaves H semi-definite.
    let hinv = loop {
        let mut hd = h.clone();
        for i in 0..k {
            hd[i * k + i] += damp;
        }
        if let Some(inv) = invert_spd(&hd, k) {
            break inv;
        }
        damp *= 10.0;
        assert!(damp.is_finite(), "GPTQ damping diverged");
    };
    // permute Hinv to the processing order, then take U = chol(Hinv_perm)^T
    let mut hp = vec![0.0f64; k * k];
    for (ii, &oi) in order.iter().enumerate() {
        for (jj, &oj) in order.iter().enumerate() {
            hp[ii * k + jj] = hinv[oi * k + oj];
        }
    }
    let l = cholesky(&hp, k).expect("Hinv must be SPD");
    // U[i][j] for j >= i is L^T upper triangle: U[i][j] = l[j*k+i]

    // working copy of W in processing order
    let mut wa = vec![0.0f32; k * n];
    for (ii, &oi) in order.iter().enumerate() {
        wa[ii * n..(ii + 1) * n].copy_from_slice(w.row(oi));
    }

    let mut codes = vec![0i8; k * n];
    let mut scales = Tensor::zeros(&[nb, n]);
    let cb: Vec<f32> = qcfg.format.codebook.iter().map(|&v| v as f32).collect();
    let enc = qcfg.format.encoder();

    // per-column scale state, refreshed at each block boundary (in the
    // *original* row index space so artifacts stay block-aligned)
    let mut cur_scales = vec![1.0f32; n];

    let mut colbuf = vec![0.0f32; block];
    for ii in 0..k {
        let oi = order[ii];
        let bi = oi / block;
        // refresh scales at the first visit of each block (original order
        // without act_order this is exactly the block boundary)
        if oi % block == 0 || gcfg.act_order {
            if !gcfg.act_order {
                // compute scales for the whole block from current weights
                for j in 0..n {
                    for r in 0..block {
                        // rows of this block in processing space == original
                        colbuf[r] = wa[(bi * block + r) * n + j];
                    }
                    let s = block_scale(&qcfg.format, &colbuf, qcfg.calib);
                    scales.set2(bi, j, s);
                }
            }
        }
        if gcfg.act_order {
            // act_order breaks block contiguity; use running per-block
            // absmax computed once up-front from the original weights.
            for j in 0..n {
                if scales.at2(bi, j) == 0.0 {
                    for r in 0..block {
                        colbuf[r] = w.at2(bi * block + r, j);
                    }
                    let s = block_scale(&qcfg.format, &colbuf, qcfg.calib);
                    scales.set2(bi, j, s);
                }
            }
        }
        for j in 0..n {
            cur_scales[j] = scales.at2(bi, j);
        }

        let d = l[ii * k + ii]; // U[ii][ii]
        for j in 0..n {
            let wv = wa[ii * n + j];
            let s = cur_scales[j];
            let idx = enc.encode(wv / s);
            codes[oi * n + j] = idx as i8;
            let qv = cb[idx] * s;
            let err = ((wv - qv) as f64 / d) as f32;
            // propagate into not-yet-quantized rows
            for jj in ii + 1..k {
                let u = l[jj * k + ii]; // U[ii][jj]
                wa[jj * n + j] -= (u as f32) * err;
            }
        }
    }

    QuantizedWeight { codes, scales, k, n, block }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats;
    use crate::quant::{quantize_weight, BlockSize, Calib};
    use crate::rng::Pcg64;

    fn setup(k: usize, n: usize, m: usize, seed: u64) -> (Tensor, Tensor) {
        let mut rng = Pcg64::new(seed);
        let w = Tensor::new(&[k, n], rng.student_t_vec(k * n, 5.0, 0.02));
        let x = Tensor::new(&[m, k], rng.normal_vec(m * k, 1.0));
        (w, x)
    }

    fn task_error(w: &Tensor, q: &QuantizedWeight, x: &Tensor, spec: &formats::FormatSpec) -> f64 {
        // || X W - X Q ||^2 — the objective GPTQ actually minimizes
        let deq = q.dequant(spec);
        x.matmul(w).sq_err(&x.matmul(&deq))
    }

    #[test]
    fn gptq_beats_rtn_on_task_error() {
        let spec = formats::must("int4");
        let (w, x) = setup(64, 16, 256, 1);
        let qcfg = QuantConfig {
            format: spec.clone(),
            block: BlockSize::Sub(64),
            calib: Calib::None,
        };
        let rtn = quantize_weight(&w, &qcfg);
        let gq = gptq_quantize(&w, &x, &qcfg, &GptqConfig::default());
        let e_rtn = task_error(&w, &rtn, &x, &spec);
        let e_gptq = task_error(&w, &gq, &x, &spec);
        assert!(
            e_gptq < e_rtn,
            "gptq {e_gptq} should beat rtn {e_rtn}"
        );
    }

    #[test]
    fn gptq_block_scales_stay_aligned() {
        let spec = formats::must("sf4");
        let (w, x) = setup(128, 8, 128, 2);
        let qcfg = QuantConfig {
            format: spec,
            block: BlockSize::Sub(32),
            calib: Calib::None,
        };
        let q = gptq_quantize(&w, &x, &qcfg, &GptqConfig::default());
        assert_eq!(q.scales.shape(), &[4, 8]);
        assert_eq!(q.block, 32);
        // codes must index within the codebook
        assert!(q.codes.iter().all(|&c| (c as usize) < 16));
    }

    #[test]
    fn gptq_with_act_order_runs() {
        let spec = formats::must("e2m1");
        let (w, x) = setup(64, 8, 64, 3);
        let qcfg = QuantConfig {
            format: spec.clone(),
            block: BlockSize::Sub(64),
            calib: Calib::None,
        };
        let g = GptqConfig { damp: 0.01, act_order: true };
        let q = gptq_quantize(&w, &x, &qcfg, &g);
        // still a sane reconstruction
        let rel = w.sq_err(&q.dequant(&spec)) / w.data().iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        assert!(rel < 0.2, "{rel}");
    }

    #[test]
    fn gptq_handles_degenerate_calibration() {
        // rank-deficient X (single repeated row) must not crash
        let spec = formats::must("int4");
        let (w, _) = setup(32, 4, 8, 4);
        let mut rng = Pcg64::new(9);
        let row = rng.normal_vec(32, 1.0);
        let mut xd = Vec::new();
        for _ in 0..8 {
            xd.extend_from_slice(&row);
        }
        let x = Tensor::new(&[8, 32], xd);
        let qcfg = QuantConfig {
            format: spec,
            block: BlockSize::Sub(32),
            calib: Calib::None,
        };
        let q = gptq_quantize(&w, &x, &qcfg, &GptqConfig::default());
        assert_eq!(q.codes.len(), 32 * 4);
    }
}
