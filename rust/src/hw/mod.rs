//! Hardware cost model: MAC-unit area and power per datatype (paper §5).
//!
//! The paper synthesizes SystemVerilog MAC units with Synopsys DC on TSMC
//! 28nm. We replace that flow with a **unit-gate structural model**
//! (substitution documented in DESIGN.md §2): each MAC = multiplier +
//! accumulator sized for *lossless* accumulation of a 256-term dot product,
//! exactly the paper's assumption. Gate counts are converted to µm² with
//! constants calibrated on the paper's INT4/INT5 rows; every other format's
//! area is then *predicted* by structure, so the Pareto ordering
//! (INT4 < E2M1 < +SR < +SP ≈ E3M0 < E2M1-I < E2M1-B) is a model output,
//! not an input.

use crate::formats::{self, Family, FormatSpec};

/// Dot-product length the accumulator must absorb losslessly (paper: 256).
pub const ACCUM_TERMS: u32 = 256;

// Calibrated area constants (µm², TSMC-28-ish from Table 10's INT rows):
// int multiplier = A_MULT_SQ * k^2 + A_MULT_LIN * k  (fits 75.3@4, 106.6@5)
const A_MULT_SQ: f64 = 2.5;
const A_MULT_LIN: f64 = 8.825;
/// accumulator (adder+register) per bit — fits 85.4 µm² @ 16 bits.
const A_ACCUM_BIT: f64 = 5.34;
/// exponent adder + bias handling, per exponent bit.
const A_EXP_BIT: f64 = 7.0;
/// product-aligning barrel shifter: per (shift stage x accum/4 bits).
const A_SHIFT: f64 = 1.05;
/// APoT shift-add: per stage cost of the two shifters.
const A_APOT_STAGE: f64 = 0.94;
/// power scales with area at a fixed activity factor (fits 48.5 µW @ INT4).
const P_PER_AREA: f64 = 0.302;

/// Bit-structure analysis of one format's MAC datapath.
#[derive(Clone, Debug)]
pub struct MacAnalysis {
    pub format: String,
    /// accumulator width for lossless 256-term accumulation
    pub accum_bits: u32,
    /// integer bits of the largest |product|
    pub prod_int_bits: u32,
    /// fractional bits of the product grid
    pub prod_frac_bits: u32,
    pub mult_area: f64,
    pub accum_area: f64,
    pub power: f64,
}

impl MacAnalysis {
    pub fn mac_area(&self) -> f64 {
        self.mult_area + self.accum_area
    }
}

/// Raw (unnormalized) codebook values — Table 15's raw grids.
fn raw_codebook(spec: &FormatSpec) -> Vec<f64> {
    let mx = spec.raw_max();
    spec.codebook.iter().map(|v| v * mx).collect()
}

/// Fractional bits needed to represent `x` on a dyadic grid (capped).
fn frac_bits(x: f64) -> u32 {
    let mut f = 0u32;
    let mut v = x.abs();
    while f < 16 && (v - v.round()).abs() > 1e-9 {
        v *= 2.0;
        f += 1;
    }
    f
}

fn int_bits(x: f64) -> u32 {
    let mut b = 0u32;
    while (1u64 << b) as f64 <= x.abs() && b < 40 {
        b += 1;
    }
    b
}

/// Product-grid analysis: all pairwise |a*b| over the raw codebook,
/// excluding subnormal x subnormal (hardware flushes those — they sit below
/// the accumulation grid, the standard cheap-MAC choice).
fn product_grid(spec: &FormatSpec) -> (u32, u32) {
    let raw = raw_codebook(spec);
    // subnormals: magnitudes below the format's smallest normal value;
    // other families have none (cut = 0 disables flushing).
    let subnormal_cut = spec.min_normal() - 1e-9;
    let mut max_prod = 0.0f64;
    let mut max_frac = 0u32;
    for &a in &raw {
        for &b in &raw {
            let p = (a * b).abs();
            if p == 0.0 {
                continue;
            }
            let a_sub = a.abs() < subnormal_cut;
            let b_sub = b.abs() < subnormal_cut;
            if a_sub && b_sub {
                continue; // flushed
            }
            max_prod = max_prod.max(p);
            max_frac = max_frac.max(frac_bits(p));
        }
    }
    (int_bits(max_prod), max_frac)
}

/// Accumulator width: sign + product int bits + product frac bits +
/// log2(terms) guard bits (lossless fixed-point accumulation).
pub fn accum_bits(spec: &FormatSpec) -> u32 {
    let (pi, pf) = product_grid(spec);
    let guard = (ACCUM_TERMS as f64).log2().ceil() as u32;
    let supernormal_penalty = match spec.name {
        // SP widens the mantissa datapath by one bit; the product grid
        // gains up to two fractional bits of range in hardware.
        n if n.ends_with("_sp") && spec.family == Family::Float => 2,
        _ => 0,
    };
    1 + pi + pf + guard + supernormal_penalty
}

fn int_mult_area(bits: f64) -> f64 {
    A_MULT_SQ * bits * bits + A_MULT_LIN * bits
}

/// Full MAC analysis for one format. Lookup formats (NF/SF) have no
/// hardened MAC (they need fp16-class lookup pipelines) and return None —
/// the paper likewise omits them from Table 10.
pub fn analyze(spec: &FormatSpec) -> Option<MacAnalysis> {
    if spec.family == Family::Lookup {
        return None;
    }
    let ab = accum_bits(spec);
    let (pi, pf) = product_grid(spec);
    let mult_area = match spec.family {
        Family::Int => int_mult_area(spec.bits as f64),
        Family::Float => {
            let (e, m) = spec.fp_split.unwrap();
            let m_eff =
                m + if spec.supernormal > 0 && spec.name.ends_with("_sp") { 1 } else { 0 };
            // mantissa multiplier (hidden bit included) + exponent adder +
            // shifter aligning the product into the accumulation grid.
            let mant = int_mult_area((m_eff + 1) as f64);
            let exp = A_EXP_BIT * (e + 1) as f64;
            let shift_stages = (pi + pf) as f64;
            let subnormal_mux = if has_deep_subnormal(spec) { 18.0 } else { 6.0 };
            mant + exp + A_SHIFT * shift_stages * ab as f64 / 4.0 + subnormal_mux
        }
        Family::Apot => {
            // two power-of-two shifters + a merge adder over the grid;
            // a supernormal code extends the decoder slightly.
            let stages = (pi + pf) as f64;
            2.0 * A_APOT_STAGE * stages * 4.0 + 9.0 * 4.0
                + 3.5 * spec.supernormal as f64
        }
        Family::Lookup => unreachable!(),
    };
    let accum_area = A_ACCUM_BIT * ab as f64;
    let power = P_PER_AREA * (mult_area + accum_area);
    Some(MacAnalysis {
        format: spec.name.to_string(),
        accum_bits: ab,
        prod_int_bits: pi,
        prod_frac_bits: pf,
        mult_area,
        accum_area,
        power,
    })
}

/// Formats whose subnormal sits far below the normal range (Intel/bnb
/// variants): they need deeper normalization muxing.
fn has_deep_subnormal(spec: &FormatSpec) -> bool {
    matches!(spec.name, "e2m1_i" | "e2m1_b")
}

/// Relative whole-chip overhead vs INT4 (paper Table 10, last column):
/// MAC units ~10% of chip area, memory ~60%, memory scales with bitwidth.
pub fn system_overhead(mac_area: f64, bits: u32, int4_mac_area: f64) -> f64 {
    0.10 * (mac_area / int4_mac_area - 1.0) + 0.60 * (bits as f64 / 4.0 - 1.0)
}

/// One row of the regenerated Table 10.
#[derive(Clone, Debug)]
pub struct Table10Row {
    pub format: String,
    pub accum_bits: u32,
    pub mult_area: f64,
    pub accum_area: f64,
    pub mac_area: f64,
    pub power: f64,
    pub overhead_pct: f64,
}

/// The formats of the paper's Table 10, in row order.
pub const TABLE10_FORMATS: [&str; 10] = [
    "int4", "int5", "e2m1_i", "e2m1_b", "e2m1", "e2m1_sr", "e2m1_sp", "e3m0",
    "apot4", "apot4_sp",
];

/// Regenerate Table 10 from the structural model.
pub fn table10() -> Vec<Table10Row> {
    let int4 = analyze(&formats::must("int4")).unwrap();
    TABLE10_FORMATS
        .iter()
        .map(|name| {
            let spec = formats::must(name);
            let a = analyze(&spec).unwrap();
            Table10Row {
                format: name.to_string(),
                accum_bits: a.accum_bits,
                mult_area: a.mult_area,
                accum_area: a.accum_area,
                mac_area: a.mac_area(),
                power: a.power,
                overhead_pct: 100.0
                    * system_overhead(a.mac_area(), spec.bits, int4.mac_area()),
            }
        })
        .collect()
}

/// System overhead (%) for one format by name — the Pareto x-axis. Lookup
/// formats have no hardened MAC and return None (as in the paper).
pub fn overhead_pct(name: &str) -> Option<f64> {
    let int4 = analyze(&formats::must("int4")).unwrap();
    let spec = formats::must(name);
    analyze(&spec)
        .map(|a| 100.0 * system_overhead(a.mac_area(), spec.bits, int4.mac_area()))
}

/// MAC area for one format by name.
pub fn mac_area(name: &str) -> Option<f64> {
    analyze(&formats::must(name)).map(|a| a.mac_area())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(name: &str) -> Table10Row {
        table10().into_iter().find(|r| r.format == name).unwrap()
    }

    #[test]
    fn accum_bits_match_paper_for_anchor_formats() {
        for (name, want) in [
            ("int4", 16),
            ("int5", 18),
            ("e2m1", 17),
            ("e2m1_sr", 18),
            ("e2m1_sp", 19),
            ("e2m1_i", 20),
            ("e3m0", 22),
        ] {
            let got = accum_bits(&formats::must(name));
            assert_eq!(got, want, "{name}: accum bits {got} != paper {want}");
        }
    }

    #[test]
    fn calibration_anchors_match_paper() {
        let int4 = row("int4");
        assert!((int4.mult_area - 75.3).abs() < 1.0, "{}", int4.mult_area);
        assert!((int4.accum_area - 85.4).abs() < 1.0, "{}", int4.accum_area);
        assert!((int4.power - 48.5).abs() < 2.0, "{}", int4.power);
        let int5 = row("int5");
        assert!((int5.mult_area - 106.6).abs() < 1.5, "{}", int5.mult_area);
    }

    #[test]
    fn pareto_area_ordering_matches_paper() {
        let a = |n: &str| row(n).mac_area;
        assert!(a("int4") < a("e2m1"), "int4 must be cheapest");
        assert!(a("e2m1") < a("e2m1_sr"));
        assert!(a("e2m1_sr") < a("e2m1_sp"));
        assert!(a("e2m1") < a("e2m1_i"));
        assert!(a("e2m1_i") < a("e2m1_b"));
        assert!(a("int4") < a("apot4"));
        assert!(a("apot4") < a("apot4_sp"));
    }

    #[test]
    fn system_overhead_formula_matches_paper_rows() {
        // verified against the paper's own MAC areas
        let ov_int5 = system_overhead(203.6, 5, 160.7);
        assert!((ov_int5 * 100.0 - 17.7).abs() < 0.2, "{ov_int5}");
        let ov_e2m1i = system_overhead(228.2, 4, 160.7);
        assert!((ov_e2m1i * 100.0 - 4.2).abs() < 0.2, "{ov_e2m1i}");
        let ov_e2m1 = system_overhead(170.4, 4, 160.7);
        assert!((ov_e2m1 * 100.0 - 0.6).abs() < 0.2, "{ov_e2m1}");
    }

    #[test]
    fn model_areas_within_tolerance_of_paper() {
        // calibrated on INT rows; everything else is structural prediction.
        let paper = [
            ("int4", 160.7),
            ("int5", 203.6),
            ("e2m1", 170.4),
            ("e2m1_sr", 191.3),
            ("e2m1_sp", 218.0),
            ("e3m0", 217.7),
            ("e2m1_i", 228.2),
            ("e2m1_b", 268.9),
            ("apot4", 181.6),
            ("apot4_sp", 185.1),
        ];
        for (name, want) in paper {
            let got = row(name).mac_area;
            let rel = (got - want).abs() / want;
            assert!(rel < 0.25, "{name}: model {got:.1} vs paper {want:.1} ({rel:.2})");
        }
    }

    #[test]
    fn lookup_formats_have_no_mac() {
        assert!(analyze(&formats::must("sf4")).is_none());
        assert!(analyze(&formats::must("nf4")).is_none());
        assert!(mac_area("int4").is_some());
    }

    #[test]
    fn supernormal_costs_are_small_at_system_level() {
        // the paper's headline: SP adds ~3.6% chip overhead, SR ~1.9%
        let sp = row("e2m1_sp").overhead_pct;
        let sr = row("e2m1_sr").overhead_pct;
        assert!(sp > 0.0 && sp < 8.0, "{sp}");
        assert!(sr > 0.0 && sr < sp, "{sr} vs {sp}");
    }
}
