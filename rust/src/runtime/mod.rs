//! PJRT runtime: load HLO-text artifacts, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin). Artifacts come from
//! `python/compile/aot.py` as HLO *text* + a `.params.txt` manifest; this
//! module parses the manifest, marshals typed inputs in manifest order and
//! unpacks the tuple outputs. Weights can be pinned as device buffers
//! (`BoundInputs`) so the serve/eval hot loop only uploads the small
//! per-request tensors.
//!
//! The [`pool`] submodule is unrelated to PJRT: it is the crate's persistent
//! CPU worker pool (shared by the GEMM, LUT-GEMM and fused-attention
//! kernels) and the home of the cached [`pool::parallelism`] helper.

pub mod pool;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;

/// Global serialization of PJRT calls — see the SAFETY note on [`Engine`].
static PJRT_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn pjrt_lock() -> std::sync::MutexGuard<'static, ()> {
    PJRT_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Artifact input/output element type.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dt {
    F32,
    I32,
    I8,
}

impl Dt {
    fn parse(s: &str) -> Result<Dt> {
        Ok(match s {
            "f32" => Dt::F32,
            "i32" => Dt::I32,
            "i8" => Dt::I8,
            _ => bail!("unknown dtype {s}"),
        })
    }
}

/// One input/output descriptor from the manifest.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub dtype: Dt,
    pub dims: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Parsed `<artifact>.params.txt`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub inputs: Vec<ParamSpec>,
    pub outputs: Vec<ParamSpec>,
    /// input name -> position
    pub index: HashMap<String, usize>,
}

fn parse_manifest(name: &str, text: &str) -> Result<ArtifactMeta> {
    let mut inputs = Vec::new();
    let mut outputs = Vec::new();
    let mut in_outputs = false;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "-- outputs --" {
            in_outputs = true;
            continue;
        }
        let mut parts = line.split_whitespace();
        let pname = parts.next().ok_or_else(|| anyhow!("bad manifest line: {line}"))?;
        let dtype = Dt::parse(parts.next().ok_or_else(|| anyhow!("missing dtype: {line}"))?)?;
        let dims: Vec<usize> = match parts.next() {
            Some(d) => d
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.parse())
                .collect::<Result<_, _>>()?,
            None => vec![], // scalar
        };
        let spec = ParamSpec { name: pname.to_string(), dtype, dims };
        if in_outputs {
            outputs.push(spec);
        } else {
            inputs.push(spec);
        }
    }
    let index = inputs.iter().enumerate().map(|(i, s)| (s.name.clone(), i)).collect();
    Ok(ArtifactMeta { name: name.to_string(), inputs, outputs, index })
}

// ---------------------------------------------------------------------------
// Typed host values
// ---------------------------------------------------------------------------

/// A typed host-side value destined for (or read from) the device.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(Vec<i32>, Vec<usize>),
    I8(Vec<i8>, Vec<usize>),
}

impl Value {
    pub fn dims(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(_, d) | Value::I8(_, d) => d,
        }
    }

    pub fn dtype(&self) -> Dt {
        match self {
            Value::F32(_) => Dt::F32,
            Value::I32(..) => Dt::I32,
            Value::I8(..) => Dt::I8,
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("value is not f32"),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let t = self.as_f32()?;
        if t.len() != 1 {
            bail!("expected scalar, got {:?}", t.shape());
        }
        Ok(t.data()[0])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let (ty, dims, bytes): (xla::ElementType, &[usize], &[u8]) = match self {
            Value::F32(t) => (
                xla::ElementType::F32,
                t.shape(),
                unsafe {
                    std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.len() * 4)
                },
            ),
            Value::I32(v, d) => (
                xla::ElementType::S32,
                d,
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) },
            ),
            Value::I8(v, d) => (
                xla::ElementType::S8,
                d,
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len()) },
            ),
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(ty, dims, bytes)?)
    }

    fn from_literal(lit: &xla::Literal, spec: &ParamSpec) -> Result<Value> {
        Ok(match spec.dtype {
            Dt::F32 => {
                let v = lit.to_vec::<f32>()?;
                Value::F32(Tensor::new(&spec.dims, v))
            }
            Dt::I32 => Value::I32(lit.to_vec::<i32>()?, spec.dims.clone()),
            Dt::I8 => Value::I8(lit.to_vec::<i8>()?, spec.dims.clone()),
        })
    }
}

// ---------------------------------------------------------------------------
// Engine + executables
// ---------------------------------------------------------------------------

/// Process-wide PJRT client handle. Clone freely (Arc inside).
#[derive(Clone)]
pub struct Engine {
    client: Arc<xla::PjRtClient>,
    artifacts_dir: PathBuf,
}

// SAFETY: every PJRT call in this module is serialized behind [`pjrt_lock`]
// (this xla_extension build is not safe under concurrent client use — it
// SIGSEGVs), so cross-thread access only ever observes the wrappers' raw
// pointers while holding the lock. XLA's CPU backend parallelizes inside a
// single execute call via its own Eigen thread pool, so serializing calls
// costs little.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}
unsafe impl Send for BoundInputs {}
unsafe impl Sync for BoundInputs {}

impl Engine {
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let _g = pjrt_lock();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client: Arc::new(client),
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    pub fn artifact_exists(&self, name: &str) -> bool {
        self.artifacts_dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Load + compile one artifact by name (e.g. `lm_fwd_small`).
    pub fn load(&self, name: &str) -> Result<Executable> {
        let hlo_path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let manifest_path = self.artifacts_dir.join(format!("{name}.params.txt"));
        let manifest = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let meta = parse_manifest(name, &manifest)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let _g = pjrt_lock();
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        Ok(Executable { exe, meta, client: self.client.clone() })
    }
}

/// A compiled artifact, ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
    client: Arc<xla::PjRtClient>,
}

impl Executable {
    /// Execute with host values in manifest order; returns outputs in
    /// manifest order.
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        self.check_inputs(inputs)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        let _g = pjrt_lock();
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let mut tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.decompose_tuple()?;
        self.unpack(outs)
    }

    /// Execute with inputs given by name (order-free convenience).
    pub fn run_named(&self, named: &HashMap<String, Value>) -> Result<Vec<Value>> {
        let mut inputs = Vec::with_capacity(self.meta.inputs.len());
        for spec in &self.meta.inputs {
            let v = named
                .get(&spec.name)
                .ok_or_else(|| anyhow!("{}: missing input `{}`", self.meta.name, spec.name))?;
            inputs.push(v.clone());
        }
        self.run(&inputs)
    }

    /// Pre-upload a fixed set of inputs (weights) as device buffers.
    ///
    /// PJRT's BufferFromHostLiteral is asynchronous: the transfer may still
    /// be reading the literal's host memory when the call returns, so every
    /// literal is kept alive alongside its buffer for the bind's lifetime.
    pub fn bind(&self, fixed: &HashMap<String, Value>) -> Result<BoundInputs> {
        let _g = pjrt_lock();
        let mut buffers: Vec<Option<xla::PjRtBuffer>> = Vec::new();
        let mut literals: Vec<xla::Literal> = Vec::new();
        let mut missing = Vec::new();
        for spec in &self.meta.inputs {
            match fixed.get(&spec.name) {
                Some(v) => {
                    check_one(&self.meta.name, spec, v)?;
                    let lit = v.to_literal()?;
                    let buf = self.client.buffer_from_host_literal(None, &lit)?;
                    literals.push(lit);
                    buffers.push(Some(buf));
                }
                None => {
                    buffers.push(None);
                    missing.push(spec.name.clone());
                }
            }
        }
        Ok(BoundInputs { buffers, _literals: literals, missing })
    }

    /// Execute with pre-bound buffers plus the remaining (per-request)
    /// values by name.
    pub fn run_bound(
        &self,
        bound: &BoundInputs,
        rest: &HashMap<String, Value>,
    ) -> Result<Vec<Value>> {
        let _g = pjrt_lock();
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        // keep per-request literals alive until the execution has synced
        // (async host->device transfer, see `bind`)
        let mut owned_lits: Vec<xla::Literal> = Vec::new();
        for (i, spec) in self.meta.inputs.iter().enumerate() {
            if bound.buffers[i].is_none() {
                let v = rest.get(&spec.name).ok_or_else(|| {
                    anyhow!("missing per-request input `{}` for {}", spec.name, self.meta.name)
                })?;
                check_one(&self.meta.name, spec, v)?;
                let lit = v.to_literal()?;
                owned.push(self.client.buffer_from_host_literal(None, &lit)?);
                owned_lits.push(lit);
            }
        }
        let mut owned_iter = owned.iter();
        let mut bufs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.meta.inputs.len());
        for b in &bound.buffers {
            match b {
                Some(buf) => bufs.push(buf),
                None => bufs.push(owned_iter.next().expect("owned buffer count")),
            }
        }
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(&bufs)?;
        let mut tuple = result[0][0].to_literal_sync()?;
        drop(owned_lits); // transfers definitely consumed after the sync
        let outs = tuple.decompose_tuple()?;
        self.unpack(outs)
    }

    fn unpack(&self, outs: Vec<xla::Literal>) -> Result<Vec<Value>> {
        if outs.len() != self.meta.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.meta.name,
                self.meta.outputs.len(),
                outs.len()
            );
        }
        outs.iter()
            .zip(&self.meta.outputs)
            .map(|(lit, spec)| Value::from_literal(lit, spec))
            .collect()
    }

    fn check_inputs(&self, inputs: &[Value]) -> Result<()> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (v, spec) in inputs.iter().zip(&self.meta.inputs) {
            check_one(&self.meta.name, spec, v)?;
        }
        Ok(())
    }
}

fn check_one(art: &str, spec: &ParamSpec, v: &Value) -> Result<()> {
    if v.dtype() != spec.dtype || v.dims() != spec.dims.as_slice() {
        bail!(
            "{art}: input `{}` expected {:?}{:?}, got {:?}{:?}",
            spec.name,
            spec.dtype,
            spec.dims,
            v.dtype(),
            v.dims()
        );
    }
    Ok(())
}

/// Device-resident fixed inputs (weights) for a specific executable.
pub struct BoundInputs {
    buffers: Vec<Option<xla::PjRtBuffer>>,
    /// Host literals backing the buffers (async transfer — see `bind`).
    _literals: Vec<xla::Literal>,
    /// Names that must be supplied per call.
    pub missing: Vec<String>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_scalars_and_tensors() {
        let text = "step f32\ntokens i32 8,33\nw f32 16,16\n-- outputs --\nloss f32\n";
        let m = parse_manifest("t", text).unwrap();
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.inputs[0].dims.len(), 0);
        assert_eq!(m.inputs[1].dims, vec![8, 33]);
        assert_eq!(m.inputs[1].dtype, Dt::I32);
        assert_eq!(m.outputs.len(), 1);
        assert_eq!(m.index["w"], 2);
    }

    #[test]
    fn manifest_rejects_bad_dtype() {
        assert!(parse_manifest("t", "x f16 2,2\n-- outputs --\n").is_err());
    }

    #[test]
    fn value_shapes() {
        let v = Value::F32(Tensor::zeros(&[2, 3]));
        assert_eq!(v.dims(), &[2, 3]);
        assert_eq!(v.dtype(), Dt::F32);
        let v = Value::I8(vec![0; 6], vec![6]);
        assert_eq!(v.dtype(), Dt::I8);
        assert!(v.as_f32().is_err());
    }
}
