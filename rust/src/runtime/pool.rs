//! Persistent worker pool — the crate's one source of CPU-bound task
//! parallelism on the serving hot path.
//!
//! The blocked GEMM (`tensor::gemm_threaded`), the fused packed-weight
//! `quant::lut_gemm` (which rides it) and the fused dequant-attention
//! kernels (`tensor::lut_attend`) all dispatch row/head chunks here instead
//! of spawning scoped threads per call. A mid-sized prefill issues six GEMMs
//! per layer per step; at ~10–20 µs per `std::thread::spawn`+join round trip
//! the old per-call `thread::scope` tax was pure overhead that the pool
//! amortizes to one condvar wake per chunk.
//!
//! Design:
//!
//! * **Lazy global.** [`global`] spawns `parallelism() - 1` workers on first
//!   use and leaks them for the process lifetime (they idle on a condvar).
//!   Single-core hosts get zero workers and every dispatch runs inline.
//! * **Scoped dispatch over borrowed closures.** [`WorkerPool::scoped`]
//!   takes non-`'static` tasks: it enqueues them (lifetime-erased), then the
//!   *dispatching thread drains the queue too* and finally blocks on a
//!   count-down latch until every task has finished — so the borrows can
//!   never escape the call. This is the same contract `std::thread::scope`
//!   gives, minus the spawn/join cost.
//! * **Panic containment.** A panicking task poisons its latch (the
//!   dispatcher re-panics after all tasks settle) but never kills a worker.
//! * **Determinism.** The pool only decides *where* a task runs, never what
//!   it computes; callers (the GEMM row chunks, attention heads) partition
//!   work into tasks whose arithmetic is independent of placement, so pool
//!   size cannot change any result bit.
//!
//! [`parallelism`] is also the crate-wide cached `available_parallelism`
//! helper (the std call re-reads cgroup state on Linux on every invocation,
//! too slow for a per-GEMM decision) — `tensor` and `coordinator::runner`
//! both use it instead of private copies.

use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

use crate::obs::{clock, trace};

/// Cached `std::thread::available_parallelism` (>= 1).
pub fn parallelism() -> usize {
    static CORES: OnceLock<usize> = OnceLock::new();
    *CORES
        .get_or_init(|| std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1))
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Count-down latch with a poison flag for panicked tasks.
struct Latch {
    state: Mutex<(usize, bool)>,
    done: Condvar,
}

impl Latch {
    fn new(count: usize) -> Arc<Latch> {
        Arc::new(Latch { state: Mutex::new((count, false)), done: Condvar::new() })
    }

    fn count_down(&self, panicked: bool) {
        let mut s = lock(&self.state);
        s.0 -= 1;
        s.1 |= panicked;
        if s.0 == 0 {
            self.done.notify_all();
        }
    }

    /// Block until every task settled; returns true if any panicked.
    fn wait(&self) -> bool {
        let mut s = lock(&self.state);
        while s.0 > 0 {
            s = self.done.wait(s).unwrap_or_else(|e| e.into_inner());
        }
        s.1
    }
}

struct Task {
    job: Job,
    latch: Arc<Latch>,
    /// µs-since-epoch enqueue time, 0 when tracing was off at dispatch —
    /// lets the per-task span split queue wait from execution.
    enqueued_us: u64,
}

impl Task {
    fn run(self) {
        let Task { job, latch, enqueued_us } = self;
        let t0 = trace::start();
        let panicked = std::panic::catch_unwind(AssertUnwindSafe(|| {
            // the pool_worker_panic injection site: one relaxed atomic load
            // when disarmed; armed, the task dies before its job runs and
            // the latch's poison flag carries the panic to the dispatcher
            if crate::faults::fire(crate::faults::Site::PoolWorkerPanic) {
                panic!("{} pool worker panic", crate::faults::PANIC_MARK);
            }
            job()
        }))
        .is_err();
        if let Some(t0) = t0 {
            let wait = if enqueued_us > 0 { t0.saturating_sub(enqueued_us) } else { 0 };
            trace::complete_here("pool", "pool.task", t0, &[("queue_wait_us", wait as f64)]);
        }
        latch.count_down(panicked);
    }
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct Inner {
    queue: Mutex<VecDeque<Task>>,
    available: Condvar,
    dispatches: AtomicU64,
    pool_tasks: AtomicU64,
    caller_tasks: AtomicU64,
}

/// The pool handle. Obtain via [`global`]; sized once at first use.
pub struct WorkerPool {
    inner: Arc<Inner>,
    workers: usize,
}

/// The process-wide pool, spawned lazily with `parallelism() - 1` workers
/// (the dispatching thread is the final lane, so a full dispatch engages
/// exactly `parallelism()` threads).
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::start(parallelism().saturating_sub(1)))
}

impl WorkerPool {
    fn start(workers: usize) -> WorkerPool {
        let inner = Arc::new(Inner {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            dispatches: AtomicU64::new(0),
            pool_tasks: AtomicU64::new(0),
            caller_tasks: AtomicU64::new(0),
        });
        for i in 0..workers {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name(format!("llmdt-pool-{i}"))
                .spawn(move || worker_loop(inner))
                .expect("spawning pool worker");
        }
        WorkerPool { inner, workers }
    }

    /// Worker threads parked on the queue (0 on single-core hosts).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `tasks` to completion, using the pool workers plus the calling
    /// thread. Blocks until every task has finished — tasks may therefore
    /// borrow from the caller's stack ('s), exactly like `thread::scope`
    /// spawns. Panics (after all tasks settle) if any task panicked.
    ///
    /// Tasks must not block on work that only the current queue can make
    /// progress on *without draining it* — the GEMM/attention chunks are
    /// plain compute, and nested `scoped` calls are safe because every
    /// dispatcher drains the shared queue before waiting.
    pub fn scoped<'s>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 's>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        if n == 1 || self.workers == 0 {
            self.inner.caller_tasks.fetch_add(n as u64, Ordering::Relaxed);
            for t in tasks {
                t();
            }
            return;
        }
        self.inner.dispatches.fetch_add(1, Ordering::Relaxed);
        let _dispatch_span =
            trace::span("pool", "pool.dispatch").arg("tasks", n as f64);
        let enqueued_us = if trace::enabled() { clock::now_micros() } else { 0 };
        let latch = Latch::new(n);
        {
            let mut q = lock(&self.inner.queue);
            for t in tasks {
                // SAFETY: `scoped` does not return until `latch.wait()` has
                // observed every task settled, so the 's borrows inside the
                // job strictly outlive its execution even though the queue
                // stores it lifetime-erased.
                let job: Job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 's>, Job>(t)
                };
                q.push_back(Task { job, latch: latch.clone(), enqueued_us });
            }
        }
        self.inner.available.notify_all();
        // the dispatching thread is a worker too: drain the queue rather
        // than idle-wait (it may run other dispatchers' tasks — fine, their
        // latches account for them)
        loop {
            let task = lock(&self.inner.queue).pop_front();
            match task {
                Some(t) => {
                    self.inner.caller_tasks.fetch_add(1, Ordering::Relaxed);
                    t.run();
                }
                None => break,
            }
        }
        if latch.wait() {
            panic!("worker pool task panicked");
        }
    }

    /// Monotonic counters snapshot (for utilization accounting).
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            workers: self.workers,
            dispatches: self.inner.dispatches.load(Ordering::Relaxed),
            pool_tasks: self.inner.pool_tasks.load(Ordering::Relaxed),
            caller_tasks: self.inner.caller_tasks.load(Ordering::Relaxed),
        }
    }
}

fn worker_loop(inner: Arc<Inner>) {
    loop {
        let task = {
            let mut q = lock(&inner.queue);
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = inner.available.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        inner.pool_tasks.fetch_add(1, Ordering::Relaxed);
        task.run();
    }
}

/// Monotonic pool counters; subtract two snapshots for a per-run view.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Pool worker threads (excludes dispatching callers).
    pub workers: usize,
    /// Multi-task `scoped` dispatches (single-task and zero-worker calls run
    /// inline and are not counted).
    pub dispatches: u64,
    /// Tasks executed on pool workers.
    pub pool_tasks: u64,
    /// Tasks executed inline on dispatching threads.
    pub caller_tasks: u64,
}

impl PoolStats {
    /// Counter deltas against an earlier snapshot.
    pub fn since(&self, earlier: &PoolStats) -> PoolStats {
        PoolStats {
            workers: self.workers,
            dispatches: self.dispatches - earlier.dispatches,
            pool_tasks: self.pool_tasks - earlier.pool_tasks,
            caller_tasks: self.caller_tasks - earlier.caller_tasks,
        }
    }

    /// Mean fraction of pool workers engaged per dispatch, in [0, 1]
    /// (tasks that ran on workers over worker-slots offered). 0 when the
    /// pool never dispatched or has no workers.
    pub fn utilization(&self) -> f64 {
        if self.dispatches == 0 || self.workers == 0 {
            return 0.0;
        }
        let offered = self.dispatches * self.workers as u64;
        (self.pool_tasks as f64 / offered as f64).min(1.0)
    }
}

/// [`PoolStats`] for the global pool (spawns it on first call).
pub fn stats() -> PoolStats {
    global().stats()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn parallelism_is_cached_and_positive() {
        let a = parallelism();
        assert!(a >= 1);
        assert_eq!(a, parallelism());
    }

    #[test]
    fn scoped_runs_every_task_with_borrows() {
        let mut out = vec![0usize; 16];
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .chunks_mut(4)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = i * 10 + j;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        global().scoped(tasks);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i / 4) * 10 + i % 4);
        }
    }

    #[test]
    fn scoped_handles_empty_and_single() {
        global().scoped(Vec::new());
        let hit = AtomicUsize::new(0);
        global().scoped(vec![Box::new(|| {
            hit.fetch_add(1, Ordering::Relaxed);
        }) as Box<dyn FnOnce() + Send + '_>]);
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn nested_scoped_dispatch_makes_progress() {
        // a task that itself dispatches: dispatchers drain the shared queue,
        // so nesting cannot deadlock even with a tiny pool
        let total = AtomicUsize::new(0);
        let outer: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                Box::new(|| {
                    let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                        .map(|_| {
                            Box::new(|| {
                                total.fetch_add(1, Ordering::Relaxed);
                            })
                                as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    global().scoped(inner);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        global().scoped(outer);
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn panicking_task_propagates_without_killing_workers() {
        let boom = std::panic::catch_unwind(|| {
            global().scoped(vec![
                Box::new(|| panic!("task boom")) as Box<dyn FnOnce() + Send + '_>,
                Box::new(|| ()) as Box<dyn FnOnce() + Send + '_>,
            ]);
        });
        assert!(boom.is_err(), "dispatcher must re-panic");
        // the pool still works afterwards
        let hit = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|_| {
                Box::new(|| {
                    hit.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        global().scoped(tasks);
        assert_eq!(hit.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn repeated_panic_rounds_never_poison_the_pool() {
        // the containment contract, exercised repeatedly: a panicking task
        // (even several per dispatch) re-panics at the dispatcher but must
        // leave every worker alive, and the very next dispatch — in the
        // same round — must run all its tasks to completion
        for round in 0..3 {
            let boom = std::panic::catch_unwind(|| {
                global().scoped(vec![
                    Box::new(|| panic!("round {round} boom a")) as Box<dyn FnOnce() + Send + '_>,
                    Box::new(|| panic!("round {round} boom b")) as Box<dyn FnOnce() + Send + '_>,
                    Box::new(|| ()) as Box<dyn FnOnce() + Send + '_>,
                ]);
            });
            assert!(boom.is_err(), "round {round}: dispatcher must re-panic");
            let hit = AtomicUsize::new(0);
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..5)
                .map(|_| {
                    Box::new(|| {
                        hit.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            global().scoped(tasks);
            assert_eq!(hit.load(Ordering::Relaxed), 5, "round {round}: pool still dispatches");
        }
    }

    #[test]
    fn stats_since_subtracts_every_counter() {
        let earlier =
            PoolStats { workers: 4, dispatches: 10, pool_tasks: 30, caller_tasks: 12 };
        let later = PoolStats { workers: 4, dispatches: 13, pool_tasks: 45, caller_tasks: 20 };
        let d = later.since(&earlier);
        assert_eq!(d.workers, 4);
        assert_eq!(d.dispatches, 3);
        assert_eq!(d.pool_tasks, 15);
        assert_eq!(d.caller_tasks, 8);
    }

    #[test]
    fn utilization_bounds_and_degenerate_cases() {
        // no dispatches or no workers -> 0, never NaN/inf
        let idle = PoolStats { workers: 4, dispatches: 0, pool_tasks: 0, caller_tasks: 9 };
        assert_eq!(idle.utilization(), 0.0);
        let solo = PoolStats { workers: 0, dispatches: 7, pool_tasks: 0, caller_tasks: 7 };
        assert_eq!(solo.utilization(), 0.0);
        // half the offered worker slots ran pool tasks
        let half = PoolStats { workers: 4, dispatches: 2, pool_tasks: 4, caller_tasks: 2 };
        assert!((half.utilization() - 0.5).abs() < 1e-12);
        // over-subscribed dispatches cap at 1.0
        let hot = PoolStats { workers: 2, dispatches: 1, pool_tasks: 9, caller_tasks: 0 };
        assert_eq!(hot.utilization(), 1.0);
    }

    #[test]
    fn stats_count_dispatches_and_tasks() {
        let before = stats();
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
            (0..6).map(|_| Box::new(|| ()) as Box<dyn FnOnce() + Send + '_>).collect();
        global().scoped(tasks);
        // deltas are lower bounds: other tests share the global pool
        let d = stats().since(&before);
        if global().workers() == 0 {
            assert!(d.caller_tasks >= 6, "zero-worker pools run inline: {d:?}");
        } else {
            assert!(d.dispatches >= 1, "{d:?}");
            assert!(
                d.pool_tasks + d.caller_tasks >= 6,
                "all six tasks accounted somewhere: {d:?}"
            );
            assert!(d.utilization() <= 1.0);
        }
    }
}
