//! Hand-rolled benchmark harness (criterion is not in the offline vendor
//! set). Used by every target under `rust/benches/` with `harness = false`.
//!
//! Reports mean / p50 / p99 wall time over a warmup + timed phase, plus an
//! optional throughput figure, in a stable greppable format:
//!
//! ```text
//! bench <name>  iters=64  mean=1.234ms  p50=1.200ms  p99=1.900ms  thrpt=123.4 MB/s
//! ```

use std::time::{Duration, Instant};

/// One benchmark run's statistics.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Benchmark a closure: `warmup` untimed runs then up to `iters` timed runs
/// (capped by `budget`). The closure's return value is black-boxed.
pub fn bench<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    bench_with_budget(name, iters, Duration::from_secs(20), &mut f)
}

pub fn bench_with_budget<T>(
    name: &str,
    iters: usize,
    budget: Duration,
    f: &mut impl FnMut() -> T,
) -> BenchStats {
    // warmup: 2 runs or 10% of budget, whichever first
    let warm_start = Instant::now();
    for _ in 0..2 {
        black_box(f());
        if warm_start.elapsed() > budget / 10 {
            break;
        }
    }
    let mut samples = Vec::with_capacity(iters);
    let start = Instant::now();
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
        if start.elapsed() > budget {
            break;
        }
    }
    samples.sort();
    let n = samples.len().max(1);
    let mean = samples.iter().sum::<Duration>() / n as u32;
    let stats = BenchStats {
        name: name.to_string(),
        iters: n,
        mean,
        p50: samples[(n / 2).min(n - 1)],
        p99: samples[(n * 99 / 100).min(n - 1)],
        min: samples[0],
    };
    println!(
        "bench {:40} iters={:<5} mean={:>10} p50={:>10} p99={:>10}",
        stats.name,
        stats.iters,
        fmt_dur(stats.mean),
        fmt_dur(stats.p50),
        fmt_dur(stats.p99),
    );
    stats
}

/// Report a throughput line alongside a bench.
pub fn report_throughput(stats: &BenchStats, bytes_per_iter: usize) {
    let mbps = bytes_per_iter as f64 / stats.mean_secs() / 1e6;
    println!("bench {:40} thrpt={mbps:.1} MB/s", stats.name);
}

/// Opaque value sink to prevent the optimizer from deleting the work.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// ---------------------------------------------------------------------------
// Perf-trajectory JSON (`BENCH_*.json`)
// ---------------------------------------------------------------------------

/// Accumulates named metric groups and writes them as a flat two-level JSON
/// object — `{"bench": {"metric": value, ...}, ...}` — so every perf bench
/// leaves a machine-readable `BENCH_*.json` next to its stdout report and
/// future PRs can diff the trajectory. Hand-rolled (serde is not in the
/// offline vendor set); keys must be plain identifiers-with-punctuation
/// (no quotes/backslashes — asserted).
#[derive(Default, Debug)]
pub struct BenchJson {
    entries: Vec<(String, Vec<(String, f64)>)>,
}

impl BenchJson {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `metric = value` under `bench` (groups append in call order).
    pub fn record(&mut self, bench: &str, metric: &str, value: f64) {
        for key in [bench, metric] {
            assert!(
                !key.contains('"') && !key.contains('\\'),
                "BenchJson keys must not need escaping: {key:?}"
            );
        }
        if let Some((_, metrics)) = self.entries.iter_mut().find(|(b, _)| b == bench) {
            metrics.push((metric.to_string(), value));
        } else {
            self.entries.push((bench.to_string(), vec![(metric.to_string(), value)]));
        }
    }

    /// Render the JSON document (stable ordering, non-finite values -> null).
    pub fn render(&self) -> String {
        let mut s = String::from("{\n");
        for (gi, (bench, metrics)) in self.entries.iter().enumerate() {
            s.push_str(&format!("  {bench:?}: {{"));
            for (mi, (metric, value)) in metrics.iter().enumerate() {
                if mi > 0 {
                    s.push(',');
                }
                if value.is_finite() {
                    s.push_str(&format!(" {metric:?}: {value:.6}"));
                } else {
                    s.push_str(&format!(" {metric:?}: null"));
                }
            }
            s.push_str(" }");
            if gi + 1 < self.entries.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push('}');
        s.push('\n');
        s
    }

    /// Write to `path`, creating parent directories as needed.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.render())?;
        println!("bench json written to {path}");
        Ok(())
    }
}

/// Parse `--quick` style flags every bench target accepts.
pub struct BenchArgs {
    pub quick: bool,
    pub filter: Option<String>,
}

impl BenchArgs {
    pub fn from_env() -> Self {
        let mut quick = false;
        let mut filter = None;
        for a in std::env::args().skip(1) {
            match a.as_str() {
                "--quick" => quick = true,
                "--bench" => {} // cargo bench passes this through
                s if !s.starts_with('-') => filter = Some(s.to_string()),
                _ => {}
            }
        }
        // cargo test --benches runs bench targets with --test-threads etc.;
        // treat that as quick mode.
        if std::env::var("LLMDT_BENCH_QUICK").is_ok() {
            quick = true;
        }
        BenchArgs { quick, filter }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let stats = bench("noop", 16, || 1 + 1);
        assert!(stats.iters >= 1);
        assert!(stats.mean <= Duration::from_millis(50));
    }

    #[test]
    fn single_iteration_does_not_divide_by_zero() {
        let stats = bench_with_budget("one", 1, Duration::from_secs(5), &mut || 7);
        assert_eq!(stats.iters, 1);
        assert_eq!(stats.p50, stats.min);
    }

    #[test]
    fn bench_json_renders_groups_in_order() {
        let mut j = BenchJson::new();
        j.record("lut_gemm", "gflops", 1.25);
        j.record("lut_gemm", "mean_ms", 0.5);
        j.record("dense", "gflops", f64::NAN);
        let doc = j.render();
        assert!(doc.starts_with('{') && doc.trim_end().ends_with('}'));
        assert!(doc.contains("\"lut_gemm\": { \"gflops\": 1.250000, \"mean_ms\": 0.500000 }"));
        assert!(doc.contains("\"dense\": { \"gflops\": null }"));
        let lut = doc.find("lut_gemm").unwrap();
        let dense = doc.find("dense").unwrap();
        assert!(lut < dense, "insertion order preserved");
    }

    #[test]
    fn percentiles_ordered() {
        let stats = bench("spin", 32, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(stats.min <= stats.p50);
        assert!(stats.p50 <= stats.p99);
    }
}
