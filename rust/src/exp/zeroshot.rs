//! Table 4 (and Tables 16-21): the zero-shot suite on one model —
//! LAMB + five MC tasks + the mean relative change vs fp32.

use anyhow::Result;

use super::quality::{eval_cell, paper_format_rows, require_ckpt, Metrics};
use super::Scale;
use crate::coordinator::{corpus_for, PipelineConfig, Session};
use crate::report::{fnum, pct, Table};

pub fn run(session: &Session, scale: Scale, model: &str) -> Result<Table> {
    let suite = scale.suite();
    let (cfg, ckpt) = require_ckpt(session, model)?;
    let corpus = corpus_for(&cfg);
    let mut table = Table::new(
        &format!("Table 4 — {model} weight-only zero-shot suite"),
        &["format", "LAMB", "Hella", "Wino", "PIQA", "BoolQ", "ARC-c", "Wiki", "D%"],
    );
    let base =
        eval_cell(session, &cfg, &ckpt, &corpus, None, &suite, Metrics::FullSuite)?;
    let fmt_row = |name: &str, cell: &super::quality::CellResult, d: f64| {
        let mut row = vec![name.to_string(), fnum(cell.lamb * 100.0, 2)];
        for (_, acc) in &cell.mc {
            row.push(fnum(acc * 100.0, 2));
        }
        row.push(fnum(cell.wiki_ppl, 2));
        row.push(pct(d));
        row
    };
    table.row(fmt_row("fp32", &base, 0.0));
    for fmt in paper_format_rows() {
        let pc = PipelineConfig::weight_only(fmt);
        let cell =
            eval_cell(session, &cfg, &ckpt, &corpus, Some(&pc), &suite, Metrics::FullSuite)?;
        let d = cell.rel_change_pct(&base);
        table.row(fmt_row(fmt, &cell, d));
    }
    Ok(table)
}
