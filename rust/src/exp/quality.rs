//! Shared quality-evaluation plumbing for the accuracy tables: quantize a
//! checkpoint per [`PipelineConfig`], bind the right eval graph, run the
//! task suite (or the cheap LAMB+Wiki subset), and cache fp32 baselines.

use anyhow::Result;

use crate::coordinator::model::{GraphKind, LmHandle};
use crate::coordinator::pipeline::{fp32_values, quantize_lm, PipelineConfig};
use crate::coordinator::Session;
use crate::data::Corpus;
use crate::model_io::{zoo, Checkpoint, ModelConfig};
use crate::tasks::{
    completion_accuracy, mc_accuracy, gen_mc_items, perplexity, McTask, SuiteConfig, SuiteResult,
};

/// Which metrics a table needs (LAMB+Wiki is ~10x cheaper than the suite).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metrics {
    LambWiki,
    FullSuite,
}

/// One evaluated cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub lamb: f64,
    pub wiki_ppl: f64,
    pub mc: Vec<(McTask, f64)>,
}

impl CellResult {
    pub fn to_suite(&self) -> SuiteResult {
        SuiteResult { lamb: self.lamb, wiki_ppl: self.wiki_ppl, mc: self.mc.clone() }
    }

    /// Mean relative accuracy change (%) vs baseline across all accuracy
    /// metrics present in both (the paper's Delta% aggregation).
    pub fn rel_change_pct(&self, base: &CellResult) -> f64 {
        self.to_suite().rel_change_pct(&base.to_suite())
    }
}

/// Evaluate one (checkpoint, pipeline) cell. `pc = None` -> fp32 baseline.
pub fn eval_cell(
    session: &Session,
    cfg: &ModelConfig,
    ckpt: &Checkpoint,
    corpus: &Corpus,
    pc: Option<&PipelineConfig>,
    suite: &SuiteConfig,
    metrics: Metrics,
) -> Result<CellResult> {
    let (kind, values) = match pc {
        None => (GraphKind::Fp32, fp32_values(cfg, ckpt)?),
        Some(pc) => {
            let qm = quantize_lm(cfg, ckpt, pc, corpus)?;
            let kind = if qm.w4a4 { GraphKind::W4A4 } else { GraphKind::WeightOnly };
            (kind, qm.values)
        }
    };
    let mut handle = LmHandle::bind(&session.engine, cfg, kind, &values)?;
    let windows = corpus.heldout_windows(suite.n_completion.max(suite.n_ppl_windows), cfg.seq);
    let lamb =
        completion_accuracy(&mut handle, &windows[..suite.n_completion.min(windows.len())])?;
    let wiki = perplexity(&mut handle, &windows[..suite.n_ppl_windows.min(windows.len())])?;
    let mc = match metrics {
        Metrics::LambWiki => Vec::new(),
        Metrics::FullSuite => {
            let mut out = Vec::new();
            for task in McTask::ALL {
                let items =
                    gen_mc_items(corpus, task, suite.n_mc_items, suite.mc_context, suite.seed);
                out.push((task, mc_accuracy(&mut handle, &items)?));
            }
            out
        }
    };
    Ok(CellResult { lamb, wiki_ppl: wiki, mc })
}

/// Load a model's checkpoint, failing with a actionable message.
pub fn require_ckpt(session: &Session, model: &str) -> Result<(ModelConfig, Checkpoint)> {
    let cfg = zoo(model)?;
    let ckpt = session
        .load_checkpoint(model)
        .map_err(|e| anyhow::anyhow!("{e}; run `repro train --model {model}` first"))?;
    Ok((cfg, ckpt))
}

/// The 11 main formats + fp32 row labels, paper order (Tables 3/8).
pub fn paper_format_rows() -> Vec<&'static str> {
    let mut v = vec!["nf4", "sf4", "int4", "e2m1_i", "e2m1_b", "e2m1", "e2m1_sr", "e2m1_sp",
                     "e3m0", "apot4", "apot4_sp"];
    v.shrink_to_fit();
    v
}
