//! Table 10: MAC area/power from the unit-gate model, side by side with
//! the paper's Synopsys DC numbers.

use anyhow::Result;

use crate::hw;
use crate::report::{fnum, Table};

/// Paper Table 10 values for the comparison columns.
pub const PAPER_TABLE10: [(&str, u32, f64, f64); 10] = [
    ("int4", 16, 160.7, 48.5),
    ("int5", 18, 203.6, 59.8),
    ("e2m1_i", 20, 228.2, 59.7),
    ("e2m1_b", 23, 268.9, 67.9),
    ("e2m1", 17, 170.4, 49.6),
    ("e2m1_sr", 18, 191.3, 53.5),
    ("e2m1_sp", 19, 218.0, 54.6),
    ("e3m0", 22, 217.7, 59.5),
    ("apot4", 16, 181.6, 47.2),
    ("apot4_sp", 16, 185.1, 45.5),
];

pub fn run() -> Result<Table> {
    let mut table = Table::new(
        "Table 10 — MAC unit area/power (unit-gate model vs paper synthesis)",
        &[
            "format", "accum.bits", "mult.um2", "accum.um2", "MAC.um2", "uW",
            "overhead%", "paper.bits", "paper.MAC", "MAC.err%",
        ],
    );
    let rows = hw::table10();
    for row in rows {
        let paper = PAPER_TABLE10.iter().find(|(n, ..)| *n == row.format);
        let (pb, pa) = paper.map(|(_, b, a, _)| (*b as i64, *a)).unwrap_or((-1, f64::NAN));
        table.row(vec![
            row.format.clone(),
            row.accum_bits.to_string(),
            fnum(row.mult_area, 1),
            fnum(row.accum_area, 1),
            fnum(row.mac_area, 1),
            fnum(row.power, 1),
            fnum(row.overhead_pct, 1),
            pb.to_string(),
            fnum(pa, 1),
            fnum(100.0 * (row.mac_area - pa) / pa, 1),
        ]);
    }
    Ok(table)
}
