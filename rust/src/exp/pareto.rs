//! Figure 3 / Figure 8: quality-vs-area Pareto — W4A4 quality deltas
//! (Table 8 machinery) against the MAC-unit system overhead (Table 10).

use anyhow::Result;

use super::w4a4;
use super::Scale;
use crate::coordinator::Session;
use crate::hw;
use crate::report::AsciiScatter;

/// Marker characters per format for the ASCII scatter.
fn marker(fmt: &str) -> char {
    match fmt {
        "int4" => 'I',
        "e2m1" => 'E',
        "e2m1_i" => 'i',
        "e2m1_b" => 'b',
        "e2m1_sr" => 'R',
        "e2m1_sp" => 'P',
        "e3m0" => '3',
        "apot4" => 'A',
        "apot4_sp" => 'S',
        "nf4" => 'n',
        "sf4" => 's',
        _ => '?',
    }
}

/// Build the Pareto from fresh W4A4 results; returns (rendered figure,
/// (format, overhead%, delta%) points, Pareto-front format names).
pub fn run(session: &Session, scale: Scale) -> Result<(String, Vec<(String, f64, f64)>)> {
    // reuse a previous Table 8 run when available (it is the expensive part)
    let res = match w4a4::cached(session) {
        Some(r) => r,
        None => w4a4::compute(session, scale)?,
    };
    let mut points = Vec::new();
    for (fmt, per_model) in &res.rows {
        let Some(overhead) = hw::overhead_pct(fmt) else {
            continue; // lookup formats have no hardened MAC (as in paper)
        };
        // best-of SQ policy per model, averaged (the paper's figure uses
        // the SmoothQuant-on numbers for the models that need it)
        let mut acc = 0.0f64;
        let mut n = 0.0f64;
        for (no_sq, sq) in per_model {
            let v = no_sq.max(*sq);
            if v.is_finite() {
                acc += v;
                n += 1.0;
            }
        }
        points.push((fmt.clone(), overhead, acc / n.max(1.0)));
    }

    let mut fig = AsciiScatter::new(
        "Figure 3 — Quality vs Area (mean D% accuracy vs chip overhead %)",
        "chip overhead % vs INT4",
        "mean accuracy D% vs fp32",
    );
    for (fmt, x, y) in &points {
        fig.point(*x, *y, marker(fmt), fmt);
    }
    let rendered = fig.render(64, 20);

    // save TSV
    let dir = std::path::Path::new(&session.results_dir);
    std::fs::create_dir_all(dir)?;
    let mut tsv = String::from("format\toverhead_pct\tdelta_pct\n");
    for (fmt, x, y) in &points {
        tsv.push_str(&format!("{fmt}\t{x:.3}\t{y:.3}\n"));
    }
    std::fs::write(dir.join("fig3_pareto.tsv"), tsv)?;
    Ok((rendered, points))
}

/// The Pareto front (formats not dominated in (area, quality)).
pub fn pareto_front(points: &[(String, f64, f64)]) -> Vec<String> {
    let mut front = Vec::new();
    for (f, x, y) in points {
        let dominated = points.iter().any(|(f2, x2, y2)| {
            f2 != f && x2 <= x && y2 >= y && (x2 < x || y2 > y)
        });
        if !dominated {
            front.push(f.clone());
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pareto_front_filters_dominated() {
        let pts = vec![
            ("a".to_string(), 0.0, -5.0),
            ("b".to_string(), 1.0, -2.0),
            ("c".to_string(), 2.0, -3.0), // dominated by b
            ("d".to_string(), 3.0, -1.0),
        ];
        let front = pareto_front(&pts);
        assert_eq!(front, vec!["a", "b", "d"]);
    }
}
