//! Experiment modules: one per paper table/figure (DESIGN.md §5 index).
//!
//! Every module exposes a `run(...) -> report::Table` (or figure string)
//! that the CLI (`repro table N` / `repro figure N`) and the bench targets
//! both call; results are also saved as TSV under `results/`.

pub mod blocksize;
pub mod convergence;
pub mod dof_sweep;
pub mod gptq_cmp;
pub mod hardware;
pub mod multilingual;
pub mod pareto;
pub mod profile;
pub mod quality;
pub mod three_bit;
pub mod vision;
pub mod w4a4;
pub mod weight_only;
pub mod zeroshot;

use anyhow::Result;

use crate::coordinator::Session;

/// Scale knob shared by all experiments: `quick` shrinks workloads ~8x for
/// tests and smoke benches; `full` is the EXPERIMENTS.md configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    pub fn suite(&self) -> crate::tasks::SuiteConfig {
        match self {
            Scale::Quick => crate::tasks::SuiteConfig::quick(),
            Scale::Full => crate::tasks::SuiteConfig::standard(),
        }
    }

    /// Models used by the multi-model tables at this scale. `med` is kept
    /// out of the XLA-heavy quality tables (CPU budget) but profiled in
    /// Tables 1/12; add it back per-run with `--model med`.
    pub fn table_models(&self) -> Vec<&'static str> {
        match self {
            Scale::Quick => vec!["nano"],
            Scale::Full => vec!["micro", "small"],
        }
    }
}

/// Save a rendered table + its TSV under the session's results dir.
pub fn emit(session: &Session, id: &str, table: &crate::report::Table) -> Result<()> {
    let dir = std::path::Path::new(&session.results_dir);
    table.save_tsv(&dir.join(format!("{id}.tsv")))?;
    let txt = table.render();
    std::fs::write(dir.join(format!("{id}.txt")), &txt)?;
    println!("{txt}");
    Ok(())
}

/// Ensure a zoo model's checkpoint exists (trains it if missing) — used by
/// the bench targets and examples so they are self-contained.
pub fn ensure_model(session: &Session, model: &str) -> Result<()> {
    let path = crate::model_io::checkpoint_path(&session.checkpoints_dir, model);
    if path.exists() {
        return Ok(());
    }
    let cfg = crate::model_io::zoo(model)?;
    let corpus = crate::coordinator::corpus_for(&cfg);
    crate::coordinator::trainer::train_and_save(
        &session.engine,
        &cfg,
        &corpus,
        &session.checkpoints_dir,
        false,
    )?;
    Ok(())
}

/// Ensure a classifier checkpoint exists (Table 9 benches).
pub fn ensure_cls(session: &Session, name: &str) -> Result<()> {
    let path =
        crate::model_io::checkpoint_path(&session.checkpoints_dir, &format!("cls_{name}"));
    if path.exists() {
        return Ok(());
    }
    let cfg = crate::nn::cls_zoo(name)?;
    let images = crate::data::ImageSet::new(16, 10, 7, 0.6);
    crate::coordinator::trainer::train_cls_and_save(
        &session.engine,
        &cfg,
        &images,
        &session.checkpoints_dir,
        false,
    )?;
    Ok(())
}
