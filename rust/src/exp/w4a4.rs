//! Table 8 (and Tables 22-28): W4A4 weight+activation quantization with
//! and without SmoothQuant — mean relative accuracy change vs fp32.

use anyhow::Result;

use super::quality::{eval_cell, paper_format_rows, require_ckpt, CellResult, Metrics};
use super::Scale;
use crate::coordinator::{corpus_for, PipelineConfig, Session};
use crate::report::{pct, Table};

/// Raw results (format, model, smoothquant) -> Delta% — reused by Fig. 3.
pub struct W4a4Results {
    pub models: Vec<String>,
    /// rows[fmt][model] = (no-SQ delta, SQ delta)
    pub rows: Vec<(String, Vec<(f64, f64)>)>,
}

pub fn compute(session: &Session, scale: Scale) -> Result<W4a4Results> {
    // med under the full suite x 22 W4A4 cells is CPU-prohibitive; the
    // paper's shape needs multiple models, not the largest one.
    let models = match scale {
        Scale::Quick => vec!["nano"],
        Scale::Full => vec!["micro", "small"],
    };
    let suite = scale.suite();
    let mut baselines = Vec::new();
    for model in &models {
        let (cfg, ckpt) = require_ckpt(session, model)?;
        let corpus = corpus_for(&cfg);
        let base =
            eval_cell(session, &cfg, &ckpt, &corpus, None, &suite, Metrics::FullSuite)?;
        baselines.push((cfg, ckpt, corpus, base));
    }
    let mut rows = Vec::new();
    for fmt in paper_format_rows() {
        let mut per_model = Vec::new();
        for (cfg, ckpt, corpus, base) in &baselines {
            let mut deltas = (f64::NAN, f64::NAN);
            for (sq, slot) in [(false, 0), (true, 1)] {
                let pc = PipelineConfig::w4a4(fmt, sq);
                let cell: CellResult =
                    eval_cell(session, cfg, ckpt, corpus, Some(&pc), &suite, Metrics::FullSuite)?;
                let d = cell.rel_change_pct(base);
                if slot == 0 {
                    deltas.0 = d;
                } else {
                    deltas.1 = d;
                }
            }
            per_model.push(deltas);
        }
        rows.push((fmt.to_string(), per_model));
    }
    let res = W4a4Results { models: models.iter().map(|s| s.to_string()).collect(), rows };
    cache_write(session, &res).ok();
    Ok(res)
}

fn cache_path(session: &Session) -> std::path::PathBuf {
    std::path::Path::new(&session.results_dir).join("table8_raw.tsv")
}

fn cache_write(session: &Session, res: &W4a4Results) -> Result<()> {
    std::fs::create_dir_all(&session.results_dir)?;
    let mut s = String::from("# format\tmodel\tno_sq\tsq\n");
    for (fmt, per_model) in &res.rows {
        for (m, (a, b)) in res.models.iter().zip(per_model) {
            s.push_str(&format!("{fmt}\t{m}\t{a}\t{b}\n"));
        }
    }
    std::fs::write(cache_path(session), s)?;
    Ok(())
}

/// Load cached Table 8 raw results if a previous full run saved them
/// (Figure 3 reuses them instead of re-running the whole W4A4 grid).
pub fn cached(session: &Session) -> Option<W4a4Results> {
    let text = std::fs::read_to_string(cache_path(session)).ok()?;
    let mut models: Vec<String> = Vec::new();
    let mut map: std::collections::HashMap<String, Vec<(f64, f64)>> = Default::default();
    let mut order: Vec<String> = Vec::new();
    for line in text.lines().skip(1) {
        let p: Vec<&str> = line.split('\t').collect();
        if p.len() != 4 {
            continue;
        }
        if !models.contains(&p[1].to_string()) {
            models.push(p[1].to_string());
        }
        if !order.contains(&p[0].to_string()) {
            order.push(p[0].to_string());
        }
        map.entry(p[0].to_string())
            .or_default()
            .push((p[2].parse().ok()?, p[3].parse().ok()?));
    }
    let rows = order.into_iter().map(|f| (f.clone(), map[&f].clone())).collect();
    Some(W4a4Results { models, rows })
}

pub fn run(session: &Session, scale: Scale) -> Result<Table> {
    let res = compute(session, scale)?;
    let mut headers = vec!["format".to_string()];
    for m in &res.models {
        headers.push(format!("{m}:noSQ"));
        headers.push(format!("{m}:SQ"));
    }
    let mut table = Table::new(
        "Table 8 — W4A4 eval, mean D% vs fp32 (without / with SmoothQuant)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (fmt, per_model) in &res.rows {
        let mut row = vec![fmt.clone()];
        for (no_sq, sq) in per_model {
            row.push(pct(*no_sq));
            row.push(pct(*sq));
        }
        table.row(row);
    }
    Ok(table)
}
