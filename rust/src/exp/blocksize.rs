//! Table 5: sub-channel block-size sweep (16..256 + channelwise) — format
//! differences persist even at tiny blocks; SR collapses at block 16.

use anyhow::Result;

use super::quality::{eval_cell, paper_format_rows, require_ckpt, Metrics};
use super::Scale;
use crate::coordinator::{corpus_for, PipelineConfig, Session};
use crate::quant::BlockSize;
use crate::report::{pct, Table};

pub fn run(session: &Session, scale: Scale, model: &str) -> Result<Table> {
    let suite = scale.suite();
    let (cfg, ckpt) = require_ckpt(session, model)?;
    let corpus = corpus_for(&cfg);
    let blocks: Vec<BlockSize> = match scale {
        Scale::Quick => vec![BlockSize::Sub(16), BlockSize::Channelwise],
        Scale::Full => vec![
            BlockSize::Sub(16),
            BlockSize::Sub(32),
            BlockSize::Sub(64),
            BlockSize::Sub(128),
            BlockSize::Sub(256),
            BlockSize::Channelwise,
        ],
    };
    // blocks must divide d_model; drop those that don't
    let blocks: Vec<BlockSize> = blocks
        .into_iter()
        .filter(|b| match b {
            BlockSize::Sub(b) => cfg.d_model % b == 0 && cfg.d_ff % b == 0,
            BlockSize::Channelwise => true,
        })
        .collect();

    let mut headers = vec!["format".to_string()];
    headers.extend(blocks.iter().map(|b| b.label()));
    let mut table = Table::new(
        &format!("Table 5 — {model} sub-channel block-size sweep (mean D% vs fp32)"),
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    let base = eval_cell(session, &cfg, &ckpt, &corpus, None, &suite, Metrics::FullSuite)?;
    for fmt in paper_format_rows() {
        let mut row = vec![fmt.to_string()];
        for block in &blocks {
            let mut pc = PipelineConfig::weight_only(fmt);
            pc.block = *block;
            let cell =
                eval_cell(session, &cfg, &ckpt, &corpus, Some(&pc), &suite, Metrics::FullSuite)?;
            row.push(pct(cell.rel_change_pct(&base)));
        }
        table.row(row);
    }
    Ok(table)
}
