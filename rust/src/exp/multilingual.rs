//! Table 14: multi-lingual evaluation — a model quantized once and
//! evaluated on five synthetic "languages" (per-language corpora).

use anyhow::Result;

use super::quality::{eval_cell, require_ckpt, Metrics};
use super::Scale;
use crate::coordinator::{corpus_for_language, PipelineConfig, Session};
use crate::data::LANGUAGES;
use crate::report::{fnum, Table};

pub const ML_FORMATS: [&str; 7] =
    ["nf4", "sf4", "int4", "e2m1", "e2m1_sr", "e2m1_sp", "apot4_sp"];

pub fn run(session: &Session, scale: Scale, model: &str) -> Result<Table> {
    let suite = scale.suite();
    let (cfg, ckpt) = require_ckpt(session, model)?;
    let mut headers = vec!["format".to_string()];
    for (lang, ..) in LANGUAGES {
        headers.push(lang.to_uppercase());
    }
    headers.push("Wiki(en)".into());
    let mut table = Table::new(
        &format!("Table 14 — {model} multi-lingual completion accuracy"),
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    let corpora: Vec<_> =
        LANGUAGES.iter().map(|(l, ..)| corpus_for_language(&cfg, l)).collect();

    // fp32 baseline row
    let mut row = vec!["fp32".to_string()];
    let mut wiki = f64::NAN;
    for (i, corpus) in corpora.iter().enumerate() {
        let cell = eval_cell(session, &cfg, &ckpt, corpus, None, &suite, Metrics::LambWiki)?;
        row.push(fnum(cell.lamb * 100.0, 2));
        if i == 0 {
            wiki = cell.wiki_ppl;
        }
    }
    row.push(fnum(wiki, 2));
    table.row(row);

    for fmt in ML_FORMATS {
        let pc = PipelineConfig::weight_only(fmt);
        let mut row = vec![fmt.to_string()];
        let mut wiki = f64::NAN;
        for (i, corpus) in corpora.iter().enumerate() {
            let cell =
                eval_cell(session, &cfg, &ckpt, corpus, Some(&pc), &suite, Metrics::LambWiki)?;
            row.push(fnum(cell.lamb * 100.0, 2));
            if i == 0 {
                wiki = cell.wiki_ppl;
            }
        }
        row.push(fnum(wiki, 2));
        table.row(row);
    }
    Ok(table)
}
