//! Table 9: vision models (MLP + im2col-CNN roles) under weight+activation
//! quantization — the t-shaped-weights story transfers beyond LLMs.

use std::collections::HashMap;

use anyhow::Result;

use super::Scale;
use crate::coordinator::Session;
use crate::data::ImageSet;
use crate::formats;
use crate::model_io::Checkpoint;
use crate::nn::{self, ClsConfig, CLS_ZOO};
use crate::quant::{quantize_weight, smooth_scales, BlockSize, Calib, QuantConfig, SmoothQuant};
use crate::report::{fnum, Table};
use crate::rng::Pcg64;
use crate::runtime::Value;
use crate::tensor::{argmax, Tensor};

pub const VISION_FORMATS: [&str; 9] =
    ["nf4", "sf4", "int4", "e2m1", "e2m1_sr", "e2m1_sp", "e3m0", "apot4", "apot4_sp"];

/// Quantize a classifier checkpoint into W4A4 artifact inputs.
fn quantize_cls(
    cfg: &ClsConfig,
    ckpt: &Checkpoint,
    fmt: &str,
    images: &ImageSet,
) -> Result<HashMap<String, Value>> {
    let spec = formats::must(fmt);
    // calibration activations from a fixed batch
    let mut rng = Pcg64::new(0x0ca1b);
    let (x, _) = images.batch(64, &mut rng);
    let mut cap = nn::ActivationCapture::new(4096);
    nn::forward_cls(cfg, ckpt, &x, Some(&mut cap))?;

    let qnames = cfg.quant_linear_names();
    let mut values = HashMap::new();
    for (name, _) in cfg.param_specs() {
        let t = ckpt.get(&name)?;
        if !qnames.contains(&name) {
            values.insert(name.clone(), Value::F32(t.clone()));
            continue;
        }
        let k = t.rows();
        let acts = cap.stacked(&name).ok_or_else(|| anyhow::anyhow!("no acts for {name}"))?;
        let smooth = smooth_scales(&acts, t, 0.5);
        let w = smooth.apply_to_weight(t);
        let block = if k % 128 == 0 { BlockSize::Sub(128) } else { BlockSize::Channelwise };
        let q = quantize_weight(&w, &QuantConfig { format: spec.clone(), block, calib: Calib::None });
        values.insert(format!("{name}.codes"), Value::I8(q.codes.clone(), vec![q.k, q.n]));
        values.insert(format!("{name}.scales"), Value::F32(q.expanded_scales()));
        values.insert(
            format!("{name}.smooth"),
            Value::F32(Tensor::new(&[k], smooth.inv_smooth.clone())),
        );
        let _ = SmoothQuant::identity(k);
    }
    values.insert("codebook".into(), Value::F32(Tensor::new(&[16], spec.padded16())));
    values.insert(
        "act_codebook".into(),
        Value::F32(Tensor::new(&[16], spec.padded16())),
    );
    Ok(values)
}

/// Top-1 accuracy of a bound classifier executable over `n_batches`.
fn accuracy(
    session: &Session,
    cfg: &ClsConfig,
    artifact: &str,
    values: &HashMap<String, Value>,
    images: &ImageSet,
    n_batches: usize,
) -> Result<f64> {
    let exe = session.engine.load(artifact)?;
    let bound = exe.bind(values)?;
    let mut rng = Pcg64::new(0xe5a1);
    let mut correct = 0usize;
    let mut total = 0usize;
    for _ in 0..n_batches {
        let (x, labels) = images.batch(cfg.batch_eval, &mut rng);
        let mut rest = HashMap::new();
        rest.insert("x".to_string(), Value::F32(x));
        let outs = exe.run_bound(&bound, &rest)?;
        let logits = outs[0].as_f32()?;
        for (r, &lbl) in labels.iter().enumerate() {
            if argmax(logits.row(r)) == lbl as usize {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total as f64)
}

pub fn run(session: &Session, scale: Scale) -> Result<Table> {
    let n_batches = match scale {
        Scale::Quick => 2,
        Scale::Full => 16,
    };
    let mut table = Table::new(
        "Table 9 — Vision models, W4A4 channelwise (top-1 accuracy %)",
        &["format", "mlp", "cnn"],
    );
    let images = ImageSet::new(16, 10, 7, 0.6);

    let mut fp32_row = vec!["fp32".to_string()];
    let mut ckpts = Vec::new();
    for cfg in CLS_ZOO {
        let ckpt = session
            .load_checkpoint(&format!("cls_{}", cfg.name))
            .map_err(|e| anyhow::anyhow!("{e}; run `repro train --all` first"))?;
        let mut values = HashMap::new();
        for (name, _) in cfg.param_specs() {
            values.insert(name.clone(), Value::F32(ckpt.get(&name)?.clone()));
        }
        let acc = accuracy(
            session,
            &cfg,
            &format!("cls_fwd_fp32_{}", cfg.name),
            &values,
            &images,
            n_batches,
        )?;
        fp32_row.push(fnum(acc * 100.0, 2));
        ckpts.push((cfg, ckpt));
    }
    table.row(fp32_row);

    for fmt in VISION_FORMATS {
        let mut row = vec![fmt.to_string()];
        for (cfg, ckpt) in &ckpts {
            let values = quantize_cls(cfg, ckpt, fmt, &images)?;
            let acc = accuracy(
                session,
                cfg,
                &format!("cls_fwd_w4a4_{}", cfg.name),
                &values,
                &images,
                n_batches,
            )?;
            row.push(fnum(acc * 100.0, 2));
        }
        table.row(row);
    }
    Ok(table)
}
