//! Table 7: three-bit formats — SF3 keeps beating NF3; E2M0 (the only
//! well-defined FP3) beats INT3 everywhere.

use anyhow::Result;

use super::quality::{eval_cell, require_ckpt, Metrics};
use super::Scale;
use crate::coordinator::{corpus_for, PipelineConfig, Session};
use crate::report::{fnum, Table};

pub const THREE_BIT_FORMATS: [&str; 4] = ["nf3", "sf3", "int3", "e2m0"];

pub fn run(session: &Session, scale: Scale, model: &str) -> Result<Table> {
    let suite = scale.suite();
    let (cfg, ckpt) = require_ckpt(session, model)?;
    let corpus = corpus_for(&cfg);
    let mut table = Table::new(
        &format!("Table 7 — {model} three-bit formats"),
        &["format", "LAMB", "Hella", "Wino", "PIQA", "BoolQ", "ARC-c", "Wiki"],
    );
    let mut add = |name: &str, cell: &super::quality::CellResult| {
        let mut row = vec![name.to_string(), fnum(cell.lamb * 100.0, 2)];
        for (_, acc) in &cell.mc {
            row.push(fnum(acc * 100.0, 2));
        }
        row.push(fnum(cell.wiki_ppl, 2));
        table.row(row);
    };
    let base = eval_cell(session, &cfg, &ckpt, &corpus, None, &suite, Metrics::FullSuite)?;
    add("fp32", &base);
    for fmt in THREE_BIT_FORMATS {
        let pc = PipelineConfig::weight_only(fmt);
        let cell =
            eval_cell(session, &cfg, &ckpt, &corpus, Some(&pc), &suite, Metrics::FullSuite)?;
        add(fmt, &cell);
    }
    Ok(table)
}
