//! Tables 1/11/12 + Figure 2: weight & activation profiling — DNN tensors
//! are Student-t distributed with single-digit nu.
//!
//! Weights come from the trained zoo checkpoints; activations from the
//! pure-Rust forward over held-out sequences. "Paper-role" probe tensors
//! (t samples at the nu values Table 1 reports per model) extend the sweep
//! to the full 30-network scale the paper profiles.

use anyhow::Result;

use super::Scale;
use crate::coordinator::{corpus_for, Session};
use crate::distfit::{histogram, profile_tensor, qq_data};
use crate::model_io::zoo;
use crate::nn;
use crate::report::{fnum, Table};
use crate::rng::Pcg64;

/// Paper Table 1 role models and their reported weight/activation nu —
/// used to synthesize probe tensors exercising the fitting pipeline at the
/// paper's operating points.
pub const PAPER_ROLES: [(&str, f64, f64); 8] = [
    ("OPT-1B(role)", 6.68, 5.91),
    ("BLOOM-7B(role)", 10.13, 4.51),
    ("LLaMA2-7B(role)", 6.78, 2.98),
    ("Mistral-7B(role)", 1.66, 1.67),
    ("Yi-6B(role)", 7.26, 2.50),
    ("FLAN-T5(role)", 13.47, 5.34),
    ("ResNet18(role)", 2.71, 10.94),
    ("MobileNetV2(role)", 5.02, 8.22),
];

struct Agg {
    nus: Vec<f64>,
    ks_deltas: Vec<f64>,
}

impl Agg {
    fn new() -> Agg {
        Agg { nus: Vec::new(), ks_deltas: Vec::new() }
    }

    fn push(&mut self, values: &[f32]) {
        let pr = profile_tensor(values);
        self.nus.push(pr.t.nu);
        self.ks_deltas.push(pr.ks_delta());
    }

    fn mean_std(&self) -> (f64, f64) {
        let n = self.nus.len().max(1) as f64;
        let mu = self.nus.iter().sum::<f64>() / n;
        let var = self.nus.iter().map(|x| (x - mu).powi(2)).sum::<f64>() / n;
        (mu, var.sqrt())
    }

    fn mean_ks(&self) -> f64 {
        self.ks_deltas.iter().sum::<f64>() / self.ks_deltas.len().max(1) as f64
    }
}

/// Table 1/11: per-model weight + activation profiling.
pub fn run(session: &Session, scale: Scale) -> Result<Table> {
    let mut table = Table::new(
        "Table 1 — Weight & Activation Profiling (fitted nu, KS-delta)",
        &["model", "W:nu", "W:nu-std", "W:KS-d", "A:nu", "A:nu-std", "A:KS-d"],
    );
    let models = match scale {
        Scale::Quick => vec!["nano"],
        Scale::Full => vec!["micro", "small", "med"],
    };
    for model in models {
        let cfg = zoo(model)?;
        let Ok(ckpt) = session.load_checkpoint(model) else {
            eprintln!("[profile] {model}: no checkpoint, skipping");
            continue;
        };
        let corpus = corpus_for(&cfg);
        let mut w_agg = Agg::new();
        for name in cfg.quant_linear_names() {
            w_agg.push(ckpt.get(&name)?.data());
        }
        // activations from held-out sequences
        let n_seqs = match scale {
            Scale::Quick => 2,
            Scale::Full => 6,
        };
        let windows = corpus.heldout_windows(n_seqs, cfg.seq);
        let seqs: Vec<Vec<i32>> = windows.iter().map(|w| w[..cfg.seq].to_vec()).collect();
        let cap = nn::calibrate_lm(&cfg, &ckpt, &seqs, 4096)?;
        let mut a_agg = Agg::new();
        for name in cfg.quant_linear_names() {
            if let Some(x) = cap.stacked(&name) {
                a_agg.push(x.data());
            }
        }
        let (wmu, wsd) = w_agg.mean_std();
        let (amu, asd) = a_agg.mean_std();
        table.row(vec![
            model.to_string(),
            fnum(wmu, 2),
            fnum(wsd, 2),
            fnum(w_agg.mean_ks(), 3),
            fnum(amu, 2),
            fnum(asd, 2),
            fnum(a_agg.mean_ks(), 3),
        ]);
    }

    // probe tensors at the paper's reported nu operating points
    let n = match scale {
        Scale::Quick => 4_000,
        Scale::Full => 30_000,
    };
    let mut rng = Pcg64::new(0x9f0f11e);
    for (name, w_nu, a_nu) in PAPER_ROLES {
        let w: Vec<f32> = rng.student_t_vec(n, w_nu, 0.02);
        let a: Vec<f32> = rng.student_t_vec(n, a_nu, 1.0);
        let wp = profile_tensor(&w);
        let ap = profile_tensor(&a);
        table.row(vec![
            name.to_string(),
            fnum(wp.t.nu, 2),
            "-".into(),
            fnum(wp.ks_delta(), 3),
            fnum(ap.t.nu, 2),
            "-".into(),
            fnum(ap.ks_delta(), 3),
        ]);
    }
    Ok(table)
}

/// Table 12: per-layer-type breakdown for one model.
pub fn run_breakdown(session: &Session, scale: Scale, model: &str) -> Result<Table> {
    let cfg = zoo(model)?;
    let ckpt = session.load_checkpoint(model)?;
    let corpus = corpus_for(&cfg);
    let n_seqs = match scale {
        Scale::Quick => 2,
        Scale::Full => 6,
    };
    let windows = corpus.heldout_windows(n_seqs, cfg.seq);
    let seqs: Vec<Vec<i32>> = windows.iter().map(|w| w[..cfg.seq].to_vec()).collect();
    let cap = nn::calibrate_lm(&cfg, &ckpt, &seqs, 4096)?;

    let mut table = Table::new(
        &format!("Table 12 — {model} per-layer-type profiling"),
        &["layer", "W:nu", "W:KS-d", "A:nu", "A:KS-d"],
    );
    for (label, leaf) in [
        ("Query", "wq"),
        ("Key", "wk"),
        ("Value", "wv"),
        ("Out", "wo"),
        ("FC1", "w1"),
        ("FC2", "w2"),
    ] {
        let mut w_agg = Agg::new();
        let mut a_agg = Agg::new();
        for l in 0..cfg.n_layers {
            let name = format!("l{l}.{leaf}");
            w_agg.push(ckpt.get(&name)?.data());
            if let Some(x) = cap.stacked(&name) {
                a_agg.push(x.data());
            }
        }
        let (wmu, _) = w_agg.mean_std();
        let (amu, _) = a_agg.mean_std();
        table.row(vec![
            label.to_string(),
            fnum(wmu, 2),
            fnum(w_agg.mean_ks(), 3),
            fnum(amu, 2),
            fnum(a_agg.mean_ks(), 3),
        ]);
    }
    Ok(table)
}

/// Figure 2: histogram + Q-Q TSVs for one weight tensor.
pub fn run_fig2(session: &Session, model: &str) -> Result<String> {
    let cfg = zoo(model)?;
    let ckpt = session.load_checkpoint(model)?;
    // an MLP weight tensor, as in the paper's Mistral-7B figure
    let name = format!("l{}.w1", cfg.n_layers / 2);
    let w = ckpt.get(&name)?;
    let pr = profile_tensor(w.data());
    let lim = 4.0 * pr.t.sigma;
    let hist = histogram(w.data(), 61, -lim, lim);
    let qq = qq_data(w.data(), 64);

    let dir = std::path::Path::new(&session.results_dir);
    std::fs::create_dir_all(dir)?;
    let mut h = String::from("center\tdensity\tt_pdf\tnormal_pdf\n");
    for (c, d) in &hist {
        let t = crate::special::student_t::pdf((c - pr.t.mu) / pr.t.sigma, pr.t.nu) / pr.t.sigma;
        let n = crate::special::normal::pdf((c - pr.normal.mu) / pr.normal.sigma)
            / pr.normal.sigma;
        h.push_str(&format!("{c:.6}\t{d:.6}\t{t:.6}\t{n:.6}\n"));
    }
    std::fs::write(dir.join("fig2_hist.tsv"), h)?;
    let mut q = String::from("p\tempirical\ttheo_t\ttheo_normal\n");
    for i in 0..qq.probs.len() {
        q.push_str(&format!(
            "{:.4}\t{:.6}\t{:.6}\t{:.6}\n",
            qq.probs[i], qq.empirical[i], qq.theo_t[i], qq.theo_normal[i]
        ));
    }
    std::fs::write(dir.join("fig2_qq.tsv"), q)?;

    Ok(format!(
        "Figure 2 — {model} {name}: fitted t(nu={:.2}, sigma={:.4}), \
         KS_t={:.4} KS_normal={:.4} (delta {:+.4})\n\
         data: results/fig2_hist.tsv, results/fig2_qq.tsv",
        pr.t.nu,
        pr.t.sigma,
        pr.ks_t,
        pr.ks_normal,
        pr.ks_delta()
    ))
}
