//! Table 2: SF4 degrees-of-freedom sweep — accuracy peaks near nu = 5,
//! well before SF4 converges to NF4.

use anyhow::Result;

use super::quality::{eval_cell, require_ckpt, Metrics};
use super::Scale;
use crate::coordinator::{corpus_for, PipelineConfig, Session};
use crate::report::{fnum, Table};

pub fn run(session: &Session, scale: Scale) -> Result<Table> {
    let models = match scale {
        Scale::Quick => vec!["nano"],
        Scale::Full => vec!["micro", "small"],
    };
    let suite = scale.suite();
    let mut headers = vec!["format".to_string(), "nu".to_string()];
    for m in &models {
        headers.push(format!("{m}:PPL"));
        headers.push(format!("{m}:ACC"));
    }
    let mut table = Table::new(
        "Table 2 — SF4 Degrees of Freedom sweep (Wiki PPL / LAMB ACC)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    let cells: Vec<(String, String, Vec<(f64, f64)>)> = Vec::new();
    let mut rows_spec: Vec<(&str, &str)> = vec![("fp32", "-"), ("nf4", "-")];
    for nu in ["3", "4", "5", "6", "7", "8"] {
        rows_spec.push(("sf4", nu));
    }

    let mut per_model: Vec<Vec<(f64, f64)>> = vec![Vec::new(); rows_spec.len()];
    for (mi, model) in models.iter().enumerate() {
        let (cfg, ckpt) = require_ckpt(session, model)?;
        let corpus = corpus_for(&cfg);
        for (ri, (fmt, nu)) in rows_spec.iter().enumerate() {
            let cell = match (*fmt, *nu) {
                ("fp32", _) => {
                    eval_cell(session, &cfg, &ckpt, &corpus, None, &suite, Metrics::LambWiki)?
                }
                (f, nu) => {
                    let name = if f == "sf4" { format!("sf4_v{nu}") } else { f.to_string() };
                    let pc = PipelineConfig::weight_only(&name);
                    eval_cell(session, &cfg, &ckpt, &corpus, Some(&pc), &suite, Metrics::LambWiki)?
                }
            };
            per_model[ri].push((cell.wiki_ppl, cell.lamb));
            let _ = mi;
        }
    }
    for (ri, (fmt, nu)) in rows_spec.iter().enumerate() {
        let mut row = vec![fmt.to_string(), nu.to_string()];
        for &(ppl, acc) in &per_model[ri] {
            row.push(fnum(ppl, 2));
            row.push(fnum(acc * 100.0, 2));
        }
        table.row(row);
        let _ = &cells;
    }
    Ok(table)
}
