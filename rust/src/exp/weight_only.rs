//! Table 3 / Table 13: weight-only evaluation — every format x model x
//! calibration (None / MSE), LAMBADA-role accuracy + WikiText-role ppl.

use anyhow::Result;

use super::quality::{eval_cell, paper_format_rows, require_ckpt, Metrics};
use super::Scale;
use crate::coordinator::{corpus_for, PipelineConfig, Session};
use crate::quant::Calib;
use crate::report::{fnum, Table};

pub fn run(session: &Session, scale: Scale) -> Result<Table> {
    let models = scale.table_models();
    let suite = scale.suite();
    let mut table = Table::new(
        "Table 3 — Weight-Only Eval (LAMB accuracy / Wiki perplexity)",
        &{
            let mut h = vec!["format"];
            for m in &models {
                h.push(Box::leak(format!("{m}:None").into_boxed_str()));
                h.push(Box::leak(format!("{m}:MSE").into_boxed_str()));
            }
            h
        },
    );

    // fp32 baselines first
    let mut baselines = Vec::new();
    for model in &models {
        let (cfg, ckpt) = require_ckpt(session, model)?;
        let corpus = corpus_for(&cfg);
        let base = eval_cell(session, &cfg, &ckpt, &corpus, None, &suite, Metrics::LambWiki)?;
        baselines.push((cfg, ckpt, corpus, base));
    }
    let mut row = vec!["fp32".to_string()];
    for (_, _, _, base) in &baselines {
        let cell = format!("{}/{}", fnum(base.lamb * 100.0, 2), fnum(base.wiki_ppl, 2));
        row.push(cell.clone());
        row.push(cell);
    }
    table.row(row);

    for fmt in paper_format_rows() {
        let mut row = vec![fmt.to_string()];
        for (cfg, ckpt, corpus, _) in &baselines {
            for calib in [Calib::None, Calib::Mse] {
                let mut pc = PipelineConfig::weight_only(fmt);
                pc.calib = calib;
                let cell =
                    eval_cell(session, cfg, ckpt, corpus, Some(&pc), &suite, Metrics::LambWiki)?;
                row.push(format!(
                    "{}/{}",
                    fnum(cell.lamb * 100.0, 2),
                    fnum(cell.wiki_ppl, 2)
                ));
            }
        }
        table.row(row);
    }
    Ok(table)
}
