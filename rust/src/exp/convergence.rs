//! Figures 4/5/6/7: analytic format studies — SF4(nu) -> NF4 convergence,
//! t-distribution PDF shapes, the datatype gallery and APoT variant space.

use anyhow::Result;

use crate::coordinator::Session;
use crate::formats::{self, enumerate_apot_variants, normal_float, student_float};
use crate::report::{fnum, Table};
use crate::special::student_t;

/// Figure 4: max |SF4(nu) - NF4| as nu grows (convergence curve).
pub fn run_fig4(session: &Session) -> Result<Table> {
    let nf4 = normal_float(4);
    let mut table = Table::new(
        "Figure 4 — SF4(nu) convergence to NF4 (max codebook distance)",
        &["nu", "max|SF4-NF4|", "mean|SF4-NF4|"],
    );
    let mut tsv = String::from("nu\tmax_dist\tmean_dist\n");
    for nu in [1.5, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 10.0, 15.0, 20.0, 50.0, 100.0, 1000.0] {
        let sf = student_float(nu, 4);
        let max: f64 =
            sf.iter().zip(&nf4).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        let mean: f64 =
            sf.iter().zip(&nf4).map(|(a, b)| (a - b).abs()).sum::<f64>() / 16.0;
        table.row(vec![fnum(nu, 1), fnum(max, 4), fnum(mean, 4)]);
        tsv.push_str(&format!("{nu}\t{max:.6}\t{mean:.6}\n"));
    }
    let dir = std::path::Path::new(&session.results_dir);
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join("fig4_convergence.tsv"), tsv)?;

    // Figure 5 data: t-pdf shapes across nu
    let mut tsv5 = String::from("x");
    let nus = [1.0, 2.0, 5.0, 10.0, 100.0];
    for nu in nus {
        tsv5.push_str(&format!("\tnu{nu}"));
    }
    tsv5.push('\n');
    for i in 0..201 {
        let x = -5.0 + 10.0 * i as f64 / 200.0;
        tsv5.push_str(&format!("{x:.3}"));
        for nu in nus {
            tsv5.push_str(&format!("\t{:.6}", student_t::pdf(x, nu)));
        }
        tsv5.push('\n');
    }
    std::fs::write(dir.join("fig5_tpdf.tsv"), tsv5)?;
    Ok(table)
}

/// Figure 6 / Table 15: the full datatype gallery (codebook values).
pub fn run_table15() -> Result<Table> {
    let mut table = Table::new(
        "Table 15 — Quantized datatype values (normalized)",
        &["format", "n", "values"],
    );
    for name in formats::all_names() {
        let s = formats::must(name);
        let values = s
            .codebook
            .iter()
            .map(|v| format!("{v:+.3}"))
            .collect::<Vec<_>>()
            .join(" ");
        table.row(vec![name.to_string(), s.n_values().to_string(), values]);
    }
    Ok(table)
}

/// Figure 7: APoT variant enumeration with distance-to-SF4 (the paper's
/// argument for the 2S(3) choice).
pub fn run_fig7() -> Result<Table> {
    let sf4 = formats::must("sf4");
    let mut table = Table::new(
        "Figure 7 — APoT 4-bit variant space (distance to SF4 reference)",
        &["variant", "n_values", "rms_dist_to_sf4", "is_paper_2S3"],
    );
    let paper = formats::must("apot4");
    let mut rows: Vec<(String, usize, f64, bool)> = Vec::new();
    for v in enumerate_apot_variants() {
        // rms distance between quantization behaviours: compare nearest-value
        // maps over a dense grid (codebooks have different sizes).
        let mut acc = 0.0;
        let n_grid = 401;
        for i in 0..n_grid {
            let x = -1.0 + 2.0 * i as f64 / (n_grid - 1) as f64;
            let qa = nearest(&v.codebook, x);
            let qs = sf4.quantize(x);
            acc += (qa - qs).powi(2);
        }
        let rms = (acc / n_grid as f64).sqrt();
        let is_paper = v.codebook.len() == paper.codebook.len()
            && v.codebook.iter().zip(&paper.codebook).all(|(a, b)| (a - b).abs() < 1e-9);
        rows.push((v.label.clone(), v.codebook.len(), rms, is_paper));
    }
    rows.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
    for (label, n, rms, is_paper) in rows {
        table.row(vec![
            label,
            n.to_string(),
            fnum(rms, 4),
            if is_paper { "YES".into() } else { "".into() },
        ]);
    }
    Ok(table)
}

fn nearest(cb: &[f64], x: f64) -> f64 {
    cb.iter()
        .copied()
        .min_by(|a, b| (a - x).abs().partial_cmp(&(b - x).abs()).unwrap())
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_variant_is_near_the_top_of_fig7() {
        // the 2S(3) variant is chosen for its SF4 proximity; it must rank
        // in the upper half of the enumeration.
        let t = run_fig7().unwrap();
        let pos = t.rows.iter().position(|r| r[3] == "YES").expect("paper row");
        // several near-ties sit within one RMS hair of each other; require
        // the paper variant in the upper ~60% rather than a strict median.
        assert!(pos * 5 <= t.rows.len() * 3, "paper variant ranked {pos}/{}", t.rows.len());
    }

    #[test]
    fn table15_covers_all_formats() {
        let t = run_table15().unwrap();
        assert_eq!(t.rows.len(), formats::all_names().len());
    }
}
