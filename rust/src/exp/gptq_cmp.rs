//! Table 6: RTN vs GPTQ, channelwise vs sub-channel — format quality
//! differences persist under second-order PTQ optimization.

use anyhow::Result;

use super::quality::{eval_cell, paper_format_rows, require_ckpt, Metrics};
use super::Scale;
use crate::coordinator::{corpus_for, PipelineConfig, QuantMethod, Session};
use crate::quant::BlockSize;
use crate::report::{pct, Table};

pub fn run(session: &Session, scale: Scale, model: &str) -> Result<Table> {
    let suite = scale.suite();
    let (cfg, ckpt) = require_ckpt(session, model)?;
    let corpus = corpus_for(&cfg);
    let mut table = Table::new(
        &format!("Table 6 — {model} RTN vs GPTQ (mean D% vs fp32)"),
        &["format", "CW:RTN", "CW:GPTQ", "Sub128:RTN", "Sub128:GPTQ"],
    );
    let base = eval_cell(session, &cfg, &ckpt, &corpus, None, &suite, Metrics::FullSuite)?;
    let cells: Vec<(BlockSize, QuantMethod)> = vec![
        (BlockSize::Channelwise, QuantMethod::Rtn),
        (BlockSize::Channelwise, QuantMethod::Gptq),
        (BlockSize::Sub(128), QuantMethod::Rtn),
        (BlockSize::Sub(128), QuantMethod::Gptq),
    ];
    for fmt in paper_format_rows() {
        let mut row = vec![fmt.to_string()];
        for (block, method) in &cells {
            let mut pc = PipelineConfig::weight_only(fmt);
            pc.block = *block;
            pc.method = *method;
            pc.calib_seqs = match scale {
                Scale::Quick => 4,
                Scale::Full => 8,
            };
            let cell =
                eval_cell(session, &cfg, &ckpt, &corpus, Some(&pc), &suite, Metrics::FullSuite)?;
            row.push(pct(cell.rel_change_pct(&base)));
        }
        table.row(row);
    }
    Ok(table)
}
