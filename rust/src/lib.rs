//! `llm-datatypes` — Rust + JAX + Pallas reproduction of *"Learning from
//! Students: Applying t-Distributions to Explore Accurate and Efficient
//! Formats for LLMs"* (Dotzel et al., ICML 2024).
//!
//! Layer 3 of the three-layer stack: everything that runs at request time is
//! Rust. The JAX/Pallas layers (under `python/`) are build-time only — they
//! author the HLO-text artifacts that [`runtime`] loads through PJRT.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * substrates: [`tensor`], [`rng`], [`special`], [`distfit`]
//! * the paper's contribution: [`formats`] (datatype zoo incl. SF4 and the
//!   supernormal variants), [`quant`] (RTN / MSE-clip / GPTQ / SmoothQuant),
//!   [`hw`] (MAC-unit area/power model)
//! * model plumbing: [`nn`] (pure-Rust reference forward), [`model_io`],
//!   [`data`] (synthetic corpora), [`tasks`] (eval suites)
//! * execution: [`runtime`] (PJRT), [`coordinator`] (experiment scheduler +
//!   serve shim), [`serving`] (continuous-batching decode engine + KV
//!   cache), [`exp`] (one module per paper table/figure), [`report`]
//! * tooling: [`cli`], [`bench_util`], [`obs`] (tracing + metrics:
//!   span timelines, histogram registry, Chrome-trace/Prometheus export),
//!   [`faults`] (deterministic seeded fault injection for chaos testing)

pub mod bench_util;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod distfit;
pub mod exp;
pub mod faults;
pub mod formats;
pub mod hw;
pub mod model_io;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod serving;
pub mod special;
pub mod tasks;
pub mod tensor;

/// Repository-relative default locations, overridable via CLI flags.
pub mod paths {
    /// AOT artifacts directory (HLO text + manifests + codebooks.tsv).
    pub const ARTIFACTS: &str = "artifacts";
    /// Trained checkpoints directory.
    pub const CHECKPOINTS: &str = "checkpoints";
    /// Experiment outputs (tables, figures as TSV).
    pub const RESULTS: &str = "results";
}
