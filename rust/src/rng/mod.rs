//! Deterministic, seedable RNG + sampling distributions.
//!
//! PCG64 (XSL-RR) core with the distributions the repo needs: normal,
//! Student-t, uniform, Zipf and categorical. Every experiment takes an
//! explicit seed so tables are exactly reproducible run-to-run.

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive an independent generator (new stream) — used to hand each
    /// worker thread its own RNG.
    pub fn split(&mut self) -> Pcg64 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg64::with_stream(seed, stream)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal (Box-Muller; one value per call for simplicity).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Student-t with `nu` degrees of freedom: t = Z / sqrt(ChiSq_nu / nu).
    pub fn student_t(&mut self, nu: f64) -> f64 {
        let z = self.normal();
        let chi2 = self.gamma(nu / 2.0, 2.0);
        z / (chi2 / nu).sqrt()
    }

    /// Gamma(shape k, scale theta) via Marsaglia-Tsang (with Johnk boost for
    /// k < 1).
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        if k < 1.0 {
            // boost: Gamma(k) = Gamma(k+1) * U^(1/k)
            let u = self.uniform().max(f64::MIN_POSITIVE);
            return self.gamma(k + 1.0, theta) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v * theta;
            }
        }
    }

    /// Fill a vector of standard normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f64) -> Vec<f32> {
        (0..n).map(|_| (self.normal() * std) as f32).collect()
    }

    /// Fill a vector of Student-t samples scaled by `scale`.
    pub fn student_t_vec(&mut self, n: usize, nu: f64, scale: f64) -> Vec<f32> {
        (0..n).map(|_| (self.student_t(nu) * scale) as f32).collect()
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Shuffle a slice in place (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Precomputed Zipf-like sampler over `n` items with exponent `s`.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        for c in &mut cdf {
            *c /= acc;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.uniform();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Pcg64::new(7);
        for _ in 0..1000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn student_t_heavier_tails_than_normal() {
        let mut rng = Pcg64::new(11);
        let n = 50_000;
        let t3: Vec<f64> = (0..n).map(|_| rng.student_t(3.0)).collect();
        let frac_beyond_3 = t3.iter().filter(|x| x.abs() > 3.0).count() as f64 / n as f64;
        // For N(0,1) P(|x|>3) ~ 0.0027; for t(3) it is ~ 0.029.
        assert!(frac_beyond_3 > 0.015, "{frac_beyond_3}");
        // t(nu) variance = nu/(nu-2) = 3 for nu=3... use nu=5: var 5/3.
        let t5: Vec<f64> = (0..n).map(|_| rng.student_t(5.0)).collect();
        let var = t5.iter().map(|x| x * x).sum::<f64>() / n as f64;
        assert!((var - 5.0 / 3.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn gamma_mean() {
        let mut rng = Pcg64::new(5);
        let n = 30_000;
        for (k, theta) in [(0.5, 2.0), (2.5, 1.0), (7.0, 0.5)] {
            let mean: f64 =
                (0..n).map(|_| rng.gamma(k, theta)).sum::<f64>() / n as f64;
            assert!((mean - k * theta).abs() < 0.08 * (k * theta), "{k} {theta} {mean}");
        }
    }

    #[test]
    fn zipf_is_monotone() {
        let mut rng = Pcg64::new(9);
        let z = Zipf::new(50, 1.1);
        let mut counts = vec![0usize; 50];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[1] > counts[30]);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg64::new(1);
        let mut a = root.split();
        let mut b = root.split();
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
