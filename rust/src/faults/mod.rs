//! Deterministic, seeded fault injection for the serving stack.
//!
//! Mirrors the `obs::trace` cost model: every injection site opens with one
//! relaxed atomic load of the global arm flag ([`enabled`], via [`fire`]) —
//! with injection disarmed that load is the *entire* cost, so sites live
//! safely inside per-token, per-reserve and per-task paths. Armed, each
//! site draws from its **own** seeded PCG stream ([`crate::rng::Pcg64`]
//! split per site), so the decision sequence at one site is independent of
//! how often any other site is queried — a schedule is reproducible from
//! `(seed, per-site rates/limits)` alone.
//!
//! The site registry (what fires where, and what supervises it):
//!
//! | site | fires in | blast radius under supervision |
//! |---|---|---|
//! | `pool_worker_panic` | `runtime::pool` task execution | one scoped dispatch re-panics; workers survive; engine forwards retire the session |
//! | `kv_reserve_fail` | `KvCache::try_reserve` | `slots_mut` panics; the fused forward is caught and rows re-run individually |
//! | `kv_page_spike` | `Engine::step` (pool seizure) | admission backpressure + page-pressure preemption; pages returned after the spike |
//! | `forward_panic` | `Engine::step` per batch row | the flagged session retires as `FinishReason::Failed`; the batch re-runs without it |
//! | `engine_step_panic` | end of `Engine::step` | the engine thread unwinds; `http::serve`'s supervisor restarts the run loop |
//! | `http_client_stall` | bundled client `ChunkStream` reads | server-side write deadline bounds the connection thread |
//! | `http_client_disconnect` | bundled client `ChunkStream` reads | server sees a dead socket mid-write; session retires `Disconnected` |
//! | `clock_skew` | `Engine::step` micro-steps (fake clock only) | the stall watchdog (`SchedulerConfig::step_deadline`) kills the offender |
//! | `host_tier_fail` | `HostTier` spill / restore copies | the engine falls back to preempt-and-recompute; no pages leak on either tier |
//! | `restore_stall` | host-tier page restore | the restore bubble lands in the session's `resume_gap`, not its ITL |
//!
//! Only chaos tests (`tests/chaos.rs`), the `perf_chaos` bench and the
//! `serve-http --fault-*` flags ever [`arm`] this module; unit tests must
//! not, because the flag is process-global and the test harness runs tests
//! concurrently.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use crate::obs::trace;
use crate::rng::Pcg64;

/// Number of named injection sites (indexes [`Site`]).
pub const SITE_COUNT: usize = 10;

/// A named injection site. The discriminant indexes the per-site rate,
/// limit, RNG stream and fired counter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// Panic inside a `runtime::pool` task body.
    PoolWorkerPanic = 0,
    /// `KvCache::try_reserve` reports the pool dry.
    KvReserveFail = 1,
    /// `Engine::step` seizes free KV pages for a few steps.
    KvPageSpike = 2,
    /// One session's row of the fused forward panics.
    ForwardPanic = 3,
    /// `Engine::step` panics after its work (engine-thread supervision).
    EngineStepPanic = 4,
    /// The bundled HTTP client stalls before a read.
    HttpClientStall = 5,
    /// The bundled HTTP client kills its socket mid-stream.
    HttpClientDisconnect = 6,
    /// The engine's fake clock jumps forward mid-micro-step.
    ClockSkew = 7,
    /// A host-tier spill or restore copy fails (simulated allocation /
    /// transfer failure); the engine falls back to preempt-and-recompute.
    HostTierFail = 8,
    /// A host-tier page restore stalls (simulated slow host link).
    RestoreStall = 9,
}

impl Site {
    pub const ALL: [Site; SITE_COUNT] = [
        Site::PoolWorkerPanic,
        Site::KvReserveFail,
        Site::KvPageSpike,
        Site::ForwardPanic,
        Site::EngineStepPanic,
        Site::HttpClientStall,
        Site::HttpClientDisconnect,
        Site::ClockSkew,
        Site::HostTierFail,
        Site::RestoreStall,
    ];

    /// Stable snake_case name (metric suffixes, `--fault-sites` parsing).
    pub fn name(self) -> &'static str {
        match self {
            Site::PoolWorkerPanic => "pool_worker_panic",
            Site::KvReserveFail => "kv_reserve_fail",
            Site::KvPageSpike => "kv_page_spike",
            Site::ForwardPanic => "forward_panic",
            Site::EngineStepPanic => "engine_step_panic",
            Site::HttpClientStall => "http_client_stall",
            Site::HttpClientDisconnect => "http_client_disconnect",
            Site::ClockSkew => "clock_skew",
            Site::HostTierFail => "host_tier_fail",
            Site::RestoreStall => "restore_stall",
        }
    }

    pub fn from_name(name: &str) -> Option<Site> {
        Site::ALL.into_iter().find(|s| s.name() == name)
    }
}

/// A seeded injection schedule: per-site probabilities and fire limits plus
/// the shape parameters the stateful sites need. Built fluently:
/// `FaultPlan::new(42).rate(Site::ForwardPanic, 0.05).limit(Site::ForwardPanic, 3)`.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    rates: [f64; SITE_COUNT],
    limits: [u64; SITE_COUNT],
    /// Free pages a `kv_page_spike` seizes (clamped to what is free).
    pub spike_pages: usize,
    /// Engine steps a seizure lasts before the pages return.
    pub spike_steps: usize,
    /// Fake-clock jump per `clock_skew` fire.
    pub skew: Duration,
    /// Sleep per `http_client_stall` fire.
    pub stall: Duration,
    /// Restrict `pool_worker_panic` to the worker thread named
    /// `llmdt-pool-<i>` (repeated-panic-on-one-worker coverage).
    pub pool_worker: Option<usize>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            rates: [0.0; SITE_COUNT],
            limits: [u64::MAX; SITE_COUNT],
            spike_pages: 4,
            spike_steps: 2,
            skew: Duration::from_millis(50),
            stall: Duration::from_millis(20),
            pool_worker: None,
        }
    }

    /// Probability a query at `site` fires (0.0 = dormant).
    pub fn rate(mut self, site: Site, p: f64) -> FaultPlan {
        self.rates[site as usize] = p.clamp(0.0, 1.0);
        self
    }

    /// Cap total fires at `site` for the armed window.
    pub fn limit(mut self, site: Site, n: u64) -> FaultPlan {
        self.limits[site as usize] = n;
        self
    }

    /// Fire exactly once, on the first query: `rate(1.0).limit(1)`.
    pub fn one_shot(self, site: Site) -> FaultPlan {
        self.rate(site, 1.0).limit(site, 1)
    }

    pub fn spike(mut self, pages: usize, steps: usize) -> FaultPlan {
        self.spike_pages = pages;
        self.spike_steps = steps;
        self
    }

    pub fn skew(mut self, d: Duration) -> FaultPlan {
        self.skew = d;
        self
    }

    pub fn stall(mut self, d: Duration) -> FaultPlan {
        self.stall = d;
        self
    }

    pub fn pool_worker(mut self, worker: usize) -> FaultPlan {
        self.pool_worker = Some(worker);
        self
    }
}

struct Armed {
    plan: FaultPlan,
    /// One independent PCG stream per site: the draw sequence at a site
    /// depends only on (seed, site, query count at that site).
    rngs: [Pcg64; SITE_COUNT],
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static ARMED: Mutex<Option<Armed>> = Mutex::new(None);
static FIRED: [AtomicU64; SITE_COUNT] = [const { AtomicU64::new(0) }; SITE_COUNT];

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // injected faults panic on purpose; never let that poison the plan
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Is injection armed? One relaxed load — the whole disarmed-path cost.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Arm `plan`: reset all fired counters, seed the per-site streams, raise
/// the flag. Process-global — serialize callers (chaos tests hold a lock).
pub fn arm(plan: FaultPlan) {
    let rngs = std::array::from_fn(|i| Pcg64::with_stream(plan.seed, i as u64));
    for c in &FIRED {
        c.store(0, Ordering::SeqCst);
    }
    *lock(&ARMED) = Some(Armed { plan, rngs });
    ENABLED.store(true, Ordering::SeqCst);
}

/// Lower the flag and drop the plan. Fired counters survive so a drained
/// run can still be audited ([`injected`] / [`counters`]).
pub fn disarm() {
    ENABLED.store(false, Ordering::SeqCst);
    *lock(&ARMED) = None;
}

/// Should this site fire now? Consumes one draw from the site's stream
/// when the site is armed with a positive rate. Disarmed, this is the one
/// relaxed atomic load.
#[inline]
pub fn fire(site: Site) -> bool {
    if !enabled() {
        return false;
    }
    fire_slow(site)
}

#[cold]
fn fire_slow(site: Site) -> bool {
    let i = site as usize;
    let fired = {
        let mut g = lock(&ARMED);
        let armed = match g.as_mut() {
            Some(a) => a,
            None => return false,
        };
        if armed.plan.rates[i] <= 0.0 {
            return false;
        }
        if site == Site::PoolWorkerPanic {
            if let Some(w) = armed.plan.pool_worker {
                let want = format!("llmdt-pool-{w}");
                if std::thread::current().name() != Some(want.as_str()) {
                    return false;
                }
            }
        }
        if FIRED[i].load(Ordering::SeqCst) >= armed.plan.limits[i] {
            return false;
        }
        armed.rngs[i].uniform() < armed.plan.rates[i]
    };
    if fired {
        FIRED[i].fetch_add(1, Ordering::SeqCst);
        trace::instant(trace::current_track(), "fault", site.name(), &[]);
    }
    fired
}

/// Fires at `site` since the last [`arm`].
pub fn injected(site: Site) -> u64 {
    FIRED[site as usize].load(Ordering::SeqCst)
}

/// Total fires across every site since the last [`arm`].
pub fn injected_total() -> u64 {
    FIRED.iter().map(|c| c.load(Ordering::SeqCst)).sum()
}

/// `(site name, fires)` for every site — the `llmdt_faults_*` series.
pub fn counters() -> [(&'static str, u64); SITE_COUNT] {
    std::array::from_fn(|i| (Site::ALL[i].name(), FIRED[i].load(Ordering::SeqCst)))
}

/// `kv_page_spike` shape from the armed plan: `(pages, steps)`.
pub fn spike_shape() -> (usize, usize) {
    lock(&ARMED).as_ref().map(|a| (a.plan.spike_pages, a.plan.spike_steps)).unwrap_or((0, 0))
}

/// `clock_skew` jump from the armed plan.
pub fn skew() -> Duration {
    lock(&ARMED).as_ref().map(|a| a.plan.skew).unwrap_or(Duration::ZERO)
}

/// `http_client_stall` sleep from the armed plan.
pub fn stall() -> Duration {
    lock(&ARMED).as_ref().map(|a| a.plan.stall).unwrap_or(Duration::ZERO)
}

/// Marker every injected panic message carries, so supervisors and panic
/// hooks can tell scheduled chaos from genuine bugs.
pub const PANIC_MARK: &str = "fault-injected";

/// Install (once) a panic hook that swallows the default report for
/// injected panics — chaos runs fire hundreds and each would otherwise
/// print a backtrace banner. Genuine panics still report through the
/// previous hook.
pub fn silence_injected_panics() {
    static INSTALLED: OnceLock<()> = OnceLock::new();
    INSTALLED.get_or_init(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| s.contains(PANIC_MARK))
                .or_else(|| {
                    info.payload().downcast_ref::<String>().map(|s| s.contains(PANIC_MARK))
                })
                .unwrap_or(false);
            if !injected {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests arm the process-global plan; they serialize on this and
    // use sites nothing else in the lib test binary queries while armed
    // (no engine/pool/http activity happens here).
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disarmed_sites_never_fire_and_cost_only_the_flag_check() {
        let _g = lock(&LOCK);
        disarm();
        for site in Site::ALL {
            assert!(!fire(site));
        }
    }

    #[test]
    fn one_shot_fires_exactly_once_deterministically() {
        let _g = lock(&LOCK);
        arm(FaultPlan::new(7).one_shot(Site::ClockSkew));
        assert!(fire(Site::ClockSkew), "rate 1.0 must fire on the first query");
        for _ in 0..10 {
            assert!(!fire(Site::ClockSkew), "limit 1 caps the schedule");
        }
        assert_eq!(injected(Site::ClockSkew), 1);
        assert!(!fire(Site::HttpClientStall), "unconfigured sites stay dormant");
        disarm();
        assert_eq!(injected(Site::ClockSkew), 1, "counters survive disarm");
    }

    #[test]
    fn seeded_schedules_are_reproducible_per_site() {
        let _g = lock(&LOCK);
        let run = || {
            arm(FaultPlan::new(99)
                .rate(Site::HttpClientStall, 0.3)
                .rate(Site::HttpClientDisconnect, 0.7));
            let a: Vec<bool> = (0..64).map(|_| fire(Site::HttpClientStall)).collect();
            let b: Vec<bool> = (0..64).map(|_| fire(Site::HttpClientDisconnect)).collect();
            disarm();
            (a, b)
        };
        let first = run();
        let second = run();
        assert_eq!(first, second, "same seed, same per-site decision sequence");
        assert!(first.0.iter().any(|&f| f), "rate 0.3 over 64 draws fires");
        assert!(first.0.iter().any(|&f| !f), "rate 0.3 over 64 draws also skips");
    }

    #[test]
    fn per_site_streams_are_independent_of_interleaving() {
        let _g = lock(&LOCK);
        arm(FaultPlan::new(5).rate(Site::HttpClientStall, 0.5));
        let solo: Vec<bool> = (0..32).map(|_| fire(Site::HttpClientStall)).collect();
        disarm();
        arm(FaultPlan::new(5)
            .rate(Site::HttpClientStall, 0.5)
            .rate(Site::HttpClientDisconnect, 0.5));
        let interleaved: Vec<bool> = (0..32)
            .map(|_| {
                fire(Site::HttpClientDisconnect);
                fire(Site::HttpClientStall)
            })
            .collect();
        disarm();
        assert_eq!(solo, interleaved, "another site's draws must not perturb this site");
    }

    #[test]
    fn names_round_trip() {
        for site in Site::ALL {
            assert_eq!(Site::from_name(site.name()), Some(site));
        }
        assert_eq!(Site::from_name("nope"), None);
    }
}
