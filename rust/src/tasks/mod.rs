//! Evaluation task suite — synthetic analogues of the paper's benchmarks.
//!
//! * `LAMB`  — last-token completion accuracy (LAMBADA role): the most
//!   perturbation-sensitive metric, exactly as the paper argues.
//! * `Wiki`  — held-out perplexity (WikiText-2 role).
//! * `Hella` / `Wino` / `PIQA` / `BoolQ` / `ARC-c` roles — multiple-choice
//!   items scored by length-normalized option log-probability; corruptions
//!   differ per task so difficulty and "maskedness" vary like the originals.
//!
//! Everything evaluates through the [`LmScorer`] trait so the same code runs
//! against the XLA executables (request path) and the pure-Rust reference
//! model (tests).

use anyhow::Result;

use crate::data::Corpus;
use crate::rng::Pcg64;
use crate::tensor::Tensor;

/// Batched logits/NLL provider. `B`-sized batches of `S`-token sequences.
pub trait LmScorer {
    fn batch(&self) -> usize;
    fn seq(&self) -> usize;
    fn vocab(&self) -> usize;
    /// tokens `[B*S]` -> logits `[B*S, V]`.
    fn logits(&mut self, tokens: &[i32]) -> Result<Tensor>;
    /// tokens `[B*(S+1)]` -> (summed next-token NLL, token count).
    fn nll_sum(&mut self, tokens: &[i32]) -> Result<(f64, f64)> {
        let (b, s, v) = (self.batch(), self.seq(), self.vocab());
        let mut inputs = Vec::with_capacity(b * s);
        for r in 0..b {
            inputs.extend_from_slice(&tokens[r * (s + 1)..r * (s + 1) + s]);
        }
        let logits = self.logits(&inputs)?;
        let logp = logits.log_softmax_last();
        let mut total = 0.0f64;
        for r in 0..b {
            for i in 0..s {
                let tgt = tokens[r * (s + 1) + i + 1] as usize;
                total -= logp.at2(r * s + i, tgt.min(v - 1)) as f64;
            }
        }
        Ok((total, (b * s) as f64))
    }
}

// ---------------------------------------------------------------------------
// Completion accuracy (LAMB role)
// ---------------------------------------------------------------------------

/// Fraction of windows whose final token is argmax-predicted from the prefix.
pub fn completion_accuracy(scorer: &mut dyn LmScorer, windows: &[Vec<i32>]) -> Result<f64> {
    let (b, s) = (scorer.batch(), scorer.seq());
    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in windows.chunks(b) {
        let mut tokens = vec![0i32; b * s];
        for (r, w) in chunk.iter().enumerate() {
            assert!(w.len() >= s + 1, "window too short");
            tokens[r * s..(r + 1) * s].copy_from_slice(&w[..s]);
        }
        let logits = scorer.logits(&tokens)?;
        for (r, w) in chunk.iter().enumerate() {
            let row = logits.row(r * s + s - 1);
            if crate::tensor::argmax(row) == w[s] as usize {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Held-out perplexity (Wiki role): exp(mean NLL).
pub fn perplexity(scorer: &mut dyn LmScorer, windows: &[Vec<i32>]) -> Result<f64> {
    let (b, s) = (scorer.batch(), scorer.seq());
    let mut nll = 0.0f64;
    let mut count = 0.0f64;
    for chunk in windows.chunks(b) {
        if chunk.len() < b {
            break; // fixed-shape artifact: drop ragged tail
        }
        let mut tokens = Vec::with_capacity(b * (s + 1));
        for w in chunk {
            tokens.extend_from_slice(&w[..s + 1]);
        }
        let (tn, tc) = scorer.nll_sum(&tokens)?;
        nll += tn;
        count += tc;
    }
    Ok((nll / count.max(1.0)).exp())
}

// ---------------------------------------------------------------------------
// Multiple-choice tasks
// ---------------------------------------------------------------------------

/// The multiple-choice task roles of the paper's zero-shot suite.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum McTask {
    Hella, // 4-way, distractors = continuations from elsewhere
    Wino,  // 2-way, distractor = true continuation with a local swap
    Piqa,  // 2-way, distractor = re-sampled from the language model
    Boolq, // 2-way, short continuation, harder cut
    ArcC,  // 4-way, distractors include reversed + resampled
}

impl McTask {
    pub fn label(&self) -> &'static str {
        match self {
            McTask::Hella => "Hella",
            McTask::Wino => "Wino",
            McTask::Piqa => "PIQA",
            McTask::Boolq => "BoolQ",
            McTask::ArcC => "ARC-c",
        }
    }

    pub fn n_options(&self) -> usize {
        match self {
            McTask::Hella | McTask::ArcC => 4,
            _ => 2,
        }
    }

    fn option_len(&self) -> usize {
        match self {
            McTask::Hella => 8,
            McTask::Wino => 4,
            McTask::Piqa => 6,
            McTask::Boolq => 2,
            McTask::ArcC => 6,
        }
    }

    pub const ALL: [McTask; 5] =
        [McTask::Hella, McTask::Wino, McTask::Piqa, McTask::Boolq, McTask::ArcC];
}

/// One multiple-choice item.
#[derive(Clone, Debug)]
pub struct McItem {
    pub context: Vec<i32>,
    pub options: Vec<Vec<i32>>,
    pub correct: usize,
}

/// Generate `n` items for a task from the corpus held-out stream.
pub fn gen_mc_items(
    corpus: &Corpus,
    task: McTask,
    n: usize,
    context_len: usize,
    seed: u64,
) -> Vec<McItem> {
    let mut rng = Pcg64::with_stream(seed, task as u64 + 0x40);
    let olen = task.option_len();
    let held = &corpus.heldout;
    let span = context_len + olen;
    let mut items = Vec::with_capacity(n);
    for _ in 0..n {
        let start = rng.below(held.len() - span - 1);
        let context = held[start..start + context_len].to_vec();
        let truth = held[start + context_len..start + span].to_vec();
        let mut options = vec![truth.clone()];
        while options.len() < task.n_options() {
            let opt = match task {
                McTask::Hella => {
                    // continuation stolen from elsewhere in the corpus
                    let s2 = rng.below(held.len() - olen - 1);
                    held[s2..s2 + olen].to_vec()
                }
                McTask::Wino => {
                    // local swap of two tokens in the true continuation
                    let mut o = truth.clone();
                    let i = rng.below(olen - 1);
                    o.swap(i, i + 1);
                    o
                }
                McTask::Piqa | McTask::Boolq => {
                    // token-level corruption: resample half the positions
                    let mut o = truth.clone();
                    for v in o.iter_mut() {
                        if rng.uniform() < 0.5 {
                            *v = rng.below(corpus.vocab) as i32;
                        }
                    }
                    o
                }
                McTask::ArcC => {
                    if options.len() == 1 {
                        let mut o = truth.clone();
                        o.reverse();
                        o
                    } else {
                        let s2 = rng.below(held.len() - olen - 1);
                        held[s2..s2 + olen].to_vec()
                    }
                }
            };
            if opt != truth {
                options.push(opt);
            }
        }
        // shuffle option order, remember the truth's slot
        let mut order: Vec<usize> = (0..options.len()).collect();
        rng.shuffle(&mut order);
        let correct = order.iter().position(|&i| i == 0).unwrap();
        let options = order.into_iter().map(|i| options[i].clone()).collect();
        items.push(McItem { context, options, correct });
    }
    items
}

/// Score items: an item is correct when the true option has the highest
/// length-normalized log-probability under the model.
pub fn mc_accuracy(scorer: &mut dyn LmScorer, items: &[McItem]) -> Result<f64> {
    let (b, s) = (scorer.batch(), scorer.seq());
    // flatten (item, option) pairs into fixed-size batches
    struct Slot {
        item: usize,
        option: usize,
        ctx_len: usize,
        opt_len: usize,
    }
    let mut seqs: Vec<(Vec<i32>, Slot)> = Vec::new();
    for (ii, item) in items.iter().enumerate() {
        for (oi, opt) in item.options.iter().enumerate() {
            let mut t = item.context.clone();
            t.extend_from_slice(opt);
            assert!(t.len() <= s, "item longer than artifact seq");
            let slot = Slot {
                item: ii,
                option: oi,
                ctx_len: item.context.len(),
                opt_len: opt.len(),
            };
            t.resize(s, 0);
            seqs.push((t, slot));
        }
    }
    let mut scores: Vec<Vec<f64>> =
        items.iter().map(|it| vec![f64::NEG_INFINITY; it.options.len()]).collect();
    for chunk in seqs.chunks(b) {
        let mut tokens = vec![0i32; b * s];
        for (r, (t, _)) in chunk.iter().enumerate() {
            tokens[r * s..(r + 1) * s].copy_from_slice(t);
        }
        let logits = scorer.logits(&tokens)?;
        let logp = logits.log_softmax_last();
        for (r, (t, slot)) in chunk.iter().enumerate() {
            let mut lp = 0.0f64;
            for i in 0..slot.opt_len {
                let pos = slot.ctx_len + i; // token at `pos` predicted at pos-1
                lp += logp.at2(r * s + pos - 1, t[pos] as usize) as f64;
            }
            scores[slot.item][slot.option] = lp / slot.opt_len as f64;
        }
    }
    let mut correct = 0usize;
    for (item, sc) in items.iter().zip(&scores) {
        let best = sc
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if best == item.correct {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len().max(1) as f64)
}

/// A full evaluation across the paper's task suite.
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub lamb: f64,
    pub wiki_ppl: f64,
    pub mc: Vec<(McTask, f64)>,
}

impl SuiteResult {
    /// All accuracy metrics (LAMB + MC tasks), in table order.
    pub fn accuracies(&self) -> Vec<f64> {
        let mut v = vec![self.lamb];
        v.extend(self.mc.iter().map(|(_, a)| *a));
        v
    }

    /// Mean relative accuracy change vs a baseline (the paper's Delta%).
    pub fn rel_change_pct(&self, base: &SuiteResult) -> f64 {
        let a = self.accuracies();
        let b = base.accuracies();
        let mut acc = 0.0f64;
        let mut n = 0.0f64;
        for (x, y) in a.iter().zip(&b) {
            if *y > 0.0 {
                acc += (x - y) / y * 100.0;
                n += 1.0;
            }
        }
        acc / n.max(1.0)
    }
}

/// Evaluation workload sizes (scaled by `quick` for tests/benches).
#[derive(Clone, Copy, Debug)]
pub struct SuiteConfig {
    pub n_completion: usize,
    pub n_ppl_windows: usize,
    pub n_mc_items: usize,
    pub mc_context: usize,
    pub seed: u64,
}

impl SuiteConfig {
    pub fn standard() -> Self {
        SuiteConfig { n_completion: 128, n_ppl_windows: 32, n_mc_items: 48, mc_context: 16, seed: 1234 }
    }

    pub fn quick() -> Self {
        SuiteConfig { n_completion: 32, n_ppl_windows: 8, n_mc_items: 12, mc_context: 8, seed: 1234 }
    }
}

/// Run the whole suite against one scorer.
pub fn run_suite(
    scorer: &mut dyn LmScorer,
    corpus: &Corpus,
    cfg: &SuiteConfig,
) -> Result<SuiteResult> {
    let s = scorer.seq();
    let windows = corpus.heldout_windows(cfg.n_completion.max(cfg.n_ppl_windows), s);
    let lamb = completion_accuracy(scorer, &windows[..cfg.n_completion.min(windows.len())])?;
    let wiki = perplexity(scorer, &windows[..cfg.n_ppl_windows.min(windows.len())])?;
    let mut mc = Vec::new();
    for task in McTask::ALL {
        let items = gen_mc_items(corpus, task, cfg.n_mc_items, cfg.mc_context, cfg.seed);
        mc.push((task, mc_accuracy(scorer, &items)?));
    }
    Ok(SuiteResult { lamb, wiki_ppl: wiki, mc })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Language;

    /// A scorer that knows the corpus bigram table — near-oracle.
    struct OracleScorer {
        b: usize,
        s: usize,
        v: usize,
        bigram: Vec<f32>, // [v, v] log-probs
    }

    impl OracleScorer {
        fn new(corpus: &Corpus, v: usize, b: usize, s: usize) -> Self {
            let mut counts = vec![1.0f32; v * v];
            for w in corpus.train.windows(2) {
                counts[w[0] as usize * v + w[1] as usize] += 1.0;
            }
            OracleScorer { b, s, v, bigram: counts }
        }
    }

    impl LmScorer for OracleScorer {
        fn batch(&self) -> usize {
            self.b
        }
        fn seq(&self) -> usize {
            self.s
        }
        fn vocab(&self) -> usize {
            self.v
        }
        fn logits(&mut self, tokens: &[i32]) -> Result<Tensor> {
            let mut out = Tensor::zeros(&[self.b * self.s, self.v]);
            for r in 0..self.b {
                for i in 0..self.s {
                    let prev = tokens[r * self.s + i] as usize;
                    let row = out.row_mut(r * self.s + i);
                    for j in 0..self.v {
                        row[j] = self.bigram[prev * self.v + j].ln();
                    }
                }
            }
            Ok(out)
        }
    }

    /// Uniform-random scorer: the chance-level baseline.
    struct RandomScorer {
        b: usize,
        s: usize,
        v: usize,
        rng: Pcg64,
    }

    impl LmScorer for RandomScorer {
        fn batch(&self) -> usize {
            self.b
        }
        fn seq(&self) -> usize {
            self.s
        }
        fn vocab(&self) -> usize {
            self.v
        }
        fn logits(&mut self, _tokens: &[i32]) -> Result<Tensor> {
            let n = self.b * self.s * self.v;
            let data = (0..n).map(|_| self.rng.normal() as f32 * 0.01).collect();
            Ok(Tensor::new(&[self.b * self.s, self.v], data))
        }
    }

    fn corpus() -> Corpus {
        let lang = Language::default_for(64, 5);
        Corpus::build(&lang, 60_000, 20_000, 17)
    }

    #[test]
    fn oracle_beats_random_on_completion() {
        let c = corpus();
        let windows = c.heldout_windows(64, 16);
        let mut oracle = OracleScorer::new(&c, 64, 8, 16);
        let mut random = RandomScorer { b: 8, s: 16, v: 64, rng: Pcg64::new(1) };
        let a_o = completion_accuracy(&mut oracle, &windows).unwrap();
        let a_r = completion_accuracy(&mut random, &windows).unwrap();
        assert!(a_o > a_r + 0.1, "oracle {a_o} vs random {a_r}");
        assert!(a_r < 0.2);
    }

    #[test]
    fn perplexity_ordering() {
        let c = corpus();
        let windows = c.heldout_windows(32, 16);
        let mut oracle = OracleScorer::new(&c, 64, 8, 16);
        let mut random = RandomScorer { b: 8, s: 16, v: 64, rng: Pcg64::new(2) };
        let p_o = perplexity(&mut oracle, &windows).unwrap();
        let p_r = perplexity(&mut random, &windows).unwrap();
        assert!(p_o < p_r, "oracle ppl {p_o} vs random {p_r}");
        assert!(p_o < 64.0); // better than uniform over vocab
        assert!((p_r - 64.0).abs() < 8.0); // random ~ uniform
    }

    #[test]
    fn mc_tasks_oracle_above_chance() {
        let c = corpus();
        let mut oracle = OracleScorer::new(&c, 64, 8, 32);
        for task in McTask::ALL {
            let items = gen_mc_items(&c, task, 64, 12, 3);
            let acc = mc_accuracy(&mut oracle, &items).unwrap();
            let chance = 1.0 / task.n_options() as f64;
            assert!(
                acc > chance,
                "{}: oracle {acc} should beat chance {chance}",
                task.label()
            );
        }
    }

    #[test]
    fn mc_items_shapes() {
        let c = corpus();
        for task in McTask::ALL {
            let items = gen_mc_items(&c, task, 16, 10, 4);
            assert_eq!(items.len(), 16);
            for it in &items {
                assert_eq!(it.context.len(), 10);
                assert_eq!(it.options.len(), task.n_options());
                assert!(it.correct < it.options.len());
                let olen = it.options[0].len();
                assert!(it.options.iter().all(|o| o.len() == olen));
            }
        }
    }

    #[test]
    fn mc_item_generation_deterministic() {
        let c = corpus();
        let a = gen_mc_items(&c, McTask::Hella, 8, 10, 9);
        let b = gen_mc_items(&c, McTask::Hella, 8, 10, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.correct, y.correct);
        }
    }

    #[test]
    fn suite_runs_end_to_end() {
        let c = corpus();
        let mut oracle = OracleScorer::new(&c, 64, 8, 32);
        let r = run_suite(&mut oracle, &c, &SuiteConfig::quick()).unwrap();
        assert!(r.lamb > 0.0 && r.lamb <= 1.0);
        assert!(r.wiki_ppl > 1.0);
        assert_eq!(r.mc.len(), 5);
        // relative change vs itself is zero
        assert!(r.rel_change_pct(&r).abs() < 1e-9);
    }
}
