//! Dense row-major f32 tensors — the numeric substrate for the quantization
//! engine, the pure-Rust reference model and the fitting code.
//!
//! Deliberately small: shapes, views, matmul, reductions. The heavy math on
//! the request path runs inside the XLA executables; this type backs
//! calibration, quantization and statistics, so clarity beats generality.
//! (The offline vendor set has no ndarray; this module is the substitute.)

mod linalg;

pub use linalg::{cholesky, cholesky_solve, invert_spd};

use std::fmt;

/// A dense row-major tensor of `f32`.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} incompatible with {} elements",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(|i| f(i)).collect() }
    }

    // -- accessors ---------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows / columns for a rank-2 tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.ndim(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.ndim(), 2);
        self.shape[1]
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.shape[1] + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    // -- elementwise -------------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Self {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn add(&self, other: &Tensor) -> Self {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Self {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Self {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Self {
        self.map(|x| x * s)
    }

    // -- reductions --------------------------------------------------------

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        self.sum() / self.data.len().max(1) as f64
    }

    pub fn min(&self) -> f32 {
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        let mu = self.mean();
        self.data.iter().map(|&x| (x as f64 - mu).powi(2)).sum::<f64>()
            / self.data.len().max(1) as f64
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Sum of squared differences against `other` (reconstruction error).
    pub fn sq_err(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum()
    }

    // -- linear algebra ----------------------------------------------------

    /// `self [M,K] @ other [K,N] -> [M,N]`; thin wrapper over [`gemm`].
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (other.rows(), other.cols());
        assert_eq!(k, k2, "matmul shape mismatch {:?} x {:?}", self.shape, other.shape);
        let mut out = vec![0.0f32; m * n];
        gemm(m, k, n, &self.data, &other.data, &mut out);
        Tensor::new(&[m, n], out)
    }

    /// `self [M,K] @ other^T` where `other` is `[N,K]`.
    pub fn matmul_t(&self, other: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (n, k2) = (other.rows(), other.cols());
        assert_eq!(k, k2);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let b_row = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += a_row[kk] * b_row[kk];
                }
                out[i * n + j] = acc;
            }
        }
        Tensor::new(&[m, n], out)
    }

    pub fn transpose2(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(&[n, m], out)
    }

    /// Softmax along the last axis.
    pub fn softmax_last(&self) -> Tensor {
        let n = *self.shape.last().expect("softmax on scalar");
        let mut out = self.clone();
        for chunk in out.data.chunks_mut(n) {
            let mx = chunk.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for x in chunk.iter_mut() {
                *x = (*x - mx).exp();
                z += *x;
            }
            for x in chunk.iter_mut() {
                *x /= z;
            }
        }
        out
    }

    /// Log-softmax along the last axis (numerically stable).
    pub fn log_softmax_last(&self) -> Tensor {
        let n = *self.shape.last().expect("log_softmax on scalar");
        let mut out = self.clone();
        for chunk in out.data.chunks_mut(n) {
            let mx = chunk.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = chunk.iter().map(|&x| (x - mx).exp()).sum();
            let lz = z.ln() + mx;
            for x in chunk.iter_mut() {
                *x -= lz;
            }
        }
        out
    }
}

/// Row-major GEMM kernel: accumulate `a [m,k] @ b [k,n]` into `out [m,n]`
/// (caller provides a zeroed — or pre-accumulated — `out`).
///
/// This is the crate's one matmul inner loop: `Tensor::matmul` and the fused
/// batched decode step (`nn::forward_lm_step_batch`) both go through it, so a
/// `[B, d]` batch of rows is arithmetically identical, row for row, to `B`
/// separate `[1, d]` calls. ikj loop order streams `b` rows once per `a` row
/// and keeps the j loop a contiguous zip over slices — the shape a future
/// SIMD pass autovectorizes.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "gemm: lhs is not [{m}, {k}]");
    assert_eq!(b.len(), k * n, "gemm: rhs is not [{k}, {n}]");
    assert_eq!(out.len(), m * n, "gemm: out is not [{m}, {n}]");
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let o_row = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in a_row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let b_row = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in o_row.iter_mut().zip(b_row) {
                *o += av * bv;
            }
        }
    }
}

/// Argmax of a slice (first maximum wins).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn gemm_batched_rows_bit_identical_to_single_rows() {
        // the fused-decode contract: one [B,K] GEMM == B separate [1,K] GEMMs
        let a = Tensor::from_fn(&[5, 16], |i| ((i * 37 % 23) as f32 - 11.0) * 0.125);
        let b = Tensor::from_fn(&[16, 9], |i| ((i * 11 % 19) as f32 - 9.0) * 0.25);
        let fused = a.matmul(&b);
        for i in 0..5 {
            let row = Tensor::new(&[1, 16], a.row(i).to_vec());
            let single = row.matmul(&b);
            assert_eq!(fused.row(i), single.row(0), "row {i} differs bitwise");
        }
    }

    #[test]
    fn gemm_accumulates_into_out() {
        let a = Tensor::new(&[1, 2], vec![1.0, 2.0]);
        let b = Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let mut out = vec![10.0f32, 20.0];
        gemm(1, 2, 2, a.data(), b.data(), &mut out);
        assert_eq!(out, vec![11.0, 22.0]);
    }

    #[test]
    fn matmul_t_agrees() {
        let a = Tensor::from_fn(&[3, 4], |i| i as f32 * 0.3 - 1.0);
        let b = Tensor::from_fn(&[4, 5], |i| (i as f32).sin());
        let c1 = a.matmul(&b);
        let c2 = a.matmul_t(&b.transpose2());
        for (x, y) in c1.data().iter().zip(c2.data()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Tensor::from_fn(&[3, 7], |i| i as f32);
        assert_eq!(a.transpose2().transpose2(), a);
    }

    #[test]
    fn softmax_sums_to_one() {
        let a = Tensor::from_fn(&[4, 8], |i| (i as f32 * 0.7).cos() * 5.0);
        let s = a.softmax_last();
        for row in 0..4 {
            let sum: f32 = s.row(row).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn log_softmax_matches_softmax() {
        let a = Tensor::from_fn(&[2, 5], |i| i as f32 * 0.3);
        let s = a.softmax_last();
        let ls = a.log_softmax_last();
        for (p, lp) in s.data().iter().zip(ls.data()) {
            assert!((p.ln() - lp).abs() < 1e-5);
        }
    }

    #[test]
    fn reductions() {
        let a = Tensor::new(&[4], vec![1.0, -3.0, 2.0, 0.0]);
        assert_eq!(a.sum(), 0.0);
        assert_eq!(a.abs_max(), 3.0);
        assert_eq!(a.min(), -3.0);
        assert_eq!(a.max(), 2.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        a.matmul(&b); // 3 != 2
    }
}
