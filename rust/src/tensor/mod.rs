//! Dense row-major f32 tensors — the numeric substrate for the quantization
//! engine, the pure-Rust reference model and the fitting code.
//!
//! Deliberately small: shapes, views, matmul, reductions. The heavy math on
//! the request path runs inside the XLA executables; this type backs
//! calibration, quantization and statistics, so clarity beats generality.
//! (The offline vendor set has no ndarray; this module is the substitute.)

mod linalg;
pub mod simd;

pub use linalg::{cholesky, cholesky_solve, invert_spd};

use std::fmt;

/// A dense row-major tensor of `f32`.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} incompatible with {} elements",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(|i| f(i)).collect() }
    }

    // -- accessors ---------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows / columns for a rank-2 tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.ndim(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.ndim(), 2);
        self.shape[1]
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.shape[1] + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    // -- elementwise -------------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Self {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn add(&self, other: &Tensor) -> Self {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Self {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Self {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Self {
        self.map(|x| x * s)
    }

    // -- reductions --------------------------------------------------------

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        self.sum() / self.data.len().max(1) as f64
    }

    pub fn min(&self) -> f32 {
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        let mu = self.mean();
        self.data.iter().map(|&x| (x as f64 - mu).powi(2)).sum::<f64>()
            / self.data.len().max(1) as f64
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Sum of squared differences against `other` (reconstruction error).
    pub fn sq_err(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum()
    }

    // -- linear algebra ----------------------------------------------------

    /// `self [M,K] @ other [K,N] -> [M,N]`; thin wrapper over [`gemm`].
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (other.rows(), other.cols());
        assert_eq!(k, k2, "matmul shape mismatch {:?} x {:?}", self.shape, other.shape);
        let mut out = vec![0.0f32; m * n];
        gemm(m, k, n, &self.data, &other.data, &mut out);
        Tensor::new(&[m, n], out)
    }

    /// `self [M,K] @ other^T` where `other` is `[N,K]`. Routed through the
    /// same blocked [`gemm`] kernel as [`Tensor::matmul`] (transpose once,
    /// then multiply) — the transpose cost is O(KN) against the O(MKN)
    /// multiply it unlocks, and both products share one fast path.
    pub fn matmul_t(&self, other: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (n, k2) = (other.rows(), other.cols());
        assert_eq!(k, k2, "matmul_t shape mismatch {:?} x {:?}", self.shape, other.shape);
        let bt = other.transpose2();
        let mut out = vec![0.0f32; m * n];
        gemm(m, k, n, &self.data, bt.data(), &mut out);
        Tensor::new(&[m, n], out)
    }

    pub fn transpose2(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(&[n, m], out)
    }

    /// Softmax along the last axis.
    pub fn softmax_last(&self) -> Tensor {
        let n = *self.shape.last().expect("softmax on scalar");
        let mut out = self.clone();
        for chunk in out.data.chunks_mut(n) {
            let mx = chunk.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for x in chunk.iter_mut() {
                *x = (*x - mx).exp();
                z += *x;
            }
            for x in chunk.iter_mut() {
                *x /= z;
            }
        }
        out
    }

    /// Log-softmax along the last axis (numerically stable).
    pub fn log_softmax_last(&self) -> Tensor {
        let n = *self.shape.last().expect("log_softmax on scalar");
        let mut out = self.clone();
        for chunk in out.data.chunks_mut(n) {
            let mx = chunk.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = chunk.iter().map(|&x| (x - mx).exp()).sum();
            let lz = z.ln() + mx;
            for x in chunk.iter_mut() {
                *x -= lz;
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Blocked GEMM kernel
// ---------------------------------------------------------------------------

/// Rows per register micro-tile.
pub const GEMM_MR: usize = 4;
/// Accumulator columns per register micro-tile (fits two AVX2 lanes of
/// independent scalar chains; LLVM autovectorizes the `t` loops below).
pub const GEMM_NR: usize = 16;
/// K-block length: one `[KC, NR]` panel of `b` stays cache-resident while
/// every row tile streams over it.
pub const GEMM_KC: usize = 256;
/// Below this many multiply-adds the pool-dispatch cost dominates; stay
/// single-threaded so decode-sized calls never pay it. Shared with the
/// fused attention kernels ([`lut_attend`]), whose per-call work is gated
/// by the same constant.
pub(crate) const GEMM_PAR_FLOPS: usize = 1 << 21;

/// Row-major GEMM kernel: accumulate `a [m,k] @ b [k,n]` into `out [m,n]`
/// (caller provides a zeroed — or pre-accumulated — `out`).
///
/// This is the crate's one matmul inner loop: `Tensor::matmul`,
/// `Tensor::matmul_t`, the fused batched decode step
/// (`nn::forward_lm_step_batch`) and the packed-weight `quant::lut_gemm`
/// all go through it. Structure: the K dimension is split into
/// [`GEMM_KC`]-length blocks; within a block, `[GEMM_MR, GEMM_NR]` register
/// micro-tiles hold explicit accumulator arrays and the inner loop is a
/// contiguous multiply-add over `b` row slices that LLVM autovectorizes.
/// Row blocks run on the persistent `runtime::pool` workers once the
/// problem passes a FLOP threshold (prefill / quantizer sizes), never for
/// decode-sized calls.
///
/// **Batch-row bit-identity invariant** (the PR-2 contract
/// `rust/tests/batched_decode.rs` enforces): every output row is an
/// independent chain of f32 operations whose order depends only on `k`, `n`
/// and the fixed blocking constants — never on `m`, the row index, the tile
/// the row landed in (full or remainder) or the thread that ran it. A
/// `[B, d]` batch of rows is therefore *bit-identical*, row for row, to `B`
/// separate `[1, d]` calls.
///
/// The old kernel's `a[i][k] == 0.0` sparsity skip is gone: dense decode
/// rows made the branch mispredict on nearly every element (measured in
/// `perf_kernel`, see `BENCH_kernel.json`), and skipping work per-element
/// would also break the bit-identity argument above for rows that happen to
/// share zeros. The naive reference lives on as [`gemm_naive`].
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    gemm_threaded(m, k, n, a, b, out, gemm_auto_threads(m, k, n));
}

/// [`gemm`] with an explicit row-thread count (`1` = serial). The thread
/// count only changes how rows are chunked across pool tasks — never any
/// row's arithmetic — so every value produces bit-identical output.
/// `gemm` picks the count via [`gemm_auto_threads`]; `quant::lut_gemm`
/// pins one decision from its *full* K so its per-K-block calls thread
/// exactly when the dense path on the same problem would.
///
/// Parallel chunks run on the persistent [`crate::runtime::pool`] worker
/// pool (PR 4) instead of per-call `std::thread::scope` spawns: a mid-sized
/// prefill issues six GEMMs per layer per step, and the old spawn/join
/// round trip per chunk was pure overhead the pool amortizes to a condvar
/// wake (`perf_kernel` records pool vs scope under `gemm_pool_*` /
/// `gemm_scope_*`).
pub fn gemm_threaded(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "gemm: lhs is not [{m}, {k}]");
    assert_eq!(b.len(), k * n, "gemm: rhs is not [{k}, {n}]");
    assert_eq!(out.len(), m * n, "gemm: out is not [{m}, {n}]");
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let threads = threads.max(1).min(m.div_ceil(GEMM_MR));
    let _span = crate::obs::trace::span("kernel", "tensor.gemm")
        .arg("m", m as f64)
        .arg("k", k as f64)
        .arg("n", n as f64)
        .arg("threads", threads as f64);
    if threads <= 1 {
        gemm_block(m, k, n, a, b, out);
        return;
    }
    // Split rows into contiguous chunks of whole GEMM_MR multiples. Each
    // chunk runs the identical serial kernel on its own disjoint slice of
    // `out`, so threading cannot change any row's arithmetic.
    let tiles = m.div_ceil(GEMM_MR);
    let tiles_per = tiles.div_ceil(threads);
    let rows_per = tiles_per * GEMM_MR;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(threads);
    let mut rest = out;
    let mut i0 = 0usize;
    while i0 < m {
        let mb = rows_per.min(m - i0);
        let (chunk, tail) = rest.split_at_mut(mb * n);
        rest = tail;
        let a_chunk = &a[i0 * k..(i0 + mb) * k];
        tasks.push(Box::new(move || gemm_block(mb, k, n, a_chunk, b, chunk)));
        i0 += mb;
    }
    crate::runtime::pool::global().scoped(tasks);
}

/// Row-block thread count [`gemm`] would pick for an `[m, k] x [k, n]`
/// problem (`1` = stay serial). Decode-sized calls always return 1.
/// ISA-aware fan-out sizing: the SIMD tiles retire multiply-adds a few
/// times faster than the scalar chains, so the serial kernel covers ~2x
/// larger problems before a `runtime::pool` dispatch pays for itself —
/// the break-even threshold doubles when a vector ISA is active. Thread
/// count never changes any row's arithmetic (see [`gemm_threaded`]), so
/// this only moves the dispatch point, not a single bit.
pub fn gemm_auto_threads(m: usize, k: usize, n: usize) -> usize {
    let par_floor = match simd::active() {
        simd::Isa::Scalar => GEMM_PAR_FLOPS,
        _ => 2 * GEMM_PAR_FLOPS,
    };
    if m < 2 * GEMM_MR || m.saturating_mul(k).saturating_mul(n) < par_floor {
        return 1;
    }
    crate::runtime::pool::parallelism().min(m.div_ceil(GEMM_MR)).min(8)
}

/// Serial blocked kernel over one row range (see [`gemm`] for the layout).
/// The ISA is resolved once per call; each micro-tile then runs the SIMD
/// variant (bit-identical to the scalar chains — see `tensor::simd`) or
/// the scalar oracle itself.
fn gemm_block(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    let isa = simd::active();
    let mut k0 = 0usize;
    while k0 < k {
        let kb = GEMM_KC.min(k - k0);
        let b_block = &b[k0 * n..(k0 + kb) * n];
        let mut i0 = 0usize;
        while i0 < m {
            match m - i0 {
                1 => simd::micro_tile_vec::<1>(isa, kb, k, n, k0, i0, a, b_block, out),
                2 => simd::micro_tile_vec::<2>(isa, kb, k, n, k0, i0, a, b_block, out),
                3 => simd::micro_tile_vec::<3>(isa, kb, k, n, k0, i0, a, b_block, out),
                _ => simd::micro_tile_vec::<GEMM_MR>(isa, kb, k, n, k0, i0, a, b_block, out),
            }
            i0 += GEMM_MR.min(m - i0);
        }
        k0 += kb;
    }
}

/// One `[MB, n]` register-tiled pass over a K-block: accumulators for
/// `GEMM_NR` columns at a time live in registers across the whole `kb`
/// loop, then flush into `out` once per tile. Each accumulator is an
/// independent scalar chain in `kk` order — full tiles, the column
/// remainder and every `MB` compute the same per-(row, column) sequence.
#[inline(always)]
fn micro_tile<const MB: usize>(
    kb: usize,
    k: usize,
    n: usize,
    k0: usize,
    i0: usize,
    a: &[f32],
    b_block: &[f32],
    out: &mut [f32],
) {
    let a_rows: [&[f32]; MB] =
        std::array::from_fn(|r| &a[(i0 + r) * k + k0..(i0 + r) * k + k0 + kb]);
    let mut j0 = 0usize;
    while j0 + GEMM_NR <= n {
        let mut acc = [[0.0f32; GEMM_NR]; MB];
        let mut boff = j0;
        for kk in 0..kb {
            let b_row = &b_block[boff..boff + GEMM_NR];
            for r in 0..MB {
                let av = a_rows[r][kk];
                let accr = &mut acc[r];
                for t in 0..GEMM_NR {
                    accr[t] += av * b_row[t];
                }
            }
            boff += n;
        }
        for r in 0..MB {
            let o = (i0 + r) * n + j0;
            let o_row = &mut out[o..o + GEMM_NR];
            for t in 0..GEMM_NR {
                o_row[t] += acc[r][t];
            }
        }
        j0 += GEMM_NR;
    }
    if j0 < n {
        // column remainder: same accumulator chains, narrower tile
        let rem = n - j0;
        let mut acc = [[0.0f32; GEMM_NR]; MB];
        let mut boff = j0;
        for kk in 0..kb {
            let b_row = &b_block[boff..boff + rem];
            for r in 0..MB {
                let av = a_rows[r][kk];
                let accr = &mut acc[r];
                for t in 0..rem {
                    accr[t] += av * b_row[t];
                }
            }
            boff += n;
        }
        for r in 0..MB {
            let o = (i0 + r) * n + j0;
            let o_row = &mut out[o..o + rem];
            for t in 0..rem {
                o_row[t] += acc[r][t];
            }
        }
    }
}

/// Naive triple-loop reference GEMM (no blocking, no skips): plain
/// sequential accumulation per output element. Kept as the oracle the
/// blocked kernel is property-tested against (`rust/tests/blocked_gemm.rs`)
/// and as the before-side of the `perf_kernel` comparison.
pub fn gemm_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "gemm_naive: lhs is not [{m}, {k}]");
    assert_eq!(b.len(), k * n, "gemm_naive: rhs is not [{k}, {n}]");
    assert_eq!(out.len(), m * n, "gemm_naive: out is not [{m}, {n}]");
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let o_row = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in a_row.iter().enumerate() {
            let b_row = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in o_row.iter_mut().zip(b_row) {
                *o += av * bv;
            }
        }
    }
}

/// Argmax of a slice (first maximum wins). NaN-tolerant: the running best
/// is tracked as a value starting at -inf, so a NaN entry never becomes the
/// comparison baseline and any finite entry after it still wins — the old
/// `x > xs[best]` scan wedged at a leading NaN because every comparison
/// against NaN is false. Input with no entry above -inf (all-NaN, empty)
/// returns index 0. `serving::emit_token` greedy-streams through this, so a
/// single NaN logit must not freeze the argmax at position 0.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best = i;
            best_v = x;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// Attention kernels (fp32 + fused packed-KV dequant)
// ---------------------------------------------------------------------------

/// Immutable view of one packed 4-bit KV lane: `rows` cached positions of
/// `d` values each, stored as two nibble codes per byte plus per-block
/// scales and the format's 16-entry dequant LUT. Built by
/// `quant::KvFormat` encoders (`nn::SeqKvCache` / the serving slot pool);
/// consumed by [`lut_attend_head`]. Element `(r, j)` dequantizes as
/// `lut[code(r, j)] * scales[r][j / block]` — the exact f32 expression of
/// the dequantize-then-attend oracle.
#[derive(Clone, Copy, Debug)]
pub struct PackedLane<'a> {
    /// `[rows, d/2]` packed nibbles: column `2j` in the low nibble and
    /// `2j+1` in the high nibble of byte `(r, j)`.
    pub codes: &'a [u8],
    /// `[rows, d/block]` per-block dequant scales.
    pub scales: &'a [f32],
    /// The codebook padded to 16 f32 entries.
    pub lut: &'a [f32; 16],
    /// Values per cached position.
    pub d: usize,
    /// Values per scale block (divides `d` and the attention head width).
    pub block: usize,
}

/// One attention head over fp32 K/V lanes: scores `q · K[j]` for
/// `j < rows`, softmax, then accumulates the V rows into `ctx_head`
/// (`+=`). `kbuf`/`vbuf` are position-major `[.., d]` lanes and `off` is
/// the head's column offset. This is the exact loop structure (and
/// therefore the exact f32 arithmetic) of the pre-PR-4 inline attention in
/// `nn::forward_lm_step`, hoisted here so the single-sequence step, the
/// fused batched step, the full forward and the benches all share one body.
///
/// Since PR 5 the body lives in [`attend_head_paged`]: a contiguous lane
/// is the degenerate one-page block table, so the contiguous and paged
/// entry points share every loop (and therefore every bit).
#[allow(clippy::too_many_arguments)]
pub fn attend_head(
    q_head: &[f32],
    kbuf: &[f32],
    vbuf: &[f32],
    d: usize,
    off: usize,
    rows: usize,
    scale: f32,
    att: &mut [f32],
    ctx_head: &mut [f32],
) {
    attend_head_paged(q_head, &[kbuf], &[vbuf], rows.max(1), d, off, rows, scale, att, ctx_head);
}

/// [`attend_head`] over a *block table*: K/V arrive as a sequence of
/// fixed-size page slices (`page_rows` positions of `d` values each; the
/// last page may be partially filled) instead of one contiguous lane —
/// the layout of the paged KV cache (`serving::kv_cache`).
///
/// **Bit-identity:** position `j` lives at row `j % page_rows` of page
/// `j / page_rows`, and the kernel walks pages in table order, so every
/// position is visited in exactly the same order — and with exactly the
/// same score/softmax/accumulate arithmetic — as the contiguous kernel
/// over the same values. Paging changes where rows live, never what is
/// computed (`rust/tests/paged_kv.rs` locks this down end to end).
#[allow(clippy::too_many_arguments)]
pub fn attend_head_paged(
    q_head: &[f32],
    k_pages: &[&[f32]],
    v_pages: &[&[f32]],
    page_rows: usize,
    d: usize,
    off: usize,
    rows: usize,
    scale: f32,
    att: &mut [f32],
    ctx_head: &mut [f32],
) {
    let dh = q_head.len();
    debug_assert!(att.len() >= rows, "attention scratch too small");
    debug_assert_eq!(ctx_head.len(), dh);
    assert!(
        k_pages.len() * page_rows >= rows && v_pages.len() * page_rows >= rows,
        "block table holds {} K / {} V pages x {page_rows} rows, attending {rows}",
        k_pages.len(),
        v_pages.len(),
    );
    let mut mx = f32::NEG_INFINITY;
    let mut j = 0usize;
    'score: for page in k_pages {
        for r in 0..page_rows {
            if j == rows {
                break 'score;
            }
            let kj = &page[r * d + off..r * d + off + dh];
            let mut dot = 0.0f32;
            for t in 0..dh {
                dot += q_head[t] * kj[t];
            }
            att[j] = dot * scale;
            mx = mx.max(att[j]);
            j += 1;
        }
    }
    let mut z = 0.0f32;
    for a in att.iter_mut().take(rows) {
        *a = (*a - mx).exp();
        z += *a;
    }
    let mut j = 0usize;
    'accum: for page in v_pages {
        for r in 0..page_rows {
            if j == rows {
                break 'accum;
            }
            let w = att[j] / z;
            let vj = &page[r * d + off..r * d + off + dh];
            for t in 0..dh {
                ctx_head[t] += w * vj[t];
            }
            j += 1;
        }
    }
}

/// A packed 4-bit KV lane split across a block table of fixed-size pages:
/// page `p` holds positions `p * page_rows ..` as its own codes/scales
/// slices with the [`PackedLane`] row layout. The paged attention kernels
/// ([`lut_attend_head_paged`] / [`lut_attend_paged`]) walk this exactly
/// like [`attend_head_paged`] walks fp32 pages; a contiguous lane is the
/// one-page special case.
#[derive(Clone, Copy, Debug)]
pub struct PagedPackedLane<'a> {
    /// Per page: `[page_rows, d/2]` packed nibbles (see [`PackedLane::codes`]).
    pub pages_codes: &'a [&'a [u8]],
    /// Per page: `[page_rows, d/block]` dequant scales.
    pub pages_scales: &'a [&'a [f32]],
    /// The codebook padded to 16 f32 entries (shared by every page).
    pub lut: &'a [f32; 16],
    /// Values per cached position.
    pub d: usize,
    /// Values per scale block.
    pub block: usize,
    /// Positions per page (the last page may be partially filled).
    pub page_rows: usize,
}

impl<'a> PagedPackedLane<'a> {
    /// One page viewed as a contiguous [`PackedLane`].
    fn page(&self, p: usize) -> PackedLane<'a> {
        PackedLane {
            codes: self.pages_codes[p],
            scales: self.pages_scales[p],
            lut: self.lut,
            d: self.d,
            block: self.block,
        }
    }
}

/// One attention head over **packed 4-bit** K/V lanes, dequantizing inside
/// the kernel: the lane stream from memory is nibble codes + per-block
/// scales (~5x less KV traffic than fp32 lanes), and the f32 expansion
/// lives only in a 16-entry `lut * scale` register tile per (position,
/// block) — the same cache-resident LUT-expansion trick as
/// `quant::lut_gemm`, shrunk to attention's row granularity.
///
/// Loop structure mirrors [`attend_head`] exactly, and each element
/// expands as `lut[code] * scale` — the same f32 product the
/// dequantize-then-attend oracle stores — so the fused path is
/// **bit-identical** to dequantizing the lanes and calling `attend_head`
/// (`rust/tests/quant_kv.rs` locks this down per step).
///
/// `off` must be block-aligned and the head width a multiple of `block`
/// (the engine picks `block = d_head`, which satisfies both). The body
/// lives in [`lut_attend_head_paged`]; a contiguous lane is the one-page
/// block table.
#[allow(clippy::too_many_arguments)]
pub fn lut_attend_head(
    q_head: &[f32],
    k: PackedLane<'_>,
    v: PackedLane<'_>,
    off: usize,
    rows: usize,
    scale: f32,
    att: &mut [f32],
    ctx_head: &mut [f32],
) {
    let (kc, ks, vc, vs) = ([k.codes], [k.scales], [v.codes], [v.scales]);
    let kp = PagedPackedLane {
        pages_codes: &kc,
        pages_scales: &ks,
        lut: k.lut,
        d: k.d,
        block: k.block,
        page_rows: rows.max(1),
    };
    let vp = PagedPackedLane {
        pages_codes: &vc,
        pages_scales: &vs,
        lut: v.lut,
        d: v.d,
        block: v.block,
        page_rows: rows.max(1),
    };
    lut_attend_head_paged(q_head, kp, vp, off, rows, scale, att, ctx_head);
}

/// [`lut_attend_head`] over a block table of packed pages — the fused
/// dequant-attention kernel of the paged KV cache. Position `j` is row
/// `j % page_rows` of page `j / page_rows`; pages are walked in table
/// order, so the per-position arithmetic (and therefore every bit) is
/// identical to the contiguous kernel over the same codes.
///
/// Dispatches on the active ISA: the vector path
/// (`simd::lut_attend_head_paged_vec`) expands each `lut * scale` dequant
/// tile in-register and vectorizes the V accumulation while keeping the
/// score reduction a scalar chain, so it is bit-identical to
/// [`lut_attend_head_paged_scalar`] — the verbatim pre-SIMD body, kept as
/// the oracle (`rust/tests/simd_kernels.rs`).
#[allow(clippy::too_many_arguments)]
pub fn lut_attend_head_paged(
    q_head: &[f32],
    k: PagedPackedLane<'_>,
    v: PagedPackedLane<'_>,
    off: usize,
    rows: usize,
    scale: f32,
    att: &mut [f32],
    ctx_head: &mut [f32],
) {
    match simd::active() {
        simd::Isa::Scalar => {
            lut_attend_head_paged_scalar(q_head, k, v, off, rows, scale, att, ctx_head)
        }
        isa => simd::lut_attend_head_paged_vec(isa, q_head, k, v, off, rows, scale, att, ctx_head),
    }
}

/// The scalar oracle body of [`lut_attend_head_paged`] (pre-PR-10,
/// verbatim). Public so the differential tests and the force-scalar bench
/// cells can target it directly regardless of dispatch state.
#[allow(clippy::too_many_arguments)]
pub fn lut_attend_head_paged_scalar(
    q_head: &[f32],
    k: PagedPackedLane<'_>,
    v: PagedPackedLane<'_>,
    off: usize,
    rows: usize,
    scale: f32,
    att: &mut [f32],
    ctx_head: &mut [f32],
) {
    let dh = q_head.len();
    debug_assert!(att.len() >= rows, "attention scratch too small");
    debug_assert_eq!(ctx_head.len(), dh);
    debug_assert_eq!(off % k.block, 0, "head offset must be block-aligned");
    debug_assert_eq!(dh % k.block, 0, "head width must be whole blocks");
    assert!(
        k.pages_codes.len() * k.page_rows >= rows && v.pages_codes.len() * v.page_rows >= rows,
        "block table holds {} K / {} V pages, attending {rows} rows",
        k.pages_codes.len(),
        v.pages_codes.len(),
    );
    let mut mx = f32::NEG_INFINITY;
    let mut j = 0usize;
    'score: for p in 0..k.pages_codes.len() {
        let lane = k.page(p);
        for r in 0..k.page_rows {
            if j == rows {
                break 'score;
            }
            let mut dot = 0.0f32;
            lane_row_blocks(&lane, r, off, dh, |t0, slut, codes| {
                for (t, &c) in codes.iter().enumerate() {
                    dot += q_head[t0 + t] * slut[c as usize];
                }
            });
            att[j] = dot * scale;
            mx = mx.max(att[j]);
            j += 1;
        }
    }
    let mut z = 0.0f32;
    for a in att.iter_mut().take(rows) {
        *a = (*a - mx).exp();
        z += *a;
    }
    let mut j = 0usize;
    'accum: for p in 0..v.pages_codes.len() {
        let lane = v.page(p);
        for r in 0..v.page_rows {
            if j == rows {
                break 'accum;
            }
            let w = att[j] / z;
            lane_row_blocks(&lane, r, off, dh, |t0, slut, codes| {
                for (t, &c) in codes.iter().enumerate() {
                    ctx_head[t0 + t] += w * slut[c as usize];
                }
            });
            j += 1;
        }
    }
}

/// Max values per scale block the stack-resident decode buffers support
/// (every zoo `d_head` is far below this).
pub const LANE_MAX_BLOCK: usize = 256;

/// Walk one packed row's blocks inside `[off, off + dh)`: for each block,
/// build the scaled 16-entry LUT tile (`slut[c] = lut[c] * scale`, the
/// oracle's exact product) and the unpacked nibble codes, then hand both to
/// `f(head-relative offset, slut, codes)`.
#[inline]
fn lane_row_blocks(
    lane: &PackedLane<'_>,
    row: usize,
    off: usize,
    dh: usize,
    mut f: impl FnMut(usize, &[f32; 16], &[u8]),
) {
    let block = lane.block;
    assert!(block <= LANE_MAX_BLOCK, "block {block} exceeds LANE_MAX_BLOCK");
    let row_bytes = lane.d / 2;
    let codes_row = &lane.codes[row * row_bytes..(row + 1) * row_bytes];
    let scales_row = &lane.scales[row * (lane.d / block)..(row + 1) * (lane.d / block)];
    let mut slut = [0.0f32; 16];
    let mut codes = [0u8; LANE_MAX_BLOCK];
    let mut t = 0usize;
    while t < dh {
        let col0 = off + t;
        let s = scales_row[col0 / block];
        for (o, &l) in slut.iter_mut().zip(lane.lut) {
            *o = l * s;
        }
        // off and block are even (asserted by the encoders), so a block
        // always covers whole bytes
        for (p, &byte) in codes_row[col0 / 2..(col0 + block) / 2].iter().enumerate() {
            codes[2 * p] = byte & 0x0f;
            codes[2 * p + 1] = byte >> 4;
        }
        f(t, &slut, &codes[..block]);
        t += block;
    }
}

/// All-heads fused packed-KV attention for one query row: dispatches each
/// head through [`lut_attend_head`], splitting heads across the persistent
/// `runtime::pool` once the problem passes the same FLOP threshold as the
/// GEMM (decode-sized calls always stay serial). Heads write disjoint
/// `ctx_row` chunks and each head's arithmetic is an independent chain, so
/// the pool path is bit-identical to the serial one. The body lives in
/// [`lut_attend_paged`]; a contiguous lane is the one-page block table.
#[allow(clippy::too_many_arguments)]
pub fn lut_attend(
    q_row: &[f32],
    k: PackedLane<'_>,
    v: PackedLane<'_>,
    n_heads: usize,
    rows: usize,
    scale: f32,
    att: &mut [f32],
    ctx_row: &mut [f32],
) {
    let (kc, ks, vc, vs) = ([k.codes], [k.scales], [v.codes], [v.scales]);
    let kp = PagedPackedLane {
        pages_codes: &kc,
        pages_scales: &ks,
        lut: k.lut,
        d: k.d,
        block: k.block,
        page_rows: rows.max(1),
    };
    let vp = PagedPackedLane {
        pages_codes: &vc,
        pages_scales: &vs,
        lut: v.lut,
        d: v.d,
        block: v.block,
        page_rows: rows.max(1),
    };
    lut_attend_paged(q_row, kp, vp, n_heads, rows, scale, att, ctx_row);
}

/// All-heads [`lut_attend_head_paged`] with the same pool fan-out policy
/// as [`lut_attend`]: long-context calls split heads across the persistent
/// worker pool (disjoint `ctx_row` chunks, placement-independent
/// arithmetic), decode-sized calls stay serial.
#[allow(clippy::too_many_arguments)]
pub fn lut_attend_paged(
    q_row: &[f32],
    k: PagedPackedLane<'_>,
    v: PagedPackedLane<'_>,
    n_heads: usize,
    rows: usize,
    scale: f32,
    att: &mut [f32],
    ctx_row: &mut [f32],
) {
    let dh = q_row.len() / n_heads;
    debug_assert_eq!(q_row.len(), n_heads * dh);
    debug_assert_eq!(ctx_row.len(), q_row.len());
    let _span = crate::obs::trace::span("kernel", "tensor.lut_attend")
        .arg("rows", rows as f64)
        .arg("heads", n_heads as f64);
    // scores + V accumulation are each one MAC per (position, value)
    let work = 2 * rows * q_row.len();
    if n_heads > 1 && work >= GEMM_PAR_FLOPS {
        // one scratch allocation for the whole call; each head gets its
        // own disjoint rows-sized score chunk (the caller's `att` buffer
        // is single-head-sized, so the parallel path cannot share it)
        let mut att_all = vec![0.0f32; n_heads * rows];
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ctx_row
            .chunks_mut(dh)
            .zip(att_all.chunks_mut(rows))
            .enumerate()
            .map(|(h, (ctx_head, att_head))| {
                let q_head = &q_row[h * dh..(h + 1) * dh];
                Box::new(move || {
                    lut_attend_head_paged(q_head, k, v, h * dh, rows, scale, att_head, ctx_head);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        crate::runtime::pool::global().scoped(tasks);
    } else {
        for h in 0..n_heads {
            lut_attend_head_paged(
                &q_row[h * dh..(h + 1) * dh],
                k,
                v,
                h * dh,
                rows,
                scale,
                att,
                &mut ctx_row[h * dh..(h + 1) * dh],
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn gemm_batched_rows_bit_identical_to_single_rows() {
        // the fused-decode contract: one [B,K] GEMM == B separate [1,K] GEMMs
        let a = Tensor::from_fn(&[5, 16], |i| ((i * 37 % 23) as f32 - 11.0) * 0.125);
        let b = Tensor::from_fn(&[16, 9], |i| ((i * 11 % 19) as f32 - 9.0) * 0.25);
        let fused = a.matmul(&b);
        for i in 0..5 {
            let row = Tensor::new(&[1, 16], a.row(i).to_vec());
            let single = row.matmul(&b);
            assert_eq!(fused.row(i), single.row(0), "row {i} differs bitwise");
        }
    }

    #[test]
    fn gemm_accumulates_into_out() {
        let a = Tensor::new(&[1, 2], vec![1.0, 2.0]);
        let b = Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let mut out = vec![10.0f32, 20.0];
        gemm(1, 2, 2, a.data(), b.data(), &mut out);
        assert_eq!(out, vec![11.0, 22.0]);
    }

    #[test]
    fn blocked_gemm_matches_naive_on_remainder_shapes() {
        // shapes straddling every tile boundary: MR=4 rows, NR=16 cols,
        // KC=256 k-block (k=300 crosses it)
        for &(m, k, n) in
            &[(1usize, 1usize, 1usize), (3, 5, 7), (4, 16, 16), (5, 17, 18), (9, 300, 33)]
        {
            let a: Vec<f32> = (0..m * k).map(|i| ((i * 13 % 17) as f32 - 8.0) * 0.25).collect();
            let b: Vec<f32> = (0..k * n).map(|i| ((i * 7 % 19) as f32 - 9.0) * 0.125).collect();
            let mut fast = vec![0.0f32; m * n];
            let mut naive = vec![0.0f32; m * n];
            gemm(m, k, n, &a, &b, &mut fast);
            gemm_naive(m, k, n, &a, &b, &mut naive);
            for (i, (x, y)) in fast.iter().zip(&naive).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-4 * (1.0 + y.abs()),
                    "[{m},{k},{n}] elem {i}: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn gemm_zero_rows_in_a_do_not_skip_work() {
        // the sparsity-skip branch is gone: zeros in `a` must still produce
        // exact results (and identical arithmetic) rather than early-outs
        let mut a = vec![0.0f32; 2 * 8];
        a[3] = 2.0; // row 0 mostly zero
        a[8] = 1.0; // row 1 leading 1
        let b: Vec<f32> = (0..8 * 5).map(|i| i as f32 * 0.5).collect();
        let mut fast = vec![0.0f32; 2 * 5];
        let mut naive = vec![0.0f32; 2 * 5];
        gemm(2, 8, 5, &a, &b, &mut fast);
        gemm_naive(2, 8, 5, &a, &b, &mut naive);
        assert_eq!(fast, naive);
    }

    #[test]
    fn argmax_is_nan_tolerant() {
        // regression: a leading NaN used to freeze `x > xs[best]` at 0
        assert_eq!(argmax(&[f32::NAN, 1.0, 3.0, 2.0]), 2);
        assert_eq!(argmax(&[1.0, f32::NAN, 0.5]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0, "all-NaN falls back to 0");
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NAN, 7.0]), 2);
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 0, "ties: first maximum wins");
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn matmul_t_agrees() {
        let a = Tensor::from_fn(&[3, 4], |i| i as f32 * 0.3 - 1.0);
        let b = Tensor::from_fn(&[4, 5], |i| (i as f32).sin());
        let c1 = a.matmul(&b);
        let c2 = a.matmul_t(&b.transpose2());
        for (x, y) in c1.data().iter().zip(c2.data()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Tensor::from_fn(&[3, 7], |i| i as f32);
        assert_eq!(a.transpose2().transpose2(), a);
    }

    #[test]
    fn softmax_sums_to_one() {
        let a = Tensor::from_fn(&[4, 8], |i| (i as f32 * 0.7).cos() * 5.0);
        let s = a.softmax_last();
        for row in 0..4 {
            let sum: f32 = s.row(row).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn log_softmax_matches_softmax() {
        let a = Tensor::from_fn(&[2, 5], |i| i as f32 * 0.3);
        let s = a.softmax_last();
        let ls = a.log_softmax_last();
        for (p, lp) in s.data().iter().zip(ls.data()) {
            assert!((p.ln() - lp).abs() < 1e-5);
        }
    }

    #[test]
    fn reductions() {
        let a = Tensor::new(&[4], vec![1.0, -3.0, 2.0, 0.0]);
        assert_eq!(a.sum(), 0.0);
        assert_eq!(a.abs_max(), 3.0);
        assert_eq!(a.min(), -3.0);
        assert_eq!(a.max(), 2.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        a.matmul(&b); // 3 != 2
    }

    /// Hand-built packed lane + its f32 dequantization (`lut[c] * scale`,
    /// the oracle expansion) over a deterministic code/scale pattern.
    fn hand_lane(
        rows: usize,
        d: usize,
        block: usize,
        seed: u32,
    ) -> (Vec<u8>, Vec<f32>, [f32; 16], Vec<f32>) {
        let lut: [f32; 16] =
            std::array::from_fn(|i| (i as f32 - 7.5) / 7.5 * if i % 3 == 0 { 0.5 } else { 1.0 });
        let mut codes = vec![0u8; rows * d / 2];
        let mut scales = vec![0.0f32; rows * d / block];
        for (i, s) in scales.iter_mut().enumerate() {
            *s = 0.25 + ((i as u32 * 37 + seed) % 11) as f32 * 0.125;
        }
        let mut dense = vec![0.0f32; rows * d];
        for r in 0..rows {
            for j in 0..d {
                let c = ((r * d + j) as u32 * 13 + seed) % 16;
                codes[r * d / 2 + j / 2] |= (c as u8) << (4 * (j % 2));
                dense[r * d + j] = lut[c as usize] * scales[r * (d / block) + j / block];
            }
        }
        (codes, scales, lut, dense)
    }

    #[test]
    fn lut_attend_head_bit_identical_to_dequant_then_attend() {
        let (rows, d, block) = (13usize, 32usize, 16usize);
        let (k_codes, k_scales, lut, k_dense) = hand_lane(rows, d, block, 1);
        let (v_codes, v_scales, _, v_dense) = hand_lane(rows, d, block, 2);
        let q: Vec<f32> = (0..d).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.1).collect();
        let scale = 0.25f32;
        for (heads, dh) in [(2usize, 16usize), (1, 32)] {
            let mut att_a = vec![0.0f32; rows];
            let mut att_b = vec![0.0f32; rows];
            let mut ctx_fused = vec![0.0f32; d];
            let mut ctx_oracle = vec![0.0f32; d];
            for h in 0..heads {
                let off = h * dh;
                let k = PackedLane { codes: &k_codes, scales: &k_scales, lut: &lut, d, block };
                let v = PackedLane { codes: &v_codes, scales: &v_scales, lut: &lut, d, block };
                lut_attend_head(
                    &q[off..off + dh],
                    k,
                    v,
                    off,
                    rows,
                    scale,
                    &mut att_a,
                    &mut ctx_fused[off..off + dh],
                );
                attend_head(
                    &q[off..off + dh],
                    &k_dense,
                    &v_dense,
                    d,
                    off,
                    rows,
                    scale,
                    &mut att_b,
                    &mut ctx_oracle[off..off + dh],
                );
            }
            assert_eq!(ctx_fused, ctx_oracle, "heads={heads}: fused attention diverged");
        }
    }

    #[test]
    fn attend_head_paged_bit_identical_to_contiguous() {
        // split one contiguous lane into 4-row pages (ragged tail) and
        // attend: every (rows, head) cell must match the contiguous kernel
        // bitwise — paging moves rows, it must not change arithmetic
        let (d, page_rows) = (32usize, 4usize);
        let max_rows = 13usize; // 4 pages, last one partial
        let kbuf: Vec<f32> =
            (0..max_rows * d).map(|i| ((i * 19 % 31) as f32 - 15.0) * 0.06).collect();
        let vbuf: Vec<f32> =
            (0..max_rows * d).map(|i| ((i * 23 % 29) as f32 - 14.0) * 0.04).collect();
        let q: Vec<f32> = (0..d).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.1).collect();
        let pages = max_rows.div_ceil(page_rows);
        // pad the paged copy so every page is full-size storage
        let mut k_padded = kbuf.clone();
        let mut v_padded = vbuf.clone();
        k_padded.resize(pages * page_rows * d, 0.0);
        v_padded.resize(pages * page_rows * d, 0.0);
        let k_pages: Vec<&[f32]> = k_padded.chunks(page_rows * d).collect();
        let v_pages: Vec<&[f32]> = v_padded.chunks(page_rows * d).collect();
        for rows in [1usize, 3, 4, 5, 8, 13] {
            for (heads, dh) in [(2usize, 16usize), (1, 32)] {
                let mut att_a = vec![0.0f32; rows];
                let mut att_b = vec![0.0f32; rows];
                let mut ctx_paged = vec![0.0f32; d];
                let mut ctx_flat = vec![0.0f32; d];
                for h in 0..heads {
                    let off = h * dh;
                    attend_head_paged(
                        &q[off..off + dh],
                        &k_pages,
                        &v_pages,
                        page_rows,
                        d,
                        off,
                        rows,
                        0.25,
                        &mut att_a,
                        &mut ctx_paged[off..off + dh],
                    );
                    attend_head(
                        &q[off..off + dh],
                        &kbuf,
                        &vbuf,
                        d,
                        off,
                        rows,
                        0.25,
                        &mut att_b,
                        &mut ctx_flat[off..off + dh],
                    );
                }
                assert_eq!(ctx_paged, ctx_flat, "rows={rows} heads={heads}: paging changed bits");
            }
        }
    }

    #[test]
    fn lut_attend_head_paged_bit_identical_to_contiguous() {
        let (d, block, page_rows) = (32usize, 16usize, 4usize);
        let max_rows = 11usize; // 3 pages, last one partial
        let (k_codes, k_scales, lut, _) = hand_lane(max_rows, d, block, 5);
        let (v_codes, v_scales, _, _) = hand_lane(max_rows, d, block, 6);
        let q: Vec<f32> = (0..d).map(|i| ((i * 11 % 17) as f32 - 8.0) * 0.07).collect();
        // paged copies, padded to whole pages
        let pages = max_rows.div_ceil(page_rows);
        let (crow, srow) = (d / 2, d / block);
        let mut kc = k_codes.clone();
        let mut ks = k_scales.clone();
        let mut vc = v_codes.clone();
        let mut vs = v_scales.clone();
        kc.resize(pages * page_rows * crow, 0);
        ks.resize(pages * page_rows * srow, 0.0);
        vc.resize(pages * page_rows * crow, 0);
        vs.resize(pages * page_rows * srow, 0.0);
        let kc_pages: Vec<&[u8]> = kc.chunks(page_rows * crow).collect();
        let ks_pages: Vec<&[f32]> = ks.chunks(page_rows * srow).collect();
        let vc_pages: Vec<&[u8]> = vc.chunks(page_rows * crow).collect();
        let vs_pages: Vec<&[f32]> = vs.chunks(page_rows * srow).collect();
        for rows in [1usize, 4, 7, 11] {
            let mut att_a = vec![0.0f32; rows];
            let mut att_b = vec![0.0f32; rows];
            let mut ctx_paged = vec![0.0f32; d];
            let mut ctx_flat = vec![0.0f32; d];
            let kp = PagedPackedLane {
                pages_codes: &kc_pages,
                pages_scales: &ks_pages,
                lut: &lut,
                d,
                block,
                page_rows,
            };
            let vp = PagedPackedLane {
                pages_codes: &vc_pages,
                pages_scales: &vs_pages,
                lut: &lut,
                d,
                block,
                page_rows,
            };
            lut_attend_paged(&q, kp, vp, 2, rows, 0.2, &mut att_a, &mut ctx_paged);
            let k = PackedLane { codes: &k_codes, scales: &k_scales, lut: &lut, d, block };
            let v = PackedLane { codes: &v_codes, scales: &v_scales, lut: &lut, d, block };
            lut_attend(&q, k, v, 2, rows, 0.2, &mut att_b, &mut ctx_flat);
            assert_eq!(ctx_paged, ctx_flat, "rows={rows}: packed paging changed bits");
        }
    }

    #[test]
    fn lut_attend_pooled_heads_match_serial() {
        // rows * d large enough to cross the pool threshold (2 * rows * d
        // >= GEMM_PAR_FLOPS): the parallel per-head path must be bitwise
        // the serial one
        let (rows, d, block, heads) = (4200usize, 256usize, 64usize, 4usize);
        let (k_codes, k_scales, lut, _) = hand_lane(rows, d, block, 3);
        let (v_codes, v_scales, _, _) = hand_lane(rows, d, block, 4);
        let q: Vec<f32> = (0..d).map(|i| ((i * 11 % 17) as f32 - 8.0) * 0.05).collect();
        let k = PackedLane { codes: &k_codes, scales: &k_scales, lut: &lut, d, block };
        let v = PackedLane { codes: &v_codes, scales: &v_scales, lut: &lut, d, block };
        let mut att = vec![0.0f32; rows];
        let mut ctx_par = vec![0.0f32; d];
        lut_attend(&q, k, v, heads, rows, 0.125, &mut att, &mut ctx_par);
        let mut ctx_ser = vec![0.0f32; d];
        let dh = d / heads;
        for h in 0..heads {
            lut_attend_head(
                &q[h * dh..(h + 1) * dh],
                k,
                v,
                h * dh,
                rows,
                0.125,
                &mut att,
                &mut ctx_ser[h * dh..(h + 1) * dh],
            );
        }
        assert_eq!(ctx_par, ctx_ser, "pool placement must not change attention bits");
    }
}
