//! Explicit SIMD microkernels with one-time runtime dispatch (PR 10).
//!
//! Three hot loops get hand-written `core::arch` paths — the `gemm`
//! MR×NR micro-tile, `lut_gemm`'s nibble→LUT row expansion, and
//! `lut_attend_head_paged`'s per-(position, block) dequant tiles — behind
//! a single [`Isa`] selector resolved once per process:
//!
//! * x86_64 with AVX2 → [`Isa::Avx2`] (256-bit tiles, `pshufb` LUT decode)
//! * aarch64 with NEON → [`Isa::Neon`] (128-bit tiles, `tbl` LUT decode)
//! * anything else, or `LLMDT_FORCE_SCALAR=1` / `--force-scalar` →
//!   [`Isa::Scalar`], the verbatim pre-PR-10 loops.
//!
//! **Bit-identity contract.** Every SIMD path computes *the same f32
//! operation sequence per output element* as its scalar oracle, so results
//! are bit-identical (property-tested in `rust/tests/simd_kernels.rs`):
//!
//! * the GEMM tile vectorizes across the `GEMM_NR` *columns* — each
//!   column's accumulator is still an independent mul-then-add chain in
//!   `kk` order. Deliberately **no FMA**: a fused multiply-add rounds once
//!   where the scalar oracle rounds twice, so the tile issues separate
//!   `mul` + `add` vector ops. The win is register blocking + width, not
//!   contraction.
//! * LUT expansion is per-element independent (`lut[code] * scale`): the
//!   16-entry f32 LUT is split into 4 byte planes and each unpacked nibble
//!   becomes an in-register byte shuffle per plane; the reassembled f32 is
//!   the exact LUT entry, and the one multiply per element matches the
//!   scalar expression.
//! * the attention score dot stays a scalar chain (reordering a reduction
//!   changes bits); only the dequant expansion and the per-element V
//!   accumulation (`ctx[t] += w * (lut[c] * s)`) vectorize.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Instruction set the kernels dispatch to. `code()` is the stable numeric
/// id exported as the `llmdt_kernel_dispatch` gauge (0/1/2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar loops — the bit-exact oracle every SIMD path is
    /// property-tested against.
    Scalar,
    /// aarch64 NEON (128-bit, `tbl` byte shuffle).
    Neon,
    /// x86_64 AVX2 (256-bit tiles, `pshufb` byte shuffle).
    Avx2,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Neon => "neon",
            Isa::Avx2 => "avx2",
        }
    }

    /// Stable numeric id for metrics/tracing (0 = scalar, 1 = neon,
    /// 2 = avx2).
    pub fn code(self) -> u8 {
        match self {
            Isa::Scalar => 0,
            Isa::Neon => 1,
            Isa::Avx2 => 2,
        }
    }
}

// The force flag initializes from LLMDT_FORCE_SCALAR on first query and can
// be flipped at runtime (`--force-scalar`, the perf_kernel/perf_serve A/B
// cells). Kernels re-read it through `active()` on every top-level call, so
// a flip applies to the next kernel invocation — tests serialize around it.
static FORCE_SCALAR: OnceLock<AtomicBool> = OnceLock::new();

fn force_flag() -> &'static AtomicBool {
    FORCE_SCALAR.get_or_init(|| {
        let on = std::env::var("LLMDT_FORCE_SCALAR")
            .map(|v| !(v.is_empty() || v == "0"))
            .unwrap_or(false);
        AtomicBool::new(on)
    })
}

/// Pin (or unpin) the scalar oracle path in this process. `true` is what
/// `LLMDT_FORCE_SCALAR=1` / `--force-scalar` set before serving starts.
pub fn force_scalar(on: bool) {
    force_flag().store(on, Ordering::SeqCst);
}

/// Whether the scalar path is currently forced.
pub fn scalar_forced() -> bool {
    force_flag().load(Ordering::Relaxed)
}

fn detect() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Isa::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Isa::Neon;
        }
    }
    Isa::Scalar
}

/// Best ISA this CPU supports (cached; ignores the force flag).
pub fn detected() -> Isa {
    static DETECTED: OnceLock<Isa> = OnceLock::new();
    *DETECTED.get_or_init(detect)
}

/// The ISA kernels dispatch to right now: [`detected`] unless the scalar
/// path is forced. One relaxed load + one cached lookup — cheap enough for
/// every kernel entry point to query per call.
pub fn active() -> Isa {
    if scalar_forced() {
        Isa::Scalar
    } else {
        detected()
    }
}

/// `active().name()` — for banners and logs.
pub fn isa_name() -> &'static str {
    active().name()
}

// ---------------------------------------------------------------------------
// Nibble→LUT expansion planes
// ---------------------------------------------------------------------------

/// A 16-entry f32 LUT split into its 4 little-endian byte planes: plane `p`
/// holds byte `p` of each `lut[c]`. A 16-lane byte shuffle per plane turns
/// 16 nibble codes into the 4 byte columns of 16 exact f32 LUT entries —
/// the in-register decode both SIMD expansion kernels share.
pub(crate) struct NibbleLut {
    #[cfg_attr(
        not(any(target_arch = "x86_64", target_arch = "aarch64")),
        allow(dead_code)
    )]
    planes: [[u8; 16]; 4],
}

impl NibbleLut {
    pub(crate) fn new(lut: &[f32; 16]) -> NibbleLut {
        let mut planes = [[0u8; 16]; 4];
        for (c, &v) in lut.iter().enumerate() {
            let b = v.to_bits().to_le_bytes();
            for (p, plane) in planes.iter_mut().enumerate() {
                plane[c] = b[p];
            }
        }
        NibbleLut { planes }
    }
}

// ---------------------------------------------------------------------------
// GEMM micro-tile dispatch
// ---------------------------------------------------------------------------

/// One `[MB, n]` register-tiled pass over a K-block, dispatched by ISA.
/// The scalar arm is `super::micro_tile` itself; the vector arms compute
/// the identical per-(row, column) mul-then-add chain with 8-/4-lane
/// columns (see the module docs for why this is bit-identical).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub(crate) fn micro_tile_vec<const MB: usize>(
    isa: Isa,
    kb: usize,
    k: usize,
    n: usize,
    k0: usize,
    i0: usize,
    a: &[f32],
    b_block: &[f32],
    out: &mut [f32],
) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::micro_tile_avx2::<MB>(kb, k, n, k0, i0, a, b_block, out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::micro_tile_neon::<MB>(kb, k, n, k0, i0, a, b_block, out) },
        _ => super::micro_tile::<MB>(kb, k, n, k0, i0, a, b_block, out),
    }
}

// ---------------------------------------------------------------------------
// lut_gemm row expansion dispatch
// ---------------------------------------------------------------------------

/// Expand one packed weight row: `wrow[j] = lut[code(j)] * srow[j]` for all
/// `j < wrow.len()` (`prow` holds two nibble codes per byte, low nibble
/// first). Per-element independent, so fully vectorizable; every element is
/// the scalar oracle's exact single-multiply expression.
#[cfg_attr(
    not(any(target_arch = "x86_64", target_arch = "aarch64")),
    allow(unused_variables)
)]
pub(crate) fn lut_expand_row(
    isa: Isa,
    planes: &NibbleLut,
    lut: &[f32; 16],
    prow: &[u8],
    srow: &[f32],
    wrow: &mut [f32],
) {
    debug_assert_eq!(prow.len(), wrow.len().div_ceil(2));
    debug_assert_eq!(srow.len(), wrow.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::lut_expand_row_avx2(planes, lut, prow, srow, wrow) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::lut_expand_row_neon(planes, lut, prow, srow, wrow) },
        _ => lut_expand_row_tail(lut, prow, srow, wrow, 0),
    }
}

/// Scalar expansion from output column `j0` (even) to the end — the tail of
/// the vector kernels and the whole loop on the scalar path. Verbatim the
/// pre-PR-10 `lut_gemm_blocks` inner loop.
fn lut_expand_row_tail(lut: &[f32; 16], prow: &[u8], srow: &[f32], wrow: &mut [f32], j0: usize) {
    let n = wrow.len();
    for (jh, &byte) in prow.iter().enumerate().skip(j0 / 2) {
        let j = 2 * jh;
        wrow[j] = lut[(byte & 0x0f) as usize] * srow[j];
        if j + 1 < n {
            wrow[j + 1] = lut[(byte >> 4) as usize] * srow[j + 1];
        }
    }
}

// ---------------------------------------------------------------------------
// Fused dequant-attention dispatch
// ---------------------------------------------------------------------------

/// Expand one packed block: `out[t] = lut[code(t)] * s` — the attention
/// kernels' per-(position, block) dequant tile. `bytes` holds the block's
/// packed nibbles (block is even, so always whole bytes).
#[cfg_attr(
    not(any(target_arch = "x86_64", target_arch = "aarch64")),
    allow(unused_variables)
)]
fn expand_block(isa: Isa, planes: &NibbleLut, lut: &[f32; 16], bytes: &[u8], s: f32, out: &mut [f32]) {
    debug_assert_eq!(bytes.len() * 2, out.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::expand_block_avx2(planes, lut, bytes, s, out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::expand_block_neon(planes, lut, bytes, s, out) },
        _ => expand_block_tail(lut, bytes, s, out, 0),
    }
}

/// Scalar block expansion from element `t0` (even) to the end.
fn expand_block_tail(lut: &[f32; 16], bytes: &[u8], s: f32, out: &mut [f32], t0: usize) {
    for (p, &byte) in bytes.iter().enumerate().skip(t0 / 2) {
        out[2 * p] = lut[(byte & 0x0f) as usize] * s;
        out[2 * p + 1] = lut[(byte >> 4) as usize] * s;
    }
}

/// `ys[t] += w * xs[t]` — the attention V accumulation, per-element
/// independent so vectorizable with the oracle's mul-then-add per lane.
#[cfg_attr(
    not(any(target_arch = "x86_64", target_arch = "aarch64")),
    allow(unused_variables)
)]
fn axpy(isa: Isa, w: f32, xs: &[f32], ys: &mut [f32]) {
    debug_assert_eq!(xs.len(), ys.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::axpy_avx2(w, xs, ys) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::axpy_neon(w, xs, ys) },
        _ => axpy_tail(w, xs, ys, 0),
    }
}

fn axpy_tail(w: f32, xs: &[f32], ys: &mut [f32], t0: usize) {
    for (y, &x) in ys.iter_mut().zip(xs).skip(t0) {
        *y += w * x;
    }
}

/// Vector-ISA body of `tensor::lut_attend_head_paged` (called with
/// `isa != Scalar`): same page walk, same scalar score chain and softmax as
/// the scalar oracle, but each block's `lut[c] * scale` dequant tile is
/// expanded in-register and the V accumulation runs 8/4 lanes wide. Every
/// per-element f32 operation sequence matches the oracle, so the result is
/// bit-identical (`rust/tests/simd_kernels.rs`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn lut_attend_head_paged_vec(
    isa: Isa,
    q_head: &[f32],
    k: super::PagedPackedLane<'_>,
    v: super::PagedPackedLane<'_>,
    off: usize,
    rows: usize,
    scale: f32,
    att: &mut [f32],
    ctx_head: &mut [f32],
) {
    let dh = q_head.len();
    debug_assert!(att.len() >= rows, "attention scratch too small");
    debug_assert_eq!(ctx_head.len(), dh);
    debug_assert_eq!(off % k.block, 0, "head offset must be block-aligned");
    debug_assert_eq!(dh % k.block, 0, "head width must be whole blocks");
    assert!(
        k.pages_codes.len() * k.page_rows >= rows && v.pages_codes.len() * v.page_rows >= rows,
        "block table holds {} K / {} V pages, attending {rows} rows",
        k.pages_codes.len(),
        v.pages_codes.len(),
    );
    assert!(k.block <= super::LANE_MAX_BLOCK && v.block <= super::LANE_MAX_BLOCK);
    let k_planes = NibbleLut::new(k.lut);
    let v_planes = NibbleLut::new(v.lut);
    let mut buf = [0.0f32; super::LANE_MAX_BLOCK];

    let mut mx = f32::NEG_INFINITY;
    let mut j = 0usize;
    'score: for p in 0..k.pages_codes.len() {
        let lane = k.page(p);
        let block = lane.block;
        let row_bytes = lane.d / 2;
        let srow_len = lane.d / block;
        for r in 0..k.page_rows {
            if j == rows {
                break 'score;
            }
            let codes_row = &lane.codes[r * row_bytes..(r + 1) * row_bytes];
            let scales_row = &lane.scales[r * srow_len..(r + 1) * srow_len];
            let mut dot = 0.0f32;
            let mut t = 0usize;
            while t < dh {
                let col0 = off + t;
                let s = scales_row[col0 / block];
                expand_block(
                    isa,
                    &k_planes,
                    lane.lut,
                    &codes_row[col0 / 2..(col0 + block) / 2],
                    s,
                    &mut buf[..block],
                );
                // the dot stays a scalar chain in t order — reordering a
                // reduction would change bits
                for (t2, &x) in buf[..block].iter().enumerate() {
                    dot += q_head[t + t2] * x;
                }
                t += block;
            }
            att[j] = dot * scale;
            mx = mx.max(att[j]);
            j += 1;
        }
    }
    let mut z = 0.0f32;
    for a in att.iter_mut().take(rows) {
        *a = (*a - mx).exp();
        z += *a;
    }
    let mut j = 0usize;
    'accum: for p in 0..v.pages_codes.len() {
        let lane = v.page(p);
        let block = lane.block;
        let row_bytes = lane.d / 2;
        let srow_len = lane.d / block;
        for r in 0..v.page_rows {
            if j == rows {
                break 'accum;
            }
            let w = att[j] / z;
            let codes_row = &lane.codes[r * row_bytes..(r + 1) * row_bytes];
            let scales_row = &lane.scales[r * srow_len..(r + 1) * srow_len];
            let mut t = 0usize;
            while t < dh {
                let col0 = off + t;
                let s = scales_row[col0 / block];
                expand_block(
                    isa,
                    &v_planes,
                    lane.lut,
                    &codes_row[col0 / 2..(col0 + block) / 2],
                    s,
                    &mut buf[..block],
                );
                axpy(isa, w, &buf[..block], &mut ctx_head[t..t + block]);
                t += block;
            }
            j += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// x86_64 AVX2 kernels
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::NibbleLut;
    use crate::tensor::GEMM_NR;
    use core::arch::x86_64::*;

    /// AVX2 `[MB, n]` micro-tile: two 8-lane accumulators per row cover the
    /// GEMM_NR=16 columns; per `kk` the broadcast `a` element is multiplied
    /// and added in separate ops (no FMA — see module docs). The column
    /// remainder runs the scalar chains verbatim.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn micro_tile_avx2<const MB: usize>(
        kb: usize,
        k: usize,
        n: usize,
        k0: usize,
        i0: usize,
        a: &[f32],
        b_block: &[f32],
        out: &mut [f32],
    ) {
        unsafe {
            let a_rows: [&[f32]; MB] =
                std::array::from_fn(|r| &a[(i0 + r) * k + k0..(i0 + r) * k + k0 + kb]);
            let mut j0 = 0usize;
            while j0 + GEMM_NR <= n {
                let mut acc_lo = [_mm256_setzero_ps(); MB];
                let mut acc_hi = [_mm256_setzero_ps(); MB];
                let mut boff = j0;
                for kk in 0..kb {
                    let b_lo = _mm256_loadu_ps(b_block.as_ptr().add(boff));
                    let b_hi = _mm256_loadu_ps(b_block.as_ptr().add(boff + 8));
                    for r in 0..MB {
                        let av = _mm256_set1_ps(a_rows[r][kk]);
                        acc_lo[r] = _mm256_add_ps(acc_lo[r], _mm256_mul_ps(av, b_lo));
                        acc_hi[r] = _mm256_add_ps(acc_hi[r], _mm256_mul_ps(av, b_hi));
                    }
                    boff += n;
                }
                for r in 0..MB {
                    let o = out.as_mut_ptr().add((i0 + r) * n + j0);
                    _mm256_storeu_ps(o, _mm256_add_ps(_mm256_loadu_ps(o), acc_lo[r]));
                    let oh = o.add(8);
                    _mm256_storeu_ps(oh, _mm256_add_ps(_mm256_loadu_ps(oh), acc_hi[r]));
                }
                j0 += GEMM_NR;
            }
            if j0 < n {
                // column remainder: the scalar oracle's chains, verbatim
                let rem = n - j0;
                let mut acc = [[0.0f32; GEMM_NR]; MB];
                let mut boff = j0;
                for kk in 0..kb {
                    let b_row = &b_block[boff..boff + rem];
                    for r in 0..MB {
                        let av = a_rows[r][kk];
                        let accr = &mut acc[r];
                        for t in 0..rem {
                            accr[t] += av * b_row[t];
                        }
                    }
                    boff += n;
                }
                for r in 0..MB {
                    let o = (i0 + r) * n + j0;
                    let o_row = &mut out[o..o + rem];
                    for t in 0..rem {
                        o_row[t] += acc[r][t];
                    }
                }
            }
        }
    }

    /// Decode 8 packed bytes (16 nibble codes) into 4 × 4 exact f32 LUT
    /// entries via one `pshufb` per byte plane.
    #[inline(always)]
    unsafe fn gather16(
        p0: __m128i,
        p1: __m128i,
        p2: __m128i,
        p3: __m128i,
        bytes: *const u8,
    ) -> (__m128, __m128, __m128, __m128) {
        unsafe {
            let x = _mm_loadl_epi64(bytes as *const __m128i);
            let nib = _mm_set1_epi8(0x0f);
            let lo = _mm_and_si128(x, nib);
            let hi = _mm_and_si128(_mm_srli_epi16::<4>(x), nib);
            // interleave: idx[2i] = low nibble of byte i (column 2i),
            // idx[2i+1] = high nibble (column 2i+1) — the packed layout
            let idx = _mm_unpacklo_epi8(lo, hi);
            let t0 = _mm_shuffle_epi8(p0, idx);
            let t1 = _mm_shuffle_epi8(p1, idx);
            let t2 = _mm_shuffle_epi8(p2, idx);
            let t3 = _mm_shuffle_epi8(p3, idx);
            // byte-plane transpose back to 16 little-endian f32s
            let b01_lo = _mm_unpacklo_epi8(t0, t1);
            let b01_hi = _mm_unpackhi_epi8(t0, t1);
            let b23_lo = _mm_unpacklo_epi8(t2, t3);
            let b23_hi = _mm_unpackhi_epi8(t2, t3);
            (
                _mm_castsi128_ps(_mm_unpacklo_epi16(b01_lo, b23_lo)),
                _mm_castsi128_ps(_mm_unpackhi_epi16(b01_lo, b23_lo)),
                _mm_castsi128_ps(_mm_unpacklo_epi16(b01_hi, b23_hi)),
                _mm_castsi128_ps(_mm_unpackhi_epi16(b01_hi, b23_hi)),
            )
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn lut_expand_row_avx2(
        planes: &NibbleLut,
        lut: &[f32; 16],
        prow: &[u8],
        srow: &[f32],
        wrow: &mut [f32],
    ) {
        unsafe {
            let p0 = _mm_loadu_si128(planes.planes[0].as_ptr() as *const __m128i);
            let p1 = _mm_loadu_si128(planes.planes[1].as_ptr() as *const __m128i);
            let p2 = _mm_loadu_si128(planes.planes[2].as_ptr() as *const __m128i);
            let p3 = _mm_loadu_si128(planes.planes[3].as_ptr() as *const __m128i);
            let n = wrow.len();
            let mut j = 0usize;
            while j + 16 <= n {
                let (v0, v1, v2, v3) = gather16(p0, p1, p2, p3, prow.as_ptr().add(j / 2));
                let sp = srow.as_ptr().add(j);
                let wp = wrow.as_mut_ptr().add(j);
                _mm_storeu_ps(wp, _mm_mul_ps(v0, _mm_loadu_ps(sp)));
                _mm_storeu_ps(wp.add(4), _mm_mul_ps(v1, _mm_loadu_ps(sp.add(4))));
                _mm_storeu_ps(wp.add(8), _mm_mul_ps(v2, _mm_loadu_ps(sp.add(8))));
                _mm_storeu_ps(wp.add(12), _mm_mul_ps(v3, _mm_loadu_ps(sp.add(12))));
                j += 16;
            }
            super::lut_expand_row_tail(lut, prow, srow, wrow, j);
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn expand_block_avx2(
        planes: &NibbleLut,
        lut: &[f32; 16],
        bytes: &[u8],
        s: f32,
        out: &mut [f32],
    ) {
        unsafe {
            let p0 = _mm_loadu_si128(planes.planes[0].as_ptr() as *const __m128i);
            let p1 = _mm_loadu_si128(planes.planes[1].as_ptr() as *const __m128i);
            let p2 = _mm_loadu_si128(planes.planes[2].as_ptr() as *const __m128i);
            let p3 = _mm_loadu_si128(planes.planes[3].as_ptr() as *const __m128i);
            let sv = _mm_set1_ps(s);
            let n = out.len();
            let mut t = 0usize;
            while t + 16 <= n {
                let (v0, v1, v2, v3) = gather16(p0, p1, p2, p3, bytes.as_ptr().add(t / 2));
                let op = out.as_mut_ptr().add(t);
                _mm_storeu_ps(op, _mm_mul_ps(v0, sv));
                _mm_storeu_ps(op.add(4), _mm_mul_ps(v1, sv));
                _mm_storeu_ps(op.add(8), _mm_mul_ps(v2, sv));
                _mm_storeu_ps(op.add(12), _mm_mul_ps(v3, sv));
                t += 16;
            }
            super::expand_block_tail(lut, bytes, s, out, t);
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_avx2(w: f32, xs: &[f32], ys: &mut [f32]) {
        unsafe {
            let wv = _mm256_set1_ps(w);
            let n = ys.len();
            let mut t = 0usize;
            while t + 8 <= n {
                let yp = ys.as_mut_ptr().add(t);
                let prod = _mm256_mul_ps(wv, _mm256_loadu_ps(xs.as_ptr().add(t)));
                _mm256_storeu_ps(yp, _mm256_add_ps(_mm256_loadu_ps(yp), prod));
                t += 8;
            }
            super::axpy_tail(w, xs, ys, t);
        }
    }
}

// ---------------------------------------------------------------------------
// aarch64 NEON kernels
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::NibbleLut;
    use crate::tensor::GEMM_NR;
    use core::arch::aarch64::*;

    /// NEON `[MB, n]` micro-tile: four 4-lane accumulators per row cover
    /// the GEMM_NR=16 columns; separate `vmul` + `vadd` (no FMA), scalar
    /// column remainder — same contract as the AVX2 tile.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn micro_tile_neon<const MB: usize>(
        kb: usize,
        k: usize,
        n: usize,
        k0: usize,
        i0: usize,
        a: &[f32],
        b_block: &[f32],
        out: &mut [f32],
    ) {
        unsafe {
            let a_rows: [&[f32]; MB] =
                std::array::from_fn(|r| &a[(i0 + r) * k + k0..(i0 + r) * k + k0 + kb]);
            let mut j0 = 0usize;
            while j0 + GEMM_NR <= n {
                let mut acc = [[vdupq_n_f32(0.0); 4]; MB];
                let mut boff = j0;
                for kk in 0..kb {
                    let bp = b_block.as_ptr().add(boff);
                    let b0 = vld1q_f32(bp);
                    let b1 = vld1q_f32(bp.add(4));
                    let b2 = vld1q_f32(bp.add(8));
                    let b3 = vld1q_f32(bp.add(12));
                    for r in 0..MB {
                        let av = vdupq_n_f32(a_rows[r][kk]);
                        acc[r][0] = vaddq_f32(acc[r][0], vmulq_f32(av, b0));
                        acc[r][1] = vaddq_f32(acc[r][1], vmulq_f32(av, b1));
                        acc[r][2] = vaddq_f32(acc[r][2], vmulq_f32(av, b2));
                        acc[r][3] = vaddq_f32(acc[r][3], vmulq_f32(av, b3));
                    }
                    boff += n;
                }
                for r in 0..MB {
                    let o = out.as_mut_ptr().add((i0 + r) * n + j0);
                    for (q, lane) in acc[r].iter().enumerate() {
                        let op = o.add(4 * q);
                        vst1q_f32(op, vaddq_f32(vld1q_f32(op), *lane));
                    }
                }
                j0 += GEMM_NR;
            }
            if j0 < n {
                let rem = n - j0;
                let mut acc = [[0.0f32; GEMM_NR]; MB];
                let mut boff = j0;
                for kk in 0..kb {
                    let b_row = &b_block[boff..boff + rem];
                    for r in 0..MB {
                        let av = a_rows[r][kk];
                        let accr = &mut acc[r];
                        for t in 0..rem {
                            accr[t] += av * b_row[t];
                        }
                    }
                    boff += n;
                }
                for r in 0..MB {
                    let o = (i0 + r) * n + j0;
                    let o_row = &mut out[o..o + rem];
                    for t in 0..rem {
                        o_row[t] += acc[r][t];
                    }
                }
            }
        }
    }

    /// Decode 8 packed bytes into 4 × 4 exact f32 LUT entries via one `tbl`
    /// per byte plane.
    #[inline(always)]
    unsafe fn gather16(
        p0: uint8x16_t,
        p1: uint8x16_t,
        p2: uint8x16_t,
        p3: uint8x16_t,
        bytes: *const u8,
    ) -> (float32x4_t, float32x4_t, float32x4_t, float32x4_t) {
        unsafe {
            let x = vld1_u8(bytes);
            let lo = vand_u8(x, vdup_n_u8(0x0f));
            let hi = vshr_n_u8::<4>(x);
            let idx = vcombine_u8(vzip1_u8(lo, hi), vzip2_u8(lo, hi));
            let t0 = vqtbl1q_u8(p0, idx);
            let t1 = vqtbl1q_u8(p1, idx);
            let t2 = vqtbl1q_u8(p2, idx);
            let t3 = vqtbl1q_u8(p3, idx);
            let b01_lo = vzip1q_u8(t0, t1);
            let b01_hi = vzip2q_u8(t0, t1);
            let b23_lo = vzip1q_u8(t2, t3);
            let b23_hi = vzip2q_u8(t2, t3);
            (
                vreinterpretq_f32_u16(vzip1q_u16(
                    vreinterpretq_u16_u8(b01_lo),
                    vreinterpretq_u16_u8(b23_lo),
                )),
                vreinterpretq_f32_u16(vzip2q_u16(
                    vreinterpretq_u16_u8(b01_lo),
                    vreinterpretq_u16_u8(b23_lo),
                )),
                vreinterpretq_f32_u16(vzip1q_u16(
                    vreinterpretq_u16_u8(b01_hi),
                    vreinterpretq_u16_u8(b23_hi),
                )),
                vreinterpretq_f32_u16(vzip2q_u16(
                    vreinterpretq_u16_u8(b01_hi),
                    vreinterpretq_u16_u8(b23_hi),
                )),
            )
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn lut_expand_row_neon(
        planes: &NibbleLut,
        lut: &[f32; 16],
        prow: &[u8],
        srow: &[f32],
        wrow: &mut [f32],
    ) {
        unsafe {
            let p0 = vld1q_u8(planes.planes[0].as_ptr());
            let p1 = vld1q_u8(planes.planes[1].as_ptr());
            let p2 = vld1q_u8(planes.planes[2].as_ptr());
            let p3 = vld1q_u8(planes.planes[3].as_ptr());
            let n = wrow.len();
            let mut j = 0usize;
            while j + 16 <= n {
                let (v0, v1, v2, v3) = gather16(p0, p1, p2, p3, prow.as_ptr().add(j / 2));
                let sp = srow.as_ptr().add(j);
                let wp = wrow.as_mut_ptr().add(j);
                vst1q_f32(wp, vmulq_f32(v0, vld1q_f32(sp)));
                vst1q_f32(wp.add(4), vmulq_f32(v1, vld1q_f32(sp.add(4))));
                vst1q_f32(wp.add(8), vmulq_f32(v2, vld1q_f32(sp.add(8))));
                vst1q_f32(wp.add(12), vmulq_f32(v3, vld1q_f32(sp.add(12))));
                j += 16;
            }
            super::lut_expand_row_tail(lut, prow, srow, wrow, j);
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn expand_block_neon(
        planes: &NibbleLut,
        lut: &[f32; 16],
        bytes: &[u8],
        s: f32,
        out: &mut [f32],
    ) {
        unsafe {
            let p0 = vld1q_u8(planes.planes[0].as_ptr());
            let p1 = vld1q_u8(planes.planes[1].as_ptr());
            let p2 = vld1q_u8(planes.planes[2].as_ptr());
            let p3 = vld1q_u8(planes.planes[3].as_ptr());
            let sv = vdupq_n_f32(s);
            let n = out.len();
            let mut t = 0usize;
            while t + 16 <= n {
                let (v0, v1, v2, v3) = gather16(p0, p1, p2, p3, bytes.as_ptr().add(t / 2));
                let op = out.as_mut_ptr().add(t);
                vst1q_f32(op, vmulq_f32(v0, sv));
                vst1q_f32(op.add(4), vmulq_f32(v1, sv));
                vst1q_f32(op.add(8), vmulq_f32(v2, sv));
                vst1q_f32(op.add(12), vmulq_f32(v3, sv));
                t += 16;
            }
            super::expand_block_tail(lut, bytes, s, out, t);
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy_neon(w: f32, xs: &[f32], ys: &mut [f32]) {
        unsafe {
            let wv = vdupq_n_f32(w);
            let n = ys.len();
            let mut t = 0usize;
            while t + 4 <= n {
                let yp = ys.as_mut_ptr().add(t);
                let prod = vmulq_f32(wv, vld1q_f32(xs.as_ptr().add(t)));
                vst1q_f32(yp, vaddq_f32(vld1q_f32(yp), prod));
                t += 4;
            }
            super::axpy_tail(w, xs, ys, t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_codes_and_names_are_stable() {
        assert_eq!(Isa::Scalar.code(), 0);
        assert_eq!(Isa::Neon.code(), 1);
        assert_eq!(Isa::Avx2.code(), 2);
        assert_eq!(Isa::Scalar.name(), "scalar");
        assert_eq!(Isa::Neon.name(), "neon");
        assert_eq!(Isa::Avx2.name(), "avx2");
    }

    #[test]
    fn nibble_lut_planes_hold_le_bytes() {
        let lut: [f32; 16] = std::array::from_fn(|i| (i as f32 - 7.5) * 0.25);
        let planes = NibbleLut::new(&lut);
        for (c, &v) in lut.iter().enumerate() {
            let b = v.to_bits().to_le_bytes();
            for p in 0..4 {
                assert_eq!(planes.planes[p][c], b[p], "plane {p} code {c}");
            }
        }
    }

    #[test]
    fn scalar_expand_matches_oracle_expression() {
        let lut: [f32; 16] = std::array::from_fn(|i| (i as f32 - 8.0) * 0.1);
        // 7 columns: odd N leaves the last high nibble unused
        let prow = [0x21u8, 0x43, 0x65, 0x07];
        let srow = [1.0f32, 0.5, 0.25, 2.0, 1.5, 0.75, 3.0];
        let mut wrow = [0.0f32; 7];
        lut_expand_row_tail(&lut, &prow, &srow, &mut wrow, 0);
        let codes = [1usize, 2, 3, 4, 5, 6, 7];
        for (j, &c) in codes.iter().enumerate() {
            assert_eq!(wrow[j], lut[c] * srow[j], "col {j}");
        }
    }
}
