//! Small dense linear algebra in f64 — Cholesky factorization and SPD
//! solves, used by the GPTQ quantizer's inverse-Hessian updates.

/// Cholesky factorization of a symmetric positive-definite matrix.
///
/// `a` is row-major `n x n`; returns lower-triangular `L` with `L L^T = A`.
/// Fails (None) if the matrix is not positive definite — GPTQ handles this
/// by increasing the damping term.
pub fn cholesky(a: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + j] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Solve `A x = b` given the Cholesky factor `L` of `A`.
pub fn cholesky_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    // forward: L y = b
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[i * n + k] * y[k];
        }
        y[i] = sum / l[i * n + i];
    }
    // backward: L^T x = y
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l[k * n + i] * x[k];
        }
        x[i] = sum / l[i * n + i];
    }
    x
}

/// Invert a symmetric positive-definite matrix via Cholesky.
pub fn invert_spd(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let l = cholesky(a, n)?;
    let mut inv = vec![0.0f64; n * n];
    let mut e = vec![0.0f64; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = cholesky_solve(&l, n, &e);
        for i in 0..n {
            inv[i * n + j] = col[i];
        }
        e[j] = 0.0;
    }
    Some(inv)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matmul(a: &[f64], b: &[f64], n: usize) -> Vec<f64> {
        let mut c = vec![0.0; n * n];
        for i in 0..n {
            for k in 0..n {
                for j in 0..n {
                    c[i * n + j] += a[i * n + k] * b[k * n + j];
                }
            }
        }
        c
    }

    fn spd(n: usize, seed: u64) -> Vec<f64> {
        // A = B B^T + n I is SPD for any B.
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) * 2.0 - 1.0
        };
        let b: Vec<f64> = (0..n * n).map(|_| next()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    a[i * n + j] += b[i * n + k] * b[j * n + k];
                }
            }
            a[i * n + i] += n as f64;
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        for n in [1, 2, 5, 17] {
            let a = spd(n, n as u64);
            let l = cholesky(&a, n).expect("spd");
            let mut lt = vec![0.0; n * n];
            for i in 0..n {
                for j in 0..n {
                    lt[i * n + j] = l[j * n + i];
                }
            }
            let rec = matmul(&l, &lt, n);
            for (x, y) in rec.iter().zip(&a) {
                assert!((x - y).abs() < 1e-8, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn solve_and_invert() {
        let n = 9;
        let a = spd(n, 3);
        let l = cholesky(&a, n).unwrap();
        let b: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let x = cholesky_solve(&l, n, &b);
        // check A x = b
        for i in 0..n {
            let mut acc = 0.0;
            for j in 0..n {
                acc += a[i * n + j] * x[j];
            }
            assert!((acc - b[i]).abs() < 1e-8);
        }
        let inv = invert_spd(&a, n).unwrap();
        let id = matmul(&a, &inv, n);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((id[i * n + j] - want).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn non_spd_rejected() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_none());
    }
}
