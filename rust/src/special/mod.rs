//! Special functions: the numerics substrate behind the SF-format derivation
//! (Student-t quantiles), the distribution fitting (t log-likelihood, CDFs)
//! and the KS tests.
//!
//! Everything is f64 and self-contained (no libm beyond std): lgamma
//! (Lanczos), erf/erfc (Abramowitz-Stegun 7.1.26 refined), regularized
//! incomplete beta (Lentz continued fraction) and the normal / Student-t
//! distribution family built on top.

use std::f64::consts::PI;

/// ln Γ(x) via the Lanczos approximation (g = 7, n = 9), |err| < 1e-13.
pub fn lgamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection: Γ(x) Γ(1-x) = π / sin(πx)
        return (PI / (PI * x).sin()).ln() - lgamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Γ(x) for moderate arguments.
pub fn gamma(x: f64) -> f64 {
    if x < 0.5 {
        PI / ((PI * x).sin() * gamma(1.0 - x))
    } else {
        lgamma(x).exp()
    }
}

/// Error function, |err| < 1.2e-7 raw, refined by one series step where it
/// matters; sufficient for CDF work (we never differentiate through this).
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// Complementary error function (rational approximation, W. J. Cody style).
pub fn erfc(x: f64) -> f64 {
    if x == 0.0 {
        return 1.0; // exact; the rational approx is only ~1e-7 here
    }
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    // Numerical Recipes erfc: fractional error < 1.2e-7 everywhere.
    let t = 1.0 / (1.0 + 0.5 * x);
    let tau = t
        * (-x * x - 1.265_512_23
            + t * (1.000_023_68
                + t * (0.374_091_96
                    + t * (0.096_784_18
                        + t * (-0.186_288_06
                            + t * (0.278_868_07
                                + t * (-1.135_203_98
                                    + t * (1.488_515_87
                                        + t * (-0.822_152_23
                                            + t * 0.170_872_77)))))))))
            .exp();
    tau
}

// ---------------------------------------------------------------------------
// Regularized incomplete beta
// ---------------------------------------------------------------------------

/// Regularized incomplete beta I_x(a, b) via Lentz's continued fraction.
pub fn betainc(a: f64, b: f64, x: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0, "betainc domain");
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front =
        lgamma(a + b) - lgamma(a) - lgamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    // `<=` matters: at x exactly equal to the switch point the complement
    // branch would recurse forever (1-x lands exactly on its own threshold).
    if x <= (a + 1.0) / (a + b + 2.0) {
        front * beta_cf(a, b, x) / a
    } else {
        1.0 - betainc(b, a, 1.0 - x)
    }
}

fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 1e-15;
    const TINY: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < TINY {
        d = TINY;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        // even step
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        h *= d * c;
        // odd step
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Inverse of the regularized incomplete beta: find x with I_x(a,b) = p.
/// Bisection + Newton polish; monotonic, robust for all (a, b) we use.
pub fn betaincinv(a: f64, b: f64, p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p));
    if p == 0.0 {
        return 0.0;
    }
    if p == 1.0 {
        return 1.0;
    }
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    let mut x = 0.5;
    for _ in 0..200 {
        let v = betainc(a, b, x);
        if v < p {
            lo = x;
        } else {
            hi = x;
        }
        x = 0.5 * (lo + hi);
        if hi - lo < 1e-15 {
            break;
        }
    }
    x
}

// ---------------------------------------------------------------------------
// Normal distribution
// ---------------------------------------------------------------------------

pub mod normal {
    use super::*;

    pub fn pdf(x: f64) -> f64 {
        (-(x * x) / 2.0).exp() / (2.0 * PI).sqrt()
    }

    pub fn cdf(x: f64) -> f64 {
        0.5 * erfc(-x / std::f64::consts::SQRT_2)
    }

    /// Quantile via Acklam's rational approximation + one Halley refinement.
    pub fn ppf(p: f64) -> f64 {
        assert!((0.0..=1.0).contains(&p), "ppf domain: {p}");
        if p == 0.0 {
            return f64::NEG_INFINITY;
        }
        if p == 1.0 {
            return f64::INFINITY;
        }
        if p == 0.5 {
            return 0.0;
        }
        const A: [f64; 6] = [
            -3.969_683_028_665_376e1,
            2.209_460_984_245_205e2,
            -2.759_285_104_469_687e2,
            1.383_577_518_672_690e2,
            -3.066_479_806_614_716e1,
            2.506_628_277_459_239,
        ];
        const B: [f64; 5] = [
            -5.447_609_879_822_406e1,
            1.615_858_368_580_409e2,
            -1.556_989_798_598_866e2,
            6.680_131_188_771_972e1,
            -1.328_068_155_288_572e1,
        ];
        const C: [f64; 6] = [
            -7.784_894_002_430_293e-3,
            -3.223_964_580_411_365e-1,
            -2.400_758_277_161_838,
            -2.549_732_539_343_734,
            4.374_664_141_464_968,
            2.938_163_982_698_783,
        ];
        const D: [f64; 4] = [
            7.784_695_709_041_462e-3,
            3.224_671_290_700_398e-1,
            2.445_134_137_142_996,
            3.754_408_661_907_416,
        ];
        let p_low = 0.02425;
        let x = if p < p_low {
            let q = (-2.0 * p.ln()).sqrt();
            (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
                / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
        } else if p <= 1.0 - p_low {
            let q = p - 0.5;
            let r = q * q;
            (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
                / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
        } else {
            let q = (-2.0 * (1.0 - p).ln()).sqrt();
            -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
                / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
        };
        // one Halley step using the exact cdf/pdf
        let e = cdf(x) - p;
        let u = e * (2.0 * PI).sqrt() * (x * x / 2.0).exp();
        x - u / (1.0 + x * u / 2.0)
    }
}

// ---------------------------------------------------------------------------
// Student-t distribution
// ---------------------------------------------------------------------------

pub mod student_t {
    use super::*;

    /// PDF of the standard t-distribution (paper Eq. 1).
    pub fn pdf(t: f64, nu: f64) -> f64 {
        let c = (lgamma((nu + 1.0) / 2.0) - lgamma(nu / 2.0)).exp()
            / (nu * PI).sqrt();
        c * (1.0 + t * t / nu).powf(-(nu + 1.0) / 2.0)
    }

    /// ln pdf (used by the MLE fit to avoid under/overflow).
    pub fn ln_pdf(t: f64, nu: f64) -> f64 {
        lgamma((nu + 1.0) / 2.0)
            - lgamma(nu / 2.0)
            - 0.5 * (nu * PI).ln()
            - (nu + 1.0) / 2.0 * (1.0 + t * t / nu).ln()
    }

    /// CDF via the regularized incomplete beta.
    pub fn cdf(t: f64, nu: f64) -> f64 {
        if t == 0.0 {
            return 0.5;
        }
        let x = nu / (nu + t * t);
        let tail = 0.5 * betainc(nu / 2.0, 0.5, x);
        if t > 0.0 {
            1.0 - tail
        } else {
            tail
        }
    }

    /// Quantile function Q_S(p; nu) — the heart of the SF4 derivation
    /// (paper Algorithm 1, step 3). Exact inverse via betaincinv.
    pub fn ppf(p: f64, nu: f64) -> f64 {
        assert!((0.0..=1.0).contains(&p));
        if p == 0.5 {
            return 0.0;
        }
        if p == 0.0 {
            return f64::NEG_INFINITY;
        }
        if p == 1.0 {
            return f64::INFINITY;
        }
        let tail = if p < 0.5 { p } else { 1.0 - p };
        // invert: 2*tail = I_x(nu/2, 1/2) with x = nu/(nu+t^2)
        let x = betaincinv(nu / 2.0, 0.5, 2.0 * tail);
        let t = (nu * (1.0 - x) / x).sqrt();
        if p < 0.5 {
            -t
        } else {
            t
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lgamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(3)=2, Γ(0.5)=sqrt(pi)
        assert!((lgamma(1.0)).abs() < 1e-12);
        assert!((lgamma(2.0)).abs() < 1e-12);
        assert!((lgamma(3.0) - 2.0f64.ln()).abs() < 1e-12);
        assert!((lgamma(0.5) - PI.sqrt().ln()).abs() < 1e-12);
        // recurrence Γ(x+1) = x Γ(x)
        for x in [0.3, 1.7, 4.2, 9.9] {
            assert!((lgamma(x + 1.0) - (lgamma(x) + x.ln())).abs() < 1e-11);
        }
    }

    #[test]
    fn erf_known_values() {
        assert!(erf(0.0).abs() < 1e-15);
        assert!((erf(1.0) - 0.842_700_792_949_714_9).abs() < 1e-6);
        assert!((erf(-1.0) + 0.842_700_792_949_714_9).abs() < 1e-6);
        assert!((erf(3.0) - 0.999_977_909_503_001_4).abs() < 1e-6);
    }

    #[test]
    fn betainc_symmetry_and_bounds() {
        for (a, b, x) in [(2.5, 0.5, 0.3), (1.0, 1.0, 0.7), (5.0, 2.0, 0.9)] {
            let v = betainc(a, b, x);
            assert!((0.0..=1.0).contains(&v));
            // I_x(a,b) = 1 - I_{1-x}(b,a)
            assert!((v - (1.0 - betainc(b, a, 1.0 - x))).abs() < 1e-12);
        }
        // I_x(1,1) = x (uniform)
        assert!((betainc(1.0, 1.0, 0.42) - 0.42).abs() < 1e-12);
    }

    #[test]
    fn betaincinv_roundtrip() {
        for (a, b) in [(2.5, 0.5), (0.5, 0.5), (3.0, 7.0)] {
            for p in [0.01, 0.2, 0.5, 0.8, 0.99] {
                let x = betaincinv(a, b, p);
                assert!((betainc(a, b, x) - p).abs() < 1e-10, "{a} {b} {p}");
            }
        }
    }

    #[test]
    fn normal_cdf_ppf_roundtrip() {
        for p in [1e-6, 0.01, 0.3, 0.5, 0.77, 0.999] {
            let x = normal::ppf(p);
            assert!((normal::cdf(x) - p).abs() < 1e-7, "p={p}");
        }
        assert!(normal::ppf(0.5).abs() < 1e-9);
        // scipy.stats.norm.ppf(0.975) = 1.959963984540054
        assert!((normal::ppf(0.975) - 1.959_963_984_540_054).abs() < 1e-6);
    }

    #[test]
    fn t_cdf_matches_normal_at_high_nu() {
        for x in [-2.0, -0.5, 0.0, 1.0, 2.5] {
            let tn = student_t::cdf(x, 1e7);
            assert!((tn - normal::cdf(x)).abs() < 1e-5, "x={x}");
        }
    }

    #[test]
    fn t_ppf_known_values() {
        // scipy.stats.t.ppf(0.975, 5) = 2.5705818366147395
        assert!((student_t::ppf(0.975, 5.0) - 2.570_581_836_614_74).abs() < 1e-8);
        // scipy.stats.t.ppf(0.9, 3) = 1.6377443536962102
        assert!((student_t::ppf(0.9, 3.0) - 1.637_744_353_696_21).abs() < 1e-8);
        // symmetry
        for nu in [2.0, 5.0, 30.0] {
            for p in [0.05, 0.2, 0.4] {
                assert!(
                    (student_t::ppf(p, nu) + student_t::ppf(1.0 - p, nu)).abs()
                        < 1e-9
                );
            }
        }
    }

    #[test]
    fn t_cdf_ppf_roundtrip() {
        for nu in [1.5, 3.0, 5.0, 12.0] {
            for p in [0.03, 0.25, 0.5, 0.66, 0.97] {
                let t = student_t::ppf(p, nu);
                assert!((student_t::cdf(t, nu) - p).abs() < 1e-9, "nu={nu} p={p}");
            }
        }
    }

    #[test]
    fn t_pdf_integrates_to_one() {
        // trapezoid over [-60, 60] at nu=2 (fat tails need wide range)
        let n = 20_000;
        let (lo, hi) = (-60.0, 60.0);
        let h = (hi - lo) / n as f64;
        let mut total = 0.0;
        for i in 0..=n {
            let x = lo + i as f64 * h;
            let w = if i == 0 || i == n { 0.5 } else { 1.0 };
            total += w * student_t::pdf(x, 2.0);
        }
        total *= h;
        assert!((total - 1.0).abs() < 1e-3, "{total}");
    }

    #[test]
    fn t_pdf_matches_ln_pdf() {
        for nu in [1.0, 4.0, 9.5] {
            for x in [-3.0, 0.0, 0.7, 8.0] {
                let a = student_t::pdf(x, nu).ln();
                let b = student_t::ln_pdf(x, nu);
                assert!((a - b).abs() < 1e-10);
            }
        }
    }
}
