//! Model configs (mirroring `python/compile/model.py`'s zoo), the canonical
//! parameter layout, and a binary checkpoint format shared by the training
//! driver, the quantization pipeline and the pure-Rust reference model.
//!
//! Checkpoint file layout (little-endian):
//! `LLMDT001` magic, u32 tensor count, then per tensor:
//! u32 name-len, name bytes, u32 ndim, u64 dims..., f32 data...

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant::{ActQuantizer, PackedWeight};
use crate::tensor::Tensor;

/// Decoder-only LM hyperparameters — must stay in sync with `model.py` ZOO
/// (the cross-check test validates against artifact manifests).
#[derive(Clone, Copy, Debug)]
pub struct ModelConfig {
    pub name: &'static str,
    pub vocab: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub batch_eval: usize,
    pub batch_train: usize,
    pub train_steps: usize,
}

pub const ZOO: [ModelConfig; 5] = [
    ModelConfig { name: "nano", vocab: 64, seq: 32, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 128, batch_eval: 4, batch_train: 16, train_steps: 60 },
    ModelConfig { name: "micro", vocab: 128, seq: 64, d_model: 64, n_layers: 2, n_heads: 4, d_ff: 256, batch_eval: 8, batch_train: 16, train_steps: 300 },
    ModelConfig { name: "small", vocab: 128, seq: 64, d_model: 128, n_layers: 4, n_heads: 4, d_ff: 512, batch_eval: 8, batch_train: 16, train_steps: 300 },
    ModelConfig { name: "med", vocab: 128, seq: 128, d_model: 256, n_layers: 4, n_heads: 8, d_ff: 1024, batch_eval: 8, batch_train: 8, train_steps: 300 },
    ModelConfig { name: "large", vocab: 128, seq: 128, d_model: 384, n_layers: 6, n_heads: 8, d_ff: 1536, batch_eval: 8, batch_train: 4, train_steps: 200 },
];

pub fn zoo(name: &str) -> Result<ModelConfig> {
    ZOO.iter().copied().find(|c| c.name == name).with_context(|| format!("unknown model `{name}`"))
}

/// The six quantized linear leaves per layer (every nn.Linear of the paper).
pub const QUANT_LINEARS: [&str; 6] = ["wq", "wk", "wv", "wo", "w1", "w2"];

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Canonical fp32 (name, shape) parameter list — same order as model.py.
    pub fn param_specs(&self) -> Vec<(String, Vec<usize>)> {
        let (d, f, v, s) = (self.d_model, self.d_ff, self.vocab, self.seq);
        let mut specs: Vec<(String, Vec<usize>)> =
            vec![("embed".into(), vec![v, d]), ("pos".into(), vec![s, d])];
        for i in 0..self.n_layers {
            for (leaf, shape) in [
                ("ln1_g", vec![d]),
                ("ln1_b", vec![d]),
                ("wq", vec![d, d]),
                ("wk", vec![d, d]),
                ("wv", vec![d, d]),
                ("wo", vec![d, d]),
                ("ln2_g", vec![d]),
                ("ln2_b", vec![d]),
                ("w1", vec![d, f]),
                ("w2", vec![f, d]),
            ] {
                specs.push((format!("l{i}.{leaf}"), shape));
            }
        }
        specs.push(("lnf_g".into(), vec![d]));
        specs.push(("lnf_b".into(), vec![d]));
        specs.push(("head".into(), vec![d, v]));
        specs
    }

    /// Names of the quantized linear weights, e.g. `l0.wq`.
    pub fn quant_linear_names(&self) -> Vec<String> {
        let mut out = Vec::new();
        for i in 0..self.n_layers {
            for leaf in QUANT_LINEARS {
                out.push(format!("l{i}.{leaf}"));
            }
        }
        out
    }

    pub fn n_params(&self) -> usize {
        self.param_specs().iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

// ---------------------------------------------------------------------------
// Checkpoints
// ---------------------------------------------------------------------------

/// Which executor a named linear weight runs through at serve time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinearBackend {
    /// Dense f32 tensor (fp32 weights or fake-quant dequantized weights):
    /// `Tensor::matmul` through the blocked `tensor::gemm` kernel.
    Dense,
    /// 4-bit packed codes + per-block scales (`quant::PackedWeight`),
    /// consumed in place by the fused `quant::lut_gemm` — the weight never
    /// exists as an f32 matrix.
    Packed4,
    /// W4A4: packed 4-bit weights *and* activations encoded on the fly
    /// through the checkpoint's `ActQuantizer`, multiplied code x code by
    /// `quant::w4a4_gemm`'s 256-entry product LUT. Active for every packed
    /// linear once an activation quantizer is installed.
    PackedW4a4,
}

/// Ordered named tensors (insertion order = canonical parameter order),
/// plus an optional packed 4-bit store per linear. A name present in the
/// packed store dispatches that linear to [`LinearBackend::Packed4`]
/// (`nn::apply_linear`); everything else stays dense. Packed entries are
/// runtime-only — `save`/`load` round-trip the dense tensors.
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    names: Vec<String>,
    map: HashMap<String, Tensor>,
    packed_names: Vec<String>,
    packed: HashMap<String, PackedWeight>,
    /// When set, every packed linear runs W4A4: activations are encoded
    /// through this quantizer (with the weight's own scale block) and the
    /// GEMM streams 4-bit codes on both sides. Runtime-only, like the
    /// packed store.
    act_quant: Option<ActQuantizer>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        if !self.map.contains_key(name) {
            self.names.push(name.to_string());
        }
        self.map.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).with_context(|| format!("checkpoint missing tensor `{name}`"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    /// Store a packed 4-bit weight for `name`; from now on the forwards run
    /// this linear through the fused LUT path.
    pub fn insert_packed(&mut self, name: &str, w: PackedWeight) {
        if !self.packed.contains_key(name) {
            self.packed_names.push(name.to_string());
        }
        self.packed.insert(name.to_string(), w);
    }

    pub fn get_packed(&self, name: &str) -> Result<&PackedWeight> {
        self.packed
            .get(name)
            .with_context(|| format!("checkpoint missing packed weight `{name}`"))
    }

    /// Install (or clear) the W4A4 activation quantizer: with one set,
    /// every packed linear dispatches to [`LinearBackend::PackedW4a4`].
    pub fn set_act_quant(&mut self, aq: Option<ActQuantizer>) {
        self.act_quant = aq;
    }

    /// The W4A4 activation quantizer, if one is installed.
    pub fn act_quant(&self) -> Option<&ActQuantizer> {
        self.act_quant.as_ref()
    }

    /// Backend for one named linear: packed wins when present, upgraded to
    /// W4A4 when an activation quantizer is installed.
    pub fn backend(&self, name: &str) -> LinearBackend {
        if self.packed.contains_key(name) {
            if self.act_quant.is_some() {
                LinearBackend::PackedW4a4
            } else {
                LinearBackend::Packed4
            }
        } else {
            LinearBackend::Dense
        }
    }

    /// Names with packed weights (insertion order).
    pub fn packed_names(&self) -> &[String] {
        &self.packed_names
    }

    pub fn has_packed(&self) -> bool {
        !self.packed_names.is_empty()
    }

    /// Total packed-store footprint in bytes (codes + scales + LUTs).
    pub fn packed_bytes(&self) -> usize {
        self.packed.values().map(|w| w.bytes()).sum()
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn total_params(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    const MAGIC: &'static [u8; 8] = b"LLMDT001";

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        // The file format holds dense tensors only. Refuse rather than
        // silently write a checkpoint missing every packed linear — the
        // loss would only surface as a `missing tensor` error on the first
        // forward after a later load.
        anyhow::ensure!(
            !self.has_packed(),
            "checkpoint holds {} packed weight(s) ({} ...); the binary format is dense-only \
             — save the source fp32/fake-quant checkpoint instead",
            self.packed_names.len(),
            self.packed_names.first().map(String::as_str).unwrap_or("")
        );
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(Self::MAGIC)?;
        w.write_all(&(self.names.len() as u32).to_le_bytes())?;
        for name in &self.names {
            let t = &self.map[name];
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&(t.ndim() as u32).to_le_bytes())?;
            for &d in t.shape() {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            let bytes = unsafe {
                std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.len() * 4)
            };
            w.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != Self::MAGIC {
            bail!("{}: not a checkpoint file", path.display());
        }
        let mut u32b = [0u8; 4];
        let mut u64b = [0u8; 8];
        r.read_exact(&mut u32b)?;
        let count = u32::from_le_bytes(u32b) as usize;
        let mut ckpt = Checkpoint::new();
        for _ in 0..count {
            r.read_exact(&mut u32b)?;
            let name_len = u32::from_le_bytes(u32b) as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name)?;
            r.read_exact(&mut u32b)?;
            let ndim = u32::from_le_bytes(u32b) as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                r.read_exact(&mut u64b)?;
                dims.push(u64::from_le_bytes(u64b) as usize);
            }
            let n: usize = dims.iter().product();
            let mut data = vec![0f32; n];
            let bytes = unsafe {
                std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, n * 4)
            };
            r.read_exact(bytes)?;
            ckpt.insert(&name, Tensor::new(&dims, data));
        }
        Ok(ckpt)
    }
}

/// Checkpoint file path for a zoo model.
pub fn checkpoint_path(dir: &str, model: &str) -> std::path::PathBuf {
    Path::new(dir).join(format!("{model}.ckpt"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_param_counts_are_consistent() {
        for cfg in ZOO {
            let specs = cfg.param_specs();
            assert_eq!(specs.len(), 2 + 10 * cfg.n_layers + 3, "{}", cfg.name);
            assert_eq!(cfg.quant_linear_names().len(), 6 * cfg.n_layers);
            assert!(cfg.n_params() > 0);
        }
        // micro ~ 0.2M, med ~ 3.3M: orders of magnitude sanity
        let micro = zoo("micro").unwrap().n_params();
        let med = zoo("med").unwrap().n_params();
        assert!(micro > 100_000 && micro < 500_000, "{micro}");
        assert!(med > 2_000_000 && med < 6_000_000, "{med}");
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join("llmdt_ckpt_test");
        let path = dir.join("t.ckpt");
        let mut c = Checkpoint::new();
        c.insert("a", Tensor::from_fn(&[3, 4], |i| i as f32 * 0.5));
        c.insert("b.c", Tensor::scalar(7.25));
        c.save(&path).unwrap();
        let d = Checkpoint::load(&path).unwrap();
        assert_eq!(d.names(), c.names());
        assert_eq!(d.get("a").unwrap(), c.get("a").unwrap());
        assert_eq!(d.get("b.c").unwrap().data(), &[7.25]);
        assert!(d.get("missing").is_err());
    }

    #[test]
    fn checkpoint_rejects_garbage() {
        let dir = std::env::temp_dir().join("llmdt_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn packed_entries_dispatch_and_survive_clone_but_not_save() {
        use crate::formats;
        use crate::quant::{quantize_weight, BlockSize, Calib, QuantConfig};
        let spec = formats::must("sf4");
        let w = Tensor::from_fn(&[32, 4], |i| ((i % 13) as f32 - 6.0) * 0.1);
        let q = quantize_weight(
            &w,
            &QuantConfig { format: spec.clone(), block: BlockSize::Sub(32), calib: Calib::None },
        );
        let mut c = Checkpoint::new();
        c.insert("dense", Tensor::scalar(1.0));
        c.insert_packed("l0.wq", PackedWeight::from_quantized(&q, &spec));
        assert_eq!(c.backend("l0.wq"), LinearBackend::Packed4);
        assert_eq!(c.backend("dense"), LinearBackend::Dense);
        assert_eq!(c.backend("missing"), LinearBackend::Dense);
        assert!(c.has_packed());
        assert_eq!(c.packed_names(), &["l0.wq".to_string()]);
        assert!(c.packed_bytes() > 0);
        assert!(c.get("l0.wq").is_err(), "packed-only weights have no dense tensor");
        let c2 = c.clone();
        assert_eq!(
            c2.get_packed("l0.wq").unwrap().packed,
            c.get_packed("l0.wq").unwrap().packed,
            "packed store survives Clone (the engine clones checkpoints)"
        );
        // the binary format is dense-only: saving a packed checkpoint must
        // refuse loudly instead of silently dropping the packed linears
        let dir = std::env::temp_dir().join("llmdt_ckpt_packed");
        let path = dir.join("p.ckpt");
        let err = c.save(&path).unwrap_err();
        assert!(err.to_string().contains("packed"), "{err}");
        // a dense-only checkpoint still round-trips
        let mut plain = Checkpoint::new();
        plain.insert("dense", Tensor::scalar(1.0));
        plain.save(&path).unwrap();
        let d = Checkpoint::load(&path).unwrap();
        assert!(!d.has_packed());
        assert_eq!(d.names(), &["dense".to_string()]);
    }

    #[test]
    fn act_quant_upgrades_packed_backend_to_w4a4() {
        use crate::formats;
        use crate::quant::{quantize_weight, BlockSize, Calib, QuantConfig};
        let spec = formats::must("sf4");
        let w = Tensor::from_fn(&[32, 4], |i| ((i % 11) as f32 - 5.0) * 0.1);
        let q = quantize_weight(
            &w,
            &QuantConfig { format: spec.clone(), block: BlockSize::Sub(32), calib: Calib::None },
        );
        let mut c = Checkpoint::new();
        c.insert_packed("l0.wq", PackedWeight::from_quantized(&q, &spec));
        assert_eq!(c.backend("l0.wq"), LinearBackend::Packed4);
        c.set_act_quant(Some(ActQuantizer::new(&spec)));
        assert_eq!(c.backend("l0.wq"), LinearBackend::PackedW4a4);
        assert_eq!(c.backend("missing"), LinearBackend::Dense, "dense stays dense under W4A4");
        assert_eq!(c.act_quant().unwrap().name, "sf4");
        // the quantizer survives Clone with the packed store
        let c2 = c.clone();
        assert_eq!(c2.backend("l0.wq"), LinearBackend::PackedW4a4);
        c.set_act_quant(None);
        assert_eq!(c.backend("l0.wq"), LinearBackend::Packed4, "clearing downgrades");
    }

    #[test]
    fn insert_overwrites_in_place() {
        let mut c = Checkpoint::new();
        c.insert("x", Tensor::scalar(1.0));
        c.insert("x", Tensor::scalar(2.0));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get("x").unwrap().data(), &[2.0]);
    }
}
