//! Synthetic data: Zipf-Markov language corpora (the LM training/eval
//! substitute for the paper's web-text datasets) and Gaussian-cluster image
//! sets (the ImageNet substitute for the vision models of Table 9).
//!
//! A "language" is a seeded Markov chain over the token vocabulary whose
//! per-state emission ranking is a permuted Zipf distribution. Different
//! languages (Table 14's multi-lingual suite) use different Zipf exponents
//! and permutation seeds, giving corpora with distinct statistics but the
//! same mechanics — models transfer imperfectly across them, exactly the
//! stress the multi-lingual table applies.

use crate::rng::{Pcg64, Zipf};
use crate::tensor::Tensor;

/// A synthetic language: Markov transition structure over `vocab` tokens.
pub struct Language {
    pub name: String,
    pub vocab: usize,
    /// per-state permutation of the Zipf ranking
    perms: Vec<Vec<u32>>,
    zipf: Zipf,
    /// interpolation to the unigram distribution (smoothing)
    pub smoothing: f64,
}

/// The five "languages" of the multi-lingual suite (Table 14 roles).
pub const LANGUAGES: [(&str, f64, u64, f64); 5] = [
    ("en", 1.25, 11, 0.05),
    ("fr", 1.10, 23, 0.10),
    ("de", 1.40, 37, 0.10),
    ("it", 1.05, 51, 0.15),
    ("es", 1.18, 67, 0.12),
];

impl Language {
    pub fn new(name: &str, vocab: usize, zipf_s: f64, seed: u64, smoothing: f64) -> Language {
        let mut rng = Pcg64::with_stream(seed, 0x11);
        // a handful of shared "syntax classes" keeps the chain learnable:
        // each state uses one of `n_classes` permutations.
        let n_classes = 16.min(vocab);
        let mut class_perms: Vec<Vec<u32>> = Vec::with_capacity(n_classes);
        for _ in 0..n_classes {
            let mut perm: Vec<u32> = (0..vocab as u32).collect();
            rng.shuffle(&mut perm);
            class_perms.push(perm);
        }
        let perms =
            (0..vocab).map(|s| class_perms[s % n_classes].clone()).collect();
        Language { name: name.to_string(), vocab, perms, zipf: Zipf::new(vocab, zipf_s), smoothing }
    }

    /// The default language for a given model role (keyed by seed).
    pub fn default_for(vocab: usize, seed: u64) -> Language {
        // zipf 1.1 + 10% smoothing keeps next-token argmax margins narrow
        // enough that 4-bit formats separate on completion accuracy.
        Language::new("en", vocab, 1.1, seed, 0.10)
    }

    pub fn by_name(name: &str, vocab: usize) -> Language {
        let (n, s, seed, sm) = LANGUAGES
            .iter()
            .copied()
            .find(|(l, ..)| *l == name)
            .unwrap_or(LANGUAGES[0]);
        Language::new(n, vocab, s, seed, sm)
    }

    /// Sample the next token given the previous one.
    pub fn next(&self, prev: usize, rng: &mut Pcg64) -> usize {
        if rng.uniform() < self.smoothing {
            return rng.below(self.vocab);
        }
        let rank = self.zipf.sample(rng);
        self.perms[prev][rank] as usize
    }

    /// Generate a token stream of length `n`.
    pub fn stream(&self, n: usize, rng: &mut Pcg64) -> Vec<i32> {
        let mut out = Vec::with_capacity(n);
        let mut prev = rng.below(self.vocab);
        for _ in 0..n {
            let t = self.next(prev, rng);
            out.push(t as i32);
            prev = t;
        }
        out
    }
}

/// A corpus: train stream + held-out stream from the same language.
pub struct Corpus {
    pub language: String,
    pub vocab: usize,
    pub train: Vec<i32>,
    pub heldout: Vec<i32>,
}

impl Corpus {
    /// Build deterministically from (language, vocab, seed).
    pub fn build(lang: &Language, train_len: usize, heldout_len: usize, seed: u64) -> Corpus {
        let mut rng = Pcg64::with_stream(seed, 0x22);
        Corpus {
            language: lang.name.clone(),
            vocab: lang.vocab,
            train: lang.stream(train_len, &mut rng),
            heldout: lang.stream(heldout_len, &mut rng),
        }
    }

    /// Random [B, S+1] training batch (flattened row-major), i32 tokens.
    pub fn batch(&self, b: usize, s: usize, rng: &mut Pcg64) -> Vec<i32> {
        let mut out = Vec::with_capacity(b * (s + 1));
        for _ in 0..b {
            let start = rng.below(self.train.len() - s - 1);
            out.extend_from_slice(&self.train[start..start + s + 1]);
        }
        out
    }

    /// Deterministic non-overlapping held-out windows `[n, S+1]`.
    pub fn heldout_windows(&self, n: usize, s: usize) -> Vec<Vec<i32>> {
        let mut out = Vec::with_capacity(n);
        let mut pos = 0;
        while out.len() < n && pos + s + 1 <= self.heldout.len() {
            out.push(self.heldout[pos..pos + s + 1].to_vec());
            pos += s + 1;
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Synthetic images (vision roles)
// ---------------------------------------------------------------------------

/// Gaussian-cluster image set: each class has a smooth random prototype;
/// samples are prototype + noise. 16x16 single channel, values ~ N(0,1).
pub struct ImageSet {
    pub side: usize,
    pub classes: usize,
    prototypes: Vec<Vec<f32>>,
    pub noise: f32,
}

impl ImageSet {
    pub fn new(side: usize, classes: usize, seed: u64, noise: f32) -> ImageSet {
        let mut rng = Pcg64::with_stream(seed, 0x33);
        let n = side * side;
        let mut prototypes = Vec::with_capacity(classes);
        for _ in 0..classes {
            // smooth pattern: sum of a few random low-frequency waves
            let mut img = vec![0.0f32; n];
            for _ in 0..4 {
                let fx = rng.range(0.5, 3.0);
                let fy = rng.range(0.5, 3.0);
                let px = rng.range(0.0, std::f64::consts::TAU);
                let py = rng.range(0.0, std::f64::consts::TAU);
                let amp = rng.range(0.4, 1.0);
                for y in 0..side {
                    for x in 0..side {
                        let vx = (fx * x as f64 / side as f64 * std::f64::consts::TAU + px).sin();
                        let vy = (fy * y as f64 / side as f64 * std::f64::consts::TAU + py).cos();
                        img[y * side + x] += (amp * vx * vy) as f32;
                    }
                }
            }
            prototypes.push(img);
        }
        ImageSet { side, classes, prototypes, noise }
    }

    /// Sample a batch: returns (images `[B, side*side]`, labels `[B]`).
    pub fn batch(&self, b: usize, rng: &mut Pcg64) -> (Tensor, Vec<i32>) {
        let n = self.side * self.side;
        let mut data = Vec::with_capacity(b * n);
        let mut labels = Vec::with_capacity(b);
        for _ in 0..b {
            let cls = rng.below(self.classes);
            labels.push(cls as i32);
            let proto = &self.prototypes[cls];
            for &p in proto {
                data.push(p + (rng.normal() as f32) * self.noise);
            }
        }
        (Tensor::new(&[b, n], data), labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic() {
        let lang = Language::default_for(128, 7);
        let c1 = Corpus::build(&lang, 1000, 200, 9);
        let c2 = Corpus::build(&lang, 1000, 200, 9);
        assert_eq!(c1.train, c2.train);
        assert_eq!(c1.heldout, c2.heldout);
    }

    #[test]
    fn languages_differ() {
        let en = Language::by_name("en", 128);
        let de = Language::by_name("de", 128);
        let mut r1 = Pcg64::new(1);
        let mut r2 = Pcg64::new(1);
        assert_ne!(en.stream(200, &mut r1), de.stream(200, &mut r2));
    }

    #[test]
    fn stream_is_predictable_not_uniform() {
        // a Markov-Zipf stream has strongly non-uniform bigram stats
        let lang = Language::default_for(64, 3);
        let mut rng = Pcg64::new(5);
        let s = lang.stream(20_000, &mut rng);
        let mut bigram = std::collections::HashMap::new();
        for w in s.windows(2) {
            *bigram.entry((w[0], w[1])).or_insert(0usize) += 1;
        }
        let max = *bigram.values().max().unwrap();
        let expected_uniform = 20_000.0 / (64.0 * 64.0);
        assert!(max as f64 > 8.0 * expected_uniform, "max={max}");
    }

    #[test]
    fn tokens_in_vocab() {
        let lang = Language::by_name("fr", 128);
        let mut rng = Pcg64::new(2);
        for t in lang.stream(5000, &mut rng) {
            assert!((0..128).contains(&t));
        }
    }

    #[test]
    fn batch_shapes() {
        let lang = Language::default_for(128, 1);
        let c = Corpus::build(&lang, 5000, 1000, 2);
        let mut rng = Pcg64::new(3);
        let b = c.batch(4, 32, &mut rng);
        assert_eq!(b.len(), 4 * 33);
        let w = c.heldout_windows(8, 32);
        assert_eq!(w.len(), 8);
        assert!(w.iter().all(|s| s.len() == 33));
    }

    #[test]
    fn heldout_windows_disjoint_and_capped() {
        let lang = Language::default_for(64, 4);
        let c = Corpus::build(&lang, 100, 100, 5);
        let w = c.heldout_windows(100, 32);
        assert_eq!(w.len(), 3); // 100 / 33
    }

    #[test]
    fn images_cluster_by_class() {
        let set = ImageSet::new(16, 10, 1, 0.3);
        let mut rng = Pcg64::new(6);
        let (x, labels) = set.batch(64, &mut rng);
        // same-class pairs must be closer than cross-class pairs on average
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(&p, &q)| ((p - q) as f64).powi(2)).sum()
        };
        let mut same = (0.0, 0);
        let mut diff = (0.0, 0);
        for i in 0..64 {
            for j in i + 1..64 {
                let d = dist(x.row(i), x.row(j));
                if labels[i] == labels[j] {
                    same = (same.0 + d, same.1 + 1);
                } else {
                    diff = (diff.0 + d, diff.1 + 1);
                }
            }
        }
        if same.1 > 0 && diff.1 > 0 {
            assert!(same.0 / same.1 as f64 > 0.0);
            assert!(same.0 / same.1 as f64 <= diff.0 / diff.1 as f64);
        }
    }
}
