//! The L3 coordinator: everything between the CLI and the PJRT runtime.
//!
//! * [`pipeline`] — the PTQ pipeline: checkpoint + format + method ->
//!   artifact-ready quantized parameter set (RTN / MSE / GPTQ / SmoothQuant).
//! * [`model`] — `LmHandle`: a model's executables with device-resident
//!   weights, implementing [`crate::tasks::LmScorer`].
//! * [`trainer`] — drives the fused AOT train-step artifacts to train the
//!   model zoo on synthetic corpora (the E2E path).
//! * [`serve`] — one-shot scoring compatibility shim over the
//!   continuous-batching decode engine in [`crate::serving`].
//! * [`runner`] — experiment grid scheduler over a worker pool.

pub mod model;
pub mod pipeline;
pub mod runner;
pub mod serve;
pub mod trainer;

pub use model::LmHandle;
pub use pipeline::{PipelineConfig, QuantMethod, QuantizedModel};
pub use runner::{run_grid, GridJob};
pub use serve::{ServeConfig, ServeStats, Server};

use anyhow::Result;

use crate::data::{Corpus, Language};
use crate::model_io::ModelConfig;

/// Shared experiment context: engine + directories.
pub struct Session {
    pub engine: crate::runtime::Engine,
    pub checkpoints_dir: String,
    pub results_dir: String,
}

impl Session {
    pub fn open(artifacts: &str, checkpoints: &str, results: &str) -> Result<Session> {
        Ok(Session {
            engine: crate::runtime::Engine::cpu(artifacts)?,
            checkpoints_dir: checkpoints.to_string(),
            results_dir: results.to_string(),
        })
    }

    pub fn corpus_for(&self, cfg: &ModelConfig) -> Corpus {
        corpus_for(cfg)
    }

    pub fn load_checkpoint(&self, model: &str) -> Result<crate::model_io::Checkpoint> {
        crate::model_io::Checkpoint::load(crate::model_io::checkpoint_path(
            &self.checkpoints_dir,
            model,
        ))
    }
}

/// Deterministic corpus for a zoo model: each model trains/evals on its own
/// language seed, so zoo members play the role of "different models" in the
/// paper's tables.
pub fn corpus_for(cfg: &ModelConfig) -> Corpus {
    let seed = cfg.name.bytes().fold(0u64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64));
    let lang = Language::default_for(cfg.vocab, seed);
    // train stream sized generously relative to the model's step budget
    let train_len = (cfg.train_steps * cfg.batch_train * (cfg.seq + 1) / 2).max(200_000);
    Corpus::build(&lang, train_len, 120_000, seed ^ 0x5eed)
}

/// Corpus in a specific "language" (Table 14 multi-lingual suite): the
/// model's own Markov chain structure (same permutation seed as its
/// training corpus) with language-specific Zipf exponent and smoothing —
/// related-but-shifted statistics, like the multilingual LAMBADA variants.
pub fn corpus_for_language(cfg: &ModelConfig, language: &str) -> Corpus {
    let base_seed = cfg.name.bytes().fold(0u64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64));
    let (name, zipf_s, _, smooth) = crate::data::LANGUAGES
        .iter()
        .copied()
        .find(|(l, ..)| *l == language)
        .unwrap_or(crate::data::LANGUAGES[0]);
    let lang = Language::new(name, cfg.vocab, zipf_s, base_seed, smooth);
    Corpus::build(&lang, 200_000, 120_000, base_seed ^ 0x7ab1e14)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_io::zoo;

    #[test]
    fn corpus_for_is_deterministic_and_distinct() {
        let a = corpus_for(&zoo("nano").unwrap());
        let b = corpus_for(&zoo("nano").unwrap());
        assert_eq!(a.train[..100], b.train[..100]);
        let c = corpus_for(&zoo("micro").unwrap());
        assert_ne!(a.train[..100], c.train[..100]);
    }

    #[test]
    fn language_corpora_differ() {
        let cfg = zoo("micro").unwrap();
        let en = corpus_for_language(&cfg, "en");
        let de = corpus_for_language(&cfg, "de");
        assert_ne!(en.train[..64], de.train[..64]);
    }
}
