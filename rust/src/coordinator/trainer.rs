//! Training driver: executes the fused AOT `lm_train_*` / `cls_train_*`
//! artifacts step by step, feeding each step's outputs back as the next
//! step's inputs. Python authored the graph once; Rust owns the loop, the
//! data order, the logging and the checkpointing.

use anyhow::{Context, Result};

use crate::data::{Corpus, ImageSet};
use crate::model_io::{checkpoint_path, Checkpoint, ModelConfig};
use crate::nn::ClsConfig;
use crate::rng::Pcg64;
use crate::runtime::{Engine, Value};
use crate::tensor::Tensor;

/// Loss trace of one training run (step, loss).
pub type LossTrace = Vec<(usize, f32)>;

/// Fresh LM parameters (heavy-tailed Student-t init, see comment below).
/// Public because the serving engine, benches and CLI use it as a
/// checkpoint-less fallback for the pure-Rust decode path.
pub fn init_lm_params(cfg: &ModelConfig, seed: u64) -> Checkpoint {
    let mut rng = Pcg64::new(seed);
    let mut c = Checkpoint::new();
    for (name, shape) in cfg.param_specs() {
        let n: usize = shape.iter().product();
        let leaf = name.rsplit('.').next().unwrap();
        let t = if leaf.ends_with("_g") {
            Tensor::full(&shape, 1.0)
        } else if leaf.ends_with("_b") {
            Tensor::zeros(&shape)
        } else if leaf == "embed" || leaf == "pos" {
            Tensor::new(&shape, rng.normal_vec(n, 0.02))
        } else {
            // Student-t(nu=5) init: zoo models carry the heavy-tailed weight
            // distribution the paper measures on trained LLMs (Table 1 finds
            // nu ~= 5; brief synthetic training cannot reproduce the long
            // training that produces it, so we plant it — DESIGN.md §2).
            // t(5) has variance nu/(nu-2); rescale to He-init variance.
            let std = (2.0 / shape[0] as f64 / (5.0 / 3.0)).sqrt();
            Tensor::new(&shape, rng.student_t_vec(n, 5.0, std))
        };
        c.insert(&name, t);
    }
    c
}

/// Train one zoo LM on its corpus; returns (checkpoint, loss trace).
pub fn train_lm(
    engine: &Engine,
    cfg: &ModelConfig,
    corpus: &Corpus,
    steps: usize,
    seed: u64,
    log_every: usize,
) -> Result<(Checkpoint, LossTrace)> {
    let exe = engine
        .load(&format!("lm_train_{}", cfg.name))
        .with_context(|| format!("train artifact for {}", cfg.name))?;
    let specs = cfg.param_specs();
    let init = init_lm_params(cfg, seed);
    let mut params: Vec<Value> =
        specs.iter().map(|(n, _)| Value::F32(init.get(n).unwrap().clone())).collect();
    let mut m: Vec<Value> = specs.iter().map(|(_, s)| Value::F32(Tensor::zeros(s))).collect();
    let mut v: Vec<Value> = specs.iter().map(|(_, s)| Value::F32(Tensor::zeros(s))).collect();

    let mut rng = Pcg64::with_stream(seed, 0x7e41);
    let mut trace = Vec::new();
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let tokens = corpus.batch(cfg.batch_train, cfg.seq, &mut rng);
        let mut inputs = Vec::with_capacity(2 + 3 * specs.len());
        inputs.push(Value::F32(Tensor::scalar(step as f32)));
        inputs.push(Value::I32(tokens, vec![cfg.batch_train, cfg.seq + 1]));
        inputs.extend(params.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        let outs = exe.run(&inputs)?;
        let loss = outs[0].scalar_f32()?;
        anyhow::ensure!(loss.is_finite(), "step {step}: loss diverged ({loss})");
        let np = specs.len();
        params = outs[1..1 + np].to_vec();
        m = outs[1 + np..1 + 2 * np].to_vec();
        v = outs[1 + 2 * np..1 + 3 * np].to_vec();
        if step % log_every == 0 || step + 1 == steps {
            trace.push((step, loss));
            eprintln!(
                "[train {}] step {step:>4}/{steps} loss {loss:.4} ({:.1}s)",
                cfg.name,
                t0.elapsed().as_secs_f32()
            );
        }
    }

    let mut ckpt = Checkpoint::new();
    for ((name, _), val) in specs.iter().zip(&params) {
        ckpt.insert(name, val.as_f32()?.clone());
    }
    Ok((ckpt, trace))
}

/// Train + save a zoo model; writes `<dir>/<name>.ckpt` and the loss trace
/// TSV alongside it. No-op if the checkpoint already exists (idempotent).
pub fn train_and_save(
    engine: &Engine,
    cfg: &ModelConfig,
    corpus: &Corpus,
    dir: &str,
    force: bool,
) -> Result<Checkpoint> {
    let path = checkpoint_path(dir, cfg.name);
    if path.exists() && !force {
        eprintln!("[train {}] checkpoint exists, skipping", cfg.name);
        return Checkpoint::load(&path);
    }
    let (ckpt, trace) = train_lm(engine, cfg, corpus, cfg.train_steps, 0xC0FFEE, 10)?;
    ckpt.save(&path)?;
    let mut tsv = String::from("step\tloss\n");
    for (s, l) in &trace {
        tsv.push_str(&format!("{s}\t{l}\n"));
    }
    std::fs::write(path.with_extension("loss.tsv"), tsv)?;
    Ok(ckpt)
}

// ---------------------------------------------------------------------------
// Classifier training (vision roles)
// ---------------------------------------------------------------------------

fn init_cls_params(cfg: &ClsConfig, seed: u64) -> Checkpoint {
    let mut rng = Pcg64::new(seed);
    let mut c = Checkpoint::new();
    for (name, shape) in cfg.param_specs() {
        let n: usize = shape.iter().product();
        let t = if shape.len() == 1 {
            Tensor::zeros(&shape)
        } else {
            Tensor::new(&shape, rng.normal_vec(n, (2.0 / shape[0] as f64).sqrt()))
        };
        c.insert(&name, t);
    }
    c
}

/// Train a classifier on a synthetic image set.
pub fn train_cls(
    engine: &Engine,
    cfg: &ClsConfig,
    images: &ImageSet,
    steps: usize,
    seed: u64,
) -> Result<(Checkpoint, LossTrace)> {
    let exe = engine.load(&format!("cls_train_{}", cfg.name))?;
    let specs = cfg.param_specs();
    let init = init_cls_params(cfg, seed);
    let mut params: Vec<Value> =
        specs.iter().map(|(n, _)| Value::F32(init.get(n).unwrap().clone())).collect();
    let mut m: Vec<Value> = specs.iter().map(|(_, s)| Value::F32(Tensor::zeros(s))).collect();
    let mut v: Vec<Value> = specs.iter().map(|(_, s)| Value::F32(Tensor::zeros(s))).collect();
    let mut rng = Pcg64::with_stream(seed, 0xc15);
    let mut trace = Vec::new();
    for step in 0..steps {
        let (x, labels) = images.batch(cfg.batch_train, &mut rng);
        let mut inputs = vec![
            Value::F32(Tensor::scalar(step as f32)),
            Value::F32(x),
            Value::I32(labels, vec![cfg.batch_train]),
        ];
        inputs.extend(params.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        let outs = exe.run(&inputs)?;
        let loss = outs[0].scalar_f32()?;
        let np = specs.len();
        params = outs[1..1 + np].to_vec();
        m = outs[1 + np..1 + 2 * np].to_vec();
        v = outs[1 + 2 * np..1 + 3 * np].to_vec();
        if step % 50 == 0 || step + 1 == steps {
            trace.push((step, loss));
        }
    }
    let mut ckpt = Checkpoint::new();
    for ((name, _), val) in specs.iter().zip(&params) {
        ckpt.insert(name, val.as_f32()?.clone());
    }
    Ok((ckpt, trace))
}

/// Train + save a classifier (idempotent like `train_and_save`).
pub fn train_cls_and_save(
    engine: &Engine,
    cfg: &ClsConfig,
    images: &ImageSet,
    dir: &str,
    force: bool,
) -> Result<Checkpoint> {
    let path = checkpoint_path(dir, &format!("cls_{}", cfg.name));
    if path.exists() && !force {
        return Checkpoint::load(&path);
    }
    let (ckpt, trace) = train_cls(engine, cfg, images, cfg.train_steps, 0xBEEF)?;
    ckpt.save(&path)?;
    let last = trace.last().map(|(_, l)| *l).unwrap_or(f32::NAN);
    eprintln!("[train cls_{}] final loss {last:.4}", cfg.name);
    Ok(ckpt)
}
